"""Training launcher.

Examples:
  # tiny end-to-end run on host devices (CPU container):
  PYTHONPATH=src python -m repro.launch.train --arch llama3.2-3b --smoke \
      --steps 50 --batch 8 --seq 128

  # production lowering check for a full config (no execution):
  PYTHONPATH=src python -m repro.launch.dryrun --arch llama3.2-3b \
      --shape train_4k --mesh single
"""

from __future__ import annotations

import argparse

import jax

from repro.configs import get_config, smoke_config
from repro.data import DataConfig
from repro.models import build_model
from repro.optim import AdamWConfig
from repro.train.loop import TrainConfig, train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--opt-dtype", default="float32",
                    choices=["float32", "int8"])
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    args = ap.parse_args()

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    model = build_model(cfg)
    data_cfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                          global_batch=args.batch)
    tcfg = TrainConfig(
        steps=args.steps, lr=args.lr, ckpt_dir=args.ckpt_dir,
        ckpt_every=args.ckpt_every, microbatches=args.microbatches,
        opt=AdamWConfig(state_dtype=args.opt_dtype))
    print(f"[train] arch={cfg.name} params={cfg.param_count()/1e6:.1f}M "
          f"devices={len(jax.devices())}")
    _, _, history = train(model, data_cfg, tcfg)
    if history:
        print(f"[train] first loss {history[0]['loss']:.4f} → "
              f"last loss {history[-1]['loss']:.4f}")


if __name__ == "__main__":
    main()
