"""Serving launcher: batched requests through the continuous-batching
engine on a reduced (CPU-runnable) config.

  PYTHONPATH=src python -m repro.launch.serve --arch gemma2-2b \
      --requests 6 --prompt-len 16 --new-tokens 24
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import smoke_config
from repro.models import build_model
from repro.serve import Engine, Request, ServeConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-3b")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=24)
    ap.add_argument("--max-batch", type=int, default=4)
    args = ap.parse_args()

    cfg = smoke_config(args.arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    engine = Engine(model, params, ServeConfig(
        max_batch=args.max_batch, max_len=args.prompt_len + args.new_tokens
        + 8, max_new_tokens=args.new_tokens))

    rng = np.random.default_rng(0)
    reqs = [Request(prompt=list(rng.integers(1, cfg.vocab_size,
                                             args.prompt_len)),
                    request_id=i) for i in range(args.requests)]
    t0 = time.time()
    engine.run(reqs)
    dt = time.time() - t0
    total = sum(len(r.out_tokens) for r in reqs)
    print(f"[serve] {args.requests} requests, {total} tokens in {dt:.2f}s "
          f"({total/dt:.1f} tok/s on {len(jax.devices())} host device(s))")
    for r in reqs[:3]:
        print(f"  req{r.request_id}: {r.out_tokens[:12]}...")


if __name__ == "__main__":
    main()
