"""Serving launcher: batched requests through the serving engines on
reduced (CPU-runnable) configs.

LM workload — continuous-batching decode:

  PYTHONPATH=src python -m repro.launch.serve --workload lm \
      --arch gemma2-2b --requests 6 --prompt-len 16 --new-tokens 24

CNN workload — plan-driven dynamic batching via ``repro.runtime``: the
deployment planner picks each layer's block/bits for the device (or a
saved plan artifact is loaded verbatim), every batch bucket is
AOT-compiled before serving, and each tick dispatches the live images
to the smallest bucket that fits:

  PYTHONPATH=src python -m repro.launch.serve --workload cnn \
      --requests 64 --max-batch 16 [--device v5e] [--shard] \
      [--save-plan plan.json]

  # serve a previously planned artifact (possibly from another machine)
  PYTHONPATH=src python -m repro.launch.serve --workload cnn \
      --plan plan.json --requests 64
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np


def run_lm(args) -> None:
    from repro.configs import smoke_config
    from repro.models import build_model
    from repro.serve import Engine, Request, ServeConfig

    cfg = smoke_config(args.arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    engine = Engine(model, params, ServeConfig(
        max_batch=args.max_batch, max_len=args.prompt_len + args.new_tokens
        + 8, max_new_tokens=args.new_tokens))

    rng = np.random.default_rng(0)
    reqs = [Request(prompt=list(rng.integers(1, cfg.vocab_size,
                                             args.prompt_len)),
                    request_id=i) for i in range(args.requests)]
    t0 = time.time()
    engine.run(reqs)
    dt = time.time() - t0
    total = sum(len(r.out_tokens) for r in reqs)
    print(f"[serve] {args.requests} requests, {total} tokens in {dt:.2f}s "
          f"({total/dt:.1f} tok/s on {len(jax.devices())} host device(s))")
    for r in reqs[:3]:
        print(f"  req{r.request_id}: {r.out_tokens[:12]}...")


def run_cnn(args) -> None:
    from repro import runtime
    from repro.core import allocate, deploy
    from repro.core.cnn import fitted_block_models, quickstart_cnn_config
    from repro.kernels import ops
    from repro.parallel.sharding import cnn_data_mesh
    from repro.serve import CNNEngine, CNNServeConfig, ImageRequest

    if args.plan:
        plan = runtime.load_plan(args.plan)
        print(f"[serve] loaded plan artifact {args.plan!r} "
              f"(planned for device {plan.device.name})")
    else:
        cfg = quickstart_cnn_config()
        bm = fitted_block_models()
        device = allocate.get_device(args.device)
        plan = deploy.plan_deployment(cfg, bm, device, target=0.8,
                                      on_infeasible="fallback")
    if args.save_plan:                 # also re-exports a loaded --plan
        runtime.save_plan(plan, args.save_plan)
        print(f"[serve] plan artifact saved to {args.save_plan!r}")
    print(f"[serve] plan for {plan.device.name}: "
          + ", ".join(f"L{a.index}={a.block}@d{a.data_bits}/c{a.coeff_bits}"
                      for a in plan.layers))

    mesh = cnn_data_mesh() if args.shard else None
    t0 = time.time()
    engine = CNNEngine.from_plan(           # AOT-compiles every bucket
        plan, serve_cfg=CNNServeConfig(max_batch=args.max_batch),
        mesh=mesh)
    print(f"[serve] AOT warmup: {len(engine.compiled.buckets)} buckets × "
          f"{len(engine.cfg.layers)} layers compiled in "
          f"{time.time() - t0:.2f}s (off the serving critical path)")

    rng = np.random.default_rng(0)
    d0 = engine.cfg.layers[0].data_bits
    reqs = [ImageRequest(
        image=np.asarray(ops.quantize_fixed(
            rng.integers(0, 1 << (d0 - 1),
                         engine.in_shape).astype(np.float32), d0)),
        request_id=i) for i in range(args.requests)]
    t0 = time.time()
    engine.run(reqs)
    dt = time.time() - t0
    stats = engine.stats()
    print(f"[serve] {len(reqs)} images in {dt:.2f}s "
          f"({len(reqs)/dt:.1f} images/s, "
          f"{stats['images_per_step']:.1f} images/step) on "
          f"{len(jax.devices())} host device(s)"
          + (f", batch sharded over mesh {dict(mesh.shape)}" if mesh
             else ""))
    print(f"[serve] occupancy histogram: {stats['occupancy_hist']}  "
          f"bucket hits: {stats['bucket_hits']}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--workload", choices=("lm", "cnn"), default="lm")
    ap.add_argument("--arch", default="llama3.2-3b")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=24)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--device", default="v5e",
                    help="deployment-planner device profile (cnn)")
    ap.add_argument("--plan", default=None,
                    help="serve a saved DeploymentPlan JSON artifact "
                         "instead of re-planning (cnn)")
    ap.add_argument("--save-plan", default=None,
                    help="write the computed plan to this JSON path (cnn)")
    ap.add_argument("--shard", action="store_true",
                    help="shard the image batch over host devices (cnn)")
    args = ap.parse_args()
    if args.workload == "cnn":
        run_cnn(args)
    else:
        run_lm(args)


if __name__ == "__main__":
    main()
