"""Serving launcher: batched requests through the serving engines on
reduced (CPU-runnable) configs.

LM workload — continuous-batching decode:

  PYTHONPATH=src python -m repro.launch.serve --workload lm \
      --arch gemma2-2b --requests 6 --prompt-len 16 --new-tokens 24

CNN workload — plan-driven dynamic batching via ``repro.runtime``: the
deployment planner picks each layer's block/bits for the device (or a
saved plan artifact is loaded verbatim), every batch bucket is
AOT-compiled before serving, and each tick dispatches the live images
to the smallest bucket that fits:

  PYTHONPATH=src python -m repro.launch.serve --workload cnn \
      --requests 64 --max-batch 16 [--device v5e] [--shard] \
      [--save-plan plan.json]

  # serve a previously planned artifact (possibly from another machine)
  PYTHONPATH=src python -m repro.launch.serve --workload cnn \
      --plan plan.json --requests 64

Async CNN workload — the continuous-batching gateway under Poisson
arrivals: bounded admission (overload is shed at the door), deadline-
aware batch formation, a new bucket dispatch the moment slots free:

  PYTHONPATH=src python -m repro.launch.serve --workload cnn --async \
      --requests 128 --max-batch 8 --occupancy 2.0 \
      [--deadline-ms 250] [--max-pending 32] \
      [--wait-budget-ms 100] [--max-inflight 2]

Fleet workload — the multi-worker front door from ``repro.fleet``: one
gateway per device profile (edge / v5e / v5p, each serving the plan the
deployment planner picked for that profile), tiered Poisson traffic
placed by a pluggable router, optional mid-trace graceful drain:

  PYTHONPATH=src python -m repro.launch.serve --workload cnn --fleet \
      --requests 96 --occupancy 1.5 [--router plan_aware] [--drain]

MoE workload — the same plan→compile→serve stack, different backend:
``plan_moe_deployment`` picks per-layer (data_bits, coeff_bits) for the
quantized expert FFNs, ``compile_plan`` builds the bucketed AOT
``CompiledMoE``, and the identical engines serve token blocks instead
of images:

  PYTHONPATH=src python -m repro.launch.serve --workload moe \
      --requests 32 --max-batch 8 [--device v5e] [--arch qwen3-moe-30b-a3b] \
      [--save-plan moe_plan.json] [--async --occupancy 2.0]
"""

from __future__ import annotations

import argparse
import asyncio
import time

import jax
import numpy as np


def _percentiles(lat_s):
    p = np.percentile(np.asarray(lat_s) * 1e3, [50, 95, 99])
    return {"p50_ms": p[0], "p95_ms": p[1], "p99_ms": p[2]}


# -- durable serving state (repro.ops) flags --------------------------------
def _apply_store_root(args):
    """``--store-root`` → one shared ``repro.ops.StoreRoot`` standing in
    for both ``--plan-store`` and ``--cache-dir``: every worker process
    pointed at the same DIR shares one plan repository and one
    content-addressed executable cache — which is what lets a respawned
    worker rebuild its predecessor's serving state with zero recompiles
    (see ``repro.chaos.respawn_gateway``)."""
    if not getattr(args, "store_root", None):
        return
    if args.plan_store or args.cache_dir:
        raise SystemExit("--store-root replaces --plan-store and "
                         "--cache-dir; give one or the other")
    from repro.ops import StoreRoot
    root = StoreRoot(args.store_root)
    args.plan_store = str(root.root)
    args.cache_dir = str(root.exec_cache_dir)
    print(f"[ops] shared store root at {args.store_root!r} "
          f"(plans + exec cache + leases)")


def _ops_cache(args):
    """``--cache-dir`` → a ``PersistentExecutableCache`` every compile
    in this process writes through; None without the flag (the callers
    fall back to an in-memory cache)."""
    if not getattr(args, "cache_dir", None):
        return None
    from repro.ops import PersistentExecutableCache
    cache = PersistentExecutableCache(args.cache_dir)
    print(f"[ops] persistent executable cache at {args.cache_dir!r}")
    return cache


def _ops_tracker(args):
    """``--metrics-out`` → a ``JsonlTracker``; None without the flag."""
    if not getattr(args, "metrics_out", None):
        return None
    from repro.ops import JsonlTracker
    tracker = JsonlTracker(args.metrics_out)
    print(f"[ops] metrics JSONL → {args.metrics_out!r}")
    return tracker


def _ops_sampler(tracker, sources, interval_s=0.5):
    if tracker is None:
        return None
    from repro.ops import StatsSampler
    return StatsSampler(tracker, sources, interval_s=interval_s)


def _ops_finish(tracker, sampler=None, cache=None):
    """Flush ops state at the end of a run and say where it went."""
    if sampler is not None:
        sampler.close()
    if tracker is not None:
        tracker.close()
        print(f"[ops] metrics: {tracker.recorded} records "
              f"({tracker.dropped} dropped) → {tracker.path}")
    if cache is not None:
        s = cache.stats()
        print(f"[ops] exec cache: {s['compiles']} compiled, "
              f"{s['disk_hits']} loaded from disk, "
              f"{s['disk_stores']} persisted")


def _plan_from_store(args, workload: str, compute):
    """Resolve the plan through ``--plan-store`` when set: serve the
    stored plan under ``<workload>-<device>`` if present, otherwise run
    ``compute()`` and persist the result — the next launch loads it."""
    from repro.ops import PlanStore
    store = PlanStore(args.plan_store)
    store_id = f"{workload}-{args.device}"
    if store_id in store:
        plan = store.load(store_id)
        print(f"[serve] loaded plan {store_id!r} from store "
              f"{args.plan_store!r}")
        return plan
    plan = compute()
    store.save(plan, store_id)
    print(f"[serve] plan {store_id!r} saved to store {args.plan_store!r}")
    return plan


def run_lm(args) -> None:
    from repro.configs import smoke_config
    from repro.models import build_model
    from repro.serve import Engine, Request, ServeConfig

    cfg = smoke_config(args.arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    engine = Engine(model, params, ServeConfig(
        max_batch=args.max_batch, max_len=args.prompt_len + args.new_tokens
        + 8, max_new_tokens=args.new_tokens))

    tracker = _ops_tracker(args)
    sampler = _ops_sampler(
        tracker, {"engine": lambda: engine.snapshot().asdict()})
    rng = np.random.default_rng(0)
    reqs = [Request(prompt=list(rng.integers(1, cfg.vocab_size,
                                             args.prompt_len)),
                    request_id=i) for i in range(args.requests)]
    t0 = time.time()
    engine.run(reqs)
    dt = time.time() - t0
    total = sum(len(r.out_tokens) for r in reqs)
    print(f"[serve] {args.requests} requests, {total} tokens in {dt:.2f}s "
          f"({total/dt:.1f} tok/s on {len(jax.devices())} host device(s))")
    for r in reqs[:3]:
        print(f"  req{r.request_id}: {r.out_tokens[:12]}...")
    _ops_finish(tracker, sampler)


def _cnn_plan(args):
    """Load or compute the deployment plan the CNN workloads serve."""
    from repro import runtime
    from repro.core import allocate, deploy
    from repro.core.cnn import fitted_block_models, quickstart_cnn_config

    def compute():
        cfg = quickstart_cnn_config()
        bm = fitted_block_models()
        device = allocate.get_device(args.device)
        return deploy.plan_deployment(cfg, bm, device, target=0.8,
                                      on_infeasible="fallback")

    if args.plan:
        plan = runtime.load_plan(args.plan)
        print(f"[serve] loaded plan artifact {args.plan!r} "
              f"(planned for device {plan.device.name})")
    elif args.plan_store:
        plan = _plan_from_store(args, "cnn", compute)
    else:
        plan = compute()
    if args.save_plan:                 # also re-exports a loaded --plan
        runtime.save_plan(plan, args.save_plan)
        print(f"[serve] plan artifact saved to {args.save_plan!r}")
    print(f"[serve] plan for {plan.device.name}: "
          + ", ".join(f"L{a.index}={a.block}@d{a.data_bits}/c{a.coeff_bits}"
                      for a in plan.layers))
    return plan


def _moe_plan(args):
    """Load or plan the quantized-MoE deployment the MoE workload
    serves.  ``--arch`` (a zoo MoE config, shrunk via ``smoke_config``)
    seeds the workload spec; ``--plan``/``--save-plan`` round-trip the
    v2 plan artifact exactly like the CNN path."""
    from repro import runtime
    from repro.configs import smoke_config
    from repro.runtime import moe_workload_from_config, plan_moe_deployment

    def compute():
        spec = moe_workload_from_config(smoke_config(args.arch))
        return plan_moe_deployment(spec, args.device, target=0.8,
                                   on_infeasible="fallback")

    if args.plan:
        plan = runtime.load_plan(args.plan)
        print(f"[serve] loaded plan artifact {args.plan!r} "
              f"(planned for device {plan.device.name}, "
              f"workload {plan.workload.kind!r})")
    elif args.plan_store:
        plan = _plan_from_store(args, "moe", compute)
    else:
        plan = compute()
    if args.save_plan:
        runtime.save_plan(plan, args.save_plan)
        print(f"[serve] plan artifact saved to {args.save_plan!r}")
    print(f"[serve] plan for {plan.device.name}: "
          + ", ".join(f"L{a.index}={a.block}@d{a.data_bits}/c{a.coeff_bits}"
                      for a in plan.layers)
          + f"  (quant rel-err {plan.quant_error:.4f})")
    return plan


def run_moe(args) -> None:
    """Quantized-MoE serving through the *same* engine as the CNN path:
    ``CNNEngine.from_plan`` dispatches on the plan's workload kind, so
    the tick loop, bucketing, and stats below are untouched code."""
    from repro.serve import CNNEngine, CNNServeConfig, ImageRequest

    plan = _moe_plan(args)
    cache = _ops_cache(args)
    tracker = _ops_tracker(args)
    t0 = time.time()
    engine = CNNEngine.from_plan(
        plan, serve_cfg=CNNServeConfig(max_batch=args.max_batch),
        exec_cache=cache)
    sampler = _ops_sampler(tracker, {"engine": engine.stats})
    compiled = engine.compiled
    print(f"[serve] AOT warmup: {len(compiled.buckets)} buckets × "
          f"{compiled.num_layers} MoE layers compiled in "
          f"{time.time() - t0:.2f}s (off the serving critical path)")

    reqs = [ImageRequest(image=x, request_id=i) for i, x in
            enumerate(compiled.sample_inputs(args.requests))]
    t0 = time.time()
    engine.run(reqs)
    dt = time.time() - t0
    stats = engine.stats()
    seq_len = compiled.in_shape[0]
    print(f"[serve] {len(reqs)} token blocks ({len(reqs) * seq_len} "
          f"tokens) in {dt:.2f}s ({len(reqs) * seq_len / dt:.0f} tok/s, "
          f"{stats['images_per_step']:.1f} blocks/step)")
    print(f"[serve] occupancy histogram: {stats['occupancy_hist']}  "
          f"bucket hits: {stats['bucket_hits']}")
    _ops_finish(tracker, sampler, cache)


def run_moe_async(args) -> None:
    """The async gateway serving MoE token blocks — identical driver to
    ``run_cnn_async`` because the gateway is plan-type-blind."""
    from repro.serve import (AsyncCNNGateway, AsyncServeConfig,
                            DeadlineExpired, GatewayBacklog)

    plan = _moe_plan(args)
    cache = _ops_cache(args)
    tracker = _ops_tracker(args)
    t0 = time.time()
    gw = AsyncCNNGateway.from_plan(
        plan, AsyncServeConfig(max_batch=args.max_batch,
                               max_pending=args.max_pending,
                               max_inflight=args.max_inflight),
        plan_id="moe", exec_cache=cache, tracker=tracker)
    sampler = _ops_sampler(tracker, {"gateway": gw.stats})
    compiled = gw.plans["moe"].compiled
    print(f"[serve] AOT warmup: {len(compiled.buckets)} buckets × "
          f"{compiled.num_layers} MoE layers in {time.time() - t0:.2f}s")

    blocks = compiled.sample_inputs(args.requests)
    xb = np.stack([np.asarray(b, compiled.in_dtype)
                   for b in blocks[:args.max_batch]])
    compiled(xb)                                   # touch
    t0 = time.perf_counter()
    jax.block_until_ready(compiled(xb))
    step_s = time.perf_counter() - t0
    rate = args.occupancy * args.max_batch / step_s
    print(f"[serve] full-batch step {step_s * 1e3:.2f}ms → offered load "
          f"{rate:.0f} blocks/s (occupancy {args.occupancy:g})")

    deadline = args.deadline_ms / 1e3 if args.deadline_ms else None
    rng = np.random.default_rng(1)
    arrivals = np.cumsum(rng.exponential(1.0 / rate, args.requests))

    async def drive():
        latencies, shed = [], 0
        async with gw:
            t_start = time.monotonic()

            async def one(i, at):
                nonlocal shed
                await asyncio.sleep(max(0.0, at - (time.monotonic()
                                                   - t_start)))
                t_sub = time.monotonic()
                try:
                    fut = gw.submit_nowait(blocks[i], deadline=deadline)
                    await fut
                    latencies.append(time.monotonic() - t_sub)
                except GatewayBacklog:
                    shed += 1
                except DeadlineExpired:
                    pass
            await asyncio.gather(*(one(i, a)
                                   for i, a in enumerate(arrivals)))
            return latencies, shed, time.monotonic() - t_start

    latencies, shed, wall = asyncio.run(drive())
    stats = gw.stats()
    pct = _percentiles(latencies) if latencies else {}
    seq_len = compiled.in_shape[0]
    print(f"[serve] {stats['served']} served / {shed} shed / "
          f"{stats['expired']} expired of {args.requests} in {wall:.2f}s "
          f"({stats['served'] * seq_len / wall:.0f} tok/s)")
    if pct:
        print(f"[serve] latency p50={pct['p50_ms']:.1f}ms "
              f"p95={pct['p95_ms']:.1f}ms p99={pct['p99_ms']:.1f}ms")
    _ops_finish(tracker, sampler, cache)


def run_cnn(args) -> None:
    from repro.parallel.sharding import cnn_data_mesh
    from repro.serve import CNNEngine, CNNServeConfig, ImageRequest

    plan = _cnn_plan(args)
    cache = _ops_cache(args)
    tracker = _ops_tracker(args)
    mesh = cnn_data_mesh() if args.shard else None
    t0 = time.time()
    engine = CNNEngine.from_plan(           # AOT-compiles every bucket
        plan, serve_cfg=CNNServeConfig(max_batch=args.max_batch),
        mesh=mesh, exec_cache=cache)
    sampler = _ops_sampler(tracker, {"engine": engine.stats})
    print(f"[serve] AOT warmup: {len(engine.compiled.buckets)} buckets × "
          f"{len(engine.cfg.layers)} layers compiled in "
          f"{time.time() - t0:.2f}s (off the serving critical path)")

    reqs = [ImageRequest(image=img, request_id=i) for i, img in
            enumerate(engine.compiled.sample_inputs(args.requests))]
    t0 = time.time()
    engine.run(reqs)
    dt = time.time() - t0
    stats = engine.stats()
    print(f"[serve] {len(reqs)} images in {dt:.2f}s "
          f"({len(reqs)/dt:.1f} images/s, "
          f"{stats['images_per_step']:.1f} images/step) on "
          f"{len(jax.devices())} host device(s)"
          + (f", batch sharded over mesh {dict(mesh.shape)}" if mesh
             else ""))
    print(f"[serve] occupancy histogram: {stats['occupancy_hist']}  "
          f"bucket hits: {stats['bucket_hits']}")
    _ops_finish(tracker, sampler, cache)


def run_cnn_async(args) -> None:
    """Continuous-batching gateway under Poisson arrivals at an offered
    load of ``--occupancy`` × the measured full-batch service capacity.
    Reports tail latency (p50/p95/p99 over *served* requests), shed and
    expired counts — the front-door view the tick loop cannot give."""
    from repro.parallel.sharding import cnn_data_mesh
    from repro.serve import (AsyncCNNGateway, AsyncServeConfig,
                             DeadlineExpired, GatewayBacklog)

    plan = _cnn_plan(args)
    cache = _ops_cache(args)
    tracker = _ops_tracker(args)
    mesh = cnn_data_mesh() if args.shard else None
    t0 = time.time()
    wait_budget = (args.wait_budget_ms / 1e3
                   if args.wait_budget_ms else None)
    gw = AsyncCNNGateway.from_plan(
        plan, AsyncServeConfig(max_batch=args.max_batch,
                               max_pending=args.max_pending,
                               max_inflight=args.max_inflight,
                               wait_budget_s=wait_budget),
        mesh=mesh, exec_cache=cache, tracker=tracker)
    sampler = _ops_sampler(tracker, {"gateway": gw.stats})
    compiled = gw.plans["plan0"].compiled
    print(f"[serve] AOT warmup: {len(compiled.buckets)} buckets × "
          f"{len(compiled.cfg.layers)} layers compiled in "
          f"{time.time() - t0:.2f}s (shared exec cache: "
          f"{len(gw.exec_cache)} executables)")

    imgs = compiled.sample_inputs(args.requests)
    # service capacity: one timed full-batch dispatch → arrival rate
    xb = np.stack([np.asarray(i, compiled.in_dtype)
                   for i in imgs[:args.max_batch]])
    compiled(xb)                                   # touch
    t0 = time.perf_counter()
    jax.block_until_ready(compiled(xb))
    step_s = time.perf_counter() - t0
    rate = args.occupancy * args.max_batch / step_s
    print(f"[serve] full-batch step {step_s * 1e3:.2f}ms → offered load "
          f"{rate:.0f} images/s (occupancy {args.occupancy:g})")

    deadline = args.deadline_ms / 1e3 if args.deadline_ms else None
    rng = np.random.default_rng(1)
    gaps = rng.exponential(1.0 / rate, args.requests)

    async def drive():
        latencies, shed = [], 0
        async with gw:
            t_start = time.monotonic()

            async def one(i, at):
                nonlocal shed
                await asyncio.sleep(max(0.0, at - (time.monotonic()
                                                   - t_start)))
                t_sub = time.monotonic()
                try:
                    fut = gw.submit_nowait(imgs[i], deadline=deadline)
                    await fut
                    latencies.append(time.monotonic() - t_sub)
                except GatewayBacklog:
                    shed += 1
                except DeadlineExpired:
                    pass                           # counted by stats()

            arrivals = np.cumsum(gaps)
            await asyncio.gather(*(one(i, a)
                                   for i, a in enumerate(arrivals)))
            return latencies, shed, time.monotonic() - t_start

    latencies, shed, wall = asyncio.run(drive())
    stats = gw.stats()
    pct = _percentiles(latencies) if latencies else {}
    print(f"[serve] {stats['served']} served / {shed} shed / "
          f"{stats['expired']} expired of {args.requests} in {wall:.2f}s "
          f"({stats['served'] / wall:.1f} images/s)")
    if pct:
        print(f"[serve] latency p50={pct['p50_ms']:.1f}ms "
              f"p95={pct['p95_ms']:.1f}ms p99={pct['p99_ms']:.1f}ms")
    print(f"[serve] occupancy histogram: {stats['occupancy_hist']}  "
          f"policy: {stats['policy']}  pending bound: "
          f"{stats['max_pending']}"
          + (f" (adaptive, budget "
             f"{stats['wait_budget_s'] * 1e3:.0f}ms)"
             if stats['wait_budget_s'] else " (static)"))
    print(f"[serve] measured service rate "
          f"{stats['service_rate']:.0f} images/s, est wait "
          f"{stats['est_wait'] * 1e3:.1f}ms, shed at bound: "
          f"{stats['shed']}")
    _ops_finish(tracker, sampler, cache)


def run_cnn_fleet(args) -> None:
    """Plan-aware fleet front door: one gateway per device profile
    (each serving the plan the deployment planner picked for *that*
    profile under one shared plan id), tiered Poisson traffic routed
    by ``--router``, per-tier tail latency reported.  ``--drain``
    gracefully drains the v5e worker halfway through — queued requests
    re-route, in-flight batches finish, nothing is lost."""
    from repro.core import allocate, deploy
    from repro.core.cnn import fitted_block_models, quickstart_cnn_config
    from repro.fleet import DEFAULT_TIERS, Fleet, FleetWorker
    from repro.serve import AsyncCNNGateway, AsyncServeConfig

    cfg = quickstart_cnn_config()
    bm = fitted_block_models()
    profiles = ("edge", "v5e", "v5p")
    # one shared persistent cache across all profile gateways: the disk
    # entries are content-addressed by layer key, so layers identical
    # across the three per-profile plans deserialize once each
    cache = _ops_cache(args)
    tracker = _ops_tracker(args)
    t0 = time.time()
    workers = []
    for name in profiles:
        plan = deploy.plan_deployment(cfg, bm, allocate.get_device(name),
                                      target=0.8, on_infeasible="fallback")
        gw = AsyncCNNGateway.from_plan(
            plan, AsyncServeConfig(max_batch=args.max_batch,
                                   max_pending=args.max_pending),
            plan_id="cnn", exec_cache=cache, tracker=tracker)
        workers.append(FleetWorker(f"{name}0", gw, name))
    print(f"[fleet] {len(workers)} workers "
          f"({', '.join(f'{w.worker_id}:{w.profile.name}' for w in workers)})"
          f" AOT-warmed in {time.time() - t0:.2f}s")

    compiled = workers[1].gateway.plans["cnn"].compiled
    imgs = compiled.sample_inputs(args.requests)
    xb = np.stack([np.asarray(i, compiled.in_dtype)
                   for i in imgs[:args.max_batch]])
    compiled(xb)                                   # touch
    t0 = time.perf_counter()
    jax.block_until_ready(compiled(xb))
    step_s = time.perf_counter() - t0
    rate = args.occupancy * args.max_batch / step_s
    print(f"[fleet] offered load {rate:.0f} images/s "
          f"(occupancy {args.occupancy:g} of one worker), "
          f"router {args.router!r}")

    rng = np.random.default_rng(args.seed)
    arrivals = np.cumsum(rng.exponential(1.0 / rate, args.requests))
    tiers = list(DEFAULT_TIERS)
    shares = [t.share for t in DEFAULT_TIERS.values()]
    tier_of = rng.choice(len(tiers), size=args.requests, p=shares)

    async def drive():
        per_tier = {t: [] for t in tiers}
        expired = 0
        fleet = Fleet(workers, router=args.router, tracker=tracker)
        sampler = _ops_sampler(tracker, {"fleet": fleet.stats})
        async with fleet:
            t_start = time.monotonic()

            async def one(i):
                nonlocal expired
                await asyncio.sleep(max(0.0, arrivals[i]
                                        - (time.monotonic() - t_start)))
                tier = tiers[tier_of[i]]
                spec = DEFAULT_TIERS[tier]
                t_sub = time.monotonic()
                try:
                    fut = await fleet.submit(imgs[i], tier=tier,
                                             deadline=spec.deadline_s)
                    await fut
                    per_tier[tier].append(time.monotonic() - t_sub)
                except Exception:       # noqa: BLE001 — expired/shed
                    expired += 1

            async def drainer():
                await asyncio.sleep(arrivals[args.requests // 2])
                print("[fleet] draining v5e0 ...")
                await fleet.drain("v5e0")
                print("[fleet] v5e0 drained (in-flight finished, "
                      "queue re-routed)")

            tasks = [one(i) for i in range(args.requests)]
            if args.drain:
                tasks.append(drainer())
            await asyncio.gather(*tasks)
            stats = fleet.stats()
        if sampler is not None:
            sampler.close()
        return per_tier, expired, stats, time.monotonic() - t_start

    per_tier, expired, stats, wall = asyncio.run(drive())
    total = sum(len(v) for v in per_tier.values())
    print(f"[fleet] {total} served / {expired} expired-or-shed of "
          f"{args.requests} in {wall:.2f}s  (rerouted={stats['rerouted']}"
          f", retried={stats['retried']}, drains={stats['drains']})")
    for tier, lats in per_tier.items():
        if not lats:
            continue
        pct = _percentiles(lats)
        print(f"[fleet]   {tier:<12} n={len(lats):<5} "
              f"p50={pct['p50_ms']:.1f}ms p95={pct['p95_ms']:.1f}ms "
              f"p99={pct['p99_ms']:.1f}ms")
    for wid, w in stats["workers"].items():
        snap = w["snapshot"] or {}
        print(f"[fleet]   {wid:<8} profile={w['profile']:<5} "
              f"served={snap.get('served', 0):<5} "
              f"draining={w['draining']}")
    _ops_finish(tracker, cache=cache)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--workload", choices=("lm", "cnn", "moe"),
                    default="lm")
    ap.add_argument("--arch", default=None,
                    help="zoo architecture (lm: any; moe: one with MoE "
                         "blocks; default llama3.2-3b / "
                         "qwen3-moe-30b-a3b)")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=24)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--device", default="v5e",
                    help="deployment-planner device profile (cnn)")
    ap.add_argument("--plan", default=None,
                    help="serve a saved DeploymentPlan JSON artifact "
                         "instead of re-planning (cnn)")
    ap.add_argument("--save-plan", default=None,
                    help="write the computed plan to this JSON path (cnn)")
    ap.add_argument("--shard", action="store_true",
                    help="shard the image batch over host devices (cnn)")
    ap.add_argument("--async", dest="async_", action="store_true",
                    help="serve through the continuous-batching gateway "
                         "under Poisson arrivals (cnn)")
    ap.add_argument("--occupancy", type=float, default=1.0,
                    help="offered load as a multiple of full-batch "
                         "service capacity (cnn --async)")
    ap.add_argument("--max-pending", type=int, default=32,
                    help="gateway admission bound — the hard cap when "
                         "--wait-budget-ms makes it adaptive "
                         "(cnn --async)")
    ap.add_argument("--wait-budget-ms", type=float, default=None,
                    help="adaptive admission: size the pending bound to "
                         "measured service rate × this wait budget "
                         "(cnn --async)")
    ap.add_argument("--max-inflight", type=int, default=1,
                    help="concurrent gateway dispatches; 2 overlaps the "
                         "next batch with the one on-device "
                         "(cnn --async)")
    ap.add_argument("--deadline-ms", type=float, default=None,
                    help="per-request deadline; late requests are "
                         "expired, never served late (cnn --async)")
    ap.add_argument("--fleet", action="store_true",
                    help="serve tiered traffic through a heterogeneous "
                         "edge/v5e/v5p fleet front door (cnn)")
    ap.add_argument("--router", default="plan_aware",
                    help="fleet routing policy: plan_aware, "
                         "least_loaded, or round_robin (cnn --fleet)")
    ap.add_argument("--drain", action="store_true",
                    help="gracefully drain the v5e worker halfway "
                         "through the trace (cnn --fleet)")
    ap.add_argument("--seed", type=int, default=1,
                    help="rng seed for generated traffic (cnn --fleet)")
    ap.add_argument("--store-root", default=None, metavar="DIR",
                    help="shared store root (repro.ops.StoreRoot): one "
                         "DIR holding the plan store, the executable "
                         "cache, and worker leases — point every worker "
                         "of a fleet here so a respawn rebuilds from its "
                         "predecessor's state (replaces --plan-store "
                         "and --cache-dir)")
    ap.add_argument("--plan-store", default=None, metavar="DIR",
                    help="durable plan repository (repro.ops.PlanStore): "
                         "load the workload's plan from DIR if present, "
                         "else plan once and save it (cnn/moe, all paths)")
    ap.add_argument("--cache-dir", default=None, metavar="DIR",
                    help="persistent executable cache "
                         "(repro.ops.PersistentExecutableCache): warm "
                         "restarts deserialize their AOT executables "
                         "from DIR instead of recompiling (all paths)")
    ap.add_argument("--metrics-out", default=None, metavar="FILE",
                    help="stream lifecycle events and periodic stats "
                         "snapshots to FILE as JSON lines "
                         "(repro.ops.JsonlTracker; all workloads)")
    args = ap.parse_args()
    _apply_store_root(args)
    if args.arch is None:
        args.arch = ("qwen3-moe-30b-a3b" if args.workload == "moe"
                     else "llama3.2-3b")
    if args.workload == "cnn":
        if args.fleet:
            run_cnn_fleet(args)
        elif args.async_:
            run_cnn_async(args)
        else:
            run_cnn(args)
    elif args.workload == "moe":
        run_moe_async(args) if args.async_ else run_moe(args)
    else:
        run_lm(args)


if __name__ == "__main__":
    main()
