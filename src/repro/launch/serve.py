"""Serving launcher: batched requests through the serving engines on
reduced (CPU-runnable) configs.

LM workload — continuous-batching decode:

  PYTHONPATH=src python -m repro.launch.serve --workload lm \
      --arch gemma2-2b --requests 6 --prompt-len 16 --new-tokens 24

CNN workload — plan-driven dynamic batching (the deployment planner
picks each layer's block/bits for the device, then the engine serves
image batches through one jitted step per tick):

  PYTHONPATH=src python -m repro.launch.serve --workload cnn \
      --requests 64 --max-batch 16 [--device v5e] [--shard]
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np


def run_lm(args) -> None:
    from repro.configs import smoke_config
    from repro.models import build_model
    from repro.serve import Engine, Request, ServeConfig

    cfg = smoke_config(args.arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    engine = Engine(model, params, ServeConfig(
        max_batch=args.max_batch, max_len=args.prompt_len + args.new_tokens
        + 8, max_new_tokens=args.new_tokens))

    rng = np.random.default_rng(0)
    reqs = [Request(prompt=list(rng.integers(1, cfg.vocab_size,
                                             args.prompt_len)),
                    request_id=i) for i in range(args.requests)]
    t0 = time.time()
    engine.run(reqs)
    dt = time.time() - t0
    total = sum(len(r.out_tokens) for r in reqs)
    print(f"[serve] {args.requests} requests, {total} tokens in {dt:.2f}s "
          f"({total/dt:.1f} tok/s on {len(jax.devices())} host device(s))")
    for r in reqs[:3]:
        print(f"  req{r.request_id}: {r.out_tokens[:12]}...")


def run_cnn(args) -> None:
    from repro.core import allocate, deploy
    from repro.core.cnn import fitted_block_models, quickstart_cnn_config
    from repro.kernels import ops
    from repro.parallel.sharding import cnn_data_mesh
    from repro.serve import CNNEngine, CNNServeConfig, ImageRequest

    cfg = quickstart_cnn_config()
    bm = fitted_block_models()
    device = allocate.get_device(args.device)
    plan = deploy.plan_deployment(cfg, bm, device, target=0.8,
                                  on_infeasible="fallback")
    print(f"[serve] plan for {device.name}: "
          + ", ".join(f"L{a.index}={a.block}@d{a.data_bits}/c{a.coeff_bits}"
                      for a in plan.layers))

    mesh = cnn_data_mesh() if args.shard else None
    engine = CNNEngine.from_plan(
        plan, cfg, serve_cfg=CNNServeConfig(max_batch=args.max_batch),
        mesh=mesh)

    rng = np.random.default_rng(0)
    d0 = cfg.layers[0].data_bits
    reqs = [ImageRequest(
        image=np.asarray(ops.quantize_fixed(
            rng.integers(0, 1 << (d0 - 1),
                         engine.in_shape).astype(np.float32), d0)),
        request_id=i) for i in range(args.requests)]
    engine.run(reqs[:1])           # warmup compile outside the clock
    t0 = time.time()
    engine.run(reqs[1:])
    dt = time.time() - t0
    stats = engine.stats()
    print(f"[serve] {len(reqs) - 1} images in {dt:.2f}s "
          f"({(len(reqs) - 1)/dt:.1f} images/s, "
          f"{stats['images_per_step']:.1f} images/step) on "
          f"{len(jax.devices())} host device(s)"
          + (f", batch sharded over mesh {dict(mesh.shape)}" if mesh
             else ""))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--workload", choices=("lm", "cnn"), default="lm")
    ap.add_argument("--arch", default="llama3.2-3b")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=24)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--device", default="v5e",
                    help="deployment-planner device profile (cnn)")
    ap.add_argument("--shard", action="store_true",
                    help="shard the image batch over host devices (cnn)")
    args = ap.parse_args()
    if args.workload == "cnn":
        run_cnn(args)
    else:
        run_lm(args)


if __name__ == "__main__":
    main()
