import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

The two lines above MUST run before any other import (jax locks the device
count at first init).  512 placeholder host devices back the production
meshes: 16×16 (one v5e pod) and 2×16×16 (two pods).

Per cell this script:
  1. builds the model and ``ShapeDtypeStruct`` input specs (no allocation),
  2. jits the right step (train_step / prefill / decode) with in/out
     shardings from parallel/sharding.py,
  3. ``.lower().compile()`` — any sharding mismatch, OOM-at-compile or
     unsupported collective fails the cell,
  4. records memory_analysis / cost_analysis / per-class collective wire
     bytes into a JSON file consumed by the roofline benchmarks.

Usage:
  python -m repro.launch.dryrun --arch qwen3-moe-30b-a3b --shape train_4k \
      --mesh single --out results/
  python -m repro.launch.dryrun --all --mesh both --out results/
"""

import argparse
import json
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.configs import SHAPES, cell_is_runnable, get_config, list_archs
from repro.core import hloscan
from repro.launch.mesh import make_production_mesh
from repro.models import build_model
from repro.optim import AdamWConfig, adamw_init
from repro.parallel.sharding import ShardingRules, choose_mode
from repro.train.step import make_train_step
from jax.sharding import NamedSharding, PartitionSpec as P


def lower_cell(arch: str, shape_name: str, *, multi_pod: bool,
               mode: str = "auto", opt_dtype: str = "float32",
               microbatches: int = 1, collect_hlo: bool = True,
               save_hlo_path=None, cfg_overrides=None, mesh_shape=None):
    cfg = get_config(arch)
    if cfg_overrides:
        cfg = cfg.with_overrides(**cfg_overrides)
    shape = SHAPES[shape_name]
    ok, why = cell_is_runnable(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name,
                "mesh": "multi" if multi_pod else "single",
                "status": "skipped", "reason": why}

    if mesh_shape is not None:
        # per-arch logical remapping of the same physical chips (§Perf):
        # the topology is fixed, the (data, model) factorization is not.
        axes = (("pod", "data", "model") if len(mesh_shape) == 3
                else ("data", "model"))
        mesh = jax.make_mesh(tuple(mesh_shape), axes)
    else:
        mesh = make_production_mesh(multi_pod=multi_pod)
    model = build_model(cfg)
    if mode == "auto":
        mode = choose_mode(cfg, mesh)
    rules = ShardingRules(cfg, mesh, mode=mode)

    specs = model.input_specs(shape)
    params_abs = model.init_abstract()
    p_spec = rules.params_spec(params_abs)
    p_shard = rules.to_sharding(p_spec)

    t0 = time.time()
    with mesh:
        if shape.kind == "train":
            opt_cfg = AdamWConfig(state_dtype=opt_dtype)
            opt_abs = jax.eval_shape(
                lambda p: adamw_init(p, opt_cfg), params_abs)
            o_shard = rules.to_sharding(rules.opt_spec(opt_abs, p_spec))
            b_shard = rules.to_sharding(rules.batch_spec(specs["batch"]))
            step = make_train_step(model, opt_cfg,
                                   microbatches=microbatches)
            jitted = jax.jit(step,
                             in_shardings=(p_shard, o_shard, b_shard),
                             out_shardings=(p_shard, o_shard, None),
                             donate_argnums=(0, 1))
            lowered = jitted.lower(params_abs, opt_abs, specs["batch"])
        elif shape.kind == "prefill":
            b_shard = rules.to_sharding(rules.batch_spec(specs["batch"]))
            jitted = jax.jit(lambda p, b: model.prefill(p, b),
                             in_shardings=(p_shard, b_shard))
            lowered = jitted.lower(params_abs, specs["batch"])
        else:  # decode
            c_shard = rules.to_sharding(rules.cache_spec(specs["cache"]))
            t_shard = rules.to_sharding(rules.batch_spec(
                {"token": specs["token"]}))["token"]
            pos_shard = NamedSharding(mesh, P())
            jitted = jax.jit(
                lambda p, c, t, i: model.decode_step(p, c, t, i),
                in_shardings=(p_shard, c_shard, t_shard, pos_shard),
                out_shardings=(None, c_shard),
                donate_argnums=(1,))
            lowered = jitted.lower(params_abs, specs["cache"],
                                   specs["token"], jnp.int32(0))
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    n_chips = mesh.size
    mem = hloscan.memory_summary(compiled)
    cost = hloscan.cost_summary(compiled)
    result = {
        "arch": arch, "shape": shape_name,
        "mesh": "multi" if multi_pod else "single",
        "status": "ok", "mode": mode, "opt_dtype": opt_dtype,
        "microbatches": microbatches,
        "n_chips": n_chips,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "memory": mem, "cost": cost,
        "params": cfg.param_count(),
        "active_params": cfg.active_param_count(),
    }
    if collect_hlo:
        try:
            text = compiled.as_text()
            if save_hlo_path is not None:
                import gzip
                with gzip.open(save_hlo_path, "wt") as fh:
                    fh.write(text)
            # trip-count-aware analyzer (cost_analysis counts while bodies
            # once — see core/hloscan.py)
            result["hlo"] = hloscan.analyze_hlo(text)
            result["collectives"] = hloscan.collective_bytes(text)
        except Exception as e:  # pragma: no cover
            result["hlo"] = {"error": str(e)}
    print(f"[dryrun] {arch} × {shape_name} × "
          f"{'multi' if multi_pod else 'single'}: OK "
          f"(mode={mode}, compile {t_compile:.0f}s, "
          f"temp/dev {mem.get('temp_size_in_bytes', 0)/2**30:.2f} GiB, "
          f"args/dev {mem.get('argument_size_in_bytes', 0)/2**30:.2f} GiB)")
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--mode", default="auto",
                    choices=["auto", "tp", "fsdp"])
    ap.add_argument("--opt-dtype", default="float32",
                    choices=["float32", "int8"])
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--out", default="results")
    ap.add_argument("--tag", default="baseline")
    ap.add_argument("--save-hlo", action="store_true")
    ap.add_argument("--attn-batch-shard", action="store_true",
                    help="§Perf: shard attention batch over (data, model)")
    ap.add_argument("--attn-bf16-logits", action="store_true",
                    help="§Perf: bf16 attention logits/probs")
    args = ap.parse_args()
    overrides = {}
    if args.attn_batch_shard:
        overrides["attn_batch_shard"] = True
    if args.attn_bf16_logits:
        overrides["attn_logits_bf16"] = True

    outdir = Path(args.out)
    outdir.mkdir(parents=True, exist_ok=True)

    cells = []
    archs = list_archs() if (args.all or args.arch is None) else [args.arch]
    archs = [a for a in archs if a != "paper-conv-sweep"]
    shapes = list(SHAPES) if (args.all or args.shape is None) \
        else [args.shape]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                cells.append((arch, shape, mp))

    n_fail = 0
    for arch, shape, mp in cells:
        mesh_name = "multi" if mp else "single"
        fname = outdir / f"{args.tag}__{arch}__{shape}__{mesh_name}.json"
        if fname.exists():
            print(f"[dryrun] {fname.name} exists, skipping")
            continue
        try:
            hlo_path = (outdir / (fname.stem + ".hlo.gz")
                        if args.save_hlo else None)
            result = lower_cell(arch, shape, multi_pod=mp, mode=args.mode,
                                opt_dtype=args.opt_dtype,
                                microbatches=args.microbatches,
                                save_hlo_path=hlo_path,
                                cfg_overrides=overrides or None)
        except Exception as e:
            n_fail += 1
            result = {"arch": arch, "shape": shape, "mesh": mesh_name,
                      "status": "error", "error": str(e),
                      "traceback": traceback.format_exc()[-4000:]}
            print(f"[dryrun] {arch} × {shape} × {mesh_name}: "
                  f"FAIL — {type(e).__name__}: {str(e)[:200]}")
        fname.write_text(json.dumps(result, indent=1))
    print(f"[dryrun] done; {n_fail} failures")
    return 1 if n_fail else 0


if __name__ == "__main__":
    raise SystemExit(main())
