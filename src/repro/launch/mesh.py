"""Production mesh construction.

A function (not a module-level constant) so importing this module never
touches jax device state.  The production target is TPU v5e:
16×16 = 256 chips per pod; the multi-pod config is 2 pods = 512 chips with
a leading "pod" axis (DCN between pods, ICI within).
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model: int = 1):
    """Small mesh over whatever devices exist (tests / examples)."""
    n = len(jax.devices())
    assert n % model == 0
    return jax.make_mesh((n // model, model), ("data", "model"))
