"""``Fleet`` — the plan-aware multi-worker serving front door.

One ``AsyncCNNGateway`` serves one process; a ``Fleet`` serves a
*heterogeneous set* of them — each worker running its own deployment
plan on its own ``DeviceProfile`` — behind a single ``submit`` /
``submit_nowait`` door with the same semantics the gateway has
(``submit`` awaits admission = backpressure; ``submit_nowait`` raises
when nothing can take the request = shedding).  Per request the fleet:

  route      builds one ``WorkerView`` per worker from a consistent
             ``GatewayStats`` snapshot and asks the ``Router`` (plan-
             aware by default: deadline-tight → fastest, best-effort →
             cheapest that fits) to place the request.  The router
             never sees — and so can never pick — a worker that lacks
             the plan, is draining, or is unhealthy.
  health     every outcome feeds the worker's ``WorkerHealth`` machine:
             ``eject_after`` consecutive failures eject it from
             routing; after ``probe_interval`` the router may send one
             canary, and a served canary re-admits the worker.  A
             failed request is retried on another worker (bounded by
             ``max_retries``) before the caller sees the error.
  drain      ``drain(worker_id)`` stops new admissions to the worker,
             pulls its queued-but-not-dispatched requests back out of
             the gateway (``extract_queued``) and re-routes them, then
             waits for its in-flight batches to finish — zero admitted
             requests lost, the invariant the fleet benchmark and the
             regression tests pin.

The fleet tracks each client request as a ``FleetRequest`` whose
deadline stays anchored to *first* admission: re-routes and retries
spend the same budget, so a detour can never smuggle a request past
its SLA.  Deadlines are handed to workers as remaining-relative
seconds, so a fleet and its gateways need not share a clock epoch.
"""

from __future__ import annotations

import asyncio
import time
from dataclasses import dataclass, field
from typing import Callable, Dict, Optional, Sequence, Tuple

import numpy as np

from repro.chaos.inject import WorkerCrashed
from repro.fleet.router import Router, RouterLike, get_router
from repro.fleet.worker import FleetWorker
from repro.serve.async_engine import DeadlineExpired, GatewayBacklog

#: gateway scheduling priority per tier — interactive preempts batch
#: preempts best-effort inside every worker's EDF admission queue
TIER_PRIORITY = {"interactive": 2, "batch": 1, "best_effort": 0}


class FleetError(RuntimeError):
    """Base class for fleet routing/admission failures."""


class NoWorkerAvailable(FleetError):
    """No healthy, non-draining worker serves the request's plan."""


class FleetSaturated(FleetError, GatewayBacklog):
    """Every admissible worker's admission queue is at its bound —
    the fleet-level analogue of ``GatewayBacklog`` (and a subclass of
    it, so gateway-aware shedding code handles fleets unchanged)."""


@dataclass(eq=False)               # identity hash — requests live in sets
class FleetRequest:
    """One client request as the fleet tracks it across workers."""
    image: np.ndarray
    plan_id: str
    tier: str
    priority: int
    deadline: Optional[float]      # absolute on the *fleet* clock
    request_id: int
    future: "asyncio.Future"
    attempts: int = 0
    client_cancelled: bool = False
    worker_fut: Optional["asyncio.Future"] = field(default=None,
                                                   repr=False)


class Fleet:
    """The front door.  Typical lifecycle::

        fleet = Fleet([FleetWorker("edge0", gw_edge, "edge"),
                       FleetWorker("v5e0", gw_v5e, "v5e"),
                       FleetWorker("v5p0", gw_v5p, "v5p")],
                      router="plan_aware")
        async with fleet:
            fut = await fleet.submit(img, tier="interactive",
                                     deadline=0.25)
            out = await fut
            await fleet.drain("v5e0")      # zero requests lost
    """

    def __init__(self, workers: Sequence[FleetWorker],
                 router: RouterLike = "plan_aware", *,
                 max_retries: int = 2,
                 clock: Callable[[], float] = time.monotonic,
                 tracker=None):
        if not workers:
            raise ValueError("a fleet needs at least one worker")
        ids = [w.worker_id for w in workers]
        if len(set(ids)) != len(ids):
            raise ValueError(f"duplicate worker ids: {sorted(ids)}")
        if max_retries < 0:
            raise ValueError(f"max_retries={max_retries} must be ≥ 0")
        self.workers: Dict[str, FleetWorker] = {
            w.worker_id: w for w in sorted(workers,
                                           key=lambda w: w.worker_id)}
        self.router: Router = get_router(router)
        self.max_retries = max_retries
        self.clock = clock
        # ops telemetry sink (repro.ops.Tracker): worker lifecycle
        # events (ejected/probed/readmitted), plan rollout/retire
        self.tracker = tracker
        self._loop: Optional[asyncio.AbstractEventLoop] = None
        self._tasks: set = set()
        self._next_id = 0
        self._closing = False
        # fleet-level counters (mutated on the loop thread)
        self.served = 0
        self.expired = 0
        self.cancelled = 0
        self.rerouted = 0
        self.retried = 0
        self.worker_failures = 0
        self.drains = 0
        self.kills = 0
        self.respawns = 0

    def _track(self, event: str, **fields) -> None:
        if self.tracker is not None:
            self.tracker.log_event(event, **fields)

    # -- lifecycle --------------------------------------------------------
    def _ensure_started(self) -> None:
        loop = asyncio.get_running_loop()
        if self._loop is None:
            self._loop = loop
        elif self._loop is not loop:
            raise RuntimeError("fleet is bound to a different event loop")

    async def __aenter__(self) -> "Fleet":
        self._ensure_started()
        return self

    async def __aexit__(self, *exc) -> None:
        await self.close()

    async def close(self) -> None:
        """Let every worker drain its queue, then shut all of them
        down.  In-flight re-route tasks are awaited first so nothing
        is submitted into a closing gateway."""
        self._closing = True
        if self._tasks:
            await asyncio.gather(*self._tasks, return_exceptions=True)
        for w in self.workers.values():
            await w.gateway.close()

    # -- admission --------------------------------------------------------
    def _resolve_plan(self, plan_id: Optional[str]) -> str:
        if plan_id is not None:
            return plan_id
        for w in self.workers.values():
            for pid in w.gateway.plans:
                return pid
        raise FleetError("no plan registered on any worker")

    def _make_request(self, image, plan_id, tier, priority, deadline
                      ) -> FleetRequest:
        if tier not in TIER_PRIORITY:
            raise ValueError(f"unknown tier {tier!r}; known: "
                             f"{sorted(TIER_PRIORITY)}")
        now = self.clock()
        fr = FleetRequest(
            image=image, plan_id=self._resolve_plan(plan_id), tier=tier,
            priority=priority, request_id=self._next_id,
            deadline=None if deadline is None else now + deadline,
            future=self._loop.create_future())
        self._next_id += 1
        fr.future.add_done_callback(
            lambda f, fr=fr: self._on_client_done(fr, f))
        return fr

    def _on_client_done(self, fr: FleetRequest, fut) -> None:
        if fut.cancelled():
            fr.client_cancelled = True
            if fr.worker_fut is not None and not fr.worker_fut.done():
                fr.worker_fut.cancel()

    def submit_nowait(self, image, *, plan_id: Optional[str] = None,
                      tier: str = "best_effort", priority: int = 0,
                      deadline: Optional[float] = None
                      ) -> "asyncio.Future":
        """Route and admit one image, or raise: ``NoWorkerAvailable``
        when no admissible worker serves the plan (health/drain),
        ``FleetSaturated`` when every admissible worker's admission
        queue is at its bound.  ``deadline`` is relative seconds from
        now and is spent across any re-routes or retries."""
        self._ensure_started()
        if self._closing:
            raise RuntimeError("fleet is closing")
        fr = self._make_request(image, plan_id, tier, priority, deadline)
        excluded: set = set()
        while True:
            worker = self._select(fr, self.clock(), excluded)
            if worker is None:
                if excluded:
                    raise FleetSaturated(
                        f"every admissible worker for plan "
                        f"{fr.plan_id!r} is at its admission bound "
                        f"({sorted(excluded)}); retry with backoff or "
                        f"use `await fleet.submit(...)`")
                raise NoWorkerAvailable(
                    f"no healthy, non-draining worker serves plan "
                    f"{fr.plan_id!r}")
            try:
                wfut = worker.gateway.submit_nowait(
                    fr.image, plan_id=fr.plan_id,
                    priority=self._gateway_priority(fr),
                    deadline=self._remaining(fr))
            except GatewayBacklog:
                excluded.add(worker.worker_id)
                continue
            self._attach(fr, worker, wfut)
            return fr.future

    def submit_chunk(self, images, *, plan_id: Optional[str] = None,
                     tier: str = "best_effort", priority: int = 0,
                     deadline: Optional[float] = None
                     ) -> Tuple[list, int]:
        """Admit a batch of images *partially*: each image routes
        independently (so a chunk may span workers), and the first
        ``FleetSaturated`` stops admission — the admitted prefix is
        returned as ``(futures, refused)`` instead of all-or-nothing.
        ``NoWorkerAvailable`` still raises: a fleet with no admissible
        worker is an outage, not saturation."""
        futs: list = []
        for image in images:
            try:
                futs.append(self.submit_nowait(
                    image, plan_id=plan_id, tier=tier,
                    priority=priority, deadline=deadline))
            except FleetSaturated:
                return futs, len(images) - len(futs)
        return futs, 0

    async def submit(self, image, *, plan_id: Optional[str] = None,
                     tier: str = "best_effort", priority: int = 0,
                     deadline: Optional[float] = None
                     ) -> "asyncio.Future":
        """Route and admit one image, **awaiting** admission when the
        chosen worker's queue is at its bound — backpressure propagates
        to the producer, exactly like ``AsyncCNNGateway.submit``."""
        self._ensure_started()
        if self._closing:
            raise RuntimeError("fleet is closing")
        fr = self._make_request(image, plan_id, tier, priority, deadline)
        await self._route_and_admit(fr)
        if fr.worker_fut is None:
            await fr.future            # routing failed: raises the error
        return fr.future

    async def infer(self, image, **kw) -> np.ndarray:
        """Submit and await the result in one call."""
        fut = await self.submit(image, **kw)
        return await fut

    # -- routing core -----------------------------------------------------
    def _gateway_priority(self, fr: FleetRequest) -> int:
        return TIER_PRIORITY[fr.tier] * 16 + fr.priority

    def _remaining(self, fr: FleetRequest) -> Optional[float]:
        """Deadline budget left, as the relative seconds the worker
        gateway expects (anchored to first fleet admission)."""
        if fr.deadline is None:
            return None
        return fr.deadline - self.clock()

    def _views(self, now: float, excluded=frozenset()):
        return [w.view(now) for wid, w in self.workers.items()
                if wid not in excluded]

    def _select(self, fr: FleetRequest, now: float,
                excluded=frozenset()) -> Optional[FleetWorker]:
        view = self.router.select(fr.plan_id, fr.tier,
                                  self._views(now, excluded), now,
                                  deadline=fr.deadline)
        if view is None:
            return None
        worker = self.workers[view.worker_id]
        if worker.health.ejected:
            worker.health.begin_probe()   # this request is the canary
            self._track("worker_probe", worker_id=worker.worker_id,
                        probes=worker.health.probes)
        return worker

    def _attach(self, fr: FleetRequest, worker: FleetWorker,
                wfut: "asyncio.Future") -> None:
        fr.attempts += 1
        fr.worker_fut = wfut
        worker.outstanding.add(fr)
        wfut.add_done_callback(
            lambda f, fr=fr, w=worker: self._on_worker_done(fr, w, f))

    def _spawn(self, coro) -> None:
        task = self._loop.create_task(coro)
        self._tasks.add(task)
        task.add_done_callback(self._tasks.discard)

    async def _route_and_admit(self, fr: FleetRequest,
                               excluded=frozenset()) -> None:
        """Route ``fr`` and admit it with backpressure; terminal
        routing failures resolve the client future instead of raising
        (callers on the re-route path are fire-and-forget tasks)."""
        if fr.future.done():
            return
        now = self.clock()
        if fr.deadline is not None and now > fr.deadline:
            self.expired += 1
            fr.future.set_exception(DeadlineExpired(
                f"fleet request {fr.request_id} deadline passed "
                f"before (re-)admission"))
            return
        worker = self._select(fr, now, excluded)
        if worker is None:
            fr.future.set_exception(NoWorkerAvailable(
                f"no healthy, non-draining worker serves plan "
                f"{fr.plan_id!r}"))
            return
        try:
            wfut = await worker.gateway.submit(
                fr.image, plan_id=fr.plan_id,
                priority=self._gateway_priority(fr),
                deadline=self._remaining(fr))
        except Exception as e:          # noqa: BLE001 — gateway closing
            if not fr.future.done():    # or admission-time validation
                fr.future.set_exception(e)
            return
        self._attach(fr, worker, wfut)

    # -- outcome handling -------------------------------------------------
    def _on_worker_done(self, fr: FleetRequest, worker: FleetWorker,
                        wfut) -> None:
        worker.outstanding.discard(fr)
        if not worker.outstanding:
            for ev in worker._idle_waiters:
                ev.set()
        if wfut.cancelled():
            if fr.client_cancelled:
                # the *client* walked away — the worker did nothing
                # wrong, but it may have been mid-probe with this very
                # request as its canary: leave the probe state cleared
                # (note_neutral), or an ejected worker would stay
                # "probing" forever and never become routable again
                worker.health.note_neutral()
                self.cancelled += 1
                if not fr.future.done():
                    fr.future.cancel()
                return
            # drain eviction: the worker gave the request back — route
            # it to another worker on the same deadline budget
            self.rerouted += 1
            self._spawn(self._route_and_admit(fr))
            return
        exc = wfut.exception()
        if exc is None:
            was_ejected = worker.health.ejected
            worker.health.note_success()
            if was_ejected:
                self._track("worker_readmitted",
                            worker_id=worker.worker_id)
            self.served += 1
            if not fr.future.done():
                fr.future.set_result(wfut.result())
        elif isinstance(exc, DeadlineExpired):
            # the worker functioned; the request was simply late — no
            # health strike, but clear any outstanding probe
            worker.health.note_neutral()
            self.expired += 1
            if not fr.future.done():
                fr.future.set_exception(exc)
        elif isinstance(exc, WorkerCrashed):
            # the worker process died mid-dispatch (chaos-injected or
            # real): record the failure, declare the worker dead (kill
            # is idempotent and sweeps up its queued + in-flight
            # siblings) and re-route this request on its original
            # deadline budget *without* spending the bounded retry
            # budget — a crashed worker's requests are victims of the
            # crash, not evidence against the requests themselves
            self.worker_failures += 1
            worker.health.note_failure(self.clock())
            if not worker.dead:
                self.kill(worker.worker_id)
            if not fr.future.done():
                self.rerouted += 1
                self._spawn(self._route_and_admit(
                    fr, excluded=frozenset({worker.worker_id})))
        else:
            self.worker_failures += 1
            was_ejected = worker.health.ejected
            worker.health.note_failure(self.clock())
            if worker.health.ejected and not was_ejected:
                self._track("worker_ejected",
                            worker_id=worker.worker_id,
                            ejections=worker.health.ejections)
            if fr.attempts <= self.max_retries and not fr.future.done():
                self.retried += 1
                self._spawn(self._route_and_admit(
                    fr, excluded=frozenset({worker.worker_id})))
            elif not fr.future.done():
                fr.future.set_exception(exc)

    # -- draining ---------------------------------------------------------
    async def drain(self, worker_id: str) -> FleetWorker:
        """Gracefully take ``worker_id`` out of service: stop new
        admissions (the router no longer sees it), re-route its queued
        requests to the rest of the fleet, and wait until its in-flight
        batches finish.  Zero admitted requests are lost: every evicted
        request re-enters routing with its original deadline budget.
        The worker stays registered (and drained) — flip ``.draining``
        back to False to re-admit it."""
        self._ensure_started()
        try:
            worker = self.workers[worker_id]
        except KeyError:
            raise FleetError(
                f"unknown worker {worker_id!r}; fleet has: "
                f"{sorted(self.workers)}") from None
        if not worker.draining:
            worker.draining = True
            self.drains += 1
            self._track("worker_draining", worker_id=worker_id)
            worker.gateway.extract_queued()   # futures cancel → re-route
        if worker.outstanding:
            ev = asyncio.Event()
            worker._idle_waiters.append(ev)
            try:
                await ev.wait()
            finally:
                worker._idle_waiters.remove(ev)
        return worker

    # -- kill / respawn (crash recovery) ----------------------------------
    def kill(self, worker_id: str) -> FleetWorker:
        """Declare ``worker_id`` dead *now* — the un-graceful cousin of
        ``drain``.  The worker becomes unroutable (``dead`` flag +
        ``force_eject``) and every request it still owes is re-routed
        on its **original** deadline budget: queued-but-undispatched
        requests come back through ``extract_queued`` and mid-dispatch
        ones have their worker futures cancelled — both resolve through
        the existing cancelled-not-by-client branch of the outcome
        machine, which re-routes.  Nothing is lost: a request that
        cannot be re-placed resolves with ``NoWorkerAvailable``
        (refused), never silence.  Idempotent."""
        self._ensure_started()
        try:
            worker = self.workers[worker_id]
        except KeyError:
            raise FleetError(
                f"unknown worker {worker_id!r}; fleet has: "
                f"{sorted(self.workers)}") from None
        if worker.dead:
            return worker
        worker.dead = True
        self.kills += 1
        worker.health.force_eject(self.clock())
        self._track("worker_killed", worker_id=worker_id)
        try:
            # queued requests: futures cancel → outcome machine re-routes
            worker.gateway.extract_queued()
        except Exception:   # noqa: BLE001 — a dead gateway may not answer
            pass
        for fr in list(worker.outstanding):
            # mid-dispatch requests: cancelling the worker future both
            # aborts the gateway-side request and re-routes here
            if fr.worker_fut is not None and not fr.worker_fut.done():
                fr.worker_fut.cancel()
        return worker

    async def respawn(self, worker_id: str, *,
                      gateway=None) -> FleetWorker:
        """Bring a killed worker back behind the same fleet identity.

        The replacement ``gateway`` is either passed in or built by the
        worker's ``spawn`` factory **off the event loop** — with a
        factory like ``repro.chaos.respawn_gateway`` over a shared
        ``StoreRoot`` the rebuild deserializes its executables from the
        shared cache (zero recompiles) and reloads its plans from the
        shared ``PlanStore``.  The worker does *not* return to routing
        directly: it stays ejected with its probe immediately due, so
        the first request routed to it is the canary and re-admission
        goes through the existing health-probe path."""
        self._ensure_started()
        try:
            worker = self.workers[worker_id]
        except KeyError:
            raise FleetError(
                f"unknown worker {worker_id!r}; fleet has: "
                f"{sorted(self.workers)}") from None
        if not worker.dead:
            raise FleetError(
                f"worker {worker_id!r} is not dead; respawn follows "
                f"kill — use drain() for graceful maintenance")
        if gateway is None:
            if worker.spawn is None:
                raise FleetError(
                    f"worker {worker_id!r} has no spawn factory; pass "
                    f"gateway= or construct FleetWorker(..., spawn=...)")
            gateway = await self._loop.run_in_executor(None, worker.spawn)
        old = worker.gateway
        worker.gateway = gateway
        worker.dead = False
        worker.draining = False
        # stay ejected, probe due *immediately*: the next routed
        # request is the canary that re-admits the worker
        health = worker.health
        health.probing = False
        health.ejected_at = self.clock() - health.policy.probe_interval
        self.respawns += 1
        self._track("worker_respawned", worker_id=worker_id)
        try:
            await old.close()
        except Exception:   # noqa: BLE001 — the dead gateway owes nothing
            pass
        return worker

    # -- live plan reload -------------------------------------------------
    def _target_workers(self, worker_ids: Optional[Sequence[str]]
                        ) -> Dict[str, FleetWorker]:
        if worker_ids is None:
            return dict(self.workers)
        targets = {}
        for wid in worker_ids:
            try:
                targets[wid] = self.workers[wid]
            except KeyError:
                raise FleetError(
                    f"unknown worker {wid!r}; fleet has: "
                    f"{sorted(self.workers)}") from None
        return targets

    async def rollout(self, plan, plan_id: str, *,
                      worker_ids: Optional[Sequence[str]] = None,
                      params=None, key=None) -> Dict[str, str]:
        """Register ``plan`` on live workers without pausing serving.

        Each target worker compiles the plan **off the event loop**
        (``run_in_executor``) into its gateway's executable cache —
        with a ``PersistentExecutableCache`` this is a deserialization,
        not a compile storm — and then registers it between dispatches.
        Workers already serving ``plan_id`` are skipped (idempotent
        rollouts).  Workers roll sequentially, so a broken plan fails
        on the first worker with the rest untouched.  Returns
        ``{worker_id: plan_id}`` for the workers that registered."""
        self._ensure_started()
        targets = self._target_workers(worker_ids)
        registered: Dict[str, str] = {}
        for wid, worker in targets.items():
            gw = worker.gateway
            if plan_id in gw.plans:
                continue
            from repro.runtime.workloads import compile_plan
            compiled = await self._loop.run_in_executor(
                None, lambda gw=gw: compile_plan(
                    plan, params=params, key=key,
                    max_batch=gw.cfg.max_batch,
                    warmup=gw.cfg.aot_warmup,
                    exec_cache=gw.exec_cache))
            gw.register_plan(plan, plan_id=plan_id, compiled=compiled)
            registered[wid] = plan_id
            self._track("plan_rollout", plan_id=plan_id, worker_id=wid)
        return registered

    async def retire_plan(self, plan_id: str, *,
                          worker_ids: Optional[Sequence[str]] = None
                          ) -> int:
        """Retire ``plan_id`` fleet-wide without dropping in-flight
        requests.  Two phases: first **every** target gateway closes
        admission for the plan (``begin_retire`` — the routers stop
        seeing it at once, so no re-route can land on a copy that is
        about to vanish), then each gateway's ``retire_plan`` drains
        its queued + in-flight requests for the plan to completion.
        Returns the total requests the plan served across the fleet.
        Workers that never hosted the plan are skipped."""
        self._ensure_started()
        targets = {wid: w for wid, w in
                   self._target_workers(worker_ids).items()
                   if plan_id in w.gateway.plans
                   or plan_id in getattr(w.gateway, "retired_plans", {})}
        for worker in targets.values():       # phase 1: stop routing
            if plan_id in worker.gateway.plans:
                worker.gateway.begin_retire(plan_id)
        total = 0
        for worker in targets.values():       # phase 2: drain + evict
            total += await worker.gateway.retire_plan(plan_id)
        self._track("plan_retired_fleet", plan_id=plan_id,
                    workers=sorted(targets), served=total)
        return total

    # -- observability ----------------------------------------------------
    def stats(self) -> dict:
        """Fleet counters plus one consistent per-worker snapshot
        (`GatewayStats` + health/drain state)."""
        now = self.clock()
        per_worker = {}
        for wid, w in self.workers.items():
            try:
                snap = w.gateway.snapshot().asdict()
            except Exception:       # noqa: BLE001 — missed heartbeat
                snap = None
            per_worker[wid] = {
                "profile": w.profile.name,
                "cost": w.profile.cost,
                "plans": sorted(w.plan_ids),
                "workloads": sorted(w.workload_kinds),
                "rate": w.rate,
                "healthy": w.health.healthy,
                "routable": w.health.routable(now),
                "ejections": w.health.ejections,
                "probes": w.health.probes,
                "draining": w.draining,
                "dead": w.dead,
                "outstanding": len(w.outstanding),
                "snapshot": snap,
            }
        return {
            "router": self.router.name,
            "served": self.served,
            "expired": self.expired,
            "cancelled": self.cancelled,
            "rerouted": self.rerouted,
            "retried": self.retried,
            "worker_failures": self.worker_failures,
            "drains": self.drains,
            "kills": self.kills,
            "respawns": self.respawns,
            "workers": per_worker,
        }
