"""Virtual-clock fleet simulation — the seeded million-request SLO
harness behind ``benchmarks/fleet_bench.py``.

Serving a million real CNN requests per benchmark run is not an option
in CI; what the fleet claims need is the *queueing* behavior, not the
convolutions.  This module replays a seeded arrival trace through the
**same router objects, the same ``WorkerView`` projection, and the same
EDF ordering discipline** the live fleet uses, against workers whose
service times follow their device profile's relative speed (a v5p is
``mxu_cost(v5p)/mxu_cost(v5e)`` ≈ 2.3× faster than a v5e per image, an
edge part 10× slower — the same ratios the deployment planner budgets
with).  Everything runs on a virtual clock driven by an event heap:

  arrival      route via ``Router.select`` over live views → push into
               the worker's EDF queue (priority tier, then deadline,
               then arrival — ``repro.serve.policy.DeadlinePolicy``'s
               key, so the sim orders work exactly like the gateway)
  dispatch     an idle worker pops up to ``max_batch`` requests and
               schedules one batch completion at
               ``now + overhead + n · per_image`` (profile-scaled)
  completion   latencies recorded arrival→completion; next batch forms
  drain        at ``drain_at`` the worker stops admissions, its queued
               requests are evicted and re-routed through the same
               router — the virtual twin of ``Fleet.drain``

Determinism is absolute: the trace is a seeded ``default_rng`` draw,
every router tie-break ends on ``worker_id``, and the clock is just
float arithmetic — the same seed produces bit-identical results, which
is what lets ``BENCH_fleet.json`` be committed and diffed.
"""

from __future__ import annotations

import heapq
import math
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.core.allocate import V5E, DeviceProfile
from repro.core.deploy import device_profile
from repro.fleet.fleet import TIER_PRIORITY
from repro.fleet.router import Router, RouterLike, WorkerView, get_router

#: v5e-scale service model: one batch costs overhead + n × per-image.
#: Other profiles scale both by their MXU budget relative to v5e —
#: the same relative-speed assumption the deployment planner budgets
#: with.  Absolute values mirror the measured quickstart-CNN step
#: (~12 ms for a full batch of 8 on the v5e profile).
V5E_IMAGE_S = 1.25e-3
V5E_OVERHEAD_S = 2.0e-3


@dataclass(frozen=True)
class TierSpec:
    """One traffic class: its share of the trace, the relative deadline
    stamped on its requests (None = no deadline), and the p99 SLO the
    benchmark holds the fleet to."""
    share: float
    deadline_s: Optional[float]
    slo_p99_s: float


#: the benchmark's three tiers: deadline-tight interactive traffic,
#: deadlined batch traffic, and undeadlined best-effort bulk
DEFAULT_TIERS: Dict[str, TierSpec] = {
    "interactive": TierSpec(share=0.2, deadline_s=0.25, slo_p99_s=0.25),
    "batch": TierSpec(share=0.3, deadline_s=2.0, slo_p99_s=2.0),
    "best_effort": TierSpec(share=0.5, deadline_s=None, slo_p99_s=15.0),
}


def profile_speed(profile: DeviceProfile) -> float:
    """Relative service speed vs v5e (the planner's MXU-budget ratio)."""
    return profile.budgets["mxu_cost"] / V5E.budgets["mxu_cost"]


@dataclass(frozen=True)
class SimWorkerSpec:
    """One simulated worker: a catalog profile (by name or value), the
    plans it serves, and its batch geometry."""
    worker_id: str
    profile: Union[str, DeviceProfile] = "v5e"
    plan_ids: Tuple[str, ...] = ("cnn",)
    max_batch: int = 8

    def resolve_profile(self) -> DeviceProfile:
        return (device_profile(self.profile)
                if isinstance(self.profile, str) else self.profile)


@dataclass(frozen=True)
class Trace:
    """A seeded request trace: sorted arrival times, per-request tier
    index, absolute deadline (+inf when none), and plan assignment
    (``plan_ids[plan_idx[i]]``; ``plan_idx=None`` constant-folds every
    request onto ``plan_ids[0]`` — the single-workload trace)."""
    arrivals: np.ndarray           # float64, sorted
    tier_idx: np.ndarray           # int8 index into tier_names
    deadlines: np.ndarray          # float64 absolute (inf = none)
    tier_names: Tuple[str, ...]
    plan_ids: Tuple[str, ...]      # distinct plan ids in the trace
    tiers: Dict[str, TierSpec]
    plan_idx: Optional[np.ndarray] = None   # int8 index into plan_ids

    def __len__(self) -> int:
        return len(self.arrivals)


def make_trace(n: int, rate: float, *,
               tiers: Dict[str, TierSpec] = DEFAULT_TIERS,
               plan_id: str = "cnn",
               plan_mix: Optional[Dict[str, float]] = None,
               seed: int = 0) -> Trace:
    """Seeded Poisson trace: exponential inter-arrivals at ``rate``
    requests/sec, tiers drawn at their configured shares, deadlines
    stamped relative to each arrival.  ``plan_mix`` (plan id → traffic
    share, summing to 1) draws a per-request plan for mixed-workload
    fleets — e.g. ``{"cnn": 0.7, "moe": 0.3}`` interleaves CNN and MoE
    requests through the same routing; without it every request targets
    ``plan_id`` and the rng stream is untouched, so pre-existing
    single-plan traces stay bit-identical.  Same (n, rate, tiers, mix,
    seed) → bit-identical trace."""
    if n < 1 or rate <= 0:
        raise ValueError(f"need n ≥ 1 and rate > 0 (got {n}, {rate})")
    shares = np.array([t.share for t in tiers.values()], dtype=np.float64)
    if not math.isclose(float(shares.sum()), 1.0, rel_tol=1e-9):
        raise ValueError(f"tier shares must sum to 1 (got "
                         f"{float(shares.sum()):.6f})")
    rng = np.random.default_rng(seed)
    arrivals = np.cumsum(rng.exponential(1.0 / rate, n))
    tier_idx = rng.choice(len(shares), size=n, p=shares).astype(np.int8)
    rel = np.array([math.inf if t.deadline_s is None else t.deadline_s
                    for t in tiers.values()])
    deadlines = arrivals + rel[tier_idx]
    plan_ids: Tuple[str, ...] = (plan_id,)
    plan_idx = None
    if plan_mix is not None:
        pshares = np.array(list(plan_mix.values()), dtype=np.float64)
        if not math.isclose(float(pshares.sum()), 1.0, rel_tol=1e-9):
            raise ValueError(f"plan_mix shares must sum to 1 (got "
                             f"{float(pshares.sum()):.6f})")
        plan_ids = tuple(plan_mix)
        plan_idx = rng.choice(len(pshares), size=n,
                              p=pshares).astype(np.int8)
    return Trace(arrivals=arrivals, tier_idx=tier_idx,
                 deadlines=deadlines, tier_names=tuple(tiers),
                 plan_ids=plan_ids, tiers=dict(tiers), plan_idx=plan_idx)


class _SimWorker:
    """Simulation-side worker: an EDF request queue, one in-flight
    batch, and a ``WorkerView`` updated in place (the router reads the
    view, never this object)."""

    __slots__ = ("spec", "profile", "per_image_s", "overhead_s", "view",
                 "queue", "busy", "served", "batches", "busy_s",
                 "served_by_tier", "served_by_plan", "dead", "gen")

    def __init__(self, spec: SimWorkerSpec):
        self.spec = spec
        self.profile = spec.resolve_profile()
        speed = profile_speed(self.profile)
        self.per_image_s = V5E_IMAGE_S / speed
        self.overhead_s = V5E_OVERHEAD_S / speed
        # steady-state full-batch service rate, for est_wait ordering
        full = self.overhead_s + spec.max_batch * self.per_image_s
        self.view = WorkerView(
            spec.worker_id, cost=self.profile.cost,
            plan_ids=spec.plan_ids, rate=spec.max_batch / full,
            max_batch=spec.max_batch)
        self.view.est_wait_s = 0.0
        self.queue: List[Tuple[tuple, int, int]] = []   # (key, seq, req)
        self.busy = False
        self.served = 0
        self.batches = 0
        self.busy_s = 0.0
        self.served_by_tier: Dict[str, int] = {}
        self.served_by_plan: Dict[str, int] = {}
        self.dead = False
        # incarnation counter: a kill bumps it, so completion events
        # scheduled by a dead incarnation are discarded at pop time
        self.gen = 0

    def service_s(self, n: int) -> float:
        return self.overhead_s + n * self.per_image_s

    def sync_wait(self) -> None:
        """Publish the view's reported wait after a queue/inflight
        mutation — the sim's stand-in for ``GatewayStats.est_wait``
        (the live gateway measures its rate; the sim's rate *is* its
        service model, so backlog over rate is exact).  Same float
        expression as the view's depth-over-rate fallback, so routing
        decisions — and the committed benchmark — are bit-identical to
        a view that reports no measured wait."""
        v = self.view
        v.est_wait_s = (v.queue_depth + v.inflight) / max(v.rate, 1e-9)


@dataclass
class SimResult:
    """One simulated run, reduced to the numbers the SLO acceptance
    reads.  ``per_tier[t]["slo_met"]`` is the headline; ``late`` counts
    deadline-carrying requests served past their deadline (the sim
    serves everything and scores lateness post-hoc — the live gateway
    would have expired them, which shows up as the same SLO miss)."""
    router: str
    n: int
    offered_rate: float
    duration_s: float
    completed: int
    lost: int
    rerouted: int
    late: int
    late_rerouted: int
    per_tier: Dict[str, Dict[str, float]]
    per_worker: Dict[str, Dict[str, object]]
    # live plan retirement (``retire_at``/``retire_plan_id``): arrivals
    # for the retired plan after routing closed — refused at the door,
    # not lost (every already-admitted request still completes)
    refused_retired: int = 0
    retired_plan: Optional[str] = None
    # kill→respawn (``kill_at``/``kill_worker``/``respawn_at``): queued
    # + mid-dispatch requests of the killed worker re-routed at kill
    # time on their original deadlines — the recovery contract is that
    # none of them land in ``lost``
    kill_rerouted: int = 0
    killed_worker: Optional[str] = None
    respawn_at_s: Optional[float] = None

    @property
    def all_slos_met(self) -> bool:
        return all(t["slo_met"] for t in self.per_tier.values())

    def to_payload(self) -> dict:
        return {
            "router": self.router,
            "requests": self.n,
            "offered_rate_per_s": self.offered_rate,
            "duration_s": self.duration_s,
            "completed": self.completed,
            "lost": self.lost,
            "rerouted": self.rerouted,
            "late": self.late,
            "late_rerouted": self.late_rerouted,
            "per_tier": self.per_tier,
            "per_worker": self.per_worker,
            "all_slos_met": self.all_slos_met,
            "refused_retired": self.refused_retired,
            "retired_plan": self.retired_plan,
            "kill_rerouted": self.kill_rerouted,
            "killed_worker": self.killed_worker,
            "respawn_at_s": self.respawn_at_s,
        }


def simulate(worker_specs: Sequence[SimWorkerSpec], trace: Trace,
             router: RouterLike = "plan_aware", *,
             drain_at: Optional[float] = None,
             drain_worker: Optional[str] = None,
             retire_at: Optional[float] = None,
             retire_plan_id: Optional[str] = None,
             kill_at: Optional[float] = None,
             kill_worker: Optional[str] = None,
             respawn_at: Optional[float] = None) -> SimResult:
    """Replay ``trace`` through a simulated fleet under ``router``.

    ``drain_at``/``drain_worker`` schedule one mid-trace graceful
    drain: at that virtual time the worker stops admissions, its queued
    requests re-enter routing (original arrival times and deadlines —
    the detour is on the request's own clock), and its in-flight batch
    finishes normally.  Fully deterministic for a fixed trace.

    ``retire_at``/``retire_plan_id`` schedule one mid-trace live plan
    retirement — the virtual twin of ``Fleet.retire_plan``: at that
    virtual time the plan disappears from every worker's routable set
    at once (phase 1), so later arrivals for it are *refused* (counted
    in ``refused_retired``, not ``lost``) while every request admitted
    before the cut still dispatches and completes normally (phase 2's
    drain) — zero admitted requests lost.

    ``kill_at``/``kill_worker`` schedule one mid-trace *crash* — the
    virtual twin of ``Fleet.kill``: unlike a drain, the in-flight batch
    does **not** finish (the process died mid-dispatch); it and every
    queued request re-enter routing at kill time on their original
    deadlines, counted in ``kill_rerouted``.  ``respawn_at`` (requires
    a kill, ≥ ``kill_at``) brings the same worker back warm — the
    virtual twin of ``Fleet.respawn`` from the shared store: same
    service model, empty queue, routable again.  The recovery
    invariant the benchmark gates: ``lost == 0`` through kill→respawn.
    """
    rtr: Router = get_router(router)
    workers = [_SimWorker(s) for s in sorted(worker_specs,
                                             key=lambda s: s.worker_id)]
    if len({w.spec.worker_id for w in workers}) != len(workers):
        raise ValueError("duplicate sim worker ids")
    if (drain_at is None) != (drain_worker is None):
        raise ValueError("drain_at and drain_worker go together")
    if (retire_at is None) != (retire_plan_id is None):
        raise ValueError("retire_at and retire_plan_id go together")
    if (kill_at is None) != (kill_worker is None):
        raise ValueError("kill_at and kill_worker go together")
    if respawn_at is not None:
        if kill_at is None:
            raise ValueError("respawn_at requires kill_at/kill_worker")
        if respawn_at < kill_at:
            raise ValueError(f"respawn_at={respawn_at} must be ≥ "
                             f"kill_at={kill_at}")
    by_id = {w.spec.worker_id: w for w in workers}
    if kill_worker is not None and kill_worker not in by_id:
        raise ValueError(f"unknown kill_worker {kill_worker!r}")
    views = [w.view for w in workers]

    n = len(trace)
    arrivals = trace.arrivals
    tier_idx = trace.tier_idx
    deadlines = trace.deadlines
    tier_names = trace.tier_names
    plan_names = trace.plan_ids
    # per-request plan index (constant 0 for single-workload traces)
    plan_arr = (np.zeros(n, dtype=np.int8) if trace.plan_idx is None
                else trace.plan_idx)
    tier_prio = np.array([TIER_PRIORITY[t] for t in tier_names])

    lat = np.full(n, np.nan)
    rerouted_mask = np.zeros(n, dtype=bool)
    lost = 0
    rerouted = 0
    refused_retired = 0
    kill_rerouted = 0

    # completion events only — arrivals stream from the sorted array;
    # ``gen`` stamps the worker incarnation that scheduled the batch,
    # so a kill invalidates its pending completion without heap surgery
    events: List[Tuple[float, int, int, int]] = []  # (time, seq, widx, gen)
    eseq = 0
    widx = {w.spec.worker_id: k for k, w in enumerate(workers)}

    def enqueue(w: _SimWorker, req: int, seq: int) -> None:
        # the gateway's EDF key: priority tier, then deadline, arrival
        key = (-int(tier_prio[tier_idx[req]]), float(deadlines[req]), seq)
        heapq.heappush(w.queue, (key, seq, req))
        w.view.queue_depth += 1
        w.sync_wait()

    def start_batch(w: _SimWorker, now: float) -> None:
        nonlocal eseq
        if w.busy or not w.queue:
            return
        # single-plan batches, most-urgent plan wins — the gateway's
        # dispatch rule: the EDF head picks the plan, the batch fills
        # with that plan's requests in EDF order (other plans' requests
        # keep their queue position for the next dispatch)
        batch = []
        head_plan = plan_arr[w.queue[0][2]]
        skipped = []
        while w.queue and len(batch) < w.spec.max_batch:
            entry = heapq.heappop(w.queue)
            if plan_arr[entry[2]] == head_plan:
                batch.append(entry[2])
            else:
                skipped.append(entry)
        for entry in skipped:
            heapq.heappush(w.queue, entry)
        w.view.queue_depth -= len(batch)
        w.view.inflight = len(batch)
        w.sync_wait()
        w.busy = batch
        svc = w.service_s(len(batch))
        w.busy_s += svc
        heapq.heappush(events,
                       (now + svc, eseq, widx[w.spec.worker_id], w.gen))
        eseq += 1

    def route(req: int, now: float, seq: int) -> bool:
        view = rtr.select(plan_names[plan_arr[req]],
                          tier_names[tier_idx[req]], views, now,
                          deadline=(None if math.isinf(deadlines[req])
                                    else float(deadlines[req])))
        if view is None:
            return False
        w = by_id[view.worker_id]
        enqueue(w, req, seq)
        start_batch(w, now)
        return True

    drain_time = math.inf if drain_at is None else float(drain_at)
    drained = False
    retire_time = math.inf if retire_at is None else float(retire_at)
    retired = False
    kill_time = math.inf if kill_at is None else float(kill_at)
    killed = False
    respawn_time = math.inf if respawn_at is None else float(respawn_at)
    respawned = False

    def note_unroutable(req: int) -> None:
        """An arrival no worker takes: a request for the retired plan
        was *refused* at the closed door; anything else is lost."""
        nonlocal lost, refused_retired
        if retired and plan_names[plan_arr[req]] == retire_plan_id:
            refused_retired += 1
        else:
            lost += 1

    def maybe_retire(now: float) -> None:
        """Phase 1 of ``Fleet.retire_plan`` on the virtual clock: the
        plan leaves every routable set at once.  Queued and in-flight
        requests for it are untouched — they dispatch through the
        normal batch path (phase 2's drain)."""
        nonlocal retired
        if retired or now < retire_time:
            return
        retired = True
        for w in workers:
            w.view.plan_ids = frozenset(
                p for p in w.view.plan_ids if p != retire_plan_id)

    def maybe_drain(now: float) -> None:
        # an evicted request failing re-route is *lost* even when its
        # plan retired — it had been admitted, unlike a fresh arrival
        nonlocal drained, rerouted, lost
        if drained or now < drain_time:
            return
        drained = True
        w = by_id[drain_worker]
        w.view.draining = True
        evicted = [req for _, _, req in sorted(w.queue)]
        w.queue.clear()
        w.view.queue_depth = 0
        w.sync_wait()
        for req in evicted:
            rerouted += 1
            rerouted_mask[req] = True
            # re-enter routing at drain time on the original deadline
            if not route(req, drain_time, 10 * n + req):
                lost += 1

    def maybe_kill(now: float) -> None:
        # the virtual twin of ``Fleet.kill``: the process dies, so —
        # unlike a drain — the in-flight batch does NOT finish; it and
        # the queue re-enter routing at kill time on their original
        # deadlines.  A re-route no survivor takes is *lost* (the
        # invariant the recovery bench gates to zero).
        nonlocal killed, rerouted, kill_rerouted, lost
        if killed or now < kill_time:
            return
        killed = True
        w = by_id[kill_worker]
        w.dead = True
        w.gen += 1                  # voids the pending completion event
        w.view.healthy = False
        # mid-dispatch first: it was dispatched because it was the most
        # urgent work, so it re-routes ahead of the queue
        evicted = ([] if not w.busy else list(w.busy)) \
            + [req for _, _, req in sorted(w.queue)]
        w.busy = False
        w.queue.clear()
        w.view.queue_depth = 0
        w.view.inflight = 0
        w.sync_wait()
        for req in evicted:
            rerouted += 1
            kill_rerouted += 1
            rerouted_mask[req] = True
            if not route(req, kill_time, 20 * n + req):
                lost += 1

    def maybe_respawn(now: float) -> None:
        # the virtual twin of ``Fleet.respawn`` from the shared store:
        # the worker returns warm (same service model — the executable
        # deserializes, nothing recompiles), empty queue, routable
        nonlocal respawned
        if respawned or now < respawn_time or not killed:
            return
        respawned = True
        w = by_id[kill_worker]
        w.dead = False
        w.view.healthy = True
        w.sync_wait()

    i = 0                           # next arrival index
    now = 0.0
    while i < n or events:
        next_arrival = arrivals[i] if i < n else math.inf
        if events and events[0][0] <= next_arrival:
            t, _, k, g = heapq.heappop(events)
            now = t
            maybe_retire(now)
            maybe_drain(now)
            maybe_kill(now)
            maybe_respawn(now)
            w = workers[k]
            if g != w.gen:
                # completion scheduled by a killed incarnation — the
                # batch already re-routed at kill time; drop the event
                continue
            batch = w.busy
            w.busy = False
            w.view.inflight = 0
            w.sync_wait()
            w.batches += 1
            for req in batch:
                lat[req] = now - arrivals[req]
                name = tier_names[tier_idx[req]]
                w.served_by_tier[name] = w.served_by_tier.get(name, 0) + 1
                pname = plan_names[plan_arr[req]]
                w.served_by_plan[pname] = w.served_by_plan.get(pname, 0) + 1
            w.served += len(batch)
            start_batch(w, now)
        else:
            now = next_arrival
            maybe_retire(now)
            maybe_drain(now)
            maybe_kill(now)
            maybe_respawn(now)
            if not route(i, now, i):
                note_unroutable(i)
            i += 1
    # a drain/retire/kill scheduled after the last event still happens
    maybe_retire(retire_time if retire_time is not math.inf else now)
    maybe_drain(drain_time if drain_time is not math.inf else now)
    maybe_kill(kill_time if kill_time is not math.inf else now)
    maybe_respawn(respawn_time if respawn_time is not math.inf else now)

    completed = int(np.count_nonzero(~np.isnan(lat)))
    finite_dl = ~np.isinf(deadlines)
    done = ~np.isnan(lat)
    late_mask = done & finite_dl & (arrivals + lat > deadlines)
    per_tier = {}
    for t, name in enumerate(tier_names):
        mask = (tier_idx == t) & done
        spec = trace.tiers[name]
        if not mask.any():
            per_tier[name] = {"served": 0, "slo_p99_s": spec.slo_p99_s,
                              "slo_met": True}
            continue
        p50, p95, p99 = np.percentile(lat[mask], [50, 95, 99])
        per_tier[name] = {
            "served": int(mask.sum()),
            "p50_s": float(p50), "p95_s": float(p95), "p99_s": float(p99),
            "mean_s": float(lat[mask].mean()),
            "max_s": float(lat[mask].max()),
            "late": int(np.count_nonzero(late_mask & (tier_idx == t))),
            "slo_p99_s": spec.slo_p99_s,
            "slo_met": bool(p99 <= spec.slo_p99_s),
        }
    duration = float(now)
    per_worker = {}
    for w in workers:
        per_worker[w.spec.worker_id] = {
            "profile": w.profile.name,
            "cost": w.profile.cost,
            "served": w.served,
            "batches": w.batches,
            "images_per_batch": w.served / max(w.batches, 1),
            "utilization": w.busy_s / max(duration, 1e-9),
            "served_by_tier": dict(sorted(w.served_by_tier.items())),
            "served_by_plan": dict(sorted(w.served_by_plan.items())),
            "plan_ids": list(w.spec.plan_ids),
            "drained": w.view.draining,
            "killed": bool(killed and w.spec.worker_id == kill_worker),
            "respawned": bool(respawned
                              and w.spec.worker_id == kill_worker),
        }
    return SimResult(
        router=rtr.name, n=n, offered_rate=float(
            n / arrivals[-1]) if n else 0.0,
        duration_s=duration, completed=completed, lost=lost,
        rerouted=rerouted, late=int(np.count_nonzero(late_mask)),
        late_rerouted=int(np.count_nonzero(late_mask & rerouted_mask)),
        per_tier=per_tier, per_worker=per_worker,
        refused_retired=refused_retired, retired_plan=retire_plan_id,
        kill_rerouted=kill_rerouted,
        killed_worker=(kill_worker if killed else None),
        respawn_at_s=(float(respawn_at) if respawned else None))
