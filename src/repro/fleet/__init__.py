"""``repro.fleet`` — plan-aware multi-worker serving fleet.

The layer above ``repro.serve``: where a gateway runs *one* worker's
continuous-batching loop, the fleet runs *many* gateways — bound to
heterogeneous device profiles from ``deploy.DEVICE_CATALOG`` — behind
one ``submit`` front door.  A pluggable ``Router`` places each request
(the default ``PlanAwareRouter`` sends deadline-tight traffic to the
fastest admissible worker and best-effort traffic to the cheapest
profile that still fits), a per-worker health machine ejects workers on
consecutive failures and probes them back in, and ``Fleet.drain``
removes a worker gracefully — in-flight batches finish, queued requests
re-route, nothing admitted is lost.  ``Fleet.kill``/``Fleet.respawn``
are the *ungraceful* pair behind ``repro.chaos``: a killed worker's
queued and mid-dispatch requests re-route on their original deadlines,
and a respawn from the shared ``repro.ops.StoreRoot`` re-admits the
worker through the health-probe path with zero recompiles.

The same routers drive ``repro.fleet.sim`` — a virtual-clock simulator
that replays seeded million-request traces for the SLO benchmark
(``benchmarks/fleet_bench.py``) bit-reproducibly.  See ``docs/fleet.md``.
"""

from repro.fleet.fleet import (
    TIER_PRIORITY,
    Fleet,
    FleetError,
    FleetRequest,
    FleetSaturated,
    NoWorkerAvailable,
)
from repro.fleet.health import HealthPolicy, WorkerHealth
from repro.fleet.router import (
    TIERS,
    LeastLoadedRouter,
    PlanAwareRouter,
    RoundRobinRouter,
    Router,
    WorkerView,
    get_router,
    list_routers,
)
from repro.fleet.sim import (
    DEFAULT_TIERS,
    SimResult,
    SimWorkerSpec,
    TierSpec,
    Trace,
    make_trace,
    profile_speed,
    simulate,
)
from repro.fleet.worker import NOMINAL_V5E_RATE, FleetWorker, nominal_rate

__all__ = [
    "DEFAULT_TIERS",
    "Fleet",
    "FleetError",
    "FleetRequest",
    "FleetSaturated",
    "FleetWorker",
    "HealthPolicy",
    "LeastLoadedRouter",
    "NOMINAL_V5E_RATE",
    "NoWorkerAvailable",
    "PlanAwareRouter",
    "RoundRobinRouter",
    "Router",
    "SimResult",
    "SimWorkerSpec",
    "TIERS",
    "TIER_PRIORITY",
    "TierSpec",
    "Trace",
    "WorkerHealth",
    "WorkerView",
    "get_router",
    "list_routers",
    "make_trace",
    "nominal_rate",
    "profile_speed",
    "simulate",
]
