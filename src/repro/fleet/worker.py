"""``FleetWorker`` — one serving worker in the fleet.

A worker binds together the three identities the router needs to agree
on: a **device profile** from ``deploy.DEVICE_CATALOG`` (what hardware
this worker's plans were made for, and what it costs), a **gateway**
(an ``AsyncCNNGateway``, or any object with the same ``submit`` /
``submit_nowait`` / ``snapshot`` / ``close`` surface — the simulator's
workers speak it too), and the **plans** registered on that gateway
(which requests it may legally receive).  On top it layers the two
pieces of fleet-only state: a ``WorkerHealth`` machine fed by serving
outcomes, and the ``draining`` flag that stops new admissions while
in-flight batches finish.

Health heartbeats ride the ``GatewayStats`` snapshot seam: ``view()``
captures one consistent snapshot per routing decision, and a worker
whose snapshot *raises* is treated as a failed heartbeat — it takes a
health strike and is presented to the router as unroutable.
"""

from __future__ import annotations

import time
from typing import Callable, Optional, Union

from repro.core.allocate import V5E, DeviceProfile
from repro.core.deploy import device_profile
from repro.fleet.health import HealthPolicy, WorkerHealth
from repro.fleet.router import WorkerView

#: nominal v5e service rate the profile-relative default is anchored to
#: (images/sec; only ratios between workers matter to the routers)
NOMINAL_V5E_RATE = 100.0


def nominal_rate(profile: DeviceProfile) -> float:
    """Profile-relative service-rate estimate: MXU budget relative to
    v5e × the nominal v5e rate.  Routers only compare waits *across*
    workers, so a consistent relative scale is all that's needed; pass
    a measured rate to ``FleetWorker`` when one is available."""
    return (NOMINAL_V5E_RATE * profile.budgets["mxu_cost"]
            / V5E.budgets["mxu_cost"])


class FleetWorker:
    """One gateway bound to a device profile, with health and drain
    state.  ``profile`` accepts a catalog name (``"edge"``) — resolved
    via ``deploy.device_profile``, so a typo raises ``DeploymentError``
    with the catalog spelled out — or a ``DeviceProfile`` directly."""

    def __init__(self, worker_id: str, gateway,
                 profile: Union[str, DeviceProfile] = "v5e", *,
                 rate: Optional[float] = None,
                 health: Optional[HealthPolicy] = None,
                 spawn: Optional[Callable[[], object]] = None):
        self.worker_id = worker_id
        self.gateway = gateway
        # zero-arg factory building a *replacement* gateway for this
        # worker identity (e.g. repro.chaos.respawn_gateway over a
        # shared StoreRoot); Fleet.respawn calls it off the event loop
        self.spawn = spawn
        # set by Fleet.kill: the process behind the gateway is gone —
        # view() short-circuits to an unroutable view without taking
        # heartbeat strikes (death was already recorded by the kill;
        # re-striking would keep re-arming the exile clock and delay
        # the post-respawn probe)
        self.dead = False
        self.profile = (device_profile(profile)
                        if isinstance(profile, str) else profile)
        self.rate = (float(rate) if rate is not None
                     else nominal_rate(self.profile))
        if self.rate <= 0:
            raise ValueError(f"worker {worker_id!r}: rate={self.rate} "
                             f"must be > 0")
        self.health = WorkerHealth(health if health is not None
                                   else HealthPolicy())
        self.draining = False
        # fleet requests currently handed to this worker (queued or
        # in-flight on its gateway); drain() waits for this to empty
        self.outstanding: set = set()
        self._idle_waiters: list = []       # asyncio Events, fleet-owned

    @property
    def plan_ids(self):
        """Plans this worker can serve (live view of its registry).
        Prefers the gateway's ``routable_plans`` so a plan being
        retired disappears from routing the moment its admission
        closes, not when its last in-flight request finishes."""
        routable = getattr(self.gateway, "routable_plans", None)
        if routable is not None:
            return frozenset(routable)
        return frozenset(self.gateway.plans)

    @property
    def workload_kinds(self):
        """The workload kinds behind this worker's plans (``{"cnn"}``,
        ``{"moe"}``, or both on a mixed worker).  Placement by plan id
        subsumes placement by kind — a worker only lists a plan it
        could register, and registering an MoE plan on an edge-profile
        worker fails at planning time — but the kinds make mixed-fleet
        telemetry and capacity audits legible."""
        kinds = set()
        for entry in self.gateway.plans.values():
            compiled = getattr(entry, "compiled", entry)
            kinds.add(getattr(compiled, "kind", "cnn"))
        return frozenset(kinds)

    def view(self, now: Optional[float] = None, *,
             clock: Callable[[], float] = time.monotonic) -> WorkerView:
        """The router's one-snapshot projection of this worker.  A
        failing ``snapshot()`` is a missed heartbeat: it strikes the
        health machine and yields an unroutable view instead of
        raising into the routing path."""
        now = clock() if now is None else now
        rate, est_wait_s = self.rate, None
        if self.dead:
            return WorkerView(
                self.worker_id, cost=self.profile.cost,
                plan_ids=self.plan_ids, rate=rate, max_batch=1,
                queue_depth=0, inflight=0, healthy=False,
                draining=self.draining)
        try:
            snap = self.gateway.snapshot()
            queue_depth, inflight = snap.queue_depth, snap.inflight
            max_batch = snap.max_batch
            # prefer the gateway's *measured* throughput telemetry over
            # the profile-relative nominal rate once the EWMA has warmed
            # up — routers then compare real waits, not modeled ones
            measured = getattr(snap, "service_rate", 0.0)
            if measured and measured > 0.0:
                rate = measured
                est_wait_s = snap.est_wait
            reachable = True
        except Exception:           # noqa: BLE001 — unreachable worker
            self.health.note_failure(now)
            queue_depth = inflight = 0
            max_batch = 1
            reachable = False
        return WorkerView(
            self.worker_id, cost=self.profile.cost,
            plan_ids=self.plan_ids, rate=rate, max_batch=max_batch,
            queue_depth=queue_depth, inflight=inflight,
            healthy=reachable and self.health.routable(now),
            draining=self.draining, est_wait_s=est_wait_s)

    def __repr__(self) -> str:                    # pragma: no cover
        return (f"FleetWorker({self.worker_id!r}, "
                f"profile={self.profile.name!r}, "
                f"plans={sorted(self.plan_ids)}, "
                f"draining={self.draining})")
