"""Worker health: consecutive-failure ejection, probe re-admission.

Health is judged from serving *outcomes* (every completed, failed, or
unreachable request reported by the fleet) against ``GatewayStats``
heartbeats: a worker that fails ``eject_after`` requests in a row is
ejected — routers stop seeing it — and after ``probe_interval`` seconds
in exile it becomes *probe-due*: the router may send it exactly one
live request as a canary.  A served probe re-admits the worker
immediately; a failed probe restarts the exile clock (linear back-off
by re-arming the same interval, so a flapping worker costs one request
per interval, not a retry storm).

The state machine is synchronous and clock-injected, exactly like the
gateway's ``AdmissionQueue``: the live asyncio ``Fleet`` feeds it
``time.monotonic`` outcomes, the virtual-clock simulator feeds it
simulated time, and the transitions are unit-tested with a fake clock.

States::

    healthy ──(eject_after consecutive failures)──▶ ejected
    ejected ──(probe_interval elapsed)────────────▶ probe-due
    probe-due ──(router picks it: begin_probe)────▶ probing
    probing ──(success)──▶ healthy      probing ──(failure)──▶ ejected
"""

from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class HealthPolicy:
    """Fleet-wide knobs for the per-worker state machine."""
    eject_after: int = 3        # consecutive failures before ejection
    probe_interval: float = 1.0   # seconds ejected before a probe is due

    def __post_init__(self):
        if self.eject_after < 1:
            raise ValueError(
                f"eject_after={self.eject_after} must be ≥ 1")
        if self.probe_interval <= 0:
            raise ValueError(
                f"probe_interval={self.probe_interval} must be > 0")


class WorkerHealth:
    """One worker's health state.  ``routable(now)`` is what the fleet
    projects into the router's ``WorkerView.healthy``; ``begin_probe``
    must be called when an ejected worker is actually *selected*, so at
    most one canary is outstanding at a time."""

    def __init__(self, policy: HealthPolicy):
        self.policy = policy
        self.consecutive_failures = 0
        self.ejected = False
        self.ejected_at = 0.0
        self.probing = False
        # cumulative telemetry
        self.ejections = 0
        self.probes = 0

    @property
    def healthy(self) -> bool:
        return not self.ejected

    def routable(self, now: float) -> bool:
        """May the router send this worker a request right now?  True
        while healthy, and for an ejected worker exactly when a probe
        is due and none is already in flight."""
        if not self.ejected:
            return True
        return (not self.probing
                and now - self.ejected_at >= self.policy.probe_interval)

    def begin_probe(self) -> None:
        """An ejected worker was selected: the request now in flight is
        the canary — no second one until it resolves."""
        if self.ejected:
            self.probing = True
            self.probes += 1

    def note_success(self) -> None:
        """A request served: reset the failure streak; a successful
        probe re-admits the worker."""
        self.consecutive_failures = 0
        if self.ejected:
            self.ejected = False
        self.probing = False

    def note_neutral(self) -> None:
        """An outcome that says nothing about worker health (e.g. the
        request's deadline expired while queued): the failure streak is
        untouched, but an outstanding probe is released so the next
        canary can go out."""
        self.probing = False

    def force_eject(self, now: float) -> None:
        """Administrative ejection (worker killed / declared dead):
        immediately unroutable, probe clock armed at ``now``.  Unlike
        ``note_failure`` this does not wait for a failure streak —
        death is not a statistical question.  Idempotent on an
        already-ejected worker (re-arms the exile clock)."""
        if not self.ejected:
            self.ejections += 1
        self.ejected = True
        self.ejected_at = now
        self.probing = False
        self.consecutive_failures = max(self.consecutive_failures,
                                        self.policy.eject_after)

    def note_failure(self, now: float) -> None:
        """A request failed (dispatch error or unreachable stats).
        Failed probes re-arm the exile clock; ``eject_after`` straight
        failures eject a healthy worker."""
        self.consecutive_failures += 1
        if self.ejected:
            self.probing = False
            self.ejected_at = now          # back off: full interval again
        elif self.consecutive_failures >= self.policy.eject_after:
            self.ejected = True
            self.ejected_at = now
            self.probing = False
            self.ejections += 1

    def __repr__(self) -> str:                    # pragma: no cover
        state = ("probing" if self.probing
                 else "ejected" if self.ejected else "healthy")
        return (f"WorkerHealth({state}, "
                f"streak={self.consecutive_failures}, "
                f"ejections={self.ejections})")
