"""Routing policies for the serving fleet — one interface, concrete
dispatch backends (the abstract-dispatcher shape of vllm-ascend's
``MoETokenDispatcher``: an ABC that fixes the contract, subclasses that
fix the placement strategy).

A router answers exactly one question: *given what every worker looks
like right now, which worker should this request go to?*  It sees the
fleet through ``WorkerView``s — a deliberately small, backend-agnostic
projection of worker state that both the live asyncio ``Fleet`` (views
built from ``GatewayStats`` snapshots) and the virtual-clock
``FleetSim`` (views updated in place at simulation speed) can produce.
Because routers only read views, every concrete router is shared
verbatim between live serving and the million-request simulation, and
the no-bad-placement invariant (never a worker that lacks the plan, is
draining, or is unhealthy) is property-tested once for all of them.

Concrete routers:

  ``RoundRobinRouter``   rotate over admissible workers — the baseline
                         the benchmark beats (it sends one third of a
                         heavy trace to an edge part with a tenth of
                         the capacity).
  ``LeastLoadedRouter``  minimize estimated wait (outstanding work /
                         service rate) — load-aware, cost-blind.
  ``PlanAwareRouter``    the paper's fleet-level payoff: deadline-tight
                         traffic goes to the *fastest* admissible
                         worker, best-effort traffic to the *cheapest*
                         profile that still fits (spilling upward only
                         when the cheap tier's backlog would blow the
                         wait budget).

All tie-breaks end on ``worker_id`` so every router is deterministic:
the same views in the same order always route the same way — the
property the bit-reproducible benchmark rests on.
"""

from __future__ import annotations

from abc import ABC, abstractmethod
from typing import List, Optional, Sequence, Tuple, Union

#: request tiers the fleet routes on, most to least urgent
TIERS = ("interactive", "batch", "best_effort")


class WorkerView:
    """Router-visible state of one worker.

    Mutable by design: the live ``Fleet`` builds fresh views from each
    worker's ``GatewayStats`` snapshot per routing decision, while the
    simulator keeps one view per worker and updates ``queue_depth`` /
    ``inflight`` / ``healthy`` / ``draining`` in place — constructing a
    frozen dataclass per request would dominate a million-request run.

    ``rate`` is the worker's estimated service rate in images/sec (its
    device profile's relative speed × the measured or modeled per-image
    time); ``est_wait`` — outstanding work over that rate — is the one
    load metric every router shares.  When the worker reports a
    *measured* wait (``GatewayStats.est_wait``, the gateway's own EWMA
    throughput applied to its own backlog), ``est_wait_s`` carries it
    and takes precedence over the depth-over-nominal-rate inference.
    """

    __slots__ = ("worker_id", "cost", "plan_ids", "queue_depth",
                 "inflight", "max_batch", "rate", "healthy", "draining",
                 "est_wait_s")

    def __init__(self, worker_id: str, *, cost: float, plan_ids,
                 rate: float, max_batch: int = 8, queue_depth: int = 0,
                 inflight: int = 0, healthy: bool = True,
                 draining: bool = False,
                 est_wait_s: Optional[float] = None):
        self.worker_id = worker_id
        self.cost = float(cost)
        self.plan_ids = frozenset(plan_ids)
        self.rate = float(rate)
        self.max_batch = int(max_batch)
        self.queue_depth = int(queue_depth)
        self.inflight = int(inflight)
        self.healthy = bool(healthy)
        self.draining = bool(draining)
        self.est_wait_s = None if est_wait_s is None else float(est_wait_s)

    @property
    def accepting(self) -> bool:
        """Admissible for *new* traffic: healthy and not draining."""
        return self.healthy and not self.draining

    def est_wait(self) -> float:
        """Seconds of outstanding work ahead of a new arrival: the
        worker's measured estimate when it reports one, otherwise
        inferred from queue depth over the nominal rate."""
        if self.est_wait_s is not None:
            return self.est_wait_s
        return (self.queue_depth + self.inflight) / max(self.rate, 1e-9)

    def __repr__(self) -> str:                    # pragma: no cover
        return (f"WorkerView({self.worker_id!r}, cost={self.cost}, "
                f"depth={self.queue_depth}+{self.inflight}, "
                f"healthy={self.healthy}, draining={self.draining})")


class Router(ABC):
    """The routing contract.  ``select`` returns the chosen worker view
    or ``None`` when no admissible worker exists (the fleet then sheds
    or backpressures).  It must never return a worker that is draining,
    unhealthy, or missing ``plan_id`` — the invariant the fleet's
    drain/health guarantees rest on, property-tested over every
    registered router in ``tests/test_fleet.py``."""

    name = "router"

    @abstractmethod
    def select(self, plan_id: str, tier: str,
               workers: Sequence[WorkerView], now: float,
               deadline: Optional[float] = None) -> Optional[WorkerView]:
        """Pick a worker for one request (``deadline`` absolute on the
        fleet clock, or None)."""

    @staticmethod
    def admissible(plan_id: str,
                   workers: Sequence[WorkerView]) -> List[WorkerView]:
        """Workers that may legally receive a ``plan_id`` request.

        ``plan_ids`` is the mixed-workload placement seam: a plan id
        stands for a full ``DeploymentPlan`` of *any* workload kind
        (CNN, quantized MoE, ...), and a worker only advertises plans
        its device profile could host — an MoE plan that exceeds an
        edge part's budgets fails ``plan_moe_deployment`` before it
        could ever be registered there.  Routing by plan id therefore
        *is* plan-aware workload placement; no router needs to know
        what kind of network hides behind the id."""
        return [w for w in workers
                if w.accepting and plan_id in w.plan_ids]


class RoundRobinRouter(Router):
    """Rotate over admissible workers, blind to load, cost, and tier —
    the trivial baseline.  Deterministic: the rotation counter advances
    once per *successful* selection."""

    name = "round_robin"

    def __init__(self) -> None:
        self._turn = 0

    def select(self, plan_id, tier, workers, now, deadline=None):
        ok = self.admissible(plan_id, workers)
        if not ok:
            return None
        ok.sort(key=lambda w: w.worker_id)
        chosen = ok[self._turn % len(ok)]
        self._turn += 1
        return chosen


class LeastLoadedRouter(Router):
    """Minimize estimated wait; ties fall to cheaper cost, then worker
    id.  Load-aware but cost-blind: a cheap idle part and an expensive
    idle part are interchangeable to it."""

    name = "least_loaded"

    def select(self, plan_id, tier, workers, now, deadline=None):
        ok = self.admissible(plan_id, workers)
        if not ok:
            return None
        return min(ok, key=lambda w: (w.est_wait(), w.cost, w.worker_id))


class PlanAwareRouter(Router):
    """Tier- and cost-aware placement — the fleet-level version of the
    paper's match-the-network-to-the-hardware claim.

    * **Deadline-tight** traffic (tier ``interactive``, or any request
      whose deadline headroom is within ``tight_s``) goes to the
      admissible worker with the lowest estimated wait — the fastest
      door, cost be damned.
    * **Everything else** (``batch`` / ``best_effort``) goes to the
      *cheapest* profile whose backlog stays inside a wait budget —
      ``spill_wait_s``, tightened to half the remaining deadline
      headroom when the request carries one — and spills to the next
      cost tier only when the cheap one is saturated.  If every worker
      is past its budget, least-loaded wins (graceful degradation, not
      a refusal).
    """

    name = "plan_aware"

    def __init__(self, *, tight_s: float = 0.3,
                 spill_wait_s: float = 1.0) -> None:
        if tight_s < 0 or spill_wait_s <= 0:
            raise ValueError(
                f"tight_s={tight_s} must be ≥ 0 and "
                f"spill_wait_s={spill_wait_s} must be > 0")
        self.tight_s = float(tight_s)
        self.spill_wait_s = float(spill_wait_s)

    def select(self, plan_id, tier, workers, now, deadline=None):
        ok = self.admissible(plan_id, workers)
        if not ok:
            return None
        headroom = None if deadline is None else deadline - now
        tight = tier == "interactive" or (
            headroom is not None and headroom <= self.tight_s)
        if tight:
            return min(ok, key=lambda w: (w.est_wait(), w.cost,
                                          w.worker_id))
        budget = self.spill_wait_s
        if headroom is not None:
            budget = min(budget, max(headroom / 2.0, 1e-3))
        ok.sort(key=lambda w: (w.cost, w.est_wait(), w.worker_id))
        for w in ok:
            if w.est_wait() <= budget:
                return w
        return min(ok, key=lambda w: (w.est_wait(), w.cost, w.worker_id))


RouterLike = Union[str, Router, None]

_ROUTERS = {
    RoundRobinRouter.name: RoundRobinRouter,
    LeastLoadedRouter.name: LeastLoadedRouter,
    PlanAwareRouter.name: PlanAwareRouter,
}


def get_router(router: RouterLike) -> Router:
    """Resolve a router name to a *fresh* instance (routers such as
    round-robin carry mutable rotation state — two fleets must never
    share one), or pass a constructed ``Router`` through.  ``None``
    means ``plan_aware`` — the production default."""
    if router is None:
        return PlanAwareRouter()
    if isinstance(router, Router):
        return router
    try:
        return _ROUTERS[router]()
    except KeyError:
        raise ValueError(f"unknown router {router!r}; known: "
                         f"{sorted(_ROUTERS)}") from None


def list_routers() -> Tuple[str, ...]:
    return tuple(sorted(_ROUTERS))
