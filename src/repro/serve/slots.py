"""Shared fixed-slot-pool discipline for the serving engines.

Both engines (transformer continuous batching in ``repro.serve.engine``
and CNN dynamic batching in ``repro.serve.cnn_engine``) run the same
loop: a fixed pool of ``max_batch`` request slots, a queue that
backfills free slots between ticks, and one engine ``step`` per tick
over the occupied slots.  The seed duplicated that bookkeeping in both
engines — and drained the queue with ``list.pop(0)``, O(n²) over a
workload.  ``SlotPool`` centralizes it:

  slots       ``active`` (fixed-size list of Optional requests),
              ``_free_slot``, ``live`` (occupied (slot, request) pairs)
  drain loop  ``run`` — deque-backed queue backfill + step until both
              queue and pool are empty (O(n) queue handling)
  telemetry   ``occupancy_hist`` — live-slot histogram per step, so the
              realized batch distribution (and thus what bucketed
              dispatch buys) is observable via ``stats``

Subclasses implement ``submit`` (admission + request validation) and
``step`` (one tick over the pool), calling ``_note_step(live)`` so the
occupancy histogram stays current.
"""

from __future__ import annotations

from collections import deque
from typing import Dict, List, Optional, Sequence


class SlotPool:
    def __init__(self, max_batch: int):
        if max_batch < 1:
            raise ValueError(
                f"max_batch={max_batch} must be ≥ 1 (a zero-slot "
                f"pool can never drain its queue)")
        self.max_batch = max_batch
        self.active: List[Optional[object]] = [None] * max_batch
        # realized live-slot counts: occupancy_hist[k] = steps that ran
        # with exactly k occupied slots (k ≥ 1; empty ticks don't step)
        self.occupancy_hist: Dict[int, int] = {}
        self.steps = 0

    # -- slot bookkeeping ------------------------------------------------
    def _free_slot(self) -> Optional[int]:
        for i, r in enumerate(self.active):
            if r is None:
                return i
        return None

    def live(self):
        """Occupied (slot, request) pairs, in slot order."""
        return [(i, r) for i, r in enumerate(self.active) if r is not None]

    def _note_step(self, live: int) -> None:
        """Record one executed tick over ``live`` occupied slots."""
        self.steps += 1
        self.occupancy_hist[live] = self.occupancy_hist.get(live, 0) + 1

    # -- engine interface ------------------------------------------------
    def submit(self, req) -> bool:
        """Admit one request into a free slot; False when it must wait
        (pool full, or the engine's admission rule defers it)."""
        raise NotImplementedError

    def step(self):
        """One tick over the occupied slots (subclasses)."""
        raise NotImplementedError

    # -- the drain loop ---------------------------------------------------
    def run(self, requests: Sequence) -> List:
        """Serve a workload to completion: backfill free slots from the
        queue, step, repeat.  The queue is a ``collections.deque`` —
        popping the head is O(1), so a large workload costs O(n), not
        the seed's O(n²) ``list.pop(0)``."""
        requests = list(requests)
        queue = deque(requests)
        while queue or any(r is not None for r in self.active):
            while queue and self.submit(queue[0]):
                queue.popleft()
            self.step()
        return requests
