"""Shared fixed-slot-pool discipline for the serving engines.

All engines (transformer continuous batching in ``repro.serve.engine``,
CNN dynamic batching in ``repro.serve.cnn_engine``, and the async
continuous-batching gateway in ``repro.serve.async_engine``) run the
same bookkeeping: a fixed pool of ``max_batch`` request slots, a queue
that backfills free slots, and one engine ``step`` per drain over the
occupied slots.  The seed duplicated that in both sync engines — and
drained the queue with ``list.pop(0)``, O(n²) over a workload.
``SlotPool`` centralizes it:

  slots       ``active`` (fixed-size list of Optional requests),
              ``_free_slot``/``free_slots``, ``occupy``/``release``,
              ``live`` (occupied (slot, request) pairs)
  drain loop  ``run`` — heap-ordered queue backfill + step until both
              queue and pool are empty.  The ordering comes from a
              shared ``repro.serve.policy`` policy (FIFO by default —
              a pre-sorted heap, so the seed's O(n) drain is kept);
              the async gateway uses the *same* policies, so sync and
              async order work identically.
  telemetry   ``occupancy_hist`` — live-slot histogram per step.  The
              backing store is a **fixed array of ``max_batch``
              counters** (a subclass reporting a bogus occupancy is
              clamped into range, never a new key), and every update
              and snapshot takes ``_stats_lock`` — ``stats()`` is safe
              to call from another thread while the async drain is
              mid-step, and two threads noting steps never lose counts.
  rate        ``service_rate`` — an EWMA of measured service capacity
              (images/sec over busy intervals), fed by ``_note_step``
              from the pool's own clock.  ``snapshot()`` derives
              ``est_wait`` (outstanding work ÷ measured rate) from it,
              which is what the async gateway's adaptive admission
              bound and the fleet routers consume: *measure, then
              resize the block to the budget*.

Subclasses implement ``submit`` (admission + request validation) and
``step`` (one tick over the pool), calling ``_note_step(live)`` so the
occupancy histogram stays current.  ``add_release_hook`` lets an async
owner be woken (e.g. ``loop.call_soon_threadsafe``) whenever capacity
frees — the async gateway's waiters block on exactly that signal.
"""

from __future__ import annotations

import dataclasses
import heapq
import threading
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Callable, Deque, Dict, List, Optional, Sequence, Tuple

from repro.serve.policy import PolicyLike, get_policy


@dataclass(frozen=True)
class GatewayStats:
    """One *consistent* point-in-time view of a serving engine — the
    snapshot the fleet health checks and routers read.

    Every field is captured in a single pass under the pool's stats
    lock (plus the owner's counters, which are only ever mutated on one
    thread), so a reader never sees e.g. a ``served`` count from after
    a step paired with an ``occupancy_hist`` from before it — the
    racing-dict-reads failure mode ``stats()`` dictionaries had.

    ``timestamp`` is the owner's monotonic clock at capture: a fleet
    treats snapshots as heartbeats and compares them by age.
    """
    timestamp: float               # monotonic clock at capture
    queue_depth: int               # admitted but not yet dispatched
    inflight: int                  # occupied slots (on-device or staged)
    max_batch: int
    steps: int
    occupancy_hist: Dict[int, int] = field(default_factory=dict)
    # terminal counts (zero for engines that don't track a class)
    served: int = 0
    rejected: int = 0
    expired: int = 0
    cancelled: int = 0
    failed: int = 0
    # measured throughput telemetry (0.0 until the first two steps):
    # ``service_rate`` is the pool's EWMA images/sec; ``est_wait`` is
    # ``depth / service_rate`` — the seconds of outstanding work a new
    # arrival would wait behind, as *measured*, not modeled.  Fleet
    # routers prefer these over inferring wait from raw queue depth.
    service_rate: float = 0.0
    est_wait: float = 0.0

    @property
    def depth(self) -> int:
        """Total outstanding work: queued + in-flight."""
        return self.queue_depth + self.inflight

    def asdict(self) -> Dict:
        return dataclasses.asdict(self)


class SlotPool:
    def __init__(self, max_batch: int, *,
                 clock: Callable[[], float] = time.monotonic,
                 rate_alpha: float = 0.25,
                 faults=None):
        if max_batch < 1:
            raise ValueError(
                f"max_batch={max_batch} must be ≥ 1 (a zero-slot "
                f"pool can never drain its queue)")
        if not 0.0 < rate_alpha <= 1.0:
            raise ValueError(
                f"rate_alpha={rate_alpha} must be in (0, 1]")
        self.max_batch = max_batch
        # fault-injection seam (repro.chaos): an object with
        # ``check(point, now=..., **ctx)`` consulted at named failure
        # points ("dispatch", "heartbeat", ...).  Raising from a check
        # is how a scheduled fault manifests — the call sites place the
        # check exactly where the real failure would surface, so the
        # injected fault rides the production error path, not a mock's.
        self.faults = faults
        self.active: List[Optional[object]] = [None] * max_batch
        # realized live-slot counts: _occupancy[k-1] = steps that ran
        # with exactly k occupied slots (k ≥ 1; empty ticks don't step).
        # Fixed-size by construction — the histogram can never grow a
        # key per distinct batch size an engine happens to report.
        self._occupancy = [0] * max_batch
        self.steps = 0
        self._stats_lock = threading.Lock()
        self._release_hooks: List[Callable[[], None]] = []
        # measured service capacity: EWMA of live/Δt between
        # consecutive *busy* steps on the pool's clock (intervals with
        # idle time are skipped when the caller reports launch times —
        # see _note_step), so a lull in traffic never reads as the
        # hardware having slowed down.
        self._rate_clock = clock
        self._rate_alpha = float(rate_alpha)
        self._rate_ewma = 0.0
        # second, much slower EWMA of the same samples: the admission
        # bound reads this one, so believing "capacity halved" takes
        # sustained evidence (~16× the fast horizon) and a transient
        # host stall absorbs into the queue instead of mass-shedding a
        # recoverable burst; ``service_rate`` (routing, est_wait) stays
        # fast so wait estimates track reality promptly
        self._rate_slow_alpha = self._rate_alpha / 16.0
        self._rate_slow = 0.0
        self._last_step_t: Optional[float] = None
        # busy-run accumulator (callers that report launch times):
        # images completed since the run's first launch — the sample
        # is run_images/Δt from that anchor, which aggregates
        # overlapped dispatches correctly and never spans idle time
        # marks are (completion time, cumulative run images) — the
        # sample window slides over them so the estimate forgets any
        # stretch more than ~2 pool-fills of images ago
        self._run_marks: Deque[Tuple[float, int]] = deque()
        self._run_images = 0

    # -- slot bookkeeping ------------------------------------------------
    def _free_slot(self) -> Optional[int]:
        for i, r in enumerate(self.active):
            if r is None:
                return i
        return None

    def free_slots(self) -> int:
        """How many slots are currently unoccupied."""
        return sum(1 for r in self.active if r is None)

    def live(self):
        """Occupied (slot, request) pairs, in slot order."""
        return [(i, r) for i, r in enumerate(self.active) if r is not None]

    def occupy(self, req) -> int:
        """Place ``req`` into the first free slot; raises when full
        (callers gate on ``free_slots``/``_free_slot`` first)."""
        slot = self._free_slot()
        if slot is None:
            raise RuntimeError("slot pool full")
        self.active[slot] = req
        return slot

    def release(self, slot: int) -> None:
        """Free one slot and wake any release hooks (async waiters)."""
        self.active[slot] = None
        for hook in self._release_hooks:
            hook()

    def add_release_hook(self, hook: Callable[[], None]) -> None:
        """Run ``hook()`` after every ``release`` — the async gateway
        registers ``loop.call_soon_threadsafe(...)`` here so coroutines
        waiting for capacity wake the moment a slot frees."""
        self._release_hooks.append(hook)

    # -- fault-injection seam ---------------------------------------------
    def _fault_check(self, point: str, **ctx) -> None:
        """Consult the bound fault checker at a named failure point.
        No-op without one; with one, a scheduled fault raises here and
        propagates through the same error handling a real failure at
        this point would take."""
        if self.faults is not None:
            self.faults.check(point, now=self._rate_clock(), **ctx)

    # -- telemetry -------------------------------------------------------
    def _note_step(self, live: int, *,
                   launched_at: Optional[float] = None) -> None:
        """Record one executed tick over ``live`` occupied slots.
        Out-of-range counts clamp to the nearest bucket (the histogram
        is bounded by construction); thread-safe under the async drain.

        Also feeds the EWMA service-*capacity* estimator.  A caller
        that knows when this step's work was *launched* should pass
        ``launched_at``: completions then accumulate into **busy
        runs** — a dispatch launched after the previous completion
        starts a fresh run at its own launch time — and each sample is
        images over elapsed time inside a **sliding window** of the
        run's most recent ~2 pool-fills of completions.  That
        aggregates overlapped dispatches correctly (pairwise
        completion gaps would alias), forgets a transient slow stretch
        within ~2 pool-fills (a cumulative run average would drag for
        the rest of the run), and idle time never enters a
        sample, so a lull in traffic cannot read as the hardware
        having slowed down: the estimate is what the pool clears when
        given work, which is what admission bounds and routers size
        against.  Callers whose loops are always busy (the sync
        drain) omit ``launched_at`` and sample ``live/Δt`` between
        consecutive steps."""
        k = min(max(int(live), 1), self.max_batch)
        now = self._rate_clock()
        with self._stats_lock:
            self.steps += 1
            self._occupancy[k - 1] += 1
            inst = None
            if launched_at is None:
                if self._last_step_t is not None:
                    dt = now - self._last_step_t
                    if dt > 0.0:
                        inst = k / dt
            else:
                if (self._last_step_t is None
                        or launched_at > self._last_step_t):
                    # fresh busy run anchored at this launch
                    self._run_images = 0
                    self._run_marks.clear()
                    self._run_marks.append((launched_at, 0))
                self._run_images += k
                # slide the window: drop marks once ≥ 2 pool-fills of
                # completions sit behind a newer one, so a transient
                # bad stretch (host noise, one slow dispatch) washes
                # out of the estimate within ~2 pool-fills instead of
                # dragging the whole run's cumulative average down
                marks = self._run_marks
                while len(marks) >= 2 and \
                        self._run_images - marks[1][1] >= 2 * self.max_batch:
                    marks.popleft()
                t0, c0 = marks[0]
                dt = now - t0
                if dt > 0.0:
                    inst = (self._run_images - c0) / dt
                marks.append((now, self._run_images))
            if inst is not None:
                # a k-image step carries k images of evidence: blend
                # with 1-(1-α)^k so the estimate converges per
                # *image*, not per step — a trickle of 1-image batches
                # cannot pin the estimate while full batches snap it
                # to the measured rate fast
                w = 1.0 - (1.0 - self._rate_alpha) ** k
                self._rate_ewma = (
                    inst if self._rate_ewma == 0.0
                    else w * inst + (1.0 - w) * self._rate_ewma)
                ws = 1.0 - (1.0 - self._rate_slow_alpha) ** k
                self._rate_slow = (
                    inst if self._rate_slow == 0.0
                    else ws * inst + (1.0 - ws) * self._rate_slow)
            self._last_step_t = now

    @property
    def service_rate(self) -> float:
        """Measured throughput (EWMA images/sec); 0.0 until two steps
        have run on the pool's clock."""
        with self._stats_lock:
            return self._rate_ewma

    @property
    def service_rate_slow(self) -> float:
        """Slow-horizon throughput EWMA (images/sec) — what capacity
        commitments (the adaptive admission bound) should read: it
        takes sustained evidence to move, so a transient host stall
        queues instead of shedding, while a real sustained slowdown
        still tightens the bound within a few dozen pool-fills."""
        with self._stats_lock:
            return self._rate_slow

    @property
    def occupancy_hist(self) -> Dict[int, int]:
        """Sparse view of the bounded histogram: {live count: steps},
        zero-count buckets omitted (snapshot — safe to mutate)."""
        with self._stats_lock:
            counts = list(self._occupancy)
        return {k + 1: c for k, c in enumerate(counts) if c}

    def snapshot(self, *, clock: Callable[[], float] = time.monotonic,
                 queue_depth: int = 0, **counters) -> GatewayStats:
        """One consistent ``GatewayStats`` capture: histogram, step
        count, and slot occupancy are read in a single critical section
        under ``_stats_lock``.  Subclasses layer their own terminal
        counters on via ``**counters`` (``served=``, ``expired=``, …)
        and their queue depth via ``queue_depth`` — those are owned by
        a single mutating thread, so reading them alongside the locked
        fields yields the one-pass snapshot fleet health checks need."""
        with self._stats_lock:
            hist = {k + 1: c for k, c in enumerate(self._occupancy) if c}
            steps = self.steps
            inflight = sum(1 for r in self.active if r is not None)
            rate = self._rate_ewma
        est_wait = ((queue_depth + inflight) / rate) if rate > 0 else 0.0
        return GatewayStats(
            timestamp=clock(), queue_depth=queue_depth, inflight=inflight,
            max_batch=self.max_batch, steps=steps, occupancy_hist=hist,
            service_rate=rate, est_wait=est_wait, **counters)

    def stats(self) -> Dict:
        """Base telemetry dict — one consistent ``snapshot()`` flattened
        to the mapping shape the engines' ``stats()`` extend."""
        return self.snapshot().asdict()

    # -- engine interface ------------------------------------------------
    def submit(self, req) -> bool:
        """Admit one request into a free slot; False when it must wait
        (pool full, or the engine's admission rule defers it)."""
        raise NotImplementedError

    def step(self):
        """One tick over the occupied slots (subclasses)."""
        raise NotImplementedError

    # -- the drain loop ---------------------------------------------------
    def run(self, requests: Sequence, *, policy: PolicyLike = None,
            clock: Callable[[], float] = time.monotonic) -> List:
        """Serve a workload to completion: backfill free slots from the
        queue in ``policy`` order, step, repeat.

        The queue is a binary heap on the policy's static sort key.
        Under the default FIFO policy the keys are the arrival indices,
        so heapify of the already-ordered list is O(n) and each pop
        O(log n) — a large workload still costs ~O(n log n), not the
        seed's O(n²) ``list.pop(0)``.  Pass ``policy="edf"`` (or any
        ``repro.serve.policy`` policy) for deadline-aware ordering —
        the *same* policies the async gateway schedules with."""
        requests = list(requests)
        pol = get_policy(policy)
        now = clock()
        heap = [(pol.key(r, i, now), i, r)
                for i, r in enumerate(requests)]
        heapq.heapify(heap)
        head = None                     # popped but not yet admitted
        while heap or head is not None \
                or any(r is not None for r in self.active):
            while True:
                if head is None:
                    if not heap:
                        break
                    head = heapq.heappop(heap)
                if not self.submit(head[2]):
                    break               # pool full / deferred: step first
                head = None
            self.step()
        return requests
