"""Shared fixed-slot-pool discipline for the serving engines.

All engines (transformer continuous batching in ``repro.serve.engine``,
CNN dynamic batching in ``repro.serve.cnn_engine``, and the async
continuous-batching gateway in ``repro.serve.async_engine``) run the
same bookkeeping: a fixed pool of ``max_batch`` request slots, a queue
that backfills free slots, and one engine ``step`` per drain over the
occupied slots.  The seed duplicated that in both sync engines — and
drained the queue with ``list.pop(0)``, O(n²) over a workload.
``SlotPool`` centralizes it:

  slots       ``active`` (fixed-size list of Optional requests),
              ``_free_slot``/``free_slots``, ``occupy``/``release``,
              ``live`` (occupied (slot, request) pairs)
  drain loop  ``run`` — heap-ordered queue backfill + step until both
              queue and pool are empty.  The ordering comes from a
              shared ``repro.serve.policy`` policy (FIFO by default —
              a pre-sorted heap, so the seed's O(n) drain is kept);
              the async gateway uses the *same* policies, so sync and
              async order work identically.
  telemetry   ``occupancy_hist`` — live-slot histogram per step.  The
              backing store is a **fixed array of ``max_batch``
              counters** (a subclass reporting a bogus occupancy is
              clamped into range, never a new key), and every update
              and snapshot takes ``_stats_lock`` — ``stats()`` is safe
              to call from another thread while the async drain is
              mid-step, and two threads noting steps never lose counts.

Subclasses implement ``submit`` (admission + request validation) and
``step`` (one tick over the pool), calling ``_note_step(live)`` so the
occupancy histogram stays current.  ``add_release_hook`` lets an async
owner be woken (e.g. ``loop.call_soon_threadsafe``) whenever capacity
frees — the async gateway's waiters block on exactly that signal.
"""

from __future__ import annotations

import dataclasses
import heapq
import threading
import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence

from repro.serve.policy import PolicyLike, get_policy


@dataclass(frozen=True)
class GatewayStats:
    """One *consistent* point-in-time view of a serving engine — the
    snapshot the fleet health checks and routers read.

    Every field is captured in a single pass under the pool's stats
    lock (plus the owner's counters, which are only ever mutated on one
    thread), so a reader never sees e.g. a ``served`` count from after
    a step paired with an ``occupancy_hist`` from before it — the
    racing-dict-reads failure mode ``stats()`` dictionaries had.

    ``timestamp`` is the owner's monotonic clock at capture: a fleet
    treats snapshots as heartbeats and compares them by age.
    """
    timestamp: float               # monotonic clock at capture
    queue_depth: int               # admitted but not yet dispatched
    inflight: int                  # occupied slots (on-device or staged)
    max_batch: int
    steps: int
    occupancy_hist: Dict[int, int] = field(default_factory=dict)
    # terminal counts (zero for engines that don't track a class)
    served: int = 0
    rejected: int = 0
    expired: int = 0
    cancelled: int = 0
    failed: int = 0

    @property
    def depth(self) -> int:
        """Total outstanding work: queued + in-flight."""
        return self.queue_depth + self.inflight

    def asdict(self) -> Dict:
        return dataclasses.asdict(self)


class SlotPool:
    def __init__(self, max_batch: int):
        if max_batch < 1:
            raise ValueError(
                f"max_batch={max_batch} must be ≥ 1 (a zero-slot "
                f"pool can never drain its queue)")
        self.max_batch = max_batch
        self.active: List[Optional[object]] = [None] * max_batch
        # realized live-slot counts: _occupancy[k-1] = steps that ran
        # with exactly k occupied slots (k ≥ 1; empty ticks don't step).
        # Fixed-size by construction — the histogram can never grow a
        # key per distinct batch size an engine happens to report.
        self._occupancy = [0] * max_batch
        self.steps = 0
        self._stats_lock = threading.Lock()
        self._release_hooks: List[Callable[[], None]] = []

    # -- slot bookkeeping ------------------------------------------------
    def _free_slot(self) -> Optional[int]:
        for i, r in enumerate(self.active):
            if r is None:
                return i
        return None

    def free_slots(self) -> int:
        """How many slots are currently unoccupied."""
        return sum(1 for r in self.active if r is None)

    def live(self):
        """Occupied (slot, request) pairs, in slot order."""
        return [(i, r) for i, r in enumerate(self.active) if r is not None]

    def occupy(self, req) -> int:
        """Place ``req`` into the first free slot; raises when full
        (callers gate on ``free_slots``/``_free_slot`` first)."""
        slot = self._free_slot()
        if slot is None:
            raise RuntimeError("slot pool full")
        self.active[slot] = req
        return slot

    def release(self, slot: int) -> None:
        """Free one slot and wake any release hooks (async waiters)."""
        self.active[slot] = None
        for hook in self._release_hooks:
            hook()

    def add_release_hook(self, hook: Callable[[], None]) -> None:
        """Run ``hook()`` after every ``release`` — the async gateway
        registers ``loop.call_soon_threadsafe(...)`` here so coroutines
        waiting for capacity wake the moment a slot frees."""
        self._release_hooks.append(hook)

    # -- telemetry -------------------------------------------------------
    def _note_step(self, live: int) -> None:
        """Record one executed tick over ``live`` occupied slots.
        Out-of-range counts clamp to the nearest bucket (the histogram
        is bounded by construction); thread-safe under the async drain."""
        k = min(max(int(live), 1), self.max_batch)
        with self._stats_lock:
            self.steps += 1
            self._occupancy[k - 1] += 1

    @property
    def occupancy_hist(self) -> Dict[int, int]:
        """Sparse view of the bounded histogram: {live count: steps},
        zero-count buckets omitted (snapshot — safe to mutate)."""
        with self._stats_lock:
            counts = list(self._occupancy)
        return {k + 1: c for k, c in enumerate(counts) if c}

    def snapshot(self, *, clock: Callable[[], float] = time.monotonic,
                 queue_depth: int = 0, **counters) -> GatewayStats:
        """One consistent ``GatewayStats`` capture: histogram, step
        count, and slot occupancy are read in a single critical section
        under ``_stats_lock``.  Subclasses layer their own terminal
        counters on via ``**counters`` (``served=``, ``expired=``, …)
        and their queue depth via ``queue_depth`` — those are owned by
        a single mutating thread, so reading them alongside the locked
        fields yields the one-pass snapshot fleet health checks need."""
        with self._stats_lock:
            hist = {k + 1: c for k, c in enumerate(self._occupancy) if c}
            steps = self.steps
            inflight = sum(1 for r in self.active if r is not None)
        return GatewayStats(
            timestamp=clock(), queue_depth=queue_depth, inflight=inflight,
            max_batch=self.max_batch, steps=steps, occupancy_hist=hist,
            **counters)

    def stats(self) -> Dict:
        """Base telemetry dict — one consistent ``snapshot()`` flattened
        to the mapping shape the engines' ``stats()`` extend."""
        return self.snapshot().asdict()

    # -- engine interface ------------------------------------------------
    def submit(self, req) -> bool:
        """Admit one request into a free slot; False when it must wait
        (pool full, or the engine's admission rule defers it)."""
        raise NotImplementedError

    def step(self):
        """One tick over the occupied slots (subclasses)."""
        raise NotImplementedError

    # -- the drain loop ---------------------------------------------------
    def run(self, requests: Sequence, *, policy: PolicyLike = None,
            clock: Callable[[], float] = time.monotonic) -> List:
        """Serve a workload to completion: backfill free slots from the
        queue in ``policy`` order, step, repeat.

        The queue is a binary heap on the policy's static sort key.
        Under the default FIFO policy the keys are the arrival indices,
        so heapify of the already-ordered list is O(n) and each pop
        O(log n) — a large workload still costs ~O(n log n), not the
        seed's O(n²) ``list.pop(0)``.  Pass ``policy="edf"`` (or any
        ``repro.serve.policy`` policy) for deadline-aware ordering —
        the *same* policies the async gateway schedules with."""
        requests = list(requests)
        pol = get_policy(policy)
        now = clock()
        heap = [(pol.key(r, i, now), i, r)
                for i, r in enumerate(requests)]
        heapq.heapify(heap)
        head = None                     # popped but not yet admitted
        while heap or head is not None \
                or any(r is not None for r in self.active):
            while True:
                if head is None:
                    if not heap:
                        break
                    head = heapq.heappop(heap)
                if not self.submit(head[2]):
                    break               # pool full / deferred: step first
                head = None
            self.step()
        return requests
