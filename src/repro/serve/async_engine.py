"""Async continuous-batching gateway over ``repro.runtime.CompiledModel``.

The sync ``CNNEngine`` is a *tick loop*: gather whatever occupies the
slots, run one blocking step, scatter, repeat — fine for offline
workloads handed over as a list, blind to everything a front door needs
under live traffic.  ``AsyncCNNGateway`` is the production path, the
vLLM-style request-level scheduler adapted to feed-forward CNN serving:

  admission     a **bounded** pending queue.  ``submit`` applies
                backpressure (awaits space); ``submit_nowait`` raises
                ``GatewayBacklog`` — traffic beyond the bound is
                refused at the door, never absorbed into an unbounded
                queue whose tail latency grows without limit.  The
                bound itself is **adaptive** when ``wait_budget_s`` is
                set: it tracks measured service rate × the wait budget
                (clamped to [``min_pending``, ``max_pending``]), so the
                queue holds exactly as much work as the hardware can
                clear inside the budget — the paper's resource-driven
                sizing applied to the one serving-tier resource,
                admission capacity.  At the bound, shedding is
                **class-aware**: a ``submit_nowait`` arrival that
                outranks the least-urgent pending request (the policy's
                ``shed_key`` order — best-effort sheds first) ejects it
                with ``GatewayBacklog`` instead of being refused
                itself.  ``submit_chunk`` admits request batches
                *partially* — free capacity worth of images instead of
                all-or-nothing.
  continuous    the drain task launches a new ``CompiledModel`` bucket
                dispatch **the moment slots free up** — no global tick.
                Dispatches run in a worker thread pool, so the event
                loop keeps admitting, cancelling, and expiring requests
                while a batch is on-device, and (``max_inflight > 1``)
                a second batch can overlap the first.
  deadlines     requests carry optional ``deadline``/``priority``;
                batches are formed in ``repro.serve.policy`` order
                (EDF by default here — the *same* policy objects the
                sync engines accept, so both paths order identically).
                A request whose deadline passes before its batch
                launches is **expired** — completed with
                ``DeadlineExpired``, never silently served late.
  cancellation  the future returned by ``submit`` supports
                ``cancel()`` at any point: while queued (slot of the
                bound is released immediately), or mid-flight (the
                dispatch polls ``CompiledModel``'s ``should_abort`` hook
                and abandons the remaining layers once every request
                in the flight is cancelled).
  multi-plan    ``register_plan`` routes any number of
                ``DeploymentPlan``s through one gateway.  All plans
                share one ``runtime.ExecutableCache``: two plans whose
                layer specs coincide share AOT executables instead of
                compiling per plan.  Each batch is single-plan (plans
                may differ in geometry/precision); the scheduler picks
                the plan owning the most urgent pending request.

The scheduling core (``AdmissionQueue``) is deliberately synchronous
and clock-injected — the admission-bound and deadline invariants are
property-tested directly, no event loop required.  The asyncio shell
owns futures and threads.
"""

from __future__ import annotations

import asyncio
import heapq
import math
import time
from concurrent.futures import ThreadPoolExecutor
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.runtime.compiled import (CompiledModel, DispatchAborted,
                                    ExecutableCache)
from repro.serve import policy as policy_mod
from repro.serve.policy import PolicyLike, get_policy
from repro.serve.slots import GatewayStats, SlotPool


class GatewayBacklog(RuntimeError):
    """Admission refused: the pending queue is at its bound.  The
    caller sheds load (or uses ``submit`` and waits) — the gateway
    never buffers beyond its bound."""


class DeadlineExpired(RuntimeError):
    """The request's deadline passed before its batch launched; it was
    removed from the queue, not served late."""


class RequestCancelled(RuntimeError):
    """The request was cancelled via ``AsyncRequest.cancel`` before a
    result was produced."""


class PlanUnavailable(RuntimeError):
    """The target plan is retiring or was retired: admission refuses
    new requests for it.  In-flight and already-queued requests still
    complete — retirement drains, it never drops."""


@dataclass(eq=False)               # identity hash: requests live in sets
class AsyncRequest:
    """One in-flight gateway request.  ``deadline`` is absolute on the
    gateway clock (``submit``'s ``deadline`` argument is *relative*
    seconds and is converted on admission).  All state transitions
    happen on the gateway's event-loop thread — call ``cancel`` from
    the loop (schedule with ``call_soon_threadsafe`` from others)."""
    image: np.ndarray
    plan_id: str
    request_id: int = 0
    priority: int = 0
    deadline: Optional[float] = None
    arrived_at: float = 0.0
    # terminal state, set exactly once by the scheduling core:
    # pending → done | expired | cancelled | failed | shed
    status: str = "pending"
    output: Optional[np.ndarray] = None
    error: Optional[BaseException] = None
    _on_done: Optional[Callable[["AsyncRequest"], None]] = field(
        default=None, repr=False)

    def cancel(self) -> bool:
        """Cancel a still-pending request (False once terminal).  A
        queued request frees its admission slot at the next queue
        operation; a mid-flight one stops the dispatch early if every
        flight-mate is cancelled too, and its result is discarded."""
        if self.status != "pending":
            return False
        self._finish("cancelled", error=RequestCancelled(
            f"request {self.request_id} cancelled"))
        return True

    def _finish(self, status: str, *, output=None, error=None) -> None:
        if self.status != "pending":      # first terminal state wins
            return
        self.status = status
        self.output = output
        self.error = error
        if self._on_done is not None:
            self._on_done(self)


class _ShedProbe:
    """Stand-in for a not-yet-built request in shed-order comparisons.
    Policies read ``priority``/``deadline`` duck-typed, so this is all
    ``AdmissionQueue.outranked_by`` needs to decide admission at the
    bound without constructing the real request first."""

    __slots__ = ("priority", "deadline")

    def __init__(self, priority: int, deadline: Optional[float]):
        self.priority = priority
        self.deadline = deadline


class AdmissionQueue:
    """Bounded, policy-ordered pending set with deadline expiry — the
    synchronous scheduling core of the gateway.

    Invariants (property-tested in ``tests/test_async_serve.py``):

    * live pending count never exceeds ``max_pending`` — ``admit``
      refuses first;
    * ``pop_batch`` never returns a request whose deadline has passed —
      expired requests are finished with ``DeadlineExpired`` instead;
    * cancelled requests are never returned either (lazy heap deletion:
      terminal entries are dropped whenever they surface).
    """

    def __init__(self, max_pending: int, policy: PolicyLike = "edf"):
        if max_pending < 1:
            raise ValueError(f"max_pending={max_pending} must be ≥ 1")
        self.max_pending = max_pending
        self.policy = get_policy(policy)
        self._heap: List[Tuple[tuple, int, AsyncRequest]] = []
        self._seq = 0
        self._live = 0                 # pending entries (≤ max_pending)
        self.expired: int = 0          # finished with DeadlineExpired
        self.shed: int = 0             # ejected for a higher-class arrival
        # upper bound on the max pending shed_key (None = unknown):
        # lets ``outranked_by`` answer the common full-queue refusal in
        # O(1).  Removals leave it stale-high (safe: forces a scan),
        # admissions raise it, scans refresh it exactly.
        self._shed_ceiling: Optional[tuple] = None

    def __len__(self) -> int:
        return self._live

    @property
    def full(self) -> bool:
        return self._live >= self.max_pending

    def resize(self, max_pending: int) -> None:
        """Set a new admission bound (adaptive admission's seam).
        Shrinking below the current live count evicts nothing — the
        queue simply reads as full until it drains back under the new
        bound; growing takes effect on the next ``admit``."""
        self.max_pending = max(1, int(max_pending))

    def note_terminal(self) -> None:
        """A queued request reached a terminal state outside the queue
        (cancel): its admission slot is free immediately."""
        self._live -= 1

    def admit(self, req: AsyncRequest, now: float) -> bool:
        """Queue ``req``; False when at the bound (caller backpressures
        or rejects).  A request already past its deadline is expired on
        the spot — it never occupies a slot of the bound.  A request
        that is already *terminal* (e.g. its future was cancelled while
        ``submit`` awaited backpressure) is likewise handled without
        queueing: admitting it would bump the live count for an entry
        whose terminal hook has already run (or never will), leaking a
        slot of the bound on every occurrence until the gateway refuses
        all traffic."""
        if req.status != "pending":
            return True                # already terminal: never queued
        if policy_mod.expired(req, now):
            self.expired += 1
            req._finish("expired", error=DeadlineExpired(
                f"request {req.request_id} deadline predates admission"))
            return True                # handled (terminally), not queued
        if self.full:
            return False
        heapq.heappush(
            self._heap, (self.policy.key(req, self._seq, now),
                         self._seq, req))
        shed_key = self.policy.shed_key(req, self._seq, now)
        if self._shed_ceiling is None or shed_key > self._shed_ceiling:
            self._shed_ceiling = shed_key
        self._seq += 1
        self._live += 1
        return True

    def outranked_by(self, probe, now: float) -> bool:
        """True when some pending entry sheds below ``probe`` — i.e. a
        request of the probe's class arriving *now* would take a
        victim's slot instead of being refused.  ``probe`` only needs
        ``priority``/``deadline`` (policies read them duck-typed), so
        the gateway can answer "would this be refused?" at the bound
        *before* paying for request construction — under overload the
        refused path is the hot path.

        That hot path is O(1) in the common case: ``_shed_ceiling``
        upper-bounds every pending shed_key (sound because both
        built-in policies' shed keys are time-invariant once assigned),
        so a probe at or above the ceiling is refused without touching
        the heap.  Only a probe *below* the ceiling pays for a scan,
        which re-tightens the ceiling to the exact maximum."""
        candidate = self.policy.shed_key(probe, self._seq, now)
        ceiling = self._shed_ceiling
        if ceiling is not None and candidate >= ceiling:
            return False
        best = None
        for _, seq, queued in self._heap:
            if queued.status == "pending":
                k = self.policy.shed_key(queued, seq, now)
                if best is None or k > best:
                    best = k
        self._shed_ceiling = best
        return best is not None and best > candidate

    def shed_victim(self, req: AsyncRequest, now: float
                    ) -> Optional[AsyncRequest]:
        """Class-aware shedding at the bound: locate the least-urgent
        pending entry (maximal ``policy.shed_key`` — the same order
        batches form in, reversed) and, **iff** the incoming ``req``
        strictly outranks it, finish the victim with ``GatewayBacklog``
        and free its admission slot so ``req`` can take it.  Returns
        the victim, or ``None`` when ``req`` is itself the least
        urgent (the caller refuses it — under FIFO nothing ever
        outranks a queued request, so shedding degenerates to plain
        refusal)."""
        candidate = self.policy.shed_key(req, self._seq, now)
        worst_key, victim = None, None
        for _, seq, queued in self._heap:
            if queued.status != "pending":
                continue               # lazy-deleted entry
            k = self.policy.shed_key(queued, seq, now)
            if worst_key is None or k > worst_key:
                worst_key, victim = k, queued
        if victim is None or worst_key <= candidate:
            return None
        self._live -= 1
        self.shed += 1
        victim._finish("shed", error=GatewayBacklog(
            f"request {victim.request_id} shed at the admission bound "
            f"for a higher-class arrival"))
        return victim

    def pop_batch(self, max_n: int, now: float
                  ) -> Tuple[Optional[str], List[AsyncRequest]]:
        """Form the next single-plan batch: the most urgent pending
        request picks the plan, then up to ``max_n`` requests of *that
        plan* follow in policy order.  Other plans' requests are held
        back for the next batch with their original heap entries (keys
        and arrival order preserved exactly).  Terminal entries are
        dropped lazily; overdue ones are expired here — ``pop_batch``
        never returns a request that is already too late."""
        held: List[Tuple[tuple, int, AsyncRequest]] = []
        batch: List[AsyncRequest] = []
        plan_id: Optional[str] = None
        while len(batch) < max_n and self._heap:
            key, seq, req = heapq.heappop(self._heap)
            if req.status != "pending":   # cancelled while queued
                continue                  # (bound slot already released)
            if policy_mod.expired(req, now):
                self._live -= 1
                self.expired += 1
                req._finish("expired", error=DeadlineExpired(
                    f"request {req.request_id} expired after "
                    f"{now - req.arrived_at:.3f}s in queue"))
                continue
            if plan_id is None:
                plan_id = req.plan_id
            if req.plan_id != plan_id:
                held.append((key, seq, req))
                continue
            self._live -= 1
            batch.append(req)
        for entry in held:
            heapq.heappush(self._heap, entry)
        return plan_id, batch

    def pending_for(self, plan_id: str) -> int:
        """Count still-pending queued entries targeting one plan — the
        drain check live plan retirement polls until zero."""
        return sum(1 for _, _, req in self._heap
                   if req.status == "pending" and req.plan_id == plan_id)

    def evict_pending(self) -> List[AsyncRequest]:
        """Remove every still-pending entry from the heap *without*
        finishing it or touching the live count.  The caller owns the
        evicted requests: it must drive each to a terminal state, whose
        hook releases the admission slot via ``note_terminal`` — the
        seam ``AsyncCNNGateway.extract_queued`` (fleet draining) uses.
        Terminal entries still parked in the heap are dropped for free
        (their lazy deletion completes here)."""
        evicted = [req for _, _, req in self._heap
                   if req.status == "pending"]
        self._heap.clear()
        return evicted


@dataclass
class AsyncServeConfig:
    max_batch: int = 8             # dispatch width = top AOT bucket
    max_pending: int = 64          # admission bound (queued, not in-flight)
    max_inflight: int = 1          # concurrent bucket dispatches
    policy: PolicyLike = "edf"     # batch-formation order
    aot_warmup: bool = True        # pre-compile all buckets at register
    # adaptive admission (None = static bound, the pre-adaptive behavior):
    # the bound tracks ceil(measured service_rate × wait_budget_s),
    # clamped to [min_pending (default max_batch), max_pending] — the
    # queue holds what the hardware clears inside the budget, no more.
    wait_budget_s: Optional[float] = None
    min_pending: Optional[int] = None
    # batch coalescing: with an idle pool and a *partial* batch queued,
    # wait up to ``batch_linger × (max_batch / measured rate)`` seconds
    # (woken early by every new arrival) for the batch to fill before
    # dispatching.  A k=1 sliver costs a whole dispatch slot the same
    # ~full-batch service time costs — during an overload ramp those
    # slivers are pure capacity loss.  0 disables (dispatch instantly).
    batch_linger: float = 0.0


class _PlanEntry:
    def __init__(self, plan_id: str, compiled: CompiledModel):
        self.plan_id = plan_id
        self.compiled = compiled
        self.served = 0

    @property
    def kind(self) -> str:
        return self.compiled.kind


class AsyncCNNGateway(SlotPool):
    """The asyncio front door.  Request lifecycle::

        fut = await gw.submit(img)        # backpressure at the bound
        out = await fut                   # (H, W, C_out) container ints

    The gateway is also an (async) context manager::

        async with AsyncCNNGateway.from_plan(plan) as gw:
            ...

    Slot accounting rides on ``SlotPool``: in-flight requests occupy
    slots, ``release`` wakes the drain task through a release hook, and
    the occupancy histogram / ``stats()`` telemetry is shared with the
    sync engines (bounded + thread-safe by construction).
    """

    def __init__(self, cfg: Optional[AsyncServeConfig] = None, *,
                 clock: Callable[[], float] = time.monotonic,
                 exec_cache: Optional[ExecutableCache] = None,
                 tracker=None, faults=None):
        cfg = cfg if cfg is not None else AsyncServeConfig()
        if cfg.max_inflight < 1:
            raise ValueError(f"max_inflight={cfg.max_inflight} must be ≥ 1")
        if cfg.wait_budget_s is not None and cfg.wait_budget_s <= 0:
            raise ValueError(
                f"wait_budget_s={cfg.wait_budget_s} must be > 0 "
                f"(or None for a static bound)")
        if cfg.min_pending is not None and cfg.min_pending < 1:
            raise ValueError(
                f"min_pending={cfg.min_pending} must be ≥ 1")
        if cfg.batch_linger < 0.0:
            raise ValueError(
                f"batch_linger={cfg.batch_linger} must be ≥ 0")
        # the slot pool holds one dispatch-width batch per allowed
        # in-flight dispatch: with max_inflight > 1 the next batch can
        # occupy slots (and launch) while the previous is on-device —
        # dispatch width itself stays cfg.max_batch (see _drain).
        super().__init__(cfg.max_batch * cfg.max_inflight, clock=clock,
                         faults=faults)
        self.cfg = cfg
        self.clock = clock
        self.queue = AdmissionQueue(cfg.max_pending, cfg.policy)
        self.plans: Dict[str, _PlanEntry] = {}
        # shared across all plans; pass a repro.ops
        # PersistentExecutableCache here and a restart deserializes
        # instead of recompiling
        self.exec_cache = (exec_cache if exec_cache is not None
                           else ExecutableCache())
        # ops telemetry sink (repro.ops.Tracker); every call is
        # fire-and-forget and must never block the loop thread
        self.tracker = tracker
        if tracker is not None \
                and getattr(self.exec_cache, "on_event", False) is None:
            self.exec_cache.on_event = (
                lambda ev, fields: tracker.log_event(ev, **fields))
        self._default_plan: Optional[str] = None
        self._retiring: set = set()    # admission-closed, still draining
        self.retired_plans: Dict[str, int] = {}   # plan_id → served
        # one device, one execution stream: a single worker thread
        # serialises device compute no matter how many dispatches are
        # staged.  ``max_inflight > 1`` still pays off — the *next*
        # batch's host-side prep (stack, future wiring) overlaps the
        # current compute, and its executable starts the instant the
        # stream frees with no event-loop round trip — but two
        # executions never timeslice the same device, which on a
        # host-shared device starves one dispatch into a straggler
        # whose latency the rate estimator then reads as lost capacity.
        self._executor = ThreadPoolExecutor(
            max_workers=1, thread_name_prefix="repro-serve")
        self._loop: Optional[asyncio.AbstractEventLoop] = None
        self._wake: Optional[asyncio.Event] = None
        self._space: Optional[asyncio.Event] = None
        self._drain_task: Optional[asyncio.Task] = None
        self._closing = False
        self._inflight = 0             # dispatches currently launched
        self._next_id = 0
        self._last_adapt = -math.inf   # rate-limits per-arrival resizes
        # counters (all mutated on the loop thread; read anywhere)
        self.served = 0
        self.rejected = 0
        self.cancelled = 0
        self.failed = 0
        self.aborted_dispatches = 0

    # -- plan registry ----------------------------------------------------
    def register_plan(self, plan, *, plan_id: Optional[str] = None,
                      params=None, key=None, mesh=None,
                      compiled: Optional[CompiledModel] = None) -> str:
        """Route ``plan`` through this gateway — **any workload kind**:
        the plan's ``WorkloadSpec`` builds the compiled backend
        (``runtime.compile_plan``), so a quantized-MoE plan and a CNN
        plan serve side by side.  All registered plans compile into the
        gateway's shared ``ExecutableCache`` — layers that coincide
        across plans (same block/bits/geometry) reuse one executable
        per bucket, so registering a second near-identical plan is
        nearly free.  The first registered plan is the default target
        for ``submit``."""
        if plan_id is None:
            plan_id = f"plan{len(self.plans)}"
        if plan_id in self.plans:
            raise ValueError(f"plan id {plan_id!r} already registered")
        if compiled is None:
            from repro.runtime.workloads import compile_plan
            compiled = compile_plan(
                plan, params=params, key=key, mesh=mesh,
                max_batch=self.cfg.max_batch, warmup=self.cfg.aot_warmup,
                exec_cache=self.exec_cache)
        elif compiled.max_batch < self.cfg.max_batch:
            raise ValueError(
                f"compiled max_batch={compiled.max_batch} smaller than "
                f"the slot pool ({self.cfg.max_batch})")
        self.plans[plan_id] = _PlanEntry(plan_id, compiled)
        if self._default_plan is None:
            self._default_plan = plan_id
        self._track("plan_registered", plan_id=plan_id,
                    kind=compiled.kind)
        return plan_id

    @classmethod
    def from_plan(cls, plan, cfg: Optional[AsyncServeConfig] = None, *,
                  plan_id: Optional[str] = None, params=None, key=None,
                  mesh=None, clock: Callable[[], float] = time.monotonic,
                  exec_cache: Optional[ExecutableCache] = None,
                  tracker=None, faults=None) -> "AsyncCNNGateway":
        gw = cls(cfg, clock=clock, exec_cache=exec_cache, tracker=tracker,
                 faults=faults)
        gw.register_plan(plan, plan_id=plan_id, params=params, key=key,
                         mesh=mesh)
        return gw

    def _track(self, event: str, **fields) -> None:
        if self.tracker is not None:
            self.tracker.log_event(event, **fields)

    @property
    def routable_plans(self) -> frozenset:
        """Plan ids admission currently accepts — registered minus
        retiring.  Fleet routing reads this, so a retiring plan stops
        receiving traffic the moment ``begin_retire`` runs."""
        return frozenset(pid for pid in self.plans
                         if pid not in self._retiring)

    def _entry(self, plan_id: Optional[str]) -> _PlanEntry:
        pid = plan_id if plan_id is not None else self._default_plan
        if pid is None:
            raise RuntimeError("no plan registered "
                               "(call register_plan first)")
        if pid in self._retiring:
            raise PlanUnavailable(
                f"plan {pid!r} is retiring; routable: "
                f"{sorted(self.routable_plans)}")
        try:
            return self.plans[pid]
        except KeyError:
            if pid in self.retired_plans:
                raise PlanUnavailable(
                    f"plan {pid!r} was retired; routable: "
                    f"{sorted(self.routable_plans)}") from None
            raise ValueError(
                f"unknown plan id {pid!r}; registered: "
                f"{sorted(self.plans)}") from None

    # -- live retirement ---------------------------------------------------
    def begin_retire(self, plan_id: str) -> None:
        """Phase 1 of live retirement: stop routing new requests to
        ``plan_id`` — admission raises ``PlanUnavailable``, the default
        plan reassigns to the next routable one — while queued and
        in-flight requests continue untouched.  Idempotent; the fleet
        marks every worker this way before draining any of them so no
        re-route lands on a copy that is about to disappear."""
        if plan_id not in self.plans:
            raise ValueError(
                f"unknown plan id {plan_id!r}; registered: "
                f"{sorted(self.plans)}")
        if plan_id in self._retiring:
            return
        self._retiring.add(plan_id)
        if self._default_plan == plan_id:
            self._default_plan = next(
                (pid for pid in self.plans if pid not in self._retiring),
                None)
        self._track("plan_retiring", plan_id=plan_id)

    def _plan_outstanding(self, plan_id: str) -> int:
        """Queued + in-flight requests still owed to ``plan_id``."""
        queued = self.queue.pending_for(plan_id)
        inflight = sum(1 for r in self.active
                       if r is not None and r.plan_id == plan_id
                       and r.status == "pending")
        return queued + inflight

    async def retire_plan(self, plan_id: str, *,
                          poll_s: float = 0.01) -> int:
        """Retire a plan from a live gateway **without dropping
        in-flight requests**: close admission (``begin_retire``), wait
        for every queued and in-flight request of the plan to reach a
        terminal state through the normal dispatch path, then evict the
        compiled entry.  Returns the plan's lifetime served count.
        Concurrent retires of the same plan join the same drain;
        retiring an already-retired plan returns its count."""
        self._ensure_started()
        if plan_id not in self.plans:
            if plan_id in self.retired_plans:
                return self.retired_plans[plan_id]
            raise ValueError(
                f"unknown plan id {plan_id!r}; registered: "
                f"{sorted(self.plans)}")
        self.begin_retire(plan_id)
        while plan_id in self.plans and self._plan_outstanding(plan_id):
            self._wake.set()          # keep the drain task moving
            self._space.set()         # wake submit waiters so those
            await asyncio.sleep(poll_s)   # targeting this plan can fail
        entry = self.plans.pop(plan_id, None)
        self._retiring.discard(plan_id)
        if entry is not None:
            self.retired_plans[plan_id] = entry.served
            self._track("plan_retired", plan_id=plan_id,
                        served=entry.served)
        return self.retired_plans.get(plan_id, 0)

    # -- lifecycle --------------------------------------------------------
    def _ensure_started(self) -> None:
        loop = asyncio.get_running_loop()
        if self._loop is None:
            self._loop = loop
            self._wake = asyncio.Event()
            self._space = asyncio.Event()
            self._space.set()
            # a freed slot can mean "next batch can launch": wake the
            # drain task from whatever thread released the slot
            self.add_release_hook(lambda: loop.call_soon_threadsafe(
                self._wake.set))
            self._drain_task = loop.create_task(self._drain())
        elif self._loop is not loop:
            raise RuntimeError("gateway is bound to a different event loop")

    async def __aenter__(self) -> "AsyncCNNGateway":
        self._ensure_started()
        return self

    async def __aexit__(self, *exc) -> None:
        await self.close()

    async def close(self) -> None:
        """Drain what is queued, then stop the drain task."""
        if self._drain_task is None:
            self._executor.shutdown(wait=True)
            return
        self._closing = True
        self._wake.set()
        self._space.set()             # backpressure waiters must not hang
        await self._drain_task
        self._executor.shutdown(wait=True)

    # -- admission --------------------------------------------------------
    def _make_request(self, image, plan_id, priority, deadline
                      ) -> Tuple[AsyncRequest, "asyncio.Future"]:
        entry = self._entry(plan_id)
        img = entry.compiled.validate_input(image, self._next_id)
        now = self.clock()
        req = AsyncRequest(
            image=img, plan_id=entry.plan_id, request_id=self._next_id,
            priority=priority,
            deadline=None if deadline is None else now + deadline,
            arrived_at=now)
        self._next_id += 1
        fut: asyncio.Future = self._loop.create_future()

        def on_done(r: AsyncRequest, fut=fut) -> None:
            if fut.done():
                return
            if r.status == "done":
                fut.set_result(r.output)
            elif r.status == "cancelled":
                fut.cancel()
            else:
                fut.set_exception(r.error)

        req._on_done = on_done
        # a caller cancelling the *future* cancels the request too
        fut.add_done_callback(
            lambda f, r=req: r.cancel() if f.cancelled() else None)
        return req, fut

    def _adapt_bound(self, force: bool = False) -> None:
        """Resize the admission bound to what the hardware can clear
        inside ``cfg.wait_budget_s`` at the *measured* service rate —
        the paper's resource-driven sizing applied to admission
        capacity.  No-op when no wait budget is configured (static
        bound).  Until the rate estimator warms up (or after an idle
        gap dilutes it to ~0) the bound floors at ``min_pending``
        (default ``max_batch``: always one full batch admissible); it
        never exceeds ``cfg.max_pending``, the configured hard cap.

        The bound reads the **slow** rate horizon: shrinking the door
        is a capacity commitment, and honouring it on a transient
        stall would shed a burst the hardware will clear moments
        later.  ``est_wait`` and routing keep the fast horizon.

        Per-arrival calls are rate-limited to ~2 ms: under sustained
        overload arrivals outnumber dispatches ~30:1, and resizing on
        each one spends event-loop time recomputing a bound that only
        moves when a step completes.  ``force=True`` (used on batch
        completion, where the estimate actually changed) bypasses the
        limiter."""
        budget = self.cfg.wait_budget_s
        if budget is None:
            return
        now = self.clock()
        if not force and now - self._last_adapt < 2e-3:
            return
        self._last_adapt = now
        floor = (self.cfg.min_pending if self.cfg.min_pending is not None
                 else self.cfg.max_batch)
        rate = self.service_rate_slow
        bound = math.ceil(rate * budget) if rate > 0 else floor
        self.queue.resize(max(floor, min(bound, self.cfg.max_pending)))
        self._signal_space()          # a grown bound frees waiters

    def submit_nowait(self, image, *, plan_id: Optional[str] = None,
                      priority: int = 0, deadline: Optional[float] = None
                      ) -> "asyncio.Future":
        """Admit one image or raise ``GatewayBacklog`` when the pending
        queue is at its bound (load shedding).  At the bound, shedding
        is class-aware: if this arrival outranks the least-urgent
        pending request (policy ``shed_key`` order), that request is
        ejected — its future raises ``GatewayBacklog`` — and this one
        takes its slot; otherwise this arrival is the one refused.
        ``deadline`` is relative seconds from now; the returned future
        resolves to the output activations, raises ``DeadlineExpired``,
        or is cancelled."""
        self._ensure_started()
        if self._closing:
            raise RuntimeError("gateway is closing")
        self._adapt_bound()
        if self.queue.full:
            # refuse *before* building the request: under sustained
            # overload the refused path is the hot path, and paying
            # image validation + future wiring per shed arrival steals
            # event-loop time from dispatch
            now = self.clock()
            probe = _ShedProbe(
                priority, None if deadline is None else now + deadline)
            if not self.queue.outranked_by(probe, now):
                self.rejected += 1
                raise GatewayBacklog(
                    f"pending queue at its bound "
                    f"({self.queue.max_pending}); retry with backoff or "
                    f"use `await submit(...)` for backpressure")
        req, fut = self._make_request(image, plan_id, priority, deadline)
        now = self.clock()
        if not self.queue.admit(req, now):
            victim = self.queue.shed_victim(req, now)
            if victim is None or not self.queue.admit(req, now):
                self.rejected += 1
                raise GatewayBacklog(
                    f"pending queue at its bound "
                    f"({self.queue.max_pending}); retry with backoff or "
                    f"use `await submit(...)` for backpressure")
        self._bookkeep_admitted(req)
        return fut

    def submit_chunk(self, images, *, plan_id: Optional[str] = None,
                     priority: int = 0, deadline: Optional[float] = None
                     ) -> Tuple[List["asyncio.Future"], int]:
        """Admit a *batch* of images partially: as many as the bound
        has room for (in order), instead of all-or-nothing.  Returns
        ``(futures, refused)`` where ``futures`` covers the admitted
        prefix and ``refused`` counts the images that were shed at the
        bound (each counted in ``rejected``).  A caller that cannot
        tolerate partial admission should ``await submit`` per image
        for backpressure instead."""
        futs: List[asyncio.Future] = []
        for image in images:
            try:
                futs.append(self.submit_nowait(
                    image, plan_id=plan_id, priority=priority,
                    deadline=deadline))
            except GatewayBacklog:
                return futs, len(images) - len(futs)
        return futs, 0

    async def submit(self, image, *, plan_id: Optional[str] = None,
                     priority: int = 0, deadline: Optional[float] = None
                     ) -> "asyncio.Future":
        """Admit one image, **awaiting** while the queue is at its
        bound — backpressure propagates to the producer instead of
        growing the queue.  The request (and its validation) is built
        once; only admission retries.  Its deadline stays anchored to
        the first attempt — time spent waiting for space counts against
        it, so backpressure cannot smuggle a request past its SLA."""
        self._ensure_started()
        if self._closing:
            raise RuntimeError("gateway is closing")
        req, fut = self._make_request(image, plan_id, priority, deadline)
        while True:
            if self._closing:
                # a wakeup from close() must *not* re-try admission:
                # the drain task may already have exited, and a request
                # admitted after that pends forever.  Fail it instead —
                # its future resolves with the error.
                if req.status == "pending":
                    self.failed += 1
                    req._finish("failed",
                                error=RuntimeError("gateway is closing"))
                return fut
            if req.plan_id in self._retiring \
                    or req.plan_id not in self.plans:
                # the target plan retired while this submit awaited
                # backpressure: admitting now would strand the request
                # (retirement has already drained past it) — fail it
                if req.status == "pending":
                    self.failed += 1
                    req._finish("failed", error=PlanUnavailable(
                        f"plan {req.plan_id!r} retired while awaiting "
                        f"admission"))
                return fut
            self._adapt_bound()
            if self.queue.admit(req, self.clock()):
                self._bookkeep_admitted(req)
                return fut
            self._space.clear()
            if not self.queue.full:   # space freed before the clear —
                continue              # re-check avoids a lost wakeup
            await self._space.wait()

    def _bookkeep_admitted(self, req: AsyncRequest) -> None:
        if req.status == "pending":
            # queued: wake the drain task
            orig = req._on_done

            def on_done(r, orig=orig):
                if r.status == "cancelled":
                    self.cancelled += 1
                    if r not in self._inflight_set:
                        self.queue.note_terminal()
                        self._signal_space()
                orig(r)

            req._on_done = on_done
            self._wake.set()
        # expired-on-admission requests already finished via _on_done

    def _signal_space(self) -> None:
        if self._space is not None and not self.queue.full:
            self._space.set()

    # -- the continuous drain ---------------------------------------------
    @property
    def _inflight_set(self):
        return {r for r in self.active if r is not None}

    async def _drain(self) -> None:
        loop = self._loop
        pending_flights = set()
        linger_until: Optional[float] = None
        while True:
            self._wake.clear()
            free = self.free_slots()
            launched = False
            # Only form a batch when a dispatch can actually *start*
            # (inflight < max_inflight): launching into a busy executor
            # would fragment what could be one full batch into slivers.
            # Overlap policy: the first dispatch launches on any
            # pending work, but a *concurrent* one (max_inflight > 1)
            # requires a full batch of backlog — overlapping hides the
            # Python-side dispatch gap under overload (throughput),
            # while at low load two half-empty contending dispatches
            # would only inflate latency.
            pressure = (self._inflight == 0
                        or len(self.queue) >= self.cfg.max_batch)
            # Batch coalescing (cfg.batch_linger): an *idle* pool with
            # a partial batch queued holds the dispatch briefly — each
            # new admission wakes this wait, so the linger ends the
            # moment the batch fills or the deadline passes.  A k=1
            # sliver occupies a dispatch slot for ~a full batch's
            # service time; during an overload ramp (queue filling in
            # milliseconds) dispatching slivers forfeits real capacity.
            want_linger = (self.cfg.batch_linger > 0.0 and free > 0
                           and 0 < len(self.queue) < self.cfg.max_batch
                           and self._inflight == 0 and not self._closing)
            if not want_linger:
                linger_until = None
            elif linger_until is None:
                rate = self.service_rate
                linger_until = self.clock() + (
                    self.cfg.batch_linger * self.cfg.max_batch / rate
                    if rate > 0.0 else 0.0)
            if want_linger and self.clock() < linger_until:
                try:
                    await asyncio.wait_for(
                        self._wake.wait(),
                        linger_until - self.clock())
                except asyncio.TimeoutError:
                    pass
                continue
            if free > 0 and len(self.queue) > 0 and pressure \
                    and self._inflight < self.cfg.max_inflight:
                # dispatch width is cfg.max_batch (the top AOT bucket),
                # not the pool size — the pool is max_inflight batches
                # wide so the next batch stages while one is on-device
                width = min(free, self.cfg.max_batch)
                plan_id, batch = self.queue.pop_batch(width, self.clock())
                self._signal_space()
                if batch and plan_id not in self.plans:
                    # the plan was evicted with requests still queued
                    # (shouldn't happen — retire drains first — but a
                    # KeyError here would kill the drain task for good)
                    for r in batch:
                        self.failed += 1
                        r._finish("failed", error=PlanUnavailable(
                            f"plan {plan_id!r} is no longer registered"))
                    continue
                if batch:
                    slots = [self.occupy(r) for r in batch]
                    self._inflight += 1
                    flight = loop.create_task(self._run_batch(
                        self.plans[plan_id], batch, slots))
                    pending_flights.add(flight)
                    flight.add_done_callback(pending_flights.discard)
                    launched = True
            if launched:
                continue              # immediately try to form another
            if self._closing and len(self.queue) == 0 \
                    and not pending_flights:
                return
            await self._wake.wait()

    async def _run_batch(self, entry: _PlanEntry, batch, slots) -> None:
        compiled = entry.compiled
        launched_at = self._rate_clock()
        alive = [r for r in batch if r.status == "pending"]
        try:
            if alive:
                images = np.stack([np.asarray(r.image, compiled.in_dtype)
                                   for r in alive])

                def abort() -> bool:
                    return all(r.status != "pending" for r in alive)

                try:
                    # chaos seam: a scheduled worker crash raises here
                    # and rides the failed-dispatch path below — the
                    # requests fail, the fleet takes a health strike
                    # and re-routes, exactly as for a real device loss
                    self._fault_check("dispatch", plan_id=entry.plan_id,
                                      n=len(alive))
                    out = await self._loop.run_in_executor(
                        self._executor,
                        lambda: np.asarray(
                            compiled(images, should_abort=abort)))
                except DispatchAborted:
                    self.aborted_dispatches += 1
                    self._track("dispatch_aborted",
                                plan_id=entry.plan_id, n=len(alive))
                    out = None
                except Exception as e:        # noqa: BLE001 — a failed
                    # dispatch must fail its requests, never strand
                    # their futures in a forever-pending state
                    for r in alive:
                        r._finish("failed", error=e)
                        self.failed += 1
                    out = None
                if out is not None:
                    done = 0
                    for k, r in enumerate(alive):
                        if r.status == "pending":
                            r._finish("done", output=out[k])
                            self.served += 1
                            entry.served += 1
                            done += 1
                    self._note_step(len(alive), launched_at=launched_at)
                    self._track("dispatch_complete",
                                plan_id=entry.plan_id, n=done)
        finally:
            self._inflight -= 1
            for s in slots:
                self.release(s)       # hooks re-wake the drain task
            self._adapt_bound(force=True)   # fresh rate → fresh bound
            self._signal_space()

    # -- fleet draining seam ----------------------------------------------
    def extract_queued(self) -> List[AsyncRequest]:
        """Pull every queued-but-not-in-flight request out of the
        admission queue so a fleet front door can re-route it to
        another worker (graceful drain).  Each extracted request is
        cancelled — its future resolves as cancelled and its admission
        slot frees via the normal terminal hook — and the returned
        ``AsyncRequest``s carry everything (image, plan id, priority,
        absolute deadline) a re-route needs.  In-flight batches are
        untouched: they finish through the usual dispatch path."""
        evicted = self.queue.evict_pending()
        for req in evicted:
            req.cancel()            # terminal hook releases the bound
        self._signal_space()
        return evicted

    # -- sugar ------------------------------------------------------------
    async def infer(self, image, **kw) -> np.ndarray:
        """Submit and await the result in one call."""
        fut = await self.submit(image, **kw)
        return await fut

    # the gateway reuses SlotPool's slot bookkeeping + telemetry, but its
    # serving interface is submit/infer — the sync drain entry points
    # would silently mis-admit (async submit has a different signature)
    def run(self, requests, **kw):
        raise TypeError(
            "AsyncCNNGateway has no sync drain — submit requests with "
            "`await gw.submit(img)` / `gw.submit_nowait(img)` (or use "
            "repro.serve.CNNEngine for list workloads)")

    def step(self):
        raise TypeError("AsyncCNNGateway dispatches continuously; "
                        "there is no manual step()")

    # -- observability ----------------------------------------------------
    def snapshot(self) -> GatewayStats:
        """One consistent ``GatewayStats`` capture on the gateway's own
        clock: queue depth, in-flight slots, occupancy histogram, and
        every terminal counter in a single pass — the heartbeat the
        fleet health checks and routers read (never racing dict
        reads)."""
        # chaos seam: a stalled/crashed worker raises here, which
        # ``FleetWorker.view`` reads as a missed heartbeat — the same
        # path a hung process takes
        self._fault_check("heartbeat")
        return super().snapshot(
            clock=self.clock, queue_depth=len(self.queue),
            served=self.served, rejected=self.rejected,
            expired=self.queue.expired, cancelled=self.cancelled,
            failed=self.failed)

    def stats(self) -> dict:
        """Gateway counters + the SlotPool occupancy histogram + the
        shared-cache compile telemetry (one entry per distinct
        (layer, bucket) across *all* registered plans).  Built from one
        ``snapshot()`` so every field is from the same instant."""
        snap = self.snapshot()
        return {
            "plans": {pid: e.served for pid, e in self.plans.items()},
            "retiring": sorted(self._retiring),
            "retired_plans": dict(self.retired_plans),
            "served": snap.served,
            "rejected": snap.rejected,
            "expired": snap.expired,
            "cancelled": snap.cancelled,
            "failed": snap.failed,
            "shed": self.queue.shed,
            "aborted_dispatches": self.aborted_dispatches,
            "pending": snap.queue_depth,
            "inflight": snap.inflight,
            "max_pending": self.queue.max_pending,
            "wait_budget_s": self.cfg.wait_budget_s,
            "max_batch": self.cfg.max_batch,
            "slots": snap.max_batch,   # = max_batch × max_inflight
            "max_inflight": self.cfg.max_inflight,
            "policy": self.queue.policy.name,
            "steps": snap.steps,
            "occupancy_hist": dict(snap.occupancy_hist),
            "service_rate": snap.service_rate,
            "est_wait": snap.est_wait,
            "exec_cache": self.exec_cache.stats(),
        }
