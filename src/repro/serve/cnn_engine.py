"""Plan-driven CNN inference engine: dynamic batching over fixed slots,
executing through ``repro.runtime.CompiledCNN``.

The engine keeps the slot discipline it shares with the transformer
engine (now factored into ``repro.serve.slots.SlotPool``): a fixed pool
of ``max_batch`` image slots filled from the request queue, one step per
tick, outputs scattered back.  Execution is the new part — each tick
gathers only the *live* images and hands them to a ``CompiledCNN``,
which dispatches to the smallest AOT-compiled batch bucket ≥ the live
count.  A lone request runs the size-1 executable instead of padding to
``max_batch`` (the seed behavior: one image paid for 16), and because
every bucket was compiled at construction, no tick ever hits a compile
stall.

Construction is **plan-driven**: ``CNNEngine.from_plan`` takes a
``deploy.DeploymentPlan`` — including one loaded from a JSON artifact
(``repro.runtime.load_plan``) — and serves exactly the per-layer
(block, data_bits, coeff_bits) assignment the planner chose.

Data parallelism: pass a device mesh (``repro.parallel.sharding.
cnn_data_mesh``) and every bucket's executable shards the batch
dimension over the data axes.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Sequence

import numpy as np

from repro.blocks import BlockLike
from repro.core.cnn import CNNConfig
from repro.runtime.compiled import CompiledCNN
from repro.serve.slots import SlotPool


@dataclass
class CNNServeConfig:
    max_batch: int = 8             # slot-pool size = top batch bucket
    aot_warmup: bool = True        # pre-compile all buckets at init


@dataclass
class ImageRequest:
    image: np.ndarray              # (H, W, C) quantized container ints
    request_id: int = 0
    priority: int = 0              # higher = more urgent (policy="edf")
    deadline: Optional[float] = None   # absolute engine-clock deadline
    output: Optional[np.ndarray] = None
    done: bool = False


def validate_image(img, in_shape, in_dtype, request_id=0) -> np.ndarray:
    """Shape + dtype admission check shared by the sync engine and the
    async gateway.  A float image must carry exact container-range
    integers — the seed's silent ``np.asarray(img, in_dtype)``
    truncation (0.9 → 0, 200.0 → -56 for int8) is a ``ValueError``
    here, as is any value that would wrap in the container."""
    img = np.asarray(img)
    if tuple(img.shape) != tuple(in_shape):
        raise ValueError(
            f"request {request_id}: image shape {tuple(img.shape)} "
            f"!= engine input {tuple(in_shape)}")
    if not np.issubdtype(img.dtype, np.integer):
        if not np.all(np.isfinite(img)) or np.any(img != np.round(img)):
            raise ValueError(
                f"request {request_id}: image dtype {img.dtype} "
                f"carries non-integral values — quantize explicitly "
                f"(e.g. ops.quantize_fixed) before submitting")
    info = np.iinfo(in_dtype)
    if np.any(img < info.min) or np.any(img > info.max):
        raise ValueError(
            f"request {request_id}: image values outside the "
            f"{np.dtype(in_dtype).name} container range "
            f"[{info.min}, {info.max}] — would wrap, not clamp")
    return img


class CNNEngine(SlotPool):
    def __init__(self, cfg: CNNConfig, params, blocks: Sequence[BlockLike],
                 serve_cfg: Optional[CNNServeConfig] = None, mesh=None, *,
                 compiled: Optional[CompiledCNN] = None):
        serve_cfg = serve_cfg if serve_cfg is not None else CNNServeConfig()
        super().__init__(serve_cfg.max_batch)
        if compiled is None:
            compiled = CompiledCNN(cfg, params, blocks,
                                   max_batch=serve_cfg.max_batch,
                                   mesh=mesh, warmup=serve_cfg.aot_warmup)
        elif compiled.max_batch < serve_cfg.max_batch:
            raise ValueError(
                f"compiled max_batch={compiled.max_batch} smaller than the "
                f"slot pool ({serve_cfg.max_batch}): a full pool could "
                f"never dispatch")
        self.compiled = compiled
        self.cfg = compiled.cfg
        self.params = compiled.params
        self.blocks = compiled.blocks
        self.serve = serve_cfg
        self.mesh = mesh
        self.in_shape = compiled.in_shape
        self.in_dtype = compiled.in_dtype
        self.images_served = 0

    # -- construction from a deployment plan ----------------------------
    @classmethod
    def from_plan(cls, plan, cfg: Optional[CNNConfig] = None, *,
                  params=None, key=None,
                  serve_cfg: Optional[CNNServeConfig] = None, mesh=None
                  ) -> "CNNEngine":
        """Engine for a planned deployment: each layer runs the
        (block, bits) assignment of ``plan`` (``cfg`` defaults to the
        network embedded in the plan); ``params`` default to a fresh
        ``init_cnn`` draw at the planned precisions."""
        serve_cfg = serve_cfg if serve_cfg is not None else CNNServeConfig()
        if serve_cfg.max_batch < 1:       # fail before compiling anything
            raise ValueError(f"max_batch={serve_cfg.max_batch} must be ≥ 1")
        compiled = CompiledCNN.from_plan(
            plan, cfg, params=params, key=key,
            max_batch=serve_cfg.max_batch, mesh=mesh,
            warmup=serve_cfg.aot_warmup)
        return cls(compiled.cfg, compiled.params, compiled.blocks,
                   serve_cfg, mesh, compiled=compiled)

    # -- admission -------------------------------------------------------
    def submit(self, req: ImageRequest) -> bool:
        """Place a request into a free slot; False when the pool is full
        (the request waits in the caller's queue for the next step).
        Shape AND dtype are validated via ``validate_image`` — the
        admission contract the async gateway shares."""
        validate_image(req.image, self.in_shape, self.in_dtype,
                       req.request_id)
        slot = self._free_slot()
        if slot is None:
            return False
        self.active[slot] = req
        return True

    # -- one engine tick: run every occupied slot through the CNN --------
    def step(self) -> int:
        """One bucketed forward over the live slots; returns how many
        images were served.  Only the occupied slots are gathered — the
        ``CompiledCNN`` pads to the smallest pre-compiled bucket, so a
        half-empty pool does a fraction of the full-pool work."""
        live = self.live()
        if not live:
            return 0
        batch = np.stack([np.asarray(r.image, self.in_dtype)
                          for _, r in live])
        out = np.asarray(self.compiled(batch))
        for k, (i, r) in enumerate(live):
            r.output = out[k]
            r.done = True
            self.release(i)
        self._note_step(len(live))
        self.images_served += len(live)
        return len(live)

    def stats(self) -> dict:
        """Aggregate serving counters plus occupancy/bucket telemetry:
        ``occupancy_hist`` is the live-slot histogram per step and
        ``bucket_hits`` counts dispatches per AOT batch bucket — together
        they make the bucketed-batching win observable.  Histogram and
        step count come from one ``SlotPool.snapshot()`` capture (the
        same consistent-snapshot seam the async gateway and the fleet
        health checks use)."""
        snap = self.snapshot(served=self.images_served)
        return {
            "images_served": snap.served,
            "steps": snap.steps,
            "images_per_step": snap.served / max(snap.steps, 1),
            "max_batch": snap.max_batch,
            "occupancy_hist": dict(snap.occupancy_hist),
            "bucket_hits": dict(self.compiled.bucket_hits),
            "aot_warmed_up": self.compiled.warmed_up,
        }
