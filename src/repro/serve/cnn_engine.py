"""Plan-driven CNN inference engine: dynamic batching over fixed slots.

The transformer engine (``repro.serve.engine``) holds a static pool of
decode slots so every step hits one compiled executable; this is the
same slot discipline for feed-forward CNN traffic.  A fixed pool of
``max_batch`` image slots is filled from the request queue, the whole
pool runs through ONE jitted ``cnn_forward`` step — every layer a
single batched kernel call on the (max_batch, H, W, C) tensor — and the
outputs scatter back to their requests.  Empty slots carry zeros; the
batch shape never changes, so the step never recompiles.

Construction is **plan-driven**: ``CNNEngine.from_plan`` takes a
``deploy.DeploymentPlan`` and runs each layer with exactly the block and
(data_bits, coeff_bits) the planner chose for the target device — the
paper's model-driven deployment loop, serving.

Data parallelism: pass a device mesh (``repro.parallel.sharding.
cnn_data_mesh``) and the batch dimension is sharded over the data axes —
inputs are placed with ``cnn_batch_sharding`` and the jitted step keeps
every layer's activations on that sharding.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.blocks import BlockLike, get_block
from repro.core.cnn import CNNConfig, cnn_forward, init_cnn
from repro.kernels import conv2d


@dataclass
class CNNServeConfig:
    max_batch: int = 8             # slot-pool size = compiled batch shape


@dataclass
class ImageRequest:
    image: np.ndarray              # (H, W, C) quantized container ints
    request_id: int = 0
    output: Optional[np.ndarray] = None
    done: bool = False


class CNNEngine:
    def __init__(self, cfg: CNNConfig, params, blocks: Sequence[BlockLike],
                 serve_cfg: Optional[CNNServeConfig] = None, mesh=None):
        if len(tuple(blocks)) != len(cfg.layers):
            raise ValueError(
                f"need one block per layer: {len(tuple(blocks))} blocks "
                f"for {len(cfg.layers)} layers")
        serve_cfg = serve_cfg if serve_cfg is not None else CNNServeConfig()
        if serve_cfg.max_batch < 1:
            raise ValueError(
                f"max_batch={serve_cfg.max_batch} must be ≥ 1 (a zero-slot "
                f"pool can never drain its queue)")
        self.cfg = cfg
        self.params = params
        self.blocks = [get_block(b) for b in blocks]
        self.serve = serve_cfg
        self.mesh = mesh

        spec0 = cfg.layers[0]
        self.in_shape = (cfg.img_h, cfg.img_w, spec0.in_channels)
        self.in_dtype = conv2d.container_dtype(spec0.data_bits)
        self.active: List[Optional[ImageRequest]] = \
            [None] * self.serve.max_batch
        self.steps = 0
        self.images_served = 0

        self._batch_sharding = None
        if mesh is not None:
            from repro.parallel.sharding import cnn_batch_sharding
            self._batch_sharding = cnn_batch_sharding(
                mesh, self.serve.max_batch)

        blks = self.blocks
        self._step = jax.jit(
            lambda p, batch: cnn_forward(p, batch, cfg, blks, mesh=mesh))

    # -- construction from a deployment plan ----------------------------
    @classmethod
    def from_plan(cls, plan, cfg: CNNConfig, *, params=None, key=None,
                  serve_cfg: Optional[CNNServeConfig] = None, mesh=None
                  ) -> "CNNEngine":
        """Engine for a planned deployment: each layer runs the
        (block, bits) assignment of ``plan`` (``deploy.plan_config``
        bakes it into the config); ``params`` default to a fresh
        ``init_cnn`` draw at the planned precisions."""
        from repro.core import deploy
        pcfg = deploy.plan_config(plan, cfg)
        if params is None:
            key = key if key is not None else jax.random.PRNGKey(0)
            params = init_cnn(key, pcfg)
        return cls(pcfg, params, plan.block_names(), serve_cfg, mesh)

    # -- slot management ------------------------------------------------
    def _free_slot(self) -> Optional[int]:
        for i, r in enumerate(self.active):
            if r is None:
                return i
        return None

    def submit(self, req: ImageRequest) -> bool:
        """Place a request into a free slot; False when the pool is full
        (the request waits in the caller's queue for the next step)."""
        img = np.asarray(req.image)
        if tuple(img.shape) != self.in_shape:
            raise ValueError(
                f"request {req.request_id}: image shape {tuple(img.shape)} "
                f"!= engine input {self.in_shape}")
        slot = self._free_slot()
        if slot is None:
            return False
        self.active[slot] = req
        return True

    # -- one engine tick: run every occupied slot through the CNN --------
    def step(self) -> int:
        """One jitted forward over the whole slot pool; returns how many
        images were served.  Empty slots ride along as zeros — the batch
        shape is static so every tick reuses the compiled step."""
        live = [(i, r) for i, r in enumerate(self.active) if r is not None]
        if not live:
            return 0
        batch = np.zeros((self.serve.max_batch,) + self.in_shape,
                         self.in_dtype)
        for i, r in live:
            batch[i] = np.asarray(r.image, self.in_dtype)
        xb = jnp.asarray(batch)
        if self._batch_sharding is not None:
            xb = jax.device_put(xb, self._batch_sharding)
        out = np.asarray(self._step(self.params, xb))
        for i, r in live:
            r.output = out[i]
            r.done = True
            self.active[i] = None
        self.steps += 1
        self.images_served += len(live)
        return len(live)

    def run(self, requests: List[ImageRequest]) -> List[ImageRequest]:
        """Serve a workload to completion: fill slots from the queue,
        step, repeat — the dynamic-batching loop."""
        queue = list(requests)
        while queue or any(r is not None for r in self.active):
            while queue and self.submit(queue[0]):
                queue.pop(0)
            self.step()
        return requests

    def stats(self) -> dict:
        """Aggregate serving counters (images/step ≈ realized batch)."""
        return {
            "images_served": self.images_served,
            "steps": self.steps,
            "images_per_step": self.images_served / max(self.steps, 1),
            "max_batch": self.serve.max_batch,
        }
