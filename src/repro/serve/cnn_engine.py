"""Plan-driven CNN inference engine: dynamic batching over fixed slots,
executing through ``repro.runtime.CompiledCNN``.

The engine keeps the slot discipline it shares with the transformer
engine (now factored into ``repro.serve.slots.SlotPool``): a fixed pool
of ``max_batch`` image slots filled from the request queue, one step per
tick, outputs scattered back.  Execution is the new part — each tick
gathers only the *live* images and hands them to a ``CompiledCNN``,
which dispatches to the smallest AOT-compiled batch bucket ≥ the live
count.  A lone request runs the size-1 executable instead of padding to
``max_batch`` (the seed behavior: one image paid for 16), and because
every bucket was compiled at construction, no tick ever hits a compile
stall.

Construction is **plan-driven**: ``CNNEngine.from_plan`` takes a
``deploy.DeploymentPlan`` — including one loaded from a JSON artifact
(``repro.runtime.load_plan``) — and serves exactly the per-layer
(block, data_bits, coeff_bits) assignment the planner chose.

Data parallelism: pass a device mesh (``repro.parallel.sharding.
cnn_data_mesh``) and every bucket's executable shards the batch
dimension over the data axes.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Sequence

import numpy as np

from repro.blocks import BlockLike
from repro.core.cnn import CNNConfig
from repro.runtime.compiled import (CompiledCNN, CompiledModel,
                                    validate_container_input)
from repro.serve.slots import SlotPool


@dataclass
class CNNServeConfig:
    max_batch: int = 8             # slot-pool size = top batch bucket
    aot_warmup: bool = True        # pre-compile all buckets at init


@dataclass
class ImageRequest:
    """One request payload.  ``image`` is whatever the plan's workload
    serves — an (H, W, C) quantized container-int image for CNN plans,
    a (seq_len, d_model) float32 token block for MoE plans; the engine's
    compiled backend validates it at admission."""
    image: np.ndarray
    request_id: int = 0
    priority: int = 0              # higher = more urgent (policy="edf")
    deadline: Optional[float] = None   # absolute engine-clock deadline
    output: Optional[np.ndarray] = None
    done: bool = False


def validate_image(img, in_shape, in_dtype, request_id=0) -> np.ndarray:
    """Deprecated alias of ``runtime.validate_container_input`` (the
    shape + container-range admission check for integer-quantized
    inputs).  Per-workload validation lives on the compiled backend now
    — ``CompiledModel.validate_input`` — so the engines cover non-image
    workloads too; this name survives for pre-workload callers."""
    import warnings
    warnings.warn(
        "validate_image is deprecated; use runtime."
        "validate_container_input, or the per-workload "
        "CompiledModel.validate_input", DeprecationWarning, stacklevel=2)
    return validate_container_input(img, in_shape, in_dtype, request_id,
                                    noun="image")


class CNNEngine(SlotPool):
    def __init__(self, cfg: Optional[CNNConfig] = None, params=None,
                 blocks: Optional[Sequence[BlockLike]] = None,
                 serve_cfg: Optional[CNNServeConfig] = None, mesh=None, *,
                 compiled: Optional[CompiledModel] = None,
                 exec_cache=None):
        serve_cfg = serve_cfg if serve_cfg is not None else CNNServeConfig()
        super().__init__(serve_cfg.max_batch)
        if compiled is None:
            compiled = CompiledCNN(cfg, params, blocks,
                                   max_batch=serve_cfg.max_batch,
                                   mesh=mesh, warmup=serve_cfg.aot_warmup,
                                   exec_cache=exec_cache)
        elif compiled.max_batch < serve_cfg.max_batch:
            raise ValueError(
                f"compiled max_batch={compiled.max_batch} smaller than the "
                f"slot pool ({serve_cfg.max_batch}): a full pool could "
                f"never dispatch")
        self.compiled = compiled
        # CNN backends expose cfg/params/blocks; other workloads don't —
        # the engine itself only ever touches the CompiledModel protocol
        self.cfg = getattr(compiled, "cfg", None)
        self.params = getattr(compiled, "params", None)
        self.blocks = getattr(compiled, "blocks", None)
        self.serve = serve_cfg
        self.mesh = mesh
        self.in_shape = compiled.in_shape
        self.in_dtype = compiled.in_dtype
        self.images_served = 0

    # -- construction from a deployment plan ----------------------------
    @classmethod
    def from_plan(cls, plan, cfg: Optional[CNNConfig] = None, *,
                  params=None, key=None,
                  serve_cfg: Optional[CNNServeConfig] = None, mesh=None,
                  exec_cache=None) -> "CNNEngine":
        """Engine for a planned deployment of **any workload kind**:
        the plan's ``WorkloadSpec`` builds the compiled backend
        (``runtime.compile_plan``), so an MoE plan serves through the
        same engine as a CNN plan.  ``cfg`` (CNN plans only) overrides
        the network embedded in the plan; ``params`` default to a fresh
        draw at the planned precisions.  ``exec_cache`` (e.g. a
        ``repro.ops.PersistentExecutableCache``) makes a warm restart
        deserialize its executables instead of recompiling."""
        serve_cfg = serve_cfg if serve_cfg is not None else CNNServeConfig()
        if serve_cfg.max_batch < 1:       # fail before compiling anything
            raise ValueError(f"max_batch={serve_cfg.max_batch} must be ≥ 1")
        if cfg is not None:
            compiled = CompiledCNN.from_plan(
                plan, cfg, params=params, key=key,
                max_batch=serve_cfg.max_batch, mesh=mesh,
                warmup=serve_cfg.aot_warmup, exec_cache=exec_cache)
        else:
            from repro.runtime.workloads import compile_plan
            compiled = compile_plan(
                plan, params=params, key=key,
                max_batch=serve_cfg.max_batch, mesh=mesh,
                warmup=serve_cfg.aot_warmup, exec_cache=exec_cache)
        return cls(serve_cfg=serve_cfg, mesh=mesh, compiled=compiled)

    # -- admission -------------------------------------------------------
    def submit(self, req: ImageRequest) -> bool:
        """Place a request into a free slot; False when the pool is full
        (the request waits in the caller's queue for the next step).
        Shape AND dtype are validated via the compiled backend's
        per-workload ``validate_input`` — the admission contract the
        async gateway shares."""
        self.compiled.validate_input(req.image, req.request_id)
        slot = self._free_slot()
        if slot is None:
            return False
        self.active[slot] = req
        return True

    # -- one engine tick: run every occupied slot through the CNN --------
    def step(self) -> int:
        """One bucketed forward over the live slots; returns how many
        images were served.  Only the occupied slots are gathered — the
        ``CompiledCNN`` pads to the smallest pre-compiled bucket, so a
        half-empty pool does a fraction of the full-pool work."""
        live = self.live()
        if not live:
            return 0
        batch = np.stack([np.asarray(r.image, self.in_dtype)
                          for _, r in live])
        out = np.asarray(self.compiled(batch))
        for k, (i, r) in enumerate(live):
            r.output = out[k]
            r.done = True
            self.release(i)
        self._note_step(len(live))
        self.images_served += len(live)
        return len(live)

    def stats(self) -> dict:
        """Aggregate serving counters plus occupancy/bucket telemetry:
        ``occupancy_hist`` is the live-slot histogram per step and
        ``bucket_hits`` counts dispatches per AOT batch bucket — together
        they make the bucketed-batching win observable.  Histogram and
        step count come from one ``SlotPool.snapshot()`` capture (the
        same consistent-snapshot seam the async gateway and the fleet
        health checks use)."""
        snap = self.snapshot(served=self.images_served)
        return {
            "images_served": snap.served,
            "steps": snap.steps,
            "images_per_step": snap.served / max(snap.steps, 1),
            "max_batch": snap.max_batch,
            "occupancy_hist": dict(snap.occupancy_hist),
            "bucket_hits": dict(self.compiled.bucket_hits),
            "aot_warmed_up": self.compiled.warmed_up,
        }
