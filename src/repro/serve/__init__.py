from repro.serve.slots import SlotPool
from repro.serve.engine import ServeConfig, Engine, Request
from repro.serve.cnn_engine import CNNEngine, CNNServeConfig, ImageRequest

__all__ = ["ServeConfig", "Engine", "Request", "SlotPool",
           "CNNEngine", "CNNServeConfig", "ImageRequest"]
