from repro.serve.slots import GatewayStats, SlotPool
from repro.serve.policy import (DeadlinePolicy, FifoPolicy,
                                SchedulingPolicy, get_policy,
                                list_policies)
from repro.serve.engine import ServeConfig, Engine, Request
from repro.serve.cnn_engine import (CNNEngine, CNNServeConfig,
                                    ImageRequest, validate_image)
from repro.serve.async_engine import (AdmissionQueue, AsyncCNNGateway,
                                      AsyncRequest, AsyncServeConfig,
                                      DeadlineExpired, GatewayBacklog,
                                      PlanUnavailable, RequestCancelled)

__all__ = ["ServeConfig", "Engine", "Request", "SlotPool", "GatewayStats",
           "CNNEngine", "CNNServeConfig", "ImageRequest", "validate_image",
           "SchedulingPolicy", "FifoPolicy", "DeadlinePolicy",
           "get_policy", "list_policies",
           "AdmissionQueue", "AsyncCNNGateway", "AsyncRequest",
           "AsyncServeConfig", "DeadlineExpired", "GatewayBacklog",
           "PlanUnavailable", "RequestCancelled"]
