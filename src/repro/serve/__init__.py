from repro.serve.engine import ServeConfig, Engine, Request
from repro.serve.cnn_engine import CNNEngine, CNNServeConfig, ImageRequest

__all__ = ["ServeConfig", "Engine", "Request",
           "CNNEngine", "CNNServeConfig", "ImageRequest"]
