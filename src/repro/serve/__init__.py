from repro.serve.engine import ServeConfig, Engine, Request

__all__ = ["ServeConfig", "Engine", "Request"]
