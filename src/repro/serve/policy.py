"""Request-scheduling policies shared by every serving engine.

The sync tick-loop engines (``repro.serve.engine``, ``repro.serve.
cnn_engine``) and the async continuous-batching gateway (``repro.serve.
async_engine``) must order work **identically** — otherwise "simple
path" and "production path" serve the same workload in different orders
and tail-latency comparisons are meaningless.  This module is the one
place that ordering lives:

  ``FifoPolicy``      arrival order (the seed behavior).
  ``DeadlinePolicy``  priority tiers first (higher ``priority`` wins),
                      then earliest deadline (EDF), then arrival order —
                      a request without a deadline sorts after every
                      request that has one, inside its priority tier.

A policy maps a request to a **static sort key** (``key``); engines are
free to heapify once (the sync drain) or keep a live heap (the async
gateway) — the realized order is the same either way.  Requests are
duck-typed: ``priority`` / ``deadline`` are read with ``getattr``
defaults, so the LM ``Request`` (which has neither) sorts FIFO under
every policy.

Deadlines are *absolute* timestamps on the engine's clock
(``time.monotonic`` unless injected); ``expired(req, now)`` is the one
shared definition of "too late" so the sync and async paths can never
disagree about it.
"""

from __future__ import annotations

import math
from typing import List, Optional, Sequence, Tuple, Union


def priority_of(req) -> int:
    """Higher = more urgent; requests without the attribute are 0."""
    p = getattr(req, "priority", 0)
    return 0 if p is None else int(p)


def deadline_of(req) -> Optional[float]:
    """Absolute deadline on the engine clock, or None (no deadline)."""
    return getattr(req, "deadline", None)


def expired(req, now: float) -> bool:
    """True when ``req`` can no longer be started on time.  The one
    shared lateness rule: a request is expired once ``now`` has passed
    its absolute deadline; no-deadline requests never expire."""
    d = deadline_of(req)
    return d is not None and now > d


class SchedulingPolicy:
    """Orders requests.  ``key`` must be a static, mutually comparable
    tuple — engines sort/heapify on it without re-keying."""

    name = "policy"

    def key(self, req, seq: int, now: float) -> Tuple:
        raise NotImplementedError

    def shed_key(self, req, seq: int, now: float) -> Tuple:
        """Shed order is the *reverse* of service order: when bounded
        admission must eject a pending request to make room for a more
        urgent arrival, the victim is the pending entry with the
        **maximal** ``shed_key`` — by default the very key batches form
        on, so the last request that would have been served is the
        first one shed.  One ordering, two doors: batch formation and
        admission shedding can never disagree about who is least
        urgent.  Under FIFO the newest arrival always carries the
        maximal key, so a newcomer never outranks anyone and shedding
        degenerates to plain refusal — the seed behavior."""
        return self.key(req, seq, now)

    def order(self, reqs: Sequence, now: float) -> List:
        """Requests sorted most-urgent-first (stable on arrival order)."""
        return [r for _, _, r in sorted(
            (self.key(r, i, now), i, r) for i, r in enumerate(reqs))]


class FifoPolicy(SchedulingPolicy):
    """Arrival order — the seed engines' implicit policy."""

    name = "fifo"

    def key(self, req, seq: int, now: float) -> Tuple:
        return (seq,)


class DeadlinePolicy(SchedulingPolicy):
    """Priority tiers, then earliest-deadline-first, then arrival.

    Sort key: ``(-priority, deadline or +inf, seq)`` — a high-priority
    request preempts every lower tier regardless of deadlines, and
    inside a tier the soonest deadline runs first (no-deadline requests
    queue behind all deadlined ones, FIFO among themselves)."""

    name = "edf"

    def key(self, req, seq: int, now: float) -> Tuple:
        d = deadline_of(req)
        return (-priority_of(req), math.inf if d is None else float(d), seq)


FIFO = FifoPolicy()
EDF = DeadlinePolicy()

_POLICIES = {"fifo": FIFO, "edf": EDF, "deadline": EDF}

PolicyLike = Union[str, SchedulingPolicy, None]


def get_policy(policy: PolicyLike) -> SchedulingPolicy:
    """Resolve a policy name (or pass a policy through).  ``None`` means
    FIFO — the seed behavior stays the default everywhere."""
    if policy is None:
        return FIFO
    if isinstance(policy, SchedulingPolicy):
        return policy
    try:
        return _POLICIES[policy]
    except KeyError:
        raise ValueError(
            f"unknown scheduling policy {policy!r}; "
            f"known: {sorted(set(_POLICIES))}") from None


def list_policies() -> Tuple[str, ...]:
    return tuple(sorted(set(_POLICIES)))
