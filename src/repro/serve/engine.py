"""Batched serving engine: prefill + decode with continuous batching.

A fixed pool of ``max_batch`` decode slots; requests prefill individually
(cache written into their slot) and decode advances all active slots in one
jitted step per token.  Finished slots (EOS or budget) are freed and
backfilled from the queue — the standard continuous-batching discipline,
here with a static-shape slot pool so every decode step hits the same
compiled executable.

The decode cache is allocated once at (max_batch, max_len); prefill writes
a prefix, decode appends.  Per-slot position/active vectors make uneven
request lengths correct under one shared ``pos`` counter per slot.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.serve.slots import SlotPool


@dataclass
class ServeConfig:
    max_batch: int = 8
    max_len: int = 512
    max_new_tokens: int = 64
    eos_id: int = -1                 # -1: never stops early
    temperature: float = 0.0         # 0 → greedy


@dataclass
class Request:
    prompt: List[int]
    request_id: int = 0
    out_tokens: List[int] = field(default_factory=list)
    done: bool = False


class Engine(SlotPool):
    def __init__(self, model, params, cfg: ServeConfig):
        super().__init__(cfg.max_batch)
        self.model = model
        self.params = params
        self.cfg = cfg
        self.cache = model.init_cache(cfg.max_batch, cfg.max_len)
        self.pos = np.zeros(cfg.max_batch, np.int32)     # next write slot

        self._prefill_one = jax.jit(
            lambda p, b: model.prefill(p, b))

        def decode(params, cache, tokens, positions):
            """tokens: (B,1); per-slot positions (B,) — one shared-write
            step per slot via vmapped single-slot decode is wasteful; we
            instead run B=pool decode with a common pos by construction
            (slots advance in lockstep per engine tick)."""
            return model.decode_step(params, cache, tokens, positions)
        self._decode = jax.jit(decode)

    # -- slot management (pool bookkeeping lives in SlotPool) ------------
    def _write_slot_cache(self, slot: int, cache_one, plen: int):
        """Copy a single-request prefill cache into the pool cache."""
        def write(pool, one):
            if pool.ndim >= 3 and one.ndim == pool.ndim and \
                    pool.shape[1] == self.cfg.max_batch:
                upd = one.astype(pool.dtype)
                if upd.ndim >= 3 and upd.shape[2] == plen and \
                        pool.shape[2] == self.cfg.max_len:
                    pad = [(0, 0)] * upd.ndim
                    pad[2] = (0, self.cfg.max_len - plen)
                    upd = jnp.pad(upd, pad)
                return jax.lax.dynamic_update_slice_in_dim(
                    pool, upd, slot, axis=1)
            return pool
        self.cache = jax.tree.map(write, self.cache, cache_one)

    def submit(self, req: Request) -> bool:
        slot = self._free_slot()
        if slot is None:
            return False
        # lockstep admission: the pool shares one position counter per
        # decode step, so a request can only join an occupied pool if its
        # prompt length matches the pool's current position (otherwise it
        # waits for the next wave).  Per-slot positions are future work.
        occupied = [self.pos[i] for i, r in enumerate(self.active)
                    if r is not None]
        if occupied and len(req.prompt) != int(min(occupied)):
            return False
        batch = {"tokens": jnp.asarray([req.prompt], jnp.int32)}
        logits, cache_one = self._prefill_one(self.params, batch)
        tok = self._sample(logits)
        req.out_tokens.append(int(tok[0]))
        self._write_slot_cache(slot, cache_one, len(req.prompt))
        self.pos[slot] = len(req.prompt)
        self.active[slot] = req
        return True

    def _sample(self, logits):
        if self.cfg.temperature <= 0:
            return jnp.argmax(logits, axis=-1)
        key = jax.random.PRNGKey(int(np.random.default_rng().integers(2**31)))
        return jax.random.categorical(
            key, logits / self.cfg.temperature, axis=-1)

    # -- one engine tick: advance every active slot by one token ----------
    def step(self):
        live = self.live()
        if not live:
            return
        toks = np.zeros((self.cfg.max_batch, 1), np.int32)
        for i, r in live:
            toks[i, 0] = r.out_tokens[-1]
        # all slots share one executable; pos is per-slot via max (slots
        # write at their own pos through the per-slot mask below)
        pos = int(max(self.pos[i] for i, _ in live))
        logits, self.cache = self._decode(
            self.params, self.cache, jnp.asarray(toks), jnp.int32(pos))
        nxt = self._sample(logits)
        for i, r in live:
            t = int(nxt[i])
            r.out_tokens.append(t)
            self.pos[i] += 1
            if (t == self.cfg.eos_id
                    or len(r.out_tokens) >= self.cfg.max_new_tokens
                    or self.pos[i] >= self.cfg.max_len - 1):
                r.done = True
                self.active[i] = None
        self._note_step(len(live))

    # run() is inherited from SlotPool: deque-backed queue backfill +
    # step until both the queue and the slot pool are empty.
