"""Mixture-of-Experts with sort-based top-k dispatch under a capacity bound.

Dispatch never materializes the O(tokens × experts × capacity) one-hot
tensor of the classic einsum formulation: assignments are ranked inside
their expert via a single argsort + bincount, then scattered into a dense
(experts × capacity, d_model) buffer that feeds one batched expert matmul.
Tokens beyond capacity are dropped (standard switch-style routing); the
combine step re-weights by the router probability and sums the surviving
top-k paths.

Expert parallelism: the expert axis of w_up/w_gate/w_down is sharded over
the ``model`` mesh axis (see parallel/sharding.py); the scatter/gather pair
is GSPMD's to schedule in the baseline, and is replaced by an explicit
``shard_map`` + ``all_to_all`` in the optimized path (§Perf).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import _act, dense_init, split_keys


def init_moe(key, cfg):
    m = cfg.moe
    d, fe, e = cfg.d_model, m.d_ff_expert, m.num_experts
    dt = cfg.jnp_dtype
    ks = split_keys(key, 7)
    p = {
        "router": dense_init(ks[0], (d, e), jnp.float32),
        "w_up": dense_init(ks[1], (e, d, fe), dt, fan_in=d),
        "w_down": dense_init(ks[2], (e, fe, d), dt, fan_in=fe),
    }
    if cfg.mlp_gated:
        p["w_gate"] = dense_init(ks[3], (e, d, fe), dt, fan_in=d)
    if m.n_shared_experts:
        fs = fe * m.n_shared_experts
        p["shared_up"] = dense_init(ks[4], (d, fs), dt)
        p["shared_down"] = dense_init(ks[5], (fs, d), dt, fan_in=fs)
        if cfg.mlp_gated:
            p["shared_gate"] = dense_init(ks[6], (d, fs), dt)
    return p


def quantize_moe_params(p, coeff_bits: int):
    """Fake-quantize the expert/shared FFN weights onto the symmetric
    ``coeff_bits``-bit fixed-point grid (per-tensor scale, mirroring
    ``ops.quantize_fixed``'s range): each tensor is scaled so its max
    magnitude maps to ``2^(c-1) - 1``, rounded, and scaled back — the
    values a ``coeff_bits``-wide container deployment would compute
    with, kept in float for the TPU matmuls.  The router projection is
    left exact: expert *choice* is control flow, and mis-rounding it
    swaps which experts run instead of adding bounded rounding noise
    (the serving planner quantizes compute, not routing).
    """
    hi = float((1 << (coeff_bits - 1)) - 1)

    def q(w):
        s = hi / jnp.maximum(jnp.max(jnp.abs(w)), 1e-9)
        return (jnp.round(w * s) / s).astype(w.dtype)

    return {k: (v if k == "router" else q(v)) for k, v in p.items()}


def _top_k(logits, k):
    vals, ids = jax.lax.top_k(logits, k)
    return vals, ids


def _hint(x, spec_axes, enable):
    """§Perf sharding hint: without it GSPMD replicates the (E, C, D)
    expert buffers across the data axis and every data rank computes every
    expert — the dominant waste in the MoE baselines (EXPERIMENTS §Perf)."""
    if not enable:
        return x
    from jax.sharding import PartitionSpec as P
    try:
        from jax._src.mesh import thread_resources
        names = thread_resources.env.physical_mesh.axis_names
        if "pod" in names:   # multi-pod: data-parallel axes are (pod, data)
            spec_axes = [("pod", "data") if a == "data" else a
                         for a in spec_axes]
        return jax.lax.with_sharding_constraint(x, P(*spec_axes))
    except Exception:
        return x   # no mesh (single-device tests)


def moe_layer(p, x, cfg):
    if cfg.moe_groups > 1:
        return moe_layer_grouped(p, x, cfg)
    return _moe_layer_flat(p, x, cfg)


def _moe_layer_flat(p, x, cfg):
    """x: (B,S,D) -> (out (B,S,D), aux_loss scalar)."""
    m = cfg.moe
    b, s, d = x.shape
    n = b * s
    e, k = m.num_experts, m.top_k
    xf = x.reshape(n, d)

    router_logits = xf.astype(jnp.float32) @ p["router"]          # (N,E)
    probs = jax.nn.softmax(router_logits, axis=-1)
    top_vals, top_ids = _top_k(probs, k)                          # (N,k)
    top_vals = top_vals / jnp.clip(
        jnp.sum(top_vals, axis=-1, keepdims=True), 1e-9)          # renorm

    # ---- load-balancing auxiliary loss (switch-style) ----------------
    me = jnp.mean(probs, axis=0)                                  # (E,)
    ce = jnp.mean(
        jnp.sum(jax.nn.one_hot(top_ids, e, dtype=jnp.float32), axis=1),
        axis=0)
    aux = e * jnp.sum(me * ce) * m.router_aux_weight

    # ---- sort-based rank-within-expert -------------------------------
    capacity = int(max(k, round(m.capacity_factor * n * k / e)))
    flat_ids = top_ids.reshape(-1)                                # (N*k,)
    sort_idx = jnp.argsort(flat_ids)                              # stable
    sorted_ids = flat_ids[sort_idx]
    counts = jnp.bincount(flat_ids, length=e)                     # (E,)
    starts = jnp.cumsum(counts) - counts                          # exclusive
    ranks_sorted = jnp.arange(n * k) - starts[sorted_ids]
    ranks = jnp.zeros_like(ranks_sorted).at[sort_idx].set(ranks_sorted)

    keep = ranks < capacity
    slot = jnp.where(keep, flat_ids * capacity + ranks, e * capacity)

    # ---- dispatch: scatter tokens into the expert buffer -------------
    token_of = jnp.repeat(jnp.arange(n), k)                       # (N*k,)
    hints = cfg.moe_shard_hints
    buf = jnp.zeros((e * capacity + 1, d), x.dtype)
    buf = buf.at[slot].set(xf[token_of], mode="drop")
    expert_in = _hint(buf[:-1].reshape(e, capacity, d),
                      ("model", "data", None), hints)

    # ---- expert FFN (batched over experts) ----------------------------
    h = jnp.einsum("ecd,edf->ecf", expert_in, p["w_up"])
    if "w_gate" in p:
        h = _act(jnp.einsum("ecd,edf->ecf", expert_in, p["w_gate"]),
                 cfg.act) * h
    else:
        h = _act(h, cfg.act)
    h = _hint(h, ("model", "data", None), hints)
    expert_out = _hint(jnp.einsum("ecf,efd->ecd", h, p["w_down"]),
                       ("model", "data", None), hints)

    # ---- combine: gather surviving assignments back -------------------
    flat_out = expert_out.reshape(e * capacity, d)
    gathered = jnp.where(
        keep[:, None], flat_out[jnp.minimum(slot, e * capacity - 1)],
        jnp.zeros((), x.dtype))                                    # (N*k, D)
    gathered = _hint(gathered, ("data", None), hints)
    # fused f32 contraction over k — never materializes an f32 (N·k, D)
    out = jnp.einsum("nkd,nk->nd", gathered.reshape(n, k, d),
                     top_vals.astype(jnp.float32),
                     preferred_element_type=jnp.float32).astype(x.dtype)
    out = _hint(out, ("data", None), hints)

    # ---- shared experts (always-on path) ------------------------------
    if "shared_up" in p:
        hs = xf @ p["shared_up"]
        if "shared_gate" in p:
            hs = _act(xf @ p["shared_gate"], cfg.act) * hs
        else:
            hs = _act(hs, cfg.act)
        out = out + hs @ p["shared_down"]

    return out.reshape(b, s, d), aux


def moe_layer_grouped(p, x, cfg):
    """§Perf (B2): group-local routing.

    Tokens are split into ``moe_groups`` groups aligned with the
    data-parallel axis; ranking / capacity / dispatch happen *inside* each
    group (a batched dimension sharded over ``data``), so the global
    argsort, rank scatter and gather collectives of the flat path
    disappear.  The expert buffers carry the group axis:
    (G→data, E→model, C, D) — the expert einsum is fully sharded with no
    resharding, and only the combine-side gather crosses the model axis
    (the all-to-all equivalent).  Capacity is per group:
    C_loc = cf·n_loc·k/E (same expected load, stricter tail — the usual
    EP trade-off).
    """
    m = cfg.moe
    b, s, d = x.shape
    n = b * s
    e, k = m.num_experts, m.top_k
    g = cfg.moe_groups
    assert n % g == 0, (n, g)
    nl = n // g
    hints = cfg.moe_shard_hints
    xg = _hint(x.reshape(g, nl, d), ("data", None, None), hints)

    router_logits = xg.astype(jnp.float32) @ p["router"]          # (G,NL,E)
    probs = jax.nn.softmax(router_logits, axis=-1)
    top_vals, top_ids = _top_k(probs, k)                          # (G,NL,k)
    top_vals = top_vals / jnp.clip(
        jnp.sum(top_vals, axis=-1, keepdims=True), 1e-9)

    me = jnp.mean(probs, axis=(0, 1))
    ce = jnp.mean(jnp.sum(jax.nn.one_hot(top_ids, e, dtype=jnp.float32),
                          axis=2), axis=(0, 1))
    aux = e * jnp.sum(me * ce) * m.router_aux_weight

    cap = int(max(k, round(m.capacity_factor * nl * k / e)))

    def rank_group(ids):
        """ids: (NL,k) — group-local capacity ranking -> (slot, keep)."""
        flat_ids = ids.reshape(-1)
        sort_idx = jnp.argsort(flat_ids)
        counts = jnp.bincount(flat_ids, length=e)
        starts = jnp.cumsum(counts) - counts
        ranks_sorted = jnp.arange(nl * k) - starts[flat_ids[sort_idx]]
        ranks = jnp.zeros_like(ranks_sorted).at[sort_idx].set(ranks_sorted)
        keep = ranks < cap
        slot = jnp.where(keep, flat_ids * cap + ranks, e * cap)
        return slot, keep

    def build_buf(xl, slot_g, keep_g):
        token_of = jnp.repeat(jnp.arange(nl), k)
        buf = jnp.zeros((e * cap + 1, d), xl.dtype)
        buf = buf.at[slot_g].set(xl[token_of], mode="drop")
        return buf[:-1].reshape(e, cap, d)

    slot, keep = jax.vmap(rank_group)(top_ids)
    if cfg.moe_combine_shardmap:
        # per model rank, build ONLY the local experts' buffers — the
        # forward dispatch needs no collective at all (§Perf B6)
        expert_in = _dispatch_shardmap(xg, slot, keep, nl=nl, e=e,
                                       cap=cap, d=d, k=k)
    else:
        expert_in = jax.vmap(build_buf)(xg, slot, keep)
    expert_in = _hint(expert_in, ("data", "model", None, None), hints)

    h = jnp.einsum("gecd,edf->gecf", expert_in, p["w_up"])
    if "w_gate" in p:
        h = _act(jnp.einsum("gecd,edf->gecf", expert_in, p["w_gate"]),
                 cfg.act) * h
    else:
        h = _act(h, cfg.act)
    h = _hint(h, ("data", "model", None, None), hints)
    expert_out = _hint(jnp.einsum("gecf,efd->gecd", h, p["w_down"]),
                       ("data", "model", None, None), hints)

    def combine_group(outs, slot_g, keep_g, vals):
        # scatter-add combine: weighted contributions accumulate straight
        # into the (NL, D) token buffer, so the cross-shard reduction is
        # k× smaller than reducing the gathered (NL·k, D) tensor (§Perf B3)
        flat = outs.reshape(e * cap, d)
        contrib = flat[jnp.minimum(slot_g, e * cap - 1)] * \
            vals.reshape(-1)[:, None].astype(flat.dtype)     # (NL*k, D)
        token_of = jnp.repeat(jnp.arange(nl), k)
        idx = jnp.where(keep_g, token_of, nl)
        acc = jnp.zeros((nl + 1, d), jnp.float32)
        acc = acc.at[idx].add(contrib.astype(jnp.float32), mode="drop")
        return acc[:-1]

    if cfg.moe_combine_shardmap:
        out = _combine_shardmap(expert_out, slot, keep, top_vals,
                                nl=nl, e=e, cap=cap, d=d, k=k)
    else:
        out = jax.vmap(combine_group)(expert_out, slot, keep, top_vals)
    out = _hint(out.astype(x.dtype), ("data", None, None), hints)
    out = out.reshape(b, s, d)

    if "shared_up" in p:
        xf = x.reshape(n, d)
        hs = xf @ p["shared_up"]
        if "shared_gate" in p:
            hs = _act(xf @ p["shared_gate"], cfg.act) * hs
        else:
            hs = _act(hs, cfg.act)
        out = out + (hs @ p["shared_down"]).reshape(b, s, d)
    return out, aux


def _combine_shardmap(expert_out, slot, keep, vals, *, nl, e, cap, d, k):
    """§Perf (B4): explicit-collective combine.

    GSPMD's gather-based combine all-reduces the k-expanded (NL·k, D)
    tensor (B3 showed it won't exploit scatter linearity).  Under
    shard_map each model rank gathers *only its local experts'* outputs,
    scatter-adds its partial (NL, D) token buffer, and a single
    ``psum`` over 'model' finishes the job — k× less wire traffic, by
    construction.
    """
    import functools

    from jax._src.mesh import thread_resources
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    mesh = thread_resources.env.physical_mesh
    if mesh.empty or "model" not in mesh.axis_names or \
            e % mesh.shape["model"]:
        # fallback: no mesh (tests) or non-divisible expert count
        return _combine_gspmd(expert_out, slot, keep, vals, nl=nl, e=e,
                              cap=cap, d=d, k=k)
    dp = ("pod", "data") if "pod" in mesh.axis_names else ("data",)
    dpa = dp if len(dp) > 1 else dp[0]

    def local(eo, sl, kp, vl):
        # eo (gl, el, cap, d); sl/kp (gl, NL·k); vl (gl, NL, k)
        gl, el = eo.shape[0], eo.shape[1]
        midx = jax.lax.axis_index("model")
        base = midx * el * cap

        def one(eo_g, sl_g, kp_g, vl_g):
            loc = sl_g - base
            ok = kp_g & (loc >= 0) & (loc < el * cap)
            flat = eo_g.reshape(el * cap, d)
            contrib = flat[jnp.clip(loc, 0, el * cap - 1)] * \
                vl_g.reshape(-1)[:, None].astype(flat.dtype)
            token_of = jnp.repeat(jnp.arange(nl), k)
            idx = jnp.where(ok, token_of, nl)
            acc = jnp.zeros((nl + 1, d), jnp.float32)
            acc = acc.at[idx].add(contrib.astype(jnp.float32),
                                  mode="drop")
            return acc[:-1]

        part = jax.vmap(one)(eo, sl, kp, vl)
        return jax.lax.psum(part.astype(jnp.bfloat16), "model")

    fn = shard_map(
        local, mesh=mesh,
        in_specs=(P(dpa, "model", None, None), P(dpa, None), P(dpa, None),
                  P(dpa, None, None)),
        out_specs=P(dpa, None, None), check_rep=False)
    return fn(expert_out, slot, keep, vals).astype(jnp.float32)


def _dispatch_shardmap(xg, slot, keep, *, nl, e, cap, d, k):
    """§Perf (B6): collective-free forward dispatch.

    Each (data, model) rank scatters its local tokens into the buffer
    slice of its *own* experts only; the result is born sharded
    (G→data, E→model) with zero forward communication.  The backward pass
    is a single psum of the (G, NL, D) token-gradient — the mirror of the
    B4 combine.
    """
    from jax._src.mesh import thread_resources
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    mesh = thread_resources.env.physical_mesh
    if mesh.empty or "model" not in mesh.axis_names or \
            e % mesh.shape["model"]:
        def build(xl, sl, kp):
            token_of = jnp.repeat(jnp.arange(nl), k)
            buf = jnp.zeros((e * cap + 1, d), xl.dtype)
            buf = buf.at[sl].set(xl[token_of], mode="drop")
            return buf[:-1].reshape(e, cap, d)
        return jax.vmap(build)(xg, slot, keep)
    msize = mesh.shape["model"]
    el = e // msize
    dp = ("pod", "data") if "pod" in mesh.axis_names else ("data",)
    dpa = dp if len(dp) > 1 else dp[0]

    def local(xl, sl, kp):
        midx = jax.lax.axis_index("model")
        base = midx * el * cap

        def one(x_g, s_g, k_g):
            loc = s_g - base
            ok = k_g & (loc >= 0) & (loc < el * cap)
            idx = jnp.where(ok, loc, el * cap)
            token_of = jnp.repeat(jnp.arange(nl), k)
            buf = jnp.zeros((el * cap + 1, d), x_g.dtype)
            buf = buf.at[idx].set(x_g[token_of], mode="drop")
            return buf[:-1].reshape(el, cap, d)

        return jax.vmap(one)(xl, sl, kp)

    fn = shard_map(local, mesh=mesh,
                   in_specs=(P(dpa, None, None), P(dpa, None),
                             P(dpa, None)),
                   out_specs=P(dpa, "model", None, None), check_rep=False)
    return fn(xg, slot, keep)


def _combine_gspmd(expert_out, slot, keep, vals, *, nl, e, cap, d, k):
    def combine_group(outs, slot_g, keep_g, vl):
        flat = outs.reshape(e * cap, d)
        contrib = flat[jnp.minimum(slot_g, e * cap - 1)] * \
            vl.reshape(-1)[:, None].astype(flat.dtype)
        token_of = jnp.repeat(jnp.arange(nl), k)
        idx = jnp.where(keep_g, token_of, nl)
        acc = jnp.zeros((nl + 1, d), jnp.float32)
        acc = acc.at[idx].add(contrib.astype(jnp.float32), mode="drop")
        return acc[:-1]
    return jax.vmap(combine_group)(expert_out, slot, keep, vals)


def moe_layer_dense_ref(p, x, cfg):
    """Oracle: run every expert on every token, combine by router weights.

    No capacity drops — used by tests to validate the dispatch path with a
    generous capacity factor (so nothing is dropped there either).
    """
    m = cfg.moe
    b, s, d = x.shape
    xf = x.reshape(-1, d)
    router_logits = xf.astype(jnp.float32) @ p["router"]
    probs = jax.nn.softmax(router_logits, axis=-1)
    top_vals, top_ids = _top_k(probs, m.top_k)
    top_vals = top_vals / jnp.clip(
        jnp.sum(top_vals, axis=-1, keepdims=True), 1e-9)
    h = jnp.einsum("nd,edf->enf", xf, p["w_up"])
    if "w_gate" in p:
        h = _act(jnp.einsum("nd,edf->enf", xf, p["w_gate"]), cfg.act) * h
    else:
        h = _act(h, cfg.act)
    every = jnp.einsum("enf,efd->end", h, p["w_down"])            # (E,N,D)
    weight = jnp.zeros((xf.shape[0], m.num_experts), jnp.float32)
    weight = weight.at[jnp.arange(xf.shape[0])[:, None], top_ids].set(
        top_vals)
    out = jnp.einsum("end,ne->nd", every.astype(jnp.float32), weight)
    out = out.astype(x.dtype)
    if "shared_up" in p:
        hs = xf @ p["shared_up"]
        if "shared_gate" in p:
            hs = _act(xf @ p["shared_gate"], cfg.act) * hs
        else:
            hs = _act(hs, cfg.act)
        out = out + hs @ p["shared_down"]
    return out.reshape(b, s, d)
