"""Unified decoder LM (+ optional encoder for Whisper).

The layer stack is a ``lax.scan`` over stacked *cycles* (the repeating
sublayer pattern from the config), so trace/compile time is O(cycle), not
O(depth) — essential for compiling the 72-layer Jamba config against a
512-device mesh in reasonable time.  Every sublayer is rematerialized
(``jax.checkpoint``), the standard activation policy at these scales.

Cache layout (decode): a pytree whose leaves carry a leading ``n_cycles``
dimension, scanned alongside the stacked parameters.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ATTN, LOCAL_ATTN, MAMBA, DENSE, MOE, NONE
from repro.models import attention as attn_mod
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.layers import (dense_init, embed_init, init_mlp, mlp,
                                 rms_norm, softcap, split_keys)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _init_sublayer(key, cfg, sub, *, cross: bool):
    ks = split_keys(key, 5)
    p = {"ln1": jnp.zeros((cfg.d_model,), jnp.float32)}
    if sub.mixer in (ATTN, LOCAL_ATTN):
        p["attn"] = attn_mod.init_attention(ks[0], cfg)
    elif sub.mixer == MAMBA:
        p["mamba"] = ssm_mod.init_mamba(ks[0], cfg)
    if cross:
        p["ln_x"] = jnp.zeros((cfg.d_model,), jnp.float32)
        p["cross"] = attn_mod.init_attention(ks[1], cfg)
    if sub.mlp != NONE:
        p["ln2"] = jnp.zeros((cfg.d_model,), jnp.float32)
        if sub.mlp == DENSE:
            p["mlp"] = init_mlp(ks[2], cfg.d_model, cfg.d_ff, cfg.mlp_gated,
                                cfg.jnp_dtype)
        else:
            p["moe"] = moe_mod.init_moe(ks[3], cfg)
    return p


def _init_cycle(key, cfg, *, cross: bool):
    ks = split_keys(key, len(cfg.layer_cycle))
    return {f"s{j}": _init_sublayer(ks[j], cfg, sub, cross=cross)
            for j, sub in enumerate(cfg.layer_cycle)}


def init_params(key, cfg):
    ks = split_keys(key, 6)
    dt = cfg.jnp_dtype
    params = {
        "embed": embed_init(ks[0], (cfg.vocab_size, cfg.d_model), dt),
        "final_norm": jnp.zeros((cfg.d_model,), jnp.float32),
        "stack": jax.vmap(
            lambda k: _init_cycle(k, cfg, cross=cfg.enc_dec))(
                jnp.stack(split_keys(ks[1], cfg.n_cycles))),
    }
    if not cfg.tie_embeddings:
        params["unembed"] = dense_init(
            ks[2], (cfg.d_model, cfg.vocab_size), dt, fan_in=cfg.d_model)
    if cfg.enc_dec:
        enc_cfg = cfg  # same width
        enc_cycle = lambda k: {  # encoder: full bidirectional attn + MLP
            "s0": {
                "ln1": jnp.zeros((cfg.d_model,), jnp.float32),
                "attn": attn_mod.init_attention(jax.random.fold_in(k, 1),
                                                enc_cfg),
                "ln2": jnp.zeros((cfg.d_model,), jnp.float32),
                "mlp": init_mlp(jax.random.fold_in(k, 2), cfg.d_model,
                                cfg.d_ff, cfg.mlp_gated, dt),
            }}
        params["enc_stack"] = jax.vmap(enc_cycle)(
            jnp.stack(split_keys(ks[3], cfg.n_enc_layers)))
        params["enc_norm"] = jnp.zeros((cfg.d_model,), jnp.float32)
    return params


# ---------------------------------------------------------------------------
# cache
# ---------------------------------------------------------------------------

def init_cache(cfg, batch: int, max_len: int, enc_len: int = 0):
    """Zero-initialized decode cache (leaves lead with n_cycles)."""
    dt = cfg.jnp_dtype
    per_cycle = {}
    for j, sub in enumerate(cfg.layer_cycle):
        if sub.mixer in (ATTN, LOCAL_ATTN):
            kv = (batch, max_len, cfg.n_kv_heads, cfg.head_dim)
            entry = {"k": jnp.zeros(kv, dt), "v": jnp.zeros(kv, dt)}
        elif sub.mixer == MAMBA:
            entry = ssm_mod.init_mamba_cache(cfg, batch)
        else:
            entry = {}
        if cfg.enc_dec:
            ckv = (batch, enc_len, cfg.n_kv_heads, cfg.head_dim)
            entry["ck"] = jnp.zeros(ckv, dt)
            entry["cv"] = jnp.zeros(ckv, dt)
        per_cycle[f"s{j}"] = entry
    return jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (cfg.n_cycles,) + x.shape),
        per_cycle)


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------

def _run_sublayer(p, x, cfg, sub, *, mode, cache, cache_pos, enc_out):
    """mode: 'train' | 'prefill' | 'decode'."""
    aux = jnp.zeros((), jnp.float32)
    new_cache = dict(cache) if cache is not None else None
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    window = cfg.sliding_window if sub.mixer == LOCAL_ATTN else None

    if sub.mixer in (ATTN, LOCAL_ATTN):
        if mode == "train":
            y, _ = attn_mod.attention_block(p["attn"], h, cfg, causal=True,
                                            window=window)
        elif mode == "prefill":
            y, kv = attn_mod.attention_block(p["attn"], h, cfg, causal=True,
                                             window=window, return_kv=True)
            new_cache["k"], new_cache["v"] = kv
        else:  # decode
            y, kv = attn_mod.attention_block(
                p["attn"], h, cfg, window=window,
                cache_kv=(cache["k"], cache["v"]), cache_pos=cache_pos)
            new_cache["k"], new_cache["v"] = kv
        x = x + y
    elif sub.mixer == MAMBA:
        mcache = None
        if mode != "train":
            mcache = ({k: cache[k] for k in
                       ("conv_x", "conv_B", "conv_C", "ssm")}
                      if mode == "decode" else ssm_mod.init_mamba_cache(
                          cfg, x.shape[0]))
        y, mc = ssm_mod.mamba_block(p["mamba"], h, cfg, cache=mcache)
        if mc is not None:
            new_cache.update(mc)
        x = x + y

    if cfg.enc_dec and "cross" in p:
        h = rms_norm(x, p["ln_x"], cfg.norm_eps)
        if mode == "decode":
            ckv = (cache["ck"], cache["cv"])
        else:
            ckv = attn_mod.init_cross_kv(p["cross"], enc_out, cfg)
            if mode == "prefill":
                new_cache["ck"], new_cache["cv"] = ckv
        y, _ = attn_mod.attention_block(p["cross"], h, cfg, cross_kv=ckv)
        x = x + y

    if cfg.remat_policy == "save_mixer_out":
        from jax.ad_checkpoint import checkpoint_name
        x = checkpoint_name(x, "mixer_out")

    if sub.mlp != NONE:
        h = rms_norm(x, p["ln2"], cfg.norm_eps)
        if sub.mlp == DENSE:
            y = mlp(p["mlp"], h, cfg.act)
        else:
            y, aux = moe_mod.moe_layer(p["moe"], h, cfg)
        x = x + y
        if cfg.remat_policy == "save_mixer_out":
            from jax.ad_checkpoint import checkpoint_name
            x = checkpoint_name(x, "mlp_out")
    return x, new_cache, aux


def _run_stack(params, x, cfg, *, mode, cache=None, cache_pos=None,
               enc_out=None):
    """Scan the cycle stack.  Returns (x, new_cache, aux_sum)."""

    def cycle_body(carry, scanned):
        xc, aux_acc = carry
        cyc_params, cyc_cache = scanned
        new_cyc_cache = {} if cyc_cache is not None else None
        policy = None
        if cfg.remat_policy == "save_mixer_out":
            policy = jax.checkpoint_policies.save_only_these_names(
                "mixer_out", "mlp_out")
        for j, sub in enumerate(cfg.layer_cycle):
            sub_cache = None if cyc_cache is None else cyc_cache[f"s{j}"]
            fn = functools.partial(_run_sublayer, cfg=cfg, sub=sub,
                                   mode=mode, cache_pos=cache_pos,
                                   enc_out=enc_out)
            fn = jax.checkpoint(
                lambda p_, x_, c_, fn=fn: fn(p_, x_, cache=c_),
                policy=policy)
            xc, nc, aux = fn(cyc_params[f"s{j}"], xc, sub_cache)
            if new_cyc_cache is not None:
                new_cyc_cache[f"s{j}"] = nc
        return (xc, aux_acc + aux), new_cyc_cache

    if cache is None:
        (x, aux), _ = jax.lax.scan(
            lambda c, p: cycle_body(c, (p, None)),
            (x, jnp.zeros((), jnp.float32)), params["stack"])
        return x, None, aux
    (x, aux), new_cache = jax.lax.scan(
        cycle_body, (x, jnp.zeros((), jnp.float32)),
        (params["stack"], cache))
    return x, new_cache, aux


def _embed(params, tokens, cfg):
    x = jnp.take(params["embed"], tokens, axis=0)
    if cfg.scale_embeddings:
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    return x


def _logits(params, x, cfg):
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    if cfg.tie_embeddings:
        logits = jnp.einsum("bsd,vd->bsv", x, params["embed"])
    else:
        logits = x @ params["unembed"]
    return softcap(logits.astype(jnp.float32), cfg.final_softcap)


def _encode(params, frames, cfg):
    """Whisper encoder over stub frame embeddings (B, F, D)."""
    pos = jnp.arange(frames.shape[1])
    d = cfg.d_model
    inv = 1.0 / (10000.0 ** (jnp.arange(0, d, 2, jnp.float32) / d))
    ang = pos[:, None].astype(jnp.float32) * inv[None, :]
    pe = jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)
    x = frames + pe[None].astype(frames.dtype)

    def body(xc, cyc):
        p = cyc["s0"]
        h = rms_norm(xc, p["ln1"], cfg.norm_eps)
        y, _ = attn_mod.attention_block(p["attn"], h, cfg, causal=False)
        xc = xc + y
        h = rms_norm(xc, p["ln2"], cfg.norm_eps)
        xc = xc + mlp(p["mlp"], h, cfg.act)
        return xc, None

    x, _ = jax.lax.scan(body, x, params["enc_stack"])
    return rms_norm(x, params["enc_norm"], cfg.norm_eps)


def _prepend_frontend(x, batch_extras, cfg):
    """VLM: prepend stub patch embeddings to the token stream."""
    if cfg.frontend == "vision" and "patches" in batch_extras:
        x = jnp.concatenate(
            [batch_extras["patches"].astype(x.dtype), x], axis=1)
    return x


# ---------------------------------------------------------------------------
# public entry points
# ---------------------------------------------------------------------------

def forward_train(params, batch, cfg):
    """batch: tokens (B,S), labels (B,S), [patches (B,P,D) | frames (B,F,D)]
    Returns (loss, metrics dict)."""
    tokens, labels = batch["tokens"], batch["labels"]
    x = _embed(params, tokens, cfg)
    n_front = 0
    enc_out = None
    if cfg.frontend == "vision":
        n_front = batch["patches"].shape[1]
        x = _prepend_frontend(x, batch, cfg)
    if cfg.enc_dec:
        enc_out = _encode(params, batch["frames"], cfg)
    x, _, aux = _run_stack(params, x, cfg, mode="train", enc_out=enc_out)
    if n_front:
        x = x[:, n_front:]
    logits = _logits(params, x, cfg)

    valid = (labels >= 0)
    labels_c = jnp.clip(labels, 0)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels_c[..., None],
                               axis=-1)[..., 0]
    nll = (logz - gold) * valid
    denom = jnp.maximum(jnp.sum(valid), 1)
    loss = jnp.sum(nll) / denom + aux
    metrics = {"nll": jnp.sum(nll) / denom, "aux": aux,
               "tokens": jnp.sum(valid)}
    return loss, metrics


def prefill(params, batch, cfg):
    """Full-sequence prefill.  Returns (last-position logits (B,V), cache)."""
    tokens = batch["tokens"]
    x = _embed(params, tokens, cfg)
    n_front = 0
    enc_out = None
    if cfg.frontend == "vision":
        n_front = batch["patches"].shape[1]
        x = _prepend_frontend(x, batch, cfg)
    if cfg.enc_dec:
        enc_out = _encode(params, batch["frames"], cfg)
    cache = init_cache(cfg, tokens.shape[0], x.shape[1],
                       enc_len=0 if enc_out is None else enc_out.shape[1])
    x, cache, _ = _run_stack(params, x, cfg, mode="prefill", cache=cache,
                             enc_out=enc_out)
    logits = _logits(params, x[:, -1:], cfg)
    return logits[:, 0], cache


def decode_step(params, cache, token, pos, cfg):
    """One decode step.  token: (B,1) int32; pos: scalar int32 (write slot).
    Returns (logits (B,V), new_cache)."""
    x = _embed(params, token, cfg)
    x, cache, _ = _run_stack(params, x, cfg, mode="decode", cache=cache,
                             cache_pos=pos)
    logits = _logits(params, x, cfg)
    return logits[:, 0], cache
