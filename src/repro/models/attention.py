"""Attention: GQA with optional sliding window, logit softcaps and KV cache.

Full-sequence attention is computed in query chunks (``lax.scan`` over chunk
index with a rematerialized body) so the live logits tensor is
O(B·H·chunk·T) instead of O(B·H·S·T) — the difference between fitting and
not fitting the 32k-prefill cells in HBM.  Decode takes the direct path
(a single query position).
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.layers import apply_rope, dense_init, softcap, split_keys

NEG_INF = -2.3819763e38  # most-negative bf16-representable


def init_attention(key, cfg):
    d, h, kh, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = split_keys(key, 4)
    return {
        "wq": dense_init(ks[0], (d, h, hd), cfg.jnp_dtype, fan_in=d),
        "wk": dense_init(ks[1], (d, kh, hd), cfg.jnp_dtype, fan_in=d),
        "wv": dense_init(ks[2], (d, kh, hd), cfg.jnp_dtype, fan_in=d),
        "wo": dense_init(ks[3], (h, hd, d), cfg.jnp_dtype, fan_in=h * hd),
    }


def _attend(qc, k, v, row_pos, col_pos, *, causal, window, valid_len, cap,
            scale, logits_dtype=jnp.float32):
    """qc: (B,C,KH,G,Dh)  k,v: (B,T,KH,Dh)  row_pos: (C,)  col_pos: (T,)."""
    logits = jnp.einsum("bckgd,btkd->bckgt", qc.astype(logits_dtype),
                        k.astype(logits_dtype)).astype(jnp.float32) * scale
    logits = softcap(logits, cap)
    mask = jnp.ones((row_pos.shape[0], col_pos.shape[0]), dtype=bool)
    if causal:
        mask &= col_pos[None, :] <= row_pos[:, None]
    if window is not None:
        mask &= col_pos[None, :] > (row_pos[:, None] - window)
    if valid_len is not None:
        mask &= (col_pos < valid_len)[None, :]
    logits = jnp.where(mask[None, :, None, None, :], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bckgt,btkd->bckgd", probs.astype(logits_dtype),
                     v.astype(logits_dtype))
    return out.astype(v.dtype)


def _maybe_batch_shard(x, enable: bool):
    """§Perf: when q/kv heads don't divide the TP axis the attention math
    is replicated across `model`; resharding the *batch* over
    ('data','model') instead parallelizes it 16× at the cost of two
    boundary reshards (see EXPERIMENTS.md §Perf)."""
    if not enable:
        return x
    from jax.sharding import PartitionSpec as P
    spec = P(("data", "model"), *([None] * (x.ndim - 1)))
    try:
        return jax.lax.with_sharding_constraint(x, spec)
    except Exception:
        return x   # no mesh context (single-device tests)


def multi_head_attention(q, k, v, *, causal: bool,
                         window: Optional[int] = None,
                         cap: Optional[float] = None,
                         q_offset=0,
                         kv_valid_len=None,
                         q_chunk: int = 1024,
                         batch_shard: bool = False,
                         logits_bf16: bool = False):
    """q: (B,S,H,Dh); k,v: (B,T,KH,Dh) -> (B,S,H,Dh).

    ``q_offset``: absolute position of q[0] (decode against a cache).
    ``kv_valid_len``: scalar — mask cache positions >= it (decode).
    """
    b, s, h, hd = q.shape
    t, kh = k.shape[1], k.shape[2]
    g = h // kh
    scale = 1.0 / (hd ** 0.5)
    ldt = jnp.bfloat16 if logits_bf16 else jnp.float32
    q = _maybe_batch_shard(q, batch_shard)
    k = _maybe_batch_shard(k, batch_shard)
    v = _maybe_batch_shard(v, batch_shard)
    qg = q.reshape(b, s, kh, g, hd)
    col_pos = jnp.arange(t)

    if s == 1:  # decode: single query position, no chunking
        row_pos = jnp.asarray(q_offset, jnp.int32).reshape(1)
        out = _attend(qg, k, v, row_pos, col_pos, causal=causal,
                      window=window, valid_len=kv_valid_len, cap=cap,
                      scale=scale, logits_dtype=ldt)
        return _maybe_batch_shard(out.reshape(b, s, h, hd), batch_shard)

    n_chunks = max(1, -(-s // q_chunk))
    while s % n_chunks:
        n_chunks += 1
    c = s // n_chunks
    qc = jnp.moveaxis(qg.reshape(b, n_chunks, c, kh, g, hd), 1, 0)

    @jax.checkpoint
    def body(_, inputs):
        qi, idx = inputs
        row_pos = q_offset + idx * c + jnp.arange(c)
        out = _attend(qi, k, v, row_pos, col_pos, causal=causal,
                      window=window, valid_len=kv_valid_len, cap=cap,
                      scale=scale, logits_dtype=ldt)
        return None, out

    _, out = jax.lax.scan(body, None, (qc, jnp.arange(n_chunks)))
    out = jnp.moveaxis(out, 0, 1).reshape(b, s, h, hd)
    return _maybe_batch_shard(out, batch_shard)


def attention_block(p, x, cfg, *, causal=True, window=None,
                    positions=None, cache_kv=None, cache_pos=None,
                    cross_kv=None, return_kv=False):
    """One attention sublayer (projections + MHA), cache-aware.

    Modes:
      * full-sequence (train / prefill): ``cache_kv=None``; pass
        ``return_kv=True`` to hand (k, v) to a new cache.
      * decode: x is (B,1,D); ``cache_kv=(k_cache, v_cache)`` with absolute
        write position ``cache_pos``; attends to cache[0:cache_pos+1].
      * cross attention: ``cross_kv=(k, v)`` precomputed from the encoder.
    """
    b, s, _ = x.shape
    if positions is None:
        start = 0 if cache_pos is None else cache_pos
        positions = (start + jnp.arange(s))[None, :]

    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    if cross_kv is not None:
        k, v = cross_kv
        out = multi_head_attention(q, k, v, causal=False,
                                   cap=cfg.attn_softcap,
                                   batch_shard=cfg.attn_batch_shard,
                                   logits_bf16=cfg.attn_logits_bf16)
        new_kv = None
    else:
        k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
        vv = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
        if cache_kv is not None:
            k_cache, v_cache = cache_kv
            k_cache = jax.lax.dynamic_update_slice(
                k_cache, k.astype(k_cache.dtype), (0, cache_pos, 0, 0))
            v_cache = jax.lax.dynamic_update_slice(
                v_cache, vv.astype(v_cache.dtype), (0, cache_pos, 0, 0))
            out = multi_head_attention(
                q, k_cache, v_cache, causal=False, window=window,
                cap=cfg.attn_softcap, q_offset=cache_pos,
                kv_valid_len=cache_pos + s,
                batch_shard=cfg.attn_batch_shard,
                logits_bf16=cfg.attn_logits_bf16)
            new_kv = (k_cache, v_cache)
        else:
            out = multi_head_attention(q, k, vv, causal=causal,
                                       window=window, cap=cfg.attn_softcap,
                                       batch_shard=cfg.attn_batch_shard,
                                       logits_bf16=cfg.attn_logits_bf16)
            new_kv = (k, vv) if return_kv else None
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    return y, new_kv


def init_cross_kv(p, enc_out, cfg):
    """Precompute cross-attention K/V from encoder output (no RoPE)."""
    k = jnp.einsum("bsd,dhk->bshk", enc_out, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", enc_out, p["wv"])
    return k, v
