"""Mamba-2 (SSD — state-space duality) block, TPU-adapted.

The chunked SSD algorithm recasts the selective-scan recurrence as dense
einsums over fixed-size chunks (MXU-friendly) plus one short sequential
scan over per-chunk states — the TPU-native form of the paper's
"quadratic-mode inside chunks, linear-mode across chunks" duality:

  intra-chunk   Y_intra = (C Bᵀ ∘ L) X           (matmuls on the MXU)
  chunk states  S_c     = (B ∘ decay_to_end)ᵀ X
  recurrence    h_c     = exp(sum_c) h_{c-1} + S_c   (lax.scan, n_chunks steps)
  inter-chunk   Y_inter = (C h_{c-1}) ∘ decay_from_start

The depthwise causal conv1d in front of the SSM is the 1-D member of the
paper's convolution-block library (kernels/conv1d.py holds the Pallas
TPU kernel; the jnp path here is numerically identical and is what the
host-CPU dry-run lowers).

Decode carries (conv_state, ssm_state) and costs O(1) per token.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init, rms_norm, split_keys


def ssm_dims(cfg):
    s = cfg.ssm
    inner = s.expand * cfg.d_model
    n_heads = inner // s.head_dim
    return inner, n_heads


def init_mamba(key, cfg):
    s = cfg.ssm
    d = cfg.d_model
    inner, nh = ssm_dims(cfg)
    gn = s.n_groups * s.state_dim
    dt = cfg.jnp_dtype
    ks = split_keys(key, 9)
    # A in (-dt_max_decay, 0): store log(-A) per head
    a_log = jnp.log(jnp.linspace(1.0, 16.0, nh)).astype(jnp.float32)
    return {
        "w_z": dense_init(ks[0], (d, inner), dt),
        "w_x": dense_init(ks[1], (d, inner), dt),
        "w_B": dense_init(ks[2], (d, gn), dt),
        "w_C": dense_init(ks[3], (d, gn), dt),
        "w_dt": dense_init(ks[4], (d, nh), dt),
        "conv_x": dense_init(ks[5], (s.conv_kernel, inner), dt,
                             fan_in=s.conv_kernel),
        "conv_B": dense_init(ks[6], (s.conv_kernel, gn), dt,
                             fan_in=s.conv_kernel),
        "conv_C": dense_init(ks[7], (s.conv_kernel, gn), dt,
                             fan_in=s.conv_kernel),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "a_log": a_log,
        "d_skip": jnp.ones((nh,), jnp.float32),
        "norm": jnp.zeros((inner,), jnp.float32),
        "w_out": dense_init(ks[8], (inner, d), dt, fan_in=inner),
    }


def causal_conv1d(x, w, conv_state=None):
    """Depthwise causal conv.  x: (B,S,C); w: (K,C).

    ``conv_state``: (B,K-1,C) trailing context (decode / chunked prefill);
    returns (y, new_state).  Implemented as a sum of K shifted slices —
    bit-identical to kernels/conv1d ref (the Pallas kernel is the TPU
    deployment artifact; see kernels/conv1d.py).
    """
    k = w.shape[0]
    b, s, c = x.shape
    if conv_state is None:
        conv_state = jnp.zeros((b, k - 1, c), x.dtype)
    xp = jnp.concatenate([conv_state, x], axis=1)          # (B, S+K-1, C)
    y = sum(xp[:, i:i + s, :] * w[i][None, None, :] for i in range(k))
    new_state = xp[:, s:, :] if k > 1 else conv_state
    return jax.nn.silu(y), new_state


def _ssd_chunked(x, dt, a, B, C, chunk):
    """SSD over a full sequence.

    x: (B,S,NH,P)  dt: (B,S,NH)  a: (NH,) negative  B,C: (B,S,G,N)
    Returns (y (B,S,NH,P), final_state (B,NH,N,P)).
    """
    b, s, nh, p = x.shape
    g, n = B.shape[2], B.shape[3]
    rep = nh // g
    s_orig = s
    if s % chunk:
        # zero-pad to a chunk multiple: padded steps have dt=0 so they leave
        # the state untouched and contribute nothing (outputs sliced off).
        pad = chunk - s % chunk
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0), (0, 0)))
        s = s + pad
    nc = s // chunk

    xr = x.reshape(b, nc, chunk, nh, p)
    dtr = dt.reshape(b, nc, chunk, nh)
    Br = B.reshape(b, nc, chunk, g, n)
    Cr = C.reshape(b, nc, chunk, g, n)

    causal = jnp.tril(jnp.ones((chunk, chunk), bool))

    # One scan over chunks does everything: intra-chunk quadratic form,
    # chunk-state construction, and the inter-chunk recurrence on the carry.
    # Live memory per step is O(Q²·NH), independent of sequence length.
    @jax.checkpoint
    def step(h, inp):
        xc, dtc, Bc, Cc = inp            # (b,Q,NH,P) (b,Q,NH) (b,Q,NH,N) ×2
        da = dtc * a[None, None, :]                        # (b,Q,NH) ≤ 0
        cum = jnp.cumsum(da, axis=1)
        total = cum[:, -1, :]                              # (b,NH)
        xdt = (xc * dtc[..., None]).astype(jnp.float32)    # (b,Q,NH,P)

        # expand groups to heads lazily, per chunk, in f32 — materializing
        # the full-sequence f32 head-expanded B/C costs rep× redundant HBM
        # traffic (§Perf C3)
        Bc = jnp.repeat(Bc, rep, axis=2).astype(jnp.float32)  # (b,Q,NH,N)
        Cc = jnp.repeat(Cc, rep, axis=2).astype(jnp.float32)

        # intra-chunk:  L[q,t] = exp(cum_q - cum_t) for q >= t
        # (mask BEFORE exp: masked lanes have rel > 0 whose exp overflows and
        #  would leak NaN into the backward pass through jnp.where)
        rel = cum[:, :, None, :] - cum[:, None, :, :]      # (b,Q,Q,NH)
        rel = jnp.where(causal[None, :, :, None], rel, -jnp.inf)
        L = jnp.exp(rel)
        scores = jnp.einsum("bqhn,bthn->bqth", Cc, Bc)     # (b,Q,Q,NH)
        y_intra = jnp.einsum("bqth,bthp->bqhp", scores * L, xdt)

        # inter-chunk: contribution of the incoming state
        decay_from_start = jnp.exp(cum)                    # (b,Q,NH)
        y_inter = jnp.einsum("bqhn,bhnp->bqhp",
                             Cc * decay_from_start[..., None], h)

        # update carry: state at end of this chunk
        decay_to_end = jnp.exp(total[:, None, :] - cum)    # (b,Q,NH)
        state = jnp.einsum("bthn,bthp->bhnp",
                           Bc * decay_to_end[..., None], xdt)
        h_next = h * jnp.exp(total)[:, :, None, None] + state
        return h_next, y_intra + y_inter

    h0 = jnp.zeros((b, nh, n, p), jnp.float32)
    xs = (jnp.moveaxis(xr, 1, 0), jnp.moveaxis(dtr, 1, 0),
          jnp.moveaxis(Br, 1, 0), jnp.moveaxis(Cr, 1, 0))
    final, y = jax.lax.scan(step, h0, xs)
    y = jnp.moveaxis(y, 0, 1).reshape(b, s, nh, p)[:, :s_orig]
    return y.astype(x.dtype), final


def _ssd_decode(x, dt, a, B, C, h):
    """One-token SSD step.  x: (B,1,NH,P) dt: (B,1,NH) B,C: (B,1,G,N)
    h: (B,NH,N,P) -> (y (B,1,NH,P), h')."""
    b, _, nh, p = x.shape
    g = B.shape[2]
    rep = nh // g
    Bh = jnp.repeat(B[:, 0], rep, axis=1).astype(jnp.float32)  # (B,NH,N)
    Ch = jnp.repeat(C[:, 0], rep, axis=1).astype(jnp.float32)
    dt0 = dt[:, 0].astype(jnp.float32)                         # (B,NH)
    da = jnp.exp(dt0 * a[None, :])                             # (B,NH)
    xdt = (x[:, 0] * dt0[..., None]).astype(jnp.float32)       # (B,NH,P)
    h = h * da[:, :, None, None] + \
        jnp.einsum("bhn,bhp->bhnp", Bh, xdt)
    y = jnp.einsum("bhn,bhnp->bhp", Ch, h)
    return y[:, None].astype(x.dtype), h


def mamba_block(p, x, cfg, *, cache=None):
    """Full Mamba-2 block.  x: (B,S,D).

    cache: None (train) or dict(conv_x, conv_B, conv_C, ssm, pos-free) for
    decode/prefill carry.  Returns (y, new_cache_or_None).
    """
    s_cfg = cfg.ssm
    b, s, _ = x.shape
    inner, nh = ssm_dims(cfg)
    g, n = s_cfg.n_groups, s_cfg.state_dim

    z = x @ p["w_z"]                                       # (B,S,inner)
    xs = x @ p["w_x"]
    Bx = x @ p["w_B"]
    Cx = x @ p["w_C"]
    dt = x.astype(jnp.float32) @ p["w_dt"].astype(jnp.float32)

    cs_x = cs_B = cs_C = None
    if cache is not None:
        cs_x, cs_B, cs_C = cache["conv_x"], cache["conv_B"], cache["conv_C"]
    xs, ns_x = causal_conv1d(xs, p["conv_x"], cs_x)
    Bx, ns_B = causal_conv1d(Bx, p["conv_B"], cs_B)
    Cx, ns_C = causal_conv1d(Cx, p["conv_C"], cs_C)

    dt = jax.nn.softplus(dt + p["dt_bias"][None, None, :])  # (B,S,NH)
    a = -jnp.exp(p["a_log"])                                # (NH,)
    xh = xs.reshape(b, s, nh, s_cfg.head_dim)
    Bh = Bx.reshape(b, s, g, n)
    Ch = Cx.reshape(b, s, g, n)

    if cache is None or s > 1:
        h0 = None if cache is None else cache["ssm"]
        if h0 is not None:
            # chunked prefill continuation not needed in this framework:
            # prefill always starts from an empty state.
            pass
        y, h_final = _ssd_chunked(xh, dt, a, Bh, Ch,
                                  min(s_cfg.chunk_size, s))
    else:
        y, h_final = _ssd_decode(xh, dt, a, Bh, Ch, cache["ssm"])

    y = y + xh.astype(jnp.float32) * p["d_skip"][None, None, :, None]
    y = y.reshape(b, s, inner).astype(x.dtype)
    y = y * jax.nn.silu(z)
    y = rms_norm(y, p["norm"], cfg.norm_eps)
    out = y @ p["w_out"]

    new_cache = None
    if cache is not None:
        new_cache = {"conv_x": ns_x, "conv_B": ns_B, "conv_C": ns_C,
                     "ssm": h_final}
    return out, new_cache


def init_mamba_cache(cfg, batch):
    s = cfg.ssm
    inner, nh = ssm_dims(cfg)
    gn = s.n_groups * s.state_dim
    k = s.conv_kernel
    dt = cfg.jnp_dtype
    return {
        "conv_x": jnp.zeros((batch, k - 1, inner), dt),
        "conv_B": jnp.zeros((batch, k - 1, gn), dt),
        "conv_C": jnp.zeros((batch, k - 1, gn), dt),
        "ssm": jnp.zeros((batch, nh, s.state_dim, s.head_dim), jnp.float32),
    }
