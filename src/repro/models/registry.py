"""Model facade: one object per architecture, plus dry-run input specs.

``input_specs`` returns ``jax.ShapeDtypeStruct`` stand-ins for every model
input of a given (arch × shape) cell — weak-type-correct, shardable, and
never allocated.  The modality frontends are stubs per the assignment:
``patches`` / ``frames`` arrive as precomputed embeddings.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import transformer as tf


@dataclass
class Model:
    cfg: ModelConfig

    # ---- param / cache construction ----------------------------------
    def init(self, key):
        return tf.init_params(key, self.cfg)

    def init_abstract(self, key=None):
        """Shape-only params (no allocation) for dry-run lowering."""
        key = key if key is not None else jax.random.PRNGKey(0)
        return jax.eval_shape(lambda k: tf.init_params(k, self.cfg), key)

    def init_cache(self, batch: int, max_len: int):
        enc_len = self.cfg.frontend_len if self.cfg.enc_dec else 0
        return tf.init_cache(self.cfg, batch, max_len, enc_len=enc_len)

    def cache_abstract(self, batch: int, max_len: int):
        return jax.eval_shape(lambda: self.init_cache(batch, max_len))

    # ---- forwards ------------------------------------------------------
    def forward_train(self, params, batch):
        return tf.forward_train(params, batch, self.cfg)

    def prefill(self, params, batch):
        return tf.prefill(params, batch, self.cfg)

    def decode_step(self, params, cache, token, pos):
        return tf.decode_step(params, cache, token, pos, self.cfg)

    # ---- dry-run input specs -------------------------------------------
    def input_specs(self, shape: ShapeConfig) -> Dict[str, Any]:
        cfg = self.cfg
        b, s = shape.global_batch, shape.seq_len
        i32 = jnp.int32
        dt = cfg.jnp_dtype
        sds = jax.ShapeDtypeStruct

        def token_batch(n_tok):
            batch = {"tokens": sds((b, n_tok), i32)}
            if cfg.frontend == "vision":
                batch["patches"] = sds((b, cfg.frontend_len, cfg.d_model), dt)
            if cfg.enc_dec:
                batch["frames"] = sds((b, cfg.frontend_len, cfg.d_model), dt)
            return batch

        if shape.kind == "train":
            n_tok = s - (cfg.frontend_len if cfg.frontend == "vision" else 0)
            batch = token_batch(n_tok)
            batch["labels"] = sds((b, n_tok), i32)
            return {"batch": batch}
        if shape.kind == "prefill":
            n_tok = s - (cfg.frontend_len if cfg.frontend == "vision" else 0)
            return {"batch": token_batch(n_tok)}
        # decode: one new token against a cache of length s
        cache = jax.tree.map(
            lambda x: sds(x.shape, x.dtype), self.cache_abstract(b, s))
        return {"cache": cache,
                "token": sds((b, 1), i32),
                "pos": sds((), i32)}


def build_model(cfg: ModelConfig) -> Model:
    return Model(cfg)
