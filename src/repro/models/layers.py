"""Shared layers: norms, rotary embeddings, MLPs, initializers.

All modules are pure functions over explicit param pytrees so that
``jax.eval_shape`` can trace full-size initializers without allocating
(the dry-run never materializes the 398B configs).
"""

from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------

def dense_init(key, shape, dtype, fan_in: Optional[int] = None):
    fan_in = fan_in if fan_in is not None else shape[0]
    scale = 1.0 / math.sqrt(max(1, fan_in))
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def embed_init(key, shape, dtype):
    return (jax.random.normal(key, shape, jnp.float32) * 0.02).astype(dtype)


def split_keys(key, n):
    return list(jax.random.split(key, n))


# ---------------------------------------------------------------------------
# normalization
# ---------------------------------------------------------------------------

def rms_norm(x, weight, eps: float = 1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * (1.0 + weight.astype(jnp.float32))).astype(dt)


# ---------------------------------------------------------------------------
# rotary position embeddings
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x, positions, theta: float):
    """x: (..., S, H, D); positions: broadcastable to (..., S)."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                       # (D/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, D/2)
    cos = jnp.cos(angles)[..., None, :]                # (..., S, 1, D/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLP (gated SwiGLU / GeGLU, or plain 2-matrix)
# ---------------------------------------------------------------------------

def init_mlp(key, d_model: int, d_ff: int, gated: bool, dtype):
    ks = split_keys(key, 3)
    p = {"w_up": dense_init(ks[0], (d_model, d_ff), dtype),
         "w_down": dense_init(ks[1], (d_ff, d_model), dtype)}
    if gated:
        p["w_gate"] = dense_init(ks[2], (d_model, d_ff), dtype)
    return p


def _act(x, act: str):
    return jax.nn.silu(x) if act == "silu" else jax.nn.gelu(x)


def mlp(p, x, act: str):
    h = x @ p["w_up"]
    if "w_gate" in p:
        h = _act(x @ p["w_gate"], act) * h
    else:
        h = _act(h, act)
    return h @ p["w_down"]


# ---------------------------------------------------------------------------
# logit softcap (gemma-2)
# ---------------------------------------------------------------------------

def softcap(x, cap: Optional[float]):
    if cap is None:
        return x
    return (cap * jnp.tanh(x.astype(jnp.float32) / cap)).astype(x.dtype)
