from repro.data.pipeline import DataConfig, make_pipeline

__all__ = ["DataConfig", "make_pipeline"]
