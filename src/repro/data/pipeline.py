"""Deterministic, restart-safe token pipeline.

Sources:
  * ``synthetic`` — Zipf-distributed tokens with injected n-gram structure
    (so a real model shows a falling loss curve), seeded by (seed, step) —
    any worker can regenerate any step, which is what makes restart and
    elastic rescaling deterministic with NO data-state checkpointing: the
    loader is a pure function of the step counter.
  * ``memmap``   — flat uint32 token file (numpy memmap), sharded by step
    offset; the same pure-function-of-step contract.

Packing: fixed-length windows with next-token labels; document boundaries
carry label -100 (masked out in the loss).  The host loader prefetches one
batch ahead of the device step (double buffering).
"""

from __future__ import annotations

import threading
from dataclasses import dataclass
from queue import Queue
from typing import Iterator, Optional

import numpy as np


@dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    source: str = "synthetic"        # synthetic | memmap
    path: Optional[str] = None       # for memmap
    seed: int = 0
    mean_doc_len: int = 512


def _synthetic_batch(cfg: DataConfig, step: int) -> np.ndarray:
    rng = np.random.default_rng((cfg.seed, step))
    b, s = cfg.global_batch, cfg.seq_len
    # Zipf body (clipped) + deterministic bigram structure
    toks = rng.zipf(1.3, size=(b, s + 1)).astype(np.int64)
    toks = np.clip(toks, 1, cfg.vocab_size - 1)
    # inject learnable structure: token t at even idx forces (t*7)%V next
    even = toks[:, 0:s:2]
    toks[:, 1:s + 1:2] = (even * 7 + 13) % cfg.vocab_size
    return toks.astype(np.int32)


def _memmap_batch(cfg: DataConfig, step: int, data: np.ndarray) -> np.ndarray:
    b, s = cfg.global_batch, cfg.seq_len
    need = b * (s + 1)
    start = (step * need) % max(len(data) - need, 1)
    return np.array(data[start:start + need]).reshape(b, s + 1) \
        .astype(np.int32)


def batch_at(cfg: DataConfig, step: int, data=None) -> dict:
    toks = _synthetic_batch(cfg, step) if cfg.source == "synthetic" \
        else _memmap_batch(cfg, step, data)
    rng = np.random.default_rng((cfg.seed, step, 1))
    batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:].copy()}
    # document boundaries: mask a few label positions
    n_bound = max(1, cfg.seq_len // cfg.mean_doc_len)
    cols = rng.integers(0, cfg.seq_len, size=(cfg.global_batch, n_bound))
    rows = np.arange(cfg.global_batch)[:, None]
    batch["labels"][rows, cols] = -100
    return batch


def make_pipeline(cfg: DataConfig, start_step: int = 0,
                  prefetch: int = 2) -> Iterator[dict]:
    """Background-prefetching iterator, resumable at any step."""
    data = None
    if cfg.source == "memmap":
        data = np.memmap(cfg.path, dtype=np.uint32, mode="r")
    q: Queue = Queue(maxsize=prefetch)
    stop = object()

    def worker():
        step = start_step
        while True:
            q.put((step, batch_at(cfg, step, data)))
            step += 1

    t = threading.Thread(target=worker, daemon=True)
    t.start()

    def gen():
        while True:
            _, b = q.get()
            yield b

    return gen()
