"""Block registry: name → ``ConvBlock`` instance.

The registry is the single source of truth for which convolution blocks
exist — synthesis sweeps, resource-model fitting, allocation and the CNN
all iterate it instead of hard-coding block names.  Adding a fifth block
is one ``register_block`` call (see docs/blocks.md for a worked
example).
"""

from __future__ import annotations

from typing import Dict, Tuple, Union

from repro.blocks.base import ConvBlock

_REGISTRY: Dict[str, ConvBlock] = {}

BlockLike = Union[str, ConvBlock]


def register_block(block: ConvBlock, *, overwrite: bool = False) -> ConvBlock:
    """Register ``block`` under ``block.name``; returns it for chaining."""
    if block.name in _REGISTRY and not overwrite:
        raise ValueError(f"block {block.name!r} already registered "
                         f"(pass overwrite=True to replace)")
    _REGISTRY[block.name] = block
    return block


def unregister_block(name: str) -> None:
    """Remove a block (mainly for tests tearing down custom blocks)."""
    _REGISTRY.pop(name, None)


def get_block(block: BlockLike) -> ConvBlock:
    """Coerce a name or a ``ConvBlock`` to the registered instance."""
    if isinstance(block, ConvBlock):
        return block
    try:
        return _REGISTRY[block]
    except KeyError:
        raise KeyError(f"unknown conv block {block!r}; registered: "
                       f"{list_blocks()}") from None


def list_blocks() -> Tuple[str, ...]:
    """Registered block names, sorted for deterministic iteration."""
    return tuple(sorted(_REGISTRY))
