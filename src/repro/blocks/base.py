"""Abstract ``ConvBlock``: the paper's parameterizable convolution block
as a first-class object.

The seed code represented a block as a bare string ("conv1".."conv4")
threaded through kernels, synthesis, allocation and the CNN, with every
module re-deriving block properties (dual output, packing validity,
weight shape) on its own.  ``ConvBlock`` centralizes that metadata and
behavior:

  metadata   ``name``, ``convs_per_step``, ``dual_output``,
             ``weight_shape(coeff_bits)``, ``supports(d, c)``,
             ``packed_ok(d, c)``
  execution  ``apply``       — one (H, W) plane through the Pallas kernel
             ``reference``   — pure-jnp oracle (exact integer math)
             ``apply_batched`` — ALL (out_ch, in_ch) planes of a CNN
             layer in one jitted/vmapped kernel call

``apply_batched`` is the performance half of the redesign: the seed CNN
forward dispatched one Python-level kernel call per (out_ch, in_ch)
plane — O(out_ch·in_ch) dispatches per layer.  Here the plane loop is a
nested ``jax.vmap`` over a single ``pallas_call``, so a whole layer is
one compiled executable.  Dual-output blocks keep their
2-convolutions-per-step semantics by pairing output channels (an odd
final channel is duplicated into the pair and its twin discarded), and
the int32 accumulation is exact, so results stay bit-identical to the
scalar reference.

``apply_batched`` also accepts a whole (N, H, W, in_ch) *image batch* —
the multi-image serving hot path.  The batch goes through
``batched_layer``: the default is an outer ``jax.vmap`` over the
single-image path (still one compiled executable per layer), and the
MXU dot blocks override it with a layer-fused formulation of the same
integer arithmetic (``fused_dot_layer`` / ``packed_dot_layer``) that
shares the im2col across output channels and widens the dot over the
batch — the throughput win behind ``repro.serve.cnn_engine``.  Every
path returns the exact int32 accumulator, bit-identical to the
reference.

Concrete subclasses (``repro.blocks.paper``) provide ``kernel_body``
and register themselves in the registry (``repro.blocks.registry``).
"""

from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.kernels import conv2d, ref

BIT_RANGE = (3, 16)     # sweep-supported data/coeff bit widths (paper §3.2)


@dataclass(frozen=True)
class ConvBlock:
    """One parameterizable 3×3 convolution block (paper §3.1).

    Frozen + hashable so instances can be jit static arguments; the
    kernel body is supplied by subclasses via ``kernel_body``.
    """

    name: str
    convs_per_step: int       # convolutions produced per grid step
    dual_output: bool         # two coefficient planes per call?
    description: str = ""

    # -- metadata -----------------------------------------------------

    def weight_shape(self, coeff_bits: int | None = None) -> Tuple[int, ...]:
        """Per-call weight operand shape (``coeff_bits`` kept for blocks
        whose operand layout depends on the coefficient width)."""
        del coeff_bits
        return (2, 3, 3) if self.dual_output else (3, 3)

    def supports(self, data_bits: int, coeff_bits: int) -> bool:
        """Whether the (data_bits, coeff_bits) design point is valid."""
        lo, hi = BIT_RANGE
        return lo <= data_bits <= hi and lo <= coeff_bits <= hi

    def packed_ok(self, data_bits: int, coeff_bits: int) -> bool:
        """Whether the block runs in its operand-packed regime at this
        design point (False for blocks that never pack)."""
        del data_bits, coeff_bits
        return False

    # -- execution ----------------------------------------------------

    def kernel_body(self, *, tile_h: int, w: int, data_bits: int,
                    coeff_bits: int):
        """Pallas kernel body for one padded row-tile (subclasses)."""
        raise NotImplementedError

    def _validate(self, x, w, data_bits: int, coeff_bits: int,
                  tile_h: int) -> None:
        if not self.supports(data_bits, coeff_bits):
            raise ValueError(
                f"{self.name}: unsupported design point "
                f"(data_bits={data_bits}, coeff_bits={coeff_bits})")
        want = self.weight_shape(coeff_bits)
        if tuple(w.shape) != want:
            raise ValueError(
                f"{self.name}: weight shape {tuple(w.shape)} != {want}")
        if x.shape[0] % tile_h:
            raise ValueError(
                f"{self.name}: image height {x.shape[0]} not divisible by "
                f"tile_h={tile_h}")

    def apply(self, x, w, *, data_bits: int, coeff_bits: int,
              tile_h: int = 16, interpret: bool = True):
        """One plane through the Pallas kernel.  x: (H, W) container int;
        w: ``weight_shape()``.  Returns int32 'same'-padded conv output —
        (H, W), or (2, H, W) for dual-output blocks."""
        self._validate(x, w, data_bits, coeff_bits, tile_h)
        return _apply_one(self, x, w, data_bits=data_bits,
                          coeff_bits=coeff_bits, tile_h=tile_h,
                          interpret=interpret)

    def reference(self, x, w):
        """Pure-jnp oracle for ``apply`` (exact integer arithmetic)."""
        if self.dual_output:
            return jnp.stack([ref.conv2d_3x3_ref(x, w[0]),
                              ref.conv2d_3x3_ref(x, w[1])])
        return ref.conv2d_3x3_ref(x, w)

    def apply_batched(self, x, w, *, data_bits: int, coeff_bits: int,
                      tile_h: int = 16, interpret: bool = True):
        """One CNN layer in a single jitted call.  x: (H, W, in_ch)
        container int, or an (N, H, W, in_ch) image batch; w: (out_ch,
        in_ch, 3, 3).  Returns the exact int32 accumulator (out_ch, H, W)
        — or (N, out_ch, H, W) — = Σ_ic conv(x[..,ic], w[oc,ic]); the
        caller applies its own rescale/activation.  Batched inputs run
        through ``batched_layer`` (one compiled executable per layer)."""
        if x.ndim not in (3, 4):
            raise ValueError(
                f"{self.name}: expected (H, W, in_ch) or (N, H, W, in_ch), "
                f"got shape {tuple(x.shape)}")
        if not self.supports(data_bits, coeff_bits):
            raise ValueError(
                f"{self.name}: unsupported design point "
                f"(data_bits={data_bits}, coeff_bits={coeff_bits})")
        if w.ndim != 4 or tuple(w.shape[2:]) != (3, 3) \
                or w.shape[1] != x.shape[-1]:
            raise ValueError(
                f"{self.name}: expected weights (out_ch, in_ch={x.shape[-1]},"
                f" 3, 3), got {tuple(w.shape)}")
        if x.shape[-3] % tile_h:
            raise ValueError(
                f"{self.name}: image height {x.shape[-3]} not divisible by "
                f"tile_h={tile_h}")
        if x.ndim == 4:
            return _apply_batched_n(self, x, w, data_bits=data_bits,
                                    coeff_bits=coeff_bits, tile_h=tile_h,
                                    interpret=interpret)
        return _apply_batched(self, x, w, data_bits=data_bits,
                              coeff_bits=coeff_bits, tile_h=tile_h,
                              interpret=interpret)

    def batched_layer(self, x, w, *, data_bits: int, coeff_bits: int,
                      tile_h: int = 16, interpret: bool = True):
        """Whole-batch layer execution: x (N, H, W, in_ch) → exact int32
        (N, out_ch, H, W).  Default: outer ``jax.vmap`` over the
        single-image plane-vmapped path — correct for any block.  The
        MXU dot blocks override this with a layer-fused dot that shares
        the im2col across output channels and the batch (bit-identical
        integer math); the multiply-free Conv1 keeps the default."""
        def one(img):
            return _apply_batched(self, img, w, data_bits=data_bits,
                                  coeff_bits=coeff_bits, tile_h=tile_h,
                                  interpret=interpret)
        return jax.vmap(one)(x)


@functools.partial(jax.jit, static_argnames=(
    "block", "data_bits", "coeff_bits", "tile_h", "interpret"))
def _apply_one(block: ConvBlock, x, w, *, data_bits, coeff_bits, tile_h,
               interpret):
    kern = block.kernel_body(tile_h=tile_h, w=x.shape[1],
                             data_bits=data_bits, coeff_bits=coeff_bits)
    return conv2d.run_block_kernel(
        kern, x, w, n_out=2 if block.dual_output else 1,
        tile_h=tile_h, interpret=interpret)


@functools.partial(jax.jit, static_argnames=(
    "block", "data_bits", "coeff_bits", "tile_h", "interpret"))
def _apply_batched(block: ConvBlock, x, w, *, data_bits, coeff_bits,
                   tile_h, interpret):
    h, wd, in_ch = x.shape
    out_ch = w.shape[0]
    planes = x.transpose(2, 0, 1)                      # (in_ch, H, W)

    def one(x2d, wk):
        return _apply_one(block, x2d, wk, data_bits=data_bits,
                          coeff_bits=coeff_bits, tile_h=tile_h,
                          interpret=interpret)

    # inner vmap pairs plane ic with weight [..., ic, :, :]; outer vmap
    # broadcasts the planes across output channels (or channel pairs)
    f = jax.vmap(jax.vmap(one, in_axes=(0, 0)), in_axes=(None, 0))
    if not block.dual_output:
        y = f(planes, w)                               # (oc, ic, H, W)
        return jnp.sum(y, axis=1)                      # exact int32
    # pair output channels two per call; odd tail duplicates the last
    # channel and discards the twin — same sum as the scalar path
    if out_ch % 2:
        w = jnp.concatenate([w, w[-1:]], axis=0)
    pairs = w.shape[0] // 2
    wp = w.reshape(pairs, 2, in_ch, 3, 3).transpose(0, 2, 1, 3, 4)
    y = f(planes, wp)                                  # (p, ic, 2, H, W)
    acc = jnp.sum(y, axis=1)                           # (p, 2, H, W)
    return acc.reshape(pairs * 2, h, wd)[:out_ch]


@functools.partial(jax.jit, static_argnames=(
    "block", "data_bits", "coeff_bits", "tile_h", "interpret"))
def _apply_batched_n(block: ConvBlock, x, w, *, data_bits, coeff_bits,
                     tile_h, interpret):
    return block.batched_layer(x, w, data_bits=data_bits,
                               coeff_bits=coeff_bits, tile_h=tile_h,
                               interpret=interpret)


# ---------------------------------------------------------------------------
# layer-fused batched paths for the MXU dot blocks
#
# Same integer arithmetic as the per-plane kernels — int8/int16 products
# widen exactly into int32 and int32 accumulation is order-independent
# (mod 2^32), so both formulations are bit-identical to the reference —
# but the im2col is built once per input plane instead of once per
# (out_ch, in_ch) call, and the dot contracts over all taps × input
# channels for every output channel and image at once.
# ---------------------------------------------------------------------------

def _layer_taps(x):
    """(N, H, W, ic) → 'same'-padded tap stack (N, H, W, ic, 9)."""
    n, h, wd, ic = x.shape
    xp = jnp.pad(x, ((0, 0), (1, 1), (1, 1), (0, 0)))
    return jnp.stack([xp[:, di:di + h, dj:dj + wd, :]
                      for di in range(3) for dj in range(3)], axis=-1)


def fused_dot_layer(x, w, *, data_bits: int, coeff_bits: int):
    """One integer dot for the whole layer: x (N, H, W, ic) container
    int, w (oc, ic, 3, 3) → exact int32 (N, oc, H, W).  The batched
    widening of the Conv2/Conv4 im2col-plus-dot step (operands stay in
    the kernels' dot dtype, so int8×int8 products keep the native MXU
    rate)."""
    n, h, wd, ic = x.shape
    oc = w.shape[0]
    ddt = conv2d._dot_dtype(data_bits, coeff_bits)
    pat = _layer_taps(x).astype(ddt).reshape(n, h * wd, ic * 9)
    wm = w.transpose(1, 2, 3, 0).reshape(ic * 9, oc).astype(ddt)
    y = jax.lax.dot_general(pat, wm, (((2,), (0,)), ((), ())),
                            preferred_element_type=jnp.int32)
    return y.reshape(n, h, wd, oc).transpose(0, 3, 1, 2)


def packed_dot_layer(x, w, *, data_bits: int, coeff_bits: int):
    """Conv3's operand packing, layer-fused: coefficient pairs share one
    int32 dot column (w_hi·2^S + w_lo), halving the dot width.  The
    S-bit field split must happen per 9-tap convolution — before the
    cross-plane sum — so the contraction runs per input channel and the
    unpacked halves accumulate afterwards (exact int32, bit-identical
    to the per-plane packed kernel)."""
    n, h, wd, ic = x.shape
    oc = w.shape[0]
    s = conv2d._pack_shift(data_bits, coeff_bits)
    if oc % 2:                      # odd tail: duplicate + discard twin
        w = jnp.concatenate([w, w[-1:]], axis=0)
    pairs = w.shape[0] // 2
    wk = w.astype(jnp.int32).reshape(pairs, 2, ic, 9)
    packed = (wk[:, 0] << s) + wk[:, 1]                # (pairs, ic, 9)
    pat = _layer_taps(x).astype(jnp.int32) \
        .transpose(0, 3, 1, 2, 4).reshape(n, ic, h * wd, 9)
    acc = jax.lax.dot_general(                         # (ic, n, HW, pairs)
        pat, packed.transpose(1, 2, 0),
        (((3,), (1,)), ((1,), (0,))),
        preferred_element_type=jnp.int32)
    half = jnp.int32(1 << (s - 1))
    lo = ((acc + half) & ((1 << s) - 1)) - half        # signed low field
    hi = (acc - lo) >> s
    out = jnp.stack([jnp.sum(hi, axis=0), jnp.sum(lo, axis=0)], axis=-1)
    return out.reshape(n, h * wd, pairs * 2)[..., :oc] \
        .reshape(n, h, wd, oc).transpose(0, 3, 1, 2)
