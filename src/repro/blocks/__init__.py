"""``repro.blocks`` — the convolution-block library as a first-class API.

    from repro.blocks import get_block, list_blocks, register_block

    blk = get_block("conv3")
    y = blk.apply(x2d, w2, data_bits=8, coeff_bits=4)       # one plane
    acc = blk.apply_batched(x_hwc, w_oihw, data_bits=8, coeff_bits=4)

Importing the package registers the paper's four blocks (conv1..conv4).
See docs/blocks.md for the API reference and a custom-block example.
"""

from repro.blocks.base import (BIT_RANGE, ConvBlock, fused_dot_layer,
                               packed_dot_layer)
from repro.blocks.paper import (CONV1, CONV2, CONV3, CONV4, Conv1Block,
                                Conv2Block, Conv3Block, Conv4Block)
from repro.blocks.registry import (BlockLike, get_block, list_blocks,
                                   register_block, unregister_block)

__all__ = [
    "BIT_RANGE", "BlockLike", "ConvBlock",
    "CONV1", "CONV2", "CONV3", "CONV4",
    "Conv1Block", "Conv2Block", "Conv3Block", "Conv4Block",
    "fused_dot_layer", "packed_dot_layer",
    "get_block", "list_blocks", "register_block", "unregister_block",
]
