"""The paper's four convolution blocks as ``ConvBlock`` subclasses.

Each class pairs the block's metadata (convolutions per step, dual
output, packing regime) with its Pallas kernel body from
``repro.kernels.conv2d``; instances are registered at import so
``get_block("conv1")`` etc. work everywhere.

The MXU dot blocks additionally override ``batched_layer`` — the
(N, H, W, C) serving hot path — with the layer-fused formulations from
``repro.blocks.base``: Conv2/Conv4 widen their im2col-plus-dot across
output channels and the batch, Conv3 keeps its operand-packing identity
(two convolutions per dot column) inside the fused dot while packing is
valid.  Conv1 is multiply-free by construction, so it inherits the
outer-vmap default.
"""

from __future__ import annotations

import functools
from dataclasses import dataclass

from repro.blocks.base import ConvBlock, fused_dot_layer, packed_dot_layer
from repro.blocks.registry import register_block
from repro.kernels import conv2d


def _partial(body, *, tile_h, w, data_bits, coeff_bits):
    return functools.partial(body, th=tile_h, w=w, data_bits=data_bits,
                             coeff_bits=coeff_bits)


@dataclass(frozen=True)
class Conv1Block(ConvBlock):
    """Multiply-free shift-add (VPU / LUT+carry-chain analogue)."""

    def kernel_body(self, *, tile_h, w, data_bits, coeff_bits):
        return _partial(conv2d.conv1_kernel, tile_h=tile_h, w=w,
                        data_bits=data_bits, coeff_bits=coeff_bits)


@dataclass(frozen=True)
class Conv2Block(ConvBlock):
    """im2col + one integer dot on the MXU (1-DSP analogue)."""

    def kernel_body(self, *, tile_h, w, data_bits, coeff_bits):
        return _partial(conv2d.conv2_kernel, tile_h=tile_h, w=w,
                        data_bits=data_bits, coeff_bits=coeff_bits)

    def batched_layer(self, x, w, *, data_bits, coeff_bits, tile_h=16,
                      interpret=True):
        return fused_dot_layer(x, w, data_bits=data_bits,
                               coeff_bits=coeff_bits)


@dataclass(frozen=True)
class Conv3Block(ConvBlock):
    """Two coefficient planes packed into one operand: a single dot
    yields both convolutions while data_bits + coeff_bits ≤ 12; outside
    that regime it degrades to two dots (the discontinuity the paper's
    segmented regression models)."""

    def packed_ok(self, data_bits, coeff_bits):
        return conv2d.conv3_packed_ok(data_bits, coeff_bits)

    def kernel_body(self, *, tile_h, w, data_bits, coeff_bits):
        return _partial(conv2d.conv3_kernel, tile_h=tile_h, w=w,
                        data_bits=data_bits, coeff_bits=coeff_bits)

    def batched_layer(self, x, w, *, data_bits, coeff_bits, tile_h=16,
                      interpret=True):
        if self.packed_ok(data_bits, coeff_bits):
            return packed_dot_layer(x, w, data_bits=data_bits,
                                    coeff_bits=coeff_bits)
        # outside the packing regime the kernel degrades to two dots —
        # exactly the plain fused dot
        return fused_dot_layer(x, w, data_bits=data_bits,
                               coeff_bits=coeff_bits)


@dataclass(frozen=True)
class Conv4Block(ConvBlock):
    """Two parallel dots (2-DSP analogue), two convolutions per step."""

    def kernel_body(self, *, tile_h, w, data_bits, coeff_bits):
        return _partial(conv2d.conv4_kernel, tile_h=tile_h, w=w,
                        data_bits=data_bits, coeff_bits=coeff_bits)

    def batched_layer(self, x, w, *, data_bits, coeff_bits, tile_h=16,
                      interpret=True):
        return fused_dot_layer(x, w, data_bits=data_bits,
                               coeff_bits=coeff_bits)


CONV1 = register_block(Conv1Block(
    name="conv1", convs_per_step=1, dual_output=False,
    description="multiply-free shift-add (logic-only)"))
CONV2 = register_block(Conv2Block(
    name="conv2", convs_per_step=1, dual_output=False,
    description="im2col + one MXU dot (1 DSP)"))
CONV3 = register_block(Conv3Block(
    name="conv3", convs_per_step=2, dual_output=True,
    description="operand-packed dual conv (1 DSP for 2 convs when packed)"))
CONV4 = register_block(Conv4Block(
    name="conv4", convs_per_step=2, dual_output=True,
    description="two parallel MXU dots (2 DSPs)"))
