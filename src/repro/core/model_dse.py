"""Framework-level DSE: the paper's prediction methodology lifted from
convolution blocks to whole-model training/serving steps.

The expensive oracle is now the 512-device XLA compile (minutes per cell —
the synthesis analogue); the model predicts the compiled roofline terms
from *analytic* config features, so mesh/sharding/architecture trade-offs
can be explored without compiling:

  features  x_f = analytic FLOPs   (6·N_active·tokens · train-multiplier)
            x_m = analytic bytes   (param + activation + cache residency)
            x_c = analytic collective bytes (TP all-reduces + DP grad
                   reduction + EP dispatch, from the sharding rules)
  targets   measured per-device HLO flops / HBM bytes / wire bytes from
            the dry-run corpus (results/*.json)

Per target, Algorithm 1 fits y = poly(x) (degree ≤ 2 here — the relation
is near-linear with a remat/dispatch calibration slope), validated by
leave-one-out MAPE — the same §4.1 metrics as the block-level tables.
"""

from __future__ import annotations

import json
from dataclasses import dataclass
from pathlib import Path
from typing import Dict, List, Optional

import numpy as np

from repro.configs import SHAPES, get_config
from repro.core import polyfit
from repro.core.roofline import model_flops


def analytic_features(arch: str, shape_name: str, n_chips: int,
                      mesh: str) -> Dict[str, float]:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    tp = 16
    dp = n_chips // tp
    tokens_step = (shape.global_batch if shape.kind == "decode"
                   else shape.seq_len * shape.global_batch)
    passes = 4.0 if shape.kind == "train" else 1.0   # fwd+remat+bwd

    # parameter-path flops (MoE padded by the capacity factor)
    n_act = cfg.active_param_count()
    if cfg.moe is not None:
        n_act = n_act * cfg.moe.capacity_factor
    f = 2.0 * n_act * tokens_step * passes

    # attention flops, with the sharding rule's head-replication factor:
    # heads that don't divide the model axis are computed on every TP rank
    n_attn = sum(1 for s in cfg.layer_cycle
                 if s.mixer in ("attn", "local")) * cfg.n_cycles
    if n_attn and cfg.n_heads:
        t_kv = shape.seq_len
        q_rows = tokens_step
        attn = 4.0 * q_rows * t_kv * cfg.n_heads * cfg.head_dim \
            * n_attn * passes
        if cfg.n_heads % tp:
            attn *= tp               # replicated over the model axis
        f += attn
    # SSD flops (intra-chunk quadratic + state updates)
    if cfg.ssm is not None:
        n_mamba = sum(1 for s in cfg.layer_cycle
                      if s.mixer == "mamba") * cfg.n_cycles
        inner = cfg.ssm.expand * cfg.d_model
        nh = inner // cfg.ssm.head_dim
        q = cfg.ssm.chunk_size
        per_tok = 2 * q * nh * (cfg.ssm.state_dim + 2 * cfg.ssm.head_dim)
        ssd = per_tok * tokens_step * n_mamba * passes
        if shape.kind == "decode":
            ssd = 2 * nh * cfg.ssm.state_dim * cfg.ssm.head_dim \
                * tokens_step * n_mamba
        f += ssd
    # memory: params (+grads+moments for train) + working activations
    pbytes = cfg.param_count() * 2
    if shape.kind == "train":
        pbytes = cfg.param_count() * (2 + 4 + 4 + 4)
    tokens = shape.seq_len * shape.global_batch
    if shape.kind == "decode":
        tokens = shape.global_batch
        # cache residency
        kv = (cfg.n_layers * 2 * shape.seq_len * shape.global_batch
              * cfg.kv_dim * 2)
        pbytes += kv
    act = tokens * cfg.d_model * 2 * max(cfg.n_layers // 8, 1)
    mem = pbytes + act
    # collectives: TP activation reductions + DP gradient reduction
    tp_coll = tokens * cfg.d_model * 2 * 2 * cfg.n_layers / n_chips
    dp_coll = (cfg.param_count() * 4 * 2 / n_chips
               if shape.kind == "train" else 0.0)
    ep_coll = 0.0
    if cfg.moe is not None:
        ep_coll = tokens * cfg.d_model * 2 * cfg.moe.top_k * 2 / n_chips
    return {"x_flops": f / n_chips, "x_mem": mem / n_chips,
            "x_coll": tp_coll + dp_coll + ep_coll,
            "is_train": 1.0 if shape.kind == "train" else 0.0}


TARGETS = {"flops": ("x_flops",), "hbm_bytes": ("x_mem",),
           "collective_total": ("x_coll",)}


@dataclass
class DSEModel:
    models: Dict[str, polyfit.PolyModel]
    loo: Dict[str, Dict[str, float]]

    def predict(self, arch: str, shape_name: str, n_chips: int = 256,
                mesh: str = "single") -> Dict[str, float]:
        from repro.configs import SHAPES
        feats = analytic_features(arch, shape_name, n_chips, mesh)
        kind = SHAPES[shape_name].kind
        out = {}
        for tgt, (fx,) in TARGETS.items():
            m = self.models[tgt]
            pred = (m.predict(feats[fx], 0.0, kind=kind)
                    if isinstance(m, _KindModel)
                    else m.predict(feats[fx], 0.0))
            out[tgt] = float(np.maximum(pred[0], 0.0))
        return out


def load_corpus(results_dir: str | Path, tag: str = "baseline"
                ) -> List[dict]:
    rows = []
    for f in sorted(Path(results_dir).glob(f"{tag}__*.json")):
        r = json.loads(f.read_text())
        if r.get("status") == "ok" and "flops" in r.get("hlo", {}):
            rows.append(r)
    return rows


def fit_dse(rows: List[dict]) -> DSEModel:
    """Per (target × shape-kind) log-space fits: train / prefill / decode
    cells have different calibration slopes (backward+remat multipliers,
    cache streaming), which one pooled fit smears together."""
    from repro.configs import SHAPES
    models, loo = {}, {}
    kinds = sorted({SHAPES[r["shape"]].kind for r in rows})
    for tgt, (fx,) in TARGETS.items():
        preds_all, y_all = [], []
        kind_models = {}
        for kind in kinds:
            sel = [r for r in rows if SHAPES[r["shape"]].kind == kind]
            X = np.array([analytic_features(
                r["arch"], r["shape"], r["n_chips"], r["mesh"])[fx]
                for r in sel])
            Y = np.array([r["hlo"].get(tgt, 0.0) for r in sel])
            lx = np.log10(np.maximum(X, 1.0))
            ly = np.log10(np.maximum(Y, 1.0))
            kind_models[kind] = _LogPoly(
                polyfit.algorithm1(lx, np.zeros_like(lx), ly,
                                   max_degree=2))
            for i in range(len(X)):   # leave-one-out within kind
                mask = np.arange(len(X)) != i
                mi = polyfit.algorithm1(lx[mask], np.zeros_like(lx[mask]),
                                        ly[mask], max_degree=2)
                preds_all.append(10 ** mi.predict(lx[i], 0.0)[0])
                y_all.append(Y[i])
        models[tgt] = _KindModel(kind_models)
        preds_all, y_all = np.array(preds_all), np.array(y_all)
        loo[tgt] = polyfit.error_metrics(y_all, preds_all)
        loo[tgt]["log_mae"] = float(np.mean(np.abs(
            np.log10(np.maximum(preds_all, 1.0))
            - np.log10(np.maximum(y_all, 1.0)))))
    return DSEModel(models, loo)


class _KindModel:
    """Dispatch to the shape-kind-specific log-space fit."""

    def __init__(self, kind_models):
        self.kind_models = kind_models

    def predict(self, x, c, kind="train"):
        m = self.kind_models.get(kind,
                                 next(iter(self.kind_models.values())))
        return m.predict(x, c)


class _LogPoly:
    """Wrap a log-space PolyModel to predict in linear space."""

    def __init__(self, inner):
        self.inner = inner

    def predict(self, x, c):
        lx = np.log10(np.maximum(np.atleast_1d(np.asarray(x, float)), 1.0))
        return 10 ** self.inner.predict(lx, np.zeros_like(lx))
