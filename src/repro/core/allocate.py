"""Block allocation under resource budgets (paper §4.2, Table 5).

The paper packs a ZCU104 to a target utilization (80 %) with a mix of
convolution blocks chosen purely from the fitted models.  TPU adaptation
(DESIGN.md §7): FPGA area budgets become per-chip *rate* budgets — a block
instance is a streaming pipeline consuming predicted resources per tile
step (normalized to 1 tile/µs, the paper's one-conv-per-cycle unit):

  DSP  → MXU issue (int32-equivalent FLOPs/µs)
  LLUT → VPU lane-ops/µs
  BRAM → HBM bytes/µs
  VMEM → VMEM bytes (capacity, not rate)

The allocation itself is the same optimization problem: maximize total
convolutions subject to every resource ≤ target·budget, solved by LP
relaxation (scipy linprog) + greedy integer rounding.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional

import numpy as np
from scipy.optimize import linprog

from repro.blocks import get_block
from repro.core import polyfit, synth

# v5e per-chip budgets in the allocator's normalized units
V5E_BUDGETS = {
    "mxu_cost": 98.5e6,       # int32-equiv FLOPs/µs (197 TFLOP/s bf16 peak)
    "vpu_ops": 3.0e6,         # int32 lane-ops/µs
    "hbm_bytes": 819e3,       # bytes/µs (819 GB/s)
    "vmem_bytes": 128 * 2**20,  # bytes (capacity)
}


@dataclass
class BlockModels:
    """Fitted per-resource models for every block (from the sweep)."""
    models: Dict[str, Dict[str, object]]   # block -> resource -> model
    convs: Dict[str, float]                # block -> convolutions per step

    @classmethod
    def fit(cls, rows: List[dict]) -> "BlockModels":
        """Fit one model per (registered block, budgeted resource).

        Every budgeted resource gets a model — including columns that are
        constant over the sweep (e.g. Conv1 never touches the MXU):
        ``fit_auto`` degrades to the constant polynomial there, which
        predicts the flat value exactly, and ``demand()`` then always
        covers every budgeted resource.  Block identity (convs/step)
        comes from the ``ConvBlock`` registry when the block is
        registered; rows naming an unregistered block (e.g. a cached
        sweep from a session that registered a custom block) fall back
        to the ``convs_per_step`` recorded in the rows themselves.
        """
        blocks = sorted({r["block"] for r in rows})
        models, convs = {}, {}
        for b in blocks:
            d, c, ys = synth.sweep_arrays(rows, b)
            models[b] = {res: polyfit.fit_auto(d, c, ys[res], block=b)
                         for res in V5E_BUDGETS}
            try:
                convs[b] = float(get_block(b).convs_per_step)
            except KeyError:
                convs[b] = float(next(r["convs_per_step"] for r in rows
                                      if r["block"] == b))
        return cls(models, convs)

    def demand(self, block: str, data_bits: int, coeff_bits: int) -> Dict:
        return {res: float(max(m.predict(data_bits, coeff_bits)[0], 0.0))
                for res, m in self.models[block].items()}


@dataclass
class Allocation:
    counts: Dict[str, int]
    usage_pct: Dict[str, float]
    total_convs: float


def allocate(bm: BlockModels, *, data_bits: int = 8, coeff_bits: int = 8,
             target: float = 0.8,
             budgets: Optional[Dict[str, float]] = None,
             only_block: Optional[str] = None) -> Allocation:
    budgets = budgets or V5E_BUDGETS
    blocks = [only_block] if only_block else sorted(bm.models)
    res_names = sorted(budgets)
    A = np.array([[bm.demand(b, data_bits, coeff_bits)[r] for b in blocks]
                  for r in res_names])
    ub = np.array([target * budgets[r] for r in res_names])
    objective = -np.array([bm.convs[b] for b in blocks])

    lp = linprog(objective, A_ub=A, b_ub=ub, bounds=[(0, None)] * len(blocks),
                 method="highs")
    n = np.floor(lp.x + 1e-9).astype(int) if lp.success else \
        np.zeros(len(blocks), int)

    # greedy top-up: add whichever block still fits and adds most convs
    improved = True
    while improved:
        improved = False
        order = sorted(range(len(blocks)),
                       key=lambda i: -bm.convs[blocks[i]])
        for i in order:
            trial = n.copy()
            trial[i] += 1
            if np.all(A @ trial <= ub + 1e-9):
                n = trial
                improved = True
    used = A @ n
    usage = {r: float(100 * used[k] / budgets[r])
             for k, r in enumerate(res_names)}
    total = float(sum(bm.convs[b] * n[i] for i, b in enumerate(blocks)))
    return Allocation({b: int(n[i]) for i, b in enumerate(blocks)},
                      usage, total)
