"""Block allocation under resource budgets (paper §4.2, Table 5).

The paper packs a ZCU104 to a target utilization (80 %) with a mix of
convolution blocks chosen purely from the fitted models.  TPU adaptation
(DESIGN.md §7): FPGA area budgets become per-chip *rate* budgets — a block
instance is a streaming pipeline consuming predicted resources per tile
step (normalized to 1 tile/µs, the paper's one-conv-per-cycle unit):

  DSP  → MXU issue (int32-equivalent FLOPs/µs)
  LLUT → VPU lane-ops/µs
  BRAM → HBM bytes/µs
  VMEM → VMEM bytes (capacity, not rate)

The allocation itself is the same optimization problem: maximize total
convolutions subject to every resource ≤ target·budget, solved by LP
relaxation (scipy linprog) + greedy integer rounding.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Mapping, Optional, Tuple, Union

import numpy as np
from scipy.optimize import linprog

from repro.blocks import get_block
from repro.core import polyfit, synth

# the resource classes every device budgets (and every BlockModels fits)
BUDGET_RESOURCES = ("hbm_bytes", "mxu_cost", "vmem_bytes", "vpu_ops")


@dataclass(frozen=True)
class DeviceProfile:
    """One deployable part: a named budget vector plus a relative unit
    cost — the TPU analogue of choosing among FPGA parts (ZCU104 vs a
    bigger/smaller Zynq) in the paper's companion resource-driven flow.

    ``budgets`` maps every resource in ``BUDGET_RESOURCES`` to the
    device's capacity in the allocator's normalized units (rates per µs,
    except ``vmem_bytes`` which is a capacity)."""

    name: str
    budgets: Mapping[str, float]
    cost: float = 1.0              # relative unit price (v5e ≡ 1.0)
    description: str = ""

    def __post_init__(self):
        missing = [r for r in BUDGET_RESOURCES if r not in self.budgets]
        if missing:
            raise ValueError(f"device {self.name!r} missing budgets for "
                             f"{missing}")


# v5e per-chip budgets in the allocator's normalized units
V5E_BUDGETS = {
    "mxu_cost": 98.5e6,       # int32-equiv FLOPs/µs (197 TFLOP/s bf16 peak)
    "vpu_ops": 3.0e6,         # int32 lane-ops/µs
    "hbm_bytes": 819e3,       # bytes/µs (819 GB/s)
    "vmem_bytes": 128 * 2**20,  # bytes (capacity)
}

V5E = DeviceProfile(
    name="v5e", budgets=V5E_BUDGETS, cost=1.0,
    description="TPU v5e chip — the mid-range baseline part")

V5P = DeviceProfile(
    name="v5p", cost=3.4,
    budgets={
        "mxu_cost": 229.5e6,      # 459 TFLOP/s bf16 peak
        "vpu_ops": 6.0e6,
        "hbm_bytes": 2765e3,      # 2765 GB/s
        "vmem_bytes": 128 * 2**20,
    },
    description="TPU v5p chip — the large training part")

EDGE = DeviceProfile(
    name="edge", cost=0.2,
    budgets={
        "mxu_cost": 9.85e6,       # one-tenth of a v5e
        "vpu_ops": 0.5e6,
        "hbm_bytes": 102e3,
        "vmem_bytes": 32 * 2**20,
    },
    description="constrained edge part — the ZCU104-class analogue")

# cheapest first, so "first profile that fits" is also the cheapest fit
DEVICE_CATALOG: Tuple[DeviceProfile, ...] = (EDGE, V5E, V5P)

BudgetLike = Union[DeviceProfile, Mapping[str, float]]


def get_device(name: str) -> DeviceProfile:
    for dev in DEVICE_CATALOG:
        if dev.name == name:
            return dev
    raise KeyError(f"unknown device {name!r}; catalog: "
                   f"{[d.name for d in DEVICE_CATALOG]}")


def as_budgets(budgets: Optional[BudgetLike]) -> Dict[str, float]:
    """Coerce a DeviceProfile / budget mapping / None (→ v5e) to a dict."""
    if budgets is None:
        return dict(V5E_BUDGETS)
    if isinstance(budgets, DeviceProfile):
        return dict(budgets.budgets)
    return dict(budgets)


@dataclass
class BlockModels:
    """Fitted per-resource models for every block (from the sweep)."""
    models: Dict[str, Dict[str, object]]   # block -> resource -> model
    convs: Dict[str, float]                # block -> convolutions per step

    @classmethod
    def fit(cls, rows: List[dict]) -> "BlockModels":
        """Fit one model per (registered block, budgeted resource).

        Every budgeted resource gets a model — including columns that are
        constant over the sweep (e.g. Conv1 never touches the MXU):
        ``fit_auto`` degrades to the constant polynomial there, which
        predicts the flat value exactly, and ``demand()`` then always
        covers every budgeted resource.  Block identity (convs/step)
        comes from the ``ConvBlock`` registry when the block is
        registered; rows naming an unregistered block (e.g. a cached
        sweep from a session that registered a custom block) fall back
        to the ``convs_per_step`` recorded in the rows themselves.
        """
        blocks = sorted({r["block"] for r in rows})
        models, convs = {}, {}
        for b in blocks:
            d, c, ys = synth.sweep_arrays(rows, b)
            models[b] = {res: polyfit.fit_auto(d, c, ys[res], block=b)
                         for res in BUDGET_RESOURCES}
            try:
                convs[b] = float(get_block(b).convs_per_step)
            except KeyError:
                convs[b] = float(next(r["convs_per_step"] for r in rows
                                      if r["block"] == b))
        return cls(models, convs)

    def demand(self, block: str, data_bits: int, coeff_bits: int) -> Dict:
        return {res: float(max(m.predict(data_bits, coeff_bits)[0], 0.0))
                for res, m in self.models[block].items()}


@dataclass
class Allocation:
    counts: Dict[str, int]
    usage_pct: Dict[str, float]
    total_convs: float


def allocate(bm: BlockModels, *, data_bits: int = 8, coeff_bits: int = 8,
             target: float = 0.8,
             budgets: Optional[BudgetLike] = None,
             only_block: Optional[str] = None,
             max_topup_rounds: int = 10_000) -> Allocation:
    budgets = as_budgets(budgets)
    blocks = [only_block] if only_block else sorted(bm.models)
    res_names = sorted(budgets)
    A = np.array([[bm.demand(b, data_bits, coeff_bits)[r] for b in blocks]
                  for r in res_names])
    ub = np.array([target * budgets[r] for r in res_names])
    objective = -np.array([bm.convs[b] for b in blocks])

    # Blocks whose predicted demand is ~0 on EVERY budgeted resource are
    # excluded from both the LP and the greedy top-up: a free column with
    # positive objective makes the LP unbounded (discarding its solution
    # for every block), and the top-up would add the block forever.
    nonzero = [i for i in range(len(blocks)) if np.any(A[:, i] > 1e-9)]
    n = np.zeros(len(blocks), int)
    if nonzero:
        lp = linprog(objective[nonzero], A_ub=A[:, nonzero], b_ub=ub,
                     bounds=[(0, None)] * len(nonzero), method="highs")
        if lp.success:
            n[nonzero] = np.floor(lp.x + 1e-9).astype(int)

    # greedy top-up: add whichever block still fits and adds most convs.
    # The round cap is a backstop against demands so tiny that the top-up
    # degenerates into counting to the budget one by one.
    order = sorted(nonzero, key=lambda i: -bm.convs[blocks[i]])
    improved, rounds = True, 0
    while improved and rounds < max_topup_rounds:
        improved = False
        rounds += 1
        for i in order:
            trial = n.copy()
            trial[i] += 1
            if np.all(A @ trial <= ub + 1e-9):
                n = trial
                improved = True
    used = A @ n
    usage = {r: float(100 * used[k] / budgets[r])
             for k, r in enumerate(res_names)}
    total = float(sum(bm.convs[b] * n[i] for i, b in enumerate(blocks)))
    return Allocation({b: int(n[i]) for i, b in enumerate(blocks)},
                      usage, total)
