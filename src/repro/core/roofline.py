"""Roofline terms from dry-run artifacts (TPU v5e targets).

  compute    = HLO_FLOPs   / (chips · 197 TFLOP/s)
  memory     = HLO_bytes   / (chips · 819 GB/s)
  collective = wire_bytes  / (chips · 50 GB/s·link)   [already per chip]

cost_analysis() reports whole-program FLOPs/bytes for the *per-device*
partitioned module, so FLOPs/bytes are divided by chips only when the
source is a global count; collective bytes scraped from post-SPMD HLO are
per-chip already.  MODEL_FLOPS = 6·N·D (train) / 2·N·D (inference) with N
the active parameter count — the useful-work yardstick that exposes
remat/dispatch overhead in the HLO_FLOPs ratio.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict

PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link (~per chip, 1 link used)


_SHAPE_META = {
    "train_4k": ("train", 4096, 256),
    "prefill_32k": ("prefill", 32768, 32),
    "decode_32k": ("decode", 32768, 128),
    "long_500k": ("decode", 524288, 1),
}


def min_bytes(result: Dict) -> float:
    """Lower bound on HBM bytes that MUST move per step (global):
    weights (+ optimizer state round-trip for train) + KV/state cache for
    decode — the memory-side roofline floor."""
    n, n_act = result["params"], result["active_params"]
    kind, seq, batch = _SHAPE_META[result["shape"]]
    if kind == "train":
        # read bf16 params + write grads + read/write fp32 m,v + param write
        return n * (2 + 2 + 16 + 2)
    if kind == "prefill":
        return n * 2
    # decode: active weights stream once per token + cache read
    from repro.configs import get_config
    try:
        cfg = get_config(result["arch"])
        n_attn = sum(1 for s in cfg.layer_cycle
                     if s.mixer in ("attn", "local")) * cfg.n_cycles
        cache = n_attn * 2 * seq * batch * cfg.kv_dim * 2
        if cfg.ssm is not None:
            n_mamba = sum(1 for s in cfg.layer_cycle
                          if s.mixer == "mamba") * cfg.n_cycles
            inner = cfg.ssm.expand * cfg.d_model
            nh = inner // cfg.ssm.head_dim
            cache += n_mamba * batch * nh * cfg.ssm.state_dim * \
                cfg.ssm.head_dim * 4
    except Exception:
        cache = 0.0
    return n_act * 2 + cache


def model_flops(result: Dict) -> float:
    """Useful FLOPs per step for the cell, from analytic param counts."""
    n_active = result["active_params"]
    shape = result["shape"]
    kind = {"train_4k": "train", "prefill_32k": "prefill",
            "decode_32k": "decode", "long_500k": "decode"}[shape]
    seq = {"train_4k": 4096, "prefill_32k": 32768, "decode_32k": 32768,
           "long_500k": 524288}[shape]
    batch = {"train_4k": 256, "prefill_32k": 32, "decode_32k": 128,
             "long_500k": 1}[shape]
    if kind == "train":
        tokens = seq * batch
        return 6.0 * n_active * tokens
    if kind == "prefill":
        return 2.0 * n_active * seq * batch
    return 2.0 * n_active * batch           # one new token per sequence


def roofline_terms(result: Dict) -> Dict:
    chips = result["n_chips"]
    hlo = result.get("hlo", {})
    if "flops" in hlo:
        # trip-count-aware analyzer values (per-device module)
        flops_dev = hlo["flops"]
        bytes_dev = hlo["hbm_bytes"]
        coll = hlo.get("collective_total", 0.0)
    else:  # fall back to cost_analysis (undercounts while-loop bodies)
        cost = result["cost"]
        flops_dev = cost["flops"]
        bytes_dev = cost["bytes_accessed"]
        coll = result.get("collectives", {}).get("total", 0.0)

    t_compute = flops_dev / PEAK_FLOPS
    t_memory = bytes_dev / HBM_BW
    t_coll = coll / ICI_BW
    terms = {"compute_s": t_compute, "memory_s": t_memory,
             "collective_s": t_coll}
    dominant = max(terms, key=terms.get)

    mf = model_flops(result)
    useful_ratio = mf / max(flops_dev * chips, 1.0)
    # the ideal step is bounded by BOTH the useful compute and the
    # minimal weight/cache traffic (decode is legitimately memory-bound)
    ideal = max(mf / (chips * PEAK_FLOPS),
                min_bytes(result) / (chips * HBM_BW))
    bound = max(terms.values())
    return {
        **terms,
        "dominant": dominant,
        "model_flops": mf,
        "hlo_flops_global": flops_dev * chips,
        "useful_flops_ratio": useful_ratio,
        "ideal_s": ideal,
        "roofline_fraction": ideal / bound if bound > 0 else 0.0,
    }
