"""Compiled-artifact analysis: the TPU analogue of the paper's synthesis
resource report.

* ``collective_bytes(hlo_text)`` — scrape post-SPMD HLO for all-gather /
  all-reduce / reduce-scatter / all-to-all / collective-permute and sum
  wire bytes per chip (ring-model factors).
* ``cost_summary(compiled)`` — FLOPs / bytes from ``cost_analysis()``.
* ``jaxpr_resources(fn, *args)`` — pre-XLA op-class census used by the
  convolution-block sweep: MXU flops (dot/conv), VPU elementwise ops,
  accumulation-add chain length (the carry-chain analogue), and byte
  traffic, recursing through scan/pjit/remat with trip-count multipliers.
"""

from __future__ import annotations

import re
from collections import defaultdict
from typing import Any, Dict

import jax
import numpy as np

_DTYPE_BYTES = {
    "pred": 1, "s4": 0.5, "u4": 0.5, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1,
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8, "c64": 8, "c128": 16,
}

# wire-traffic factor per result byte (ring algorithms, large-n limit)
_COLLECTIVE_FACTOR = {
    "all-gather": 1.0,        # each chip receives (n-1)/n of the result
    "all-reduce": 2.0,        # reduce-scatter + all-gather
    "reduce-scatter": 1.0,
    "all-to-all": 1.0,
    "collective-permute": 1.0,
}

_COLL_RE = re.compile(
    r"=\s*(?:\()?\s*((?:[a-z0-9]+\[[^\]]*\](?:\{[^}]*\})?(?:,\s*)?)+)\)?\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\(")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(type_str: str) -> float:
    total = 0.0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Per-collective-class wire bytes (per chip) from post-SPMD HLO text."""
    out: Dict[str, float] = defaultdict(float)
    for m in _COLL_RE.finditer(hlo_text):
        types, op, _start = m.group(1), m.group(2), m.group(3)
        out[op] += _shape_bytes(types) * _COLLECTIVE_FACTOR[op]
    out["total"] = sum(v for k, v in out.items() if k != "total")
    return dict(out)


def count_collectives(hlo_text: str) -> Dict[str, int]:
    out: Dict[str, int] = defaultdict(int)
    for m in _COLL_RE.finditer(hlo_text):
        out[m.group(2)] += 1
    return dict(out)


def cost_summary(compiled) -> Dict[str, float]:
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    out = {"flops": float(ca.get("flops", 0.0)),
           "bytes_accessed": float(ca.get("bytes accessed", 0.0))}
    for k, v in ca.items():
        if k.startswith("bytes accessed") and isinstance(v, (int, float)):
            out.setdefault("bytes_detail", {})[k] = float(v)
    return out


def memory_summary(compiled) -> Dict[str, float]:
    ma = compiled.memory_analysis()
    keys = ["argument_size_in_bytes", "output_size_in_bytes",
            "temp_size_in_bytes", "alias_size_in_bytes",
            "generated_code_size_in_bytes"]
    out = {}
    for k in keys:
        v = getattr(ma, k, None)
        if v is not None:
            out[k] = float(v)
    out["total_hbm_bytes"] = (out.get("argument_size_in_bytes", 0.0)
                              + out.get("output_size_in_bytes", 0.0)
                              + out.get("temp_size_in_bytes", 0.0)
                              - out.get("alias_size_in_bytes", 0.0))
    return out


# ---------------------------------------------------------------------------
# Trip-count-aware HLO module analyzer
# ---------------------------------------------------------------------------
# XLA's cost_analysis() counts while-loop bodies ONCE, so any scanned layer
# stack is undercounted by its trip count.  This analyzer walks the
# post-optimization (per-device) HLO text from the ENTRY computation,
# multiplying through while-loop trip counts:
#   * flops      — dot/convolution ops (including inside fusions)
#   * hbm_bytes  — operand+result bytes of top-level macro ops (fusion
#                  internals stay in registers/VMEM; fusion boundaries are
#                  the HBM traffic)
#   * collective — wire bytes per chip with ring-model factors
# It is also the dry-run "profiler": per-op-class tallies expose redundant
# collectives and remat recompute for the §Perf iterations.

_COMP_HDR = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(.*->.*\{\s*$")
_INST = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\(.*?\)|[a-z0-9]+\[[^\]]*\]"
    r"(?:\{[^}]*\})?)\s*([a-z][a-z0-9\-]*)\((.*)$")
_TRIP_CFG = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_OPERAND = re.compile(r"%([\w.\-]+)")
_CALL_ATTR = re.compile(
    r"(?:calls|body|condition|true_computation|false_computation|to_apply|"
    r"branch_computations)=\{?%?([\w.\-]+(?:,\s*%?[\w.\-]+)*)\}?")
_CONST_INT = re.compile(r"constant\((\d+)\)")
_DIMS_ATTR = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_BATCH_ATTR = re.compile(r"lhs_batch_dims=\{([0-9,]*)\}")

_MACRO_TRAFFIC_OPS = {
    "fusion", "dot", "convolution", "copy", "all-gather", "all-reduce",
    "reduce-scatter", "all-to-all", "collective-permute", "dynamic-slice",
    "dynamic-update-slice", "gather", "scatter", "sort", "reduce",
    "broadcast", "transpose", "reshape", "slice", "concatenate", "pad",
    "iota", "convert", "select-and-scatter", "cholesky",
    "triangular-solve", "rng", "custom-call",
}
_COLLECTIVES = set(_COLLECTIVE_FACTOR)


def _parse_dims(type_str: str):
    """First shape in a (possibly tuple) type string -> (dtype, [dims])."""
    m = _SHAPE_RE.search(type_str)
    if not m:
        return None, []
    dt, dims = m.group(1), m.group(2)
    return dt, [int(d) for d in dims.split(",") if d] if dims else []


class HloModule:
    def __init__(self, text: str):
        self.computations: Dict[str, list] = {}
        self.shapes: Dict[str, str] = {}
        self.entry = None
        cur = None
        for line in text.splitlines():
            hdr = _COMP_HDR.match(line)
            if hdr:
                cur = hdr.group(2)
                self.computations[cur] = []
                if hdr.group(1):
                    self.entry = cur
                continue
            if cur is None:
                continue
            m = _INST.match(line)
            if m:
                name, type_str, op, rest = m.groups()
                self.computations[cur].append((name, type_str, op, rest))
                self.shapes[name] = type_str
        # parameter shapes are declared as instructions ("parameter(0)"),
        # so the def map above already covers them.

    # -- helpers -----------------------------------------------------------
    def _trip_count(self, cond_name: str, depth: int = 0) -> int:
        """Loop bound from the while condition: the largest integer constant
        in the condition computation (or its callees).  Scans are lowered
        with a `lt(counter, constant(N))` condition, so this recovers N."""
        best = 1
        if depth > 3:
            return best
        for name, _, op, rest in self.computations.get(cond_name, []):
            if op == "constant":
                cm = re.match(r"\s*(\d+)\s*\)", rest)
                if cm:
                    best = max(best, int(cm.group(1)))
            cm = re.search(r"(?:calls|to_apply)=%?([\w.\-]+)", rest)
            if cm:
                best = max(best, self._trip_count(cm.group(1), depth + 1))
        return best

    def _dot_flops(self, type_str: str, rest: str) -> float:
        _, out_dims = _parse_dims(type_str)
        out = 1
        for d in out_dims:
            out *= d
        ops = _OPERAND.findall(rest.split(")", 1)[0])
        k = 1
        if ops:
            lhs_type = self.shapes.get(ops[0], "")
            _, lhs_dims = _parse_dims(lhs_type)
            cm = _DIMS_ATTR.search(rest)
            if cm and lhs_dims:
                for idx in cm.group(1).split(","):
                    if idx and int(idx) < len(lhs_dims):
                        k *= lhs_dims[int(idx)]
        return 2.0 * out * k

    def _operand_bytes_list(self, rest: str):
        args = rest.split(")", 1)[0]
        return [_shape_bytes(self.shapes.get(name, ""))
                for name in _OPERAND.findall(args)]

    def _operand_bytes(self, rest: str) -> float:
        return sum(self._operand_bytes_list(rest))

    def _macro_traffic(self, name: str, type_str: str, op: str,
                       rest: str) -> float:
        """HBM traffic of one top-level macro op.

        Slice-like ops (and fusions rooted in them — XLA names fusions
        after their root) move only their *output*-sized window, not the
        whole operand: counting the 28-layer stacked-weight carry per scan
        iteration would overstate traffic ~depth-fold.  Update-slice roots
        move only the update window of their (aliased, in-place) buffer.
        """
        out_b = _shape_bytes(type_str)
        ops_b = self._operand_bytes_list(rest)
        tag = name if op == "fusion" else op
        tag = tag.replace("_", "-")
        if "dynamic-update-slice" in tag or "scatter" in tag:
            small = sum(ops_b) - (max(ops_b) if ops_b else 0.0)
            return 2.0 * small
        if "dynamic-slice" in tag or "gather" in tag or \
                tag.startswith("slice") or "-slice" in tag:
            return 2.0 * out_b
        return out_b + sum(ops_b)

    # -- main walk -----------------------------------------------------------
    def analyze(self) -> Dict[str, float]:
        res = defaultdict(float)
        self._walk(self.entry, 1.0, res, top=True)
        res["collective_total"] = sum(
            v for k, v in res.items() if k.startswith("coll_"))
        return dict(res)

    def _walk(self, comp: str, mult: float, res, *, top: bool):
        for name, type_str, op, rest in self.computations.get(comp, []):
            if op in ("dot", "convolution"):
                res["flops"] += mult * self._dot_flops(type_str, rest)
            if op in _COLLECTIVES:
                b = _shape_bytes(type_str) * _COLLECTIVE_FACTOR[op]
                res[f"coll_{op}"] += mult * b
                res[f"colln_{op}"] += mult
            if top and op in _MACRO_TRAFFIC_OPS:
                res["hbm_bytes"] += mult * self._macro_traffic(
                    name, type_str, op, rest)
            if op == "while":
                body_m = re.search(r"body=%?([\w.\-]+)", rest)
                cond_m = re.search(r"condition=%?([\w.\-]+)", rest)
                body = body_m.group(1) if body_m else None
                cond = cond_m.group(1) if cond_m else None
                trip_m = _TRIP_CFG.search(rest)   # XLA's own loop analysis
                if trip_m:
                    trip = int(trip_m.group(1))
                else:
                    trip = self._trip_count(cond) if cond else 1
                res["while_trips"] = max(res.get("while_trips", 0), trip)
                if body:
                    self._walk(body, mult * trip, res, top=top)
            elif op == "fusion":
                cm = re.search(r"calls=%?([\w.\-]+)", rest)
                if cm:
                    self._walk(cm.group(1), mult, res, top=False)
            elif op == "conditional":
                for cname in re.findall(
                        r"computation[s]?=\{?%?([\w.\-]+)", rest):
                    self._walk(cname, mult, res, top=top)


def analyze_hlo(text: str) -> Dict[str, float]:
    mod = HloModule(text)
    out = mod.analyze()
    out["collectives"] = {
        k.removeprefix("coll_"): v for k, v in out.items()
        if isinstance(v, float) and k.startswith("coll_")}
    return out


# ---------------------------------------------------------------------------
# jaxpr-level op census (the block-sweep "synthesis report")
# ---------------------------------------------------------------------------

_ELEMENTWISE = {
    "add", "sub", "mul", "div", "max", "min", "exp", "log", "tanh",
    "logistic", "erf", "rsqrt", "sqrt", "neg", "sign", "floor", "round",
    "clamp", "select_n", "and", "or", "xor", "not", "shift_left",
    "shift_right_logical", "shift_right_arithmetic", "rem", "pow",
    "integer_pow", "abs", "ge", "gt", "le", "lt", "eq", "ne",
    "convert_element_type", "nextafter",
}

_ADD_LIKE = {"add", "sub"}
_MEMORY_OPS = {"gather", "scatter", "scatter-add", "dynamic_slice",
               "dynamic_update_slice", "concatenate", "pad", "slice",
               "reshape", "transpose", "broadcast_in_dim", "rev", "squeeze"}


def _size(aval) -> int:
    try:
        return int(np.prod(aval.shape)) if aval.shape else 1
    except Exception:
        return 1


def _bytes(aval) -> int:
    try:
        return _size(aval) * aval.dtype.itemsize
    except Exception:
        return 0


def _dot_flops(eqn) -> int:
    (lhs, rhs) = eqn.invars[0].aval, eqn.invars[1].aval
    dims = eqn.params["dimension_numbers"]
    (lc, rc), (lb, rb) = dims
    m = _size_excluding(lhs, list(lc) + list(lb))
    n = _size_excluding(rhs, list(rc) + list(rb))
    k = 1
    for i in lc:
        k *= lhs.shape[i]
    b = 1
    for i in lb:
        b *= lhs.shape[i]
    return 2 * m * n * k * b


def _size_excluding(aval, axes) -> int:
    out = 1
    for i, d in enumerate(aval.shape):
        if i not in axes:
            out *= d
    return out


def _conv_flops(eqn) -> int:
    out = eqn.outvars[0].aval
    rhs = eqn.invars[1].aval
    dn = eqn.params["dimension_numbers"]
    k_spatial = [rhs.shape[i] for i in dn.rhs_spec[2:]]
    cin = rhs.shape[dn.rhs_spec[1]]
    flops = 2 * _size(out) * cin
    for k in k_spatial:
        flops *= k
    return flops


def jaxpr_resources(fn, *args, **kwargs) -> Dict[str, float]:
    jaxpr = jax.make_jaxpr(fn, **kwargs)(*args)
    res = defaultdict(float)

    def walk(jx, mult: float):
        for eqn in jx.eqns:
            prim = eqn.primitive.name
            if prim == "dot_general":
                f = _dot_flops(eqn)
                res["mxu_flops"] += mult * f
                # issue-slot cost: the MXU runs int8 at 4× the int32 rate
                # (the DSP-width analogue — see DESIGN.md §2)
                wid = max(v.aval.dtype.itemsize for v in eqn.invars)
                res["mxu_cost"] += mult * f * wid / 4.0
            elif prim == "conv_general_dilated":
                f = _conv_flops(eqn)
                res["mxu_flops"] += mult * f
                wid = max(v.aval.dtype.itemsize for v in eqn.invars)
                res["mxu_cost"] += mult * f * wid / 4.0
            elif prim in _ELEMENTWISE:
                n = sum(_size(o.aval) for o in eqn.outvars)
                res["vpu_count"] += mult * n
                # lane cost ∝ container width (int16 = 2× int32 throughput)
                wid = max(o.aval.dtype.itemsize for o in eqn.outvars)
                res["vpu_ops"] += mult * n * wid / 4.0
                if prim in _ADD_LIKE:
                    res["add_chain"] += mult * n * wid / 4.0
            elif prim in ("reduce_sum", "reduce_max", "reduce_min",
                          "reduce_prod", "cumsum", "cumlogsumexp",
                          "argmax", "argmin"):
                n = sum(_size(v.aval) for v in eqn.invars)
                res["vpu_ops"] += mult * n
                res["add_chain"] += mult * n
            elif prim in _MEMORY_OPS:
                res["mem_move_bytes"] += mult * sum(
                    _bytes(o.aval) for o in eqn.outvars)
            res["temp_bytes"] += mult * sum(
                _bytes(o.aval) for o in eqn.outvars)
            # recurse
            sub_mult = mult
            if prim == "scan":
                sub_mult = mult * eqn.params.get("length", 1)
            elif prim == "pallas_call":
                gm = eqn.params.get("grid_mapping")
                grid = 1
                for g in getattr(gm, "grid", ()) or ():
                    if isinstance(g, int):
                        sub_mult *= g
                        grid *= g
                # per-grid-step VMEM working set of the kernel as traced:
                # operands staged whole + one output tile (capacity —
                # max across kernels, not additive)
                staged = (sum(_bytes(v.aval) for v in eqn.invars)
                          + sum(_bytes(o.aval) for o in eqn.outvars)
                          / max(grid, 1))
                res["pallas_vmem_bytes"] = max(
                    res.get("pallas_vmem_bytes", 0.0), staged)
            for pname in ("jaxpr", "call_jaxpr"):
                sub = eqn.params.get(pname)
                if sub is None:
                    continue
                inner = getattr(sub, "jaxpr", sub)
                walk(inner, sub_mult)
            if prim == "pjit" and "jaxpr" not in eqn.params:
                sub = eqn.params.get("name")
            if prim == "custom_vjp_call" or prim == "custom_jvp_call":
                sub = eqn.params.get("call_jaxpr")
                if sub is not None:
                    walk(getattr(sub, "jaxpr", sub), mult)

    walk(jaxpr.jaxpr, 1.0)
    res["arg_bytes"] = sum(_bytes(v.aval) for v in jaxpr.jaxpr.invars)
    res["out_bytes"] = sum(_bytes(v.aval) for v in jaxpr.jaxpr.outvars)
    res["hbm_bytes"] = res["arg_bytes"] + res["out_bytes"]
    return dict(res)
