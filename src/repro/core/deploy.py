"""Deployment planner: per-layer precision/block search over a device
catalog (the paper's §4.1-4.2 loop closed end-to-end).

The paper's conclusion promises "a useful tool for FPGA selection and
optimized CNN deployment"; its companion resource-driven flow (arXiv:
2510.02990) and CNN2Gate (arXiv:2004.04641) both iterate *part selection*
and *per-layer precision* until the network fits.  This module is that
workflow on the TPU adaptation:

  1. ``plan_deployment``   — greedy per-layer search over
     (block, data_bits, coeff_bits) under one ``DeviceProfile``'s
     budgets, driven entirely by the fitted resource models.
  2. ``pareto_frontier``   — plans across the whole catalog × candidate
     precisions, filtered to the mutually non-dominated set over
     (predicted utilization ↓, convs/step throughput ↑, quantization
     error vs the float oracle ↓).
  3. ``select_device``     — cheapest catalog part whose plan fits at
     the target utilization.
  4. ``validate_plan``     — execute the plan via ``cnn_forward``
     (bit-exact against ``cnn_forward_ref``), re-trace the deployed
     kernels at the deployed geometry with ``hloscan.jaxpr_resources``,
     and report predicted-vs-measured MSE/MAE/R²/MAPE per budgeted
     resource class (the paper's §4.1 validation metrics).

Demand units: the sweep models predict per *kernel call* at the sweep
image (4·tile_h × tile_w).  A CNN layer issues ``ceil(out_ch/step)·in_ch``
calls per forward (step = 2 for dual-output blocks), each over the
deployed image, so per-layer rate demand scales by calls × the grid-step
ratio (img_h/sweep_h · img_w/sweep_w).  ``vmem_bytes`` is a capacity:
calls reuse one BlockSpec working set, evaluated at the deployed
geometry (``synth.vmem_bytes``) and corrected by the fitted model's
ratio at the design point, and a plan takes the max over its layers
(layers run sequentially).
"""

from __future__ import annotations

import dataclasses
import json
import math
from dataclasses import dataclass
from pathlib import Path
from typing import Dict, List, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.blocks import get_block
from repro.configs.paper_conv import SWEEP, ConvSweepConfig
from repro.core import allocate, hloscan, polyfit, synth
from repro.core.allocate import (BUDGET_RESOURCES, BudgetLike, DeviceProfile,
                                 DEVICE_CATALOG)
from repro.core.cnn import (CNNConfig, ConvLayerSpec, cnn_forward,
                            cnn_forward_ref, init_cnn, init_cnn_float)
from repro.kernels import conv2d, ops

# budgeted resources that are rates (additive across layer instances);
# vmem_bytes is the one capacity
RATE_RESOURCES = tuple(r for r in BUDGET_RESOURCES if r != "vmem_bytes")

# per-layer precisions searched when the caller does not pin bits: spans
# the packed dual-conv regime (d+c ≤ 12), the 8-bit baseline, and the
# wide end of the sweep range
DEFAULT_BIT_CANDIDATES: Tuple[Tuple[int, int], ...] = (
    (4, 4), (6, 4), (6, 6), (8, 6), (8, 8), (10, 8), (12, 10))


class DeploymentError(RuntimeError):
    """A CNN (or one of its layers) does not fit a device's budgets."""


# Version of the serialized DeploymentPlan payload.  Bump whenever the
# JSON field semantics change and regenerate tests/golden/plan_golden.json
# (mirrors synth.SWEEP_SCHEMA_VERSION for the sweep cache).
#
# v1 → v2: the CNN-only ``"cnn"`` key became a typed ``"workload"``
# envelope ``{"kind": ..., "spec": ...}`` dispatched through the
# ``repro.runtime.workloads`` registry.  v1 payloads still load — the
# upgrade wraps the embedded CNN spec unchanged, pinned bit-identical
# (same executable-cache keys, same ``plan_config``) by
# tests/golden/plan_v1_golden.json.
PLAN_SCHEMA_VERSION = 2

# schema versions ``from_json`` accepts (older ones upgrade in place)
_READABLE_SCHEMA_VERSIONS = (1, PLAN_SCHEMA_VERSION)


@dataclass(frozen=True)
class LayerAssignment:
    """One layer's planned execution: block + precision + its predicted
    per-layer demand in the device budget units."""
    index: int
    block: str
    data_bits: int
    coeff_bits: int
    calls: int                     # kernel calls per forward pass
    demand: Dict[str, float]       # per-layer predicted demand


@dataclass
class DeploymentPlan:
    device: DeviceProfile
    target: float
    layers: Tuple[LayerAssignment, ...]
    demand: Dict[str, float]       # plan totals (Σ rates, max vmem)
    usage_pct: Dict[str, float]    # demand / device budget, percent
    convs_per_step: float          # plane convolutions per kernel call
    feasible: bool = True
    quant_error: Optional[float] = None   # filled by quantization_error
    cnn: Optional[CNNConfig] = None       # the planned network (CNN plans)
    #: typed non-CNN workload spec (``runtime.workloads.WorkloadSpec``).
    #: CNN plans keep using ``cnn`` (and leave this None) so v1-era
    #: callers and the v1→v2 upgrade stay bit-identical; exactly one of
    #: ``cnn``/``workload`` is set on a planner-produced plan.
    workload: Optional[object] = None

    @property
    def max_usage_pct(self) -> float:
        return max(self.usage_pct.values())

    def block_names(self) -> List[str]:
        return [a.block for a in self.layers]

    def bits(self) -> List[Tuple[int, int]]:
        return [(a.data_bits, a.coeff_bits) for a in self.layers]

    # -- serialization (the durable deployment artifact) -----------------
    #
    # A plan embeds everything a runtime needs: the device it was planned
    # for, the per-layer (block, bits) assignment with predicted demand,
    # AND the network geometry (``cnn``) — so ``to_json`` on one machine
    # and ``repro.runtime`` on another reproduces the exact deployment.

    def to_json(self, *, indent: Optional[int] = 2) -> str:
        """Versioned JSON payload; ``from_json`` round-trips it exactly
        (schema pinned by tests/golden/plan_golden.json).  The network
        itself is a typed ``workload`` envelope: CNN plans wrap their
        ``cnn`` config as kind ``"cnn"``, other workloads serialize
        their registered ``WorkloadSpec``."""
        # lazy: runtime.workloads imports this module (and importing it
        # registers the built-in workload kinds)
        from repro.runtime import workloads as _wl
        workload = None
        if self.workload is not None:
            workload = {"kind": self.workload.kind,
                        "spec": self.workload.to_payload()}
        elif self.cnn is not None:
            workload = {"kind": "cnn",
                        "spec": _wl.CNNWorkloadSpec(self.cnn).to_payload()}
        payload = {
            "version": PLAN_SCHEMA_VERSION,
            "device": {
                "name": self.device.name,
                "budgets": {r: float(v)
                            for r, v in sorted(self.device.budgets.items())},
                "cost": float(self.device.cost),
                "description": self.device.description,
            },
            "target": float(self.target),
            "layers": [{
                "index": int(a.index),
                "block": a.block,
                "data_bits": int(a.data_bits),
                "coeff_bits": int(a.coeff_bits),
                "calls": int(a.calls),
                "demand": {r: float(v) for r, v in sorted(a.demand.items())},
            } for a in self.layers],
            "demand": {r: float(v) for r, v in sorted(self.demand.items())},
            "usage_pct": {r: float(v)
                          for r, v in sorted(self.usage_pct.items())},
            "convs_per_step": float(self.convs_per_step),
            "feasible": bool(self.feasible),
            "quant_error": (None if self.quant_error is None
                            else float(self.quant_error)),
            "workload": workload,
        }
        return json.dumps(payload, indent=indent, sort_keys=True)

    @classmethod
    def from_json(cls, text: str) -> "DeploymentPlan":
        """Parse a versioned plan payload.  v2 is the native schema;
        v1 payloads (the CNN-only era) upgrade in place — the embedded
        ``"cnn"`` spec loads into ``plan.cnn`` exactly as it always
        did, so executable-cache keys and ``plan_config`` output are
        bit-identical across the bump (pinned by the v1 golden)."""
        from repro.runtime import workloads as _wl
        payload = json.loads(text)
        version = payload.get("version")
        if version not in _READABLE_SCHEMA_VERSIONS:
            raise ValueError(
                f"deployment plan schema version {version!r} != supported "
                f"{PLAN_SCHEMA_VERSION} (readable: "
                f"{_READABLE_SCHEMA_VERSIONS}) — re-plan with this repro "
                f"version (plans are not migrated across unknown schema "
                f"bumps)")
        dev = payload["device"]
        device = DeviceProfile(
            name=dev["name"], budgets=dict(dev["budgets"]),
            cost=dev["cost"], description=dev.get("description", ""))
        layers = tuple(LayerAssignment(
            index=int(a["index"]), block=a["block"],
            data_bits=int(a["data_bits"]), coeff_bits=int(a["coeff_bits"]),
            calls=int(a["calls"]), demand=dict(a["demand"]))
            for a in payload["layers"])
        cnn = None
        workload = None
        if version == 1:
            if payload.get("cnn") is not None:
                cnn = _wl.CNNWorkloadSpec.from_payload(payload["cnn"]).cnn
        elif payload.get("workload") is not None:
            w = payload["workload"]
            spec = _wl.get_workload(w["kind"]).from_payload(w["spec"])
            if w["kind"] == "cnn":
                cnn = spec.cnn     # CNN plans keep the legacy field
            else:
                workload = spec
        return cls(device=device, target=payload["target"], layers=layers,
                   demand=dict(payload["demand"]),
                   usage_pct=dict(payload["usage_pct"]),
                   convs_per_step=payload["convs_per_step"],
                   feasible=payload["feasible"],
                   quant_error=payload["quant_error"], cnn=cnn,
                   workload=workload)

    def save(self, path: Union[str, Path]) -> Path:
        path = Path(path)
        path.write_text(self.to_json() + "\n")
        return path

    @classmethod
    def load(cls, path: Union[str, Path]) -> "DeploymentPlan":
        return cls.from_json(Path(path).read_text())


def device_profile(name: str) -> DeviceProfile:
    """Look up a catalog part by name (``"edge"`` / ``"v5e"`` / ``"v5p"``).

    Fleet configs and launch flags reference profiles as strings; an
    unknown name raises ``DeploymentError`` with the available catalog
    spelled out, instead of the bare ``KeyError`` of
    ``allocate.get_device`` — a typo in a fleet topology should read as
    a deployment problem, not a dict miss."""
    try:
        return allocate.get_device(name)
    except KeyError:
        raise DeploymentError(
            f"unknown device profile {name!r}; the catalog has: "
            f"{sorted(d.name for d in DEVICE_CATALOG)}") from None


def _as_device(device: Optional[BudgetLike]) -> DeviceProfile:
    if device is None:
        return allocate.V5E
    if isinstance(device, DeviceProfile):
        return device
    return DeviceProfile(name="custom", budgets=dict(device))


def layer_calls(block, in_channels: int, out_channels: int) -> int:
    """Kernel calls per forward for one layer: dual-output blocks cover
    output channels two per call (odd tail still costs a call)."""
    blk = get_block(block)
    step = 2 if blk.dual_output else 1
    return math.ceil(out_channels / step) * in_channels


def predict_layer_demand(bm: allocate.BlockModels, block, data_bits: int,
                         coeff_bits: int, spec: ConvLayerSpec, img_h: int,
                         img_w: int, *, tile_h: int = 16,
                         sweep: ConvSweepConfig = SWEEP) -> Dict[str, float]:
    """Predicted whole-layer demand from the per-call fitted models (see
    the module docstring for the scaling rules)."""
    blk = get_block(block)
    per_call = bm.demand(blk.name, data_bits, coeff_bits)
    calls = layer_calls(blk, spec.in_channels, spec.out_channels)
    sweep_h, sweep_w = 4 * sweep.tile_h, sweep.tile_w
    geom = (img_h / sweep_h) * (img_w / sweep_w)
    out = {r: per_call[r] * calls * geom for r in RATE_RESOURCES}
    n_out = 2 if blk.dual_output else 1
    dep = synth.vmem_bytes(img_h, img_w, tile_h, data_bits, coeff_bits, n_out)
    ref = synth.vmem_bytes(sweep_h, sweep_w, sweep.tile_h, data_bits,
                           coeff_bits, n_out)
    out["vmem_bytes"] = per_call["vmem_bytes"] * dep / max(ref, 1.0)
    return out


def _layer_candidates(spec: ConvLayerSpec, bm: allocate.BlockModels,
                      bit_candidates) -> List[Tuple[str, int, int]]:
    """Search space for one layer.  A spec with an explicit ``block`` is
    fully user-pinned — block AND bits are taken verbatim, the planner
    never overrides them (the caller handles a pin the models don't
    cover)."""
    if spec.block is not None:
        return [(get_block(spec.block).name, spec.data_bits,
                 spec.coeff_bits)]
    bits = [(spec.data_bits, spec.coeff_bits)] if bit_candidates is None \
        else list(dict.fromkeys(tuple(b) for b in bit_candidates))
    out = []
    for name in sorted(bm.models):
        blk = get_block(name)
        out.extend((name, d, c) for d, c in bits if blk.supports(d, c))
    return out


def plan_deployment(cfg: CNNConfig, bm: allocate.BlockModels,
                    device: Optional[BudgetLike] = None, *,
                    bit_candidates=None, target: float = 0.8,
                    tile_h: int = 16,
                    on_infeasible: str = "raise") -> DeploymentPlan:
    """Greedy per-layer assignment under one device's budgets.

    Layers are assigned in order; each takes the candidate that fits the
    remaining budget and maximizes, lexicographically: precision
    (data+coeff bits), convolutions/step, then lowest budget-normalized
    demand — i.e. the highest-quality, highest-throughput assignment
    that still fits.  ``bit_candidates=None`` pins every layer to its
    spec's bits (block search only); a sequence of (data, coeff) pairs
    opens the per-layer precision search.  ``on_infeasible="fallback"``
    assigns the least-demanding candidate instead of raising and marks
    the plan ``feasible=False``.
    """
    if on_infeasible not in ("raise", "fallback"):
        raise ValueError(f"on_infeasible={on_infeasible!r}")
    dev = _as_device(device)
    budgets = {r: float(dev.budgets[r]) for r in BUDGET_RESOURCES}
    remaining = {r: target * budgets[r] for r in RATE_RESOURCES}
    vmem_cap = target * budgets["vmem_bytes"]
    eps = 1e-9

    assignments: List[LayerAssignment] = []
    feasible = True
    for i, spec in enumerate(cfg.layers):
        if spec.block is not None \
                and get_block(spec.block).name not in bm.models:
            if on_infeasible == "raise":
                raise DeploymentError(
                    f"layer {i} pins block {spec.block!r} but the fitted "
                    f"models only cover {sorted(bm.models)}")
            # an explicit pin wins unconditionally (the seed contract
            # choose_blocks preserves) even when the sweep never modeled
            # the block; its demand is unknown, so the plan cannot claim
            # feasibility
            name = get_block(spec.block).name
            assignments.append(LayerAssignment(
                index=i, block=name, data_bits=spec.data_bits,
                coeff_bits=spec.coeff_bits,
                calls=layer_calls(name, spec.in_channels,
                                  spec.out_channels),
                demand={r: 0.0 for r in BUDGET_RESOURCES}))
            feasible = False
            continue
        best = None
        best_key = None
        cheapest = None                # least over-budget, for fallback
        cheapest_over = float("inf")
        for name, d, c in _layer_candidates(spec, bm, bit_candidates):
            demand = predict_layer_demand(bm, name, d, c, spec,
                                          cfg.img_h, cfg.img_w,
                                          tile_h=tile_h)
            # overflow as a fraction of the device budget, so bytes and
            # rates are comparable when picking the least-bad candidate
            over = max(
                max((demand[r] - remaining[r]) / budgets[r]
                    for r in RATE_RESOURCES),
                (demand["vmem_bytes"] - vmem_cap) / budgets["vmem_bytes"])
            norm = sum(demand[r] / budgets[r] for r in RATE_RESOURCES)
            if over < cheapest_over:
                cheapest, cheapest_over = (name, d, c, demand), over
            if over > eps:
                continue
            key = (d + c, bm.convs[name], -norm, name)
            if best_key is None or key > best_key:
                best, best_key = (name, d, c, demand), key
        if best is None:
            if cheapest is None:
                raise DeploymentError(
                    f"layer {i}: no (block, bits) candidate at all — "
                    f"fitted models cover {sorted(bm.models)}")
            if on_infeasible == "raise":
                cname, cd, cc, cdem = cheapest
                caps = dict(remaining, vmem_bytes=vmem_cap)
                worst = max(cdem, key=lambda r: (cdem[r] - caps[r])
                            / budgets[r])
                raise DeploymentError(
                    f"layer {i} ({spec.in_channels}→{spec.out_channels}ch)"
                    f" does not fit device {dev.name!r} at target "
                    f"{target:.0%}: least-demanding candidate "
                    f"{cname}@d{cd}/c{cc} exceeds the remaining "
                    f"{worst!r} budget by {cheapest_over:.1%} of the "
                    f"device budget")
            best = cheapest
            feasible = False
        name, d, c, demand = best
        for r in RATE_RESOURCES:
            remaining[r] = max(0.0, remaining[r] - demand[r])
        assignments.append(LayerAssignment(
            index=i, block=name, data_bits=d, coeff_bits=c,
            calls=layer_calls(name, spec.in_channels, spec.out_channels),
            demand=demand))

    totals = {r: sum(a.demand[r] for a in assignments)
              for r in RATE_RESOURCES}
    totals["vmem_bytes"] = max(
        (a.demand["vmem_bytes"] for a in assignments), default=0.0)
    usage = {r: 100.0 * totals[r] / budgets[r] for r in BUDGET_RESOURCES}
    plane_convs = sum(s.in_channels * s.out_channels for s in cfg.layers)
    total_calls = sum(a.calls for a in assignments)
    return DeploymentPlan(
        device=dev, target=target, layers=tuple(assignments),
        demand=totals, usage_pct=usage,
        convs_per_step=plane_convs / max(total_calls, 1),
        feasible=feasible, cnn=cfg)


def plan_config(plan: DeploymentPlan,
                cfg: Optional[CNNConfig] = None) -> CNNConfig:
    """The plan baked back into a runnable config: each layer spec gets
    the planned block and bits (shift and channels are unchanged).
    ``cfg`` defaults to the network the plan was made for (``plan.cnn``
    — always present on planner output and serialized plans)."""
    if cfg is None:
        cfg = plan.cnn
    if cfg is None:
        if plan.workload is not None:
            raise ValueError(
                f"plan carries a {plan.workload.kind!r} workload, not a "
                f"CNN — use runtime.workloads (e.g. moe_plan_spec / "
                f"compile_plan) instead of plan_config")
        raise ValueError("plan carries no CNNConfig; pass cfg explicitly")
    specs = tuple(dataclasses.replace(spec, block=a.block,
                                      data_bits=a.data_bits,
                                      coeff_bits=a.coeff_bits)
                  for spec, a in zip(cfg.layers, plan.layers))
    return dataclasses.replace(cfg, layers=specs)


# ---------------------------------------------------------------------------
# quantization error vs the float oracle
# ---------------------------------------------------------------------------

def _conv3x3_f32(x2d, w3x3):
    """Float 'same'-padded 3×3 convolution (the float twin of
    ref.conv2d_3x3_ref)."""
    xp = jnp.pad(x2d, 1)
    h, w = x2d.shape
    return sum(w3x3[di, dj] * xp[di:di + h, dj:dj + w]
               for di in range(3) for dj in range(3))


def _float_forward(float_params, x, cfg: CNNConfig):
    """Float mirror of ``cnn_forward_ref``: same per-layer 2^-shift
    rescale and [0, 2^(d-1)-1] clamp, but no rounding or integer
    containers — the quantization-free oracle."""
    act = x
    for spec, w in zip(cfg.layers, float_params):
        h, wd, cin = act.shape
        acc = jnp.zeros((spec.out_channels, h, wd), jnp.float32)
        for oc in range(spec.out_channels):
            for ic in range(cin):
                acc = acc.at[oc].add(_conv3x3_f32(act[:, :, ic], w[oc, ic]))
        hi = (1 << (spec.data_bits - 1)) - 1
        act = jnp.clip(acc / (1 << spec.shift), 0.0, hi).transpose(1, 2, 0)
    return act


def quantization_error(cfg: CNNConfig, *, key=None, seed: int = 0) -> float:
    """Relative RMSE of the quantized CNN against its float oracle on a
    deterministic probe image (per-plan Pareto axis)."""
    key = key if key is not None else jax.random.PRNGKey(0)
    float_params = init_cnn_float(key, cfg)
    params = [ops.quantize_fixed(w, spec.coeff_bits)
              for w, spec in zip(float_params, cfg.layers)]
    rng = np.random.default_rng(seed)
    d0 = cfg.layers[0].data_bits
    hi0 = (1 << (d0 - 1)) - 1
    xf = jnp.asarray(
        rng.uniform(0, hi0, (cfg.img_h, cfg.img_w,
                             cfg.layers[0].in_channels)), jnp.float32)
    xq = ops.quantize_fixed(xf, d0)
    yq = cnn_forward_ref(params, xq, cfg).astype(jnp.float32)
    yf = _float_forward(float_params, xf, cfg)
    num = float(jnp.sqrt(jnp.mean((yq - yf) ** 2)))
    den = float(jnp.sqrt(jnp.mean(yf ** 2)))
    return num / max(den, 1e-9)


# ---------------------------------------------------------------------------
# Pareto frontier + device selection
# ---------------------------------------------------------------------------

def _dominates(a: DeploymentPlan, b: DeploymentPlan) -> bool:
    """a dominates b over (utilization ↓, convs/step ↑, quant error ↓)."""
    ge = (a.max_usage_pct <= b.max_usage_pct
          and a.convs_per_step >= b.convs_per_step
          and (a.quant_error or 0.0) <= (b.quant_error or 0.0))
    gt = (a.max_usage_pct < b.max_usage_pct
          or a.convs_per_step > b.convs_per_step
          or (a.quant_error or 0.0) < (b.quant_error or 0.0))
    return ge and gt


def pareto_filter(plans: Sequence[DeploymentPlan]) -> List[DeploymentPlan]:
    return [p for p in plans
            if not any(_dominates(q, p) for q in plans if q is not p)]


def pareto_frontier(cfg: CNNConfig, bm: allocate.BlockModels,
                    devices: Optional[Sequence[DeviceProfile]] = None, *,
                    bit_candidates=DEFAULT_BIT_CANDIDATES,
                    target: float = 0.8,
                    measure_error: bool = True) -> List[DeploymentPlan]:
    """Feasible plans across the catalog: one mixed-precision searched
    plan per device plus one uniform-precision plan per (device, bit
    candidate), Pareto-filtered over (max utilization, convs/step,
    quantization error).  Infeasible (device, precision) combinations
    are silently skipped — an empty result means nothing in the catalog
    fits."""
    devices = tuple(devices if devices is not None else DEVICE_CATALOG)
    bit_candidates = tuple(bit_candidates or ())
    plans: List[DeploymentPlan] = []
    seen = set()
    for dev in devices:
        trials = [dict(bit_candidates=bit_candidates or None)]
        trials += [dict(bit_candidates=(bits,)) for bits in bit_candidates]
        for kw in trials:
            try:
                plan = plan_deployment(cfg, bm, dev, target=target, **kw)
            except DeploymentError:
                continue
            key = (dev.name, tuple(plan.block_names()), tuple(plan.bits()))
            if key not in seen:
                seen.add(key)
                plans.append(plan)
    if measure_error:
        cache: Dict[tuple, float] = {}
        for plan in plans:
            k = tuple(plan.bits())
            if k not in cache:
                cache[k] = quantization_error(plan_config(plan, cfg))
            plan.quant_error = cache[k]
    return pareto_filter(plans)


def select_device(cfg: CNNConfig, bm: allocate.BlockModels,
                  catalog: Optional[Sequence[DeviceProfile]] = None, *,
                  bit_candidates=None, target: float = 0.8
                  ) -> Tuple[DeviceProfile, DeploymentPlan]:
    """Cheapest catalog part whose plan fits at the target utilization."""
    catalog = sorted(catalog if catalog is not None else DEVICE_CATALOG,
                     key=lambda d: d.cost)
    failures = []
    for dev in catalog:
        try:
            return dev, plan_deployment(cfg, bm, dev, target=target,
                                        bit_candidates=bit_candidates)
        except DeploymentError as e:
            failures.append(f"{dev.name}: {e}")
    raise DeploymentError(
        "no device in the catalog fits the network:\n  "
        + "\n  ".join(failures))


# ---------------------------------------------------------------------------
# predicted-vs-measured validation (paper §4.1)
# ---------------------------------------------------------------------------

@dataclass
class PlanValidation:
    predicted: Dict[str, np.ndarray]   # resource → per-layer vector
    measured: Dict[str, np.ndarray]
    metrics: Dict[str, Dict[str, float]]   # resource → mse/mae/r2/mape_pct
    bit_exact: bool
    quant_error: float


def measure_layer_resources(plan: DeploymentPlan, cfg: CNNConfig, *,
                            tile_h: int = 16) -> Dict[str, np.ndarray]:
    """Re-trace every planned layer's kernel at the *deployed* geometry
    with the jaxpr op census and aggregate exactly like the predictor:
    per-call trace × calls for rates; for vmem, the staged-operand
    working set the trace actually exposes (``pallas_vmem_bytes``) —
    measured from the kernel's own avals, independent of the analytic
    ``synth.vmem_bytes`` formula the models were fitted on."""
    measured = {r: np.zeros(len(plan.layers)) for r in BUDGET_RESOURCES}
    for i, a in enumerate(plan.layers):
        blk = get_block(a.block)
        x = jnp.zeros((cfg.img_h, cfg.img_w),
                      conv2d.container_dtype(a.data_bits))
        wk = jnp.zeros(blk.weight_shape(a.coeff_bits),
                       conv2d.container_dtype(a.coeff_bits))
        res = hloscan.jaxpr_resources(
            lambda p, q, _a=a, _b=blk: _b.apply(
                p, q, data_bits=_a.data_bits, coeff_bits=_a.coeff_bits,
                tile_h=tile_h), x, wk)
        for r in RATE_RESOURCES:
            measured[r][i] = float(res.get(r, 0.0)) * a.calls
        measured["vmem_bytes"][i] = float(res["pallas_vmem_bytes"])
    return measured


def validate_plan(plan: DeploymentPlan, cfg: CNNConfig, *,
                  key=None, seed: int = 0,
                  tile_h: int = 16) -> PlanValidation:
    """Close the loop: run the plan bit-exactly and score the resource
    models against a fresh trace of the deployed kernels."""
    key = key if key is not None else jax.random.PRNGKey(0)
    pcfg = plan_config(plan, cfg)

    # execute via the batched forward, bit-exact vs the integer oracle
    params = init_cnn(key, pcfg)
    rng = np.random.default_rng(seed)
    d0 = pcfg.layers[0].data_bits
    x = ops.quantize_fixed(
        jnp.asarray(rng.integers(0, (1 << (d0 - 1)),
                                 (pcfg.img_h, pcfg.img_w,
                                  pcfg.layers[0].in_channels)),
                    jnp.float32), d0)
    y = cnn_forward(params, x, pcfg, plan.block_names())
    yr = cnn_forward_ref(params, x, pcfg)
    bit_exact = bool(jnp.all(y == yr))

    predicted = {r: np.array([a.demand[r] for a in plan.layers])
                 for r in BUDGET_RESOURCES}
    measured = measure_layer_resources(plan, cfg, tile_h=tile_h)
    metrics = {r: polyfit.error_metrics(measured[r], predicted[r])
               for r in BUDGET_RESOURCES}
    return PlanValidation(
        predicted=predicted, measured=measured, metrics=metrics,
        bit_exact=bit_exact,
        quant_error=quantization_error(pcfg, key=key, seed=seed))
