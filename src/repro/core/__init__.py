# The paper's primary contribution: configurable convolution blocks +
# resource-prediction models (synthesis-free design-space exploration),
# adapted FPGA→TPU.  See DESIGN.md §2.
from repro.core import hloscan

__all__ = ["hloscan"]
