"""The "synthesis" sweep (paper §3.2), FPGA→TPU.

For every block × (data_bits, coeff_bits) ∈ [3..16]² — 196 configurations
per block, 784 total — trace the Pallas kernel and extract its resource
vector with the jaxpr op census (core/hloscan.py).  This is the analogue of
running Vivado synthesis per configuration and scraping the utilization
report; results are cached to JSON so downstream analyses (correlation,
model fitting, allocation) never re-trace.

Resource classes and their FPGA counterparts:

  vpu_ops        ↔ LLUT   (elementwise combinational work)
  add_chain      ↔ CChain (accumulation adds)
  mxu_flops      ↔ DSP    (dot/conv MACs)
  mem_move_bytes ↔ MLUT   (distributed-memory movement)
  temp_bytes     ↔ FF     (live intermediate storage)
  hbm_bytes      ↔ BRAM   (block-memory traffic)
  vmem_bytes     — the Pallas BlockSpec working set (VMEM footprint)
"""

from __future__ import annotations

import json
from pathlib import Path
from typing import Dict, List

import jax.numpy as jnp
import numpy as np

from repro.blocks import BlockLike, get_block
from repro.configs.paper_conv import ConvSweepConfig, SWEEP
from repro.core import hloscan
from repro.kernels import conv2d

RESOURCES = ["vpu_ops", "add_chain", "mxu_cost", "mxu_flops",
             "mem_move_bytes", "temp_bytes", "hbm_bytes", "vmem_bytes"]

_FPGA_NAME = {
    "vpu_ops": "LLUT", "add_chain": "CChain", "mxu_cost": "DSP",
    "mxu_flops": "DSP_raw", "mem_move_bytes": "MLUT", "temp_bytes": "FF",
    "hbm_bytes": "BRAM", "vmem_bytes": "VMEM",
}


def fpga_name(resource: str) -> str:
    return _FPGA_NAME.get(resource, resource)


def vmem_bytes(img_h: int, img_w: int, tile_h: int, data_bits: int,
               coeff_bits: int, n_out: int) -> float:
    """Analytic BlockSpec working set: padded image + weights + out tile.

    The padded image is staged into VMEM in its *data container* dtype
    (int8 ≤ 8 bits, else int16 — kernels widen per-tile), so the image
    term scales with ``d_item``, the datapath-width ∝ memory effect the
    paper measures; weights likewise use the coeff container, while the
    int32 output tile is width-independent.  Geometry-parameterized so
    the deployment planner (core/deploy.py) can evaluate the working set
    at the deployed image size, not just the sweep image."""
    d_item = 1 if data_bits <= 8 else 2
    c_item = 1 if coeff_bits <= 8 else 2
    img = (img_h + 2) * (img_w + 2) * d_item   # container-width pad
    wk = n_out * 9 * c_item
    out = n_out * tile_h * img_w * 4
    return float(img + wk + out)


def _vmem_bytes(cfg: ConvSweepConfig, data_bits: int, coeff_bits: int,
                n_out: int) -> float:
    # sweep image: 4 row-tiles high, one tile wide
    return vmem_bytes(4 * cfg.tile_h, cfg.tile_w, cfg.tile_h,
                      data_bits, coeff_bits, n_out)


def synth_one(block: BlockLike, data_bits: int, coeff_bits: int,
              cfg: ConvSweepConfig = SWEEP) -> Dict[str, float]:
    """Trace one registered block at one design point; all block
    properties (weight shape, convs/step, packing) come from the
    ``ConvBlock`` registry entry, not re-derived from the name."""
    blk = get_block(block)
    h, w = 4 * cfg.tile_h, cfg.tile_w
    x = jnp.zeros((h, w), conv2d.container_dtype(data_bits))
    wk = jnp.zeros(blk.weight_shape(coeff_bits),
                   conv2d.container_dtype(coeff_bits))

    res = hloscan.jaxpr_resources(
        lambda a, b: blk.apply(a, b, data_bits=data_bits,
                               coeff_bits=coeff_bits, tile_h=cfg.tile_h),
        x, wk)
    out = {k: float(res.get(k, 0.0)) for k in RESOURCES if k != "vmem_bytes"}
    out["vmem_bytes"] = _vmem_bytes(cfg, data_bits, coeff_bits,
                                    2 if blk.dual_output else 1)
    out["convs_per_step"] = float(blk.convs_per_step)
    out["packed"] = float(blk.packed_ok(data_bits, coeff_bits))
    return out


# bump when row semantics change (e.g. the _vmem_bytes container-width
# model) so pre-existing caches regenerate instead of silently serving
# stale numbers; legacy bare-list caches count as version 0
SWEEP_SCHEMA_VERSION = 2


def run_sweep(cfg: ConvSweepConfig = SWEEP,
              cache_path: str | Path = "benchmarks/_cache/synth.json",
              force: bool = False) -> List[dict]:
    cache = Path(cache_path)
    if cache.exists() and not force:
        payload = json.loads(cache.read_text())
        if (isinstance(payload, dict)
                and payload.get("version") == SWEEP_SCHEMA_VERSION):
            return payload["rows"]
        # stale or pre-versioning cache → fall through and re-sweep
    rows = []
    for block in cfg.blocks:
        blk = get_block(block)
        for d in cfg.data_bits:
            for c in cfg.coeff_bits:
                row = {"block": blk.name, "data_bits": d, "coeff_bits": c}
                row.update(synth_one(blk, d, c, cfg))
                rows.append(row)
    cache.parent.mkdir(parents=True, exist_ok=True)
    cache.write_text(json.dumps({"version": SWEEP_SCHEMA_VERSION,
                                 "rows": rows}))
    return rows


def sweep_arrays(rows: List[dict], block: str):
    """(d, c, {resource: y}) numpy arrays for one block."""
    sel = [r for r in rows if r["block"] == block]
    d = np.array([r["data_bits"] for r in sel], float)
    c = np.array([r["coeff_bits"] for r in sel], float)
    ys = {k: np.array([r[k] for r in sel], float) for k in RESOURCES}
    return d, c, ys
