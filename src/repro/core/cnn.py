"""Fixed-point CNN built on the paper's convolution-block library.

This is the deployment story of the paper closed end-to-end: a small CNN
whose every 3×3 layer is executed by one of the four parameterizable
blocks, with the block TYPE chosen *by the fitted resource models* (the
Table-5 allocator) under a per-platform budget — exactly the "model-driven
block selection" workflow of §4.2.

Numerics: power-of-two fixed-point. Activations and weights are quantized
to (data_bits, coeff_bits); accumulation is exact int32; each layer
rescales by a right-shift and clamps back into the activation range
(ReLU folded into the clamp).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import allocate, synth
from repro.kernels import conv2d
from repro.kernels import ops


@dataclass(frozen=True)
class ConvLayerSpec:
    in_channels: int
    out_channels: int
    data_bits: int = 8
    coeff_bits: int = 8
    shift: int = 7                 # post-accumulation right-shift
    block: Optional[str] = None    # None → allocator decides


@dataclass
class CNNConfig:
    layers: Tuple[ConvLayerSpec, ...]
    img_h: int = 32
    img_w: int = 128


def choose_blocks(cfg: CNNConfig, rows=None,
                  budgets=None) -> List[str]:
    """Model-driven block selection (paper §4.2): for each layer pick the
    block that maximizes convolutions/step-per-resource under the fitted
    models — conv pairs go to dual-output blocks while the MXU budget
    lasts, the rest to Conv1 (logic) / Conv2 (single-MXU)."""
    rows = rows if rows is not None else synth.run_sweep()
    bm = allocate.BlockModels.fit(rows)
    budgets = dict(budgets or allocate.V5E_BUDGETS)
    chosen = []
    remaining = {k: v * 0.8 for k, v in budgets.items()}
    for spec in cfg.layers:
        if spec.block is not None:
            chosen.append(spec.block)
            continue
        best, best_score = "conv1", -1.0
        for b in ("conv4", "conv3", "conv2", "conv1"):
            demand = bm.demand(b, spec.data_bits, spec.coeff_bits)
            if any(demand[r] > remaining[r] for r in demand):
                continue
            score = bm.convs[b] / (1e-12 + sum(
                demand[r] / budgets[r] for r in demand))
            if score > best_score:
                best, best_score = b, score
        demand = bm.demand(best, spec.data_bits, spec.coeff_bits)
        for r in demand:
            remaining[r] = max(0.0, remaining[r] - demand[r])
        chosen.append(best)
    return chosen


def init_cnn(key, cfg: CNNConfig):
    params = []
    for i, spec in enumerate(cfg.layers):
        k = jax.random.fold_in(key, i)
        w = jax.random.normal(
            k, (spec.out_channels, spec.in_channels, 3, 3), jnp.float32)
        scale = (1 << (spec.coeff_bits - 2)) / 3.0
        params.append(ops.quantize_fixed(w * scale, spec.coeff_bits))
    return params


def _run_block_conv(block, x2d, w2d, spec):
    y = ops.conv_block(block, x2d, w2d, data_bits=spec.data_bits,
                       coeff_bits=spec.coeff_bits)
    return y


def cnn_forward(params, x, cfg: CNNConfig, blocks: List[str]):
    """x: (H, W, C_in) quantized ints.  Returns (H, W, C_out) of the last
    layer.  Each (out_ch, in_ch) plane runs through its assigned block;
    dual-output blocks (conv3/conv4) process two output channels per call
    — the paper's 2-convolutions-per-DSP win, visible as half the calls.
    """
    act = x
    for spec, w, block in zip(cfg.layers, params, blocks):
        h, wd, cin = act.shape
        acc = jnp.zeros((spec.out_channels, h, wd), jnp.int32)
        dual = block in ("conv3", "conv4")
        step = 2 if dual else 1
        for oc in range(0, spec.out_channels, step):
            for ic in range(cin):
                x2d = act[:, :, ic]
                if dual:
                    oc2 = min(oc + 1, spec.out_channels - 1)
                    w2 = jnp.stack([w[oc, ic], w[oc2, ic]])
                    y = _run_block_conv(block, x2d, w2, spec)
                    acc = acc.at[oc].add(y[0])
                    if oc2 != oc:
                        acc = acc.at[oc2].add(y[1])
                else:
                    y = _run_block_conv(block, x2d, w[oc, ic], spec)
                    acc = acc.at[oc].add(y)
        # rescale + ReLU + requantize
        lo, hi = 0, (1 << (spec.data_bits - 1)) - 1
        act = jnp.clip(acc >> spec.shift, lo, hi) \
            .astype(conv2d.container_dtype(spec.data_bits)) \
            .transpose(1, 2, 0)
    return act


def cnn_forward_ref(params, x, cfg: CNNConfig):
    """Float-free oracle using the ref conv (exact same integer math)."""
    from repro.kernels import ref
    act = x
    for spec, w in zip(cfg.layers, params):
        h, wd, cin = act.shape
        acc = jnp.zeros((spec.out_channels, h, wd), jnp.int32)
        for oc in range(spec.out_channels):
            for ic in range(cin):
                acc = acc.at[oc].add(
                    ref.conv2d_3x3_ref(act[:, :, ic], w[oc, ic]))
        lo, hi = 0, (1 << (spec.data_bits - 1)) - 1
        act = jnp.clip(acc >> spec.shift, lo, hi) \
            .astype(conv2d.container_dtype(spec.data_bits)) \
            .transpose(1, 2, 0)
    return act
