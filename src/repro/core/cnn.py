"""Fixed-point CNN built on the paper's convolution-block library.

This is the deployment story of the paper closed end-to-end: a small CNN
whose every 3×3 layer is executed by one of the parameterizable blocks
from the ``repro.blocks`` registry, with the block chosen *by the fitted
resource models* (the Table-5 allocator) under a per-platform budget —
exactly the "model-driven block selection" workflow of §4.2.

The hot path is ``cnn_forward``: each layer runs through
``ConvBlock.apply_batched``, which convolves all (out_ch, in_ch) planes
in ONE jitted/vmapped kernel call.  ``cnn_forward_loop`` keeps the seed's
O(out_ch·in_ch) per-plane dispatch as the benchmark baseline and a
cross-check; both are bit-exact against ``cnn_forward_ref``.

Numerics: power-of-two fixed-point. Activations and weights are quantized
to (data_bits, coeff_bits); accumulation is exact int32; each layer
rescales by a right-shift and clamps back into the activation range
(ReLU folded into the clamp).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.blocks import BlockLike, ConvBlock, get_block
from repro.core import allocate, synth
from repro.kernels import conv2d
from repro.kernels import ops


@dataclass(frozen=True)
class ConvLayerSpec:
    in_channels: int
    out_channels: int
    data_bits: int = 8
    coeff_bits: int = 8
    shift: int = 7                 # post-accumulation right-shift
    block: Optional[str] = None    # registry name; None → allocator decides


@dataclass
class CNNConfig:
    layers: Tuple[ConvLayerSpec, ...]
    img_h: int = 32
    img_w: int = 128


def quickstart_cnn_config() -> CNNConfig:
    """The quickstart CNN (examples/cnn_blocks.py and the batched-vs-loop
    benchmark share this single definition)."""
    return CNNConfig(layers=(
        ConvLayerSpec(1, 8, data_bits=8, coeff_bits=6),
        ConvLayerSpec(8, 8, data_bits=8, coeff_bits=6),
        ConvLayerSpec(8, 4, data_bits=6, coeff_bits=4),
    ), img_h=32, img_w=128)


def choose_blocks(cfg: CNNConfig, rows=None,
                  budgets=None) -> List[ConvBlock]:
    """Model-driven block selection (paper §4.2), now a thin wrapper over
    the deployment planner (``repro.core.deploy``): each layer gets the
    block the fitted models pick under the device budget at the layer's
    spec bits.  An explicit ``ConvLayerSpec.block`` wins unconditionally,
    and — matching the seed contract — selection never fails: a network
    that overflows the device falls back to the least-demanding block
    per overflowing layer instead of raising.  Use
    ``deploy.plan_deployment`` directly for strict budget enforcement,
    precision search, and the full plan (demand, utilization,
    predicted-vs-measured validation)."""
    from repro.core import deploy
    rows = rows if rows is not None else synth.run_sweep()
    bm = allocate.BlockModels.fit(rows)
    plan = deploy.plan_deployment(cfg, bm, budgets, target=0.8,
                                  on_infeasible="fallback")
    return [get_block(a.block) for a in plan.layers]


def init_cnn_float(key, cfg: CNNConfig):
    """Per-layer float weight draws *before* coefficient quantization —
    shared by ``init_cnn`` and the deployment planner's float oracle
    (``deploy.quantization_error``), so the quantized network and its
    quantization-free twin always start from the same weights."""
    params = []
    for i, spec in enumerate(cfg.layers):
        k = jax.random.fold_in(key, i)
        w = jax.random.normal(
            k, (spec.out_channels, spec.in_channels, 3, 3), jnp.float32)
        scale = (1 << (spec.coeff_bits - 2)) / 3.0
        params.append(w * scale)
    return params


def init_cnn(key, cfg: CNNConfig):
    return [ops.quantize_fixed(w, spec.coeff_bits)
            for w, spec in zip(init_cnn_float(key, cfg), cfg.layers)]


def _requantize(acc, spec: ConvLayerSpec):
    """Rescale + ReLU + requantize one layer's int32 accumulator
    ((out_ch, H, W)) back into the (H, W, out_ch) activation range."""
    lo, hi = 0, (1 << (spec.data_bits - 1)) - 1
    return jnp.clip(acc >> spec.shift, lo, hi) \
        .astype(conv2d.container_dtype(spec.data_bits)) \
        .transpose(1, 2, 0)


def cnn_forward(params, x, cfg: CNNConfig, blocks: Sequence[BlockLike]):
    """x: (H, W, C_in) quantized ints.  Returns (H, W, C_out) of the last
    layer.  Each layer is ONE ``apply_batched`` call — all (out_ch,
    in_ch) planes through the assigned block's kernel in a single jitted
    vmap; dual-output blocks pair output channels, keeping the paper's
    2-convolutions-per-step semantics."""
    act = x
    for spec, w, block in zip(cfg.layers, params, blocks):
        blk = get_block(block)
        acc = blk.apply_batched(act, w, data_bits=spec.data_bits,
                                coeff_bits=spec.coeff_bits)
        act = _requantize(acc, spec)
    return act


def cnn_forward_loop(params, x, cfg: CNNConfig,
                     blocks: Sequence[BlockLike]):
    """Seed-era baseline: one Python-level kernel dispatch per
    (out_ch, in_ch) plane.  Kept for the batched-vs-loop benchmark
    (benchmarks/cnn_forward_bench.py) and as a cross-check; prefer
    ``cnn_forward``."""
    act = x
    for spec, w, block in zip(cfg.layers, params, blocks):
        blk = get_block(block)
        h, wd, cin = act.shape
        acc = jnp.zeros((spec.out_channels, h, wd), jnp.int32)
        step = 2 if blk.dual_output else 1
        for oc in range(0, spec.out_channels, step):
            for ic in range(cin):
                x2d = act[:, :, ic]
                if blk.dual_output:
                    oc2 = min(oc + 1, spec.out_channels - 1)
                    w2 = jnp.stack([w[oc, ic], w[oc2, ic]])
                    y = blk.apply(x2d, w2, data_bits=spec.data_bits,
                                  coeff_bits=spec.coeff_bits)
                    acc = acc.at[oc].add(y[0])
                    if oc2 != oc:
                        acc = acc.at[oc2].add(y[1])
                else:
                    y = blk.apply(x2d, w[oc, ic],
                                  data_bits=spec.data_bits,
                                  coeff_bits=spec.coeff_bits)
                    acc = acc.at[oc].add(y)
        act = _requantize(acc, spec)
    return act


def cnn_forward_ref(params, x, cfg: CNNConfig):
    """Float-free oracle using the ref conv (exact same integer math)."""
    from repro.kernels import ref
    act = x
    for spec, w in zip(cfg.layers, params):
        h, wd, cin = act.shape
        acc = jnp.zeros((spec.out_channels, h, wd), jnp.int32)
        for oc in range(spec.out_channels):
            for ic in range(cin):
                acc = acc.at[oc].add(
                    ref.conv2d_3x3_ref(act[:, :, ic], w[oc, ic]))
        act = _requantize(acc, spec)
    return act
