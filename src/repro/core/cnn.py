"""Fixed-point CNN built on the paper's convolution-block library.

This is the deployment story of the paper closed end-to-end: a small CNN
whose every 3×3 layer is executed by one of the parameterizable blocks
from the ``repro.blocks`` registry, with the block chosen *by the fitted
resource models* (the Table-5 allocator) under a per-platform budget —
exactly the "model-driven block selection" workflow of §4.2.

The hot path is ``cnn_forward``: each layer runs through
``ConvBlock.apply_batched``, which convolves all (out_ch, in_ch) planes
in ONE jitted/vmapped kernel call.  It is batch-first: ``x`` may be one
(H, W, C) image or a whole (N, H, W, C) batch — the serving path of
``repro.serve.cnn_engine`` — and stays one compiled executable per
layer either way, with optional data-parallel sharding of the batch
dimension over a device mesh (``mesh=``).  ``cnn_forward_loop`` keeps
the seed's O(out_ch·in_ch) per-plane dispatch as the benchmark baseline
and a cross-check; everything is bit-exact against ``cnn_forward_ref``.

Numerics: power-of-two fixed-point. Activations and weights are quantized
to (data_bits, coeff_bits); accumulation is exact int32; each layer
rescales by a right-shift and clamps back into the activation range
(ReLU folded into the clamp).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.blocks import BIT_RANGE, BlockLike, ConvBlock, get_block
from repro.core import allocate, synth
from repro.kernels import conv2d
from repro.kernels import ops


@dataclass(frozen=True)
class ConvLayerSpec:
    in_channels: int
    out_channels: int
    data_bits: int = 8
    coeff_bits: int = 8
    shift: int = 7                 # post-accumulation right-shift
    block: Optional[str] = None    # registry name; None → allocator decides

    def __post_init__(self):
        # validate bit widths at construction (the seed let coeff_bits < 2
        # through and ``init_cnn_float`` then raised on a negative shift
        # count deep inside the weight draw)
        lo, hi = BIT_RANGE
        for name in ("data_bits", "coeff_bits"):
            bits = getattr(self, name)
            if not lo <= bits <= hi:
                raise ValueError(
                    f"ConvLayerSpec.{name}={bits} outside the supported "
                    f"block bit range {BIT_RANGE}")
        if self.shift < 0:
            raise ValueError(f"ConvLayerSpec.shift={self.shift} must be ≥ 0")
        if self.in_channels < 1 or self.out_channels < 1:
            raise ValueError(
                f"ConvLayerSpec needs ≥ 1 channel, got "
                f"{self.in_channels}→{self.out_channels}")


@dataclass
class CNNConfig:
    layers: Tuple[ConvLayerSpec, ...]
    img_h: int = 32
    img_w: int = 128


def quickstart_cnn_config() -> CNNConfig:
    """The quickstart CNN (examples/cnn_blocks.py and the batched-vs-loop
    benchmark share this single definition)."""
    return CNNConfig(layers=(
        ConvLayerSpec(1, 8, data_bits=8, coeff_bits=6),
        ConvLayerSpec(8, 8, data_bits=8, coeff_bits=6),
        ConvLayerSpec(8, 4, data_bits=6, coeff_bits=4),
    ), img_h=32, img_w=128)


# fitted-model memo for the default sweep, keyed on the sweep schema
# version: repeated planning/serving calls (choose_blocks, the CNN serve
# engine, benchmarks) share ONE multi-second sweep + fit per process; a
# SWEEP_SCHEMA_VERSION bump naturally invalidates the entry
_FITTED_MODELS: Dict[int, allocate.BlockModels] = {}


def fitted_block_models(rows=None) -> allocate.BlockModels:
    """``BlockModels`` for the block library.  Explicit ``rows`` are
    fitted directly (caller owns the sweep); ``rows=None`` serves the
    process-wide memoized fit of the default sweep."""
    if rows is not None:
        return allocate.BlockModels.fit(rows)
    key = synth.SWEEP_SCHEMA_VERSION
    if key not in _FITTED_MODELS:
        _FITTED_MODELS[key] = allocate.BlockModels.fit(synth.run_sweep())
    return _FITTED_MODELS[key]


def clear_fitted_model_cache() -> None:
    """Drop the memoized default-sweep fit (tests / custom registries)."""
    _FITTED_MODELS.clear()


def choose_blocks(cfg: CNNConfig, rows=None,
                  budgets=None) -> List[ConvBlock]:
    """Model-driven block selection (paper §4.2), now a thin wrapper over
    the deployment planner (``repro.core.deploy``): each layer gets the
    block the fitted models pick under the device budget at the layer's
    spec bits.  An explicit ``ConvLayerSpec.block`` wins unconditionally,
    and — matching the seed contract — selection never fails: a network
    that overflows the device falls back to the least-demanding block
    per overflowing layer instead of raising.  The default sweep's
    fitted models are memoized (``fitted_block_models``), so repeated
    calls don't re-pay the sweep.  Use ``deploy.plan_deployment``
    directly for strict budget enforcement, precision search, and the
    full plan (demand, utilization, predicted-vs-measured validation)."""
    from repro.core import deploy
    bm = fitted_block_models(rows)
    plan = deploy.plan_deployment(cfg, bm, budgets, target=0.8,
                                  on_infeasible="fallback")
    return [get_block(a.block) for a in plan.layers]


def init_cnn_float(key, cfg: CNNConfig):
    """Per-layer float weight draws *before* coefficient quantization —
    shared by ``init_cnn`` and the deployment planner's float oracle
    (``deploy.quantization_error``), so the quantized network and its
    quantization-free twin always start from the same weights."""
    params = []
    for i, spec in enumerate(cfg.layers):
        k = jax.random.fold_in(key, i)
        w = jax.random.normal(
            k, (spec.out_channels, spec.in_channels, 3, 3), jnp.float32)
        # float power keeps the formula total over every validated width
        # (the seed's ``1 << (coeff_bits - 2)`` raised on coeff_bits < 2)
        scale = 2.0 ** (spec.coeff_bits - 2) / 3.0
        params.append(w * scale)
    return params


def init_cnn(key, cfg: CNNConfig):
    return [ops.quantize_fixed(w, spec.coeff_bits)
            for w, spec in zip(init_cnn_float(key, cfg), cfg.layers)]


def _requantize(acc, spec: ConvLayerSpec):
    """Rescale + ReLU + requantize one layer's int32 accumulator —
    (out_ch, H, W) or (N, out_ch, H, W) — back into the channels-last
    activation range."""
    lo, hi = 0, (1 << (spec.data_bits - 1)) - 1
    return jnp.moveaxis(
        jnp.clip(acc >> spec.shift, lo, hi)
        .astype(conv2d.container_dtype(spec.data_bits)), -3, -1)


def cnn_forward(params, x, cfg: CNNConfig, blocks: Sequence[BlockLike],
                *, mesh=None):
    """.. deprecated:: as a serving entry point — prefer
    ``repro.runtime.CompiledCNN`` (AOT batch-bucketed executables, plan
    construction, no per-call re-threading of cfg/params/blocks/mesh).
    The signature is kept verbatim: this remains the jit-traceable
    functional core that ``CompiledCNN`` compiles per layer, and the
    oracle-adjacent path ``deploy.validate_plan`` executes.

    x: (H, W, C_in) quantized ints, or an (N, H, W, C_in) image batch.
    Returns the last layer's (H, W, C_out) — or (N, H, W, C_out).  Each
    layer is ONE ``apply_batched`` call — all (out_ch, in_ch) planes (and
    all batch images) through the assigned block in a single jitted
    executable; dual-output blocks pair output channels, keeping the
    paper's 2-convolutions-per-step semantics.

    ``mesh``: optional device mesh for data-parallel serving — every
    layer's batched activation is constrained to the batch sharding from
    ``repro.parallel.sharding.cnn_batch_sharding`` (batch dimension over
    the data axes).  Only meaningful for 4-D inputs under ``jax.jit``
    (the serve engine's step)."""
    sharding = None
    if mesh is not None and x.ndim == 4:
        from repro.parallel.sharding import cnn_batch_sharding
        sharding = cnn_batch_sharding(mesh, x.shape[0])
        x = jax.lax.with_sharding_constraint(x, sharding)
    act = x
    for spec, w, block in zip(cfg.layers, params, blocks):
        blk = get_block(block)
        acc = blk.apply_batched(act, w, data_bits=spec.data_bits,
                                coeff_bits=spec.coeff_bits)
        act = _requantize(acc, spec)
        if sharding is not None:
            act = jax.lax.with_sharding_constraint(act, sharding)
    return act


def cnn_forward_loop(params, x, cfg: CNNConfig,
                     blocks: Sequence[BlockLike]):
    """Seed-era baseline: one Python-level kernel dispatch per
    (out_ch, in_ch) plane.  Kept for the batched-vs-loop benchmark
    (benchmarks/cnn_forward_bench.py) and as a cross-check; prefer
    ``cnn_forward``."""
    act = x
    for spec, w, block in zip(cfg.layers, params, blocks):
        blk = get_block(block)
        h, wd, cin = act.shape
        acc = jnp.zeros((spec.out_channels, h, wd), jnp.int32)
        step = 2 if blk.dual_output else 1
        for oc in range(0, spec.out_channels, step):
            for ic in range(cin):
                x2d = act[:, :, ic]
                if blk.dual_output:
                    oc2 = min(oc + 1, spec.out_channels - 1)
                    w2 = jnp.stack([w[oc, ic], w[oc2, ic]])
                    y = blk.apply(x2d, w2, data_bits=spec.data_bits,
                                  coeff_bits=spec.coeff_bits)
                    acc = acc.at[oc].add(y[0])
                    if oc2 != oc:
                        acc = acc.at[oc2].add(y[1])
                else:
                    y = blk.apply(x2d, w[oc, ic],
                                  data_bits=spec.data_bits,
                                  coeff_bits=spec.coeff_bits)
                    acc = acc.at[oc].add(y)
        act = _requantize(acc, spec)
    return act


def cnn_forward_ref(params, x, cfg: CNNConfig):
    """Float-free oracle using the ref conv (exact same integer math).
    Accepts a single (H, W, C) image or an (N, H, W, C) batch — batches
    run image-by-image through the scalar oracle, so the batched hot
    path is checked against genuinely independent per-image math."""
    from repro.kernels import ref
    if x.ndim == 4:
        return jnp.stack([cnn_forward_ref(params, xi, cfg) for xi in x])
    act = x
    for spec, w in zip(cfg.layers, params):
        h, wd, cin = act.shape
        acc = jnp.zeros((spec.out_channels, h, wd), jnp.int32)
        for oc in range(spec.out_channels):
            for ic in range(cin):
                acc = acc.at[oc].add(
                    ref.conv2d_3x3_ref(act[:, :, ic], w[oc, ic]))
        act = _requantize(acc, spec)
    return act
