"""Algorithm 1 (paper §3.4): polynomial resource models with term pruning.

For each (block, resource): fit bivariate polynomials of degree 1..4 in
(data_bits, coeff_bits); keep — exactly as the paper's pseudocode — the
*lowest* R² that still clears the 0.9 gate (the least-overfitting model
above threshold); drop statistically insignificant terms (OLS t-test) and
keep the pruned model if its R² stays ≥ 0.9.

Conv3 gets the paper's segmented regression: one polynomial per packing
regime (data_bits + coeff_bits ≤ 12 → packed dual-conv; else two-dot
fallback), matching its zero Pearson correlation with data size.

Validation metrics (paper §4.1): MSE (EQM), MAE (EAM), R², MAPE (EAMP).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np
from scipy import stats


def _terms(degree: int) -> List[Tuple[int, int]]:
    return [(i, j) for i in range(degree + 1) for j in range(degree + 1)
            if i + j <= degree]


def _design(d: np.ndarray, c: np.ndarray,
             terms: List[Tuple[int, int]]) -> np.ndarray:
    return np.stack([(d ** i) * (c ** j) for i, j in terms], axis=1)


@dataclass
class PolyModel:
    terms: List[Tuple[int, int]]
    coefs: np.ndarray
    degree: int
    r2: float

    def predict(self, d, c):
        d = np.asarray(d, float)
        c = np.asarray(c, float)
        return _design(np.atleast_1d(d), np.atleast_1d(c),
                       self.terms) @ self.coefs

    def formula(self, target: str = "y") -> str:
        parts = []
        for (i, j), co in zip(self.terms, self.coefs):
            t = f"{co:+.4g}"
            if i:
                t += f"·d{'^' + str(i) if i > 1 else ''}"
            if j:
                t += f"·c{'^' + str(j) if j > 1 else ''}"
            parts.append(t)
        return f"{target} = " + " ".join(parts)


# Segment schemes: known hardware regime boundaries (the paper segments
# Conv3 at its 8-bit DSP-packing limit; our TPU analogues are the int8/int16
# container boundary and the int32-accumulator packing budget d+c ≤ 12).
def _container_seg(d, c):
    return (d > 8).astype(int) * 2 + (c > 8).astype(int)


def _pack_seg(d, c):
    return np.where((d + c) <= 12, 0, 1 + _container_seg(d, c))


SCHEMES = {"container": _container_seg, "pack": _pack_seg}


@dataclass
class SegmentedModel:
    """Piecewise polynomial split at hardware regime boundaries."""
    scheme: str
    models: Dict[int, PolyModel]
    r2: float = 0.0

    def predict(self, d, c):
        d = np.atleast_1d(np.asarray(d, float))
        c = np.atleast_1d(np.asarray(c, float))
        seg = SCHEMES[self.scheme](d, c)
        out = np.empty_like(d)
        default = next(iter(self.models.values()))
        for s in np.unique(seg):
            m = self.models.get(int(s), default)
            mask = seg == s
            out[mask] = m.predict(d[mask], c[mask])
        return out


def r_squared(y, yhat) -> float:
    ss_res = float(np.sum((y - yhat) ** 2))
    ss_tot = float(np.sum((y - np.mean(y)) ** 2))
    if ss_tot < 1e-12:
        return 1.0 if ss_res < 1e-9 else 0.0
    return 1.0 - ss_res / ss_tot


def fit_poly(d, c, y, degree: int,
             terms: Optional[List[Tuple[int, int]]] = None) -> PolyModel:
    terms = terms if terms is not None else _terms(degree)
    X = _design(d, c, terms)
    coefs, *_ = np.linalg.lstsq(X, y, rcond=None)
    return PolyModel(terms, coefs, degree, r_squared(y, X @ coefs))


def prune_insignificant(model: PolyModel, d, c, y,
                        alpha: float = 0.05) -> PolyModel:
    """Drop terms whose OLS t-test p-value exceeds alpha, then refit.
    The intercept is always kept."""
    X = _design(d, c, model.terms)
    n, k = X.shape
    if n <= k:
        return model
    resid = y - X @ model.coefs
    dof = n - k
    sigma2 = float(resid @ resid) / max(dof, 1)
    xtx_inv = np.linalg.pinv(X.T @ X)
    se = np.sqrt(np.maximum(np.diag(xtx_inv) * sigma2, 1e-30))
    tvals = model.coefs / se
    pvals = 2 * (1 - stats.t.cdf(np.abs(tvals), dof))
    keep = [t for t, p in zip(model.terms, pvals)
            if p <= alpha or t == (0, 0)]
    if len(keep) == len(model.terms) or not keep:
        return model
    return fit_poly(d, c, y, model.degree, terms=keep)


def algorithm1(d, c, y, *, r2_gate: float = 0.9,
               max_degree: int = 4) -> PolyModel:
    """Paper Algorithm 1, verbatim: among degrees 1..4, keep the model with
    the smallest R² that is still ≥ the 0.9 gate; prune insignificant
    terms; keep the pruned model if it stays above the gate.  Falls back to
    the best-R² model when nothing clears the gate."""
    best: Optional[PolyModel] = None
    best_r2 = 1.0 + 1e-9
    fallback: Optional[PolyModel] = None
    for degree in range(1, max_degree + 1):
        m = fit_poly(d, c, y, degree)
        if fallback is None or m.r2 > fallback.r2:
            fallback = m
        if r2_gate <= m.r2 < best_r2:
            best, best_r2 = m, m.r2
    if best is None:
        best = fallback
    pruned = prune_insignificant(best, d, c, y)
    if pruned.r2 >= r2_gate:
        best = pruned
    return best


def fit_segmented(d, c, y, scheme: str = "container", **kw) -> SegmentedModel:
    seg_ids = SCHEMES[scheme](d, c)
    models = {}
    for s in np.unique(seg_ids):
        mask = seg_ids == s
        models[int(s)] = algorithm1(d[mask], c[mask], y[mask], **kw)
    seg = SegmentedModel(scheme, models)
    seg.r2 = r_squared(y, seg.predict(d, c))
    return seg


def fit_auto(d, c, y, *, block: str = "", r2_gate: float = 0.9):
    """The paper's end-to-end model choice: plain polynomial when it clears
    the R² gate, otherwise segmented at the block's regime boundaries."""
    m = algorithm1(d, c, y, r2_gate=r2_gate)
    if m.r2 >= r2_gate:
        return m
    scheme = "pack" if block == "conv3" else "container"
    return fit_segmented(d, c, y, scheme=scheme, r2_gate=r2_gate)


def error_metrics(y, yhat) -> Dict[str, float]:
    err = y - yhat
    nz = np.abs(y) > 1e-9
    mape = float(np.mean(np.abs(err[nz] / y[nz])) * 100) if nz.any() else 0.0
    return {"mse": float(np.mean(err ** 2)),
            "mae": float(np.mean(np.abs(err))),
            "r2": r_squared(y, yhat),
            "mape_pct": mape}
