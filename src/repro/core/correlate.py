"""Pearson correlation analysis (paper §3.3, Table 3).

Correlates (data_bits, coeff_bits) against each resource class per block,
and resources against each other — the step that decides which model family
Algorithm 1 fits (linear-polynomial vs segmented)."""

from __future__ import annotations

from typing import Dict, List

import numpy as np

from repro.core.synth import RESOURCES, sweep_arrays


def pearson(a: np.ndarray, b: np.ndarray) -> float:
    sa, sb = np.std(a), np.std(b)
    if sa < 1e-12 or sb < 1e-12:
        return 0.0
    return float(np.corrcoef(a, b)[0, 1])


def correlation_table(rows: List[dict], block: str) -> Dict:
    """Paper Table 3 analogue for one block: every resource vs the two
    input parameters and vs every other resource."""
    d, c, ys = sweep_arrays(rows, block)
    out = {}
    names = [r for r in RESOURCES if np.std(ys[r]) > 1e-12]
    for r in names:
        entry = {"data_bits": pearson(d, ys[r]),
                 "coeff_bits": pearson(c, ys[r])}
        for r2 in names:
            if r2 == r:
                break
            entry[r2] = pearson(ys[r], ys[r2])
        out[r] = entry
    return out


def choose_model_family(corr_entry: Dict[str, float]) -> str:
    """Paper §3.3: strong linear correlation → plain polynomial; a
    zero/weak correlation with one input (Conv3's packing regime) →
    segmented regression."""
    cd = abs(corr_entry.get("data_bits", 0.0))
    cc = abs(corr_entry.get("coeff_bits", 0.0))
    if min(cd, cc) < 0.3 and max(cd, cc) < 0.65:
        return "segmented"
    return "polynomial"
