"""Workload registry: typed ``WorkloadSpec``s behind ``DeploymentPlan``.

A deployment plan used to *be* a CNN plan — ``ConvLayerSpec`` was wired
through the planner, the AOT runtime, the gateway, and the fleet.  This
module is the seam that breaks that coupling: a plan now carries a
typed, versioned **workload spec** (schema v2), and every layer above
the kernels speaks the spec's protocol instead of assuming images:

``WorkloadSpec``     the protocol: a frozen, JSON-round-trippable
                     description of *what* is being served (network
                     geometry + per-layer quantization), with a
                     ``compile`` hook that builds the matching
                     ``CompiledModel`` backend for a plan.
``register_workload``/``get_workload``/``list_workloads``
                     the kind → spec-class registry ``DeploymentPlan``
                     serialization dispatches through.
``CNNWorkloadSpec``  wraps the embedded ``CNNConfig`` — v1 plans
                     upgrade to this spec bit-identically.
``MoEWorkloadSpec``  quantized mixture-of-experts inference: expert
                     weights fake-quantized to the plan's coeff_bits
                     grid (``models.moe.quantize_moe_params``),
                     activations to data_bits, validated against
                     ``moe_layer_dense_ref`` the way ``validate_plan``
                     re-traces conv kernels.
``compile_plan``     one call from any plan to its AOT executor —
                     the entry point the serving engines use, so
                     ``CNNEngine``/``AsyncCNNGateway``/``Fleet`` are
                     plan-type-blind.
``plan_moe_deployment``
                     the per-layer (bits) search under a
                     ``DeviceProfile``'s budgets for MoE workloads —
                     the same greedy predict-then-deploy loop as
                     ``deploy.plan_deployment``, driven by an analytic
                     demand model (matmul MACs, quantized weight
                     bytes, expert-buffer working set).

A request payload for an MoE plan is one ``(seq_len, d_model)`` float32
block of token activations (the per-request analogue of an image); the
compiled forward runs ``num_layers`` residual MoE layers over the
bucketed batch.  All ``CompiledModel`` machinery — bucket ladder, AOT
warmup, ``ExecutableCache`` sharing, chunking, ``should_abort`` — is
inherited, so MoE plans serve through exactly the same gateway code
paths as CNNs.
"""

from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple, Type

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import MoEConfig
from repro.core.allocate import BUDGET_RESOURCES
from repro.core.cnn import CNNConfig, ConvLayerSpec
from repro.core.deploy import (DEFAULT_BIT_CANDIDATES, DeploymentError,
                               DeploymentPlan, LayerAssignment, _as_device,
                               device_profile)
from repro.models import moe as moe_mod
from repro.models.layers import split_keys
from repro.runtime.compiled import CompiledModel, ExecutableCache

#: registry block name for an MoE layer's assignment (LayerAssignment
#: .block is a string either way; conv blocks come from repro.blocks,
#: MoE layers are all the one batched expert-FFN kernel)
MOE_BLOCK_NAME = "moe_ffn"

#: rate resources (additive across layers); vmem_bytes is the capacity
_RATE_RESOURCES = tuple(r for r in BUDGET_RESOURCES if r != "vmem_bytes")


# ---------------------------------------------------------------------------
# the protocol + registry
# ---------------------------------------------------------------------------

class WorkloadSpec:
    """What a ``DeploymentPlan`` deploys, as a typed value.

    Implementations are frozen dataclasses with a ``kind`` class
    attribute and three obligations:

    * ``to_payload()`` / ``from_payload(payload)`` — an exact JSON
      round-trip (the plan schema embeds the payload under
      ``workload.spec``; goldens pin it).
    * ``compile(plan, ...)`` — build the ``CompiledModel`` backend that
      executes ``plan`` (same keyword surface as
      ``CompiledCNN.from_plan`` so the serving layers stay generic).
    * value semantics — ``==`` must hold across a round-trip (the
      golden-fixture tests rely on it).

    Register implementations with ``register_workload`` so
    ``DeploymentPlan.from_json`` can dispatch on ``kind``.
    """

    kind: str = "workload"

    def to_payload(self) -> dict:
        raise NotImplementedError

    @classmethod
    def from_payload(cls, payload: dict) -> "WorkloadSpec":
        raise NotImplementedError

    def compile(self, plan, *, params=None, key=None, max_batch: int = 16,
                mesh=None, warmup: bool = True,
                exec_cache: Optional[ExecutableCache] = None
                ) -> CompiledModel:
        raise NotImplementedError


_WORKLOADS: Dict[str, Type[WorkloadSpec]] = {}


def register_workload(cls: Type[WorkloadSpec]) -> Type[WorkloadSpec]:
    """Class decorator: make ``cls`` the spec for its ``kind``."""
    kind = cls.kind
    if not kind or kind == WorkloadSpec.kind:
        raise ValueError(f"{cls.__name__} must define a concrete kind")
    if kind in _WORKLOADS and _WORKLOADS[kind] is not cls:
        raise ValueError(f"workload kind {kind!r} already registered "
                         f"by {_WORKLOADS[kind].__name__}")
    _WORKLOADS[kind] = cls
    return cls


def get_workload(kind: str) -> Type[WorkloadSpec]:
    try:
        return _WORKLOADS[kind]
    except KeyError:
        raise ValueError(
            f"unknown workload kind {kind!r}; registered: "
            f"{sorted(_WORKLOADS)}") from None


def list_workloads() -> List[str]:
    return sorted(_WORKLOADS)


def workload_spec(plan: DeploymentPlan) -> WorkloadSpec:
    """The typed spec of any plan: the ``workload`` field when present,
    else the embedded ``CNNConfig`` wrapped as a ``CNNWorkloadSpec``
    (every v1 plan and every planner-produced CNN plan)."""
    if plan.workload is not None:
        return plan.workload
    if plan.cnn is not None:
        return CNNWorkloadSpec(cnn=plan.cnn)
    raise ValueError(
        "plan carries neither a workload spec nor a CNNConfig — it "
        "cannot be compiled (re-plan, or attach a spec)")


def compile_plan(plan: DeploymentPlan, *, params=None, key=None,
                 max_batch: int = 16, mesh=None, warmup: bool = True,
                 exec_cache: Optional[ExecutableCache] = None
                 ) -> CompiledModel:
    """Any plan → its AOT batch-bucketed executor, dispatched through
    the workload registry.  This is the one construction path the
    serving layers use — ``CNNEngine.from_plan``, ``AsyncCNNGateway.
    register_plan`` and the fleet all stay plan-type-blind."""
    return workload_spec(plan).compile(
        plan, params=params, key=key, max_batch=max_batch, mesh=mesh,
        warmup=warmup, exec_cache=exec_cache)


# ---------------------------------------------------------------------------
# CNN: the legacy workload, wrapped
# ---------------------------------------------------------------------------

@register_workload
@dataclass(frozen=True)
class CNNWorkloadSpec(WorkloadSpec):
    """The convolution workload: exactly the network the v1 schema
    embedded as ``plan.cnn`` — the upgrade path wraps it unchanged, so
    executable-cache keys and ``plan_config`` are bit-identical across
    the v1 → v2 bump."""

    cnn: CNNConfig
    kind = "cnn"

    def to_payload(self) -> dict:
        return {
            "img_h": int(self.cnn.img_h),
            "img_w": int(self.cnn.img_w),
            "layers": [{
                "in_channels": int(s.in_channels),
                "out_channels": int(s.out_channels),
                "data_bits": int(s.data_bits),
                "coeff_bits": int(s.coeff_bits),
                "shift": int(s.shift),
                "block": s.block,
            } for s in self.cnn.layers],
        }

    @classmethod
    def from_payload(cls, payload: dict) -> "CNNWorkloadSpec":
        return cls(cnn=CNNConfig(
            layers=tuple(ConvLayerSpec(
                in_channels=int(s["in_channels"]),
                out_channels=int(s["out_channels"]),
                data_bits=int(s["data_bits"]),
                coeff_bits=int(s["coeff_bits"]),
                shift=int(s["shift"]), block=s["block"])
                for s in payload["layers"]),
            img_h=int(payload["img_h"]), img_w=int(payload["img_w"])))

    def compile(self, plan, *, params=None, key=None, max_batch: int = 16,
                mesh=None, warmup: bool = True,
                exec_cache: Optional[ExecutableCache] = None
                ) -> CompiledModel:
        from repro.runtime.compiled import CompiledCNN
        return CompiledCNN.from_plan(
            plan, self.cnn, params=params, key=key, max_batch=max_batch,
            mesh=mesh, warmup=warmup, exec_cache=exec_cache)


# ---------------------------------------------------------------------------
# MoE: quantized mixture-of-experts inference
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class MoELayerSpec:
    """One MoE layer's geometry + planned quantization.  The typed
    per-layer spec the v2 plan schema carries for MoE workloads (the
    analogue of ``ConvLayerSpec``)."""
    d_ff_expert: int
    num_experts: int
    top_k: int
    data_bits: int = 8             # activation fake-quant grid
    coeff_bits: int = 8            # expert-weight fake-quant grid
    n_shared_experts: int = 0
    capacity_factor: float = 2.0

    def __post_init__(self):
        if self.top_k < 1 or self.top_k > self.num_experts:
            raise ValueError(
                f"top_k={self.top_k} must be in [1, num_experts="
                f"{self.num_experts}]")
        for name in ("data_bits", "coeff_bits"):
            v = getattr(self, name)
            if not 2 <= v <= 16:
                raise ValueError(f"{name}={v} outside [2, 16]")


@register_workload
@dataclass(frozen=True)
class MoEWorkloadSpec(WorkloadSpec):
    """A stack of residual MoE layers serving ``(seq_len, d_model)``
    float32 token blocks — one block per request, the MoE analogue of
    one image."""

    layers: Tuple[MoELayerSpec, ...]
    d_model: int
    seq_len: int = 32
    act: str = "silu"
    mlp_gated: bool = True
    kind = "moe"

    def __post_init__(self):
        if not self.layers:
            raise ValueError("MoE workload needs at least one layer")
        if self.d_model < 1 or self.seq_len < 1:
            raise ValueError(
                f"d_model={self.d_model} and seq_len={self.seq_len} "
                f"must be ≥ 1")

    def to_payload(self) -> dict:
        return {
            "d_model": int(self.d_model),
            "seq_len": int(self.seq_len),
            "act": self.act,
            "mlp_gated": bool(self.mlp_gated),
            "layers": [{
                "d_ff_expert": int(s.d_ff_expert),
                "num_experts": int(s.num_experts),
                "top_k": int(s.top_k),
                "data_bits": int(s.data_bits),
                "coeff_bits": int(s.coeff_bits),
                "n_shared_experts": int(s.n_shared_experts),
                "capacity_factor": float(s.capacity_factor),
            } for s in self.layers],
        }

    @classmethod
    def from_payload(cls, payload: dict) -> "MoEWorkloadSpec":
        return cls(
            layers=tuple(MoELayerSpec(
                d_ff_expert=int(s["d_ff_expert"]),
                num_experts=int(s["num_experts"]),
                top_k=int(s["top_k"]),
                data_bits=int(s["data_bits"]),
                coeff_bits=int(s["coeff_bits"]),
                n_shared_experts=int(s["n_shared_experts"]),
                capacity_factor=float(s["capacity_factor"]))
                for s in payload["layers"]),
            d_model=int(payload["d_model"]),
            seq_len=int(payload["seq_len"]),
            act=payload["act"], mlp_gated=bool(payload["mlp_gated"]))

    def compile(self, plan, *, params=None, key=None, max_batch: int = 16,
                mesh=None, warmup: bool = True,
                exec_cache: Optional[ExecutableCache] = None
                ) -> CompiledModel:
        return CompiledMoE.from_plan(
            plan, params=params, key=key, max_batch=max_batch, mesh=mesh,
            warmup=warmup, exec_cache=exec_cache)

    # -- model-config shim + params --------------------------------------
    def layer_cfg(self, i: int) -> "_MoELayerModelCfg":
        """The config view ``models.moe`` expects, for layer ``i``."""
        s = self.layers[i]
        return _MoELayerModelCfg(
            moe=MoEConfig(num_experts=s.num_experts, top_k=s.top_k,
                          d_ff_expert=s.d_ff_expert,
                          n_shared_experts=s.n_shared_experts,
                          capacity_factor=s.capacity_factor),
            d_model=self.d_model, act=self.act, mlp_gated=self.mlp_gated)

    def init_params(self, key, *, quantized: bool = True) -> list:
        """Per-layer ``init_moe`` draws (float32), expert weights
        fake-quantized to each layer's ``coeff_bits`` grid unless
        ``quantized=False`` (the float oracle draw)."""
        ks = split_keys(key, len(self.layers))
        out = []
        for i, s in enumerate(self.layers):
            p = moe_mod.init_moe(ks[i], self.layer_cfg(i))
            out.append(moe_mod.quantize_moe_params(p, s.coeff_bits)
                       if quantized else p)
        return out


@dataclass(frozen=True)
class _MoELayerModelCfg:
    """The slice of ``configs.base.ModelConfig`` that ``models.moe``
    reads, so a workload spec can drive ``moe_layer`` without
    fabricating a whole transformer config.  Serving runs float32 on
    the flat (single-group, hint-free) path — deterministic on CPU."""
    moe: MoEConfig
    d_model: int
    act: str = "silu"
    mlp_gated: bool = True
    moe_groups: int = 1
    moe_shard_hints: bool = False
    moe_combine_shardmap: bool = False

    @property
    def jnp_dtype(self):
        return jnp.float32


def _fake_quant(x, bits: int):
    """Symmetric ``bits``-bit fake quantization with a dynamic
    **per-token** scale: each token's max magnitude maps to
    ``2^(bits-1) - 1`` levels — the activation-side twin of
    ``quantize_moe_params``.  Per-token (not per-tensor) scaling is
    what makes bucketed dispatch sound: a token's quantization grid
    never depends on which batch — or how much padding — it shares a
    dispatch with, so padding to a bucket cannot perturb real
    outputs."""
    hi = float((1 << (bits - 1)) - 1)
    s = hi / jnp.maximum(
        jnp.max(jnp.abs(x), axis=-1, keepdims=True), 1e-6)
    return jnp.round(x * s) / s


class CompiledMoE(CompiledModel):
    """The quantized-MoE backend: each layer is one AOT-compiled
    residual MoE block — activations fake-quantized to the layer's
    ``data_bits``, expert weights pre-quantized to ``coeff_bits`` —
    bucketed/batched/cached exactly like ``CompiledCNN``."""

    kind = "moe"
    input_noun = "token block"

    def __init__(self, spec: MoEWorkloadSpec, params, *,
                 max_batch: int = 16, mesh=None, warmup: bool = True,
                 exec_cache: Optional[ExecutableCache] = None):
        if len(params) != len(spec.layers):
            raise ValueError(
                f"need one param dict per layer: {len(params)} for "
                f"{len(spec.layers)} layers")
        self.spec = spec
        self.params = list(params)
        self.num_layers = len(spec.layers)
        self.in_shape = (spec.seq_len, spec.d_model)
        self.in_dtype = jnp.float32
        super().__init__(max_batch=max_batch, mesh=mesh, warmup=warmup,
                         exec_cache=exec_cache)

    @classmethod
    def from_plan(cls, plan, *, params=None, key=None,
                  max_batch: int = 16, mesh=None, warmup: bool = True,
                  exec_cache: Optional[ExecutableCache] = None
                  ) -> "CompiledMoE":
        """Executor for a planned MoE deployment: the spec with each
        layer's planned (data_bits, coeff_bits) baked in; ``params``
        default to a fresh quantized ``init_moe`` draw per layer."""
        spec = moe_plan_spec(plan)
        if params is None:
            key = key if key is not None else jax.random.PRNGKey(0)
            params = spec.init_params(key)
        return cls(spec, params, max_batch=max_batch, mesh=mesh,
                   warmup=warmup, exec_cache=exec_cache)

    # -- backend hooks ----------------------------------------------------
    def _layer_key(self, i: int, bucket: int) -> tuple:
        s = self.spec.layers[i]
        return (MOE_BLOCK_NAME, self.spec.d_model, s.d_ff_expert,
                s.num_experts, s.top_k, s.n_shared_experts,
                float(s.capacity_factor), s.data_bits, s.coeff_bits,
                self.spec.seq_len, self.spec.act, self.spec.mlp_gated,
                self._mesh_token, bucket)

    def _layer_fn(self, i: int):
        cfg = self.spec.layer_cfg(i)
        data_bits = self.spec.layers[i].data_bits

        def layer(p, x):
            # residual MoE block over the quantized activation grid;
            # the aux (load-balancing) loss is a training quantity —
            # inference drops it
            y, _aux = moe_mod.moe_layer(p, _fake_quant(x, data_bits), cfg)
            return x + y

        return layer

    def _layer_params(self, i: int):
        return self.params[i]

    def _layer_in_sds(self, i: int, bucket: int) -> jax.ShapeDtypeStruct:
        return jax.ShapeDtypeStruct(
            (bucket, self.spec.seq_len, self.spec.d_model), jnp.float32)

    def _empty_output(self):
        return jnp.zeros((0,) + self.in_shape, jnp.float32)

    # -- workload helpers --------------------------------------------------
    def sample_inputs(self, k: int, seed: int = 0):
        """``k`` random float32 token blocks (unit-normal activations)
        matching this executor's ``(seq_len, d_model)`` contract."""
        rng = np.random.default_rng(seed)
        return [rng.standard_normal(self.in_shape).astype(np.float32)
                for _ in range(k)]

    def validate_input(self, x, request_id: int = 0) -> np.ndarray:
        """Shape + finiteness admission check: token activations must be
        real finite floats (NaN/Inf would propagate through every
        expert); any real dtype is accepted and served as float32."""
        x = np.asarray(x)
        if tuple(x.shape) != tuple(self.in_shape):
            raise ValueError(
                f"request {request_id}: {self.input_noun} shape "
                f"{tuple(x.shape)} != engine input {tuple(self.in_shape)}")
        if not np.issubdtype(x.dtype, np.floating) \
                and not np.issubdtype(x.dtype, np.integer):
            raise ValueError(
                f"request {request_id}: {self.input_noun} dtype {x.dtype} "
                f"is not a real numeric type")
        if not np.all(np.isfinite(x)):
            raise ValueError(
                f"request {request_id}: {self.input_noun} carries "
                f"non-finite values (NaN/Inf) — they would propagate "
                f"through every routed expert")
        return x


# ---------------------------------------------------------------------------
# the MoE planner: per-layer bit search under device budgets
# ---------------------------------------------------------------------------

def moe_layer_demand(spec: MoEWorkloadSpec, layer: MoELayerSpec,
                     data_bits: int, coeff_bits: int) -> Dict[str, float]:
    """Analytic per-request demand of one MoE layer in the device
    budget units: matmul MACs (``mxu_cost``), weight traffic at the
    quantized container width plus activation traffic (``hbm_bytes``),
    elementwise work (``vpu_ops``), and the expert-buffer + one-weight
    working set (``vmem_bytes``, a capacity).  The MoE twin of
    ``deploy.predict_layer_demand`` — analytic rather than sweep-fitted
    because the expert FFN is dense matmul, the regime the roofline
    model is exact in."""
    S, d = spec.seq_len, spec.d_model
    fe, e, k = layer.d_ff_expert, layer.num_experts, layer.top_k
    fs = fe * layer.n_shared_experts
    nmats = 3 if spec.mlp_gated else 2
    routed = S * k                      # expert-token assignments
    mxu = (S * d * e                    # router projection
           + nmats * routed * d * fe    # expert FFN on dispatched tokens
           + nmats * S * d * fs)        # always-on shared experts
    weight_bytes = (nmats * e * d * fe + nmats * d * fs) * coeff_bits / 8
    act_bytes = S * d * data_bits / 8
    vpu = S * (e + k * fe + d)          # softmax + act + combine
    cap = int(max(k, round(layer.capacity_factor * S * k / e)))
    vmem = float(e * cap * d * 4 + e * d * fe * 4)
    return {"mxu_cost": float(mxu),
            "hbm_bytes": float(weight_bytes + act_bytes),
            "vpu_ops": float(vpu), "vmem_bytes": vmem}


def plan_moe_deployment(spec: MoEWorkloadSpec, device=None, *,
                        bit_candidates=DEFAULT_BIT_CANDIDATES,
                        target: float = 0.8,
                        on_infeasible: str = "raise") -> DeploymentPlan:
    """Greedy per-layer (data_bits, coeff_bits) assignment for an MoE
    workload under one device's budgets — ``deploy.plan_deployment``'s
    loop with the analytic MoE demand model.  Each layer takes the
    highest-precision candidate that fits the remaining budget
    (lexicographically: data+coeff bits, then lowest normalized
    demand); ``bit_candidates=None`` pins every layer to its spec's
    bits.  ``on_infeasible="fallback"`` assigns the least-over-budget
    candidate and marks the plan ``feasible=False`` instead of raising.
    The returned plan embeds the spec with assigned bits baked in
    (``plan.workload``) — the MoE analogue of ``plan.cnn``."""
    if on_infeasible not in ("raise", "fallback"):
        raise ValueError(f"on_infeasible={on_infeasible!r}")
    dev = (device_profile(device) if isinstance(device, str)
           else _as_device(device))
    budgets = {r: float(dev.budgets[r]) for r in BUDGET_RESOURCES}
    remaining = {r: target * budgets[r] for r in _RATE_RESOURCES}
    vmem_cap = target * budgets["vmem_bytes"]
    eps = 1e-9

    assignments: List[LayerAssignment] = []
    planned_layers: List[MoELayerSpec] = []
    feasible = True
    for i, layer in enumerate(spec.layers):
        cands = ([(layer.data_bits, layer.coeff_bits)]
                 if bit_candidates is None
                 else list(dict.fromkeys(tuple(b) for b in bit_candidates)))
        best = best_key = None
        cheapest, cheapest_over = None, float("inf")
        for d_bits, c_bits in cands:
            demand = moe_layer_demand(spec, layer, d_bits, c_bits)
            over = max(
                max((demand[r] - remaining[r]) / budgets[r]
                    for r in _RATE_RESOURCES),
                (demand["vmem_bytes"] - vmem_cap) / budgets["vmem_bytes"])
            norm = sum(demand[r] / budgets[r] for r in _RATE_RESOURCES)
            if over < cheapest_over:
                cheapest, cheapest_over = (d_bits, c_bits, demand), over
            if over > eps:
                continue
            key = (d_bits + c_bits, -norm)
            if best_key is None or key > best_key:
                best, best_key = (d_bits, c_bits, demand), key
        if best is None:
            if on_infeasible == "raise":
                d_bits, c_bits, cdem = cheapest
                raise DeploymentError(
                    f"MoE layer {i} (E={layer.num_experts}, "
                    f"ff={layer.d_ff_expert}, k={layer.top_k}) does not "
                    f"fit device {dev.name!r} at target {target:.0%}: "
                    f"least-demanding candidate d{d_bits}/c{c_bits} "
                    f"exceeds the budget by {cheapest_over:.1%}")
            best = cheapest
            feasible = False
        d_bits, c_bits, demand = best
        for r in _RATE_RESOURCES:
            remaining[r] = max(0.0, remaining[r] - demand[r])
        assignments.append(LayerAssignment(
            index=i, block=MOE_BLOCK_NAME, data_bits=d_bits,
            coeff_bits=c_bits, calls=spec.seq_len * layer.top_k,
            demand=demand))
        planned_layers.append(dataclasses.replace(
            layer, data_bits=d_bits, coeff_bits=c_bits))

    totals = {r: sum(a.demand[r] for a in assignments)
              for r in _RATE_RESOURCES}
    totals["vmem_bytes"] = max(
        (a.demand["vmem_bytes"] for a in assignments), default=0.0)
    usage = {r: 100.0 * totals[r] / budgets[r] for r in BUDGET_RESOURCES}
    planned = dataclasses.replace(spec, layers=tuple(planned_layers))
    plan = DeploymentPlan(
        device=dev, target=target, layers=tuple(assignments),
        demand=totals, usage_pct=usage,
        convs_per_step=float(spec.seq_len),    # tokens per request
        feasible=feasible, cnn=None, workload=planned)
    plan.quant_error = moe_quantization_error(planned)
    return plan


def moe_plan_spec(plan: DeploymentPlan) -> MoEWorkloadSpec:
    """The plan baked back into a runnable spec: each layer gets the
    planned (data_bits, coeff_bits) — the MoE analogue of
    ``deploy.plan_config``."""
    spec = workload_spec(plan)
    if not isinstance(spec, MoEWorkloadSpec):
        raise ValueError(
            f"plan carries a {spec.kind!r} workload, not 'moe'")
    if len(spec.layers) != len(plan.layers):
        raise ValueError(
            f"plan has {len(plan.layers)} assignments for "
            f"{len(spec.layers)} spec layers")
    layers = tuple(dataclasses.replace(s, data_bits=a.data_bits,
                                       coeff_bits=a.coeff_bits)
                   for s, a in zip(spec.layers, plan.layers))
    return dataclasses.replace(spec, layers=layers)


# ---------------------------------------------------------------------------
# validation vs the dense oracle (the MoE twin of deploy.validate_plan)
# ---------------------------------------------------------------------------

def _eager_forward(spec: MoEWorkloadSpec, params, x, *,
                   quant_act: bool = True):
    """Un-jitted residual stack over the spec's layers."""
    act = x
    for i in range(len(spec.layers)):
        xi = (_fake_quant(act, spec.layers[i].data_bits)
              if quant_act else act)
        y, _ = moe_mod.moe_layer(params[i], xi, spec.layer_cfg(i))
        act = act + y
    return act


def _dense_ref_forward(spec: MoEWorkloadSpec, params, x):
    """Residual stack through ``moe_layer_dense_ref`` — every expert on
    every token, no capacity drops, no quantization: the float oracle."""
    act = x
    for i in range(len(spec.layers)):
        act = act + moe_mod.moe_layer_dense_ref(
            params[i], act, spec.layer_cfg(i))
    return act


def moe_quantization_error(spec: MoEWorkloadSpec, *, key=None,
                           seed: int = 0) -> float:
    """Relative RMSE of the quantized MoE stack against the float
    dense-reference oracle on a deterministic probe block (the per-plan
    Pareto axis — ``deploy.quantization_error``'s MoE twin)."""
    key = key if key is not None else jax.random.PRNGKey(0)
    float_params = spec.init_params(key, quantized=False)
    quant_params = [moe_mod.quantize_moe_params(p, s.coeff_bits)
                    for p, s in zip(float_params, spec.layers)]
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal(
        (1, spec.seq_len, spec.d_model)), jnp.float32)
    yq = _eager_forward(spec, quant_params, x)
    yf = _dense_ref_forward(spec, float_params, x)
    num = float(jnp.sqrt(jnp.mean((yq - yf) ** 2)))
    den = float(jnp.sqrt(jnp.mean(yf ** 2)))
    return num / max(den, 1e-9)


@dataclass
class MoEPlanValidation:
    """Validation verdict for one MoE plan: the compiled (bucketed,
    AOT) path must match the eager quantized stack, and the quantized
    stack must track the dense float oracle within quantization
    tolerance."""
    compiled_matches_eager: bool
    dense_ref_rel_err: float
    quant_error: float             # the probe-seed Pareto number


def validate_moe_plan(plan: DeploymentPlan, *, key=None, seed: int = 0,
                      max_batch: int = 4, batch: int = 3,
                      atol: float = 1e-5) -> MoEPlanValidation:
    """Close the loop for an MoE plan the way ``deploy.validate_plan``
    does for CNNs: execute the plan through ``CompiledMoE`` (bucketed
    AOT dispatch, including a padded bucket) and check it against the
    un-jitted quantized stack, then score quantization against
    ``moe_layer_dense_ref``."""
    key = key if key is not None else jax.random.PRNGKey(0)
    spec = moe_plan_spec(plan)
    params = spec.init_params(key)
    compiled = CompiledMoE(spec, params, max_batch=max_batch)
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal(
        (batch, spec.seq_len, spec.d_model)), jnp.float32)
    y_compiled = np.asarray(compiled(x))
    y_eager = np.asarray(_eager_forward(spec, params, x))
    matches = bool(np.allclose(y_compiled, y_eager,
                               rtol=1e-5, atol=atol))
    float_params = spec.init_params(key, quantized=False)
    y_ref = np.asarray(_dense_ref_forward(spec, float_params, x))
    denom = float(np.sqrt(np.mean(y_ref ** 2)))
    rel = float(np.sqrt(np.mean((y_eager - y_ref) ** 2))) / max(denom,
                                                                1e-9)
    return MoEPlanValidation(
        compiled_matches_eager=matches, dense_ref_rel_err=rel,
        quant_error=moe_quantization_error(spec, key=key, seed=seed))


# ---------------------------------------------------------------------------
# bridge from the config zoo
# ---------------------------------------------------------------------------

def moe_workload_from_config(cfg, *, n_layers: int = 2,
                             seq_len: int = 32,
                             data_bits: int = 8, coeff_bits: int = 8,
                             capacity_factor: Optional[float] = None
                             ) -> MoEWorkloadSpec:
    """An ``MoEWorkloadSpec`` from a registry ``ModelConfig`` (e.g.
    ``smoke_config("qwen3-moe-30b-a3b")``): ``n_layers`` MoE blocks at
    the config's expert geometry, planned at the given starting bits.
    ``capacity_factor`` defaults to a generous 2.0 — serving validates
    against the no-drop dense oracle, so the capacity bound should not
    be the thing dropping tokens."""
    if cfg.moe is None:
        raise ValueError(
            f"config {cfg.name!r} (family {cfg.family!r}) has no MoE "
            f"block — pick an arch with cfg.moe set")
    m = cfg.moe
    layer = MoELayerSpec(
        d_ff_expert=m.d_ff_expert, num_experts=m.num_experts,
        top_k=m.top_k, data_bits=data_bits, coeff_bits=coeff_bits,
        n_shared_experts=m.n_shared_experts,
        capacity_factor=(2.0 if capacity_factor is None
                         else capacity_factor))
    return MoEWorkloadSpec(
        layers=(layer,) * n_layers, d_model=cfg.d_model,
        seq_len=seq_len, act=cfg.act, mlp_gated=cfg.mlp_gated)
