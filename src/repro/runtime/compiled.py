"""``CompiledModel``: AOT batch-bucketed executables for any planned
workload — with ``CompiledCNN`` as the convolution backend.

The serving hot path used to pay two avoidable costs:

* **first-request compile stalls** — ``jax.jit`` traces and compiles on
  the first call, inside the serving critical path;
* **fixed-batch padding waste** — the engine always ran the full
  ``(max_batch, ...)`` tensor, so a single live request paid for
  ``max_batch`` (16× the arithmetic at occupancy 1).

``CompiledModel`` removes both, for *every* registered workload.  At
construction (or an explicit ``warmup()``) it AOT-compiles each layer
via ``jax.jit(...).lower(...).compile()`` across a **bucket ladder** of
power-of-two batch sizes (1, 2, 4, …, max_batch), caching executables
keyed on ``(layer spec, bucket)`` — two layers with identical spec
share one executable per bucket.  A call then dispatches to the
*smallest bucket ≥ the live batch*: occupancy 1 runs the size-1
executable, occupancy 5 pads to 8, and a full pool still runs
max_batch — every shape pre-compiled, zero traces at serve time.

Subclasses supply the workload: the layer count, the per-layer compile
key/function/params, the input contract (``in_shape``/``in_dtype`` +
``validate_input``) and the canonical request generator
(``sample_inputs``).  ``CompiledCNN`` is the convolution backend;
``repro.runtime.workloads.CompiledMoE`` is the quantized
mixture-of-experts backend, and ``repro.runtime.workloads.compile_plan``
dispatches a ``DeploymentPlan`` of any registered kind to its backend.

Construction is plan-first: ``CompiledCNN.from_plan`` consumes a
``deploy.DeploymentPlan`` (including one loaded from JSON on a machine
that never ran the planner) and executes exactly the per-layer
(block, data_bits, coeff_bits) assignment the planner chose.  Outputs
are bit-exact against ``cnn_forward_ref`` — bucket padding rides along
as zero images that are sliced off, never summed.

Data parallelism: pass a device mesh and each bucket's executable
constrains its batch to ``sharding.cnn_batch_sharding`` (batch over the
data axes when divisible, replicated otherwise).

Multi-plan serving: executables live in an ``ExecutableCache`` — pass
one cache to several ``CompiledModel`` instances (the async gateway
does) and plans whose layer specs coincide share compiles instead of
paying per plan; CNN and MoE plans coexist in one cache because every
key leads with the workload-specific identity.  Dispatch is
cancellation-safe: ``__call__(x, should_abort=...)`` polls the callback
between layers and raises ``DispatchAborted`` instead of finishing work
nobody is waiting for, and all telemetry counters are lock-protected so
``stats()`` snapshots are consistent under the async drain thread.
"""

from __future__ import annotations

import threading
import time
import warnings
from typing import Callable, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.blocks import BlockLike, get_block
from repro.core.cnn import CNNConfig, _requantize, init_cnn
from repro.kernels import conv2d


class DispatchAborted(RuntimeError):
    """A bucketed dispatch was abandoned mid-flight: every request it
    was serving has been cancelled, so finishing the remaining layers
    would be pure waste.  Raised by ``CompiledModel.__call__`` when its
    ``should_abort`` callback returns True between layers."""


class ExecutableCache:
    """Shareable ``(layer spec, bucket) → compiled executable`` map.

    Backends key executables on the full layer identity — for a CNN
    layer (block, bits, shift, channels, geometry, mesh, bucket); for an
    MoE layer (kind, expert geometry, bits, mesh, bucket) — so the
    cache is content-addressed: two *plans* whose layers coincide can
    safely share one cache and every coinciding (layer, bucket) pair
    compiles exactly once, even across workload kinds.  The async
    gateway routes every registered plan through one ``ExecutableCache``
    for exactly this reason.

    Thread-safe and **single-flight**: lookups/inserts take a lock,
    production runs outside it, and a key already being produced by
    another thread is *waited on* (condition variable), never produced
    twice — two plans registering concurrently over coinciding layers
    pay for one compile, with the loser parked instead of burning a
    core on a duplicate build (``coalesced`` counts those waits).

    Subclass seam: ``_produce(key, build)`` turns a missing key into an
    executable (base class: call ``build()``); a disk tier like
    ``repro.ops.PersistentExecutableCache`` overrides it to try a
    deserialization load first and compile only on a true miss.
    ``on_event`` (``callable(event: str, fields: dict)``) receives the
    *rare* cache transitions — compiles and disk loads/stores/fallbacks
    — never per-dispatch memory hits, so wiring a tracker here costs
    nothing on the serving hot path.
    """

    def __init__(self, *, on_event: Optional[Callable[[str, dict],
                                                      None]] = None):
        self._execs: Dict[tuple, object] = {}
        self._lock = threading.Lock()
        self._cond = threading.Condition(self._lock)
        self._building: set = set()    # keys with a production in flight
        self.compiles = 0              # builds that entered the cache
        self.hits = 0                  # lookups served without building
        self.coalesced = 0             # waits piggybacked on another build
        self.on_event = on_event

    def __len__(self) -> int:
        with self._lock:
            return len(self._execs)

    def __contains__(self, key: tuple) -> bool:
        with self._lock:
            return key in self._execs

    def _emit(self, event: str, **fields) -> None:
        """Report a rare cache transition to ``on_event`` (tracker
        seam).  A misbehaving observer must never break serving."""
        cb = self.on_event
        if cb is None:
            return
        try:
            cb(event, fields)
        except Exception:              # noqa: BLE001 — observer only
            pass

    def _produce(self, key: tuple, build: Callable[[], object]
                 ) -> Tuple[object, bool]:
        """Produce the executable for a missing ``key`` — called
        outside the lock, single-flighted per key.  Returns
        ``(executable, compiled)`` where ``compiled`` says ``build()``
        actually ran (a disk tier returns False for a load)."""
        t0 = time.perf_counter()
        exe = build()
        self._emit("cache_compile", key=repr(key)[:160],
                   seconds=time.perf_counter() - t0)
        return exe, True

    def get_or_build(self, key: tuple, build: Callable[[], object]):
        with self._cond:
            while True:
                exe = self._execs.get(key)
                if exe is not None:
                    self.hits += 1
                    return exe
                if key not in self._building:
                    self._building.add(key)
                    break
                # another thread is producing this very key: wait for
                # it instead of compiling a duplicate (single-flight)
                self.coalesced += 1
                self._cond.wait()
        try:
            exe, compiled = self._produce(key, build)   # outside the lock
        except BaseException:
            with self._cond:
                # failed production frees the key: a parked waiter (or
                # the next caller) becomes the new producer and retries
                self._building.discard(key)
                self._cond.notify_all()
            raise
        with self._cond:
            self._building.discard(key)
            self._execs[key] = exe
            if compiled:
                self.compiles += 1
            self._cond.notify_all()
        return exe

    def stats(self) -> dict:
        with self._lock:
            return {"executables": len(self._execs),
                    "compiles": self.compiles, "hits": self.hits,
                    "coalesced": self.coalesced}


def bucket_ladder(max_batch: int) -> Tuple[int, ...]:
    """Power-of-two batch buckets up to ``max_batch`` (which is always
    the top rung, even when it is not itself a power of two)."""
    if max_batch < 1:
        raise ValueError(f"max_batch={max_batch} must be ≥ 1")
    rungs = []
    b = 1
    while b < max_batch:
        rungs.append(b)
        b <<= 1
    rungs.append(max_batch)
    return tuple(rungs)


def validate_container_input(x, in_shape, in_dtype, request_id=0, *,
                             noun: str = "input") -> np.ndarray:
    """Shape + dtype admission check for integer-container workloads
    (the CNN input contract).  A float array must carry exact
    container-range integers — silent ``np.asarray(x, in_dtype)``
    truncation (0.9 → 0, 200.0 → -56 for int8) is a ``ValueError``
    here, as is any value that would wrap in the container."""
    x = np.asarray(x)
    if tuple(x.shape) != tuple(in_shape):
        raise ValueError(
            f"request {request_id}: {noun} shape {tuple(x.shape)} "
            f"!= engine input {tuple(in_shape)}")
    if not np.issubdtype(x.dtype, np.integer):
        if not np.all(np.isfinite(x)) or np.any(x != np.round(x)):
            raise ValueError(
                f"request {request_id}: {noun} dtype {x.dtype} "
                f"carries non-integral values — quantize explicitly "
                f"(e.g. ops.quantize_fixed) before submitting")
    info = np.iinfo(in_dtype)
    if np.any(x < info.min) or np.any(x > info.max):
        raise ValueError(
            f"request {request_id}: {noun} values outside the "
            f"{np.dtype(in_dtype).name} container range "
            f"[{info.min}, {info.max}] — would wrap, not clamp")
    return x


class CompiledModel:
    """AOT-compiled, batch-bucketed executor for one planned workload.

    The generic machinery — bucket ladder, ``ExecutableCache``, AOT
    warmup, smallest-bucket dispatch with padding, chunking above
    ``max_batch``, between-layer ``should_abort`` polling, telemetry —
    lives here.  A backend subclass supplies:

    ``num_layers``            how many sequential executables a forward is
    ``in_shape``/``in_dtype`` the per-request input contract
    ``input_noun``            what a request payload is called in errors
    ``_layer_key(i, bucket)`` the full-identity cache key (incl. mesh)
    ``_layer_fn(i)``          ``(params, x) -> y`` traced per bucket
    ``_layer_params(i)``      the pytree passed as ``params``
    ``_layer_in_sds(i, b)``   the ShapeDtypeStruct the layer is lowered at
    ``_empty_output()``       the zero-batch result
    ``_place_batch(xb, b)``   optional device placement (mesh sharding)
    ``sample_inputs(k)``      canonical request generator
    ``validate_input(x)``     per-workload admission check
    """

    kind = "model"                 # registry name of the workload
    input_noun = "input"           # request payload, as named in errors

    # subclass contract: these must be set before delegating to
    # ``CompiledModel.__init__`` (warmup compiles through them)
    num_layers: int
    in_shape: Tuple[int, ...]
    in_dtype = None

    def __init__(self, *, max_batch: int = 16, mesh=None,
                 warmup: bool = True,
                 exec_cache: Optional[ExecutableCache] = None):
        self.max_batch = max_batch
        self.buckets = bucket_ladder(max_batch)
        self.mesh = mesh
        # executables shard differently per mesh, so the mesh is part of
        # the cache key.  The mesh object itself (hashable, compared by
        # devices + axis names) — not id(), whose recycled addresses
        # could alias two different meshes in a long-lived shared cache
        self._mesh_token = mesh

        # (layer key, bucket) → compiled executable; identical layer
        # specs share one compile per bucket — across *instances* too
        # when an ``exec_cache`` is passed in (multi-plan serving)
        self.cache = exec_cache if exec_cache is not None \
            else ExecutableCache()
        self.compiles = 0              # compiles this instance performed
        self.bucket_hits: Dict[int, int] = {b: 0 for b in self.buckets}
        self.calls = 0
        self._stats_lock = threading.Lock()
        if warmup:
            self.warmup()

    # -- backend hooks ----------------------------------------------------
    def _layer_key(self, i: int, bucket: int) -> tuple:
        raise NotImplementedError

    def _layer_fn(self, i: int):
        """The traceable ``(params, x) -> y`` for layer ``i``."""
        raise NotImplementedError

    def _layer_params(self, i: int):
        raise NotImplementedError

    def _layer_in_sds(self, i: int, bucket: int) -> jax.ShapeDtypeStruct:
        raise NotImplementedError

    def _empty_output(self):
        raise NotImplementedError

    def _place_batch(self, xb, bucket: int):
        """Optional pre-dispatch device placement (mesh sharding)."""
        return xb

    def sample_inputs(self, k: int, seed: int = 0):
        """``k`` random requests matching this executor's input contract
        (shape + dtype) — the canonical workload generator shared by the
        launcher, benchmarks, and examples, so the input rules live in
        one place."""
        raise NotImplementedError

    def validate_input(self, x, request_id: int = 0) -> np.ndarray:
        """Admission check: shape + dtype-compatibility.  Backends
        override to enforce their quantization contract (the CNN
        backend rejects non-integral floats and container overflow; the
        MoE backend rejects non-finite activations)."""
        x = np.asarray(x)
        if tuple(x.shape) != tuple(self.in_shape):
            raise ValueError(
                f"request {request_id}: {self.input_noun} shape "
                f"{tuple(x.shape)} != engine input {tuple(self.in_shape)}")
        return x

    # -- AOT compilation --------------------------------------------------
    def _compile_layer(self, i: int, bucket: int):
        def build():
            fn = self._layer_fn(i)
            w_sds = jax.tree_util.tree_map(
                lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype),
                self._layer_params(i))
            x_sds = self._layer_in_sds(i, bucket)
            with self._stats_lock:
                self.compiles += 1
            return jax.jit(fn).lower(w_sds, x_sds).compile()

        return self.cache.get_or_build(self._layer_key(i, bucket), build)

    def warmup(self) -> "CompiledModel":
        """AOT-compile every (layer, bucket) executable now, so no call
        ever compiles on the serving critical path."""
        for b in self.buckets:
            for i in range(self.num_layers):
                self._compile_layer(i, b)
        return self

    @property
    def warmed_up(self) -> bool:
        return all(self._layer_key(i, b) in self.cache
                   for b in self.buckets
                   for i in range(self.num_layers))

    # -- dispatch ----------------------------------------------------------
    def bucket_for(self, n: int) -> int:
        """Smallest bucket ≥ n (n must be ≤ max_batch)."""
        for b in self.buckets:
            if b >= n:
                return b
        raise ValueError(f"batch {n} exceeds max_batch={self.max_batch}")

    def _run_bucket(self, xb, should_abort=None):
        """xb: (n, *in_shape) with n ≤ max_batch → (n, *out_shape)."""
        n = xb.shape[0]
        bucket = self.bucket_for(n)
        if n < bucket:
            pad = jnp.zeros((bucket - n,) + xb.shape[1:], xb.dtype)
            xb = jnp.concatenate([xb, pad])
        xb = self._place_batch(xb, bucket)
        act = xb
        for i in range(self.num_layers):
            if should_abort is not None and should_abort():
                raise DispatchAborted(
                    f"dispatch abandoned before layer {i} "
                    f"(all served requests cancelled)")
            act = self._compile_layer(i, bucket)(
                self._layer_params(i), act)
        with self._stats_lock:
            self.bucket_hits[bucket] += 1
        return act[:n]

    def __call__(self, x, *, should_abort=None):
        """x: one ``in_shape`` request or an ``(N, *in_shape)`` batch.
        Batches larger than ``max_batch`` run in max_batch-sized chunks
        (the tail dispatching to its own bucket).

        ``should_abort`` (optional zero-arg callable) is polled between
        layers; returning True raises ``DispatchAborted`` — the async
        gateway's cancellation hook, so a flight whose every request was
        cancelled mid-execution stops paying for the remaining layers."""
        x = jnp.asarray(x)
        single = x.ndim == len(self.in_shape)
        if single:
            x = x[None]
        if x.shape[1:] != tuple(self.in_shape):
            raise ValueError(
                f"{self.input_noun} shape {tuple(x.shape[1:])} != "
                f"compiled input {tuple(self.in_shape)}")
        if x.dtype != self.in_dtype:
            raise ValueError(
                f"{self.input_noun} dtype {x.dtype} != compiled input "
                f"{np.dtype(self.in_dtype).name}")
        with self._stats_lock:
            self.calls += 1
        if x.shape[0] == 0:            # empty queue tick: nothing to run
            return self._empty_output()
        outs = [self._run_bucket(x[s:s + self.max_batch], should_abort)
                for s in range(0, x.shape[0], self.max_batch)]
        y = outs[0] if len(outs) == 1 else jnp.concatenate(outs)
        return y[0] if single else y

    # -- observability -----------------------------------------------------
    def stats(self) -> dict:
        """Dispatch + compile telemetry.  ``executables``/``cache_*``
        describe the (possibly shared) ``ExecutableCache``; ``compiles``
        counts builds *this instance* performed — with a shared cache,
        a second plan over identical layers reports 0.  Snapshot is
        lock-consistent under the async drain."""
        with self._stats_lock:
            hits = dict(self.bucket_hits)
            calls = self.calls
            compiles = self.compiles
        cache = self.cache.stats()
        return {
            "kind": self.kind,
            "buckets": list(self.buckets),
            "bucket_hits": hits,
            "executables": cache["executables"],
            "compiles": compiles,
            "cache_compiles": cache["compiles"],
            "cache_hits": cache["hits"],
            "calls": calls,
            "warmed_up": self.warmed_up,
        }


class CompiledCNN(CompiledModel):
    """The convolution backend: AOT-compiled, batch-bucketed executor
    for one planned CNN deployment.  Bit-exact vs ``cnn_forward_ref``
    at every batch size."""

    kind = "cnn"
    input_noun = "image"

    def __init__(self, cfg: CNNConfig, params, blocks: Sequence[BlockLike],
                 *, max_batch: int = 16, mesh=None, warmup: bool = True,
                 exec_cache: Optional[ExecutableCache] = None):
        blocks = [get_block(b) for b in blocks]
        if len(blocks) != len(cfg.layers):
            raise ValueError(
                f"need one block per layer: {len(blocks)} blocks "
                f"for {len(cfg.layers)} layers")
        self.cfg = cfg
        self.params = params
        self.blocks = blocks
        self.num_layers = len(cfg.layers)

        spec0 = cfg.layers[0]
        self.in_shape = (cfg.img_h, cfg.img_w, spec0.in_channels)
        self.in_dtype = conv2d.container_dtype(spec0.data_bits)
        super().__init__(max_batch=max_batch, mesh=mesh, warmup=warmup,
                         exec_cache=exec_cache)

    # -- construction from a deployment plan -----------------------------
    @classmethod
    def from_plan(cls, plan, cfg: Optional[CNNConfig] = None, *,
                  params=None, key=None, max_batch: int = 16, mesh=None,
                  warmup: bool = True,
                  exec_cache: Optional[ExecutableCache] = None
                  ) -> "CompiledCNN":
        """Executor for a planned deployment: each layer runs the
        (block, bits) the planner assigned.  ``cfg`` defaults to the
        network embedded in the plan (always present on planner output
        and on plans loaded from JSON); ``params`` default to a fresh
        ``init_cnn`` draw at the planned precisions."""
        from repro.core import deploy
        pcfg = deploy.plan_config(plan, cfg)
        if params is None:
            key = key if key is not None else jax.random.PRNGKey(0)
            params = init_cnn(key, pcfg)
        return cls(pcfg, params, plan.block_names(), max_batch=max_batch,
                   mesh=mesh, warmup=warmup, exec_cache=exec_cache)

    @classmethod
    def from_json(cls, text: str, **kw) -> "CompiledCNN":
        """Executor straight from a serialized plan artifact."""
        from repro.core import deploy
        return cls.from_plan(deploy.DeploymentPlan.from_json(text), **kw)

    # -- backend hooks ----------------------------------------------------
    def _layer_key(self, i: int, bucket: int) -> tuple:
        spec = self.cfg.layers[i]
        return (self.blocks[i].name, spec.data_bits, spec.coeff_bits,
                spec.shift, spec.in_channels, spec.out_channels,
                self.cfg.img_h, self.cfg.img_w, self._mesh_token, bucket)

    def _layer_fn(self, i: int):
        spec, blk, mesh = self.cfg.layers[i], self.blocks[i], self.mesh

        def layer(w, x):
            if mesh is not None:
                from repro.parallel.sharding import cnn_batch_sharding
                sh = cnn_batch_sharding(mesh, x.shape[0])
                x = jax.lax.with_sharding_constraint(x, sh)
            acc = blk.apply_batched(x, w, data_bits=spec.data_bits,
                                    coeff_bits=spec.coeff_bits)
            return _requantize(acc, spec)

        return layer

    def _layer_params(self, i: int):
        return self.params[i]

    def _layer_in_sds(self, i: int, bucket: int) -> jax.ShapeDtypeStruct:
        spec = self.cfg.layers[i]
        return jax.ShapeDtypeStruct(
            (bucket, self.cfg.img_h, self.cfg.img_w, spec.in_channels),
            conv2d.container_dtype(spec.data_bits))

    def _empty_output(self):
        last = self.cfg.layers[-1]
        return jnp.zeros(
            (0, self.cfg.img_h, self.cfg.img_w, last.out_channels),
            conv2d.container_dtype(last.data_bits))

    def _place_batch(self, xb, bucket: int):
        if self.mesh is not None:
            from repro.parallel.sharding import cnn_batch_sharding
            xb = jax.device_put(xb, cnn_batch_sharding(self.mesh, bucket))
        return xb

    # -- workload helpers --------------------------------------------------
    def sample_inputs(self, k: int, seed: int = 0):
        """``k`` random quantized images matching this executor's input
        contract (shape + container dtype)."""
        from repro.kernels import ops
        rng = np.random.default_rng(seed)
        d0 = self.cfg.layers[0].data_bits
        return [np.asarray(ops.quantize_fixed(
            rng.integers(0, 1 << (d0 - 1),
                         self.in_shape).astype(np.float32), d0))
            for _ in range(k)]

    def sample_images(self, k: int, seed: int = 0):
        """.. deprecated:: use the workload-generic ``sample_inputs``."""
        warnings.warn(
            "CompiledCNN.sample_images is deprecated; use the "
            "workload-generic CompiledModel.sample_inputs",
            DeprecationWarning, stacklevel=2)
        return self.sample_inputs(k, seed)

    def validate_input(self, x, request_id: int = 0) -> np.ndarray:
        return validate_container_input(
            x, self.in_shape, self.in_dtype, request_id,
            noun=self.input_noun)
