"""``CompiledCNN``: AOT batch-bucketed executables for a planned CNN.

The serving hot path used to pay two avoidable costs:

* **first-request compile stalls** — ``jax.jit`` traces and compiles on
  the first call, inside the serving critical path;
* **fixed-batch padding waste** — the engine always ran the full
  ``(max_batch, H, W, C)`` tensor, so a single live image paid for
  ``max_batch`` (16× the arithmetic at occupancy 1).

``CompiledCNN`` removes both.  At construction (or an explicit
``warmup()``) it AOT-compiles each layer via
``jax.jit(...).lower(...).compile()`` across a **bucket ladder** of
power-of-two batch sizes (1, 2, 4, …, max_batch), caching executables
keyed on ``(layer spec, bucket)`` — two layers with identical
(block, bits, geometry) share one executable per bucket.  A call then
dispatches to the *smallest bucket ≥ the live batch*: occupancy 1 runs
the size-1 executable, occupancy 5 pads to 8, and a full pool still
runs max_batch — every shape pre-compiled, zero traces at serve time.

Construction is plan-first: ``CompiledCNN.from_plan`` consumes a
``deploy.DeploymentPlan`` (including one loaded from JSON on a machine
that never ran the planner) and executes exactly the per-layer
(block, data_bits, coeff_bits) assignment the planner chose.  Outputs
are bit-exact against ``cnn_forward_ref`` — bucket padding rides along
as zero images that are sliced off, never summed.

Data parallelism: pass a device mesh and each bucket's executable
constrains its batch to ``sharding.cnn_batch_sharding`` (batch over the
data axes when divisible, replicated otherwise).
"""

from __future__ import annotations

from typing import Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.blocks import BlockLike, get_block
from repro.core.cnn import CNNConfig, _requantize, init_cnn
from repro.kernels import conv2d


def bucket_ladder(max_batch: int) -> Tuple[int, ...]:
    """Power-of-two batch buckets up to ``max_batch`` (which is always
    the top rung, even when it is not itself a power of two)."""
    if max_batch < 1:
        raise ValueError(f"max_batch={max_batch} must be ≥ 1")
    rungs = []
    b = 1
    while b < max_batch:
        rungs.append(b)
        b <<= 1
    rungs.append(max_batch)
    return tuple(rungs)


class CompiledCNN:
    """AOT-compiled, batch-bucketed executor for one CNN deployment."""

    def __init__(self, cfg: CNNConfig, params, blocks: Sequence[BlockLike],
                 *, max_batch: int = 16, mesh=None, warmup: bool = True):
        blocks = [get_block(b) for b in blocks]
        if len(blocks) != len(cfg.layers):
            raise ValueError(
                f"need one block per layer: {len(blocks)} blocks "
                f"for {len(cfg.layers)} layers")
        self.cfg = cfg
        self.params = params
        self.blocks = blocks
        self.max_batch = max_batch
        self.buckets = bucket_ladder(max_batch)
        self.mesh = mesh

        spec0 = cfg.layers[0]
        self.in_shape = (cfg.img_h, cfg.img_w, spec0.in_channels)
        self.in_dtype = conv2d.container_dtype(spec0.data_bits)

        # (layer key, bucket) → compiled executable; identical layer
        # specs share one compile per bucket
        self._execs: Dict[tuple, object] = {}
        self.compiles = 0
        self.bucket_hits: Dict[int, int] = {b: 0 for b in self.buckets}
        self.calls = 0
        if warmup:
            self.warmup()

    # -- construction from a deployment plan -----------------------------
    @classmethod
    def from_plan(cls, plan, cfg: Optional[CNNConfig] = None, *,
                  params=None, key=None, max_batch: int = 16, mesh=None,
                  warmup: bool = True) -> "CompiledCNN":
        """Executor for a planned deployment: each layer runs the
        (block, bits) the planner assigned.  ``cfg`` defaults to the
        network embedded in the plan (always present on planner output
        and on plans loaded from JSON); ``params`` default to a fresh
        ``init_cnn`` draw at the planned precisions."""
        from repro.core import deploy
        pcfg = deploy.plan_config(plan, cfg)
        if params is None:
            key = key if key is not None else jax.random.PRNGKey(0)
            params = init_cnn(key, pcfg)
        return cls(pcfg, params, plan.block_names(), max_batch=max_batch,
                   mesh=mesh, warmup=warmup)

    @classmethod
    def from_json(cls, text: str, **kw) -> "CompiledCNN":
        """Executor straight from a serialized plan artifact."""
        from repro.core import deploy
        return cls.from_plan(deploy.DeploymentPlan.from_json(text), **kw)

    # -- AOT compilation --------------------------------------------------
    def _layer_key(self, i: int, bucket: int) -> tuple:
        spec = self.cfg.layers[i]
        return (self.blocks[i].name, spec.data_bits, spec.coeff_bits,
                spec.shift, spec.in_channels, spec.out_channels,
                self.cfg.img_h, self.cfg.img_w, bucket)

    def _compile_layer(self, i: int, bucket: int):
        key = self._layer_key(i, bucket)
        exe = self._execs.get(key)
        if exe is not None:
            return exe
        spec, blk, mesh = self.cfg.layers[i], self.blocks[i], self.mesh

        def layer(w, x):
            if mesh is not None:
                from repro.parallel.sharding import cnn_batch_sharding
                sh = cnn_batch_sharding(mesh, x.shape[0])
                x = jax.lax.with_sharding_constraint(x, sh)
            acc = blk.apply_batched(x, w, data_bits=spec.data_bits,
                                    coeff_bits=spec.coeff_bits)
            return _requantize(acc, spec)

        w = self.params[i]
        x_sds = jax.ShapeDtypeStruct(
            (bucket, self.cfg.img_h, self.cfg.img_w, spec.in_channels),
            conv2d.container_dtype(spec.data_bits))
        w_sds = jax.ShapeDtypeStruct(w.shape, w.dtype)
        exe = jax.jit(layer).lower(w_sds, x_sds).compile()
        self._execs[key] = exe
        self.compiles += 1
        return exe

    def warmup(self) -> "CompiledCNN":
        """AOT-compile every (layer, bucket) executable now, so no call
        ever compiles on the serving critical path."""
        for b in self.buckets:
            for i in range(len(self.cfg.layers)):
                self._compile_layer(i, b)
        return self

    @property
    def warmed_up(self) -> bool:
        return all(self._layer_key(i, b) in self._execs
                   for b in self.buckets
                   for i in range(len(self.cfg.layers)))

    # -- dispatch ----------------------------------------------------------
    def bucket_for(self, n: int) -> int:
        """Smallest bucket ≥ n (n must be ≤ max_batch)."""
        for b in self.buckets:
            if b >= n:
                return b
        raise ValueError(f"batch {n} exceeds max_batch={self.max_batch}")

    def _run_bucket(self, xb):
        """xb: (n, H, W, C) with n ≤ max_batch → (n, H, W, C_out)."""
        n = xb.shape[0]
        bucket = self.bucket_for(n)
        if n < bucket:
            pad = jnp.zeros((bucket - n,) + xb.shape[1:], xb.dtype)
            xb = jnp.concatenate([xb, pad])
        if self.mesh is not None:
            from repro.parallel.sharding import cnn_batch_sharding
            xb = jax.device_put(xb, cnn_batch_sharding(self.mesh, bucket))
        act = xb
        for i in range(len(self.cfg.layers)):
            act = self._compile_layer(i, bucket)(self.params[i], act)
        self.bucket_hits[bucket] += 1
        return act[:n]

    def __call__(self, x):
        """x: one (H, W, C) image or an (N, H, W, C) batch of quantized
        container ints.  Batches larger than ``max_batch`` run in
        max_batch-sized chunks (the tail dispatching to its own bucket).
        Bit-exact vs ``cnn_forward_ref`` at every batch size."""
        x = jnp.asarray(x)
        single = x.ndim == 3
        if single:
            x = x[None]
        if x.shape[1:] != self.in_shape:
            raise ValueError(
                f"image shape {tuple(x.shape[1:])} != compiled input "
                f"{self.in_shape}")
        if x.dtype != self.in_dtype:
            raise ValueError(
                f"image dtype {x.dtype} != compiled input container "
                f"{np.dtype(self.in_dtype).name}")
        self.calls += 1
        if x.shape[0] == 0:            # empty queue tick: nothing to run
            last = self.cfg.layers[-1]
            return jnp.zeros(
                (0, self.cfg.img_h, self.cfg.img_w, last.out_channels),
                conv2d.container_dtype(last.data_bits))
        outs = [self._run_bucket(x[s:s + self.max_batch])
                for s in range(0, x.shape[0], self.max_batch)]
        y = outs[0] if len(outs) == 1 else jnp.concatenate(outs)
        return y[0] if single else y

    # -- observability -----------------------------------------------------
    def stats(self) -> dict:
        return {
            "buckets": list(self.buckets),
            "bucket_hits": dict(self.bucket_hits),
            "executables": len(self._execs),
            "compiles": self.compiles,
            "calls": self.calls,
            "warmed_up": self.warmed_up,
        }
