"""``repro.runtime`` — the single plan→compile→serve execution facade.

The paper's point is that parameterizable blocks plus fitted resource
models let you *pick a configuration once and deploy it without
re-running the search*.  This package is that workflow as one API,
generalized across workloads (schema v2):

  plan     ``deploy.plan_deployment`` (CNN) and
           ``workloads.plan_moe_deployment`` (quantized MoE) → a
           ``DeploymentPlan`` that is a durable, versioned JSON
           artifact (``save_plan``/``load_plan`` — plan on one machine,
           serve on another) carrying a typed ``WorkloadSpec``
  compile  ``compile_plan(plan)`` → the plan's ``CompiledModel``
           backend (``CompiledCNN``, ``CompiledMoE``, or any kind in
           the ``workloads`` registry) — AOT batch-bucketed
           executables (no first-request compile stall, no
           fixed-max_batch padding waste)
  serve    ``repro.serve.CNNEngine`` — the dynamic-batching engine —
           and ``repro.serve.AsyncCNNGateway``, the continuous-batching
           front door that routes *multiple* plans of *any* workload
           kind through one shared ``ExecutableCache`` (identical
           layers compile once across plans)

Re-exports the plan types so callers need only ``repro.runtime`` and
``repro.serve``.  Importing this package registers the built-in
workload kinds (``"cnn"``, ``"moe"``).
"""

from repro.core.deploy import (DeploymentError, DeploymentPlan,
                               PLAN_SCHEMA_VERSION, plan_deployment)
from repro.runtime.compiled import (CompiledCNN, CompiledModel,
                                    DispatchAborted, ExecutableCache,
                                    bucket_ladder, validate_container_input)
from repro.runtime.plan_io import atomic_write_text, load_plan, save_plan
from repro.runtime.workloads import (CNNWorkloadSpec, CompiledMoE,
                                     MoELayerSpec, MoEWorkloadSpec,
                                     WorkloadSpec, compile_plan,
                                     get_workload, list_workloads,
                                     moe_workload_from_config,
                                     plan_moe_deployment, register_workload,
                                     validate_moe_plan, workload_spec)

__all__ = [
    "CNNWorkloadSpec", "CompiledCNN", "CompiledMoE", "CompiledModel",
    "DeploymentError", "DeploymentPlan", "DispatchAborted",
    "ExecutableCache", "MoELayerSpec", "MoEWorkloadSpec",
    "PLAN_SCHEMA_VERSION", "WorkloadSpec", "atomic_write_text",
    "bucket_ladder", "compile_plan",
    "get_workload", "list_workloads", "load_plan",
    "moe_workload_from_config", "plan_deployment", "plan_moe_deployment",
    "register_workload", "save_plan", "validate_container_input",
    "validate_moe_plan", "workload_spec",
]
