"""``repro.runtime`` — the single plan→compile→serve execution facade.

The paper's point is that parameterizable blocks plus fitted resource
models let you *pick a configuration once and deploy it without
re-running the search*.  This package is that workflow as one API:

  plan     ``deploy.plan_deployment`` → a ``DeploymentPlan`` that is a
           durable, versioned JSON artifact (``save_plan``/``load_plan``
           — plan on one machine, serve on another)
  compile  ``CompiledCNN`` — AOT batch-bucketed executables for the
           planned network (no first-request compile stall, no
           fixed-max_batch padding waste)
  serve    ``repro.serve.CNNEngine`` — the dynamic-batching engine,
           built on ``CompiledCNN`` — and ``repro.serve.
           AsyncCNNGateway``, the continuous-batching front door that
           routes *multiple* plans through one shared
           ``ExecutableCache`` (identical layers compile once across
           plans)

Re-exports the plan types so callers need only ``repro.runtime`` and
``repro.serve``.
"""

from repro.core.deploy import (DeploymentError, DeploymentPlan,
                               PLAN_SCHEMA_VERSION, plan_deployment)
from repro.runtime.compiled import (CompiledCNN, DispatchAborted,
                                    ExecutableCache, bucket_ladder)
from repro.runtime.plan_io import load_plan, save_plan

__all__ = [
    "CompiledCNN", "DeploymentError", "DeploymentPlan", "DispatchAborted",
    "ExecutableCache", "PLAN_SCHEMA_VERSION", "bucket_ladder", "load_plan",
    "plan_deployment", "save_plan",
]
