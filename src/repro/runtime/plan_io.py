"""Plan artifacts on disk: save/load helpers for ``DeploymentPlan``.

Thin conveniences over ``DeploymentPlan.to_json``/``from_json`` so the
plan→compile→serve flow reads naturally at call sites:

    plan = deploy.plan_deployment(cfg, bm, device)     # or
    plan = workloads.plan_moe_deployment(spec, "v5e")  # any workload kind
    runtime.save_plan(plan, "plan.json")          # machine A
    ...
    plan = runtime.load_plan("plan.json")         # machine B
    model = runtime.compile_plan(plan, params=params)

The payload is versioned (``deploy.PLAN_SCHEMA_VERSION``) and pinned by
the golden fixtures ``tests/golden/plan_golden.json`` (v2) and
``plan_v1_golden.json`` (the frozen v1 upgrade input); loading a payload
from an unknown schema version raises rather than mis-deserializing,
while v1 CNN payloads upgrade in place bit-identically.

Writes are **crash-safe**: ``atomic_write_text`` stages the payload in a
temp file in the destination directory, fsyncs it, and ``os.replace``s
it into place, so a reader never observes a torn or partially-written
plan — the file either has the old bytes or the new bytes.
``repro.ops.PlanStore`` builds its repository on the same primitive.
"""

from __future__ import annotations

import os
import threading
from pathlib import Path
from typing import Union

from repro.core.deploy import DeploymentPlan


def _fsync_dir(path: Path) -> None:
    """Flush a directory entry so a rename survives a crash.  Best
    effort: some filesystems/platforms refuse O_RDONLY dir fds."""
    try:
        fd = os.open(path, os.O_RDONLY)
    except OSError:
        return
    try:
        os.fsync(fd)
    except OSError:
        pass
    finally:
        os.close(fd)


def atomic_write_text(path: Union[str, Path], text: str, *,
                      fsync: bool = True) -> Path:
    """Write ``text`` to ``path`` atomically (tmp + fsync + rename).

    The temp file lives in the destination directory (``os.replace`` is
    only atomic within a filesystem) and its name is unique per
    (pid, thread), so concurrent writers of the same path race only at
    the rename — last writer wins, readers never see a torn file.
    """
    path = Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    tmp = path.parent / (
        f".{path.name}.{os.getpid()}.{threading.get_ident()}.tmp")
    try:
        with open(tmp, "w", encoding="utf-8") as fh:
            fh.write(text)
            fh.flush()
            if fsync:
                os.fsync(fh.fileno())
        os.replace(tmp, path)
    except BaseException:
        try:
            tmp.unlink()
        except OSError:
            pass
        raise
    if fsync:
        _fsync_dir(path.parent)
    return path


def save_plan(plan: DeploymentPlan, path: Union[str, Path]) -> Path:
    """Write the versioned JSON artifact atomically; returns the path."""
    return atomic_write_text(path, plan.to_json())


def load_plan(path: Union[str, Path]) -> DeploymentPlan:
    """Load a plan artifact (raises ValueError on schema mismatch)."""
    return DeploymentPlan.load(path)
