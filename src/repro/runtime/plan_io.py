"""Plan artifacts on disk: save/load helpers for ``DeploymentPlan``.

Thin conveniences over ``DeploymentPlan.to_json``/``from_json`` so the
plan→compile→serve flow reads naturally at call sites:

    plan = deploy.plan_deployment(cfg, bm, device)     # or
    plan = workloads.plan_moe_deployment(spec, "v5e")  # any workload kind
    runtime.save_plan(plan, "plan.json")          # machine A
    ...
    plan = runtime.load_plan("plan.json")         # machine B
    model = runtime.compile_plan(plan, params=params)

The payload is versioned (``deploy.PLAN_SCHEMA_VERSION``) and pinned by
the golden fixtures ``tests/golden/plan_golden.json`` (v2) and
``plan_v1_golden.json`` (the frozen v1 upgrade input); loading a payload
from an unknown schema version raises rather than mis-deserializing,
while v1 CNN payloads upgrade in place bit-identically.
"""

from __future__ import annotations

from pathlib import Path
from typing import Union

from repro.core.deploy import DeploymentPlan


def save_plan(plan: DeploymentPlan, path: Union[str, Path]) -> Path:
    """Write the versioned JSON artifact; returns the path."""
    return plan.save(path)


def load_plan(path: Union[str, Path]) -> DeploymentPlan:
    """Load a plan artifact (raises ValueError on schema mismatch)."""
    return DeploymentPlan.load(path)
