"""Flash attention as a Pallas TPU kernel (online-softmax, VMEM-resident
logits).

This is the fix for the dominant memory term of the dense §Roofline cells:
the jnp chunked-attention path materializes (chunk × T) f32 logits +
softmax intermediates in HBM every layer; this kernel keeps the running
(bq × bk) tile, the row max/denominator and the output accumulator in VMEM
and writes only the (S × Dh) output — O(S·Dh) HBM traffic instead of
O(S·T) per head.

Tiling: grid over query blocks; K/V live in VMEM as full blocks (fits for
T ≤ ~8k at Dh=128; production sizes stream K/V via a second grid dim —
same math, the online-softmax update is associative).  Batch and heads are
vmapped (TPU lowers that to a leading grid dimension).

Validated in interpret mode against the pure-jnp oracle
(tests/test_flash_attention.py); the model's jnp path remains the host
dry-run implementation (DESIGN.md §8.4).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, *, bq, bk, t, d, scale,
                  causal):
    i = pl.program_id(0)
    q = q_ref[...].astype(jnp.float32) * scale            # (bq, d)
    n_kv = t // bk

    def body(j, carry):
        m, l, acc = carry
        k = jax.lax.dynamic_slice(k_ref[...], (j * bk, 0),
                                  (bk, d)).astype(jnp.float32)
        v = jax.lax.dynamic_slice(v_ref[...], (j * bk, 0),
                                  (bk, d)).astype(jnp.float32)
        s = q @ k.T                                        # (bq, bk)
        if causal:
            rows = i * bq + jax.lax.broadcasted_iota(jnp.int32,
                                                     (bq, bk), 0)
            cols = j * bk + jax.lax.broadcasted_iota(jnp.int32,
                                                     (bq, bk), 1)
            s = jnp.where(cols <= rows, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + jnp.sum(p, axis=-1)
        acc_new = acc * alpha[:, None] + p @ v
        return m_new, l_new, acc_new

    m0 = jnp.full((bq,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((bq,), jnp.float32)
    acc0 = jnp.zeros((bq, d), jnp.float32)
    m, l, acc = jax.lax.fori_loop(0, n_kv, body, (m0, l0, acc0))
    o_ref[...] = (acc / jnp.maximum(l, 1e-20)[:, None]).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal: bool = True, block_q: int = 128,
                    block_k: int = 128, interpret: bool = True):
    """q: (B, S, H, Dh); k, v: (B, T, KH, Dh) -> (B, S, H, Dh).

    GQA: query head h reads kv head h // (H // KH).
    """
    b, s, h, d = q.shape
    t, kh = k.shape[1], k.shape[2]
    g = h // kh
    scale = 1.0 / (d ** 0.5)
    bq = min(block_q, s)
    bk = min(block_k, t)
    assert s % bq == 0 and t % bk == 0, (s, bq, t, bk)

    kernel = functools.partial(_flash_kernel, bq=bq, bk=bk, t=t, d=d,
                               scale=scale, causal=causal)

    def one_head(qh, kh_, vh_):
        return pl.pallas_call(
            kernel,
            grid=(s // bq,),
            in_specs=[pl.BlockSpec((bq, d), lambda i: (i, 0)),
                      pl.BlockSpec((t, d), lambda i: (0, 0)),
                      pl.BlockSpec((t, d), lambda i: (0, 0))],
            out_specs=pl.BlockSpec((bq, d), lambda i: (i, 0)),
            out_shape=jax.ShapeDtypeStruct((s, d), q.dtype),
            interpret=interpret,
        )(qh, kh_, vh_)

    def one_batch(qb, kb, vb):
        # (S,H,D) -> per-head call, mapping GQA heads to kv groups
        qh = jnp.moveaxis(qb, 1, 0)                        # (H, S, D)
        kv_idx = jnp.arange(h) // g
        kb_h = jnp.moveaxis(kb, 1, 0)[kv_idx]              # (H, T, D)
        vb_h = jnp.moveaxis(vb, 1, 0)[kv_idx]
        out = jax.vmap(one_head)(qh, kb_h, vb_h)           # (H, S, D)
        return jnp.moveaxis(out, 0, 1)

    return jax.vmap(one_batch)(q, k, v)
