"""Depthwise causal conv1d Pallas kernel — the 1-D member of the paper's
block library, used by the Mamba/Jamba SSM path.

Depthwise convolution has no contraction dimension to feed the MXU, so this
is a Conv1-family (VPU) block: K shifted multiply-adds per tile.  Tiling:
sequence in row-tiles, channels across lanes (128-aligned).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, w_ref, o_ref, *, k, ts, c):
    i = pl.program_id(0)
    xpad = jax.lax.dynamic_slice(
        x_ref[...], (i * ts, 0), (ts + k - 1, c))
    wk = w_ref[...]
    acc = jnp.zeros((ts, c), jnp.float32)
    for j in range(k):                           # VPU multiply-add chain
        acc = acc + xpad[j:j + ts, :].astype(jnp.float32) * \
            wk[j][None, :].astype(jnp.float32)
    o_ref[...] = acc


def causal_conv1d_pallas(x, w, *, tile_s: int = 128,
                         interpret: bool = True):
    """x: (B, S, C); w: (K, C).  Returns (B, S, C) float32 (pre-silu).

    Batched by vmap over B; each call tiles the sequence with a K-1 halo.
    """
    k, c = w.shape
    b, s, cc = x.shape
    assert cc == c
    ts = min(tile_s, s)
    pad_s = (-s) % ts

    def one(xb):
        xp = jnp.pad(xb, ((k - 1, pad_s), (0, 0)))   # causal left-pad
        grid = (s + pad_s) // ts
        y = pl.pallas_call(
            functools.partial(_kernel, k=k, ts=ts, c=c),
            grid=(grid,),
            in_specs=[pl.BlockSpec(xp.shape, lambda i: (0, 0)),
                      pl.BlockSpec(w.shape, lambda i: (0, 0))],
            out_specs=pl.BlockSpec((ts, c), lambda i: (i, 0)),
            out_shape=jax.ShapeDtypeStruct((s + pad_s, c), jnp.float32),
            interpret=interpret,
        )(xp, w)
        return y[:s]

    return jax.vmap(one)(x)
