"""Jit'd public wrappers for the kernel library + quantization helpers."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import conv2d, conv1d, ref


def quantize_fixed(x, bits: int, *, signed: bool = True):
    """Clamp float/int data into a ``bits``-bit signed fixed-point range and
    store it in the smallest integer container."""
    lo = -(1 << (bits - 1)) if signed else 0
    hi = (1 << (bits - 1)) - 1 if signed else (1 << bits) - 1
    q = jnp.clip(jnp.round(x), lo, hi)
    return q.astype(conv2d.container_dtype(bits))


@functools.partial(jax.jit, static_argnames=("block", "data_bits",
                                             "coeff_bits", "tile_h",
                                             "interpret"))
def conv_block(block, x, w, *, data_bits, coeff_bits, tile_h=16,
               interpret=True):
    return conv2d.conv_block(block, x, w, data_bits=data_bits,
                             coeff_bits=coeff_bits, tile_h=tile_h,
                             interpret=interpret)


def conv_block_ref(block, x, w, **kw):
    return ref.conv_block_ref(block, x, w, **kw)


@functools.partial(jax.jit, static_argnames=("interpret",))
def causal_conv1d(x, w, interpret=True):
    return conv1d.causal_conv1d_pallas(x, w, interpret=interpret)


def causal_conv1d_ref(x, w, conv_state=None):
    return ref.causal_conv1d_ref(x, w, conv_state)
