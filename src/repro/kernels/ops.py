"""Jit'd public wrappers for the kernel library + quantization helpers.

``conv_block``/``conv_block_ref`` survive only as deprecated shims over
the ``repro.blocks`` registry — use ``get_block(name).apply(...)`` /
``.reference(...)`` instead.
"""

from __future__ import annotations

import functools
import warnings

import jax
import jax.numpy as jnp

from repro.kernels import conv2d, conv1d, ref


def quantize_fixed(x, bits: int, *, signed: bool = True):
    """Clamp float/int data into a ``bits``-bit signed fixed-point range and
    store it in the smallest integer container."""
    lo = -(1 << (bits - 1)) if signed else 0
    hi = (1 << (bits - 1)) - 1 if signed else (1 << bits) - 1
    q = jnp.clip(jnp.round(x), lo, hi)
    return q.astype(conv2d.container_dtype(bits))


def conv_block(block, x, w, *, data_bits, coeff_bits, tile_h=16,
               interpret=True):
    """Deprecated string-dispatch shim; use
    ``repro.blocks.get_block(block).apply(...)``."""
    warnings.warn(
        "ops.conv_block is deprecated; use "
        "repro.blocks.get_block(name).apply(...)",
        DeprecationWarning, stacklevel=2)
    from repro.blocks import get_block
    try:
        blk = get_block(block)
    except KeyError as e:       # preserve the seed contract (ValueError)
        raise ValueError(f"unknown block {block!r}") from e
    return blk.apply(x, w, data_bits=data_bits, coeff_bits=coeff_bits,
                     tile_h=tile_h, interpret=interpret)


def conv_block_ref(block, x, w, **kw):
    """Deprecated shim; use ``repro.blocks.get_block(block).reference``."""
    warnings.warn(
        "ops.conv_block_ref is deprecated; use "
        "repro.blocks.get_block(name).reference(...)",
        DeprecationWarning, stacklevel=2)
    del kw  # legacy signature compatibility
    from repro.blocks import get_block
    return get_block(block).reference(x, w)


@functools.partial(jax.jit, static_argnames=("interpret",))
def causal_conv1d(x, w, interpret=True):
    return conv1d.causal_conv1d_pallas(x, w, interpret=interpret)


def causal_conv1d_ref(x, w, conv_state=None):
    return ref.causal_conv1d_ref(x, w, conv_state)
