"""The paper's four parameterizable convolution blocks as Pallas TPU kernels.

FPGA→TPU adaptation (DESIGN.md §2): fixed-point 3×3 convolution over an
image tile streamed through VMEM, one output row-tile per grid step ("one
convolution per cycle" → one tile per grid step).

  Conv1  multiply-free shift-add (VPU / LUT+carry-chain analogue):
         each coefficient multiply is unrolled into ``coeff_bits``
         mask-and-add passes — op count is *linear in coeff_bits*,
         zero MXU work.
  Conv2  im2col + one integer dot on the MXU (1-DSP analogue).
  Conv3  two coefficient planes packed into one integer operand
         (w_hi·2^S + w_lo): a single dot yields both convolutions,
         split arithmetically after accumulation.  Valid while both
         results fit the 32-bit accumulator guard bits
         (data_bits + coeff_bits ≤ 12 — the TPU analogue of the paper's
         ≤8-bit DSP-packing constraint; the FPGA DSP48 has a 48-bit
         accumulator where int TPU lanes have 32).  Outside that regime
         the block degrades to two dots — the discontinuity the paper's
         segmented regression models.
  Conv4  two parallel dots (2-DSP analogue), two convolutions per step.

Containers: data/coeff values quantized to ``*_bits`` live in the smallest
supported integer container (int8 ≤ 8 bits, else int16); arithmetic is
exact in int32.  The padded image is staged into VMEM in its *container*
dtype (kernels widen per-tile), so the VMEM working set scales with the
data container width — mirrored by ``synth._vmem_bytes``.

Block selection lives in ``repro.blocks`` (the ConvBlock registry); this
module only provides the kernel bodies and the ``pallas_call`` runner.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

PACK_SHIFT_BUDGET = 31          # int32 accumulator bits
PACKED_LIMIT = 12               # data_bits + coeff_bits ≤ 12 → packed mode


def container_dtype(bits: int):
    return jnp.int8 if bits <= 8 else jnp.int16


def conv3_packed_ok(data_bits: int, coeff_bits: int) -> bool:
    return data_bits + coeff_bits <= PACKED_LIMIT


def _pack_shift(data_bits: int, coeff_bits: int) -> int:
    # |y| <= 9 · 2^(d-1) · 2^(c-1) < 2^(d+c+2); one guard bit for sign.
    return data_bits + coeff_bits + 3


# ---------------------------------------------------------------------------
# kernel bodies (operate on one padded row-tile in VMEM)
# ---------------------------------------------------------------------------

def _taps(xpad, th, w):
    """9 shifted (th, w) views of the (th+2, w+2) padded tile."""
    return [xpad[di:di + th, dj:dj + w]
            for di in range(3) for dj in range(3)]


def _acc_dtype(data_bits: int, coeff_bits: int):
    """Narrowest safe accumulator for 9 taps of d-bit × c-bit products:
    needs d+c-1 product bits + 4 accumulation bits + sign.  Narrow
    accumulation doubles VPU lane throughput — the TPU analogue of the
    datapath-width ∝ LUT-count effect the paper measures."""
    need = data_bits + coeff_bits + 5
    return jnp.int16 if need <= 16 else jnp.int32


def conv1_kernel(x_ref, w_ref, o_ref, *, th, w, data_bits, coeff_bits):
    i = pl.program_id(0)
    adt = _acc_dtype(data_bits, coeff_bits)
    xpad = jax.lax.dynamic_slice(
        x_ref[...], (i * th, 0), (th + 2, w + 2)).astype(adt)
    wk = w_ref[...].astype(adt)
    acc = jnp.zeros((th, w), adt)
    taps = _taps(xpad, th, w)
    for t, (di, dj) in enumerate((a, b) for a in range(3) for b in range(3)):
        c = wk[di, dj]
        mag = jnp.abs(c)
        sign = jnp.where(c < 0, adt(-1), adt(1))
        part = jnp.zeros((th, w), adt)
        for b in range(coeff_bits):          # unrolled: ops ∝ coeff_bits
            bit = (mag >> b) & 1
            part = part + jnp.where(bit == 1,
                                    taps[t] << b,
                                    jnp.zeros((th, w), adt))
        acc = acc + sign * part
    o_ref[...] = acc.astype(jnp.int32)


def _im2col(xpad, th, w):
    return jnp.stack(_taps(xpad, th, w), axis=-1).reshape(th * w, 9)


def _dot_dtype(data_bits: int, coeff_bits: int):
    """Keep native int8 operands when possible: the MXU's low-precision
    rate is the analogue of fitting the DSP's 27×18 multiplier."""
    return jnp.int8 if (data_bits <= 8 and coeff_bits <= 8) else jnp.int32


def conv2_kernel(x_ref, w_ref, o_ref, *, th, w, data_bits, coeff_bits):
    i = pl.program_id(0)
    ddt = _dot_dtype(data_bits, coeff_bits)
    xpad = jax.lax.dynamic_slice(
        x_ref[...], (i * th, 0), (th + 2, w + 2)).astype(ddt)
    patches = _im2col(xpad, th, w)
    wk = w_ref[...].astype(ddt).reshape(9)
    y = jax.lax.dot_general(patches, wk[:, None], (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.int32)
    o_ref[...] = y.reshape(th, w)


def conv3_kernel(x_ref, w_ref, o_ref, *, th, w, data_bits, coeff_bits):
    i = pl.program_id(0)
    xpad = jax.lax.dynamic_slice(
        x_ref[...], (i * th, 0), (th + 2, w + 2)).astype(jnp.int32)
    patches = _im2col(xpad, th, w)
    wk = w_ref[...].astype(jnp.int32)            # (2, 3, 3)
    if conv3_packed_ok(data_bits, coeff_bits):
        s = _pack_shift(data_bits, coeff_bits)
        packed = (wk[0].reshape(9) << s) + wk[1].reshape(9)
        acc = jax.lax.dot_general(
            patches, packed[:, None], (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.int32).reshape(th, w)
        half = jnp.int32(1 << (s - 1))
        lo = ((acc + half) & ((1 << s) - 1)) - half      # signed low field
        hi = (acc - lo) >> s
        o_ref[0] = hi
        o_ref[1] = lo
    else:  # fallback: packing infeasible → two dots (degenerates to Conv4)
        ddt = _dot_dtype(data_bits, coeff_bits)
        for j in range(2):
            y = jax.lax.dot_general(
                patches.astype(ddt), wk[j].reshape(9)[:, None].astype(ddt),
                (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.int32)
            o_ref[j] = y.reshape(th, w)


def conv4_kernel(x_ref, w_ref, o_ref, *, th, w, data_bits, coeff_bits):
    i = pl.program_id(0)
    ddt = _dot_dtype(data_bits, coeff_bits)
    xpad = jax.lax.dynamic_slice(
        x_ref[...], (i * th, 0), (th + 2, w + 2)).astype(ddt)
    patches = _im2col(xpad, th, w)
    wk = w_ref[...].astype(ddt)                  # (2, 3, 3)
    for j in range(2):                           # two parallel "DSPs"
        y = jax.lax.dot_general(
            patches, wk[j].reshape(9)[:, None], (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.int32)
        o_ref[j] = y.reshape(th, w)


# ---------------------------------------------------------------------------
# pallas_call wrappers
# ---------------------------------------------------------------------------

def _call(kernel, xpad, wk, *, th, w, n_out, interpret):
    grid = (xpad.shape[0] - 2) // th
    out_shape = ((n_out, th * grid, w) if n_out > 1
                 else (th * grid, w))
    out_block = ((n_out, th, w) if n_out > 1 else (th, w))
    out_index = ((lambda i: (0, i, 0)) if n_out > 1
                 else (lambda i: (i, 0)))
    return pl.pallas_call(
        kernel,
        grid=(grid,),
        in_specs=[
            pl.BlockSpec(xpad.shape, lambda i: (0, 0)),   # whole image VMEM
            pl.BlockSpec(wk.shape, (lambda i: (0, 0)) if wk.ndim == 2
                         else (lambda i: (0, 0, 0))),
        ],
        out_specs=pl.BlockSpec(out_block, out_index),
        out_shape=jax.ShapeDtypeStruct(out_shape, jnp.int32),
        interpret=interpret,
    )(xpad, wk)


def run_block_kernel(kernel, x, wk, *, n_out: int, tile_h: int = 16,
                     interpret: bool = True):
    """Pad + run one block kernel body.  x: (H, W) container int; wk:
    (3,3) or (2,3,3).  Returns int32 conv output ((H, W) or (2, H, W)),
    zero-padded 'same' semantics.  The pad keeps the data container
    dtype — VMEM footprint scales with the container width; kernels
    widen per-tile.  Dispatch by block lives in ``repro.blocks``."""
    h, w = x.shape
    assert h % tile_h == 0, (h, tile_h)
    xpad = jnp.pad(x, ((1, 1), (1, 1)))
    return _call(kernel, xpad, wk, th=tile_h, w=w, n_out=n_out,
                 interpret=interpret)
