"""Pure-jnp oracles for every Pallas kernel (exact integer arithmetic)."""

from __future__ import annotations

import jax.numpy as jnp


def conv2d_3x3_ref(x, wk):
    """'same' zero-padded 3×3 convolution (cross-correlation, matching the
    kernels).  x: (H, W) any int dtype; wk: (3, 3).  Returns int32."""
    h, w = x.shape
    xpad = jnp.pad(x.astype(jnp.int32), ((1, 1), (1, 1)))
    acc = jnp.zeros((h, w), jnp.int32)
    for di in range(3):
        for dj in range(3):
            acc = acc + xpad[di:di + h, dj:dj + w] * \
                wk[di, dj].astype(jnp.int32)
    return acc


def conv_block_ref(block: str, x, wk, **_):
    """Oracle for ops.conv_block: conv1/conv2 -> (H,W); conv3/conv4 ->
    (2,H,W) (both coefficient planes)."""
    if block in ("conv1", "conv2"):
        return conv2d_3x3_ref(x, wk)
    return jnp.stack([conv2d_3x3_ref(x, wk[0]), conv2d_3x3_ref(x, wk[1])])


def causal_conv1d_ref(x, w, conv_state=None):
    """Depthwise causal conv (pre-activation).  x: (B,S,C); w: (K,C)."""
    k = w.shape[0]
    b, s, c = x.shape
    if conv_state is None:
        conv_state = jnp.zeros((b, k - 1, c), x.dtype)
    xp = jnp.concatenate([conv_state, x], axis=1)
    y = sum(xp[:, i:i + s, :].astype(jnp.float32)
            * w[i][None, None, :].astype(jnp.float32) for i in range(k))
    return y
