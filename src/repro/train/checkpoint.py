"""Mesh-agnostic, atomic, fault-tolerant checkpointing.

Design (for 1000+-node deployments):
  * leaves are written per-file under a step directory, with a JSON
    manifest (tree structure, shapes, dtypes, step, config digest);
  * writes go to ``<step>.tmp`` then ``os.rename`` → a crash mid-write can
    never corrupt the latest checkpoint (restore only sees committed dirs);
  * arrays are saved *unsharded* (gathered), so restore works on ANY mesh
    or device count — this is what makes elastic rescaling after a node
    failure a restore, not a reshard job;
  * ``keep`` bounds disk usage; restore picks the newest committed step.

On a real multi-host deployment the per-leaf writes become
process-local-shard writes with the same manifest/rename protocol (each
host writes its addressable shards); the protocol here is the same code
path jax.Array makes multi-host-safe via ``jax.device_get`` on fully
replicated/gathered arrays.
"""

from __future__ import annotations

import hashlib
import json
import os
import shutil
from pathlib import Path
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np


def _flatten(tree) -> Dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        flat[key] = leaf
    return flat


def _unflatten_like(template, flat: Dict[str, Any]):
    paths = jax.tree_util.tree_flatten_with_path(template)[0]
    leaves = []
    for path, leaf in paths:
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        if key not in flat:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        arr = flat[key]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(
                f"shape mismatch for {key}: ckpt {arr.shape} vs "
                f"model {leaf.shape}")
        leaves.append(arr.astype(leaf.dtype))
    treedef = jax.tree_util.tree_structure(template)
    return jax.tree_util.tree_unflatten(treedef, leaves)


class Checkpointer:
    def __init__(self, directory: str | Path, keep: int = 3):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep

    # -- save -------------------------------------------------------------
    def save(self, step: int, state: Dict[str, Any],
             metadata: Optional[Dict] = None):
        final = self.dir / f"step_{step:010d}"
        tmp = self.dir / f"step_{step:010d}.tmp"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        flat = _flatten(state)
        manifest = {"step": step, "leaves": {}, "metadata": metadata or {}}
        for key, leaf in flat.items():
            arr = jax.device_get(leaf)
            orig_dtype = str(arr.dtype)
            if orig_dtype not in ("float32", "float64", "int32", "int64",
                                  "int8", "uint8", "int16", "uint16",
                                  "uint32", "uint64", "bool"):
                # bfloat16 & friends: store losslessly as float32
                arr = np.asarray(arr, np.float32)
            else:
                arr = np.asarray(arr)
            fname = hashlib.sha1(key.encode()).hexdigest()[:16] + ".npy"
            np.save(tmp / fname, arr)
            manifest["leaves"][key] = {
                "file": fname, "shape": list(arr.shape),
                "dtype": orig_dtype}
        (tmp / "manifest.json").write_text(json.dumps(manifest))
        if final.exists():
            shutil.rmtree(final)
        os.rename(tmp, final)                      # atomic commit
        self._gc()
        return final

    def _gc(self):
        steps = sorted(self.all_steps())
        for s in steps[:-self.keep]:
            shutil.rmtree(self.dir / f"step_{s:010d}", ignore_errors=True)

    # -- restore -----------------------------------------------------------
    def all_steps(self):
        out = []
        for p in self.dir.glob("step_*"):
            if p.suffix == ".tmp" or not (p / "manifest.json").exists():
                continue
            out.append(int(p.name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, template, step: Optional[int] = None
                ) -> Tuple[int, Any]:
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {self.dir}")
        d = self.dir / f"step_{step:010d}"
        manifest = json.loads((d / "manifest.json").read_text())
        flat = {}
        for key, info in manifest["leaves"].items():
            flat[key] = np.load(d / info["file"])
        return step, _unflatten_like(template, flat)
