"""Fault-tolerant training loop.

Production behaviors implemented (and exercised by tests):
  * checkpoint/restart — periodic atomic checkpoints; on (re)start the loop
    restores the newest committed step and the data pipeline resumes from
    it deterministically (pipeline is a pure function of the step index);
  * preemption safety — SIGTERM/KeyboardInterrupt triggers a final
    checkpoint before exit (simulated preemptions in tests inject failures
    at arbitrary steps);
  * elastic rescale — checkpoints are mesh-agnostic; restore works on a
    different device count / mesh shape than the save;
  * straggler visibility — per-step wall-time ring buffer with p50/p95/max
    published every log interval; on real multi-host deployments this is
    the signal the controller uses to evict slow hosts (the SPMD step
    itself cannot skip a straggler — mitigation is restart-without-host,
    which the elastic restore above makes cheap).
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Optional

import jax
import numpy as np

from repro.data import DataConfig, make_pipeline
from repro.data.pipeline import batch_at
from repro.optim import AdamWConfig, adamw_init
from repro.train.checkpoint import Checkpointer
from repro.train.step import make_train_step


@dataclass
class TrainConfig:
    steps: int = 100
    lr: float = 3e-4
    log_every: int = 10
    ckpt_every: int = 50
    ckpt_dir: str = "/tmp/repro_ckpt"
    ckpt_keep: int = 3
    microbatches: int = 1
    opt: AdamWConfig = field(default_factory=AdamWConfig)
    fail_at_step: Optional[int] = None   # test hook: simulated preemption


class StepTimer:
    def __init__(self, window: int = 100):
        self.times = []
        self.window = window

    def add(self, dt: float):
        self.times.append(dt)
        self.times = self.times[-self.window:]

    def stats(self):
        if not self.times:
            return {}
        a = np.array(self.times)
        return {"p50_ms": float(np.percentile(a, 50) * 1e3),
                "p95_ms": float(np.percentile(a, 95) * 1e3),
                "max_ms": float(np.max(a) * 1e3)}


def train(model, data_cfg: DataConfig, tcfg: TrainConfig,
          *, params=None, log: Callable = print):
    """Runs (or resumes) training; returns (params, opt_state, history)."""
    ckpt = Checkpointer(tcfg.ckpt_dir, keep=tcfg.ckpt_keep)
    step_fn = jax.jit(make_train_step(model, tcfg.opt, lr=tcfg.lr,
                                      microbatches=tcfg.microbatches),
                      donate_argnums=(0, 1))

    if params is None:
        params = model.init(jax.random.PRNGKey(0))
    opt_state = adamw_init(params, tcfg.opt)
    start = 0

    latest = ckpt.latest_step()
    if latest is not None:
        start, state = ckpt.restore({"params": params, "opt": opt_state})
        params, opt_state = state["params"], state["opt"]
        log(f"[train] resumed from step {start}")

    timer = StepTimer()
    history = []
    step = start
    try:
        for step in range(start, tcfg.steps):
            if tcfg.fail_at_step is not None and step == tcfg.fail_at_step:
                raise RuntimeError(f"simulated preemption at step {step}")
            batch = batch_at(data_cfg, step)
            t0 = time.time()
            params, opt_state, metrics = step_fn(params, opt_state, batch)
            jax.block_until_ready(metrics["loss"])
            timer.add(time.time() - t0)
            if (step + 1) % tcfg.log_every == 0 or step == start:
                m = {k: float(v) for k, v in metrics.items()}
                m.update(timer.stats())
                history.append({"step": step + 1, **m})
                log(f"[train] step {step + 1}: loss={m['loss']:.4f} "
                    f"p50={m.get('p50_ms', 0):.0f}ms "
                    f"p95={m.get('p95_ms', 0):.0f}ms")
            if (step + 1) % tcfg.ckpt_every == 0:
                ckpt.save(step + 1, {"params": params, "opt": opt_state})
    except (KeyboardInterrupt, RuntimeError):
        # preemption path: commit progress before propagating
        ckpt.save(step, {"params": params, "opt": opt_state})
        raise
    ckpt.save(tcfg.steps, {"params": params, "opt": opt_state})
    return params, opt_state, history
