"""Train / serve step builders (jit-able, mesh-agnostic pure functions)."""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.optim import AdamWConfig, adamw_update
from repro.parallel.compress import compress_grads_int8, decompress_grads


def make_train_step(model, opt_cfg: AdamWConfig, *, lr: float = 3e-4,
                    microbatches: int = 1, grad_compression: bool = False):
    """Returns step(params, opt_state, batch) -> (params, opt_state, metrics).

    ``microbatches > 1`` accumulates gradients over a ``lax.scan`` of
    microbatch slices — activation memory drops by the factor, and XLA
    overlaps each microbatch's gradient all-reduce with the next
    microbatch's compute.
    ``grad_compression`` rounds gradients through the int8 block codec
    before the (GSPMD-inserted) data-parallel reduction.
    """

    def loss_fn(params, batch):
        loss, metrics = model.forward_train(params, batch)
        return loss, metrics

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def single(params, batch):
        (loss, metrics), grads = grad_fn(params, batch)
        return loss, metrics, grads

    def accumulate(params, batch):
        def slice_mb(i, x):
            mb = x.shape[0] // microbatches
            return jax.lax.dynamic_slice_in_dim(x, i * mb, mb, axis=0)

        def body(carry, i):
            acc, = carry
            mb_batch = jax.tree.map(functools.partial(slice_mb, i), batch)
            loss, metrics, grads = single(params, mb_batch)
            acc = jax.tree.map(jnp.add, acc, grads)
            return (acc,), (loss, metrics)

        zeros = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (acc,), (losses, metricses) = jax.lax.scan(
            body, (zeros,), jnp.arange(microbatches))
        grads = jax.tree.map(lambda g: g / microbatches, acc)
        loss = jnp.mean(losses)
        metrics = jax.tree.map(jnp.mean, metricses)
        return loss, metrics, grads

    def step(params, opt_state, batch, step_idx=None):
        if microbatches > 1:
            loss, metrics, grads = accumulate(params, batch)
        else:
            loss, metrics, grads = single(params, batch)
        if grad_compression:
            grads = decompress_grads(compress_grads_int8(grads), grads)
        params, opt_state, opt_metrics = adamw_update(
            grads, opt_state, params, lr, opt_cfg)
        metrics = dict(metrics, loss=loss, **opt_metrics)
        return params, opt_state, metrics

    return step


def make_serve_steps(model):
    """Returns (prefill_fn, decode_fn) suitable for jit."""

    def prefill_fn(params, batch):
        return model.prefill(params, batch)

    def decode_fn(params, cache, token, pos):
        return model.decode_step(params, cache, token, pos)

    return prefill_fn, decode_fn
