"""GPipe-style pipeline parallelism via shard_map + collective_permute.

For deployments beyond one pod, the ``pod`` axis can run as a pipeline
axis instead of outer-DP: each pod holds a contiguous span of layer
cycles, microbatches stream through stages with ``jax.lax.ppermute``
boundary transfers, and the bubble fraction is (S-1)/(M+S-1) for S stages
and M microbatches.

This module implements the schedule generically over a user-supplied
``stage_fn(stage_params, x) -> x`` so it composes with the model zoo's
stacked-cycle parameters: stage s owns cycles [s·C/S, (s+1)·C/S).

The rotating-buffer formulation below runs every stage every tick on its
current microbatch (SPMD-friendly: no per-stage control flow), which is
the standard JAX pipelining pattern.
"""

from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map


def pipeline_forward(stage_fn: Callable, stage_params, x_microbatches,
                     *, mesh: Mesh, axis: str = "pipe"):
    """Run M microbatches through S pipeline stages.

    stage_params: pytree whose leaves lead with the stage axis (sharded
      over ``axis``);
    x_microbatches: (M, mb, ...) activations (replicated across ``axis``).
    Returns (M, mb, ...) outputs from the LAST stage.
    """
    n_stages = mesh.shape[axis]
    m = x_microbatches.shape[0]

    def stage_local(params, xs):
        # params: leaves (1, ...) — this stage's slice; xs: (M, mb, d)
        params = jax.tree.map(lambda p: p[0], params)
        idx = jax.lax.axis_index(axis)
        total = m + n_stages - 1

        def tick(carry, t):
            buf, outs = carry
            # stage 0 injects microbatch t (or zeros when drained)
            inject = jnp.where(t < m, t, 0)
            x0 = xs[inject]
            x_in = jnp.where(idx == 0, x0, buf)
            y = stage_fn(params, x_in)
            # pass to next stage
            nxt = jax.lax.ppermute(
                y, axis, [(i, i + 1) for i in range(n_stages - 1)])
            # last stage emits microbatch t - (S-1)
            emit_t = t - (n_stages - 1)
            outs = jax.lax.cond(
                emit_t >= 0,
                lambda o: o.at[jnp.maximum(emit_t, 0)].set(y),
                lambda o: o, outs)
            return (nxt, outs), None

        buf0 = jnp.zeros_like(xs[0])
        outs0 = jnp.zeros_like(xs)
        (_, outs), _ = jax.lax.scan(
            tick, (buf0, outs0), jnp.arange(total))
        # only the last stage's outs are real; broadcast them back
        gathered = jax.lax.all_gather(outs, axis)      # (S, M, mb, d)
        return gathered[n_stages - 1]

    spec_params = jax.tree.map(lambda _: P(axis), stage_params)
    fn = shard_map(stage_local, mesh=mesh,
                   in_specs=(spec_params, P()), out_specs=P(),
                   check_rep=False)
    return fn(stage_params, x_microbatches)


def bubble_fraction(n_stages: int, n_microbatches: int) -> float:
    return (n_stages - 1) / (n_microbatches + n_stages - 1)
