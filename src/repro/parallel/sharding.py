"""Sharding rules: param/batch/cache PartitionSpecs for DP / TP / EP / SP.

Two weight-sharding modes:

* ``tp``   — tensor parallelism only: heads / FFN-hidden / experts / vocab
             sharded over the ``model`` axis; weights replicated across the
             data axes.  Matches the classic Megatron layout.
* ``fsdp`` — additionally shards every weight's largest remaining dimension
             over the data axes (ZeRO-3 style); XLA inserts per-cycle
             all-gathers.  Required for the ~400B configs to fit v5e HBM.

Rules are *path-driven* over the parameter pytree, so they apply uniformly
to every architecture in the zoo.  Any dimension that does not divide the
mesh axis stays unsharded (e.g. Granite's single KV head).
"""

from __future__ import annotations

import re
from typing import Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig


def mesh_axes(mesh: Mesh) -> Tuple[Tuple[str, ...], str]:
    """Returns (data_axes, model_axis) for single- or multi-pod meshes."""
    names = mesh.axis_names
    if "pod" in names:
        return ("pod", "data"), "model"
    return ("data",), "model"


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def _divides(dim: int, size: int) -> bool:
    return size > 0 and dim % size == 0


class ShardingRules:
    def __init__(self, cfg: ModelConfig, mesh: Mesh, mode: str = "tp"):
        assert mode in ("tp", "fsdp")
        self.cfg = cfg
        self.mesh = mesh
        self.mode = mode
        self.dp, self.tp = mesh_axes(mesh)
        self.tp_size = mesh.shape[self.tp]
        self.dp_size = 1
        for a in self.dp:
            self.dp_size *= mesh.shape[a]

    # -- helpers ---------------------------------------------------------
    def _fsdp_wrap(self, spec: Tuple, shape: Tuple[int, ...]) -> P:
        """In fsdp mode, shard the largest unsharded dim over the data axes.

        Leading stacked-cycle dims (handled by caller) are not candidates.
        """
        if self.mode != "fsdp":
            return P(*spec)
        spec = list(spec)
        cands = sorted(
            (i for i in range(len(spec))
             if spec[i] is None and _divides(shape[i], self.dp_size)),
            key=lambda i: -shape[i])
        if cands:
            spec[cands[0]] = self.dp if len(self.dp) > 1 else self.dp[0]
        return P(*spec)

    def _leaf_spec(self, path: str, shape: Tuple[int, ...]) -> P:
        tp, cfg = self.tp, self.cfg
        stacked = path.startswith("stack/") or path.startswith("enc_stack/")
        core = shape[1:] if stacked else shape

        def out(*spec):
            spec = self._fsdp_wrap(spec, core)
            if stacked:
                return P(None, *spec)
            return spec

        leaf = path.rsplit("/", 1)[-1]
        # --- embeddings ------------------------------------------------
        if leaf == "embed":
            if cfg.tie_embeddings and _divides(shape[0], self.tp_size):
                return P(tp, None)       # vocab-sharded: free tied unembed
            if _divides(shape[1], self.tp_size):
                return P(None, tp)       # d_model-sharded: free gather
            return P(None, None)
        if leaf == "unembed":
            return P(None, tp) if _divides(shape[1], self.tp_size) \
                else P(None, None)
        # --- attention ---------------------------------------------------
        if leaf == "wq" or (leaf in ("wk", "wv")):
            h = core[1]
            return out(None, tp if _divides(h, self.tp_size) else None, None)
        if leaf == "wo":
            h = core[0]
            return out(tp if _divides(h, self.tp_size) else None, None, None)
        # --- MoE -----------------------------------------------------------
        if re.search(r"moe/(w_up|w_gate)$", path):
            return out(tp if _divides(core[0], self.tp_size) else None,
                       None, None)
        if re.search(r"moe/w_down$", path):
            return out(tp if _divides(core[0], self.tp_size) else None,
                       None, None)
        if leaf == "router":
            return out(None, None)
        if leaf in ("shared_up", "shared_gate"):
            return out(None, tp if _divides(core[1], self.tp_size) else None)
        if leaf == "shared_down":
            return out(tp if _divides(core[0], self.tp_size) else None, None)
        # --- dense MLP ------------------------------------------------------
        if leaf in ("w_up", "w_gate"):
            return out(None, tp if _divides(core[1], self.tp_size) else None)
        if leaf == "w_down":
            return out(tp if _divides(core[0], self.tp_size) else None, None)
        # --- mamba ------------------------------------------------------------
        if leaf in ("w_z", "w_x"):
            return out(None, tp if _divides(core[1], self.tp_size) else None)
        if leaf in ("w_B", "w_C", "conv_B", "conv_C"):
            return out(*(None,) * len(core))
        if leaf == "w_dt":
            return out(None, tp if _divides(core[1], self.tp_size) else None)
        if leaf == "conv_x":
            return out(None, tp if _divides(core[1], self.tp_size) else None)
        if leaf in ("dt_bias", "a_log", "d_skip"):
            return out(tp if _divides(core[0], self.tp_size) else None)
        if leaf == "norm" and len(core) == 1 and core[0] != cfg.d_model:
            return out(tp if _divides(core[0], self.tp_size) else None)
        if leaf == "w_out":
            return out(tp if _divides(core[0], self.tp_size) else None, None)
        # --- norms & everything else: replicated ---------------------------
        return out(*(None,) * len(core))

    # -- public ------------------------------------------------------------
    def params_spec(self, params_shapes):
        def spec(path, leaf):
            return self._leaf_spec(_path_str(path), leaf.shape)
        return jax.tree_util.tree_map_with_path(spec, params_shapes)

    def params_sharding(self, params_shapes):
        return jax.tree.map(lambda s: NamedSharding(self.mesh, s),
                            self.params_spec(params_shapes))

    # -- activations ---------------------------------------------------------
    def batch_spec(self, batch_shapes):
        dp = self.dp if len(self.dp) > 1 else self.dp[0]

        def spec(path, leaf):
            b = leaf.shape[0]
            lead = dp if _divides(b, self.dp_size) else None
            return P(lead, *(None,) * (len(leaf.shape) - 1))
        return jax.tree_util.tree_map_with_path(spec, batch_shapes)

    def cache_spec(self, cache_shapes):
        """Decode cache: batch over data if divisible, else sequence (SP);
        head-like dims over model when divisible."""
        dp = self.dp if len(self.dp) > 1 else self.dp[0]

        def spec(path, leaf):
            shape = leaf.shape  # leading dim = n_cycles
            p = _path_str(path).rsplit("/", 1)[-1]
            s = [None] * len(shape)
            if len(shape) >= 2:
                if _divides(shape[1], self.dp_size):
                    s[1] = dp            # batch over data axes
                elif p in ("k", "v", "ck", "cv") and len(shape) == 5 and \
                        _divides(shape[2], self.dp_size):
                    s[2] = dp            # SP: sequence over data axes
            if p in ("k", "v", "ck", "cv") and len(shape) == 5 and \
                    _divides(shape[3], self.tp_size):
                s[3] = self.tp           # kv heads over model
            if p == "ssm" and len(shape) == 5 and \
                    _divides(shape[2], self.tp_size):
                s[2] = self.tp           # ssm heads over model
            if p in ("conv_x",) and len(shape) == 4 and \
                    _divides(shape[3], self.tp_size):
                s[3] = self.tp           # inner channels over model
            return P(*s)
        return jax.tree_util.tree_map_with_path(spec, cache_shapes)

    def opt_spec(self, opt_shapes, params_spec):
        """Optimizer-state specs: fp32 moments mirror the param specs;
        int8 block codecs shard the block dim over the data axes (ZeRO-1)."""
        flat_pspec = {
            _path_str(p): s for p, s in
            jax.tree_util.tree_flatten_with_path(params_spec)[0]}
        dp = self.dp if len(self.dp) > 1 else self.dp[0]

        def leaf(path, x):
            ps = _path_str(path)
            if ps == "step":
                return P()
            rest = ps.split("/", 1)[1]
            if rest.endswith("/codes") or rest.endswith("/scale"):
                lead = dp if _divides(x.shape[0], self.dp_size) else None
                return P(lead, *(None,) * (len(x.shape) - 1))
            if rest in flat_pspec:
                return flat_pspec[rest]
            return P(*(None,) * len(x.shape))
        return jax.tree_util.tree_map_with_path(leaf, opt_shapes)

    def to_sharding(self, spec_tree):
        return jax.tree.map(lambda s: NamedSharding(self.mesh, s), spec_tree)


def choose_mode(cfg: ModelConfig, mesh: Mesh) -> str:
    """Default policy: fsdp when TP-only weights would blow past ~8GB/chip."""
    tp_size = mesh.shape["model"]
    bytes_per_chip = cfg.param_count() * 2 / tp_size
    return "fsdp" if bytes_per_chip > 8e9 else "tp"


# ---------------------------------------------------------------------------
# CNN image batches (data-parallel multi-image serving)
#
# The CNN hot path has no tensor-parallel dimension worth sharding (whole
# layers fit one chip by construction — that is the deployment planner's
# job), so serving parallelism is pure DP: the (N, H, W, C) batch
# dimension over the data axes.  Used by ``core.cnn.cnn_forward(mesh=)``
# and the AOT bucketed runtime (``repro.runtime.CompiledCNN``, which the
# serve engine executes through): each batch-bucket executable places
# and constrains its bucket-sized batch with ``cnn_batch_sharding``.
# ---------------------------------------------------------------------------

def cnn_data_mesh(devices: Optional[Sequence] = None) -> Mesh:
    """1-D all-``data`` mesh over the host's devices for CNN serving."""
    devices = jax.devices() if devices is None else list(devices)
    return Mesh(np.asarray(devices), ("data",))


def cnn_batch_sharding(mesh: Mesh, batch: int) -> NamedSharding:
    """Sharding for an (N, H, W, C) image batch: N over the mesh's data
    axes when it divides their product, else replicated (the same
    divisibility rule every other spec here follows)."""
    if "data" in mesh.axis_names:
        axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    else:                          # bespoke mesh: first axis is the batch axis
        axes = (mesh.axis_names[0],)
    size = 1
    for a in axes:
        size *= mesh.shape[a]
    lead = None
    if _divides(batch, size):
        lead = axes if len(axes) > 1 else axes[0]
    return NamedSharding(mesh, P(lead, None, None, None))
