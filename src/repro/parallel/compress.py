"""Gradient compression: block-wise int8 round-trip ahead of the DP
all-reduce.

Under GSPMD the data-parallel gradient reduction is inserted by the
compiler, so "compressed all-reduce" is expressed as quantize → dequantize
around the point where the reduction happens: XLA reduces the
dequantized-but-8-bit-grained values.  The codec is shared with the 8-bit
optimizer (optim/adamw.py).  The explicit shard_map variant that reduces
raw int8 over the wire lives in parallel/collectives.py (§Perf).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.optim.adamw import dequantize_state, quantize_state


def compress_grads_int8(grads):
    return jax.tree.map(quantize_state, grads,
                        is_leaf=lambda x: isinstance(x, jnp.ndarray))


def decompress_grads(q, like):
    return jax.tree.map(
        lambda qq, g: dequantize_state(qq, g.shape).astype(g.dtype),
        q, like, is_leaf=lambda x: isinstance(x, dict) and "codes" in x)
