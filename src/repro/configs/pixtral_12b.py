"""Pixtral-12B — 40L d_model=5120 32H (GQA kv=8) d_ff=14336 vocab=131072.
Pixtral ViT frontend is a STUB: input_specs() provides precomputed patch
embeddings prepended to the token stream; the decoder is the Mistral-Nemo
backbone.  [hf:mistralai/Pixtral-12B-2409; unverified]"""

from repro.configs.base import ModelConfig, SubLayer, ATTN, DENSE, register

CONFIG = register(ModelConfig(
    name="pixtral-12b",
    family="vlm",
    n_layers=40,
    d_model=5120,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=131072,
    layer_cycle=(SubLayer(mixer=ATTN, mlp=DENSE),),
    frontend="vision",
    frontend_len=256,              # stub patch count per image
    rope_theta=1e6,
    act="silu",
    source="hf:mistralai/Pixtral-12B-2409; unverified",
))
