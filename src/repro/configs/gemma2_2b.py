"""Gemma-2-2B — 26L d_model=2304 8H (GQA kv=4) d_ff=9216 vocab=256000,
alternating local/global attention, logit softcaps.  [arXiv:2408.00118; hf]"""

from repro.configs.base import (ModelConfig, SubLayer, ATTN, LOCAL_ATTN,
                                DENSE, register)

CONFIG = register(ModelConfig(
    name="gemma2-2b",
    family="dense",
    n_layers=26,
    d_model=2304,
    n_heads=8,
    n_kv_heads=4,
    head_dim=256,
    d_ff=9216,
    vocab_size=256000,
    layer_cycle=(SubLayer(mixer=LOCAL_ATTN, mlp=DENSE),
                 SubLayer(mixer=ATTN, mlp=DENSE)),
    sliding_window=4096,
    attn_softcap=50.0,
    final_softcap=30.0,
    act="gelu",
    tie_embeddings=True,
    scale_embeddings=True,
    source="arXiv:2408.00118; hf",
))
