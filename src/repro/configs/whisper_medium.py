"""Whisper-medium — enc-dec, 24 encoder + 24 decoder layers, d_model=1024,
16H (MHA: kv=16), d_ff=4096, vocab=51865.  Conv frame frontend is a STUB:
input_specs() provides precomputed frame embeddings (1500 frames) as encoder
input.  [arXiv:2212.04356; unverified]"""

from repro.configs.base import ModelConfig, SubLayer, ATTN, DENSE, register

CONFIG = register(ModelConfig(
    name="whisper-medium",
    family="audio",
    n_layers=24,                   # decoder layers
    n_enc_layers=24,
    enc_dec=True,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    head_dim=64,
    d_ff=4096,
    vocab_size=51865,
    layer_cycle=(SubLayer(mixer=ATTN, mlp=DENSE),),
    frontend="audio",
    frontend_len=1500,             # stub mel-frame embeddings
    act="gelu",
    mlp_gated=False,               # plain 2-matrix GELU MLP
    source="arXiv:2212.04356; unverified",
))
