"""Llama-4-Maverick-400B-A17B — 48L d_model=5120 40H (GQA kv=8) d_ff=8192,
vocab=202048, MoE 128 experts top-1 + 1 shared expert, MoE interleaved every
other layer (dense MLP on the rest), early-fusion multimodal (text backbone
here).  [hf:meta-llama/Llama-4-Scout-17B-16E; unverified]

With d_ff_expert=8192 and MoE on alternate layers the total lands at ~400B
params with ~17B active — matching the a17b designation.
"""

from repro.configs.base import (ModelConfig, MoEConfig, SubLayer, ATTN, MOE,
                                DENSE, register)

CONFIG = register(ModelConfig(
    name="llama4-maverick-400b-a17b",
    family="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=202048,
    # interleaved: dense MLP layer, then MoE layer (cycle of 2)
    layer_cycle=(SubLayer(mixer=ATTN, mlp=DENSE),
                 SubLayer(mixer=ATTN, mlp=MOE)),
    moe=MoEConfig(num_experts=128, top_k=1, d_ff_expert=8192,
                  n_shared_experts=1),
    rope_theta=5e5,
    act="silu",
    source="hf:meta-llama/Llama-4-Scout-17B-16E; unverified",
))
