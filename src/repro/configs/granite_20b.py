"""Granite-20B (code) — dense, 52L d_model=6144 48H (MQA kv=1) d_ff=24576
vocab=49152, llama-style architecture.  [arXiv:2405.04324; hf]"""

from repro.configs.base import ModelConfig, SubLayer, ATTN, DENSE, register

CONFIG = register(ModelConfig(
    name="granite-20b",
    family="dense",
    n_layers=52,
    d_model=6144,
    n_heads=48,
    n_kv_heads=1,
    head_dim=128,
    d_ff=24576,
    vocab_size=49152,
    layer_cycle=(SubLayer(mixer=ATTN, mlp=DENSE),),
    act="gelu",
    mlp_gated=False,               # gpt-bigcode-style plain MLP
    source="arXiv:2405.04324; hf",
))
