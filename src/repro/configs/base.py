"""Config system: model/shape dataclasses, the arch registry and input specs.

Every assigned architecture is a ``ModelConfig`` built from a *layer cycle*:
a short repeating pattern of sublayers (attention / local-attention / mamba,
each optionally followed by a dense or MoE MLP).  The decoder stack is a
``lax.scan`` over ``n_layers // len(cycle)`` stacked cycles, which keeps
trace/compile time flat in depth even for the 72-layer Jamba config.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple

import jax.numpy as jnp

# ---------------------------------------------------------------------------
# Sublayer / cycle specification
# ---------------------------------------------------------------------------

# mixer kinds
ATTN = "attn"            # full (causal for decoder) attention
LOCAL_ATTN = "local"     # sliding-window attention
MAMBA = "mamba"          # Mamba-2 SSD block (includes its own gating/conv)

# mlp kinds
DENSE = "dense"
MOE = "moe"
NONE = "none"            # mamba blocks carry no separate MLP unless configured


@dataclass(frozen=True)
class SubLayer:
    """One (mixer, mlp) residual pair inside a layer cycle."""

    mixer: str = ATTN
    mlp: str = DENSE


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_ff_expert: int
    n_shared_experts: int = 0
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01


@dataclass(frozen=True)
class SSMConfig:
    state_dim: int = 128
    conv_kernel: int = 4
    expand: int = 2
    head_dim: int = 64
    n_groups: int = 1
    chunk_size: int = 256
    dt_min: float = 0.001
    dt_max: float = 0.1


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | vlm | audio | hybrid | ssm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int
    layer_cycle: Tuple[SubLayer, ...] = (SubLayer(),)
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    # encoder-decoder (whisper)
    enc_dec: bool = False
    n_enc_layers: int = 0
    frontend: Optional[str] = None   # None | "audio" | "vision"
    frontend_len: int = 0            # stub frames / patches
    # attention details
    sliding_window: int = 4096
    attn_softcap: Optional[float] = None
    final_softcap: Optional[float] = None
    rope_theta: float = 10000.0
    act: str = "silu"                # silu | gelu
    mlp_gated: bool = True           # gated (3-matrix) vs plain (2-matrix) MLP
    scale_embeddings: bool = False   # multiply embeddings by sqrt(d_model)
    # perf knobs (§Perf): resharding hints applied inside the model
    attn_batch_shard: bool = False   # shard attention over (data, model)
                                     # batch when heads don't divide TP
    attn_logits_bf16: bool = False   # keep attention logits in bf16
    moe_shard_hints: bool = False    # constrain expert buffers to
                                     # (E→model, capacity→data) sharding
    moe_groups: int = 1              # >1: route per token-group (aligned
                                     # to the data axis) — local dispatch,
                                     # no global sort/scatter collectives
    moe_combine_shardmap: bool = False  # explicit shard_map combine: one
                                        # psum(NL·D) instead of the k×
                                        # larger gather all-reduce
    remat_policy: str = "full"       # full | save_mixer_out — the latter
                                     # keeps sublayer outputs so backward
                                     # never re-runs forward collectives
    tie_embeddings: bool = False
    norm_eps: float = 1e-6
    dtype: str = "bfloat16"
    # notes carried into DESIGN/EXPERIMENTS
    source: str = ""

    # ---- derived -----------------------------------------------------
    @property
    def n_cycles(self) -> int:
        assert self.n_layers % len(self.layer_cycle) == 0, (
            f"{self.name}: n_layers={self.n_layers} not divisible by "
            f"cycle length {len(self.layer_cycle)}")
        return self.n_layers // len(self.layer_cycle)

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim

    @property
    def jnp_dtype(self):
        return jnp.dtype(self.dtype)

    def with_overrides(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # Parameter count (analytic; used by model_dse + roofline MODEL_FLOPS).
    def param_count(self) -> int:
        d, ff, v = self.d_model, self.d_ff, self.vocab_size
        total = v * d  # embed
        if not self.tie_embeddings:
            total += v * d
        def attn_params():
            return d * (self.q_dim + 2 * self.kv_dim) + self.q_dim * d
        n_mats = 3 if self.mlp_gated else 2
        def dense_mlp():
            return n_mats * d * ff
        def moe_mlp():
            m = self.moe
            per = n_mats * d * m.d_ff_expert
            return m.num_experts * per + m.n_shared_experts * per + d * m.num_experts
        def mamba_params():
            s = self.ssm
            inner = s.expand * d
            nh = inner // s.head_dim
            in_proj = d * (2 * inner + 2 * s.n_groups * s.state_dim + nh)
            conv = (inner + 2 * s.n_groups * s.state_dim) * s.conv_kernel
            out = inner * d
            return in_proj + conv + out + 2 * nh + inner
        per_cycle = 0
        for sub in self.layer_cycle:
            if sub.mixer in (ATTN, LOCAL_ATTN):
                per_cycle += attn_params()
            elif sub.mixer == MAMBA:
                per_cycle += mamba_params()
            if sub.mlp == DENSE:
                per_cycle += dense_mlp()
            elif sub.mlp == MOE:
                per_cycle += moe_mlp()
            per_cycle += 2 * d  # norms
        total += per_cycle * self.n_cycles
        if self.enc_dec:
            # encoder layers: attn + dense mlp; decoder adds cross-attn
            total += self.n_enc_layers * (attn_params() + dense_mlp() + 2 * d)
            total += self.n_layers * attn_params()  # cross attention
        return int(total)

    def active_param_count(self) -> int:
        """Params touched per token (MoE counts only routed top-k + shared)."""
        if self.moe is None:
            return self.param_count()
        m = self.moe
        per = 3 * self.d_model * m.d_ff_expert
        inactive = (m.num_experts - m.top_k) * per
        n_moe_layers = sum(1 for s in self.layer_cycle if s.mlp == MOE) * self.n_cycles
        return int(self.param_count() - n_moe_layers * inactive)


# ---------------------------------------------------------------------------
# Shapes
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}

# long_500k requires sub-quadratic context handling: run only for SSM/hybrid.
SUBQUADRATIC_FAMILIES = ("ssm", "hybrid")


def cell_is_runnable(cfg: ModelConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    if shape.name == "long_500k" and cfg.family not in SUBQUADRATIC_FAMILIES:
        return False, (f"{cfg.name} is a full-attention arch; long_500k needs "
                       "sub-quadratic attention (see DESIGN.md §4)")
    return True, ""


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: dict = {}


def register(cfg: ModelConfig) -> ModelConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    _ensure_loaded()
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; have {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def list_archs():
    _ensure_loaded()
    return sorted(_REGISTRY)


_LOADED = False

_ARCH_MODULES = [
    "qwen3_moe_30b_a3b", "llama4_maverick_400b_a17b", "pixtral_12b",
    "whisper_medium", "granite_20b", "gemma2_9b", "llama3_2_3b",
    "gemma2_2b", "jamba_1_5_large_398b", "mamba2_1_3b", "paper_conv",
]


def _ensure_loaded():
    global _LOADED
    if _LOADED:
        return
    import importlib
    for mod in _ARCH_MODULES:
        importlib.import_module(f"repro.configs.{mod}")
    _LOADED = True


# ---------------------------------------------------------------------------
# Reduced ("smoke") configs: same family, tiny dims — for CPU tests.
# ---------------------------------------------------------------------------

def smoke_config(name: str) -> ModelConfig:
    cfg = get_config(name)
    cyc = len(cfg.layer_cycle)
    kw = dict(
        n_layers=2 * cyc,
        d_model=64,
        n_heads=4,
        n_kv_heads=max(1, min(cfg.n_kv_heads, 2)),
        head_dim=16,
        d_ff=128,
        vocab_size=503,
        sliding_window=16,
    )
    if cfg.moe is not None:
        kw["moe"] = dataclasses.replace(
            cfg.moe, num_experts=4, top_k=min(cfg.moe.top_k, 2),
            d_ff_expert=64)
    if cfg.ssm is not None:
        kw["ssm"] = dataclasses.replace(
            cfg.ssm, state_dim=16, head_dim=16, chunk_size=8)
    if cfg.enc_dec:
        kw["n_enc_layers"] = 2
    if cfg.frontend is not None:
        kw["frontend_len"] = 8
    return cfg.with_overrides(**kw)
