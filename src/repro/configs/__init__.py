from repro.configs.base import (ModelConfig, MoEConfig, SSMConfig,
                                ShapeConfig, SubLayer, SHAPES,
                                cell_is_runnable, get_config, list_archs,
                                register, smoke_config)

__all__ = [
    "ModelConfig", "MoEConfig", "SSMConfig", "ShapeConfig", "SubLayer",
    "SHAPES", "cell_is_runnable", "get_config", "list_archs", "register",
    "smoke_config",
]
