"""The paper's own workload: a library of parameterizable 3x3 convolution
blocks swept over data/coefficient bit widths (3..16), per §3.2 of the paper.

This is not an LM arch; it configures the block-level resource sweep
(core/synth.py) that reproduces Tables 3-5.
"""

from dataclasses import dataclass
from typing import Tuple


@dataclass(frozen=True)
class ConvSweepConfig:
    name: str = "paper-conv-sweep"
    blocks: Tuple[str, ...] = ("conv1", "conv2", "conv3", "conv4")
    data_bits: Tuple[int, ...] = tuple(range(3, 17))
    coeff_bits: Tuple[int, ...] = tuple(range(3, 17))
    # image tile the blocks stream over (one output tile per grid step)
    tile_h: int = 16
    tile_w: int = 128
    channels: int = 8              # input channel depth per block instance
    kernel: int = 3


SWEEP = ConvSweepConfig()

# Reduced sweep for CI's `-m sweep` job and the deployment planner's
# end-to-end tests: one logic block + one dual-output MXU block over a
# 6×6 bit grid — 72 kernel traces instead of 784.  The grid straddles
# the int8/int16 container boundary with three points on each side so
# the segmented container models still lock onto the step exactly (a
# sparser grid lets a plain polynomial squeak past the R² gate and
# mispredict by ~40% at the boundary).
REDUCED_SWEEP = ConvSweepConfig(
    name="paper-conv-sweep-reduced",
    blocks=("conv1", "conv4"),
    data_bits=(4, 6, 8, 10, 12, 16),
    coeff_bits=(4, 6, 8, 10, 12, 16),
)
