"""The paper's own workload: a library of parameterizable 3x3 convolution
blocks swept over data/coefficient bit widths (3..16), per §3.2 of the paper.

This is not an LM arch; it configures the block-level resource sweep
(core/synth.py) that reproduces Tables 3-5.
"""

from dataclasses import dataclass
from typing import Tuple


@dataclass(frozen=True)
class ConvSweepConfig:
    name: str = "paper-conv-sweep"
    blocks: Tuple[str, ...] = ("conv1", "conv2", "conv3", "conv4")
    data_bits: Tuple[int, ...] = tuple(range(3, 17))
    coeff_bits: Tuple[int, ...] = tuple(range(3, 17))
    # image tile the blocks stream over (one output tile per grid step)
    tile_h: int = 16
    tile_w: int = 128
    channels: int = 8              # input channel depth per block instance
    kernel: int = 3


SWEEP = ConvSweepConfig()
