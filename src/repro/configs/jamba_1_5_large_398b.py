"""Jamba-1.5-Large-398B — hybrid, 72L d_model=8192 64H (GQA kv=8)
d_ff=24576 vocab=65536, Mamba:attention 7:1 interleave, MoE 16 experts top-2
on every other layer.  [arXiv:2403.19887; hf]

Cycle of 8: [mamba ×3, attn, mamba ×4]; MLPs alternate dense/MoE within the
cycle (4 MoE layers per cycle) — matching the paper's 1:7 attention ratio and
every-other-layer MoE.
"""

from repro.configs.base import (ModelConfig, MoEConfig, SSMConfig, SubLayer,
                                ATTN, MAMBA, MOE, DENSE, register)

_CYCLE = (
    SubLayer(mixer=MAMBA, mlp=DENSE),
    SubLayer(mixer=MAMBA, mlp=MOE),
    SubLayer(mixer=MAMBA, mlp=DENSE),
    SubLayer(mixer=ATTN, mlp=MOE),
    SubLayer(mixer=MAMBA, mlp=DENSE),
    SubLayer(mixer=MAMBA, mlp=MOE),
    SubLayer(mixer=MAMBA, mlp=DENSE),
    SubLayer(mixer=MAMBA, mlp=MOE),
)

CONFIG = register(ModelConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    n_layers=72,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=24576,
    vocab_size=65536,
    layer_cycle=_CYCLE,
    moe=MoEConfig(num_experts=16, top_k=2, d_ff_expert=24576),
    ssm=SSMConfig(state_dim=128, conv_kernel=4, expand=2, head_dim=128,
                  chunk_size=256),
    act="silu",
    source="arXiv:2403.19887; hf",
))
