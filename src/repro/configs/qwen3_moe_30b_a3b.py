"""Qwen3-MoE-30B-A3B — 48L d_model=2048 32H (GQA kv=4) vocab=151936,
MoE 128 experts top-8, expert d_ff=768.  [hf:Qwen/Qwen3-30B-A3B; hf]"""

from repro.configs.base import (ModelConfig, MoEConfig, SubLayer, ATTN, MOE,
                                register)

CONFIG = register(ModelConfig(
    name="qwen3-moe-30b-a3b",
    family="moe",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=4,
    head_dim=128,
    d_ff=768,                      # expert FFN width (MoE on every layer)
    vocab_size=151936,
    layer_cycle=(SubLayer(mixer=ATTN, mlp=MOE),),
    moe=MoEConfig(num_experts=128, top_k=8, d_ff_expert=768),
    rope_theta=1e6,
    act="silu",
    source="hf:Qwen/Qwen3-30B-A3B; hf",
))
