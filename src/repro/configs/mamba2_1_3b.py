"""Mamba-2-1.3B — attention-free SSM (SSD / state-space duality), 48L
d_model=2048, ssm_state=128, expand=2, vocab=50280.
[arXiv:2405.21060; unverified]"""

from repro.configs.base import (ModelConfig, SSMConfig, SubLayer, MAMBA,
                                NONE, register)

CONFIG = register(ModelConfig(
    name="mamba2-1.3b",
    family="ssm",
    n_layers=48,
    d_model=2048,
    n_heads=0,                     # attention-free
    n_kv_heads=0,
    head_dim=0,
    d_ff=0,                        # no separate MLP; gated SSM block only
    vocab_size=50280,
    layer_cycle=(SubLayer(mixer=MAMBA, mlp=NONE),),
    ssm=SSMConfig(state_dim=128, conv_kernel=4, expand=2, head_dim=64,
                  chunk_size=256),
    act="silu",
    tie_embeddings=True,
    source="arXiv:2405.21060; unverified",
))
