"""Gemma-2-9B — 42L d_model=3584 16H (GQA kv=8) d_ff=14336 vocab=256000,
alternating local(sliding-window 4096)/global attention, attn+final logit
softcaps, GeGLU.  [arXiv:2408.00118; hf]"""

from repro.configs.base import (ModelConfig, SubLayer, ATTN, LOCAL_ATTN,
                                DENSE, register)

CONFIG = register(ModelConfig(
    name="gemma2-9b",
    family="dense",
    n_layers=42,
    d_model=3584,
    n_heads=16,
    n_kv_heads=8,
    head_dim=256,
    d_ff=14336,
    vocab_size=256000,
    layer_cycle=(SubLayer(mixer=LOCAL_ATTN, mlp=DENSE),
                 SubLayer(mixer=ATTN, mlp=DENSE)),
    sliding_window=4096,
    attn_softcap=50.0,
    final_softcap=30.0,
    act="gelu",
    tie_embeddings=True,
    scale_embeddings=True,
    source="arXiv:2408.00118; hf",
))
