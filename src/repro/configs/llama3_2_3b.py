"""Llama-3.2-3B — dense, 28L d_model=3072 24H (GQA kv=8) d_ff=8192
vocab=128256.  [hf:meta-llama/Llama-3.2-1B; unverified]"""

from repro.configs.base import ModelConfig, SubLayer, ATTN, DENSE, register

CONFIG = register(ModelConfig(
    name="llama3.2-3b",
    family="dense",
    n_layers=28,
    d_model=3072,
    n_heads=24,
    n_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=128256,
    layer_cycle=(SubLayer(mixer=ATTN, mlp=DENSE),),
    rope_theta=5e5,
    act="silu",
    tie_embeddings=True,
    source="hf:meta-llama/Llama-3.2-1B; unverified",
))
