"""``repro.ops`` — durable serving state and ops telemetry.

The serving stack (``repro.runtime`` → ``repro.serve`` → ``repro.fleet``)
is fast once warm, but a process restart used to forget everything:
every AOT executable recompiled, every registered plan re-planned.
This package makes that state durable and observable:

* ``PlanStore`` — crash-safe on-disk plan repository
  (save/load/retire/quarantine, atomic writes);
* ``PersistentExecutableCache`` — disk tier under
  ``runtime.ExecutableCache`` via JAX AOT executable serialization, so
  a warm restart deserializes instead of compiling;
* ``Tracker`` / ``JsonlTracker`` / ``StatsSampler`` — background-
  threaded telemetry that records lifecycle events and periodic
  ``stats()`` snapshots without ever blocking the serving path
  (``read_log`` parses a file back with its seal totals);
* ``StoreRoot`` — one shared plan-store + executable-cache location
  for a whole fleet, with per-worker lease files so a respawned
  worker warm-starts from its dead predecessor's compiles.

Live reload lives on the serving objects themselves
(``AsyncCNNGateway.register_plan``/``retire_plan``,
``Fleet.rollout``/``Fleet.retire_plan``); this package supplies the
durable state they read from and report into.  See ``docs/ops.md``.
"""

from repro.ops.cache import (CACHE_FORMAT_VERSION, PersistentExecutableCache,
                             cache_fingerprint)
from repro.ops.root import Lease, LeaseHeld, StoreRoot
from repro.ops.store import (PlanCorrupt, PlanNotFound, PlanRetired,
                             PlanStore, PlanStoreError)
from repro.ops.tracker import (JsonlTracker, NullTracker, StatsSampler,
                               Tracker, TrackerLog, read_events, read_log)

__all__ = [
    "PlanStore", "PlanStoreError", "PlanNotFound", "PlanRetired",
    "PlanCorrupt",
    "PersistentExecutableCache", "cache_fingerprint",
    "CACHE_FORMAT_VERSION",
    "StoreRoot", "Lease", "LeaseHeld",
    "Tracker", "NullTracker", "JsonlTracker", "StatsSampler",
    "TrackerLog", "read_log", "read_events",
]
