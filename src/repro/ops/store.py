"""``PlanStore``: an on-disk, crash-safe repository of deployment plans.

A gateway restart used to forget every registered plan — re-deriving
them meant re-running the planner (the software analog of the paper's
synthesis loop).  ``PlanStore`` keeps the versioned plan artifacts on
disk, keyed by ``plan_id``, so plans outlive the process:

    store = PlanStore("state/plans")
    store.save(plan, "cnn-v5e")           # atomic tmp+fsync+rename
    ...restart...
    plan = store.load("cnn-v5e")          # exactly the saved bytes
    store.retire("cnn-v5e")               # atomic move to retired/

Layout under the root directory::

    plans/<plan_id>.json       live plans (schema-versioned via plan_io)
    retired/<plan_id>.json     retired plans, kept for audit
    quarantine/<file>          corrupt payloads moved aside, never deleted

Guarantees:

* **No torn reads.** Every write goes through
  ``plan_io.atomic_write_text`` (tmp file in the same directory, fsync,
  ``os.replace``) and retire is a single ``os.replace`` — a concurrent
  reader sees either the complete old artifact or the complete new one.
* **Corruption is quarantined, not propagated.** A payload that fails
  to parse is moved to ``quarantine/`` and ``load`` raises
  ``PlanCorrupt`` naming the quarantined path; the store itself stays
  healthy.
* **Retire is terminal but auditable.** ``load`` of a retired id raises
  ``PlanRetired`` (a ``KeyError`` subclass) rather than silently
  resurrecting it; the artifact remains under ``retired/``.
"""

from __future__ import annotations

import os
import re
from pathlib import Path
from typing import List, Union

from repro.core.deploy import DeploymentPlan
from repro.runtime.plan_io import _fsync_dir, atomic_write_text

__all__ = [
    "PlanStore", "PlanStoreError", "PlanNotFound", "PlanRetired",
    "PlanCorrupt",
]

# plan_ids become filenames: accept a conservative portable subset and
# refuse anything that could traverse directories or hide as a dotfile.
_PLAN_ID_RE = re.compile(r"^[A-Za-z0-9][A-Za-z0-9._-]{0,99}$")


class PlanStoreError(RuntimeError):
    """Base class for plan-store failures."""


class PlanNotFound(PlanStoreError, KeyError):
    """No live or retired plan under this id."""

    def __str__(self) -> str:  # KeyError quotes its arg; keep it readable
        return RuntimeError.__str__(self)


class PlanRetired(PlanStoreError, KeyError):
    """The plan exists but was retired; ``load`` refuses to serve it."""

    def __str__(self) -> str:
        return RuntimeError.__str__(self)


class PlanCorrupt(PlanStoreError):
    """The artifact failed to parse; it was moved to quarantine."""


class PlanStore:
    """Directory-backed plan repository (see module docstring)."""

    def __init__(self, root: Union[str, Path]):
        self.root = Path(root)
        self._live = self.root / "plans"
        self._retired = self.root / "retired"
        self._quarantine = self.root / "quarantine"
        for d in (self._live, self._retired, self._quarantine):
            d.mkdir(parents=True, exist_ok=True)

    # -- paths -------------------------------------------------------

    @staticmethod
    def _check_id(plan_id: str) -> str:
        if not _PLAN_ID_RE.match(plan_id):
            raise ValueError(
                f"invalid plan_id {plan_id!r}: must match "
                f"{_PLAN_ID_RE.pattern}")
        return plan_id

    def path_for(self, plan_id: str) -> Path:
        return self._live / f"{self._check_id(plan_id)}.json"

    def retired_path_for(self, plan_id: str) -> Path:
        return self._retired / f"{self._check_id(plan_id)}.json"

    # -- write side --------------------------------------------------

    def save(self, plan: DeploymentPlan, plan_id: str) -> Path:
        """Persist ``plan`` under ``plan_id`` (atomic; overwrite OK).

        Saving an id that was retired revives it as a *new* live plan —
        the retired artifact stays in ``retired/`` for audit.
        """
        if not isinstance(plan, DeploymentPlan):
            raise PlanStoreError(
                f"save expects a DeploymentPlan, got {type(plan).__name__}")
        return atomic_write_text(self.path_for(plan_id), plan.to_json())

    def retire(self, plan_id: str) -> Path:
        """Atomically move a live plan to ``retired/``.

        Raises ``PlanNotFound`` if no live plan exists (retiring an
        already-retired id is not an error a second time only if the
        live file still exists — it won't, so callers get
        ``PlanNotFound``, which is the honest answer).
        """
        src = self.path_for(plan_id)
        dst = self.retired_path_for(plan_id)
        try:
            os.replace(src, dst)
        except FileNotFoundError:
            raise PlanNotFound(f"no live plan {plan_id!r} to retire "
                               f"(root={self.root})") from None
        _fsync_dir(self._live)
        _fsync_dir(self._retired)
        return dst

    # -- read side ---------------------------------------------------

    def _read(self, path: Path, plan_id: str) -> DeploymentPlan:
        try:
            text = path.read_text(encoding="utf-8")
        except FileNotFoundError:
            raise PlanNotFound(
                f"no plan {plan_id!r} in store (root={self.root})"
            ) from None
        try:
            return DeploymentPlan.from_json(text)
        except Exception as err:
            qpath = self._quarantine / path.name
            try:
                os.replace(path, qpath)
            except OSError:
                qpath = path          # couldn't move; name it in place
            raise PlanCorrupt(
                f"plan {plan_id!r} failed to parse ({err}); "
                f"quarantined at {qpath}") from err

    def load(self, plan_id: str) -> DeploymentPlan:
        """Load a live plan; ``PlanRetired``/``PlanNotFound``/
        ``PlanCorrupt`` otherwise."""
        path = self.path_for(plan_id)
        if not path.exists():
            if self.retired_path_for(plan_id).exists():
                raise PlanRetired(
                    f"plan {plan_id!r} was retired (root={self.root})")
            raise PlanNotFound(
                f"no plan {plan_id!r} in store (root={self.root})")
        return self._read(path, plan_id)

    def load_retired(self, plan_id: str) -> DeploymentPlan:
        """Load a retired plan's artifact (audit/rollback tooling)."""
        return self._read(self.retired_path_for(plan_id), plan_id)

    # -- listing -----------------------------------------------------

    @staticmethod
    def _ids_in(d: Path) -> List[str]:
        out = []
        for p in d.iterdir():
            # skip in-flight temp files and anything non-plan-shaped
            if p.suffix == ".json" and not p.name.startswith("."):
                out.append(p.stem)
        return sorted(out)

    def list_plans(self) -> List[str]:
        """Sorted ids of live plans."""
        return self._ids_in(self._live)

    def list_retired(self) -> List[str]:
        """Sorted ids of retired plans."""
        return self._ids_in(self._retired)

    def __contains__(self, plan_id: str) -> bool:
        try:
            return self.path_for(plan_id).exists()
        except ValueError:
            return False

    def __len__(self) -> int:
        return len(self.list_plans())

    def __repr__(self) -> str:
        return (f"PlanStore(root={str(self.root)!r}, "
                f"live={len(self)}, retired={len(self.list_retired())})")
