"""``StoreRoot``: one shared durable-state location for a whole fleet.

PR 9 made a single process durable — but each worker pointed at its own
``--cache-dir``, so a respawned worker re-compiled everything its dead
predecessor had already paid for.  ``StoreRoot`` is the next rung: one
directory holding the fleet's ``PlanStore`` *and* one shared
``PersistentExecutableCache`` location, coordinated across worker
processes with per-worker **lease files**::

    <root>/plans/        live plans        (PlanStore — same layout)
    <root>/retired/      retired plans
    <root>/quarantine/   corrupt plans
    <root>/exec-cache/   serialized AOT executables (shared by workers)
    <root>/leases/<worker_id>   one JSON lease per live worker identity

Usage::

    root = StoreRoot("state")
    root.plans.save(plan, "cnn-v5e")
    lease = root.acquire_lease("w0")       # crash-safe worker identity
    cache = root.exec_cache()              # warm across restarts
    ...
    lease.release()

Lease semantics — deliberately minimal:

* ``acquire_lease`` creates ``leases/<worker_id>`` with
  ``O_CREAT | O_EXCL`` (atomic on every POSIX filesystem), recording
  the holder's pid.  A second *live* process claiming the same
  ``worker_id`` gets ``LeaseHeld`` — two gateways must never serve one
  worker identity off one store.
* A lease whose recorded pid is **dead** (or is this very process) is
  taken over atomically: crash recovery must not require manual lock
  removal.  Same-process takeover is what lets ``Fleet.respawn`` build
  the replacement gateway in the process that held the old one.
* Leases guard **cross-process** duplication only.  Two threads of one
  process racing the same worker_id is a caller bug, not a lease
  feature — in-process coordination belongs to ``Fleet``.
"""

from __future__ import annotations

import json
import os
import time
from pathlib import Path
from typing import List, Union

from repro.ops.cache import PersistentExecutableCache
from repro.ops.store import PlanStore
from repro.runtime.plan_io import _fsync_dir

__all__ = ["StoreRoot", "Lease", "LeaseHeld"]


class LeaseHeld(RuntimeError):
    """Another live process holds this worker's lease."""


def _pid_alive(pid: int) -> bool:
    """Is ``pid`` a live process?  ``PermissionError`` means it exists
    but belongs to someone else — still alive."""
    if pid <= 0:
        return False
    try:
        os.kill(pid, 0)
    except ProcessLookupError:
        return False
    except PermissionError:
        return True
    except OSError:
        return False
    return True


class Lease:
    """A held per-worker lease file (see ``StoreRoot``)."""

    def __init__(self, path: Path, worker_id: str, pid: int,
                 token: float):
        self.path = path
        self.worker_id = worker_id
        self.pid = pid
        self.token = token            # acquired_at written into the file
        self._released = False

    @property
    def held(self) -> bool:
        return not self._released

    def release(self) -> None:
        """Remove the lease file (idempotent).  The unlink is
        token-checked: if a successor has already taken the lease over
        (same worker_id, newer ``acquired_at``), this stale handle
        leaves the successor's file alone — releasing an old handle
        after a respawn must never evict the live holder."""
        if self._released:
            return
        self._released = True
        try:
            current = json.loads(self.path.read_text(encoding="utf-8"))
            if current.get("pid") != self.pid \
                    or current.get("acquired_at") != self.token:
                return                 # taken over: not ours to remove
            self.path.unlink()
        except (OSError, ValueError, AttributeError):
            pass

    def __enter__(self) -> "Lease":
        return self

    def __exit__(self, *exc) -> None:
        self.release()

    def __repr__(self) -> str:
        state = "held" if self.held else "released"
        return (f"Lease(worker_id={self.worker_id!r}, pid={self.pid}, "
                f"{state})")


class StoreRoot:
    """One shared durable-state directory for a fleet (see module
    docstring)."""

    def __init__(self, root: Union[str, Path]):
        self.root = Path(root)
        self.plans = PlanStore(self.root)
        self.exec_cache_dir = self.root / "exec-cache"
        self._leases = self.root / "leases"
        for d in (self.exec_cache_dir, self._leases):
            d.mkdir(parents=True, exist_ok=True)

    # -- the shared executable tier -----------------------------------

    def exec_cache(self, **kwargs) -> PersistentExecutableCache:
        """A fresh ``PersistentExecutableCache`` over the shared disk
        tier.  Each caller gets its own in-memory tier (counters and
        single-flight state are per-process), but every instance reads
        and writes the same ``exec-cache/`` directory — a respawned
        worker deserializes what its predecessor compiled."""
        return PersistentExecutableCache(self.exec_cache_dir, **kwargs)

    # -- worker leases ------------------------------------------------

    def _lease_path(self, worker_id: str) -> Path:
        PlanStore._check_id(worker_id)   # same portable-filename rules
        return self._leases / worker_id

    def acquire_lease(self, worker_id: str) -> Lease:
        """Claim ``worker_id`` for this process; ``LeaseHeld`` if a
        *live* foreign process already holds it.  Dead-holder and
        own-pid leases are taken over atomically."""
        path = self._lease_path(worker_id)
        token = time.time()
        payload = json.dumps({"worker_id": worker_id, "pid": os.getpid(),
                              "acquired_at": token})
        try:
            fd = os.open(path, os.O_CREAT | os.O_EXCL | os.O_WRONLY)
        except FileExistsError:
            holder = self._holder_pid(path)
            if holder is not None and holder != os.getpid() \
                    and _pid_alive(holder):
                raise LeaseHeld(
                    f"worker {worker_id!r} is leased by live pid "
                    f"{holder} ({path})") from None
            # stale (dead holder / unreadable) or our own: take over
            tmp = path.with_name(f".{path.name}.{os.getpid()}.tmp")
            with open(tmp, "w", encoding="utf-8") as fh:
                fh.write(payload)
                fh.flush()
                os.fsync(fh.fileno())
            os.replace(tmp, path)
            _fsync_dir(self._leases)
            return Lease(path, worker_id, os.getpid(), token)
        try:
            os.write(fd, payload.encode("utf-8"))
            os.fsync(fd)
        finally:
            os.close(fd)
        _fsync_dir(self._leases)
        return Lease(path, worker_id, os.getpid(), token)

    @staticmethod
    def _holder_pid(path: Path):
        try:
            return int(json.loads(path.read_text(encoding="utf-8"))
                       .get("pid", -1))
        except (OSError, ValueError, AttributeError):
            return None   # unreadable/torn lease: treat as stale

    def list_leases(self) -> List[str]:
        """Sorted worker ids with a lease file on disk (live or stale)."""
        return sorted(p.name for p in self._leases.iterdir()
                      if not p.name.startswith("."))

    def __repr__(self) -> str:
        return (f"StoreRoot(root={str(self.root)!r}, "
                f"plans={len(self.plans)}, "
                f"leases={len(self.list_leases())})")
