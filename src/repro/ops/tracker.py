"""Ops telemetry: ``Tracker`` ABC, JSONL exporter, and stats sampler.

Serving components (gateway, fleet, caches) emit two kinds of signal:
**lifecycle events** (plan registered/retired, cache compile/disk hit/
fallback, worker ejected/probed) and **periodic stats snapshots** (the
``stats()`` dicts ``SlotPool``/``AsyncCNNGateway``/``Fleet`` already
expose).  ``Tracker`` is the sink abstraction for both; components take
an optional tracker and call it fire-and-forget.

The contract that matters: **a tracker never blocks or breaks the
serving path.**  ``JsonlTracker`` writes from a background thread fed
by a bounded queue — when the queue is full the entry is *dropped and
counted*, not waited on; writer errors are swallowed; ``close()``
flushes everything queued and appends a final ``tracker_closed`` record
carrying the recorded/dropped totals, so the file itself says whether
it is complete.

    with JsonlTracker("metrics.jsonl") as tr:
        gw = AsyncCNNGateway(cfg, tracker=tr)
        sampler = StatsSampler(tr, {"gateway": gw.stats}, interval_s=0.5)
        ...
        sampler.close()
    log = read_log("metrics.jsonl")
    assert log.sealed and log.dropped == 0

Every record is one JSON object per line with at least ``t`` (epoch
seconds) and ``event``; samples use ``event: "stats"`` plus ``source``
and the snapshot under ``metrics``.  ``read_log`` parses a file back
into a ``TrackerLog`` that surfaces the seal totals (recorded /
dropped / write_errors) so recovery tests can bound telemetry loss;
``read_events`` remains the events-only convenience.
"""

from __future__ import annotations

import abc
import json
import os
import queue
import threading
import time
from dataclasses import dataclass
from pathlib import Path
from typing import Callable, List, Mapping, Optional, Tuple, Union

__all__ = ["Tracker", "NullTracker", "JsonlTracker", "StatsSampler",
           "TrackerLog", "read_log", "read_events"]


class Tracker(abc.ABC):
    """Sink for lifecycle events and metric snapshots.

    Implementations must make ``record`` cheap and non-blocking — it is
    called from the serving path.  ``log_event``/``log_metrics`` are
    convenience shapers over ``record``.
    """

    @abc.abstractmethod
    def record(self, entry: dict) -> None:
        """Accept one record (must not block or raise)."""

    def log_event(self, event: str, **fields) -> None:
        entry = {"t": time.time(), "event": event}
        entry.update(fields)
        self.record(entry)

    def log_metrics(self, source: str, metrics: Mapping) -> None:
        self.record({"t": time.time(), "event": "stats",
                     "source": source, "metrics": dict(metrics)})

    def close(self) -> None:
        """Flush and release resources (idempotent)."""

    def __enter__(self) -> "Tracker":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


class NullTracker(Tracker):
    """Discards everything; the default when no tracker is wired."""

    def record(self, entry: dict) -> None:
        pass


class _CLOSE:  # sentinel enqueued by close()
    pass


class JsonlTracker(Tracker):
    """Background-threaded JSONL exporter (see module docstring).

    ``max_queue`` bounds memory under a stalled disk: overflow entries
    are dropped and tallied in ``dropped`` rather than back-pressuring
    the caller.  ``flush_interval_s`` bounds how stale the file can be
    while the process lives; ``close()`` (or context-manager exit)
    drains the queue fully and fsyncs.
    """

    def __init__(self, path: Union[str, Path], *, max_queue: int = 4096,
                 flush_interval_s: float = 0.25,
                 io_fault: Optional[Callable[[dict], None]] = None):
        self.path = Path(path)
        self.path.parent.mkdir(parents=True, exist_ok=True)
        self._q: "queue.Queue" = queue.Queue(maxsize=max_queue)
        self.recorded = 0
        self.dropped = 0
        self.write_errors = 0
        #: fault-injection seam: called with each entry before the disk
        #: write; raising simulates a full/failing disk for that entry
        #: (the entry is counted in ``write_errors``, never retried)
        self.io_fault = io_fault
        self._closed = False
        self._lock = threading.Lock()
        self._flush_interval_s = flush_interval_s
        self._fh = open(self.path, "a", encoding="utf-8")
        self._thread = threading.Thread(
            target=self._run, name="jsonl-tracker", daemon=True)
        self._thread.start()

    # -- producer side (serving path) --------------------------------

    def record(self, entry: dict) -> None:
        with self._lock:
            if self._closed:
                self.dropped += 1
                return
            try:
                self._q.put_nowait(entry)
                self.recorded += 1
            except queue.Full:
                self.dropped += 1

    # -- writer thread -----------------------------------------------

    def _write(self, entry: dict) -> None:
        try:
            if self.io_fault is not None:
                self.io_fault(entry)
            self._fh.write(json.dumps(entry, default=repr,
                                      sort_keys=True) + "\n")
        except Exception:   # noqa: BLE001 — telemetry must not raise
            with self._lock:
                self.write_errors += 1

    def _run(self) -> None:
        dirty = False
        while True:
            try:
                item = self._q.get(timeout=self._flush_interval_s)
            except queue.Empty:
                if dirty:
                    try:
                        self._fh.flush()
                    except Exception:
                        pass
                    dirty = False
                continue
            if item is _CLOSE:
                break
            self._write(item)
            dirty = True
        # drain whatever raced in behind the sentinel, then seal
        while True:
            try:
                self._write(self._q.get_nowait())
            except queue.Empty:
                break
        with self._lock:
            recorded, dropped = self.recorded, self.dropped
            write_errors = self.write_errors
        self._write({"t": time.time(), "event": "tracker_closed",
                     "recorded": recorded, "dropped": dropped,
                     "write_errors": write_errors})
        try:
            self._fh.flush()
            os.fsync(self._fh.fileno())
        except Exception:
            pass
        self._fh.close()

    def close(self) -> None:
        with self._lock:
            if self._closed:
                return
            self._closed = True
        self._q.put(_CLOSE)       # blocking put is fine at shutdown
        self._thread.join()


@dataclass(frozen=True)
class TrackerLog:
    """A parsed tracker file plus its integrity verdict.

    ``sealed`` is True when the file ends with the ``tracker_closed``
    record a clean ``close()`` writes; only then are ``recorded`` /
    ``dropped`` / ``write_errors`` available (they come from the seal,
    the single source of truth for telemetry-loss bounds — recovery
    tests assert ``log.dropped == 0`` after a kill→respawn run).  An
    unsealed file means the tracker process died mid-flight: the events
    read are a prefix and no loss bound can be claimed.  ``torn_lines``
    counts unparseable lines skipped during the read (crash-torn
    trailing writes).
    """

    events: Tuple[dict, ...]
    sealed: bool
    recorded: Optional[int] = None
    dropped: Optional[int] = None
    write_errors: Optional[int] = None
    torn_lines: int = 0


def read_log(path: Union[str, Path]) -> TrackerLog:
    """Parse a tracker JSONL file into events + seal totals."""
    events: List[dict] = []
    torn = 0
    with open(path, encoding="utf-8") as fh:
        for line in fh:
            line = line.strip()
            if not line:
                continue
            try:
                events.append(json.loads(line))
            except json.JSONDecodeError:
                torn += 1
    sealed = bool(events) and events[-1].get("event") == "tracker_closed"
    seal = events[-1] if sealed else {}
    return TrackerLog(events=tuple(events), sealed=sealed,
                      recorded=seal.get("recorded"),
                      dropped=seal.get("dropped"),
                      write_errors=seal.get("write_errors"),
                      torn_lines=torn)


def read_events(path: Union[str, Path]) -> List[dict]:
    """Parse a tracker JSONL file (skipping any torn trailing line)."""
    return list(read_log(path).events)


class StatsSampler:
    """Periodically records ``stats()`` snapshots into a tracker.

    ``sources`` maps a name to a zero-arg callable returning a dict
    (e.g. ``{"gateway": gw.stats, "fleet": fleet.stats}``).  A source
    that raises produces a ``sample_error`` event instead of killing
    the sampler.  ``close()`` takes one final sample so short runs
    still leave a snapshot, then stops the thread.
    """

    def __init__(self, tracker: Tracker,
                 sources: Mapping[str, Callable[[], Mapping]], *,
                 interval_s: float = 0.5):
        self.tracker = tracker
        self.sources = dict(sources)
        self.interval_s = interval_s
        self.samples = 0
        self._stop = threading.Event()
        self._thread = threading.Thread(
            target=self._run, name="stats-sampler", daemon=True)
        self._thread.start()

    def _sample_once(self) -> None:
        for name, fn in self.sources.items():
            try:
                self.tracker.log_metrics(name, fn())
            except Exception as err:   # noqa: BLE001 — keep sampling
                self.tracker.log_event("sample_error", source=name,
                                       error=repr(err))
        self.samples += 1

    def _run(self) -> None:
        while not self._stop.wait(self.interval_s):
            self._sample_once()

    def close(self) -> None:
        if self._stop.is_set():
            return
        self._stop.set()
        self._thread.join()
        self._sample_once()       # final snapshot at shutdown

    def __enter__(self) -> "StatsSampler":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
