"""``PersistentExecutableCache``: a disk tier under ``ExecutableCache``.

The paper's whole premise is that re-running synthesis for every design
iteration is the bottleneck; our analog is XLA compilation, and a
gateway restart used to replay the entire compile storm.  This cache
serializes each AOT executable (``jax.experimental.serialize_executable``)
to disk the first time it is compiled and deserializes it on the next
process's first request — a warm restart *loads* instead of compiling:

    cache = PersistentExecutableCache("state/exec-cache")
    model = runtime.compile_plan(plan, params=params, exec_cache=cache)
    # first process: compiles, stores .exe files
    # after restart: zero compiles — every bucket deserialized

Keying and safety:

* Entries are keyed on the existing content-addressed layer keys (the
  ``(layer spec, bucket)`` tuples backends already use) **plus a
  fingerprint** of (cache format, jax version, backend, device
  topology).  An artifact produced by a different jax build or device
  layout never deserializes into this process — a fingerprint mismatch
  is treated as a miss and the slot is overwritten with a fresh
  compile.
* Stale/corrupt/unreadable entries **silently fall back to a live
  compile**: a corrupt file is renamed to ``*.corrupt`` and an entry
  whose embedded fingerprint drifted from the current environment (a
  jax upgrade or topology change under an unchanged path — possible
  when a shared dir outlives a deploy) is renamed to ``*.stale``; both
  are quarantined for inspection, never deserialized, and serving
  proceeds exactly as with a cold cache.
  Persistence failures on the write side are likewise swallowed — the
  disk tier is an accelerator, never a point of failure.
* Writes are atomic (tmp + fsync + ``os.replace``), so two processes
  sharing one cache directory can race without torn files.

Executables that are not jax ``Compiled`` objects (some backends cache
plain callables) are skipped — they compile live, as before.
"""

from __future__ import annotations

import hashlib
import os
import pickle
from pathlib import Path
from typing import Callable, Optional, Tuple, Union

import jax

from repro.runtime.compiled import ExecutableCache
from repro.runtime.plan_io import _fsync_dir

__all__ = ["PersistentExecutableCache", "cache_fingerprint",
           "CACHE_FORMAT_VERSION"]

CACHE_FORMAT_VERSION = 1


def cache_fingerprint() -> tuple:
    """Identity of the compile environment a serialized executable is
    only valid for: cache format, jax version, backend, topology."""
    devs = jax.devices()
    kinds = sorted({(d.platform, getattr(d, "device_kind", "?"))
                    for d in devs})
    return (CACHE_FORMAT_VERSION, jax.__version__, jax.default_backend(),
            len(devs), tuple(kinds))


def _stable_token(obj) -> object:
    """Reduce a cache-key element to something ``repr``-stable across
    processes.  Primitives pass through; tuples recurse; a ``Mesh``
    (identity-hashed, so its repr varies per process) is replaced by
    its shape and device names; anything else falls back to repr."""
    if obj is None or isinstance(obj, (bool, int, float, str, bytes)):
        return obj
    if isinstance(obj, tuple):
        return tuple(_stable_token(o) for o in obj)
    if isinstance(obj, jax.sharding.Mesh):
        return ("mesh", tuple(obj.shape.items()),
                tuple(str(d) for d in obj.devices.flat))
    return ("repr", repr(obj))


class PersistentExecutableCache(ExecutableCache):
    """Disk-backed ``ExecutableCache`` (see module docstring).

    Inherits single-flight semantics: a key being loaded/compiled by
    one thread is waited on by the others.  ``stats()`` gains
    ``disk_hits`` / ``disk_stores`` / ``disk_errors``.
    """

    def __init__(self, cache_dir: Union[str, Path], *,
                 on_event: Optional[Callable[[str, dict], None]] = None):
        super().__init__(on_event=on_event)
        self.cache_dir = Path(cache_dir)
        self.cache_dir.mkdir(parents=True, exist_ok=True)
        self.fingerprint = cache_fingerprint()
        self.disk_hits = 0     # executables deserialized instead of compiled
        self.disk_stores = 0   # executables serialized to disk
        self.disk_errors = 0   # corrupt/unwritable entries fallen back from
        self.disk_stale = 0    # fingerprint-drift entries quarantined

    # -- key → file --------------------------------------------------

    def _entry_path(self, key: tuple) -> Path:
        token = repr((self.fingerprint, _stable_token(key)))
        digest = hashlib.sha256(token.encode("utf-8")).hexdigest()
        return self.cache_dir / f"{digest[:32]}.exe"

    # -- disk read ---------------------------------------------------

    def _load_entry(self, key: tuple):
        """Deserialize the on-disk executable for ``key``; None on any
        miss (absent, wrong fingerprint, corrupt — corrupt files are
        quarantined as ``*.corrupt``)."""
        path = self._entry_path(key)
        try:
            blob = path.read_bytes()
        except FileNotFoundError:
            return None
        except OSError:
            with self._lock:
                self.disk_errors += 1
            return None
        try:
            entry = pickle.loads(blob)
            if entry["fingerprint"] != self.fingerprint:
                # drifted build/topology under an unchanged path:
                # quarantine, never deserialize, recompile fresh
                with self._lock:
                    self.disk_stale += 1
                try:
                    os.replace(path, path.with_suffix(".stale"))
                except OSError:
                    pass
                self._emit("cache_disk_stale", path=str(path))
                return None
            from jax.experimental.serialize_executable import (
                deserialize_and_load)
            return deserialize_and_load(entry["payload"],
                                        entry["in_tree"],
                                        entry["out_tree"])
        except Exception:
            with self._lock:
                self.disk_errors += 1
            try:
                os.replace(path, path.with_suffix(".corrupt"))
            except OSError:
                pass
            self._emit("cache_disk_fallback", path=str(path))
            return None

    # -- disk write --------------------------------------------------

    def _store_entry(self, key: tuple, exe) -> None:
        """Best-effort atomic persist; failures never surface."""
        try:
            from jax.experimental.serialize_executable import serialize
            payload, in_tree, out_tree = serialize(exe)
            blob = pickle.dumps({
                "format": CACHE_FORMAT_VERSION,
                "fingerprint": self.fingerprint,
                "key": repr(_stable_token(key)),
                "payload": payload,
                "in_tree": in_tree,
                "out_tree": out_tree,
            })
            path = self._entry_path(key)
            tmp = path.with_name(f".{path.name}.{os.getpid()}.tmp")
            with open(tmp, "wb") as fh:
                fh.write(blob)
                fh.flush()
                os.fsync(fh.fileno())
            os.replace(tmp, path)
            _fsync_dir(path.parent)
        except Exception:
            with self._lock:
                self.disk_errors += 1
            self._emit("cache_disk_store_failed", key=repr(key)[:160])
            return
        with self._lock:
            self.disk_stores += 1
        self._emit("cache_disk_store", path=str(path), bytes=len(blob))

    # -- the ExecutableCache production seam -------------------------

    def _produce(self, key: tuple, build: Callable[[], object]
                 ) -> Tuple[object, bool]:
        exe = self._load_entry(key)
        if exe is not None:
            with self._lock:
                self.disk_hits += 1
            self._emit("cache_disk_hit", key=repr(key)[:160])
            return exe, False
        exe, compiled = super()._produce(key, build)
        # only jax Compiled objects serialize; plain callables skip disk
        if hasattr(exe, "as_text") or type(exe).__name__ == "Compiled":
            self._store_entry(key, exe)
        return exe, compiled

    def stats(self) -> dict:
        out = super().stats()
        out.update({"disk_hits": self.disk_hits,
                    "disk_stores": self.disk_stores,
                    "disk_errors": self.disk_errors,
                    "disk_stale": self.disk_stale})
        return out
