"""``FaultPlan`` — a seeded, serializable schedule of injected faults.

The paper's planning loop works because resource behavior is
*predictable*; the chaos layer applies the same discipline to failure
testing: faults are not random monkeypatches sprinkled at runtime but a
**plan** — JSON-serializable like ``DeploymentPlan``, derivable from a
seed, diffable in a repo — that both the live asyncio fleet and the
virtual-clock simulator can execute, so a failing chaos run replays
bit-for-bit from its plan.

A plan is a tuple of ``FaultSpec``s.  Each spec names

* ``kind`` — one of ``FAULT_KINDS``;
* ``target`` — the unit it hits (a ``worker_id`` for runtime faults,
  a store/cache label for disk faults);
* a **trigger**: ``at`` (seconds on the harness clock) *or*
  ``after_n`` (the n-th visit to the fault's seam point) — exactly one;
* optionally a window: ``duration_s`` (time-triggered transients) or
  ``count`` (occurrence-triggered transients).  Absent, a transient
  fault is permanent until revived and a crash is always sticky.

Kinds and where they bite:

====================  ====================================================
``crash_dispatch``    the worker dies mid-dispatch — raises
                      ``WorkerCrashed`` at the gateway's "dispatch" seam;
                      sticky until ``FaultInjector.revive``
``stall_heartbeat``   ``snapshot()`` raises ``HeartbeatStalled`` at the
                      "heartbeat" seam — the fleet reads a missed
                      heartbeat, exactly like a hung process
``corrupt_cache_entry``  disk fault: a serialized executable is
                      overwritten with garbage
                      (``inject.corrupt_cache_entries``)
``torn_plan_write``   disk fault: a ``PlanStore`` atomic-write temp file
                      is left truncated, as a crash mid-write would
                      (``inject.tear_plan_write``)
``tracker_disk_full`` the tracker's disk writes fail — injected through
                      ``JsonlTracker(io_fault=...)``
====================  ====================================================

Runtime kinds are enforced by ``inject.FaultInjector`` through the
``SlotPool.faults`` seam; disk kinds are applied by the harness with
the ``inject`` helpers at the scheduled moment — the plan is the single
schedule for both.
"""

from __future__ import annotations

import json
import random
from dataclasses import dataclass
from typing import Iterable, Optional, Sequence, Tuple

__all__ = ["FAULT_KINDS", "FAULT_PLAN_SCHEMA_VERSION", "FaultSpec",
           "FaultPlan", "make_fault_plan"]

FAULT_KINDS = ("crash_dispatch", "stall_heartbeat", "corrupt_cache_entry",
               "torn_plan_write", "tracker_disk_full")

FAULT_PLAN_SCHEMA_VERSION = 1

#: kinds whose window field is time (``duration_s``) vs occurrences
#: (``count``); crash kinds take no window (sticky until revive)
_TIME_WINDOW_KINDS = ("stall_heartbeat",)
_COUNT_WINDOW_KINDS = ("stall_heartbeat", "tracker_disk_full")


@dataclass(frozen=True)
class FaultSpec:
    """One scheduled fault (see module docstring)."""
    kind: str
    target: str
    at: Optional[float] = None         # trigger: harness-clock seconds
    after_n: Optional[int] = None      # trigger: n-th seam visit
    duration_s: Optional[float] = None   # window for time triggers
    count: Optional[int] = None          # window for occurrence triggers

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; known: "
                             f"{FAULT_KINDS}")
        if not self.target:
            raise ValueError("FaultSpec.target must be non-empty")
        if (self.at is None) == (self.after_n is None):
            raise ValueError(
                f"exactly one of at/after_n must be set "
                f"(got at={self.at}, after_n={self.after_n})")
        if self.at is not None and self.at < 0:
            raise ValueError(f"at={self.at} must be ≥ 0")
        if self.after_n is not None and self.after_n < 1:
            raise ValueError(f"after_n={self.after_n} must be ≥ 1")
        if self.duration_s is not None:
            if self.kind not in _TIME_WINDOW_KINDS:
                raise ValueError(
                    f"duration_s does not apply to kind {self.kind!r}")
            if self.duration_s <= 0:
                raise ValueError(
                    f"duration_s={self.duration_s} must be > 0")
        if self.count is not None:
            if self.kind not in _COUNT_WINDOW_KINDS:
                raise ValueError(
                    f"count does not apply to kind {self.kind!r}")
            if self.count < 1:
                raise ValueError(f"count={self.count} must be ≥ 1")

    def to_payload(self) -> dict:
        out = {"kind": self.kind, "target": self.target}
        for name in ("at", "after_n", "duration_s", "count"):
            val = getattr(self, name)
            if val is not None:
                out[name] = val
        return out

    @classmethod
    def from_payload(cls, payload: dict) -> "FaultSpec":
        known = {"kind", "target", "at", "after_n", "duration_s", "count"}
        extra = set(payload) - known
        if extra:
            raise ValueError(f"unknown FaultSpec fields: {sorted(extra)}")
        return cls(**payload)


@dataclass(frozen=True)
class FaultPlan:
    """An ordered, serializable set of scheduled faults."""
    specs: Tuple[FaultSpec, ...]
    seed: Optional[int] = None

    def __post_init__(self):
        object.__setattr__(self, "specs", tuple(self.specs))

    def __iter__(self):
        return iter(self.specs)

    def __len__(self) -> int:
        return len(self.specs)

    def for_target(self, target: str) -> Tuple[FaultSpec, ...]:
        return tuple(s for s in self.specs if s.target == target)

    def of_kind(self, *kinds: str) -> Tuple[FaultSpec, ...]:
        for k in kinds:
            if k not in FAULT_KINDS:
                raise ValueError(f"unknown fault kind {k!r}")
        return tuple(s for s in self.specs if s.kind in kinds)

    # -- serialization (the DeploymentPlan idiom) ---------------------

    def to_payload(self) -> dict:
        out = {"schema_version": FAULT_PLAN_SCHEMA_VERSION,
               "specs": [s.to_payload() for s in self.specs]}
        if self.seed is not None:
            out["seed"] = self.seed
        return out

    def to_json(self, *, indent: int = 2) -> str:
        return json.dumps(self.to_payload(), indent=indent,
                          sort_keys=True)

    @classmethod
    def from_payload(cls, payload: dict) -> "FaultPlan":
        version = payload.get("schema_version")
        if version != FAULT_PLAN_SCHEMA_VERSION:
            raise ValueError(
                f"unknown FaultPlan schema_version {version!r} "
                f"(this build reads {FAULT_PLAN_SCHEMA_VERSION})")
        return cls(specs=tuple(FaultSpec.from_payload(p)
                               for p in payload["specs"]),
                   seed=payload.get("seed"))

    @classmethod
    def from_json(cls, text: str) -> "FaultPlan":
        return cls.from_payload(json.loads(text))


def make_fault_plan(seed: int, *, workers: Sequence[str],
                    horizon_s: float,
                    kinds: Iterable[str] = ("crash_dispatch",)
                    ) -> FaultPlan:
    """Derive a reproducible ``FaultPlan`` from a seed: one spec per
    requested kind, each hitting a seeded-random worker at a
    seeded-random moment inside ``(0.2, 0.7) × horizon_s`` (away from
    the edges, so there is traffic both before and after the fault).
    The same ``(seed, workers, horizon_s, kinds)`` always yields the
    same plan — a failing chaos run names its seed and replays."""
    if not workers:
        raise ValueError("make_fault_plan needs at least one worker")
    if horizon_s <= 0:
        raise ValueError(f"horizon_s={horizon_s} must be > 0")
    rng = random.Random(seed)
    specs = []
    for kind in kinds:
        if kind not in FAULT_KINDS:
            raise ValueError(f"unknown fault kind {kind!r}; known: "
                             f"{FAULT_KINDS}")
        target = rng.choice(list(workers))
        at = round(rng.uniform(0.2, 0.7) * horizon_s, 6)
        if kind == "stall_heartbeat":
            specs.append(FaultSpec(
                kind, target, at=at,
                duration_s=round(rng.uniform(0.05, 0.2) * horizon_s, 6)))
        elif kind == "tracker_disk_full":
            specs.append(FaultSpec(kind, target,
                                   after_n=rng.randint(1, 16),
                                   count=rng.randint(1, 8)))
        else:
            specs.append(FaultSpec(kind, target, at=at))
    return FaultPlan(specs=tuple(specs), seed=seed)
