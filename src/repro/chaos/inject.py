"""Fault enforcement: the ``FaultInjector`` behind the runtime seams,
plus deterministic disk-fault helpers.

Runtime faults ride the production seams rather than monkeypatches:

* ``SlotPool``/``AsyncCNNGateway`` accept ``faults=`` — an object with
  ``check(point, now=..., **ctx)`` consulted at the **"dispatch"** seam
  (inside ``_run_batch``'s try, so a raise takes the real
  failed-dispatch path) and the **"heartbeat"** seam (``snapshot()``,
  so a raise reads as a missed heartbeat to ``FleetWorker.view``);
* ``JsonlTracker`` accepts ``io_fault=`` — a callable invoked before
  each disk write; ``FaultInjector.tracker_io_fault`` builds one from
  the plan's ``tracker_disk_full`` specs.

One injector executes one ``FaultPlan`` for any number of workers:
``for_target(worker_id)`` binds a per-worker seam to pass as the
gateway's ``faults=``.  A fired ``crash_dispatch`` is **sticky** — the
target keeps raising ``WorkerCrashed`` at every seam until
``revive(target)`` — because a dead process stays dead until something
restarts it; ``Fleet.respawn`` swaps in a fresh gateway (typically
*without* a bound seam), which is that restart.

Disk faults (``corrupt_cache_entry``, ``torn_plan_write``) are not
runtime checks: the harness applies them at the scheduled moment with
the deterministic helpers here (``corrupt_cache_entries``,
``tear_plan_write``) and the recovery layer proves serving survives.

This module imports nothing from ``repro.fleet`` or ``repro.serve`` —
``fleet.fleet`` imports ``WorkerCrashed`` from here, so the dependency
only points downward.
"""

from __future__ import annotations

import os
import threading
from pathlib import Path
from typing import Callable, Dict, List, Optional, Union

from repro.chaos.plan import FaultPlan, FaultSpec

__all__ = ["WorkerCrashed", "HeartbeatStalled", "TrackerDiskFull",
           "FaultInjector", "FaultSeam", "corrupt_cache_entries",
           "tear_plan_write"]


class WorkerCrashed(RuntimeError):
    """The worker process died (injected at the dispatch seam; the
    fleet treats it as a death, not a per-request failure)."""


class HeartbeatStalled(RuntimeError):
    """The worker's stats snapshot hung (injected at the heartbeat
    seam; reads as a missed heartbeat upstream)."""


class TrackerDiskFull(OSError):
    """The telemetry disk refused a write (injected via the tracker's
    ``io_fault`` seam)."""


#: which seam points each runtime fault kind fires at
_KIND_POINTS = {"crash_dispatch": ("dispatch",),
                "stall_heartbeat": ("heartbeat",)}


class FaultSeam:
    """A ``FaultInjector`` bound to one target — the object a gateway
    takes as ``faults=``.  ``check(point, now=...)`` raises when the
    plan says this target fails at this point now."""

    def __init__(self, injector: "FaultInjector", target: str):
        self.injector = injector
        self.target = target

    def check(self, point: str, now: Optional[float] = None,
              **ctx) -> None:
        self.injector.check(self.target, point, now=now, **ctx)

    def __repr__(self) -> str:                    # pragma: no cover
        return f"FaultSeam(target={self.target!r})"


class FaultInjector:
    """Executes a ``FaultPlan``'s runtime faults (see module docstring).

    Thread-safe: gateways consult seams from the event loop while the
    dispatch executor and samplers read clocks elsewhere.  ``injected``
    logs every fault firing as ``(kind, target, now)`` so harnesses can
    assert the schedule actually happened.
    """

    def __init__(self, plan: FaultPlan):
        self.plan = plan
        self._lock = threading.Lock()
        self._counts: Dict[FaultSpec, int] = {}
        self._fired: set = set()       # one-shot specs already fired
        self._crashed: set = set()     # targets sticky-crashed
        self.injected: List[tuple] = []

    def for_target(self, target: str) -> FaultSeam:
        return FaultSeam(self, target)

    def revive(self, target: str) -> None:
        """Clear a sticky crash — the restart side of the fault.  The
        crash spec that fired stays consumed, so a revived target does
        not immediately re-crash."""
        with self._lock:
            self._crashed.discard(target)

    @property
    def crashed(self) -> frozenset:
        with self._lock:
            return frozenset(self._crashed)

    # -- trigger/window evaluation (under self._lock) -----------------

    def _visit(self, spec: FaultSpec) -> int:
        n = self._counts.get(spec, 0) + 1
        self._counts[spec] = n
        return n

    def _active(self, spec: FaultSpec, now: Optional[float],
                visits: int) -> bool:
        if spec.at is not None:
            if now is None or now < spec.at:
                return False
            if spec.duration_s is not None \
                    and now >= spec.at + spec.duration_s:
                return False
            return True
        if visits < spec.after_n:
            return False
        if spec.count is not None \
                and visits >= spec.after_n + spec.count:
            return False
        return True

    # -- the runtime seam ---------------------------------------------

    def check(self, target: str, point: str,
              now: Optional[float] = None, **ctx) -> None:
        with self._lock:
            if target in self._crashed:
                raise WorkerCrashed(
                    f"worker {target!r} is dead (injected crash)")
            for spec in self.plan.for_target(target):
                points = _KIND_POINTS.get(spec.kind, ())
                if point not in points:
                    continue
                visits = self._visit(spec)
                if spec.kind == "crash_dispatch":
                    if spec in self._fired \
                            or not self._active(spec, now, visits):
                        continue
                    self._fired.add(spec)
                    self._crashed.add(target)
                    self.injected.append((spec.kind, target, now))
                    raise WorkerCrashed(
                        f"worker {target!r} crashed mid-dispatch "
                        f"(injected at t={now})")
                if spec.kind == "stall_heartbeat" \
                        and self._active(spec, now, visits):
                    self.injected.append((spec.kind, target, now))
                    raise HeartbeatStalled(
                        f"worker {target!r} heartbeat stalled "
                        f"(injected at t={now})")

    # -- the tracker seam ---------------------------------------------

    def tracker_io_fault(self, target: str
                         ) -> Optional[Callable[[dict], None]]:
        """An ``io_fault`` callable for ``JsonlTracker`` enforcing this
        target's ``tracker_disk_full`` specs, or None when the plan has
        none for it (so callers can pass it through unconditionally)."""
        specs = [s for s in self.plan.for_target(target)
                 if s.kind == "tracker_disk_full"]
        if not specs:
            return None

        def io_fault(entry: dict) -> None:
            with self._lock:
                for spec in specs:
                    visits = self._visit(spec)
                    if self._active(spec, None, visits):
                        self.injected.append((spec.kind, target, visits))
                        raise TrackerDiskFull(
                            f"telemetry disk full for {target!r} "
                            f"(injected, write #{visits})")

        return io_fault


# ---------------------------------------------------------------------------
# disk-fault helpers (applied by the harness at the scheduled moment)
# ---------------------------------------------------------------------------

_GARBAGE = b"\x00repro.chaos: corrupted cache entry\x00"


def corrupt_cache_entries(cache_dir: Union[str, Path], *,
                          limit: Optional[int] = None) -> List[Path]:
    """Overwrite serialized executables with garbage bytes — the
    on-disk effect of bit-rot or a torn write that slipped past fsync.
    Deterministic: entries are hit in sorted order, ``limit`` bounds
    how many.  Returns the paths corrupted.  Recovery contract: the
    cache quarantines each as ``*.corrupt`` and recompiles."""
    paths = sorted(Path(cache_dir).glob("*.exe"))
    if limit is not None:
        paths = paths[:limit]
    for p in paths:
        p.write_bytes(_GARBAGE)
    return paths


def tear_plan_write(store, plan_id: str, text: str, *,
                    cut: int) -> Path:
    """Stage what a crash mid-``atomic_write_text`` leaves behind: the
    temp file (same naming protocol — dot-prefixed, ``.tmp`` suffix,
    in the destination directory) holding the first ``cut`` bytes of
    ``text``, **without** the rename.  The store contract under test:
    the torn temp never shadows the live plan and never appears in
    listings — a reader after the crash sees the old plan bytes."""
    dest = store.path_for(plan_id)
    data = text.encode("utf-8")[:cut]
    tmp = dest.parent / (f".{dest.name}.{os.getpid()}"
                         f".{threading.get_ident()}.tmp")
    tmp.write_bytes(data)
    return tmp
