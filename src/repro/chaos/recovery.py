"""Restart-from-store: rebuild a dead worker's gateway from a shared
``StoreRoot``.

``respawn_gateway`` is the factory ``Fleet.respawn`` (or a
``FleetWorker(..., spawn=...)`` closure) uses to replace a killed
worker's process:

    root = StoreRoot("state")                    # shared by the fleet
    gw = respawn_gateway(root, "w1-v5e", ["cnn-v5e"])
    await fleet.respawn("w1-v5e", gateway=gw)

What "from the store" buys:

* the worker's **lease** is (re-)acquired — a takeover when the old
  holder is dead or is this very process, ``LeaseHeld`` when another
  live process still claims the identity;
* its **plans** are loaded from the shared ``PlanStore`` — no
  re-planning;
* its **executables** deserialize from the shared
  ``PersistentExecutableCache`` directory — the predecessor already
  paid the compile storm, so a warm respawn serves its first request
  with **zero recompiles** (the acceptance headline
  ``BENCH_recovery.json`` gates).

The returned gateway carries the held lease as ``gw.lease``; release
it when the gateway retires for good (a later takeover by the same
worker identity is safe either way — lease release is token-checked).

This module imports only ``repro.serve`` and ``repro.ops`` — never
``repro.fleet`` — so ``chaos`` sits below the fleet in the layering.
"""

from __future__ import annotations

import time
from typing import Callable, Optional, Sequence

from repro.ops.root import StoreRoot
from repro.serve.async_engine import AsyncCNNGateway, AsyncServeConfig

__all__ = ["respawn_gateway"]


def respawn_gateway(root: StoreRoot, worker_id: str,
                    plan_ids: Sequence[str],
                    cfg: Optional[AsyncServeConfig] = None, *,
                    clock: Callable[[], float] = time.monotonic,
                    tracker=None, faults=None) -> AsyncCNNGateway:
    """Build a replacement gateway for ``worker_id`` from the shared
    store (see module docstring).  Raises ``LeaseHeld`` when a live
    foreign process still owns the identity, and whatever the plan
    store raises when a plan is missing/corrupt — a respawn must fail
    loudly, not serve a partial plan set."""
    lease = root.acquire_lease(worker_id)
    try:
        gw = AsyncCNNGateway(cfg, clock=clock,
                             exec_cache=root.exec_cache(),
                             tracker=tracker, faults=faults)
        for plan_id in plan_ids:
            gw.register_plan(root.plans.load(plan_id), plan_id=plan_id)
    except BaseException:
        lease.release()
        raise
    gw.lease = lease
    return gw
