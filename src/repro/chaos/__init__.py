"""``repro.chaos`` — deterministic fault injection and fleet recovery.

Production-viability is survival under faults, not just peak
throughput.  This package proves the serving stack's invariants hold
through failures, with the same determinism the planner applies to
resources:

* ``FaultPlan`` / ``FaultSpec`` / ``make_fault_plan`` — a seeded,
  JSON-serializable schedule of faults (worker crash mid-dispatch,
  stalled heartbeat, corrupt cache entry, torn plan write, tracker
  disk-full);
* ``FaultInjector`` — executes a plan's runtime faults through the
  production seams (``SlotPool``/``AsyncCNNGateway`` ``faults=``,
  ``JsonlTracker`` ``io_fault=``), never monkeypatches;
  ``corrupt_cache_entries`` / ``tear_plan_write`` apply the disk
  faults;
* ``respawn_gateway`` — restart-from-store recovery: rebuild a dead
  worker's gateway from a shared ``repro.ops.StoreRoot`` (lease
  takeover, plans from the shared ``PlanStore``, executables
  deserialized from the shared cache → zero recompiles), ready for
  ``Fleet.respawn`` to re-admit through the health-probe path.

The fleet-wide contract under kill→restart, pinned by
``benchmarks/recovery_bench.py`` (live and in ``fleet.sim``):
``completed + refused == trace`` and ``lost == 0`` — every request
either completes on its original deadline budget or is refused
loudly; none vanish.  See ``docs/fleet.md`` and ``docs/ops.md``.
"""

from repro.chaos.inject import (FaultInjector, FaultSeam,
                                HeartbeatStalled, TrackerDiskFull,
                                WorkerCrashed, corrupt_cache_entries,
                                tear_plan_write)
from repro.chaos.plan import (FAULT_KINDS, FAULT_PLAN_SCHEMA_VERSION,
                              FaultPlan, FaultSpec, make_fault_plan)
from repro.chaos.recovery import respawn_gateway

__all__ = [
    "FAULT_KINDS", "FAULT_PLAN_SCHEMA_VERSION", "FaultSpec", "FaultPlan",
    "make_fault_plan",
    "FaultInjector", "FaultSeam",
    "WorkerCrashed", "HeartbeatStalled", "TrackerDiskFull",
    "corrupt_cache_entries", "tear_plan_write",
    "respawn_gateway",
]
