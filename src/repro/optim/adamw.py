"""AdamW with optional block-wise 8-bit quantized moments.

The 8-bit mode stores m and v as int8 with one fp32 scale per 256-element
block (bitsandbytes-style dynamic quantization, TPU-adapted: block size is
lane-aligned and the quantize/dequantize round-trips are fused elementwise
VPU work).  For the ~400B assigned configs this takes the optimizer-state
footprint from 8 bytes/param to 2 bytes/param — the difference between
fitting and not fitting v5e HBM at 256 chips (see EXPERIMENTS.md §Perf).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp

BLOCK = 256


@dataclass(frozen=True)
class AdamWConfig:
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    state_dtype: str = "float32"     # float32 | int8


# ---------------------------------------------------------------------------
# block-wise int8 state codec
# ---------------------------------------------------------------------------

def _pad_to_block(flat):
    n = flat.shape[0]
    pad = (-n) % BLOCK
    return jnp.pad(flat, (0, pad)), n


def quantize_state(x):
    """fp32 array -> (int8 codes, fp32 per-block scales, orig shape)."""
    flat, n = _pad_to_block(x.reshape(-1).astype(jnp.float32))
    blocks = flat.reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-20)
    codes = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return {"codes": codes, "scale": scale[:, 0]}


def dequantize_state(q, shape):
    blocks = q["codes"].astype(jnp.float32) * q["scale"][:, None]
    n = 1
    for d in shape:
        n *= d
    return blocks.reshape(-1)[:n].reshape(shape)


# ---------------------------------------------------------------------------
# init / update
# ---------------------------------------------------------------------------

def adamw_init(params, cfg: AdamWConfig):
    def zeros_like_state(p):
        z = jnp.zeros(p.shape, jnp.float32)
        if cfg.state_dtype == "int8":
            return quantize_state(z)
        return z
    return {
        "step": jnp.zeros((), jnp.int32),
        "m": jax.tree.map(zeros_like_state, params),
        "v": jax.tree.map(zeros_like_state, params),
    }


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def adamw_update(grads, opt_state, params, lr, cfg: AdamWConfig):
    step = opt_state["step"] + 1
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))

    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)
    q8 = cfg.state_dtype == "int8"

    def upd(g, m, v, p):
        g = g.astype(jnp.float32) * clip
        m_f = dequantize_state(m, g.shape) if q8 else m
        v_f = dequantize_state(v, g.shape) if q8 else v
        m_f = cfg.b1 * m_f + (1 - cfg.b1) * g
        v_f = cfg.b2 * v_f + (1 - cfg.b2) * jnp.square(g)
        upd = (m_f / b1c) / (jnp.sqrt(v_f / b2c) + cfg.eps)
        if p.ndim >= 2:  # decoupled weight decay on matrices only
            upd = upd + cfg.weight_decay * p.astype(jnp.float32)
        new_p = (p.astype(jnp.float32) - lr * upd).astype(p.dtype)
        if q8:
            return new_p, quantize_state(m_f), quantize_state(v_f)
        return new_p, m_f, v_f

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(opt_state["m"])
    flat_v = treedef.flatten_up_to(opt_state["v"])
    out = [upd(g, m, v, p)
           for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    new_params = jax.tree_util.tree_unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree_util.tree_unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree_util.tree_unflatten(treedef, [o[2] for o in out])
    new_state = {"step": step, "m": new_m, "v": new_v}
    return new_params, new_state, {"grad_norm": gnorm}
