"""Paper Table 5: predicted resource utilization for block allocations at
8-bit precision — the mixed 80%-target allocation plus single-block rows."""

from __future__ import annotations

from benchmarks.common import emit
from repro.core import allocate, synth


def run():
    rows = synth.run_sweep()
    bm = allocate.BlockModels.fit(rows)

    mix = allocate.allocate(bm, data_bits=8, coeff_bits=8, target=0.8)
    counts = ";".join(f"{b}={n}" for b, n in mix.counts.items())
    usage = ";".join(f"{r}={u:.1f}%" for r, u in mix.usage_pct.items())
    emit("table5/mixed_80pct", 0.0,
         f"{counts};total_convs={mix.total_convs:.0f};{usage}")

    for block in ("conv1", "conv2", "conv3", "conv4"):
        single = allocate.allocate(bm, data_bits=8, coeff_bits=8,
                                   target=0.8, only_block=block)
        usage = ";".join(f"{r}={u:.1f}%"
                         for r, u in single.usage_pct.items())
        emit(f"table5/only_{block}", 0.0,
             f"n={single.counts[block]};"
             f"total_convs={single.total_convs:.0f};{usage}")


if __name__ == "__main__":
    run()
