"""Seeded million-request SLO benchmark for the serving fleet.

One seeded Poisson trace — interactive 20% (deadline 250 ms), batch 30%
(2 s), best-effort 50% (no deadline, 15 s p99 SLO) — offered at 2.2×
a single v5e's full-batch capacity, replayed on the virtual clock of
``repro.fleet.sim`` through four configurations:

  single_v5e    one v5e worker (the pre-fleet deployment).  Overloaded
                by construction: EDF keeps interactive alive, but batch
                and best-effort blow their SLOs.
  round_robin   the heterogeneous edge/v5e/v5p fleet under the naive
                router — one third of the traffic lands on an edge part
                with a tenth of the capacity, and every tier's p99
                collapses under the edge backlog.
  least_loaded  load-aware, cost-blind placement over the same fleet.
  plan_aware    the headline: deadline-tight traffic to the fastest
                admissible worker, best-effort to the cheapest profile
                that fits.  Meets every per-tier SLO the single worker
                misses and beats round-robin's deadline-tier p99 by
                orders of magnitude.

A fifth run drains the v5e worker mid-trace under the plan-aware
router and pins the graceful-drain invariant: zero admitted requests
lost, zero re-routed requests served past their deadline.

Everything is virtual-clock and seed-deterministic: the same
``--seed`` produces a bit-identical ``BENCH_fleet.json`` (the default
committed artifact is the full 1,000,000-request run; CI replays a
50,000-request slice and uploads its own copy).
"""

from __future__ import annotations

import json
from pathlib import Path

from benchmarks.common import DEFAULT_SEED, add_seed_argument, emit
from repro.fleet import SimWorkerSpec, make_trace, profile_speed, simulate
from repro.fleet.sim import V5E_IMAGE_S, V5E_OVERHEAD_S

REQUESTS = 1_000_000
MAX_BATCH = 8
OCCUPANCY = 2.2                  # offered load ÷ single-v5e capacity
DRAIN_FRACTION = 0.4             # drain v5e this far into the trace
JSON_PATH = "BENCH_fleet.json"

#: the heterogeneous fleet: one worker per catalog profile
FLEET_SPECS = (
    SimWorkerSpec("w0-edge", "edge", ("cnn",), MAX_BATCH),
    SimWorkerSpec("w1-v5e", "v5e", ("cnn",), MAX_BATCH),
    SimWorkerSpec("w2-v5p", "v5p", ("cnn",), MAX_BATCH),
)
SINGLE_SPEC = (SimWorkerSpec("solo-v5e", "v5e", ("cnn",), MAX_BATCH),)

#: tiers whose deadline makes p99 an SLA, not just a report
DEADLINE_TIERS = ("interactive", "batch")


def v5e_capacity() -> float:
    """Images/sec of one v5e at full batch — the load unit."""
    return MAX_BATCH / (V5E_OVERHEAD_S + MAX_BATCH * V5E_IMAGE_S)


def run(json_path: str | Path = JSON_PATH, *, requests: int = REQUESTS,
        seed: int = DEFAULT_SEED) -> dict:
    rate = OCCUPANCY * v5e_capacity()
    trace = make_trace(requests, rate, seed=seed)
    fleet_rate = sum(
        MAX_BATCH / ((V5E_OVERHEAD_S + MAX_BATCH * V5E_IMAGE_S)
                     / profile_speed(s.resolve_profile()))
        for s in FLEET_SPECS)
    emit("fleet/offered_load", 0.0,
         f"rate={rate:.0f}img_per_s;requests={requests};"
         f"fleet_capacity={fleet_rate:.0f}img_per_s")

    runs = {}
    runs["single_v5e"] = simulate(SINGLE_SPEC, trace, "least_loaded")
    for router in ("round_robin", "least_loaded", "plan_aware"):
        runs[router] = simulate(FLEET_SPECS, trace, router)
    drain = simulate(FLEET_SPECS, trace, "plan_aware",
                     drain_at=DRAIN_FRACTION * float(trace.arrivals[-1]),
                     drain_worker="w1-v5e")
    runs["plan_aware_drain"] = drain

    for name, r in runs.items():
        for tier, d in r.per_tier.items():
            emit(f"fleet/{name}_{tier}_p99", d["p99_s"] * 1e6,
                 f"slo={d['slo_p99_s']}s;met={d['slo_met']}")

    single, rr, pa = runs["single_v5e"], runs["round_robin"], \
        runs["plan_aware"]
    single_missed = [t for t, d in single.per_tier.items()
                     if not d["slo_met"]]
    acceptance = {
        # every per-tier SLO the single worker misses, plan-aware meets
        "single_v5e_missed_tiers": single_missed,
        "plan_aware_meets_single_missed": all(
            pa.per_tier[t]["slo_met"] for t in single_missed),
        "plan_aware_all_slos_met": pa.all_slos_met,
        # plan-aware beats round-robin on every deadline tier's p99
        "plan_aware_beats_round_robin_deadline_p99": all(
            pa.per_tier[t]["p99_s"] < rr.per_tier[t]["p99_s"]
            for t in DEADLINE_TIERS),
        # graceful drain: nothing admitted is lost or served late
        "drain_rerouted": drain.rerouted,
        "drain_zero_lost": drain.lost == 0
        and drain.completed == requests,
        "drain_zero_late_rerouted": drain.late_rerouted == 0,
    }
    headline = all(v is not False for v in acceptance.values())
    emit("fleet/acceptance", 0.0,
         ";".join(f"{k}={v}" for k, v in acceptance.items()))

    payload = {
        "bench": "fleet",
        "schema": 1,
        "seed": seed,
        "requests": requests,
        "max_batch": MAX_BATCH,
        "occupancy_vs_single_v5e": OCCUPANCY,
        "offered_rate_per_s": rate,
        "fleet_capacity_per_s": fleet_rate,
        "drain_fraction": DRAIN_FRACTION,
        "runs": {name: r.to_payload() for name, r in runs.items()},
        "acceptance": acceptance,
        "accepted": headline,
    }
    Path(json_path).write_text(json.dumps(payload, indent=2) + "\n")
    return payload


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--json", default=JSON_PATH,
                    help=f"output path (default {JSON_PATH})")
    ap.add_argument("--requests", type=int, default=REQUESTS,
                    help=f"trace length (default {REQUESTS:,}; CI uses "
                         f"50000)")
    add_seed_argument(ap)
    a = ap.parse_args()
    run(a.json, requests=a.requests, seed=a.seed)
