"""Kill-mid-trace recovery benchmark: the fleet loses nothing through
a worker crash, and a warm respawn from the shared store recompiles
nothing.

Two halves, one artifact (``BENCH_recovery.json``):

**Simulated** (virtual clock, bit-reproducible): the heterogeneous
edge/v5e/v5p fleet under the plan-aware router replays the seeded
Poisson trace three ways — undisturbed baseline, kill the v5p worker
mid-trace with a warm respawn later, and kill with no respawn.  The
kill voids the worker's in-flight batch (the process died mid-dispatch,
unlike a graceful drain) and re-routes it plus the queue on original
deadlines.  Gates: ``completed == requests`` and ``lost == 0`` through
the kill, the kill actually re-routed work, and the respawned worker
demonstrably returns to rotation (it serves strictly more than in the
no-respawn run).

**Live** (asyncio, real executables): two gateway workers share one
``repro.ops.StoreRoot`` (one ``PlanStore`` + one persistent executable
cache + per-worker leases).  A seeded ``FaultPlan`` crashes worker
``a`` at its first dispatch; the fleet kills it and re-routes every
queued and mid-dispatch request; ``Fleet.respawn`` rebuilds the worker
from the shared store via ``repro.chaos.respawn_gateway`` and the
health probe re-admits it.  Gates: ``completed + refused == requests``
with every completion bit-exact against the reference forward,
``rerouted > 0``, and the respawned gateway reports **zero compiles**
(every executable deserialized from the predecessor's cache —
``disk_hits > 0``).

Same ``--seed`` → bit-identical simulated payloads; the live half's
invariant gates are timing-independent.
"""

from __future__ import annotations

import asyncio
import json
import tempfile
import time
from pathlib import Path

import numpy as np

from benchmarks.common import DEFAULT_SEED, add_seed_argument, emit

REQUESTS = 200_000
MAX_BATCH = 8
OCCUPANCY = 2.2                  # offered load ÷ single-v5e capacity
KILL_FRACTION = 0.4              # kill this far into the trace...
RESPAWN_FRACTION = 0.6           # ...respawn here
KILL_WORKER = "w1-v5e"           # the loaded worker: deepest queue to
                                 # re-route (the v5p clears its queue
                                 # too fast to be mid-batch reliably)
LIVE_REQUESTS = 48
JSON_PATH = "BENCH_recovery.json"


def _fleet_specs():
    from repro.fleet import SimWorkerSpec
    return (SimWorkerSpec("w0-edge", "edge", ("cnn",), MAX_BATCH),
            SimWorkerSpec("w1-v5e", "v5e", ("cnn",), MAX_BATCH),
            SimWorkerSpec("w2-v5p", "v5p", ("cnn",), MAX_BATCH))


def run_sim(requests: int, seed: int) -> dict:
    from repro.fleet import make_trace, simulate
    from repro.fleet.sim import V5E_IMAGE_S, V5E_OVERHEAD_S

    rate = OCCUPANCY * MAX_BATCH / (V5E_OVERHEAD_S
                                    + MAX_BATCH * V5E_IMAGE_S)
    trace = make_trace(requests, rate, seed=seed)
    horizon = float(trace.arrivals[-1])
    specs = _fleet_specs()

    baseline = simulate(specs, trace, "plan_aware")
    killed = simulate(specs, trace, "plan_aware",
                      kill_at=KILL_FRACTION * horizon,
                      kill_worker=KILL_WORKER,
                      respawn_at=RESPAWN_FRACTION * horizon)
    no_respawn = simulate(specs, trace, "plan_aware",
                          kill_at=KILL_FRACTION * horizon,
                          kill_worker=KILL_WORKER)

    for name, r in (("baseline", baseline), ("kill_respawn", killed),
                    ("kill_only", no_respawn)):
        emit(f"recovery/sim_{name}", 0.0,
             f"completed={r.completed};lost={r.lost};"
             f"rerouted={r.rerouted};kill_rerouted={r.kill_rerouted}")

    return {
        "requests": requests,
        "horizon_s": horizon,
        "kill_at_s": KILL_FRACTION * horizon,
        "respawn_at_s": RESPAWN_FRACTION * horizon,
        "kill_worker": KILL_WORKER,
        "runs": {"baseline": baseline.to_payload(),
                 "kill_respawn": killed.to_payload(),
                 "kill_only": no_respawn.to_payload()},
    }


def run_live(seed: int) -> dict:
    from repro.chaos import (FaultInjector, FaultPlan, FaultSpec,
                             respawn_gateway)
    from repro.core import deploy
    from repro.core.cnn import (CNNConfig, ConvLayerSpec, cnn_forward_ref,
                                fitted_block_models)
    from repro.fleet import Fleet, FleetError, FleetWorker, HealthPolicy
    from repro.ops import StoreRoot
    from repro.runtime import CompiledCNN
    from repro.serve import AsyncServeConfig

    import jax.numpy as jnp

    cfg = CNNConfig(layers=(
        ConvLayerSpec(1, 4, data_bits=8, coeff_bits=6, block="conv4"),
        ConvLayerSpec(4, 3, data_bits=6, coeff_bits=4, block="conv3"),
    ), img_h=16, img_w=64)
    plan = deploy.plan_deployment(cfg, fitted_block_models(),
                                  target=0.8, on_infeasible="fallback")

    with tempfile.TemporaryDirectory(prefix="recovery-bench-") as tmp:
        root = StoreRoot(Path(tmp) / "state")
        root.plans.save(plan, "cnn")

        # the predecessor process pays the compile storm into the
        # shared cache — what makes the respawn warm
        t0 = time.perf_counter()
        pre = root.exec_cache()
        compiled = CompiledCNN.from_plan(plan, max_batch=4,
                                         exec_cache=pre)
        cold_compile_s = time.perf_counter() - t0

        fault_plan = FaultPlan((
            FaultSpec("crash_dispatch", "a", after_n=1),), seed=seed)
        inj = FaultInjector(fault_plan)

        def _serve_cfg():
            return AsyncServeConfig(max_batch=4,
                                    max_pending=2 * LIVE_REQUESTS)

        respawn_s = [0.0]

        def spawn_a():
            t0 = time.perf_counter()
            inj.revive("a")
            gw = respawn_gateway(root, "a", ["cnn"], _serve_cfg())
            respawn_s[0] = time.perf_counter() - t0
            return gw

        gw_a = respawn_gateway(root, "a", ["cnn"], _serve_cfg(),
                               faults=inj.for_target("a"))
        gw_b = respawn_gateway(root, "b", ["cnn"], _serve_cfg())
        imgs = compiled.sample_inputs(LIVE_REQUESTS, seed=seed)

        async def main():
            workers = [
                FleetWorker("a", gw_a, "v5e", spawn=spawn_a,
                            health=HealthPolicy(eject_after=1,
                                                probe_interval=0.05)),
                FleetWorker("b", gw_b, "v5e"),
            ]
            fleet = Fleet(workers, router="round_robin")
            async with fleet:
                futs, refused = [], 0
                for i, img in enumerate(imgs):
                    try:
                        futs.append(fleet.submit_nowait(img))
                    except FleetError:
                        refused += 1
                    if i % 4 == 3:      # let dispatches (and the
                        await asyncio.sleep(0.005)  # crash) happen
                outs = await asyncio.gather(*futs)
                killed = fleet.workers["a"].dead
                await fleet.respawn("a")
                # the canaries that re-admit the respawned worker
                t0 = time.perf_counter()
                canary = [await fleet.infer(img) for img in imgs[:2]]
                first_served_s = time.perf_counter() - t0
                readmitted = fleet.workers["a"].health.healthy
                cache_stats = (fleet.workers["a"].gateway
                               .exec_cache.stats())
                return (outs, refused, canary, killed, readmitted,
                        first_served_s, cache_stats, fleet.stats())

        (outs, refused, canary, killed, readmitted, first_served_s,
         cache_stats, fleet_stats) = asyncio.run(main())

        pcfg = deploy.plan_config(plan)
        refs = [np.asarray(cnn_forward_ref(compiled.params,
                                           jnp.asarray(i), pcfg))
                for i in imgs]
        bit_exact = (
            all(np.array_equal(o, r) for o, r in zip(outs, refs))
            and np.array_equal(canary[0], refs[0]))

        leases = root.list_leases()

    live = {
        "requests": LIVE_REQUESTS,
        "completed": len(outs),
        "refused": refused,
        "rerouted": fleet_stats["rerouted"],
        "kills": fleet_stats["kills"],
        "respawns": fleet_stats["respawns"],
        "worker_killed": killed,
        "worker_readmitted": readmitted,
        "bit_exact": bit_exact,
        "injected": [[k, t] for k, t, _ in inj.injected],
        "leases": leases,
        "respawn_compiles": cache_stats["compiles"],
        "respawn_disk_hits": cache_stats["disk_hits"],
        "cold_compile_s": cold_compile_s,
        "respawn_build_s": respawn_s[0],
        "respawn_first_served_s": first_served_s,
    }
    emit("recovery/live_kill_respawn", first_served_s * 1e6,
         f"completed={live['completed']};refused={refused};"
         f"rerouted={live['rerouted']};"
         f"respawn_compiles={live['respawn_compiles']}")
    return live


def run(json_path: str | Path = JSON_PATH, *, requests: int = REQUESTS,
        seed: int = DEFAULT_SEED) -> dict:
    sim = run_sim(requests, seed)
    live = run_live(seed)

    killed = sim["runs"]["kill_respawn"]
    dead = sim["runs"]["kill_only"]
    victim = KILL_WORKER
    acceptance = {
        # nothing admitted is lost through the kill, sim or live
        "sim_zero_lost": killed["lost"] == 0
        and killed["completed"] == requests,
        "sim_kill_rerouted": killed["kill_rerouted"],
        # the respawn demonstrably returned the worker to rotation
        "sim_respawn_restores_service":
            killed["per_worker"][victim]["served"]
            > dead["per_worker"][victim]["served"],
        "live_zero_lost":
            live["completed"] + live["refused"] == live["requests"],
        "live_rerouted": live["rerouted"],
        "live_bit_exact": live["bit_exact"],
        "live_worker_readmitted": live["worker_readmitted"],
        # the warm-respawn headline: restart-from-store compiles nothing
        "live_respawn_zero_recompiles": live["respawn_compiles"] == 0,
        "live_respawn_disk_hits": live["respawn_disk_hits"],
    }
    headline = all(
        v is not False and v != 0 for v in acceptance.values())
    emit("recovery/acceptance", 0.0,
         ";".join(f"{k}={v}" for k, v in acceptance.items()))

    payload = {
        "bench": "recovery",
        "schema": 1,
        "seed": seed,
        "occupancy_vs_single_v5e": OCCUPANCY,
        "kill_fraction": KILL_FRACTION,
        "respawn_fraction": RESPAWN_FRACTION,
        "sim": sim,
        "live": live,
        "acceptance": acceptance,
        "accepted": headline,
    }
    Path(json_path).write_text(json.dumps(payload, indent=2) + "\n")
    return payload


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--json", default=JSON_PATH,
                    help=f"output path (default {JSON_PATH})")
    ap.add_argument("--requests", type=int, default=REQUESTS,
                    help=f"simulated trace length (default {REQUESTS:,}; "
                         f"CI uses 50000)")
    add_seed_argument(ap)
    a = ap.parse_args()
    run(a.json, requests=a.requests, seed=a.seed)
