"""Bucketed AOT dispatch vs the fixed-max_batch serving step.

Two serving costs the ``repro.runtime`` redesign removes, measured:

  padding waste — the seed engine ran every tick at the full
      ``(max_batch, H, W, C)`` shape, so occupancy 1 paid for 16.
      Here each occupancy k ∈ {1, 4, 16} is timed through
      (a) ``CompiledCNN`` bucketed dispatch (pad to the smallest
      AOT bucket ≥ k) and (b) the old fixed path (pad to max_batch,
      one jitted ``cnn_forward``) — images/sec per occupancy.
  compile stall — the first call on a cold (warmup=False)
      ``CompiledCNN`` pays trace+compile inside the serving path; the
      same call after ``warmup()`` is pure dispatch.  Both are timed,
      plus the warmup cost itself (paid once, off the critical path).

Every measured path is verified bit-exact against ``cnn_forward_ref``
first.  ``run`` records ``BENCH_runtime.json`` (uploaded by the CI
sweep job); the headline is bucketed ≥ 2× fixed images/sec at
occupancy ≤ 2.
"""

from __future__ import annotations

import json
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_call
from repro.core import deploy
from repro.core.cnn import (cnn_forward, cnn_forward_ref,
                            fitted_block_models, init_cnn,
                            quickstart_cnn_config)
from repro.kernels import ops
from repro.runtime import CompiledCNN

MAX_BATCH = 16
OCCUPANCIES = (1, 2, 4, 16)
JSON_PATH = "BENCH_runtime.json"


def run(json_path: str | Path = JSON_PATH) -> dict:
    cfg = quickstart_cnn_config()
    plan = deploy.plan_deployment(cfg, fitted_block_models(), target=0.8,
                                  on_infeasible="fallback")
    pcfg = deploy.plan_config(plan)
    params = init_cnn(jax.random.PRNGKey(0), pcfg)
    blocks = plan.block_names()

    rng = np.random.default_rng(0)
    d0 = pcfg.layers[0].data_bits
    xs = ops.quantize_fixed(
        jnp.asarray(rng.integers(0, 1 << (d0 - 1),
                                 (MAX_BATCH, cfg.img_h, cfg.img_w,
                                  pcfg.layers[0].in_channels)),
                    jnp.float32), d0)
    y_ref = np.asarray(cnn_forward_ref(params, xs, pcfg))

    # -- cold start: compile stall on the serving path vs AOT warmup ----
    cold = CompiledCNN.from_plan(plan, params=params, max_batch=MAX_BATCH,
                                 warmup=False)
    t0 = time.perf_counter()
    y1 = np.asarray(cold(xs[:1]))
    first_call_cold_ms = (time.perf_counter() - t0) * 1e3
    assert (y1 == y_ref[:1]).all(), "cold bucketed path diverged"

    warm = CompiledCNN.from_plan(plan, params=params, max_batch=MAX_BATCH,
                                 warmup=False)
    t0 = time.perf_counter()
    warm.warmup()
    warmup_ms = (time.perf_counter() - t0) * 1e3
    t0 = time.perf_counter()
    y1 = np.asarray(warm(xs[:1]))
    first_call_warm_ms = (time.perf_counter() - t0) * 1e3
    assert (y1 == y_ref[:1]).all()
    emit("runtime/first_call_cold", first_call_cold_ms * 1e3,
         "compile stall on the serving path")
    emit("runtime/first_call_warm", first_call_warm_ms * 1e3,
         f"after AOT warmup ({warmup_ms:.0f}ms off the critical path)")

    # -- padding waste: bucketed vs fixed-max_batch per occupancy -------
    fixed = jax.jit(lambda p, x: cnn_forward(p, x, pcfg, blocks))
    yf = np.asarray(fixed(params, xs))           # compile + verify
    assert (yf == y_ref).all(), "fixed path diverged"

    results = []
    for k in OCCUPANCIES:
        xk = xs[:k]
        assert (np.asarray(warm(xk)) == y_ref[:k]).all(), k

        def fixed_step(xk=xk, k=k):
            # the seed engine's tick: live images scattered into the
            # static (max_batch, ...) tensor, full-shape forward
            pad = jnp.zeros((MAX_BATCH - k,) + xk.shape[1:], xk.dtype)
            return fixed(params, jnp.concatenate([xk, pad]))[:k]

        us_fixed = time_call(fixed_step, iters=5)
        us_bucketed = time_call(lambda xk=xk: warm(xk), iters=5)
        speedup = us_fixed / us_bucketed
        results.append({
            "occupancy": k,
            "bucket": warm.bucket_for(k),
            "us_bucketed": us_bucketed,
            "us_fixed": us_fixed,
            "images_per_sec_bucketed": k / us_bucketed * 1e6,
            "images_per_sec_fixed": k / us_fixed * 1e6,
            "speedup_bucketed_vs_fixed": speedup,
        })
        emit(f"runtime/bucketed_occ{k}", us_bucketed,
             f"bucket={warm.bucket_for(k)};"
             f"images_per_s={k / us_bucketed * 1e6:.0f}")
        emit(f"runtime/fixed_occ{k}", us_fixed,
             f"batch={MAX_BATCH};images_per_s={k / us_fixed * 1e6:.0f}")
        emit(f"runtime/speedup_occ{k}", 0.0,
             f"bucketed_vs_fixed={speedup:.2f}x")

    payload = {
        "bench": "runtime",
        "schema": 1,
        "max_batch": MAX_BATCH,
        "buckets": list(warm.buckets),
        "blocks": blocks,
        "device_count": len(jax.devices()),
        "occupancy_results": results,
        "cold_start": {
            "first_call_cold_ms": first_call_cold_ms,
            "warmup_ms": warmup_ms,
            "first_call_warm_ms": first_call_warm_ms,
            "stall_removed_ms": first_call_cold_ms - first_call_warm_ms,
        },
        "speedup_occ1": results[0]["speedup_bucketed_vs_fixed"],
        "speedup_occ2": results[1]["speedup_bucketed_vs_fixed"],
    }
    Path(json_path).write_text(json.dumps(payload, indent=2) + "\n")
    return payload


if __name__ == "__main__":
    run()
