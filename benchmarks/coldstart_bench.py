"""Cold vs warm restart through the persistent executable cache.

Both runs build the same plan-driven serving engine over the same
on-disk cache directory and time **cold-start-to-first-served**: from
"process start" (engine construction begins) to the first request
coming back served.  What differs is the disk state:

  cold   the cache directory is empty — every batch bucket of every
      layer is XLA-compiled live, then persisted (``cache_disk_store``)
  warm   a *new* ``PersistentExecutableCache`` instance over the now
      populated directory — every lookup deserializes a stored
      executable (``cache_disk_hit``), and the compile counter must
      stay at **zero**

Each run constructs a fresh ``CompiledCNN`` with fresh per-layer jit
closures, so JAX's in-process jit cache cannot leak compilations
across runs — the cold compile cost is real, and the warm run's zero
compiles is the persistence layer working, not Python-level caching.

``run`` records ``BENCH_coldstart.json`` (uploaded by the CI sweep
job, gated by ``scripts/check_coldstart_bench.py``); the headline is
warm restart reaching first-served ≥ 3× faster than cold with zero
recompiles.
"""

from __future__ import annotations

import json
import shutil
import tempfile
import time
from pathlib import Path

import jax
import numpy as np

from benchmarks.common import DEFAULT_SEED, add_seed_argument, emit
from repro.core import deploy
from repro.core.cnn import fitted_block_models, quickstart_cnn_config
from repro.ops import PersistentExecutableCache
from repro.serve import CNNEngine, CNNServeConfig, ImageRequest

MAX_BATCH = 8                          # bucket ladder 1/2/4/8 per layer
WARM_RUNS = 3                          # median over repeated warm starts
JSON_PATH = "BENCH_coldstart.json"


def _launch(plan, cache_dir, seed) -> dict:
    """One 'process launch': build the engine through a fresh cache
    instance over ``cache_dir`` and serve one request; returns the
    cold-start-to-first-served wall time and the cache counters."""
    cache = PersistentExecutableCache(cache_dir)
    t0 = time.perf_counter()
    engine = CNNEngine.from_plan(
        plan, serve_cfg=CNNServeConfig(max_batch=MAX_BATCH),
        exec_cache=cache)
    img = engine.compiled.sample_inputs(1, seed=seed)[0]
    req = ImageRequest(image=img, request_id=0)
    assert engine.submit(req)
    served = engine.step()
    jax.block_until_ready(req.output)
    elapsed = time.perf_counter() - t0
    assert served == 1 and req.done
    s = cache.stats()
    return {"to_first_served_s": elapsed, "compiles": s["compiles"],
            "disk_hits": s["disk_hits"], "disk_stores": s["disk_stores"]}


def run(json_path: str = JSON_PATH, seed: int = DEFAULT_SEED) -> dict:
    cfg = quickstart_cnn_config()
    plan = deploy.plan_deployment(cfg, fitted_block_models(), target=0.8,
                                  on_infeasible="fallback")
    root = Path(tempfile.mkdtemp(prefix="coldstart_bench_"))
    try:
        cache_dir = root / "exe"
        cold = _launch(plan, cache_dir, seed)
        warms = [_launch(plan, cache_dir, seed) for _ in range(WARM_RUNS)]
    finally:
        shutil.rmtree(root, ignore_errors=True)

    warms.sort(key=lambda r: r["to_first_served_s"])
    warm = warms[len(warms) // 2]
    speedup = cold["to_first_served_s"] / warm["to_first_served_s"]
    emit("coldstart/cold", cold["to_first_served_s"] * 1e6,
         f"compiles={cold['compiles']}")
    emit("coldstart/warm", warm["to_first_served_s"] * 1e6,
         f"compiles={warm['compiles']};disk_hits={warm['disk_hits']}")
    emit("coldstart/speedup", 0.0, f"{speedup:.2f}x")

    payload = {
        "bench": "coldstart",
        "schema": 1,
        "seed": seed,
        "max_batch": MAX_BATCH,
        "warm_runs": WARM_RUNS,
        "layers": len(plan.layers),
        "device_count": len(jax.devices()),
        "jax_version": jax.__version__,
        "cold": cold,
        "warm": warm,
        "warm_all_s": [r["to_first_served_s"] for r in warms],
        # acceptance: warm restart reaches first-served ≥ 3× faster
        # than cold and never touches the compiler
        "speedup": speedup,
        "warm_compiles": warm["compiles"],
    }
    Path(json_path).write_text(json.dumps(payload, indent=2) + "\n")
    return payload


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--json", default=JSON_PATH,
                    help=f"output path (default {JSON_PATH})")
    add_seed_argument(ap)
    a = ap.parse_args()
    run(a.json, seed=a.seed)
