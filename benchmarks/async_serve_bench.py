"""Continuous-batching gateway vs the tick-loop engine under Poisson
arrivals.

Both engines serve the same plan, the same ``CompiledCNN`` bucket
ladder, and the *same arrival sequence*; what differs is the serving
discipline:

  tick loop   the sync ``CNNEngine`` driven the way a fixed global tick
      drives it: every ``tick_s`` (the full-batch service time) the
      queue backfills the slots and one blocking step runs.  Admission
      is blind — the queue is unbounded, so overload accumulates and
      every later request pays the backlog.
  gateway     ``AsyncCNNGateway``: a new bucket dispatch launches the
      moment slots free (no tick alignment, and ``max_inflight=2``
      stages the next batch while one is on-device), and admission is
      **adaptive** — the pending bound tracks measured service rate ×
      ``WAIT_BUDGET_S`` (capped at ``MAX_PENDING``), so the queue holds
      what the hardware clears inside the budget and overload beyond
      that is shed at the door.

Each occupancy k (offered load = k × full-batch service capacity) is
driven in real time with seeded exponential inter-arrivals; latency is
measured arrival→completion.  ``run`` records ``BENCH_async_serve.json``
(uploaded by the CI sweep job, gated by scripts/check_async_bench.py);
the headline is the gateway at occupancy ≥ 2 holding p99 ≤ 0.7× the
tick loop's while serving at least as many images/sec at *every*
occupancy (adaptive admission sheds whole requests only past the wait
budget, not while slots are reachable).
"""

from __future__ import annotations

import asyncio
import json
import time
from collections import deque
from pathlib import Path

import jax
import numpy as np

from benchmarks.common import DEFAULT_SEED, add_seed_argument, emit
from repro.core import deploy
from repro.core.cnn import fitted_block_models, quickstart_cnn_config
from repro.runtime import CompiledCNN
from repro.serve import (AsyncCNNGateway, AsyncServeConfig, CNNEngine,
                         CNNServeConfig, GatewayBacklog, ImageRequest)

MAX_BATCH = 8
MAX_PENDING = 128                      # hard cap on the adaptive bound
MIN_PENDING = 3 * MAX_BATCH            # adaptive floor: keeps a transient
                                       # rate-estimate dip (host noise)
                                       # from shedding a recoverable burst
WAIT_BUDGET_S = 0.1                    # bound ≈ measured rate × budget
MAX_INFLIGHT = 2                       # overlap the next dispatch's host
                                       # prep with the serial execution
                                       # stream (hides the dispatch gap)
BATCH_LINGER = 0.5                     # idle pool + partial batch: wait
                                       # up to half a batch-service-time
                                       # for it to fill before dispatch
                                       # (k=1 slivers burn whole slots)
WARMUP_BATCHES = 3                     # prime the rate estimator
OCCUPANCIES = (0.5, 1.0, 2.0, 4.0)
REQUESTS = 192                         # per occupancy per pass
PASSES = 2                             # alternating tick/async passes per
                                       # occupancy, pooled — host-noise
                                       # drift lands on both disciplines
JSON_PATH = "BENCH_async_serve.json"


def _percentiles(lat_s):
    p = np.percentile(np.asarray(lat_s) * 1e3, [50, 95, 99])
    return {"p50_ms": float(p[0]), "p95_ms": float(p[1]),
            "p99_ms": float(p[2])}


def _measure_step_s(compiled, imgs) -> float:
    xb = np.stack([np.asarray(i, compiled.in_dtype)
                   for i in imgs[:MAX_BATCH]])
    times = []
    for _ in range(4):
        t0 = time.perf_counter()
        jax.block_until_ready(compiled(xb))
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]


def _run_tick_loop(engine: CNNEngine, imgs, arrivals, tick_s):
    """The seed discipline under live traffic: a global tick every
    ``tick_s``; arrived requests backfill the slots at the tick edge
    (unbounded queue), then one blocking step.  Latency is
    arrival→completion per request."""
    n = len(arrivals)
    reqs = [ImageRequest(image=imgs[i], request_id=i) for i in range(n)]
    queue: deque = deque()
    inflight: list = []
    lat = [0.0] * n
    served = 0
    i = 0
    t0 = time.monotonic()
    next_tick = t0
    while served < n:
        now = time.monotonic()
        if now < next_tick:
            time.sleep(next_tick - now)
        next_tick += tick_s
        now = time.monotonic()
        while i < n and t0 + arrivals[i] <= now:
            queue.append(i)
            i += 1
        while queue and engine.submit(reqs[queue[0]]):
            inflight.append(queue.popleft())
        engine.step()
        done_at = time.monotonic()
        still = []
        for k in inflight:
            if reqs[k].done:
                lat[k] = done_at - (t0 + arrivals[k])
                served += 1
            else:
                still.append(k)
        inflight = still
        # a drained pool with no arrivals yet: skip ahead to the next
        # arrival's tick edge instead of spinning empty ticks
        if not queue and not inflight and i < n:
            while next_tick < t0 + arrivals[i]:
                next_tick += tick_s
    makespan = time.monotonic() - t0
    return lat, makespan


def _run_gateway(gw: AsyncCNNGateway, imgs, arrivals):
    """Same arrival sequence through the async front door; overload is
    shed at the admission bound (latency is over served requests).

    One submitter coroutine walks the arrival sequence — the async
    analogue of the tick loop's arrival scan — instead of a task per
    request: hundreds of concurrent sleeper tasks would contend with
    the gateway for the event loop and the benchmark would measure the
    driver, not the serving discipline."""
    n = len(arrivals)

    async def drive():
        latencies, shed = [], 0
        async with gw:
            # warm the gateway's rate estimator the same way
            # _measure_step_s warms the compiled ladder for the tick
            # loop: a few full batches through the real dispatch path,
            # so adaptive admission starts from a measured service rate
            # instead of its min_pending floor
            for _ in range(WARMUP_BATCHES):
                await asyncio.gather(*[gw.submit_nowait(im)
                                       for im in imgs[:MAX_BATCH]])
            t0 = time.monotonic()

            def on_done(fut, scheduled_at):
                if not fut.cancelled() and fut.exception() is None:
                    latencies.append(time.monotonic() - scheduled_at)

            futs = []
            for i in range(n):
                delay = arrivals[i] - (time.monotonic() - t0)
                if delay > 0:
                    await asyncio.sleep(delay)
                else:
                    # running behind: yield so dispatch/completion
                    # callbacks interleave with the arrival burst
                    # (each arrival is an independent client; the
                    # submitter must not monopolise the event loop)
                    await asyncio.sleep(0)
                try:
                    fut = gw.submit_nowait(imgs[i])
                except GatewayBacklog:
                    shed += 1
                    continue
                fut.add_done_callback(
                    lambda f, at=t0 + arrivals[i]: on_done(f, at))
                futs.append(fut)
            await asyncio.gather(*futs, return_exceptions=True)
            return latencies, shed, time.monotonic() - t0

    return asyncio.run(drive())


def run(json_path: str | Path = JSON_PATH, *,
        seed: int = DEFAULT_SEED) -> dict:
    cfg = quickstart_cnn_config()
    plan = deploy.plan_deployment(cfg, fitted_block_models(), target=0.8,
                                  on_infeasible="fallback")
    compiled = CompiledCNN.from_plan(plan, max_batch=MAX_BATCH)
    imgs = compiled.sample_inputs(REQUESTS)
    step_s = _measure_step_s(compiled, imgs)
    capacity = MAX_BATCH / step_s
    emit("async_serve/full_batch_step", step_s * 1e6,
         f"capacity={capacity:.0f}images_per_s")

    results = []
    for occ in OCCUPANCIES:
        rate = occ * capacity
        rng = np.random.default_rng(seed)
        arrivals = np.cumsum(rng.exponential(1.0 / rate, REQUESTS))

        tick_lat: list = []
        tick_span = 0.0
        gw_lat: list = []
        gw_span = 0.0
        shed = 0
        for _ in range(PASSES):
            engine = CNNEngine(compiled.cfg, compiled.params,
                               compiled.blocks,
                               CNNServeConfig(max_batch=MAX_BATCH),
                               compiled=compiled)
            lat, span = _run_tick_loop(engine, imgs, arrivals, step_s)
            tick_lat.extend(lat)
            tick_span += span

            gw = AsyncCNNGateway(AsyncServeConfig(
                max_batch=MAX_BATCH, max_pending=MAX_PENDING,
                min_pending=MIN_PENDING, wait_budget_s=WAIT_BUDGET_S,
                max_inflight=MAX_INFLIGHT, batch_linger=BATCH_LINGER))
            gw.register_plan(plan, plan_id="bench", compiled=compiled)
            lat, sh, span = _run_gateway(gw, imgs, arrivals)
            gw_lat.extend(lat)
            gw_span += span
            shed += sh
        tick_pct = _percentiles(tick_lat)
        tick_ips = PASSES * REQUESTS / tick_span
        gw_pct = _percentiles(gw_lat)
        served = len(gw_lat)
        gw_ips = served / gw_span

        row = {
            "occupancy": occ,
            "offered_images_per_sec": rate,
            "requests": PASSES * REQUESTS,
            "tick": {"images_per_sec": tick_ips, **tick_pct,
                     "served": PASSES * REQUESTS},
            "async": {"images_per_sec": gw_ips, **gw_pct,
                      "served": served, "shed": shed},
            "speedup_images_per_sec": gw_ips / tick_ips,
            "p99_ratio_async_vs_tick": gw_pct["p99_ms"]
            / tick_pct["p99_ms"],
            "p50_ratio_async_vs_tick": gw_pct["p50_ms"]
            / tick_pct["p50_ms"],
        }
        results.append(row)
        emit(f"async_serve/occ{occ:g}_tick_p99", tick_pct["p99_ms"] * 1e3,
             f"images_per_s={tick_ips:.0f}")
        emit(f"async_serve/occ{occ:g}_async_p99", gw_pct["p99_ms"] * 1e3,
             f"images_per_s={gw_ips:.0f};shed={shed}")
        emit(f"async_serve/occ{occ:g}_ratio", 0.0,
             f"p99={row['p99_ratio_async_vs_tick']:.2f}x;"
             f"ips={row['speedup_images_per_sec']:.2f}x")

    overloaded = [r for r in results if r["occupancy"] >= 2.0]
    headline = min(r["p99_ratio_async_vs_tick"] for r in overloaded)
    payload = {
        "bench": "async_serve",
        "schema": 2,
        "seed": seed,
        "passes": PASSES,
        "max_batch": MAX_BATCH,
        "max_pending": MAX_PENDING,
        "min_pending": MIN_PENDING,
        "wait_budget_s": WAIT_BUDGET_S,
        "max_inflight": MAX_INFLIGHT,
        "batch_linger": BATCH_LINGER,
        "full_batch_step_ms": step_s * 1e3,
        "capacity_images_per_sec": capacity,
        "device_count": len(jax.devices()),
        "occupancy_results": results,
        # acceptance: at occupancy ≥ 2, async holds p99 ≤ 0.7× the tick
        # loop (bounded admission) or serves ≥ 1.5× the images/sec
        "headline_p99_ratio_at_overload": headline,
        "headline_speedup_at_overload": max(
            r["speedup_images_per_sec"] for r in overloaded),
    }
    Path(json_path).write_text(json.dumps(payload, indent=2) + "\n")
    return payload


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--json", default=JSON_PATH,
                    help=f"output path (default {JSON_PATH})")
    add_seed_argument(ap)
    a = ap.parse_args()
    run(a.json, seed=a.seed)
