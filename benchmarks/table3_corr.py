"""Paper Table 3: Pearson correlations between (data_bits, coeff_bits) and
each resource class, per block."""

from __future__ import annotations

from benchmarks.common import emit
from repro.core import correlate, synth


def run(verbose: bool = True):
    rows = synth.run_sweep()
    for block in ("conv1", "conv2", "conv3", "conv4"):
        table = correlate.correlation_table(rows, block)
        for res, entry in table.items():
            emit(f"table3/{block}/{synth.fpga_name(res)}", 0.0,
                 f"corr_data={entry['data_bits']:.3f};"
                 f"corr_coeff={entry['coeff_bits']:.3f};"
                 f"family={correlate.choose_model_family(entry)}")


if __name__ == "__main__":
    run()
