"""Shared benchmark helpers: timing, CSV emission, seeding."""

from __future__ import annotations

import argparse
import time

import jax

#: the one seed every benchmark defaults to — recorded JSONs are
#: reproducible runs of this seed unless a ``--seed`` says otherwise
DEFAULT_SEED = 42


def add_seed_argument(parser: argparse.ArgumentParser, *,
                      default: int = DEFAULT_SEED) -> argparse.ArgumentParser:
    """Attach the shared ``--seed`` flag (benchmarks that draw traffic
    traces all spell it the same way)."""
    parser.add_argument(
        "--seed", type=int, default=default,
        help=f"rng seed for generated traffic (default {default}; the "
             f"committed BENCH jsons use the default)")
    return parser


def time_call(fn, *args, warmup: int = 1, iters: int = 5) -> float:
    """Median wall-time per call in microseconds (jit-compiled fn)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6


def emit(name: str, us_per_call: float, derived: str = ""):
    print(f"{name},{us_per_call:.1f},{derived}")
