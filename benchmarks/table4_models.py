"""Paper Table 4 (+ Figures 1-3 data): Algorithm-1 polynomial models per
block with EQM/EAM/R²/EAMP error metrics; prints the fitted formulas for
the paper's headline LLUT models."""

from __future__ import annotations

import numpy as np

from benchmarks.common import emit
from repro.core import polyfit, synth


def run():
    rows = synth.run_sweep()
    for block in ("conv1", "conv2", "conv3", "conv4"):
        d, c, ys = synth.sweep_arrays(rows, block)
        for res in synth.RESOURCES:
            y = ys[res]
            if np.std(y) < 1e-12:
                continue
            m = polyfit.fit_auto(d, c, y, block=block)
            met = polyfit.error_metrics(y, m.predict(d, c))
            kind = (f"seg[{m.scheme}]" if isinstance(m, polyfit.SegmentedModel)
                    else f"poly(deg{m.degree})")
            emit(f"table4/{block}/{synth.fpga_name(res)}", 0.0,
                 f"model={kind};mse={met['mse']:.4g};mae={met['mae']:.4g};"
                 f"r2={met['r2']:.4f};mape_pct={met['mape_pct']:.3f}")
        # headline formula (paper prints the Conv4 LLUT polynomial)
        m_llut = polyfit.fit_auto(d, c, ys["vpu_ops"], block=block)
        if isinstance(m_llut, polyfit.PolyModel):
            emit(f"table4/{block}/LLUT_formula", 0.0,
                 m_llut.formula("LLUT").replace(",", ";"))


if __name__ == "__main__":
    run()
