"""Paper Table 2: characteristics of the four convolution blocks.

Reports, per block at the 8/8-bit design point: wall-time per call
(CPU-interpret — correctness path), MXU vs VPU resource split from the op
census, and convolutions per grid step — reproducing the paper's
DSP/logic trade-off rows.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_call
from repro.core import synth
from repro.kernels import ops


def run():
    rng = np.random.default_rng(0)
    x = ops.quantize_fixed(
        jnp.asarray(rng.integers(-100, 100, (64, 128)), jnp.float32), 8)
    w1 = ops.quantize_fixed(
        jnp.asarray(rng.integers(-100, 100, (3, 3)), jnp.float32), 8)
    w2 = ops.quantize_fixed(
        jnp.asarray(rng.integers(-100, 100, (2, 3, 3)), jnp.float32), 8)
    rows = synth.run_sweep()
    for block in ("conv1", "conv2", "conv3", "conv4"):
        w = w1 if block in ("conv1", "conv2") else w2
        us = time_call(lambda b=block, ww=w: ops.conv_block(
            b, x, ww, data_bits=8, coeff_bits=8))
        r = next(rr for rr in rows
                 if rr["block"] == block and rr["data_bits"] == 8
                 and rr["coeff_bits"] == 8)
        derived = (f"mxu_cost={r['mxu_cost']:.0f};vpu_ops={r['vpu_ops']:.0f};"
                   f"convs_per_step={r['convs_per_step']:.0f};"
                   f"packed={int(r['packed'])}")
        emit(f"table2/{block}_8b", us, derived)


if __name__ == "__main__":
    run()
