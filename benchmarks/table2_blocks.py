"""Paper Table 2: characteristics of the convolution blocks.

Reports, per registered block at the 8/8-bit design point: wall-time per
call (CPU-interpret — correctness path), MXU vs VPU resource split from
the op census, and convolutions per grid step — reproducing the paper's
DSP/logic trade-off rows.  Iterates the ``repro.blocks`` registry, so a
newly registered block shows up in the table automatically.
"""

from __future__ import annotations

import sys

import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_call
from repro.blocks import get_block, list_blocks
from repro.core import synth
from repro.kernels import ops


def run():
    rng = np.random.default_rng(0)
    x = ops.quantize_fixed(
        jnp.asarray(rng.integers(-100, 100, (64, 128)), jnp.float32), 8)
    rows = synth.run_sweep()
    for name in list_blocks():
        blk = get_block(name)
        r = next((rr for rr in rows
                  if rr["block"] == name and rr["data_bits"] == 8
                  and rr["coeff_bits"] == 8), None)
        if r is None:           # block registered after the cached sweep
            print(f"table2: no sweep row for {name!r} — re-run the sweep "
                  f"with this block registered (stale cache?)",
                  file=sys.stderr)
            continue
        w = ops.quantize_fixed(
            jnp.asarray(rng.integers(-100, 100, blk.weight_shape(8)),
                        jnp.float32), 8)
        us = time_call(lambda b=blk, ww=w: b.apply(
            x, ww, data_bits=8, coeff_bits=8))
        derived = (f"mxu_cost={r['mxu_cost']:.0f};vpu_ops={r['vpu_ops']:.0f};"
                   f"convs_per_step={r['convs_per_step']:.0f};"
                   f"packed={int(r['packed'])}")
        emit(f"table2/{name}_8b", us, derived)


if __name__ == "__main__":
    run()
