"""Quantized-MoE serving throughput through the workload-generic stack.

Plans a small MoE workload for the v5e profile (``plan_moe_deployment``
picks each layer's (data_bits, coeff_bits)), then serves token blocks
two ways per batch size N ∈ {1, 2, 4, 8}:

  eager    — N un-jitted op-by-op MoE stacks, one per request (the
             pre-AOT serving baseline: every router/gather/FFN op
             dispatched individually)
  bucketed — ONE AOT-compiled ``CompiledMoE`` dispatch on the padded
             (N, S, d) bucket (what ``CNNEngine``/``AsyncCNNGateway``
             run per tick)

Every batch size is verified bit-exact against the eager quantized
stack before timing, and the recorded ``BENCH_moe_serve.json`` gates on
the bucketed path meeting or beating eager tokens/sec at every N —
the acceptance number CI uploads.
"""

from __future__ import annotations

import json
from pathlib import Path

import jax
import numpy as np

from benchmarks.common import emit, time_call
from repro.runtime import plan_moe_deployment
from repro.runtime.workloads import (CompiledMoE, MoELayerSpec,
                                     MoEWorkloadSpec, _eager_forward,
                                     moe_plan_spec)

BATCH_SIZES = (1, 2, 4, 8)
JSON_PATH = "BENCH_moe_serve.json"


def build_spec() -> MoEWorkloadSpec:
    # capacity_factor * top_k / num_experts >= 1 makes expert capacity
    # cover the worst-case load, so routing never drops a token and the
    # bucketed batch is bit-comparable to the per-request eager stacks
    return MoEWorkloadSpec(
        layers=(MoELayerSpec(d_ff_expert=64, num_experts=8, top_k=2,
                             capacity_factor=4.0),
                MoELayerSpec(d_ff_expert=64, num_experts=8, top_k=2,
                             n_shared_experts=1, capacity_factor=4.0)),
        d_model=32, seq_len=16)


def run(json_path: str | Path = JSON_PATH) -> dict:
    plan = plan_moe_deployment(build_spec(), "v5e", target=0.8,
                               on_infeasible="fallback")
    spec = moe_plan_spec(plan)
    bits = [(a.data_bits, a.coeff_bits) for a in plan.layers]
    compiled = CompiledMoE.from_plan(plan, max_batch=max(BATCH_SIZES))
    params = compiled.params
    seq_len = spec.seq_len

    rng = np.random.default_rng(0)
    xs = rng.standard_normal(
        (max(BATCH_SIZES), seq_len, spec.d_model)).astype(np.float32)

    results = []
    for n in BATCH_SIZES:
        xb = xs[:n]
        # bit-exactness first: one bucketed dispatch vs N eager stacks
        yb = np.asarray(compiled(xb))
        ye = np.concatenate(
            [np.asarray(_eager_forward(spec, params, xb[i:i + 1]))
             for i in range(n)])
        assert np.array_equal(yb, ye), \
            f"bucketed N={n} diverged from the eager quantized stack"

        def eager(xb=xb, n=n):
            return [_eager_forward(spec, params, xb[i:i + 1])
                    for i in range(n)]

        us_eager = time_call(lambda: eager()[-1], iters=3)
        us_bucketed = time_call(lambda: compiled(xb), iters=3)
        results.append({
            "batch": n,
            "us_bucketed": us_bucketed,
            "us_eager": us_eager,
            "tokens_per_sec_bucketed": n * seq_len / us_bucketed * 1e6,
            "tokens_per_sec_eager": n * seq_len / us_eager * 1e6,
        })
        emit(f"moe_serve/bucketed_n{n}", us_bucketed,
             f"tok_per_s={n * seq_len / us_bucketed * 1e6:.0f}")
        emit(f"moe_serve/eager_n{n}", us_eager,
             f"tok_per_s={n * seq_len / us_eager * 1e6:.0f}")

    # acceptance: the AOT bucketed path never loses to op-by-op eager
    accepted = all(r["tokens_per_sec_bucketed"]
                   >= r["tokens_per_sec_eager"] for r in results)
    big = results[-1]
    speedup = (big["tokens_per_sec_bucketed"]
               / big["tokens_per_sec_eager"])
    emit("moe_serve/speedup_n8", 0.0,
         f"bucketed_vs_eager={speedup:.2f}x;accepted={accepted}")

    payload = {
        "bench": "moe_serve",
        "schema": 1,
        "device": plan.device.name,
        "layer_bits": bits,
        "quant_error": plan.quant_error,
        "seq_len": seq_len,
        "d_model": spec.d_model,
        "device_count": len(jax.devices()),
        "batch_sizes": list(BATCH_SIZES),
        "results": results,
        "speedup_n8_bucketed_vs_eager": speedup,
        "accepted": accepted,
    }
    assert accepted, "bucketed AOT MoE lost to the eager baseline"
    Path(json_path).write_text(json.dumps(payload, indent=2) + "\n")
    return payload


if __name__ == "__main__":
    run()
