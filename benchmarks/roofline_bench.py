"""Roofline table: three terms per (arch × shape × mesh) cell from the
dry-run corpus (results/*.json) — see EXPERIMENTS.md §Roofline."""

from __future__ import annotations

import json
from pathlib import Path

from benchmarks.common import emit
from repro.core.model_dse import load_corpus
from repro.core.roofline import roofline_terms


def run(results_dir: str = "results", tag: str = "baseline"):
    rows = load_corpus(results_dir, tag)
    if not rows:
        emit(f"roofline/{tag}", 0.0, "no-results-yet")
        return
    for r in rows:
        t = roofline_terms(r)
        emit(f"roofline/{tag}/{r['arch']}/{r['shape']}/{r['mesh']}", 0.0,
             f"compute_s={t['compute_s']:.4g};memory_s={t['memory_s']:.4g};"
             f"collective_s={t['collective_s']:.4g};"
             f"dominant={t['dominant'].removesuffix('_s')};"
             f"roofline_frac={t['roofline_fraction']:.4f};"
             f"useful_flops_ratio={t['useful_flops_ratio']:.3f}")


if __name__ == "__main__":
    run()
