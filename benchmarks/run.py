"""Benchmark driver — one section per paper table plus the roofline and
framework-DSE tables.  Prints ``name,us_per_call,derived`` CSV."""

from __future__ import annotations


def main() -> None:
    from benchmarks import (async_serve_bench, cnn_forward_bench,
                            cnn_serve_bench, deploy_bench, fleet_bench,
                            model_dse_bench, moe_serve_bench,
                            roofline_bench, runtime_bench, table2_blocks,
                            table3_corr, table4_models, table5_alloc)
    print("name,us_per_call,derived")
    table2_blocks.run()
    table3_corr.run()
    table4_models.run()
    table5_alloc.run()
    cnn_forward_bench.run()
    cnn_serve_bench.run()      # also writes BENCH_cnn_serve.json
    runtime_bench.run()        # also writes BENCH_runtime.json
    async_serve_bench.run()    # also writes BENCH_async_serve.json
    fleet_bench.run()          # also writes BENCH_fleet.json
    moe_serve_bench.run()      # also writes BENCH_moe_serve.json
    deploy_bench.run()
    roofline_bench.run()
    model_dse_bench.run()


if __name__ == "__main__":
    main()
