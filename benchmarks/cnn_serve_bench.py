"""Batched multi-image CNN serving throughput (the (N, H, W, C) win).

Runs the quickstart CNN with planner-chosen blocks two ways per batch
size N ∈ {1, 4, 16}:

  sequential — N jitted single-image ``cnn_forward`` calls, one per
               image (the pre-batching serving baseline)
  batched    — ONE jitted ``cnn_forward`` call on the (N, H, W, C)
               batch, every layer a single fused batched kernel (the
               ``serve.cnn_engine`` step)

Every batch size is verified bit-exact against the per-image
``cnn_forward_ref`` oracle before timing.  Besides the usual CSV rows,
``run`` records the trajectory point ``BENCH_cnn_serve.json``
(images/sec per batch size, device count, and the headline
batched-N=16-vs-sequential speedup) for CI to upload.
"""

from __future__ import annotations

import json
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_call
from repro.core.cnn import (choose_blocks, cnn_forward, cnn_forward_ref,
                            init_cnn, quickstart_cnn_config)
from repro.kernels import ops

BATCH_SIZES = (1, 4, 16)
JSON_PATH = "BENCH_cnn_serve.json"


def run(json_path: str | Path = JSON_PATH) -> dict:
    cfg = quickstart_cnn_config()
    blocks = choose_blocks(cfg)
    names = "+".join(b.name for b in blocks)
    params = init_cnn(jax.random.PRNGKey(0), cfg)

    rng = np.random.default_rng(0)
    n_max = max(BATCH_SIZES)
    xs = ops.quantize_fixed(
        jnp.asarray(rng.integers(0, 100, (n_max, cfg.img_h, cfg.img_w, 1)),
                    jnp.float32), 8)

    fwd = jax.jit(lambda p, x: cnn_forward(p, x, cfg, blocks))

    results = []
    for n in BATCH_SIZES:
        xb = xs[:n]
        # bit-exactness first: batched forward vs the per-image oracle
        yb = np.asarray(fwd(params, xb))
        yr = np.asarray(cnn_forward_ref(params, xb, cfg))
        assert (yb == yr).all(), \
            f"batched N={n} forward diverged from the oracle"

        def sequential(xb=xb, n=n):
            return [fwd(params, xb[i]) for i in range(n)]

        us_seq = time_call(lambda: sequential()[-1], iters=3)
        us_batched = time_call(lambda: fwd(params, xb), iters=3)
        results.append({
            "batch": n,
            "us_batched": us_batched,
            "us_sequential": us_seq,
            "images_per_sec_batched": n / us_batched * 1e6,
            "images_per_sec_sequential": n / us_seq * 1e6,
        })
        emit(f"cnn_serve/batched_n{n}", us_batched,
             f"blocks={names};images_per_s={n / us_batched * 1e6:.0f}")
        emit(f"cnn_serve/sequential_n{n}", us_seq,
             f"images_per_s={n / us_seq * 1e6:.0f}")

    # headline: one batched N=16 step vs 16 sequential N=1 calls
    seq1 = results[0]["images_per_sec_sequential"]
    big = results[-1]["images_per_sec_batched"]
    speedup = big / seq1
    emit("cnn_serve/speedup_n16", 0.0,
         f"batched_n16_vs_n1_sequential={speedup:.2f}x")

    payload = {
        "bench": "cnn_serve",
        "schema": 1,
        "blocks": [b.name for b in blocks],
        "device_count": len(jax.devices()),
        "batch_sizes": list(BATCH_SIZES),
        "results": results,
        "speedup_n16_vs_sequential": speedup,
    }
    Path(json_path).write_text(json.dumps(payload, indent=2) + "\n")
    return payload


if __name__ == "__main__":
    run()
