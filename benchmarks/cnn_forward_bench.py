"""Batched vs per-plane-loop CNN forward (the apply_batched win).

Runs the quickstart CNN (the examples/cnn_blocks.py configuration) two
ways with identical allocator-chosen blocks:

  loop     — seed baseline: one Python-level kernel dispatch per
             (out_ch, in_ch) plane, O(out_ch·in_ch) calls per layer
             (``cnn_forward_loop``)
  batched  — one jitted/vmapped kernel call per layer
             (``cnn_forward`` via ``ConvBlock.apply_batched``)

Both are verified bit-exact against ``cnn_forward_ref`` before timing;
``derived`` reports the speedup.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_call
from repro.core.cnn import (choose_blocks, cnn_forward, cnn_forward_loop,
                            cnn_forward_ref, init_cnn, quickstart_cnn_config)
from repro.kernels import ops


def quickstart_cnn():
    cfg = quickstart_cnn_config()
    params = init_cnn(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    x = ops.quantize_fixed(
        jnp.asarray(rng.integers(0, 100, (cfg.img_h, cfg.img_w, 1)),
                    jnp.float32), 8)
    return cfg, params, x


def run():
    cfg, params, x = quickstart_cnn()
    blocks = choose_blocks(cfg)
    names = "+".join(b.name for b in blocks)

    yr = np.asarray(cnn_forward_ref(params, x, cfg))
    yb = np.asarray(cnn_forward(params, x, cfg, blocks))
    yl = np.asarray(cnn_forward_loop(params, x, cfg, blocks))
    assert (yb == yr).all(), "batched forward diverged from oracle"
    assert (yl == yr).all(), "loop forward diverged from oracle"

    us_loop = time_call(lambda: cnn_forward_loop(params, x, cfg, blocks),
                        iters=3)
    us_batched = time_call(lambda: cnn_forward(params, x, cfg, blocks),
                           iters=3)
    emit("cnn_forward/loop", us_loop, f"blocks={names}")
    emit("cnn_forward/batched", us_batched,
         f"blocks={names};speedup={us_loop / us_batched:.2f}x")


if __name__ == "__main__":
    run()
