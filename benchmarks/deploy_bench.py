"""Deployment-planner benchmark: planning latency over the catalog and
the resulting frontier/selection quality on the quickstart CNN."""

from __future__ import annotations

import time

from benchmarks.common import emit
from repro.core import allocate, deploy, synth
from repro.core.allocate import DEVICE_CATALOG
from repro.core.cnn import quickstart_cnn_config


def run():
    cfg = quickstart_cnn_config()
    rows = synth.run_sweep()
    bm = allocate.BlockModels.fit(rows)

    for dev in DEVICE_CATALOG:
        t0 = time.perf_counter()
        try:
            plan = deploy.plan_deployment(
                cfg, bm, dev, bit_candidates=deploy.DEFAULT_BIT_CANDIDATES)
            detail = (f"feasible=1;util={plan.max_usage_pct:.1f}%;"
                      f"blocks={'/'.join(plan.block_names())}")
        except deploy.DeploymentError:
            detail = "feasible=0"
        emit(f"deploy/plan_{dev.name}",
             (time.perf_counter() - t0) * 1e6, detail)

    t0 = time.perf_counter()
    frontier = deploy.pareto_frontier(cfg, bm, DEVICE_CATALOG)
    emit("deploy/pareto_frontier", (time.perf_counter() - t0) * 1e6,
         f"points={len(frontier)};devices="
         + "/".join(sorted({p.device.name for p in frontier})))

    t0 = time.perf_counter()
    dev, plan = deploy.select_device(
        cfg, bm, bit_candidates=deploy.DEFAULT_BIT_CANDIDATES)
    emit("deploy/select_device", (time.perf_counter() - t0) * 1e6,
         f"device={dev.name};cost={dev.cost};util={plan.max_usage_pct:.1f}%")

    t0 = time.perf_counter()
    val = deploy.validate_plan(plan, cfg)
    worst = max(val.metrics[r]["mape_pct"]
                for r in allocate.BUDGET_RESOURCES)
    emit("deploy/validate_plan", (time.perf_counter() - t0) * 1e6,
         f"bit_exact={int(val.bit_exact)};worst_mape={worst:.2f}%;"
         f"quant_err={val.quant_error:.4f}")


if __name__ == "__main__":
    run()
