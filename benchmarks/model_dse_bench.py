"""Framework-level DSE validation (beyond-paper): Algorithm-1 models
predicting compiled roofline inputs from analytic features, leave-one-out
validated over the dry-run corpus."""

from __future__ import annotations

from benchmarks.common import emit
from repro.core.model_dse import fit_dse, load_corpus


def run(results_dir: str = "results", tag: str = "baseline"):
    rows = load_corpus(results_dir, tag)
    if len(rows) < 8:
        emit("model_dse/skipped", 0.0, f"corpus={len(rows)}-cells")
        return
    dse = fit_dse(rows)
    for tgt, met in dse.loo.items():
        emit(f"model_dse/{tgt}", 0.0,
             f"cells={len(rows)};loo_r2={met['r2']:.4f};"
             f"loo_mape_pct={met['mape_pct']:.1f};"
             f"loo_log10_mae={met['log_mae']:.3f}")


if __name__ == "__main__":
    run()
