"""The plan-aware serving fleet: three workers on heterogeneous device
profiles behind one front door, tiered traffic routed by deadline and
cost, a worker failure absorbed by retry + health ejection, and a
graceful mid-traffic drain that loses nothing — all bit-exact against
the per-image oracle.

    PYTHONPATH=src python examples/serve_fleet.py
"""

import asyncio
import sys

sys.path.insert(0, "src")

import jax.numpy as jnp
import numpy as np

from repro.core import deploy
from repro.core.cnn import (CNNConfig, ConvLayerSpec, cnn_forward_ref,
                            fitted_block_models)
from repro.fleet import DEFAULT_TIERS, Fleet, FleetWorker
from repro.serve import AsyncCNNGateway, AsyncServeConfig

CFG = CNNConfig(layers=(
    ConvLayerSpec(1, 4, data_bits=8, coeff_bits=6, block="conv4"),
    ConvLayerSpec(4, 3, data_bits=6, coeff_bits=4, block="conv3"),
), img_h=16, img_w=64)


def make_worker(worker_id, profile, plan):
    gw = AsyncCNNGateway(AsyncServeConfig(max_batch=4, max_pending=32))
    gw.register_plan(plan, plan_id="cnn")
    return FleetWorker(worker_id, gw, profile)


async def main():
    plan = deploy.plan_deployment(CFG, fitted_block_models(), target=0.8,
                                  on_infeasible="fallback")
    workers = [make_worker(f"{p}0", p, plan)
               for p in ("edge", "v5e", "v5p")]
    fleet = Fleet(workers, router="plan_aware")
    print("fleet:", ", ".join(
        f"{w.worker_id} (cost {w.profile.cost}×)" for w in workers))

    compiled = workers[1].gateway.plans["cnn"].compiled
    imgs = compiled.sample_inputs(24)
    tiers = [t for t in DEFAULT_TIERS for _ in range(8)]

    async with fleet:
        futs = [await fleet.submit(img, tier=tier,
                                   deadline=DEFAULT_TIERS[tier].deadline_s)
                for img, tier in zip(imgs, tiers)]
        # take the v5e out for maintenance mid-traffic: queued requests
        # re-route, in-flight batches finish, nothing is lost
        await fleet.drain("v5e0")
        outs = await asyncio.gather(*futs)

    pcfg = deploy.plan_config(plan)
    exact = all(np.array_equal(out, np.asarray(
        cnn_forward_ref(compiled.params, jnp.asarray(img), pcfg)))
        for img, out in zip(imgs, outs))
    stats = fleet.stats()
    print(f"served {stats['served']}/{len(imgs)} "
          f"(rerouted={stats['rerouted']}, drains={stats['drains']})")
    for wid, w in stats["workers"].items():
        print(f"  {wid:<6} profile={w['profile']:<5} "
              f"served={w['snapshot']['served']:<3} "
              f"draining={w['draining']}")
    print(f"spot-check vs per-image oracle: bit-exact={exact}")
    assert exact and stats["served"] == len(imgs)


if __name__ == "__main__":
    asyncio.run(main())
