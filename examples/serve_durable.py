"""Durable serving state end to end: plan once into an on-disk
``PlanStore``, compile once into a ``PersistentExecutableCache``, then
restart — the second "process" loads the stored plan and deserializes
every AOT executable instead of recompiling (zero compiles), while a
``JsonlTracker`` records the full register → serve → retire lifecycle.

    PYTHONPATH=src python examples/serve_durable.py
"""

import asyncio
import sys
import tempfile
from pathlib import Path

sys.path.insert(0, "src")

import numpy as np

from repro.core import deploy
from repro.core.cnn import CNNConfig, ConvLayerSpec, fitted_block_models
from repro.ops import (JsonlTracker, PersistentExecutableCache, PlanStore,
                       read_events)
from repro.serve import AsyncCNNGateway, AsyncServeConfig

CFG = CNNConfig(layers=(
    ConvLayerSpec(1, 4, data_bits=8, coeff_bits=6, block="conv4"),
    ConvLayerSpec(4, 3, data_bits=6, coeff_bits=4, block="conv3"),
), img_h=16, img_w=64)


async def launch(root: Path, label: str) -> None:
    """One serving 'process': resolve the plan through the store, build
    the gateway over the persistent cache, serve, retire, report."""
    store = PlanStore(root / "plans")
    if "cnn-demo" in store:
        plan = store.load("cnn-demo")
        print(f"[{label}] plan loaded from store")
    else:
        plan = deploy.plan_deployment(CFG, fitted_block_models(),
                                      target=0.8, on_infeasible="fallback")
        store.save(plan, "cnn-demo")
        print(f"[{label}] plan computed and saved")

    cache = PersistentExecutableCache(root / "exe")
    tracker = JsonlTracker(root / f"{label}.jsonl")
    gw = AsyncCNNGateway.from_plan(
        plan, AsyncServeConfig(max_batch=4, max_pending=32),
        plan_id="cnn-demo", exec_cache=cache, tracker=tracker)

    compiled = gw.plans["cnn-demo"].compiled
    imgs = compiled.sample_inputs(8)
    async with gw:
        futs = [await gw.submit(img, plan_id="cnn-demo") for img in imgs]
        outs = await asyncio.gather(*futs)
        # live retire: admission closes, in-flight requests finish
        served = await gw.retire_plan("cnn-demo")
    assert all(np.asarray(o).shape == outs[0].shape for o in outs)

    s = cache.stats()
    print(f"[{label}] served {served} then retired | compiles="
          f"{s['compiles']} disk_hits={s['disk_hits']} "
          f"disk_stores={s['disk_stores']}")
    tracker.close()
    events = [e["event"] for e in read_events(tracker.path)]
    assert events.index("plan_registered") < events.index("plan_retired")
    print(f"[{label}] tracker: {len(events)} events "
          f"({' → '.join(dict.fromkeys(events))})")
    if label == "warm":
        assert s["compiles"] == 0, "warm restart must not recompile"
        print("[warm] zero recompiles: every executable deserialized")


def main() -> None:
    with tempfile.TemporaryDirectory() as tmp:
        root = Path(tmp)
        asyncio.run(launch(root, "cold"))
        asyncio.run(launch(root, "warm"))


if __name__ == "__main__":
    main()
