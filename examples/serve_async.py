"""The async continuous-batching front door: one gateway, two plans
sharing an executable cache, bounded admission with load shedding,
deadlines enforced (late requests expired, never served late), and
per-request cancellation — all bit-exact against the per-image oracle.

    PYTHONPATH=src python examples/serve_async.py
"""

import asyncio
import sys
import time

sys.path.insert(0, "src")

import jax.numpy as jnp
import numpy as np

from repro.core import deploy
from repro.core.cnn import (cnn_forward_ref, fitted_block_models,
                            quickstart_cnn_config)
from repro.serve import (AsyncCNNGateway, AsyncServeConfig,
                         DeadlineExpired)


async def main():
    cfg = quickstart_cnn_config()
    plan = deploy.plan_deployment(cfg, fitted_block_models(), target=0.8,
                                  on_infeasible="fallback")

    gw = AsyncCNNGateway(AsyncServeConfig(max_batch=8, max_pending=16))
    t0 = time.time()
    gw.register_plan(plan, plan_id="prod")
    prod_compiles = gw.exec_cache.compiles
    gw.register_plan(plan, plan_id="canary")      # identical layers
    print(f"two plans registered in {time.time() - t0:.2f}s — "
          f"'canary' added {gw.exec_cache.compiles - prod_compiles} "
          f"compiles (shares all {len(gw.exec_cache)} executables)")

    compiled = gw.plans["prod"].compiled
    imgs = compiled.sample_inputs(24)

    async with gw:
        # normal traffic, split across the two plans
        futs = [await gw.submit(img, plan_id="prod") for img in imgs[:12]]
        futs += [await gw.submit(img, plan_id="canary")
                 for img in imgs[12:]]

        # a request with an impossible deadline: expired, not served late
        doomed = await gw.submit(imgs[0], deadline=-1.0)
        try:
            await doomed
        except DeadlineExpired as e:
            print(f"deadline enforced: {e}")

        # cancellation: the future is cancelled before dispatch
        victim = await gw.submit(imgs[1])
        victim.cancel()

        outs = await asyncio.gather(*futs)

    pcfg = deploy.plan_config(plan)
    exact = all(
        np.array_equal(out, np.asarray(cnn_forward_ref(
            gw.plans[pid].compiled.params, jnp.asarray(img), pcfg)))
        for img, out, pid in zip(
            imgs, outs, ["prod"] * 12 + ["canary"] * 12))
    stats = gw.stats()
    print(f"served {stats['served']} images "
          f"(prod={stats['plans']['prod']}, "
          f"canary={stats['plans']['canary']}), "
          f"expired={stats['expired']}, cancelled={stats['cancelled']}")
    print(f"occupancy histogram: {stats['occupancy_hist']}  "
          f"policy: {stats['policy']}")
    print(f"spot-check vs per-image oracle: bit-exact={exact}")
    assert exact
    assert stats["expired"] == 1 and stats["cancelled"] == 1


if __name__ == "__main__":
    asyncio.run(main())
