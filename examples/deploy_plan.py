"""Deployment planning end-to-end (the paper's "FPGA selection and
optimized CNN deployment" tool, §4.1-4.2): plan the quickstart CNN over
the device catalog, print the Pareto frontier, pick the cheapest part
that fits, persist the plan as a versioned JSON artifact
(``repro.runtime``), execute it bit-exactly, and validate the fitted
resource models against a fresh trace of the deployed kernels.

    PYTHONPATH=src python examples/deploy_plan.py
"""

import sys
import tempfile
from pathlib import Path

sys.path.insert(0, "src")

from repro import runtime
from repro.core import allocate, deploy, synth
from repro.core.allocate import BUDGET_RESOURCES, DEVICE_CATALOG
from repro.core.cnn import quickstart_cnn_config


def main():
    cfg = quickstart_cnn_config()
    rows = synth.run_sweep()
    bm = allocate.BlockModels.fit(rows)

    print("device catalog:")
    for dev in DEVICE_CATALOG:
        print(f"  {dev.name:<5} cost={dev.cost:<4} {dev.description}")

    print("\nper-device fit at the spec's own bits (target 80%):")
    for dev in DEVICE_CATALOG:
        try:
            plan = deploy.plan_deployment(cfg, bm, dev)
            print(f"  {dev.name:<5} fits: blocks={plan.block_names()} "
                  f"max util={plan.max_usage_pct:.1f}%")
        except deploy.DeploymentError as e:
            why = str(e).split(":")[-1].strip()
            print(f"  {dev.name:<5} infeasible ({why})")

    print(f"\nPareto frontier across {len(DEVICE_CATALOG)} devices "
          "(utilization ↓ / convs-per-step ↑ / quant error ↓):")
    frontier = deploy.pareto_frontier(cfg, bm, DEVICE_CATALOG)
    for p in sorted(frontier, key=lambda p: (p.device.cost,
                                             p.max_usage_pct)):
        bits = ",".join(f"d{d}c{c}" for d, c in p.bits())
        print(f"  {p.device.name:<5} util={p.max_usage_pct:6.2f}%  "
              f"convs/step={p.convs_per_step:.2f}  "
              f"quant_err={p.quant_error:.4f}  "
              f"blocks={'/'.join(p.block_names())}  bits={bits}")

    dev, plan = deploy.select_device(
        cfg, bm, bit_candidates=deploy.DEFAULT_BIT_CANDIDATES)
    print(f"\nselected device: {dev.name} (cost {dev.cost}) — cheapest "
          f"part fitting at {plan.target:.0%} target, per-layer "
          "precision searched")
    for a in plan.layers:
        print(f"  layer {a.index}: {a.block} d={a.data_bits} "
              f"c={a.coeff_bits} calls/fwd={a.calls}")

    # the plan is a durable artifact: serialize it, reload it, and the
    # copy is exactly the plan (the ``repro.runtime`` serving contract)
    path = Path(tempfile.mkdtemp()) / "plan.json"
    runtime.save_plan(plan, path)
    assert runtime.load_plan(path) == plan
    print(f"\nplan serialized to {path} "
          f"(schema v{runtime.PLAN_SCHEMA_VERSION}; reload == original) — "
          "serve it with repro.runtime.CompiledCNN.from_plan or "
          "`python -m repro.launch.serve --workload cnn --plan plan.json`")

    print("\nexecuting the plan (cnn_forward vs the integer oracle) and "
          "re-tracing the deployed kernels:")
    val = deploy.validate_plan(plan, cfg)
    print(f"  bit-exact vs cnn_forward_ref: {val.bit_exact}")
    print(f"  quantization error vs float oracle: {val.quant_error:.4f}")
    print("\npredicted vs measured per budgeted resource "
          "(paper §4.1 metrics, across layers):")
    print(f"  {'resource':<12} {'FPGA':<5} {'MSE':>12} {'MAE':>12} "
          f"{'R²':>8} {'MAPE%':>8}")
    for r in BUDGET_RESOURCES:
        m = val.metrics[r]
        print(f"  {r:<12} {synth.fpga_name(r):<5} {m['mse']:>12.4g} "
              f"{m['mae']:>12.4g} {m['r2']:>8.4f} {m['mape_pct']:>8.2f}")

    assert val.bit_exact, "plan execution diverged from the oracle"
    bad = {r: val.metrics[r]["mape_pct"] for r in BUDGET_RESOURCES
           if val.metrics[r]["mape_pct"] > 20.0}
    assert not bad, f"MAPE over 20% on {bad}"
    print("\nall budgeted resource classes within 20% MAPE ✓")


if __name__ == "__main__":
    main()
