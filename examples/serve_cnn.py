"""Plan-driven CNN serving: the deployment planner picks each layer's
block and precision for a device, then the dynamic-batching engine
serves an image workload through one jitted batched step per tick —
bit-exact against the per-image integer oracle.

    PYTHONPATH=src python examples/serve_cnn.py
"""

import sys
import time

sys.path.insert(0, "src")

import jax.numpy as jnp
import numpy as np

from repro.core import deploy
from repro.core.cnn import (cnn_forward_ref, fitted_block_models,
                            quickstart_cnn_config)
from repro.kernels import ops
from repro.serve import CNNEngine, CNNServeConfig, ImageRequest


def main():
    cfg = quickstart_cnn_config()
    bm = fitted_block_models()              # memoized sweep + fit
    plan = deploy.plan_deployment(cfg, bm, target=0.8,
                                  on_infeasible="fallback")
    print("deployment plan (device %s):" % plan.device.name)
    for a in plan.layers:
        print(f"  layer {a.index}: {a.block} @ d={a.data_bits} "
              f"c={a.coeff_bits} ({a.calls} calls/fwd)")

    engine = CNNEngine.from_plan(plan, cfg,
                                 serve_cfg=CNNServeConfig(max_batch=8))

    rng = np.random.default_rng(0)
    d0 = cfg.layers[0].data_bits
    reqs = [ImageRequest(
        image=np.asarray(ops.quantize_fixed(
            rng.integers(0, 1 << (d0 - 1),
                         engine.in_shape).astype(np.float32), d0)),
        request_id=i) for i in range(20)]

    engine.run(reqs[:1])                    # compile outside the clock
    t0 = time.time()
    engine.run(reqs[1:])
    dt = time.time() - t0

    pcfg = deploy.plan_config(plan, cfg)
    r = reqs[-1]
    exact = np.array_equal(
        r.output,
        np.asarray(cnn_forward_ref(engine.params, jnp.asarray(r.image),
                                   pcfg)))
    stats = engine.stats()
    print(f"served {len(reqs) - 1} images in {dt:.2f}s "
          f"({(len(reqs) - 1) / dt:.1f} images/s, "
          f"{stats['images_per_step']:.1f} images/step)")
    print(f"spot-check vs per-image oracle: bit-exact={exact}")
    assert exact


if __name__ == "__main__":
    main()
