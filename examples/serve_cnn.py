"""Plan → artifact → compile → serve, the whole ``repro.runtime`` flow:
the deployment planner picks each layer's block and precision for a
device, the plan is saved to (and reloaded from) a JSON artifact — the
"plan on one machine, serve on another" contract — and the
dynamic-batching engine serves an image workload through AOT-compiled
batch buckets, bit-exact against the per-image integer oracle.

    PYTHONPATH=src python examples/serve_cnn.py

For live traffic (deadlines, backpressure, cancellation, multi-plan
routing) see the async gateway walkthrough: examples/serve_async.py.
"""

import sys
import tempfile
import time
from pathlib import Path

sys.path.insert(0, "src")

import jax.numpy as jnp
import numpy as np

from repro import runtime
from repro.core import deploy
from repro.core.cnn import (cnn_forward_ref, fitted_block_models,
                            quickstart_cnn_config)
from repro.kernels import ops
from repro.serve import CNNEngine, CNNServeConfig, ImageRequest


def main():
    cfg = quickstart_cnn_config()
    bm = fitted_block_models()              # memoized sweep + fit
    plan = deploy.plan_deployment(cfg, bm, target=0.8,
                                  on_infeasible="fallback")
    print("deployment plan (device %s):" % plan.device.name)
    for a in plan.layers:
        print(f"  layer {a.index}: {a.block} @ d={a.data_bits} "
              f"c={a.coeff_bits} ({a.calls} calls/fwd)")

    # the plan is a durable artifact: serialize, reload, serve the copy
    path = Path(tempfile.mkdtemp()) / "plan.json"
    runtime.save_plan(plan, path)
    loaded = runtime.load_plan(path)
    assert loaded == plan
    print(f"plan artifact: {path} (schema v{runtime.PLAN_SCHEMA_VERSION}, "
          f"round-trips exactly)")

    t0 = time.time()
    engine = CNNEngine.from_plan(loaded,    # cfg travels inside the plan
                                 serve_cfg=CNNServeConfig(max_batch=8))
    print(f"AOT warmup: buckets {engine.compiled.buckets} compiled in "
          f"{time.time() - t0:.2f}s — no compile on the serving path")

    rng = np.random.default_rng(0)
    d0 = engine.cfg.layers[0].data_bits
    reqs = [ImageRequest(
        image=np.asarray(ops.quantize_fixed(
            rng.integers(0, 1 << (d0 - 1),
                         engine.in_shape).astype(np.float32), d0)),
        request_id=i) for i in range(20)]

    t0 = time.time()
    engine.run(reqs)
    dt = time.time() - t0

    pcfg = deploy.plan_config(loaded)
    r = reqs[-1]
    exact = np.array_equal(
        r.output,
        np.asarray(cnn_forward_ref(engine.params, jnp.asarray(r.image),
                                   pcfg)))
    stats = engine.stats()
    print(f"served {len(reqs)} images in {dt:.2f}s "
          f"({len(reqs) / dt:.1f} images/s, "
          f"{stats['images_per_step']:.1f} images/step)")
    print(f"occupancy histogram: {stats['occupancy_hist']}  "
          f"bucket hits: {stats['bucket_hits']}")
    print(f"spot-check vs per-image oracle: bit-exact={exact}")
    assert exact


if __name__ == "__main__":
    main()
