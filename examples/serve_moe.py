"""Quantized-MoE serving through the workload-generic stack: the
deployment planner picks per-layer (data_bits, coeff_bits) for the
expert FFNs under the device's budgets, the plan round-trips as a v2
JSON artifact, ``compile_plan`` builds the bucketed AOT ``CompiledMoE``,
and the *same* async gateway that serves CNN plans serves MoE token
blocks side by side with one — no serving code knows which is which.

    PYTHONPATH=src python examples/serve_moe.py
"""

import asyncio
import sys
import tempfile
import time

sys.path.insert(0, "src")

import numpy as np

from repro.core import deploy
from repro.core.cnn import fitted_block_models, quickstart_cnn_config
from repro.core.deploy import DeploymentError
from repro.runtime import (MoELayerSpec, MoEWorkloadSpec, load_plan,
                           plan_moe_deployment, save_plan,
                           validate_moe_plan)
from repro.serve import AsyncCNNGateway, AsyncServeConfig


def build_spec():
    return MoEWorkloadSpec(
        layers=(MoELayerSpec(d_ff_expert=64, num_experts=8, top_k=2),
                MoELayerSpec(d_ff_expert=64, num_experts=8, top_k=2,
                             n_shared_experts=1)),
        d_model=32, seq_len=16)


async def main():
    spec = build_spec()

    # 1. plan: per-layer bits under the v5e budgets — and the placement
    #    story: the same spec does not fit the edge profile at all.
    plan = plan_moe_deployment(spec, "v5e", target=0.8)
    print("planned for v5e: "
          + ", ".join(f"L{a.index}@d{a.data_bits}/c{a.coeff_bits}"
                      for a in plan.layers)
          + f"  (quant rel-err {plan.quant_error:.4f})")
    try:
        plan_moe_deployment(spec, "edge")
    except DeploymentError as e:
        print(f"edge placement refused at plan time: "
              f"{str(e).splitlines()[0]}")

    # 2. the plan is a portable v2 artifact, same as a CNN plan
    with tempfile.NamedTemporaryFile(suffix=".json") as f:
        save_plan(plan, f.name)
        plan = load_plan(f.name)
    print(f"plan round-tripped (schema v2, workload "
          f"{plan.workload.kind!r})")

    # 3. quantized-vs-dense validation: compiled == eager, bit for bit
    report = validate_moe_plan(plan)
    print(f"validated: compiled==eager "
          f"{report.compiled_matches_eager}, rel-err vs dense float "
          f"oracle {report.dense_ref_rel_err:.4f}")

    # 4. serve it next to a CNN plan through one untouched gateway
    cnn_plan = deploy.plan_deployment(
        quickstart_cnn_config(), fitted_block_models(), target=0.8,
        on_infeasible="fallback")
    gw = AsyncCNNGateway(AsyncServeConfig(max_batch=4, max_pending=16))
    t0 = time.time()
    gw.register_plan(cnn_plan, plan_id="cnn")
    gw.register_plan(plan, plan_id="moe")
    print(f"CNN + MoE registered on one gateway in {time.time()-t0:.2f}s")

    imgs = gw.plans["cnn"].compiled.sample_inputs(6)
    blocks = gw.plans["moe"].compiled.sample_inputs(6)
    async with gw:
        futs = [await gw.submit(x, plan_id="cnn") for x in imgs]
        futs += [await gw.submit(x, plan_id="moe") for x in blocks]
        outs = await asyncio.gather(*futs)

    stats = gw.stats()
    print(f"served {stats['served']} requests "
          f"(cnn={stats['plans']['cnn']}, moe={stats['plans']['moe']}); "
          f"occupancy histogram: {stats['occupancy_hist']}")
    assert all(np.all(np.isfinite(np.asarray(o))) for o in outs)
    assert stats["plans"]["cnn"] == 6 and stats["plans"]["moe"] == 6


if __name__ == "__main__":
    asyncio.run(main())
