"""The paper, end-to-end: parameterizable convolution blocks → "synthesis"
sweep → Pearson correlation → Algorithm-1 polynomial models → error
metrics → 80%-utilization block allocation (Tables 2-5).

    PYTHONPATH=src python examples/conv_dse.py
"""

import sys

sys.path.insert(0, "src")

import numpy as np

from repro.core import allocate, correlate, polyfit, synth


def main():
    print("== §3.2 synthesis sweep (4 blocks × 14×14 bit configs) ==")
    rows = synth.run_sweep()
    print(f"   {len(rows)} configurations (cached)")

    print("\n== §3.3 Pearson correlation (Table 3) ==")
    for block in ("conv1", "conv2", "conv3", "conv4"):
        t = correlate.correlation_table(rows, block)
        e = t["vpu_ops"]
        fam = correlate.choose_model_family(e)
        print(f"   {block}: LLUT~data={e['data_bits']:+.3f} "
              f"LLUT~coeff={e['coeff_bits']:+.3f} → {fam}")

    print("\n== §3.4 Algorithm 1 models + §4.1 errors (Table 4) ==")
    for block in ("conv1", "conv2", "conv3", "conv4"):
        d, c, ys = synth.sweep_arrays(rows, block)
        m = polyfit.fit_auto(d, c, ys["vpu_ops"], block=block)
        met = polyfit.error_metrics(ys["vpu_ops"], m.predict(d, c))
        kind = (f"segmented[{m.scheme}]"
                if isinstance(m, polyfit.SegmentedModel)
                else m.formula("LLUT"))
        print(f"   {block}: R²={met['r2']:.4f} MAPE={met['mape_pct']:.2f}%")
        print(f"      {kind}")

    print("\n== §4.2 allocation at 80% budget, 8-bit (Table 5) ==")
    bm = allocate.BlockModels.fit(rows)
    mix = allocate.allocate(bm, data_bits=8, coeff_bits=8, target=0.8)
    print(f"   mixed: {mix.counts}  → {mix.total_convs:.0f} convs/step")
    print(f"   usage: " + ", ".join(f"{k}={v:.1f}%"
                                    for k, v in mix.usage_pct.items()))
    for b in ("conv1", "conv2", "conv3", "conv4"):
        s = allocate.allocate(bm, data_bits=8, coeff_bits=8, target=0.8,
                              only_block=b)
        print(f"   only {b}: n={s.counts[b]} "
              f"→ {s.total_convs:.0f} convs/step")


if __name__ == "__main__":
    main()
