"""Quickstart: train a small LM end-to-end on host devices.

Trains a ~20M-param reduction of the llama3.2 family on the synthetic
pipeline for a few hundred steps, with checkpointing and resumption.  The
identical code path scales to the full assigned configs on a TPU mesh —
swap ``smoke_config`` for ``get_config`` and launch via
``repro.launch.train`` / ``repro.launch.dryrun``.

    PYTHONPATH=src python examples/quickstart.py [--steps 200]

For the paper's CNN deployment quickstart (plan → JSON artifact → AOT
compile → serve via ``repro.runtime``), see ``examples/cnn_blocks.py``
and ``examples/serve_cnn.py``.
"""

import argparse
import sys

sys.path.insert(0, "src")

import jax

from repro.configs import smoke_config
from repro.data import DataConfig
from repro.models import build_model
from repro.optim import AdamWConfig
from repro.train.loop import TrainConfig, train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    args = ap.parse_args()

    cfg = smoke_config("llama3.2-3b").with_overrides(
        d_model=256, n_heads=8, n_kv_heads=4, head_dim=32, d_ff=1024,
        n_layers=4, vocab_size=4096)
    model = build_model(cfg)
    print(f"arch={cfg.name} (reduced) params={cfg.param_count()/1e6:.1f}M "
          f"devices={jax.devices()}")

    data_cfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                          global_batch=args.batch)
    tcfg = TrainConfig(steps=args.steps, lr=1e-3, log_every=20,
                       ckpt_every=100, ckpt_dir="/tmp/repro_quickstart",
                       opt=AdamWConfig())
    _, _, history = train(model, data_cfg, tcfg)
    print(f"loss: {history[0]['loss']:.3f} → {history[-1]['loss']:.3f} "
          f"over {args.steps} steps")
    assert history[-1]["loss"] < history[0]["loss"], "loss did not fall"


if __name__ == "__main__":
    main()
