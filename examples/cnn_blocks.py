"""CNN deployment on the paper's convolution-block library: the fitted
resource models pick a block per layer under the platform budget, then the
quantized network runs bit-exactly through the Pallas blocks.

    PYTHONPATH=src python examples/cnn_blocks.py
"""

import sys

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.cnn import (choose_blocks, cnn_forward, cnn_forward_ref,
                            init_cnn, quickstart_cnn_config)
from repro.kernels import ops


def main():
    cfg = quickstart_cnn_config()

    blocks = choose_blocks(cfg)          # List[ConvBlock] from the registry
    print("model-driven block selection (paper §4.2):")
    for i, (spec, blk) in enumerate(zip(cfg.layers, blocks)):
        print(f"  layer {i}: {spec.in_channels}→{spec.out_channels}ch "
              f"d={spec.data_bits} c={spec.coeff_bits} → {blk.name} "
              f"({blk.convs_per_step} convs/step)")

    params = init_cnn(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    x = ops.quantize_fixed(
        jnp.asarray(rng.integers(0, 100, (cfg.img_h, cfg.img_w, 1)),
                    jnp.float32), 8)
    y = cnn_forward(params, x, cfg, blocks)
    yr = cnn_forward_ref(params, x, cfg)
    exact = bool(jnp.all(y == yr))
    print(f"output {y.shape}, bit-exact vs oracle: {exact}")
    assert exact


if __name__ == "__main__":
    main()
