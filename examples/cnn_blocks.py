"""CNN deployment on the paper's convolution-block library: the fitted
resource models pick a block per layer under the platform budget, then
the quantized network runs bit-exactly through AOT-compiled executables
(``repro.runtime.CompiledCNN`` — the plan→compile→serve facade).

    PYTHONPATH=src python examples/cnn_blocks.py
"""

import sys

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.cnn import (choose_blocks, cnn_forward_ref, init_cnn,
                            quickstart_cnn_config)
from repro.kernels import ops
from repro.runtime import CompiledCNN


def main():
    cfg = quickstart_cnn_config()

    blocks = choose_blocks(cfg)          # List[ConvBlock] from the registry
    print("model-driven block selection (paper §4.2):")
    for i, (spec, blk) in enumerate(zip(cfg.layers, blocks)):
        print(f"  layer {i}: {spec.in_channels}→{spec.out_channels}ch "
              f"d={spec.data_bits} c={spec.coeff_bits} → {blk.name} "
              f"({blk.convs_per_step} convs/step)")

    params = init_cnn(jax.random.PRNGKey(0), cfg)
    cnn = CompiledCNN(cfg, params, blocks, max_batch=4)   # AOT buckets
    print(f"compiled buckets {cnn.buckets}: "
          f"{cnn.stats()['executables']} executables, zero compiles left "
          "on the call path")

    rng = np.random.default_rng(0)
    x = ops.quantize_fixed(
        jnp.asarray(rng.integers(0, 100, (cfg.img_h, cfg.img_w, 1)),
                    jnp.float32), 8)
    y = cnn(x)                           # single image → size-1 bucket
    yr = cnn_forward_ref(params, x, cfg)
    exact = bool(jnp.all(y == yr))
    print(f"output {y.shape}, bit-exact vs oracle: {exact}")
    assert exact

    xb = ops.quantize_fixed(
        jnp.asarray(rng.integers(0, 100, (3, cfg.img_h, cfg.img_w, 1)),
                    jnp.float32), 8)
    yb = cnn(xb)                         # 3 images → size-4 bucket
    exact_b = bool(jnp.all(yb == cnn_forward_ref(params, xb, cfg)))
    print(f"batch {xb.shape[0]} via bucket {cnn.bucket_for(xb.shape[0])}, "
          f"bit-exact: {exact_b}")
    assert exact_b


if __name__ == "__main__":
    main()
