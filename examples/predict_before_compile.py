"""Compile-free design-space exploration (the paper's contribution at
framework scale): predict a cell's roofline inputs from analytic features
using models fitted on the dry-run corpus — no 512-device compile needed.

    PYTHONPATH=src python examples/predict_before_compile.py
"""

import sys

sys.path.insert(0, "src")

from repro.core.model_dse import fit_dse, load_corpus
from repro.core.roofline import HBM_BW, ICI_BW, PEAK_FLOPS


def main():
    rows = load_corpus("results", "baseline")
    if len(rows) < 8:
        print("run the dry-run sweep first: "
              "python -m repro.launch.dryrun --all --mesh both")
        return
    dse = fit_dse(rows)
    print("LOO validation over", len(rows), "cells:")
    for tgt, met in dse.loo.items():
        print(f"  {tgt}: R²={met['r2']:.3f} log10-MAE={met['log_mae']:.3f}")

    print("\npredicting cells without compiling:")
    for arch, shape in [("qwen3-moe-30b-a3b", "train_4k"),
                        ("granite-20b", "prefill_32k"),
                        ("mamba2-1.3b", "decode_32k")]:
        p = dse.predict(arch, shape, n_chips=256)
        print(f"  {arch} × {shape}: "
              f"compute≈{p['flops']/PEAK_FLOPS:.3g}s "
              f"memory≈{p['hbm_bytes']/HBM_BW:.3g}s "
              f"collective≈{p['collective_total']/ICI_BW:.3g}s")


if __name__ == "__main__":
    main()
