"""Serve a small model with batched requests through the
continuous-batching engine (prefill + lockstep decode waves).

    PYTHONPATH=src python examples/serve_batch.py
"""

import sys
import time

sys.path.insert(0, "src")

import jax
import numpy as np

from repro.configs import smoke_config
from repro.models import build_model
from repro.serve import Engine, Request, ServeConfig


def main():
    cfg = smoke_config("gemma2-2b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    engine = Engine(model, params,
                    ServeConfig(max_batch=4, max_len=96, max_new_tokens=16))

    rng = np.random.default_rng(7)
    reqs = [Request(prompt=list(rng.integers(1, cfg.vocab_size, 12)),
                    request_id=i) for i in range(8)]
    t0 = time.time()
    engine.run(reqs)
    dt = time.time() - t0
    total = sum(len(r.out_tokens) for r in reqs)
    print(f"served {len(reqs)} requests / {total} tokens in {dt:.2f}s")
    for r in reqs:
        print(f"  req{r.request_id}: {r.out_tokens}")


if __name__ == "__main__":
    main()
