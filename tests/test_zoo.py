"""Dormant-zoo smoke: every registered architecture must construct and
run, so the config zoo can never silently rot again.

Two tiers:

* tier-1 (always on): ``build_model(cfg)`` constructs and the abstract
  init (``jax.eval_shape`` — no allocation, no compute) succeeds for
  every full-size config.  Catches import rot, config-field drift, and
  shape bugs in seconds.
* ``-m zoo`` (heavyweight, CI's zoo step): a real tiny forward pass on
  every ``smoke_config`` — params materialized, loss computed, finite.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, list_archs, smoke_config
from repro.models.registry import build_model

ARCHS = list_archs()


def _tiny_batch(cfg, b=2, n_tok=8):
    batch = {"tokens": jnp.zeros((b, n_tok), jnp.int32),
             "labels": jnp.zeros((b, n_tok), jnp.int32)}
    # modality frontends are embedding stubs: feed zeros at the
    # configured frontend length
    if cfg.frontend == "vision":
        batch["patches"] = jnp.zeros((b, cfg.frontend_len, cfg.d_model),
                                     cfg.jnp_dtype)
    if cfg.enc_dec:
        batch["frames"] = jnp.zeros((b, cfg.frontend_len, cfg.d_model),
                                    cfg.jnp_dtype)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_every_config_constructs_abstractly(arch):
    """Full-size config → model facade → shape-only param tree.  No
    weights are allocated, so even the 398B config runs in tier-1."""
    cfg = get_config(arch)
    model = build_model(cfg)
    params = model.init_abstract()
    assert jax.tree_util.tree_leaves(params), arch
    # the facade's dry-run input specs must be constructible too
    from repro.configs import SHAPES
    specs = model.input_specs(SHAPES["train_4k"])
    assert "batch" in specs or "cache" in specs


@pytest.mark.zoo
@pytest.mark.parametrize("arch", ARCHS)
def test_every_smoke_config_runs_tiny_forward(arch):
    """smoke_config → real params → one training forward; the loss must
    come out finite.  This is the step that catches numerical rot
    (NaN-producing inits, broken expert routing, bad cache shapes)."""
    cfg = smoke_config(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    out = model.forward_train(params, _tiny_batch(cfg))
    loss = out[0] if isinstance(out, tuple) else out
    assert np.all(np.isfinite(np.asarray(loss))), arch
