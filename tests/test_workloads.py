"""The workload seam end-to-end: registry dispatch, the quantized-MoE
plan lifecycle (plan → save/load → AOT compile → gateway serve → mixed
fleet routing — each step the acceptance criteria name), and the
``sample_inputs``/``validate_input`` generalization with its deprecated
CNN-named shims."""

import asyncio
import dataclasses
import json

import numpy as np
import pytest

import repro.runtime as runtime
from repro.core.deploy import DeploymentError, DeploymentPlan, plan_config
from repro.runtime.compiled import CompiledCNN, validate_container_input
from repro.runtime.workloads import (CNNWorkloadSpec, CompiledMoE,
                                     MoELayerSpec, MoEWorkloadSpec,
                                     WorkloadSpec, _dense_ref_forward,
                                     _eager_forward, compile_plan,
                                     get_workload, list_workloads,
                                     moe_plan_spec, moe_workload_from_config,
                                     plan_moe_deployment, register_workload,
                                     validate_moe_plan, workload_spec)
from repro.serve.async_engine import AsyncCNNGateway, AsyncServeConfig
from repro.serve.cnn_engine import (CNNEngine, CNNServeConfig, ImageRequest,
                                    validate_image)


def tiny_moe_spec(n_layers=2, **kw):
    layer = MoELayerSpec(d_ff_expert=16, num_experts=4, top_k=2,
                         **{k: v for k, v in kw.items()
                            if k in ("data_bits", "coeff_bits",
                                     "n_shared_experts", "capacity_factor")})
    return MoEWorkloadSpec(layers=(layer,) * n_layers, d_model=8,
                           seq_len=8)


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

def test_builtin_kinds_registered():
    assert list_workloads() == ["cnn", "moe"]
    assert get_workload("cnn") is CNNWorkloadSpec
    assert get_workload("moe") is MoEWorkloadSpec


def test_unknown_kind_lists_registered():
    with pytest.raises(ValueError, match="cnn.*moe"):
        get_workload("ssm")


def test_reregistering_kind_rejected():
    with pytest.raises(ValueError, match="already registered"):
        @register_workload
        class Impostor(WorkloadSpec):
            kind = "moe"


def test_abstract_kind_rejected():
    with pytest.raises(ValueError, match="concrete kind"):
        @register_workload
        class NoKind(WorkloadSpec):
            pass


def test_workload_spec_wraps_cnn_plans():
    plan = _cnn_plan()
    spec = workload_spec(plan)
    assert isinstance(spec, CNNWorkloadSpec)
    assert spec.cnn == plan.cnn


# ---------------------------------------------------------------------------
# MoE plan lifecycle: plan → round-trip → compile → validate
# ---------------------------------------------------------------------------

def test_moe_plan_round_trips_save_load(tmp_path):
    plan = plan_moe_deployment(tiny_moe_spec(), "v5e")
    assert plan.feasible and plan.cnn is None
    assert plan.workload.kind == "moe"
    path = runtime.save_plan(plan, tmp_path / "moe_plan.json")
    loaded = runtime.load_plan(path)
    assert loaded == plan
    assert json.loads(path.read_text())["workload"]["kind"] == "moe"


def test_moe_planner_picks_highest_precision_that_fits():
    plan = plan_moe_deployment(tiny_moe_spec(), "v5e")
    # the tiny workload fits v5e at the widest candidate precision
    assert plan.bits() == [(12, 10)] * 2
    spec = moe_plan_spec(plan)
    assert [(s.data_bits, s.coeff_bits) for s in spec.layers] \
        == plan.bits()


def test_moe_plan_infeasible_on_edge_feasible_on_v5e():
    """The plan-aware placement story: a real MoE workload exceeds the
    edge part's budgets but fits a v5e — which is exactly what keeps
    MoE plans off edge workers in a mixed fleet."""
    spec = MoEWorkloadSpec(
        layers=(MoELayerSpec(d_ff_expert=128, num_experts=8, top_k=2),),
        d_model=64, seq_len=32)
    assert plan_moe_deployment(spec, "v5e").feasible
    with pytest.raises(DeploymentError, match="does not fit device 'edge'"):
        plan_moe_deployment(spec, "edge")
    fallback = plan_moe_deployment(spec, "edge", on_infeasible="fallback")
    assert not fallback.feasible


def test_moe_plan_config_raises_with_kind():
    plan = plan_moe_deployment(tiny_moe_spec(), "v5e")
    with pytest.raises(ValueError, match="'moe' workload"):
        plan_config(plan)


def test_compiled_moe_matches_eager_and_tracks_dense_ref():
    """validate_plan's MoE twin: the bucketed AOT path is numerically
    the eager quantized stack, and quantization stays within tolerance
    of the dense float oracle."""
    plan = plan_moe_deployment(tiny_moe_spec(), "v5e")
    v = validate_moe_plan(plan)
    assert v.compiled_matches_eager
    assert v.dense_ref_rel_err < 0.15
    assert v.quant_error == plan.quant_error


def test_coarser_bits_raise_quant_error():
    fine = tiny_moe_spec(data_bits=12, coeff_bits=10)
    coarse = tiny_moe_spec(data_bits=4, coeff_bits=4)
    fine_err = plan_moe_deployment(fine, "v5e", bit_candidates=None)
    coarse_err = plan_moe_deployment(coarse, "v5e", bit_candidates=None)
    assert coarse_err.quant_error > fine_err.quant_error


def test_compile_plan_dispatches_by_kind():
    moe = compile_plan(plan_moe_deployment(tiny_moe_spec(), "v5e"),
                       max_batch=2)
    cnn = compile_plan(_cnn_plan(), max_batch=2)
    assert isinstance(moe, CompiledMoE) and moe.kind == "moe"
    assert isinstance(cnn, CompiledCNN) and cnn.kind == "cnn"
    assert moe.stats()["kind"] == "moe"


def test_compiled_moe_bucketing_and_chunking():
    """Padding to a bucket and chunking past max_batch must not change
    any request's output (the CompiledCNN contract, on the MoE backend:
    padding tokens can never displace real tokens under capacity)."""
    plan = plan_moe_deployment(tiny_moe_spec(), "v5e")
    compiled = compile_plan(plan, max_batch=4)
    xs = np.stack(compiled.sample_inputs(7, seed=3))
    y_all = np.asarray(compiled(xs))        # chunks 4 + 3(pad to 4)
    singles = np.stack([np.asarray(compiled(x)) for x in xs])
    np.testing.assert_allclose(y_all, singles, rtol=1e-5, atol=1e-5)
    assert sum(compiled.bucket_hits.values()) > 0


def test_moe_validate_input_rejects():
    compiled = compile_plan(plan_moe_deployment(tiny_moe_spec(), "v5e"),
                            max_batch=2, warmup=False)
    with pytest.raises(ValueError, match="token block shape"):
        compiled.validate_input(np.zeros((3, 3), np.float32))
    bad = np.zeros(compiled.in_shape, np.float32)
    bad[0, 0] = np.nan
    with pytest.raises(ValueError, match="non-finite"):
        compiled.validate_input(bad)
    with pytest.raises(ValueError, match="dtype"):
        compiled.validate_input(
            np.zeros(compiled.in_shape, np.complex64))


# ---------------------------------------------------------------------------
# serving: sync engine + async gateway, plan-type-blind
# ---------------------------------------------------------------------------

def _cnn_plan():
    from repro.core.cnn import CNNConfig, ConvLayerSpec
    from tests.test_plan_golden import _golden_plan
    plan = _golden_plan()
    # shrink to a fast-compiling network for serve tests
    cnn = CNNConfig(layers=(
        ConvLayerSpec(1, 2, data_bits=6, coeff_bits=4, shift=5,
                      block="conv1"),), img_h=16, img_w=16)
    return dataclasses.replace(
        plan, cnn=cnn,
        layers=(dataclasses.replace(plan.layers[1], index=0),))


def test_sync_engine_serves_moe_plan():
    plan = plan_moe_deployment(tiny_moe_spec(), "v5e")
    eng = CNNEngine.from_plan(plan, serve_cfg=CNNServeConfig(max_batch=2))
    xs = eng.compiled.sample_inputs(3, seed=1)
    reqs = [ImageRequest(image=x, request_id=i) for i, x in enumerate(xs)]
    eng.run(reqs)
    assert all(r.done for r in reqs)
    assert reqs[0].output.shape == eng.compiled.in_shape
    # admission rejects a CNN-shaped payload on the MoE plan
    with pytest.raises(ValueError, match="token block shape"):
        eng.submit(ImageRequest(image=np.zeros((8, 8, 1), np.int8)))


def test_gateway_serves_moe_and_cnn_side_by_side():
    """The acceptance path: one AsyncCNNGateway serving a CNN plan and
    a quantized MoE plan concurrently, each validating its own input
    contract, sharing one ExecutableCache."""
    async def main():
        gw = AsyncCNNGateway(AsyncServeConfig(max_batch=2, max_pending=16))
        gw.register_plan(_cnn_plan(), plan_id="cnn")
        gw.register_plan(plan_moe_deployment(tiny_moe_spec(), "v5e"),
                         plan_id="moe")
        assert gw.plans["cnn"].kind == "cnn"
        assert gw.plans["moe"].kind == "moe"
        async with gw:
            cnn_in = gw.plans["cnn"].compiled.sample_inputs(2, seed=0)
            moe_in = gw.plans["moe"].compiled.sample_inputs(2, seed=0)
            futs = [await gw.submit(x, plan_id="cnn") for x in cnn_in]
            futs += [await gw.submit(x, plan_id="moe") for x in moe_in]
            outs = await asyncio.gather(*futs)
            assert outs[0].shape == gw.plans["cnn"].compiled.in_shape[:2] \
                + (2,)
            assert outs[2].shape == gw.plans["moe"].compiled.in_shape
            # per-plan admission: an MoE block is rejected on the CNN
            # plan and vice versa, each with its workload's noun
            with pytest.raises(ValueError, match="image shape"):
                await gw.submit(moe_in[0], plan_id="cnn")
            with pytest.raises(ValueError, match="token block shape"):
                await gw.submit(cnn_in[0], plan_id="moe")
        assert gw.served == 4
    asyncio.run(main())


def test_moe_plans_share_exec_cache_across_gateway_plans():
    async def main():
        gw = AsyncCNNGateway(AsyncServeConfig(max_batch=2))
        plan = plan_moe_deployment(tiny_moe_spec(), "v5e")
        gw.register_plan(plan, plan_id="moe-a")
        before = gw.plans["moe-a"].compiled.compiles
        gw.register_plan(plan, plan_id="moe-b", key=None)
        # identical layer specs: the second registration compiles nothing
        assert gw.plans["moe-b"].compiled.compiles == 0
        assert before > 0
        await gw.close()
    asyncio.run(main())


# ---------------------------------------------------------------------------
# mixed CNN+MoE fleet: plan-aware placement honors workload hosting
# ---------------------------------------------------------------------------

def test_fleet_routes_mixed_cnn_and_moe_plans():
    """The last acceptance step: a live Fleet with an edge worker that
    only hosts the CNN plan (the MoE plan is infeasible on edge — see
    ``test_moe_plan_infeasible_on_edge_feasible_on_v5e``) and a v5e
    worker hosting both.  MoE traffic must route exclusively to the
    v5e; CNN traffic may use either; both kinds complete."""
    from repro.fleet import Fleet, FleetWorker, NoWorkerAvailable

    cnn_plan = _cnn_plan()
    moe_plan = plan_moe_deployment(tiny_moe_spec(), "v5e")

    def gateway(plans):
        gw = AsyncCNNGateway(AsyncServeConfig(max_batch=2, max_pending=16))
        for pid, plan in plans:
            gw.register_plan(plan, plan_id=pid)
        return gw

    async def main():
        edge = FleetWorker("edge0", gateway([("cnn", cnn_plan)]), "edge")
        v5e = FleetWorker("v5e0", gateway([("cnn", cnn_plan),
                                           ("moe", moe_plan)]), "v5e")
        assert edge.workload_kinds == {"cnn"}
        assert v5e.workload_kinds == {"cnn", "moe"}
        fleet = Fleet([edge, v5e], router="plan_aware")
        async with fleet:
            cnn_in = v5e.gateway.plans["cnn"].compiled.sample_inputs(
                4, seed=0)
            moe_in = v5e.gateway.plans["moe"].compiled.sample_inputs(
                4, seed=0)
            futs = [await fleet.submit(x, plan_id="cnn") for x in cnn_in]
            futs += [await fleet.submit(x, plan_id="moe") for x in moe_in]
            outs = await asyncio.gather(*futs)
            assert all(o is not None for o in outs)
            stats = fleet.stats()
            assert stats["workers"]["edge0"]["workloads"] == ["cnn"]
            assert stats["workers"]["v5e0"]["workloads"] == ["cnn", "moe"]
            # every MoE request was served by the v5e gateway
            assert v5e.gateway.plans["moe"].served == 4
            # draining the only MoE-capable worker makes MoE traffic
            # unroutable while CNN traffic still flows to the edge
            v5e.draining = True
            with pytest.raises(NoWorkerAvailable):
                fleet.submit_nowait(moe_in[0], plan_id="moe")
            fut = await fleet.submit(cnn_in[0], plan_id="cnn")
            assert (await fut) is not None
    asyncio.run(main())


# ---------------------------------------------------------------------------
# sample_inputs / validate_input seam + deprecated shims
# ---------------------------------------------------------------------------

def test_cnn_sample_inputs_and_deprecated_sample_images():
    compiled = compile_plan(_cnn_plan(), max_batch=2, warmup=False)
    fresh = compiled.sample_inputs(2, seed=7)
    with pytest.deprecated_call():
        legacy = compiled.sample_images(2, seed=7)
    np.testing.assert_array_equal(np.stack(fresh), np.stack(legacy))


def test_validate_image_shim_warns_and_delegates():
    with pytest.deprecated_call():
        out = validate_image(np.zeros((8, 8, 1), np.int8), (8, 8, 1),
                             np.int8)
    assert out.shape == (8, 8, 1)
    with pytest.deprecated_call():
        with pytest.raises(ValueError, match="container range"):
            validate_image(np.full((8, 8, 1), 300), (8, 8, 1), np.int8)


def test_validate_container_input_noun():
    with pytest.raises(ValueError, match="patch shape"):
        validate_container_input(np.zeros((2, 2), np.int8), (8, 8, 1),
                                 np.int8, noun="patch")


def test_validate_image_shim_keeps_the_image_noun_and_request_id():
    """The legacy name must keep producing legacy-shaped errors: the
    noun is ``image`` (not the generic ``input``) and the request id
    callers passed still lands in the message."""
    with pytest.deprecated_call():
        with pytest.raises(ValueError, match=r"request 7: image shape"):
            validate_image(np.zeros((2, 2), np.int8), (8, 8, 1),
                           np.int8, request_id=7)


def test_validate_image_shim_reexported_from_repro_serve():
    """PR-8 moved the engine module but the public ``repro.serve``
    surface still re-exports the shim (callers import it from there)."""
    import repro.serve as serve
    assert serve.validate_image is validate_image
    assert "validate_image" in serve.__all__


def test_sample_images_shim_seed_determinism_and_default():
    """``sample_images`` must keep its full signature contract through
    the shim: same seed ⇒ same draw as ``sample_inputs``, default seed
    included, and every call warns."""
    compiled = compile_plan(_cnn_plan(), max_batch=2, warmup=False)
    with pytest.deprecated_call():
        default = compiled.sample_images(1)
    np.testing.assert_array_equal(default[0],
                                  compiled.sample_inputs(1, seed=0)[0])
    with pytest.deprecated_call():
        a = compiled.sample_images(3, seed=11)
    with pytest.deprecated_call():
        b = compiled.sample_images(3, seed=11)
    np.testing.assert_array_equal(np.stack(a), np.stack(b))
    # the shimmed draws admit through the modern validation seam
    for img in a:
        compiled.validate_input(img)


def test_shim_warnings_name_the_replacement():
    """The deprecation text must point at the successor API — that's
    what makes the migration self-serve."""
    compiled = compile_plan(_cnn_plan(), max_batch=1, warmup=False)
    with pytest.warns(DeprecationWarning, match="sample_inputs"):
        compiled.sample_images(1)
    with pytest.warns(DeprecationWarning, match="validate_input"):
        validate_image(np.zeros((8, 8, 1), np.int8), (8, 8, 1), np.int8)


# ---------------------------------------------------------------------------
# config-zoo bridge
# ---------------------------------------------------------------------------

def test_moe_workload_from_config():
    from repro.configs import smoke_config
    cfg = smoke_config("qwen3-moe-30b-a3b")
    spec = moe_workload_from_config(cfg, n_layers=1, seq_len=4)
    assert spec.d_model == cfg.d_model
    assert spec.layers[0].num_experts == cfg.moe.num_experts
    plan = plan_moe_deployment(spec, "v5e")
    assert plan.feasible


def test_moe_workload_from_dense_config_raises():
    from repro.configs import smoke_config
    cfg = smoke_config("llama3.2-3b")
    with pytest.raises(ValueError, match="no MoE block"):
        moe_workload_from_config(cfg)
