"""``repro.ops.PlanStore``: crash-safe plan persistence — round-trips,
retire/revive lifecycle, corrupt-file quarantine, id validation, and a
property test over concurrent save/load/retire interleavings."""

import json
import os
import threading

import pytest

from hypothesis_compat import HAVE_HYPOTHESIS, given, settings, st

from repro.chaos import tear_plan_write
from repro.core import allocate, deploy
from repro.core.cnn import CNNConfig, ConvLayerSpec, fitted_block_models
from repro.ops import (PlanCorrupt, PlanNotFound, PlanRetired, PlanStore,
                       PlanStoreError)


def _plan(device=None):
    cfg = CNNConfig(layers=(
        ConvLayerSpec(1, 4, data_bits=8, coeff_bits=6, block="conv4"),
        ConvLayerSpec(4, 3, data_bits=6, coeff_bits=4, block="conv3"),
    ), img_h=16, img_w=64)
    args = ((cfg, fitted_block_models()) if device is None else
            (cfg, fitted_block_models(), allocate.get_device(device)))
    return deploy.plan_deployment(*args, target=0.8,
                                  on_infeasible="fallback")


@pytest.fixture(scope="module")
def plan():
    return _plan()


# ---------------------------------------------------------------------------
# round-trip + listing
# ---------------------------------------------------------------------------

def test_save_load_round_trip(tmp_path, plan):
    store = PlanStore(tmp_path)
    path = store.save(plan, "cnn-v1")
    assert path.exists() and path == store.path_for("cnn-v1")
    loaded = store.load("cnn-v1")
    assert [(l.block, l.data_bits, l.coeff_bits) for l in loaded.layers] \
        == [(l.block, l.data_bits, l.coeff_bits) for l in plan.layers]
    assert loaded.device == plan.device


def test_listing_sorted_and_membership(tmp_path, plan):
    store = PlanStore(tmp_path)
    for pid in ("b", "a", "c"):
        store.save(plan, pid)
    assert store.list_plans() == ["a", "b", "c"]
    assert len(store) == 3 and "b" in store and "zz" not in store
    # stray files are not plans
    (tmp_path / "plans" / "notes.txt").write_text("hi")
    (tmp_path / "plans" / ".hidden.json").write_text("{}")
    assert store.list_plans() == ["a", "b", "c"]


def test_overwrite_is_allowed(tmp_path, plan):
    store = PlanStore(tmp_path)
    store.save(plan, "p")
    store.save(plan, "p")                       # idempotent re-publish
    assert store.list_plans() == ["p"]


def test_two_instances_share_the_directory(tmp_path, plan):
    PlanStore(tmp_path).save(plan, "shared")
    again = PlanStore(tmp_path)                 # "another process"
    assert again.list_plans() == ["shared"]
    assert again.load("shared").device == plan.device


# ---------------------------------------------------------------------------
# retire lifecycle
# ---------------------------------------------------------------------------

def test_retire_moves_and_load_raises_retired(tmp_path, plan):
    store = PlanStore(tmp_path)
    store.save(plan, "old")
    store.retire("old")
    assert store.list_plans() == [] and store.list_retired() == ["old"]
    with pytest.raises(PlanRetired, match="retired"):
        store.load("old")
    # but the artifact is still readable where it went
    assert store.load_retired("old").device == plan.device


def test_revive_after_retire(tmp_path, plan):
    store = PlanStore(tmp_path)
    store.save(plan, "p")
    store.retire("p")
    store.save(plan, "p")                       # re-publish revives
    assert store.list_plans() == ["p"]
    assert store.load("p").device == plan.device


def test_retire_missing_raises_not_found(tmp_path):
    store = PlanStore(tmp_path)
    with pytest.raises(PlanNotFound, match="to retire"):
        store.retire("ghost")
    with pytest.raises(PlanNotFound):
        store.load("ghost")
    with pytest.raises(PlanNotFound):
        store.load_retired("ghost")


def test_not_found_is_also_keyerror(tmp_path):
    """``PlanNotFound`` subclasses ``KeyError`` so mapping-style callers
    catch it — but it prints like a RuntimeError (no KeyError quoting)."""
    store = PlanStore(tmp_path)
    with pytest.raises(KeyError):
        store.load("ghost")
    err = PlanNotFound("no plan 'ghost'")
    assert str(err) == "no plan 'ghost'"


# ---------------------------------------------------------------------------
# corruption + validation
# ---------------------------------------------------------------------------

def test_corrupt_file_is_quarantined(tmp_path, plan):
    store = PlanStore(tmp_path)
    store.save(plan, "ok")
    store.path_for("bad").write_text("{ not json")
    with pytest.raises(PlanCorrupt, match="quarantine"):
        store.load("bad")
    # moved aside, not deleted; store keeps working
    assert not store.path_for("bad").exists()
    q = list((tmp_path / "quarantine").iterdir())
    assert len(q) == 1 and q[0].read_text() == "{ not json"
    assert store.list_plans() == ["ok"]
    assert store.load("ok").device == plan.device


def test_schema_violation_is_corrupt_not_crash(tmp_path):
    store = PlanStore(tmp_path)
    store.path_for("vX").write_text(json.dumps({"schema": 999}))
    with pytest.raises(PlanCorrupt):
        store.load("vX")


@pytest.mark.parametrize("bad_id", [
    "", ".hidden", "../escape", "a/b", "a\\b", "x" * 101, "sp ace",
    ".", "..",
])
def test_invalid_plan_ids_rejected(tmp_path, plan, bad_id):
    store = PlanStore(tmp_path)
    with pytest.raises(ValueError, match="plan_id"):
        store.save(plan, bad_id)
    with pytest.raises(ValueError):
        store.load(bad_id)
    assert bad_id not in store                  # no traversal probe


def test_save_requires_a_plan(tmp_path):
    with pytest.raises(PlanStoreError, match="DeploymentPlan"):
        PlanStore(tmp_path).save({"not": "a plan"}, "p")


# ---------------------------------------------------------------------------
# crash mid-write: a torn temp file never corrupts a read
# ---------------------------------------------------------------------------

def test_torn_tmp_at_every_byte_offset_never_corrupts_reads(tmp_path, plan):
    """A crash at ANY byte offset of ``atomic_write_text``'s temp file —
    before the rename — leaves the store serving the complete old plan:
    the torn temp never shadows the artifact, never appears in
    listings, and the interrupted save simply retries."""
    store = PlanStore(tmp_path)
    store.save(plan, "p")
    new_plan = _plan("v5p")
    assert new_plan.device.name != plan.device.name
    text = new_plan.to_json()
    for cut in range(len(text.encode("utf-8")) + 1):
        tmp = tear_plan_write(store, "p", text, cut=cut)
        assert store.list_plans() == ["p"]       # torn temp not listed
        got = store.load("p")                    # never PlanCorrupt
        assert got.device.name == plan.device.name
        tmp.unlink()
    # the retried save completes and flips the artifact atomically
    store.save(new_plan, "p")
    assert store.load("p").device.name == new_plan.device.name


if HAVE_HYPOTHESIS:
    _cut_strategy = st.floats(min_value=0.0, max_value=1.0)
else:                                           # pragma: no cover
    _cut_strategy = None


@settings(max_examples=50, deadline=None)
@given(frac=_cut_strategy)
def test_property_crash_mid_save_yields_old_or_new(tmp_path_factory, plan,
                                                   frac):
    """Property over the crash point: load-after-crash yields either the
    complete old plan (crash before the rename, at any truncation) or
    the complete new one (crash after — the rename is the commit point)
    — never a corrupt read."""
    root = tmp_path_factory.mktemp("torn")
    store = PlanStore(root)
    store.save(plan, "p")
    new_plan = _plan("v5p")
    text = new_plan.to_json()
    data = text.encode("utf-8")
    cut = int(round(frac * len(data)))
    tmp = tear_plan_write(store, "p", text, cut=cut)
    assert store.load("p").device.name == plan.device.name
    if cut == len(data):
        # the write had finished: the rename commits the new plan
        os.replace(tmp, store.path_for("p"))
        assert store.load("p").device.name == new_plan.device.name
    else:
        tmp.unlink()
        assert store.load("p").device.name == plan.device.name


# ---------------------------------------------------------------------------
# concurrency: interleaved save/load/retire never corrupts the store
# ---------------------------------------------------------------------------

def test_threaded_save_load_retire_stress(tmp_path, plan):
    """Deterministic stress twin of the property test below: 4 threads
    hammer save/load/retire on two ids; every load must yield either a
    complete plan or a typed miss — never a torn read."""
    store = PlanStore(tmp_path)
    store.save(plan, "a")
    errors = []

    def worker(k):
        for i in range(25):
            pid = ("a", "b")[(k + i) % 2]
            try:
                op = (k + i) % 3
                if op == 0:
                    store.save(plan, pid)
                elif op == 1:
                    got = store.load(pid)
                    assert len(got.layers) == len(plan.layers)
                else:
                    store.retire(pid)
            except (PlanNotFound, PlanRetired):
                pass                            # legal interleavings
            except Exception as e:              # noqa: BLE001
                errors.append(e)

    threads = [threading.Thread(target=worker, args=(k,))
               for k in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert errors == []
    # invariants: never a torn read — every surviving artifact parses
    # (an id may be live AND retired: revive keeps the audit copy)
    for pid in store.list_plans():
        assert len(store.load(pid).layers) == len(plan.layers)
    for pid in store.list_retired():
        assert len(store.load_retired(pid).layers) == len(plan.layers)


if HAVE_HYPOTHESIS:
    _ops_strategy = st.lists(
        st.tuples(st.sampled_from(["save", "load", "retire"]),
                  st.sampled_from(["a", "b"]),
                  st.integers(min_value=0, max_value=3)),
        min_size=1, max_size=24)
else:                                           # pragma: no cover
    _ops_strategy = None


@settings(max_examples=25, deadline=None)
@given(ops=_ops_strategy)
def test_property_interleaved_ops_keep_store_consistent(tmp_path_factory,
                                                        plan, ops):
    """Any schedule of save/load/retire across threads leaves the store
    consistent: every surviving artifact (live or retired) parses, and
    loads only ever fail with the typed misses — never a torn read."""
    root = tmp_path_factory.mktemp("store")
    store = PlanStore(root)
    errors = []

    def apply(op, pid):
        try:
            if op == "save":
                store.save(plan, pid)
            elif op == "load":
                store.load(pid)
            else:
                store.retire(pid)
        except (PlanNotFound, PlanRetired):
            pass
        except Exception as e:                  # noqa: BLE001
            errors.append(e)

    # run the drawn schedule split across threads (round-robin), so
    # hypothesis shrinks over genuinely concurrent interleavings
    lanes = [[], [], []]
    for i, (op, pid, _salt) in enumerate(ops):
        lanes[i % 3].append((op, pid))
    threads = [threading.Thread(
        target=lambda lane=lane: [apply(op, pid) for op, pid in lane])
        for lane in lanes if lane]
    for t in threads:
        t.start()
    for t in threads:
        t.join()

    assert errors == []
    for pid in store.list_plans():
        assert len(store.load(pid).layers) == 2
    for pid in store.list_retired():
        assert len(store.load_retired(pid).layers) == 2
