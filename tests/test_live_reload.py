"""Live plan reload: gateway-level ``register_plan``/``retire_plan``
under traffic (zero requests lost, admission closed instantly, tracker
lifecycle order), fleet-wide rollout/retire, and the simulator's
mid-trace retirement accounting."""

import asyncio
import threading

import numpy as np
import pytest

from repro.core import deploy
from repro.core.cnn import CNNConfig, ConvLayerSpec, fitted_block_models
from repro.fleet import (Fleet, FleetError, FleetWorker, NoWorkerAvailable,
                         SimWorkerSpec, make_trace, simulate)
from repro.ops import Tracker
from repro.runtime import CompiledCNN
from repro.serve import (AsyncCNNGateway, AsyncServeConfig,
                         PlanUnavailable)


def _cfg():
    return CNNConfig(layers=(
        ConvLayerSpec(1, 4, data_bits=8, coeff_bits=6, block="conv4"),
        ConvLayerSpec(4, 3, data_bits=6, coeff_bits=4, block="conv3"),
    ), img_h=16, img_w=64)


@pytest.fixture(scope="module")
def compiled_plan():
    plan = deploy.plan_deployment(_cfg(), fitted_block_models(),
                                  target=0.8, on_infeasible="fallback")
    return plan, CompiledCNN.from_plan(plan, max_batch=4)


class ListTracker(Tracker):
    """In-memory tracker: records every entry for order assertions."""

    def __init__(self):
        self.entries = []

    def record(self, entry):
        self.entries.append(entry)

    def events(self):
        return [e["event"] for e in self.entries]


class GatedCompiled:
    """CompiledModel test double whose dispatch blocks on an event —
    requests stay verifiably *in flight* until the test releases them."""

    kind = "cnn"

    def __init__(self, gate=None, max_batch=4):
        self.gate = gate
        self.max_batch = max_batch
        self.in_shape = (4, 4, 1)
        self.in_dtype = np.int8
        self.calls = 0

    def validate_input(self, x, request_id=0):
        return np.asarray(x, self.in_dtype)

    def __call__(self, xb, should_abort=None):
        self.calls += 1
        if self.gate is not None:
            assert self.gate.wait(timeout=10)
        return np.asarray(xb) * 2


def _img():
    return np.ones((4, 4, 1), np.int8)


# ---------------------------------------------------------------------------
# gateway: retire under live traffic
# ---------------------------------------------------------------------------

def test_retire_completes_all_inflight_and_closes_admission():
    """The acceptance invariant: a plan retired while requests are
    queued AND mid-dispatch completes every one of them — zero lost —
    while new submits fail with ``PlanUnavailable`` immediately."""
    gate = threading.Event()
    tracker = ListTracker()

    async def main():
        gw = AsyncCNNGateway(AsyncServeConfig(max_batch=2, max_pending=16),
                             tracker=tracker)
        gw.register_plan(None, plan_id="a",
                         compiled=GatedCompiled(gate, max_batch=2))
        gw.register_plan(None, plan_id="b", compiled=GatedCompiled())
        async with gw:
            futs = [await gw.submit(_img(), plan_id="a")
                    for _ in range(6)]
            await asyncio.sleep(0.05)     # first batch is now in flight

            retire = asyncio.create_task(gw.retire_plan("a"))
            await asyncio.sleep(0.05)
            # admission closed the moment retirement began ...
            assert gw.routable_plans == frozenset({"b"})
            with pytest.raises(PlanUnavailable, match="retiring"):
                await gw.submit(_img(), plan_id="a")
            # ... and the default plan re-pointed off the retiring one
            assert gw._default_plan == "b"
            assert not retire.done()      # in-flight work still owed

            gate.set()                    # release the gated dispatches
            outs = await asyncio.gather(*futs)
            served = await retire
            assert served == 6 and len(outs) == 6
            for out in outs:
                np.testing.assert_array_equal(out, np.asarray(_img()) * 2)

            # plan is gone; the typed error distinguishes retired
            with pytest.raises(PlanUnavailable, match="retired"):
                await gw.submit(_img(), plan_id="a")
            # repeat retire joins the recorded result
            assert await gw.retire_plan("a") == 6
            with pytest.raises(ValueError, match="unknown plan"):
                await gw.retire_plan("ghost")
            # plan "b" is untouched throughout
            assert (await (await gw.submit(_img(), plan_id="b"))) \
                is not None
            stats = gw.stats()
            assert stats["retired_plans"] == {"a": 6}
            assert stats["failed"] == 0 and stats["cancelled"] == 0

    asyncio.run(main())
    events = tracker.events()
    # lifecycle order: registration precedes retirement intent, and
    # eviction comes only after plan a's final in-flight dispatch
    i_last_dispatch = max(
        i for i, e in enumerate(tracker.entries)
        if e["event"] == "dispatch_complete" and e["plan_id"] == "a")
    assert events.index("plan_registered") \
        < events.index("plan_retiring") \
        < i_last_dispatch < events.index("plan_retired")
    (retired,) = [e for e in tracker.entries
                  if e["event"] == "plan_retired"]
    assert retired["plan_id"] == "a" and retired["served"] == 6


def test_backpressure_waiter_fails_on_retire():
    """A submit awaiting admission (queue at bound) whose plan retires
    mid-wait must fail with ``PlanUnavailable`` — not hang, not sneak
    in behind the drain."""
    gate = threading.Event()

    async def main():
        gw = AsyncCNNGateway(AsyncServeConfig(max_batch=1, max_pending=2))
        gw.register_plan(None, plan_id="a",
                         compiled=GatedCompiled(gate, max_batch=1))
        async with gw:
            admitted = [await gw.submit(_img(), plan_id="a")
                        for _ in range(3)]   # bound 2 + 1 in flight
            waiter = asyncio.create_task(gw.submit(_img(), plan_id="a"))
            await asyncio.sleep(0.05)
            assert not waiter.done()         # parked on backpressure

            retire = asyncio.create_task(gw.retire_plan("a"))
            gate.set()
            served = await retire
            fut = await waiter
            with pytest.raises(PlanUnavailable, match="retired while"):
                await fut
            assert served == 3               # the admitted ones all ran
            for f in admitted:
                assert (await f) is not None

    asyncio.run(main())


def test_register_plan_on_live_gateway_serves_immediately():
    async def main():
        gw = AsyncCNNGateway(AsyncServeConfig(max_batch=2))
        gw.register_plan(None, plan_id="v1", compiled=GatedCompiled())
        async with gw:
            assert (await gw.infer(_img())) is not None
            gw.register_plan(None, plan_id="v2", compiled=GatedCompiled())
            assert gw.routable_plans == frozenset({"v1", "v2"})
            out = await gw.infer(_img(), plan_id="v2")
            np.testing.assert_array_equal(out, np.asarray(_img()) * 2)
            # retire the original: v2 keeps serving, becomes default
            await gw.retire_plan("v1")
            assert gw._default_plan == "v2"
            assert (await gw.infer(_img())) is not None

    asyncio.run(main())


# ---------------------------------------------------------------------------
# fleet: rollout + retire across workers
# ---------------------------------------------------------------------------

def test_fleet_rollout_then_retire_loses_nothing(compiled_plan):
    plan, compiled = compiled_plan
    tracker = ListTracker()

    def worker(wid):
        gw = AsyncCNNGateway(AsyncServeConfig(max_batch=4,
                                              max_pending=16))
        gw.register_plan(plan, plan_id="cnn-v1", compiled=compiled)
        return FleetWorker(wid, gw, "v5e")

    imgs = compiled.sample_inputs(8)

    async def main():
        workers = [worker("w0"), worker("w1")]
        fleet = Fleet(workers, router="plan_aware", tracker=tracker)
        async with fleet:
            # rollout: both workers gain cnn-v2 while serving
            registered = await fleet.rollout(plan, "cnn-v2")
            assert registered == {"w0": "cnn-v2", "w1": "cnn-v2"}
            for w in workers:
                assert w.plan_ids == frozenset({"cnn-v1", "cnn-v2"})
            # idempotent: a second rollout registers nowhere
            assert await fleet.rollout(plan, "cnn-v2") == {}
            with pytest.raises(FleetError, match="unknown worker"):
                await fleet.rollout(plan, "cnn-v3", worker_ids=["nope"])

            # in-flight traffic on v1 while it retires fleet-wide
            futs = [await fleet.submit(img, plan_id="cnn-v1")
                    for img in imgs]
            served = await fleet.retire_plan("cnn-v1")
            outs = await asyncio.gather(*futs)
            assert len(outs) == len(imgs) and served >= len(imgs)
            for w in workers:
                assert w.plan_ids == frozenset({"cnn-v2"})

            # v1 traffic now has no worker; v2 serves
            with pytest.raises(NoWorkerAvailable):
                fleet.submit_nowait(imgs[0], plan_id="cnn-v1")
            out = await (await fleet.submit(imgs[0], plan_id="cnn-v2"))
            assert out is not None
            # repeat fleet retire is joinable, not an error
            assert await fleet.retire_plan("cnn-v1") == served

    asyncio.run(main())
    events = tracker.events()
    assert events.count("plan_rollout") == 2
    assert "plan_retired_fleet" in events
    done = [e for e in tracker.entries
            if e["event"] == "plan_retired_fleet"][0]
    assert done["workers"] == ["w0", "w1"]


# ---------------------------------------------------------------------------
# simulator: mid-trace retirement accounting
# ---------------------------------------------------------------------------

_SIM_SPECS = (SimWorkerSpec("w0", "v5e", plan_ids=("cnn", "moe")),
              SimWorkerSpec("w1", "v5e", plan_ids=("cnn", "moe")))


def _mixed_trace(n=2000, seed=11):
    return make_trace(n, rate=1200.0, seed=seed,
                      plan_mix={"cnn": 0.6, "moe": 0.4})


def test_sim_retire_refuses_instead_of_losing():
    trace = _mixed_trace()
    retire_at = float(trace.arrivals[len(trace) // 2])
    res = simulate(_SIM_SPECS, trace, "plan_aware",
                   retire_at=retire_at, retire_plan_id="moe")
    assert res.lost == 0
    assert res.refused_retired > 0
    assert res.retired_plan == "moe"
    assert res.completed + res.refused_retired == len(trace)
    # refusals only come from post-retire moe arrivals
    post = np.sum((trace.arrivals >= retire_at)
                  & (np.asarray(trace.plan_idx)
                     == trace.plan_ids.index("moe")))
    assert res.refused_retired <= int(post)
    payload = res.to_payload()
    assert payload["refused_retired"] == res.refused_retired
    assert payload["retired_plan"] == "moe"


def test_sim_without_retire_is_unchanged():
    trace = _mixed_trace()
    res = simulate(_SIM_SPECS, trace, "plan_aware")
    assert res.refused_retired == 0 and res.retired_plan is None
    assert res.completed == len(trace) and res.lost == 0


def test_sim_retire_args_go_together():
    trace = _mixed_trace(n=50)
    with pytest.raises(ValueError, match="go together"):
        simulate(_SIM_SPECS, trace, "plan_aware", retire_at=1.0)
    with pytest.raises(ValueError, match="go together"):
        simulate(_SIM_SPECS, trace, "plan_aware", retire_plan_id="moe")
