"""Data pipeline: determinism (the restart contract), masking, prefetch."""

import numpy as np

from repro.data import DataConfig, make_pipeline
from repro.data.pipeline import batch_at


def _cfg(**kw):
    return DataConfig(vocab_size=997, seq_len=64, global_batch=4, **kw)


def test_batch_deterministic_in_step():
    cfg = _cfg()
    a = batch_at(cfg, 17)
    b = batch_at(cfg, 17)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    np.testing.assert_array_equal(a["labels"], b["labels"])


def test_steps_differ():
    cfg = _cfg()
    a = batch_at(cfg, 1)
    b = batch_at(cfg, 2)
    assert not np.array_equal(a["tokens"], b["tokens"])


def test_tokens_in_range_and_labels_masked():
    cfg = _cfg()
    b = batch_at(cfg, 3)
    assert b["tokens"].min() >= 0
    assert b["tokens"].max() < cfg.vocab_size
    assert (b["labels"] == -100).sum() >= cfg.global_batch  # ≥1 per row


def test_learnable_structure_exists():
    """The synthetic stream injects bigram structure (even→odd position);
    verify the deterministic mapping holds where labels are unmasked."""
    cfg = _cfg()
    b = batch_at(cfg, 5)
    toks = b["tokens"]
    pred = (toks[:, 0::2] * 7 + 13) % cfg.vocab_size
    got = toks[:, 1::2]
    match = (pred[:, : got.shape[1]] == got).mean()
    assert match > 0.95


def test_pipeline_prefetch_resume():
    cfg = _cfg()
    it = make_pipeline(cfg, start_step=7)
    first = next(it)
    np.testing.assert_array_equal(first["tokens"],
                                  batch_at(cfg, 7)["tokens"])
    second = next(it)
    np.testing.assert_array_equal(second["tokens"],
                                  batch_at(cfg, 8)["tokens"])
