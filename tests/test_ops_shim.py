"""The deprecated ``ops.conv_block`` / ``ops.conv_block_ref`` shims: they
must warn, preserve the seed's ValueError contract for unknown names, and
stay bit-exact with the registry path they wrap."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.blocks import get_block
from repro.kernels import ops


def _xw(block="conv2", bits=8):
    rng = np.random.default_rng(7)
    x = ops.quantize_fixed(
        jnp.asarray(rng.integers(-100, 100, (16, 128)), jnp.float32), bits)
    shape = get_block(block).weight_shape(bits)
    w = ops.quantize_fixed(
        jnp.asarray(rng.integers(-100, 100, shape), jnp.float32), bits)
    return x, w


@pytest.mark.parametrize("name", ["conv1", "conv3"])
def test_conv_block_warns_and_matches_registry(name):
    x, w = _xw(name)
    with pytest.warns(DeprecationWarning, match="conv_block is deprecated"):
        y = ops.conv_block(name, x, w, data_bits=8, coeff_bits=8)
    yr = get_block(name).apply(x, w, data_bits=8, coeff_bits=8)
    np.testing.assert_array_equal(np.asarray(y), np.asarray(yr))


def test_conv_block_unknown_name_raises_value_error():
    x, w = _xw()
    with pytest.warns(DeprecationWarning):
        with pytest.raises(ValueError, match="unknown block 'conv99'"):
            ops.conv_block("conv99", x, w, data_bits=8, coeff_bits=8)


def test_conv_block_ref_warns_and_matches():
    x, w = _xw("conv4")
    with pytest.warns(DeprecationWarning,
                      match="conv_block_ref is deprecated"):
        y = ops.conv_block_ref("conv4", x, w)
    np.testing.assert_array_equal(
        np.asarray(y), np.asarray(get_block("conv4").reference(x, w)))
