"""Plan-driven CNN serving engine: slot batching, bit-exact outputs,
plan construction, scheduling-policy ordering, SlotPool telemetry
bounds/thread-safety, and data-parallel sharded execution."""

import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.core import deploy
from repro.core.cnn import (CNNConfig, ConvLayerSpec, cnn_forward_ref,
                            fitted_block_models, init_cnn)
from repro.kernels import ops
from repro.parallel.sharding import cnn_batch_sharding, cnn_data_mesh
from repro.serve import CNNEngine, CNNServeConfig, ImageRequest


def _cfg():
    return CNNConfig(layers=(
        ConvLayerSpec(1, 4, data_bits=8, coeff_bits=6, block="conv4"),
        ConvLayerSpec(4, 3, data_bits=6, coeff_bits=4, block="conv3"),
    ), img_h=16, img_w=64)


def _engine(max_batch=4):
    cfg = _cfg()
    params = init_cnn(jax.random.PRNGKey(0), cfg)
    return CNNEngine(cfg, params, [s.block for s in cfg.layers],
                     CNNServeConfig(max_batch=max_batch))


def _requests(engine, k, seed=0):
    rng = np.random.default_rng(seed)
    d0 = engine.cfg.layers[0].data_bits
    return [ImageRequest(
        image=np.asarray(ops.quantize_fixed(
            rng.integers(0, 1 << (d0 - 1),
                         engine.in_shape).astype(np.float32), d0)),
        request_id=i) for i in range(k)]


def test_engine_outputs_bit_exact_vs_oracle():
    """7 requests through a 4-slot pool: 2 steps, every output equals the
    per-image integer oracle."""
    eng = _engine(max_batch=4)
    reqs = _requests(eng, 7)
    eng.run(reqs)
    for r in reqs:
        assert r.done
        yr = cnn_forward_ref(eng.params, jnp.asarray(r.image), eng.cfg)
        np.testing.assert_array_equal(r.output, np.asarray(yr))
    stats = eng.stats()
    assert stats["images_served"] == 7 and stats["steps"] == 2


def test_engine_zero_slot_isolation():
    """The same image served solo (3 empty zero slots) and in a full
    pool must produce identical outputs."""
    eng = _engine(max_batch=4)
    reqs = _requests(eng, 4, seed=1)
    solo = ImageRequest(image=reqs[2].image.copy(), request_id=99)
    eng.run([solo])
    eng.run(reqs)
    np.testing.assert_array_equal(solo.output, reqs[2].output)


def test_engine_pool_overflow_and_validation():
    eng = _engine(max_batch=2)
    reqs = _requests(eng, 3)
    assert eng.submit(reqs[0]) and eng.submit(reqs[1])
    assert not eng.submit(reqs[2])          # pool full → caller requeues
    eng.step()
    assert eng.submit(reqs[2])
    with pytest.raises(ValueError, match="image shape"):
        eng.submit(ImageRequest(image=np.zeros((8, 8, 1), np.int8)))


def test_engine_from_plan_runs_planned_assignment():
    """from_plan bakes the planner's (block, bits) into the engine and
    the served outputs match the oracle at the planned precisions."""
    cfg = CNNConfig(layers=(
        ConvLayerSpec(1, 4, data_bits=8, coeff_bits=6),
        ConvLayerSpec(4, 2, data_bits=6, coeff_bits=6),
    ), img_h=16, img_w=64)
    bm = fitted_block_models()
    plan = deploy.plan_deployment(cfg, bm, target=0.8,
                                  on_infeasible="fallback")
    eng = CNNEngine.from_plan(plan, cfg,
                              serve_cfg=CNNServeConfig(max_batch=2))
    assert [b.name for b in eng.blocks] == plan.block_names()
    assert [(s.data_bits, s.coeff_bits) for s in eng.cfg.layers] \
        == plan.bits()
    reqs = _requests(eng, 3, seed=2)
    eng.run(reqs)
    pcfg = deploy.plan_config(plan, cfg)
    for r in reqs:
        yr = cnn_forward_ref(eng.params, jnp.asarray(r.image), pcfg)
        np.testing.assert_array_equal(r.output, np.asarray(yr))


def test_engine_block_count_mismatch():
    cfg = _cfg()
    params = init_cnn(jax.random.PRNGKey(0), cfg)
    with pytest.raises(ValueError, match="one block per layer"):
        CNNEngine(cfg, params, ["conv2"])


def test_engine_rejects_non_integral_float_images():
    """A float image with fractional values used to be silently truncated
    by the int cast in step(); submit now rejects it."""
    eng = _engine(max_batch=2)
    good = _requests(eng, 1)[0]
    # float dtype but exactly integral values: accepted (cast is exact)
    float_img = np.asarray(good.image, np.float32)
    assert eng.submit(ImageRequest(image=float_img, request_id=1))
    with pytest.raises(ValueError, match="non-integral"):
        eng.submit(ImageRequest(image=float_img + 0.5, request_id=2))
    with pytest.raises(ValueError, match="non-integral"):
        eng.submit(ImageRequest(
            image=np.full(eng.in_shape, np.nan, np.float32), request_id=3))
    # values outside the container range would wrap, not clamp: rejected
    hi = np.iinfo(eng.in_dtype).max
    with pytest.raises(ValueError, match="container range"):
        eng.submit(ImageRequest(
            image=np.full(eng.in_shape, hi + 1, np.int32), request_id=4))
    # the accepted float image still serves bit-exactly
    eng.step()
    yr = cnn_forward_ref(eng.params, jnp.asarray(good.image), eng.cfg)
    req = ImageRequest(image=float_img, request_id=5)
    eng.submit(req)
    eng.step()
    np.testing.assert_array_equal(req.output, np.asarray(yr))


def test_engine_large_queue_drains_in_order():
    """Deque regression (the run loop used list.pop(0), O(n²) over a
    workload): a queue much larger than the pool drains completely, in
    FIFO waves, every output bit-exact."""
    eng = _engine(max_batch=4)
    reqs = _requests(eng, 257, seed=7)
    out = eng.run(reqs)
    assert out is not None and len(out) == 257
    assert all(r.done for r in reqs)
    stats = eng.stats()
    assert stats["images_served"] == 257
    assert stats["steps"] == 65            # 64 full waves + the tail of 1
    # FIFO: the first pool-load is exactly the first 4 requests, etc.
    ref = cnn_forward_ref(eng.params, jnp.asarray(reqs[-1].image), eng.cfg)
    np.testing.assert_array_equal(reqs[-1].output, np.asarray(ref))


def test_engine_occupancy_and_bucket_telemetry():
    """stats() exposes the live-slot histogram and the CompiledCNN
    bucket-hit counts — the observable face of bucketed batching."""
    eng = _engine(max_batch=4)
    reqs = _requests(eng, 7)
    eng.run(reqs)                          # waves of 4 then 3
    stats = eng.stats()
    assert stats["occupancy_hist"] == {4: 1, 3: 1}
    # occupancy 4 → bucket 4; occupancy 3 → smallest bucket ≥ 3 is 4
    assert stats["bucket_hits"] == {1: 0, 2: 0, 4: 2}
    assert stats["aot_warmed_up"]
    solo = _requests(eng, 1, seed=9)[0]
    eng.submit(solo)
    eng.step()
    stats = eng.stats()
    assert stats["occupancy_hist"][1] == 1
    assert stats["bucket_hits"][1] == 1    # a lone image no longer pays
    assert stats["images_per_step"] == 8 / 3


def test_engine_no_warmup_still_serves():
    cfg = _cfg()
    params = init_cnn(jax.random.PRNGKey(0), cfg)
    eng = CNNEngine(cfg, params, [s.block for s in cfg.layers],
                    CNNServeConfig(max_batch=2, aot_warmup=False))
    assert not eng.stats()["aot_warmed_up"]
    reqs = _requests(eng, 3, seed=4)
    eng.run(reqs)
    for r in reqs:
        yr = cnn_forward_ref(eng.params, jnp.asarray(r.image), eng.cfg)
        np.testing.assert_array_equal(r.output, np.asarray(yr))


def test_engine_rejects_empty_slot_pool():
    """max_batch < 1 would make run() spin forever (submit always False,
    step always 0) — must be rejected at construction."""
    cfg = _cfg()
    params = init_cnn(jax.random.PRNGKey(0), cfg)
    with pytest.raises(ValueError, match="max_batch"):
        CNNEngine(cfg, params, [s.block for s in cfg.layers],
                  CNNServeConfig(max_batch=0))


# ---------------------------------------------------------------------------
# shared scheduling policies + SlotPool telemetry
# ---------------------------------------------------------------------------

def test_engine_run_edf_policy_orders_waves():
    """The sync drain accepts the same scheduling policies as the async
    gateway: under policy="edf" the first wave is the most urgent
    requests, not arrival order."""
    eng = _engine(max_batch=2)
    reqs = _requests(eng, 4)
    reqs[0].deadline = 9.0
    reqs[1].deadline = 1.0
    reqs[2].deadline = 2.0
    reqs[3].priority = 1               # higher tier: runs first
    order = []
    orig_step = eng.step

    def spy_step():
        order.append([r.request_id for _, r in eng.live()])
        return orig_step()

    eng.step = spy_step
    eng.run(reqs, policy="edf", clock=lambda: 0.0)
    assert order == [[3, 1], [2, 0]]
    assert all(r.done for r in reqs)


def test_engine_run_fifo_default_unchanged():
    eng = _engine(max_batch=2)
    reqs = _requests(eng, 3)
    reqs[0].deadline = 99.0            # ignored under FIFO
    order = []
    orig_step = eng.step

    def spy_step():
        order.append([r.request_id for _, r in eng.live()])
        return orig_step()

    eng.step = spy_step
    eng.run(reqs)
    assert order == [[0, 1], [2]]


def test_slot_pool_occupancy_hist_is_bounded_and_clamped():
    """Regression: the histogram used to be an unbounded dict keyed on
    whatever a subclass reported.  It is now a fixed max_batch-sized
    array — bogus occupancies clamp into range instead of growing it."""
    eng = _engine(max_batch=2)
    eng._note_step(1)
    eng._note_step(10 ** 9)            # clamps to max_batch
    eng._note_step(-5)                 # clamps to 1
    hist = eng.occupancy_hist
    assert hist == {1: 2, 2: 1}
    assert len(eng._occupancy) == 2    # fixed backing store


def test_slot_pool_stats_thread_safe_under_concurrent_steps():
    """Two threads hammering _note_step while another snapshots: no
    lost counts, every snapshot internally consistent."""
    import threading

    eng = _engine(max_batch=4)
    N = 2000

    def noter():
        for _ in range(N):
            eng._note_step(3)

    threads = [threading.Thread(target=noter) for _ in range(2)]
    snapshots = []

    def reader():
        for _ in range(200):
            snapshots.append(eng.occupancy_hist.get(3, 0))

    r = threading.Thread(target=reader)
    for t in threads + [r]:
        t.start()
    for t in threads + [r]:
        t.join()
    assert eng.occupancy_hist[3] == 2 * N
    assert eng.steps == 2 * N
    assert snapshots == sorted(snapshots)  # monotone non-decreasing


# ---------------------------------------------------------------------------
# data-parallel sharding
# ---------------------------------------------------------------------------

def test_cnn_batch_sharding_divisibility():
    mesh = cnn_data_mesh()                       # 1-D all-data mesh
    n = len(jax.devices())
    assert cnn_batch_sharding(mesh, 4 * n).spec \
        == P("data", None, None, None)
    # 2-D train-style mesh: batch over the data axis only
    mesh2 = jax.make_mesh((1, 1), ("data", "model"))
    assert cnn_batch_sharding(mesh2, 8).spec == P("data", None, None, None)


def test_engine_sharded_multidevice():
    """8 host devices: the mesh-sharded engine serves bit-identically to
    the unsharded single-device forward (SPMD correctness end-to-end)."""
    prog = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import sys
        sys.path.insert(0, "src")
        import jax, jax.numpy as jnp
        import numpy as np
        from repro.core.cnn import (CNNConfig, ConvLayerSpec, cnn_forward,
                                    cnn_forward_ref, init_cnn)
        from repro.kernels import ops
        from repro.parallel.sharding import cnn_batch_sharding, cnn_data_mesh
        from repro.serve import CNNEngine, CNNServeConfig, ImageRequest

        assert len(jax.devices()) == 8
        cfg = CNNConfig(layers=(
            ConvLayerSpec(1, 4, data_bits=8, coeff_bits=6, block="conv4"),
            ConvLayerSpec(4, 3, data_bits=6, coeff_bits=4, block="conv3"),
        ), img_h=16, img_w=64)
        params = init_cnn(jax.random.PRNGKey(0), cfg)
        blocks = [s.block for s in cfg.layers]
        mesh = cnn_data_mesh()

        rng = np.random.default_rng(0)
        xb = ops.quantize_fixed(jnp.asarray(
            rng.integers(0, 128, (8, 16, 64, 1)), jnp.float32), 8)
        y_ref = cnn_forward_ref(params, xb, cfg)

        from jax.sharding import PartitionSpec as P
        assert cnn_batch_sharding(mesh, 3).spec \
            == P(None, None, None, None)   # 3 images over 8: replicated
        sh = cnn_batch_sharding(mesh, 8)
        xs = jax.device_put(xb, sh)
        fwd = jax.jit(lambda p, x: cnn_forward(p, x, cfg, blocks,
                                               mesh=mesh))
        y_sh = fwd(params, xs)
        assert len(y_sh.sharding.device_set) == 8, y_sh.sharding
        assert bool(jnp.all(y_sh == y_ref))

        eng = CNNEngine(cfg, params, blocks,
                        CNNServeConfig(max_batch=8), mesh=mesh)
        reqs = [ImageRequest(image=np.asarray(xb[i % 8]), request_id=i)
                for i in range(12)]
        eng.run(reqs)
        for i, r in enumerate(reqs):
            assert np.array_equal(
                r.output, np.asarray(y_ref[i % 8])), i
        print("CNN_SHARDED_OK")
    """)
    out = subprocess.run([sys.executable, "-c", prog], cwd=".",
                         capture_output=True, text=True, timeout=600)
    assert "CNN_SHARDED_OK" in out.stdout, out.stdout + out.stderr
