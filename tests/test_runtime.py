"""repro.runtime: serializable DeploymentPlans and the AOT
batch-bucketed CompiledCNN (plan→compile→serve, bit-exact)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import runtime
from repro.core import deploy
from repro.core.cnn import (CNNConfig, ConvLayerSpec, cnn_forward_ref,
                            fitted_block_models, init_cnn,
                            quickstart_cnn_config)
from repro.kernels import ops
from repro.runtime import CompiledCNN, bucket_ladder


def _cfg():
    return CNNConfig(layers=(
        ConvLayerSpec(1, 4, data_bits=8, coeff_bits=6, block="conv4"),
        ConvLayerSpec(4, 3, data_bits=6, coeff_bits=4, block="conv3"),
    ), img_h=16, img_w=64)


def _images(cfg, n, seed=0):
    rng = np.random.default_rng(seed)
    d0 = cfg.layers[0].data_bits
    return np.asarray(ops.quantize_fixed(jnp.asarray(
        rng.integers(0, 1 << (d0 - 1),
                     (n, cfg.img_h, cfg.img_w, cfg.layers[0].in_channels)),
        jnp.float32), d0))


@pytest.fixture(scope="module")
def bm():
    return fitted_block_models()


@pytest.fixture(scope="module")
def plan(bm):
    return deploy.plan_deployment(_cfg(), bm, target=0.8,
                                  on_infeasible="fallback")


# ---------------------------------------------------------------------------
# serializable plans
# ---------------------------------------------------------------------------

def test_plan_json_round_trip_exact(plan):
    """The acceptance contract: from_json(to_json()) == the plan, and a
    second serialization is byte-identical."""
    text = plan.to_json()
    loaded = deploy.DeploymentPlan.from_json(text)
    assert loaded == plan
    assert loaded.to_json() == text
    # the network config travels inside the artifact
    assert loaded.cnn == _cfg()
    assert deploy.plan_config(loaded) == deploy.plan_config(plan, _cfg())


def test_plan_save_load_file(plan, tmp_path):
    path = runtime.save_plan(plan, tmp_path / "plan.json")
    assert runtime.load_plan(path) == plan


def test_plan_round_trip_preserves_quant_error(bm):
    plan = deploy.plan_deployment(_cfg(), bm, target=0.8,
                                  on_infeasible="fallback")
    plan.quant_error = 0.125
    assert deploy.DeploymentPlan.from_json(plan.to_json()) == plan


def test_plan_config_needs_some_cfg():
    plan = deploy.DeploymentPlan(
        device=deploy._as_device(None), target=0.8, layers=(),
        demand={}, usage_pct={}, convs_per_step=0.0)
    with pytest.raises(ValueError, match="no CNNConfig"):
        deploy.plan_config(plan)


# ---------------------------------------------------------------------------
# bucket ladder + dispatch
# ---------------------------------------------------------------------------

def test_bucket_ladder():
    assert bucket_ladder(1) == (1,)
    assert bucket_ladder(8) == (1, 2, 4, 8)
    assert bucket_ladder(16) == (1, 2, 4, 8, 16)
    assert bucket_ladder(12) == (1, 2, 4, 8, 12)   # top rung = max_batch
    with pytest.raises(ValueError, match="max_batch"):
        bucket_ladder(0)


def test_bucket_for():
    cfg = _cfg()
    cnn = CompiledCNN(cfg, init_cnn(jax.random.PRNGKey(0), cfg),
                      [s.block for s in cfg.layers], max_batch=4,
                      warmup=False)
    assert [cnn.bucket_for(n) for n in (1, 2, 3, 4)] == [1, 2, 4, 4]
    with pytest.raises(ValueError, match="exceeds max_batch"):
        cnn.bucket_for(5)


# ---------------------------------------------------------------------------
# CompiledCNN execution
# ---------------------------------------------------------------------------

def test_compiled_bit_exact_all_batch_sizes():
    """Every live batch size — including sizes above max_batch, which
    chunk — matches the per-image integer oracle exactly."""
    cfg = _cfg()
    params = init_cnn(jax.random.PRNGKey(0), cfg)
    cnn = CompiledCNN(cfg, params, [s.block for s in cfg.layers],
                      max_batch=4)
    assert cnn.warmed_up and cnn.stats()["executables"] == 6  # 2 layers × 3
    xs = _images(cfg, 9)
    y_ref = np.asarray(cnn_forward_ref(params, jnp.asarray(xs), cfg))
    for n in (1, 2, 3, 4, 9):          # 9 > max_batch → 4+4+1 chunks
        np.testing.assert_array_equal(np.asarray(cnn(xs[:n])), y_ref[:n])
    # single (H, W, C) image round-trips without the batch axis
    y1 = np.asarray(cnn(xs[0]))
    np.testing.assert_array_equal(y1, y_ref[0])
    hits = cnn.stats()["bucket_hits"]
    assert hits[1] >= 2 and hits[2] >= 1 and hits[4] >= 3


def test_compiled_warmup_precompiles_everything():
    cfg = _cfg()
    params = init_cnn(jax.random.PRNGKey(0), cfg)
    cnn = CompiledCNN(cfg, params, [s.block for s in cfg.layers],
                      max_batch=2, warmup=False)
    assert not cnn.warmed_up and cnn.compiles == 0
    cnn(_images(cfg, 1))               # lazy compile: only bucket 1
    assert cnn.compiles == len(cfg.layers) and not cnn.warmed_up
    cnn.warmup()
    assert cnn.warmed_up
    n = cnn.compiles
    cnn.warmup()                       # idempotent — all cached
    cnn(_images(cfg, 2))
    assert cnn.compiles == n


def test_compiled_shares_executables_across_identical_layers():
    """Two layers with the same (block, bits, geometry) share one
    executable per bucket — the (layer spec, bucket) cache key."""
    cfg = CNNConfig(layers=(
        ConvLayerSpec(2, 2, data_bits=8, coeff_bits=6, block="conv2"),
        ConvLayerSpec(2, 2, data_bits=8, coeff_bits=6, block="conv2"),
    ), img_h=16, img_w=64)
    params = init_cnn(jax.random.PRNGKey(0), cfg)
    cnn = CompiledCNN(cfg, params, [s.block for s in cfg.layers],
                      max_batch=2)
    assert cnn.stats()["executables"] == 2     # 1 spec × 2 buckets
    xs = _images(cfg, 2)
    np.testing.assert_array_equal(
        np.asarray(cnn(xs)),
        np.asarray(cnn_forward_ref(params, jnp.asarray(xs), cfg)))


def test_compiled_empty_batch():
    """An empty (0, H, W, C) batch (e.g. an idle queue tick) returns an
    empty output of the network's out shape/dtype instead of crashing."""
    cfg = _cfg()
    params = init_cnn(jax.random.PRNGKey(0), cfg)
    cnn = CompiledCNN(cfg, params, [s.block for s in cfg.layers],
                      max_batch=2, warmup=False)
    y = cnn(np.zeros((0,) + cnn.in_shape, cnn.in_dtype))
    assert y.shape == (0, cfg.img_h, cfg.img_w, cfg.layers[-1].out_channels)
    assert cnn.compiles == 0           # nothing ran, nothing compiled


def test_compiled_validates_inputs():
    cfg = _cfg()
    cnn = CompiledCNN(cfg, init_cnn(jax.random.PRNGKey(0), cfg),
                      [s.block for s in cfg.layers], max_batch=2,
                      warmup=False)
    with pytest.raises(ValueError, match="image shape"):
        cnn(np.zeros((8, 8, 1), np.int8))
    with pytest.raises(ValueError, match="dtype"):
        cnn(np.zeros((1,) + cnn.in_shape, np.int32))
    with pytest.raises(ValueError, match="one block per layer"):
        CompiledCNN(cfg, init_cnn(jax.random.PRNGKey(0), cfg), ["conv2"])


# ---------------------------------------------------------------------------
# plan → compile → serve (the acceptance loop on the quickstart CNN)
# ---------------------------------------------------------------------------

def test_from_plan_loaded_json_bit_exact_quickstart(bm, tmp_path):
    """Acceptance: a plan serialized to disk, reloaded, and compiled via
    ``CompiledCNN.from_plan`` is bit-exact vs ``cnn_forward_ref`` on the
    quickstart CNN — plan on one machine, serve on another."""
    cfg = quickstart_cnn_config()
    plan = deploy.plan_deployment(cfg, bm, target=0.8,
                                  on_infeasible="fallback")
    loaded = runtime.load_plan(runtime.save_plan(plan, tmp_path / "p.json"))
    assert loaded == plan

    key = jax.random.PRNGKey(7)
    cnn = CompiledCNN.from_plan(loaded, key=key, max_batch=2)
    assert cnn.cfg == deploy.plan_config(plan, cfg)
    pcfg = deploy.plan_config(loaded)
    params = init_cnn(key, pcfg)       # same draw the runtime made
    xs = _images(pcfg, 2, seed=3)
    np.testing.assert_array_equal(
        np.asarray(cnn(xs)),
        np.asarray(cnn_forward_ref(params, jnp.asarray(xs), pcfg)))


def test_from_json_constructor(plan):
    cnn = CompiledCNN.from_json(plan.to_json(), max_batch=1)
    xs = _images(cnn.cfg, 1, seed=5)
    np.testing.assert_array_equal(
        np.asarray(cnn(xs)),
        np.asarray(cnn_forward_ref(cnn.params, jnp.asarray(xs), cnn.cfg)))
