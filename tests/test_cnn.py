"""CNN-on-conv-blocks: allocator-driven block selection + exact inference."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.cnn import (CNNConfig, ConvLayerSpec, choose_blocks,
                            cnn_forward, cnn_forward_ref, init_cnn)
from repro.kernels import ops


def _cfg():
    return CNNConfig(layers=(
        ConvLayerSpec(1, 4, data_bits=8, coeff_bits=6),
        ConvLayerSpec(4, 4, data_bits=8, coeff_bits=6),
        ConvLayerSpec(4, 2, data_bits=6, coeff_bits=6),
    ), img_h=16, img_w=128)


def test_allocator_chooses_blocks():
    cfg = _cfg()
    blocks = choose_blocks(cfg)
    assert len(blocks) == 3
    assert all(b in ("conv1", "conv2", "conv3", "conv4") for b in blocks)


def test_cnn_blocks_match_reference():
    cfg = _cfg()
    params = init_cnn(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    x = ops.quantize_fixed(
        jnp.asarray(rng.integers(0, 100, (16, 128, 1)), jnp.float32), 8)
    for blocks in (["conv1", "conv2", "conv4"], choose_blocks(cfg)):
        y = cnn_forward(params, x, cfg, blocks)
        yr = cnn_forward_ref(params, x, cfg)
        np.testing.assert_array_equal(np.asarray(y), np.asarray(yr))
        assert y.shape == (16, 128, 2)
