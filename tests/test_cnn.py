"""CNN-on-conv-blocks: allocator-driven block selection + exact inference."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.blocks import ConvBlock, get_block
from repro.core.cnn import (CNNConfig, ConvLayerSpec, choose_blocks,
                            cnn_forward, cnn_forward_loop, cnn_forward_ref,
                            init_cnn)
from repro.kernels import ops


def _cfg():
    return CNNConfig(layers=(
        ConvLayerSpec(1, 4, data_bits=8, coeff_bits=6),
        ConvLayerSpec(4, 4, data_bits=8, coeff_bits=6),
        ConvLayerSpec(4, 2, data_bits=6, coeff_bits=6),
    ), img_h=16, img_w=128)


def test_allocator_chooses_blocks():
    cfg = _cfg()
    blocks = choose_blocks(cfg)
    assert len(blocks) == 3
    assert all(isinstance(b, ConvBlock) for b in blocks)
    assert all(b.name in ("conv1", "conv2", "conv3", "conv4")
               for b in blocks)


def test_cnn_blocks_match_reference():
    cfg = _cfg()
    params = init_cnn(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    x = ops.quantize_fixed(
        jnp.asarray(rng.integers(0, 100, (16, 128, 1)), jnp.float32), 8)
    explicit = [get_block(n) for n in ("conv1", "conv2", "conv4")]
    yr = cnn_forward_ref(params, x, cfg)
    for blocks in (explicit, choose_blocks(cfg)):
        y = cnn_forward(params, x, cfg, blocks)
        np.testing.assert_array_equal(np.asarray(y), np.asarray(yr))
        assert y.shape == (16, 128, 2)


def test_cnn_forward_accepts_names_and_loop_matches():
    """Back-compat: block names coerce through the registry, and the
    per-plane loop baseline stays bit-exact with the batched path."""
    cfg = _cfg()
    params = init_cnn(jax.random.PRNGKey(1), cfg)
    rng = np.random.default_rng(1)
    x = ops.quantize_fixed(
        jnp.asarray(rng.integers(0, 100, (16, 128, 1)), jnp.float32), 8)
    names = ["conv3", "conv1", "conv2"]
    y = cnn_forward(params, x, cfg, names)
    yl = cnn_forward_loop(params, x, cfg, names)
    yr = cnn_forward_ref(params, x, cfg)
    np.testing.assert_array_equal(np.asarray(y), np.asarray(yr))
    np.testing.assert_array_equal(np.asarray(yl), np.asarray(yr))
