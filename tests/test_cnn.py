"""CNN-on-conv-blocks: allocator-driven block selection + exact inference,
batch-first (N, H, W, C) forward, spec validation, model-fit memoization."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.blocks import BIT_RANGE, ConvBlock, get_block
from repro.core import cnn as cnn_mod
from repro.core.cnn import (CNNConfig, ConvLayerSpec, choose_blocks,
                            cnn_forward, cnn_forward_loop, cnn_forward_ref,
                            init_cnn, init_cnn_float)
from repro.kernels import ops


def _cfg():
    return CNNConfig(layers=(
        ConvLayerSpec(1, 4, data_bits=8, coeff_bits=6),
        ConvLayerSpec(4, 4, data_bits=8, coeff_bits=6),
        ConvLayerSpec(4, 2, data_bits=6, coeff_bits=6),
    ), img_h=16, img_w=128)


def test_allocator_chooses_blocks():
    cfg = _cfg()
    blocks = choose_blocks(cfg)
    assert len(blocks) == 3
    assert all(isinstance(b, ConvBlock) for b in blocks)
    assert all(b.name in ("conv1", "conv2", "conv3", "conv4")
               for b in blocks)


def test_cnn_blocks_match_reference():
    cfg = _cfg()
    params = init_cnn(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    x = ops.quantize_fixed(
        jnp.asarray(rng.integers(0, 100, (16, 128, 1)), jnp.float32), 8)
    explicit = [get_block(n) for n in ("conv1", "conv2", "conv4")]
    yr = cnn_forward_ref(params, x, cfg)
    for blocks in (explicit, choose_blocks(cfg)):
        y = cnn_forward(params, x, cfg, blocks)
        np.testing.assert_array_equal(np.asarray(y), np.asarray(yr))
        assert y.shape == (16, 128, 2)


def test_cnn_forward_accepts_names_and_loop_matches():
    """Back-compat: block names coerce through the registry, and the
    per-plane loop baseline stays bit-exact with the batched path."""
    cfg = _cfg()
    params = init_cnn(jax.random.PRNGKey(1), cfg)
    rng = np.random.default_rng(1)
    x = ops.quantize_fixed(
        jnp.asarray(rng.integers(0, 100, (16, 128, 1)), jnp.float32), 8)
    names = ["conv3", "conv1", "conv2"]
    y = cnn_forward(params, x, cfg, names)
    yl = cnn_forward_loop(params, x, cfg, names)
    yr = cnn_forward_ref(params, x, cfg)
    np.testing.assert_array_equal(np.asarray(y), np.asarray(yr))
    np.testing.assert_array_equal(np.asarray(yl), np.asarray(yr))


# ---------------------------------------------------------------------------
# batch-first (N, H, W, C) forward
# ---------------------------------------------------------------------------

def test_cnn_forward_batched_bit_exact():
    """(N, H, W, C) batches through the same forward: bit-exact vs the
    per-image oracle for N ∈ {1, 4, 16}, and every image's result equals
    its solo single-image forward (no cross-batch leakage)."""
    cfg = _cfg()
    params = init_cnn(jax.random.PRNGKey(0), cfg)
    blocks = [get_block(n) for n in ("conv4", "conv3", "conv2")]
    rng = np.random.default_rng(0)
    xs = ops.quantize_fixed(
        jnp.asarray(rng.integers(0, 100, (16, 16, 128, 1)), jnp.float32), 8)
    for n in (1, 4, 16):
        xb = xs[:n]
        y = cnn_forward(params, xb, cfg, blocks)
        yr = cnn_forward_ref(params, xb, cfg)
        assert y.shape == (n, 16, 128, 2)
        np.testing.assert_array_equal(np.asarray(y), np.asarray(yr))
    solo = cnn_forward(params, xs[2], cfg, blocks)
    batched = cnn_forward(params, xs[:4], cfg, blocks)
    np.testing.assert_array_equal(np.asarray(batched[2]), np.asarray(solo))


def test_cnn_forward_batched_rejects_bad_rank():
    cfg = _cfg()
    params = init_cnn(jax.random.PRNGKey(0), cfg)
    with pytest.raises(ValueError, match="expected"):
        cnn_forward(params, jnp.zeros((2, 2, 16, 128, 1), jnp.int8), cfg,
                    ["conv2"] * 3)


# ---------------------------------------------------------------------------
# ConvLayerSpec validation + init_cnn_float across the bit range
# ---------------------------------------------------------------------------

def test_layer_spec_validates_bit_widths():
    lo, hi = BIT_RANGE
    with pytest.raises(ValueError, match="data_bits"):
        ConvLayerSpec(1, 4, data_bits=lo - 1)
    with pytest.raises(ValueError, match="coeff_bits"):
        ConvLayerSpec(1, 4, coeff_bits=1)      # the seed's 1 << -1 crash
    with pytest.raises(ValueError, match="coeff_bits"):
        ConvLayerSpec(1, 4, coeff_bits=hi + 1)
    with pytest.raises(ValueError, match="shift"):
        ConvLayerSpec(1, 4, shift=-1)
    with pytest.raises(ValueError, match="channel"):
        ConvLayerSpec(0, 4)


@pytest.mark.parametrize("bits", [BIT_RANGE[0], BIT_RANGE[1]])
def test_init_and_forward_at_bit_range_edges(bits):
    """Both edges of the supported range initialize and run bit-exactly
    (the seed's weight-scale formula raised for narrow coeff widths)."""
    cfg = CNNConfig(layers=(
        ConvLayerSpec(1, 3, data_bits=bits, coeff_bits=bits, block="conv2",
                      shift=max(bits - 2, 0)),
    ), img_h=16, img_w=64)
    floats = init_cnn_float(jax.random.PRNGKey(0), cfg)
    assert np.isfinite(np.asarray(floats[0])).all()
    params = init_cnn(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(bits)
    x = ops.quantize_fixed(
        jnp.asarray(rng.integers(0, 1 << (bits - 1), (2, 16, 64, 1)),
                    jnp.float32), bits)
    y = cnn_forward(params, x, cfg, ["conv2"])
    yr = cnn_forward_ref(params, x, cfg)
    np.testing.assert_array_equal(np.asarray(y), np.asarray(yr))


# ---------------------------------------------------------------------------
# fitted-model memoization (choose_blocks must not re-sweep per call)
# ---------------------------------------------------------------------------

def test_choose_blocks_fits_models_once(monkeypatch):
    """Repeated planning/serving calls share ONE sweep + fit: the seed
    re-ran the full synthesis sweep and refit BlockModels on every
    rows=None call."""
    from repro.core import allocate
    cnn_mod.clear_fitted_model_cache()
    calls = {"fit": 0}
    real_fit = allocate.BlockModels.fit.__func__

    def counting_fit(cls, rows):
        calls["fit"] += 1
        return real_fit(cls, rows)

    monkeypatch.setattr(allocate.BlockModels, "fit",
                        classmethod(counting_fit))
    cfg = _cfg()
    first = choose_blocks(cfg)
    second = choose_blocks(cfg)
    assert calls["fit"] == 1, "rows=None must memoize the fitted models"
    assert [b.name for b in first] == [b.name for b in second]
    # explicit rows still fit fresh (caller owns the sweep)
    rows = cnn_mod.synth.run_sweep()
    choose_blocks(cfg, rows=rows)
    assert calls["fit"] == 2
