"""Checkpointer: atomic commit, GC, mesh-agnostic restore, corruption
resistance."""

import json
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.train.checkpoint import Checkpointer


def _state(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"params": {"w": jax.random.normal(k, (4, 4)),
                       "b": jnp.zeros((4,))},
            "opt": {"step": jnp.int32(7)}}


def test_roundtrip(tmp_path):
    ck = Checkpointer(tmp_path)
    s = _state()
    ck.save(10, s)
    step, restored = ck.restore(jax.eval_shape(lambda: s))
    assert step == 10
    np.testing.assert_array_equal(np.asarray(restored["params"]["w"]),
                                  np.asarray(s["params"]["w"]))
    assert int(restored["opt"]["step"]) == 7


def test_latest_and_gc(tmp_path):
    ck = Checkpointer(tmp_path, keep=2)
    for step in (1, 2, 3, 4):
        ck.save(step, _state(step))
    assert ck.latest_step() == 4
    assert ck.all_steps() == [3, 4]       # GC keeps last 2


def test_tmp_dirs_ignored(tmp_path):
    """A crash mid-write leaves only a .tmp dir — restore must skip it."""
    ck = Checkpointer(tmp_path)
    ck.save(5, _state())
    crash = tmp_path / "step_0000000009.tmp"
    crash.mkdir()
    (crash / "junk.npy").write_bytes(b"garbage")
    assert ck.latest_step() == 5


def test_restore_shape_mismatch_raises(tmp_path):
    ck = Checkpointer(tmp_path)
    ck.save(1, {"w": jnp.zeros((4,))})
    with pytest.raises(ValueError):
        ck.restore({"w": jnp.zeros((8,))})


def test_restore_missing_leaf_raises(tmp_path):
    ck = Checkpointer(tmp_path)
    ck.save(1, {"w": jnp.zeros((4,))})
    with pytest.raises(KeyError):
        ck.restore({"w": jnp.zeros((4,)), "extra": jnp.zeros((2,))})


def test_mesh_agnostic_restore(tmp_path):
    """Arrays are stored unsharded: restoring into a differently-sharded
    (here: differently-replicated) target works — the elastic-rescale
    contract."""
    ck = Checkpointer(tmp_path)
    s = {"w": jnp.arange(16.0).reshape(4, 4)}
    ck.save(3, s)
    mesh = jax.make_mesh((1,), ("data",))
    from jax.sharding import NamedSharding, PartitionSpec as P
    target = jax.device_put(jnp.zeros((4, 4)),
                            NamedSharding(mesh, P("data", None)))
    _, restored = ck.restore({"w": target})
    np.testing.assert_array_equal(np.asarray(restored["w"]),
                                  np.asarray(s["w"]))
