"""Async continuous-batching gateway: admission bound and deadline
invariants (property-tested on the synchronous scheduling core),
end-to-end bit-exactness, backpressure, cancellation, multi-plan
routing, and cross-plan executable sharing."""

import asyncio

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from hypothesis_compat import HAVE_HYPOTHESIS, given, settings, st

from repro.core import deploy
from repro.core.cnn import (CNNConfig, ConvLayerSpec, cnn_forward_ref,
                            fitted_block_models, init_cnn)
from repro.runtime import CompiledCNN, DispatchAborted, ExecutableCache
from repro.serve import (AdmissionQueue, AsyncCNNGateway, AsyncRequest,
                         AsyncServeConfig, DeadlineExpired, GatewayBacklog,
                         get_policy)


def _cfg():
    return CNNConfig(layers=(
        ConvLayerSpec(1, 4, data_bits=8, coeff_bits=6, block="conv4"),
        ConvLayerSpec(4, 3, data_bits=6, coeff_bits=4, block="conv3"),
    ), img_h=16, img_w=64)


def _plan(cfg=None):
    cfg = cfg if cfg is not None else _cfg()
    return deploy.plan_deployment(cfg, fitted_block_models(), target=0.8,
                                  on_infeasible="fallback")


def _images(compiled, k, seed=0):
    return compiled.sample_inputs(k, seed)


def _req(i, *, plan_id="p", priority=0, deadline=None, now=0.0):
    return AsyncRequest(image=np.zeros(1), plan_id=plan_id, request_id=i,
                        priority=priority, deadline=deadline,
                        arrived_at=now)


# ---------------------------------------------------------------------------
# the synchronous scheduling core (no event loop)
# ---------------------------------------------------------------------------

def test_admission_queue_bound_and_rejection():
    q = AdmissionQueue(max_pending=3, policy="edf")
    assert all(q.admit(_req(i), 0.0) for i in range(3))
    assert q.full and len(q) == 3
    assert not q.admit(_req(3), 0.0)        # at the bound: refused
    _, batch = q.pop_batch(2, 0.0)
    assert [r.request_id for r in batch] == [0, 1]
    assert len(q) == 1 and not q.full
    assert q.admit(_req(4), 0.0)


def test_admission_queue_expires_instead_of_serving_late():
    q = AdmissionQueue(max_pending=8, policy="edf")
    on_time = _req(0, deadline=10.0)
    late = _req(1, deadline=2.0)
    assert q.admit(on_time, 0.0) and q.admit(late, 0.0)
    _, batch = q.pop_batch(8, now=5.0)      # past late's deadline
    assert [r.request_id for r in batch] == [0]
    assert late.status == "expired"
    assert isinstance(late.error, DeadlineExpired)
    assert q.expired == 1
    # already-expired on admission: terminal immediately, never queued
    dead = _req(2, deadline=1.0)
    assert q.admit(dead, now=5.0)           # handled, not refused
    assert dead.status == "expired" and len(q) == 0


def test_admission_queue_edf_order_and_priority_tiers():
    q = AdmissionQueue(max_pending=8, policy="edf")
    q.admit(_req(0, deadline=9.0), 0.0)
    q.admit(_req(1, deadline=3.0), 0.0)
    q.admit(_req(2), 0.0)                   # no deadline: last in tier
    q.admit(_req(3, deadline=99.0, priority=1), 0.0)   # higher tier
    _, batch = q.pop_batch(8, 0.0)
    assert [r.request_id for r in batch] == [3, 1, 0, 2]


def test_admission_queue_single_plan_batches_hold_others_back():
    q = AdmissionQueue(max_pending=8, policy="fifo")
    q.admit(_req(0, plan_id="a"), 0.0)
    q.admit(_req(1, plan_id="b"), 0.0)
    q.admit(_req(2, plan_id="a"), 0.0)
    pid, batch = q.pop_batch(8, 0.0)
    assert pid == "a" and [r.request_id for r in batch] == [0, 2]
    # plan b's request kept its place and forms the next batch
    pid, batch = q.pop_batch(8, 0.0)
    assert pid == "b" and [r.request_id for r in batch] == [1]
    assert len(q) == 0


def test_admission_queue_cancelled_entries_never_pop():
    q = AdmissionQueue(max_pending=4, policy="fifo")
    reqs = [_req(i) for i in range(3)]
    for r in reqs:
        q.admit(r, 0.0)
    assert reqs[1].cancel()
    q.note_terminal()                       # the gateway's cancel hook
    assert len(q) == 2
    _, batch = q.pop_batch(8, 0.0)
    assert [r.request_id for r in batch] == [0, 2]


if HAVE_HYPOTHESIS:
    _ops = st.lists(st.tuples(
        st.sampled_from(["submit", "pop", "tick", "cancel"]),
        st.integers(0, 7),                  # pop width / cancel index
        st.one_of(st.none(), st.floats(0.0, 4.0)),   # relative deadline
    ), min_size=1, max_size=60)
else:                                        # pragma: no cover
    _ops = None


@settings(max_examples=60, deadline=None)
@given(ops_list=_ops, bound=st.integers(1, 6))
def test_admission_bound_and_deadline_invariants(ops_list, bound):
    """Property: under any interleaving of submits, pops, clock ticks
    and cancels, (a) the live pending count never exceeds the bound,
    (b) a popped batch never contains an expired or cancelled request,
    and (c) every request ends served-able, expired, cancelled, or
    refused — never silently late."""
    q = AdmissionQueue(max_pending=bound, policy="edf")
    now = 0.0
    submitted, popped, refused = [], [], []
    for op, arg, dl in ops_list:
        if op == "submit":
            r = _req(len(submitted),
                     deadline=None if dl is None else now + dl, now=now)
            if q.admit(r, now):
                if r.status == "pending":
                    submitted.append(r)
            else:
                refused.append(r)
            assert len(q) <= bound
        elif op == "pop":
            _, batch = q.pop_batch(arg + 1, now)
            for r in batch:
                assert r.status == "pending"
                assert r.deadline is None or r.deadline >= now
                popped.append(r)
            assert len(q) <= bound
        elif op == "tick":
            now += 0.5 + (0.0 if dl is None else dl)
        elif op == "cancel":
            pending = [r for r in submitted
                       if r.status == "pending" and r not in popped]
            if pending:
                r = pending[arg % len(pending)]
                assert r.cancel()
                q.note_terminal()
        assert 0 <= len(q) <= bound
    # drain: nothing left behind in a non-terminal, non-poppable state
    _, batch = q.pop_batch(10 ** 6, now)
    popped.extend(batch)
    assert len(q) == 0
    for r in submitted:
        assert (r in popped and r.status == "pending") \
            or r.status in ("expired", "cancelled")
    for r in refused:
        assert r.status == "pending" and r not in popped


# ---------------------------------------------------------------------------
# adaptive admission: terminal-admit guard, shedding, resize, conservation
# ---------------------------------------------------------------------------

def test_admission_queue_refuses_terminal_requests():
    """Regression: a request that reached a terminal state before
    admission (e.g. its future was cancelled while ``submit`` awaited
    backpressure) must never be queued — pre-fix, ``admit`` pushed it
    and bumped the live count for an entry whose terminal hook had
    already run, leaking one slot of the bound per occurrence until
    the gateway refused all traffic."""
    q = AdmissionQueue(max_pending=2, policy="edf")
    r = _req(0)
    assert r.cancel()
    assert q.admit(r, 0.0)              # handled (already terminal)...
    assert len(q) == 0                  # ...but never queued
    _, batch = q.pop_batch(8, 0.0)
    assert batch == []
    # the full bound is still admissible afterwards
    assert q.admit(_req(1), 0.0) and q.admit(_req(2), 0.0)
    assert q.full and len(q) == 2


def test_admission_queue_shed_victim_and_probe():
    """Class-aware shedding: at the bound a higher-priority arrival
    ejects the least-urgent pending entry; a same-class arrival is
    refused (``outranked_by`` answers without building the request)."""
    q = AdmissionQueue(max_pending=2, policy="edf")
    lo0, lo1 = _req(0, priority=0), _req(1, priority=0)
    assert q.admit(lo0, 0.0) and q.admit(lo1, 0.0) and q.full
    # same class: nothing pending sheds below it
    assert not q.outranked_by(_req(2, priority=0), 0.0)
    assert q.shed_victim(_req(2, priority=0), 0.0) is None
    # higher class: the latest same-class arrival is the victim
    hi = _req(3, priority=9)
    assert q.outranked_by(hi, 0.0)
    victim = q.shed_victim(hi, 0.0)
    assert victim is lo1 and victim.status == "shed"
    assert isinstance(victim.error, GatewayBacklog)
    assert q.shed == 1 and len(q) == 1
    assert q.admit(hi, 0.0) and q.full
    # the cached shed ceiling stays correct across the removal: the
    # same-class fast path still refuses, the scan path still sheds
    assert not q.outranked_by(_req(4, priority=0), 0.0)
    assert q.outranked_by(_req(5, priority=10), 0.0)
    _, batch = q.pop_batch(8, 0.0)
    assert [r.request_id for r in batch] == [3, 0]


def test_admission_queue_resize_bound():
    q = AdmissionQueue(max_pending=4, policy="fifo")
    assert all(q.admit(_req(i), 0.0) for i in range(4))
    q.resize(2)                   # shrink below live: nothing evicted
    assert q.max_pending == 2 and len(q) == 4 and q.full
    assert not q.admit(_req(9), 0.0)
    _, batch = q.pop_batch(3, 0.0)
    assert len(batch) == 3
    assert q.admit(_req(4), 0.0) and q.full    # back under the bound
    q.resize(0)
    assert q.max_pending == 1                  # clamped: never zero


if HAVE_HYPOTHESIS:
    _conserve_ops = st.lists(st.tuples(
        st.sampled_from(["admit", "admit_terminal", "cancel", "pop",
                         "evict", "resize", "shed"]),
        st.integers(0, 7),
    ), min_size=1, max_size=80)
else:                                        # pragma: no cover
    _conserve_ops = None


@settings(max_examples=80, deadline=None)
@given(ops_list=_conserve_ops, bound=st.integers(1, 5))
def test_admission_live_count_conservation(ops_list, bound):
    """Property (the terminal-admit leak, generalized): across any
    interleaving of admissions — including already-terminal requests —
    cancellations, batch pops, drain evictions, bound resizes and
    class-aware sheds, the live count always equals the number of
    pending entries in the heap: the admission bound can neither leak
    shut nor over-admit, and a full drain restores the whole bound."""
    q = AdmissionQueue(max_pending=bound, policy="edf")
    n = 0
    hi_bound = bound                  # high-water admission bound seen
    for op, arg in ops_list:
        if op == "admit":
            q.admit(_req(n), 0.0)
            n += 1
        elif op == "admit_terminal":
            r = _req(n)
            n += 1
            assert r.cancel()
            assert q.admit(r, 0.0)      # handled, never queued
        elif op == "cancel":
            pending = [r for _, _, r in q._heap
                       if r.status == "pending"]
            if pending:
                assert pending[arg % len(pending)].cancel()
                q.note_terminal()       # the gateway's terminal hook
        elif op == "pop":
            q.pop_batch(arg + 1, 0.0)
        elif op == "evict":
            for r in q.evict_pending():
                # the gateway drain seam cancels each evicted request;
                # its terminal hook frees the admission slot
                assert r.cancel()
                q.note_terminal()
        elif op == "resize":
            q.resize(arg + 1)
            hi_bound = max(hi_bound, q.max_pending)
        elif op == "shed":
            r = _req(n, priority=arg)
            n += 1
            if not q.admit(r, 0.0):
                v = q.shed_victim(r, 0.0)
                if v is not None:
                    assert v.status == "shed"
                    assert q.admit(r, 0.0)
        live_in_heap = sum(1 for _, _, r in q._heap
                           if r.status == "pending")
        assert len(q) == live_in_heap
        assert 0 <= len(q) <= hi_bound
    q.resize(bound)
    q.pop_batch(10 ** 6, 0.0)
    assert len(q) == 0
    assert all(q.admit(_req(n + i), 0.0) for i in range(bound))
    assert q.full


# ---------------------------------------------------------------------------
# the asyncio gateway end-to-end
# ---------------------------------------------------------------------------

def test_gateway_serves_bit_exact():
    plan = _plan()
    gw = AsyncCNNGateway.from_plan(
        plan, AsyncServeConfig(max_batch=4, max_pending=16))
    compiled = gw.plans["plan0"].compiled
    imgs = _images(compiled, 9)

    async def main():
        async with gw:
            futs = [await gw.submit(img) for img in imgs]
            return await asyncio.gather(*futs)

    outs = asyncio.run(main())
    pcfg = deploy.plan_config(plan)
    for img, out in zip(imgs, outs):
        ref = cnn_forward_ref(compiled.params, jnp.asarray(img), pcfg)
        np.testing.assert_array_equal(out, np.asarray(ref))
    stats = gw.stats()
    assert stats["served"] == 9 and stats["pending"] == 0
    assert sum(k * v for k, v in stats["occupancy_hist"].items()) == 9


def test_gateway_backpressure_and_load_shedding():
    """submit_nowait sheds load at the bound; submit awaits space and
    completes once the drain frees it."""
    plan = _plan()
    gw = AsyncCNNGateway.from_plan(
        plan, AsyncServeConfig(max_batch=2, max_pending=3))
    compiled = gw.plans["plan0"].compiled
    imgs = _images(compiled, 12, seed=3)

    async def main():
        async with gw:
            # stall the drain so the queue actually fills: submit from
            # inside one loop iteration without yielding
            futs, shed = [], 0
            for img in imgs:
                try:
                    futs.append(gw.submit_nowait(img))
                except GatewayBacklog:
                    shed += 1
            assert shed > 0                  # the bound engaged
            assert gw.stats()["pending"] <= 3
            # backpressure path: waits for space instead of raising
            futs.append(await gw.submit(imgs[0]))
            outs = await asyncio.gather(*futs)
            return outs, shed

    outs, shed = asyncio.run(main())
    stats = gw.stats()
    assert stats["rejected"] == shed
    assert stats["served"] == len(outs)
    assert len(outs) == 12 - shed + 1


def test_gateway_expired_requests_fail_not_served_late():
    plan = _plan()
    gw = AsyncCNNGateway.from_plan(
        plan, AsyncServeConfig(max_batch=2, max_pending=32))
    compiled = gw.plans["plan0"].compiled
    imgs = _images(compiled, 3, seed=4)

    async def main():
        async with gw:
            # deadline already in the past on admission
            dead = await gw.submit(imgs[0], deadline=-1.0)
            ok = await gw.submit(imgs[1], deadline=60.0)
            with pytest.raises(DeadlineExpired):
                await dead
            return await ok

    out = asyncio.run(main())
    ref = cnn_forward_ref(compiled.params, jnp.asarray(imgs[1]),
                          deploy.plan_config(plan))
    np.testing.assert_array_equal(out, np.asarray(ref))
    assert gw.stats()["expired"] == 1


def test_gateway_cancellation_releases_bound_and_skips_serve():
    plan = _plan()
    gw = AsyncCNNGateway.from_plan(
        plan, AsyncServeConfig(max_batch=2, max_pending=4))
    compiled = gw.plans["plan0"].compiled
    imgs = _images(compiled, 4, seed=5)

    async def main():
        async with gw:
            futs = [gw.submit_nowait(img) for img in imgs]
            futs[2].cancel()
            done = await asyncio.gather(*futs, return_exceptions=True)
            return done

    done = asyncio.run(main())
    assert isinstance(done[2], asyncio.CancelledError)
    assert [isinstance(d, np.ndarray) for d in done] \
        == [True, True, False, True]
    stats = gw.stats()
    assert stats["cancelled"] == 1 and stats["served"] == 3


def test_gateway_multi_plan_routing_and_shared_cache():
    """Two plans with identical layer specs share every compiled
    executable (the regression the shared ExecutableCache exists for);
    requests route to their plan and both serve bit-exactly."""
    plan = _plan()
    gw = AsyncCNNGateway(AsyncServeConfig(max_batch=4, max_pending=16))
    gw.register_plan(plan, plan_id="a")
    compiles_after_a = gw.exec_cache.compiles
    assert compiles_after_a > 0
    gw.register_plan(plan, plan_id="b", key=jax.random.PRNGKey(7))
    # identical layer specs → zero new executables for plan b
    assert gw.exec_cache.compiles == compiles_after_a
    assert gw.plans["b"].compiled.compiles == 0
    assert gw.plans["b"].compiled.warmed_up

    ca, cb = gw.plans["a"].compiled, gw.plans["b"].compiled
    imgs = _images(ca, 6, seed=6)

    async def main():
        async with gw:
            fa = [await gw.submit(img, plan_id="a") for img in imgs[:3]]
            fb = [await gw.submit(img, plan_id="b") for img in imgs[3:]]
            return (await asyncio.gather(*fa), await asyncio.gather(*fb))

    outs_a, outs_b = asyncio.run(main())
    pcfg = deploy.plan_config(plan)
    for img, out in zip(imgs[:3], outs_a):
        np.testing.assert_array_equal(out, np.asarray(
            cnn_forward_ref(ca.params, jnp.asarray(img), pcfg)))
    for img, out in zip(imgs[3:], outs_b):
        np.testing.assert_array_equal(out, np.asarray(
            cnn_forward_ref(cb.params, jnp.asarray(img), pcfg)))
    stats = gw.stats()
    assert stats["plans"] == {"a": 3, "b": 3}


def test_gateway_failed_dispatch_fails_futures_instead_of_hanging():
    """Regression: a dispatch error other than DispatchAborted must
    propagate into every affected future — stranding them pending would
    hang clients forever."""
    plan = _plan()
    gw = AsyncCNNGateway.from_plan(
        plan, AsyncServeConfig(max_batch=2, max_pending=4))
    compiled = gw.plans["plan0"].compiled
    imgs = _images(compiled, 2)

    class _Exploding:
        def __init__(self, inner):
            self._inner = inner

        def __getattr__(self, name):
            return getattr(self._inner, name)

        def __call__(self, *a, **k):
            raise RuntimeError("device exploded")

    gw.plans["plan0"].compiled = _Exploding(compiled)

    async def main():
        async with gw:
            futs = [await gw.submit(img) for img in imgs]
            return await asyncio.gather(*futs, return_exceptions=True)

    done = asyncio.run(main())
    assert all(isinstance(d, RuntimeError)
               and "device exploded" in str(d) for d in done)
    assert gw.stats()["served"] == 0 and gw.stats()["pending"] == 0


def test_gateway_has_no_sync_drain():
    """The gateway reuses SlotPool bookkeeping but not its sync serving
    interface — run()/step() fail loudly instead of mis-admitting."""
    plan = _plan()
    gw = AsyncCNNGateway.from_plan(
        plan, AsyncServeConfig(max_batch=2, max_pending=4))
    with pytest.raises(TypeError, match="no sync drain"):
        gw.run([])
    with pytest.raises(TypeError, match="continuously"):
        gw.step()


def test_gateway_validates_images_at_the_door():
    plan = _plan()
    gw = AsyncCNNGateway.from_plan(
        plan, AsyncServeConfig(max_batch=2, max_pending=4))

    async def main():
        async with gw:
            with pytest.raises(ValueError, match="image shape"):
                gw.submit_nowait(np.zeros((3, 3, 1), np.int8))
            with pytest.raises(ValueError, match="non-integral"):
                gw.submit_nowait(np.full(
                    gw.plans["plan0"].compiled.in_shape, 0.5, np.float32))
            with pytest.raises(ValueError, match="unknown plan id"):
                gw.submit_nowait(np.zeros((3, 3, 1), np.int8),
                                 plan_id="nope")

    asyncio.run(main())
    assert gw.stats()["served"] == 0


def test_gateway_policy_matches_sync_engine_ordering():
    """The gateway and the sync drain schedule identically: same policy
    object, same keys, same realized order."""
    pol = get_policy("edf")
    reqs = [_req(0, deadline=9.0), _req(1, deadline=3.0),
            _req(2), _req(3, priority=2)]
    q = AdmissionQueue(max_pending=8, policy=pol)
    for r in reqs:
        q.admit(r, 0.0)
    _, batch = q.pop_batch(8, 0.0)
    assert [r.request_id for r in batch] \
        == [r.request_id for r in pol.order(reqs, 0.0)]


# ---------------------------------------------------------------------------
# gateway lifecycle regressions + adaptive admission end-to-end
# ---------------------------------------------------------------------------

def test_gateway_cancel_under_backpressure_recovers_full_bound():
    """Regression, hammered: repeatedly fill the admission bound,
    cancel every queued future, refill.  Each cancellation must free
    exactly one slot of the bound — a leak shows up as the bound
    shrinking round over round until nothing is admissible."""
    plan = _plan()
    gw = AsyncCNNGateway.from_plan(
        plan, AsyncServeConfig(max_batch=2, max_pending=4))
    compiled = gw.plans["plan0"].compiled
    imgs = _images(compiled, 4, seed=13)

    async def main():
        async with gw:
            for _ in range(5):
                # fill the bound without yielding to the drain task
                futs = [gw.submit_nowait(img) for img in imgs]
                with pytest.raises(GatewayBacklog):
                    gw.submit_nowait(imgs[0])
                for f in futs:
                    f.cancel()
                await asyncio.gather(*futs, return_exceptions=True)
                assert len(gw.queue) == 0
            # the whole bound is still admissible after the hammering
            futs = [gw.submit_nowait(img) for img in imgs]
            return await asyncio.gather(*futs)

    outs = asyncio.run(main())
    assert all(isinstance(o, np.ndarray) for o in outs)
    stats = gw.stats()
    assert stats["cancelled"] == 20 and stats["served"] == 4
    assert stats["pending"] == 0


def test_gateway_close_resolves_backpressured_submitters():
    """Regression: submitters parked at the admission bound when the
    gateway closes must all resolve — a waiter woken by ``close()``
    that re-tried admission first could slip into the queue after the
    drain task had already exited and pend forever."""
    plan = _plan()
    gw = AsyncCNNGateway.from_plan(
        plan, AsyncServeConfig(max_batch=2, max_pending=2))
    compiled = gw.plans["plan0"].compiled
    imgs = _images(compiled, 8, seed=14)

    async def main():
        async with gw:
            queued = [gw.submit_nowait(img) for img in imgs[:2]]
            waiters = [asyncio.ensure_future(gw.submit(img))
                       for img in imgs[2:]]
            await asyncio.sleep(0)      # park them at the bound
        # __aexit__ → close(): every waiter must resolve promptly —
        # either admitted-and-served before the drain exited, or
        # failed with "gateway is closing"; none may hang
        futs = await asyncio.wait_for(asyncio.gather(*waiters), 10.0)
        return await asyncio.wait_for(
            asyncio.gather(*queued, *futs, return_exceptions=True),
            10.0)

    outs = asyncio.run(main())
    assert all(isinstance(o, (np.ndarray, RuntimeError)) for o in outs)
    failed = [o for o in outs if isinstance(o, RuntimeError)]
    assert sum(isinstance(o, np.ndarray) for o in outs) \
        + len(failed) == 8
    assert all("closing" in str(e) for e in failed)
    assert gw.stats()["pending"] == 0


def test_gateway_class_aware_shedding_at_the_bound():
    """At the bound a higher-class arrival ejects the least-urgent
    pending request instead of being refused: the victim's future
    raises ``GatewayBacklog``, the arrival is served, and a same-class
    arrival is still the one refused."""
    plan = _plan()
    gw = AsyncCNNGateway.from_plan(
        plan, AsyncServeConfig(max_batch=2, max_pending=2,
                               policy="edf"))
    compiled = gw.plans["plan0"].compiled
    imgs = _images(compiled, 4, seed=15)

    async def main():
        async with gw:
            lo = [gw.submit_nowait(img, priority=0)
                  for img in imgs[:2]]
            hi = gw.submit_nowait(imgs[2], priority=5)
            with pytest.raises(GatewayBacklog):
                gw.submit_nowait(imgs[3], priority=0)
            return await asyncio.gather(*lo, hi,
                                        return_exceptions=True)

    done = asyncio.run(main())
    shed = [d for d in done[:2] if isinstance(d, GatewayBacklog)]
    assert len(shed) == 1                  # exactly one victim
    assert isinstance(done[2], np.ndarray)  # the high-class arrival
    assert sum(isinstance(d, np.ndarray) for d in done) == 2
    stats = gw.stats()
    assert stats["shed"] == 1 and stats["rejected"] == 1
    assert stats["served"] == 2


def test_gateway_submit_chunk_partial_admission():
    plan = _plan()
    gw = AsyncCNNGateway.from_plan(
        plan, AsyncServeConfig(max_batch=2, max_pending=3))
    compiled = gw.plans["plan0"].compiled
    imgs = _images(compiled, 5, seed=16)

    async def main():
        async with gw:
            futs, refused = gw.submit_chunk(imgs)  # no yields: bound=3
            assert len(futs) == 3 and refused == 2
            outs = await asyncio.gather(*futs)
            # with the queue drained the whole chunk fits
            futs2, refused2 = gw.submit_chunk(imgs[:2])
            assert refused2 == 0
            return outs, await asyncio.gather(*futs2)

    outs, outs2 = asyncio.run(main())
    assert len(outs) == 3 and len(outs2) == 2
    assert gw.stats()["rejected"] == 1     # chunk stops at the refusal


def test_slot_pool_rate_estimator_busy_runs_and_idle_gaps():
    from repro.serve.slots import SlotPool

    t = [0.0]
    pool = SlotPool(max_batch=8, clock=lambda: t[0])
    assert pool.service_rate == 0.0 and pool.service_rate_slow == 0.0
    # a full batch launched at t=0 completing at t=0.1 → 80 img/s
    t[0] = 0.1
    pool._note_step(8, launched_at=0.0)
    assert pool.service_rate == pytest.approx(80.0)
    assert pool.service_rate_slow == pytest.approx(80.0)
    # a long idle gap, then a fresh run at the same speed: idle time
    # must not dilute the estimate (a lull is not slowness)
    t[0] = 100.1
    pool._note_step(8, launched_at=100.0)
    assert pool.service_rate == pytest.approx(80.0)
    # sustained faster service: the fast horizon converges within the
    # sliding window; the slow horizon (capacity commitments) lags
    for _ in range(6):
        t0 = t[0]
        t[0] += 0.01                   # 8 images / 10 ms = 800 img/s
        pool._note_step(8, launched_at=t0)
    assert pool.service_rate > 400.0
    assert pool.service_rate_slow < pool.service_rate
    # est_wait derives from the fast rate in the same snapshot
    snap = pool.snapshot(queue_depth=40)
    assert snap.service_rate == pool.service_rate
    assert snap.est_wait == pytest.approx(40 / pool.service_rate)


def test_gateway_adaptive_bound_tracks_measured_rate():
    t = [0.0]
    gw = AsyncCNNGateway(
        AsyncServeConfig(max_batch=4, max_pending=64, min_pending=6,
                         wait_budget_s=0.5),
        clock=lambda: t[0])
    # no rate measured yet: the bound floors at min_pending
    gw._adapt_bound(force=True)
    assert gw.queue.max_pending == 6
    # measured 40 img/s → bound = ceil(40 × 0.5) = 20
    t[0] = 0.1
    gw._note_step(4, launched_at=0.0)
    gw._adapt_bound(force=True)
    assert gw.queue.max_pending == 20
    # a *sustained* faster rate grows it, capped at max_pending
    for _ in range(200):
        t0 = t[0]
        t[0] += 0.001                  # 4000 img/s, far past the cap
        gw._note_step(4, launched_at=t0)
    gw._adapt_bound(force=True)
    assert gw.queue.max_pending == 64
    # without a wait budget the bound is static
    gw2 = AsyncCNNGateway(AsyncServeConfig(max_batch=4, max_pending=7))
    gw2._adapt_bound(force=True)
    assert gw2.queue.max_pending == 7


def test_async_serve_config_validation_and_pool_sizing():
    with pytest.raises(ValueError, match="max_inflight"):
        AsyncCNNGateway(AsyncServeConfig(max_batch=2, max_inflight=0))
    with pytest.raises(ValueError, match="wait_budget_s"):
        AsyncCNNGateway(AsyncServeConfig(max_batch=2,
                                         wait_budget_s=0.0))
    with pytest.raises(ValueError, match="min_pending"):
        AsyncCNNGateway(AsyncServeConfig(max_batch=2, min_pending=0))
    with pytest.raises(ValueError, match="batch_linger"):
        AsyncCNNGateway(AsyncServeConfig(max_batch=2,
                                         batch_linger=-0.1))
    # the slot pool is max_inflight dispatch-widths wide so the next
    # batch can stage (and prep) while one is on-device
    gw = AsyncCNNGateway(AsyncServeConfig(max_batch=4, max_inflight=2))
    assert gw.free_slots() == 8 and gw.cfg.max_batch == 4


# ---------------------------------------------------------------------------
# runtime: shared cache + cancellation-safe dispatch
# ---------------------------------------------------------------------------

def test_compiled_cnn_shares_executables_across_instances():
    cfg = _cfg()
    params = init_cnn(jax.random.PRNGKey(0), cfg)
    blocks = [s.block for s in cfg.layers]
    cache = ExecutableCache()
    a = CompiledCNN(cfg, params, blocks, max_batch=4, exec_cache=cache)
    n = cache.compiles
    assert n == len(cache) == len(a.buckets) * len(cfg.layers)
    b = CompiledCNN(cfg, params, blocks, max_batch=4, exec_cache=cache)
    assert cache.compiles == n and b.compiles == 0   # all cache hits
    assert b.warmed_up
    x = np.stack(_images(a, 3, seed=8))
    np.testing.assert_array_equal(np.asarray(a(x)), np.asarray(b(x)))


def test_compiled_cnn_dispatch_abort():
    cfg = _cfg()
    params = init_cnn(jax.random.PRNGKey(0), cfg)
    cnn = CompiledCNN(cfg, params, [s.block for s in cfg.layers],
                      max_batch=2)
    x = np.stack(_images(cnn, 1, seed=9))
    with pytest.raises(DispatchAborted):
        cnn(x, should_abort=lambda: True)
    # a non-firing hook changes nothing
    y = cnn(x, should_abort=lambda: False)
    ref = cnn_forward_ref(params, jnp.asarray(x), cfg)
    np.testing.assert_array_equal(np.asarray(y), np.asarray(ref))


# ---------------------------------------------------------------------------
# the GatewayStats snapshot seam (shared by SlotPool and the gateway)
# ---------------------------------------------------------------------------

def test_slot_pool_and_gateway_share_the_snapshot_seam():
    """`GatewayStats` is the one stats capture both serving layers (and
    the fleet's health heartbeats) read: the raw SlotPool emits it, the
    gateway's override layers its terminal counters on, and stats() is
    derived from one snapshot rather than assembled field-by-field."""
    from repro.serve import GatewayStats
    from repro.serve.slots import SlotPool

    pool = SlotPool(max_batch=3)
    snap = pool.snapshot(clock=lambda: 12.5)
    assert isinstance(snap, GatewayStats)
    assert snap.timestamp == 12.5
    assert snap.queue_depth == 0 and snap.inflight == 0
    assert snap.depth == 0 and snap.max_batch == 3
    assert pool.stats()["occupancy_hist"] == {}

    plan = _plan()
    gw = AsyncCNNGateway.from_plan(
        plan, AsyncServeConfig(max_batch=2, max_pending=8))
    gsnap = gw.snapshot()
    assert isinstance(gsnap, GatewayStats)
    assert gsnap.max_batch == 2 and gsnap.depth == 0
    d = gsnap.asdict()
    for key in ("timestamp", "queue_depth", "inflight", "max_batch",
                "steps", "occupancy_hist", "served", "rejected",
                "expired", "cancelled", "failed"):
        assert key in d, key
    # the flattened stats() carries the same terminal counters
    stats = gw.stats()
    assert stats["served"] == 0 and stats["failed"] == 0
    assert stats["inflight"] == 0


def test_gateway_snapshot_tracks_queue_and_terminals():
    plan = _plan()
    gw = AsyncCNNGateway.from_plan(
        plan, AsyncServeConfig(max_batch=2, max_pending=8))
    compiled = gw.plans["plan0"].compiled
    imgs = _images(compiled, 5, seed=11)

    async def main():
        async with gw:
            futs = [gw.submit_nowait(img) for img in imgs]
            # before yielding to dispatch, all five sit in the queue
            pre = gw.snapshot()
            assert pre.queue_depth == 5 and pre.depth == 5
            outs = await asyncio.gather(*futs)
            return pre, outs

    pre, outs = asyncio.run(main())
    post = gw.snapshot()
    assert post.queue_depth == 0 and post.inflight == 0
    assert post.served == len(outs) == 5
    assert post.steps >= 3            # max_batch=2 → ≥ ceil(5/2) steps
    assert sum(k * v for k, v in post.occupancy_hist.items()) == 5
