"""Async continuous-batching gateway: admission bound and deadline
invariants (property-tested on the synchronous scheduling core),
end-to-end bit-exactness, backpressure, cancellation, multi-plan
routing, and cross-plan executable sharing."""

import asyncio

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from hypothesis_compat import HAVE_HYPOTHESIS, given, settings, st

from repro.core import deploy
from repro.core.cnn import (CNNConfig, ConvLayerSpec, cnn_forward_ref,
                            fitted_block_models, init_cnn)
from repro.runtime import CompiledCNN, DispatchAborted, ExecutableCache
from repro.serve import (AdmissionQueue, AsyncCNNGateway, AsyncRequest,
                         AsyncServeConfig, DeadlineExpired, GatewayBacklog,
                         get_policy)


def _cfg():
    return CNNConfig(layers=(
        ConvLayerSpec(1, 4, data_bits=8, coeff_bits=6, block="conv4"),
        ConvLayerSpec(4, 3, data_bits=6, coeff_bits=4, block="conv3"),
    ), img_h=16, img_w=64)


def _plan(cfg=None):
    cfg = cfg if cfg is not None else _cfg()
    return deploy.plan_deployment(cfg, fitted_block_models(), target=0.8,
                                  on_infeasible="fallback")


def _images(compiled, k, seed=0):
    return compiled.sample_images(k, seed)


def _req(i, *, plan_id="p", priority=0, deadline=None, now=0.0):
    return AsyncRequest(image=np.zeros(1), plan_id=plan_id, request_id=i,
                        priority=priority, deadline=deadline,
                        arrived_at=now)


# ---------------------------------------------------------------------------
# the synchronous scheduling core (no event loop)
# ---------------------------------------------------------------------------

def test_admission_queue_bound_and_rejection():
    q = AdmissionQueue(max_pending=3, policy="edf")
    assert all(q.admit(_req(i), 0.0) for i in range(3))
    assert q.full and len(q) == 3
    assert not q.admit(_req(3), 0.0)        # at the bound: refused
    _, batch = q.pop_batch(2, 0.0)
    assert [r.request_id for r in batch] == [0, 1]
    assert len(q) == 1 and not q.full
    assert q.admit(_req(4), 0.0)


def test_admission_queue_expires_instead_of_serving_late():
    q = AdmissionQueue(max_pending=8, policy="edf")
    on_time = _req(0, deadline=10.0)
    late = _req(1, deadline=2.0)
    assert q.admit(on_time, 0.0) and q.admit(late, 0.0)
    _, batch = q.pop_batch(8, now=5.0)      # past late's deadline
    assert [r.request_id for r in batch] == [0]
    assert late.status == "expired"
    assert isinstance(late.error, DeadlineExpired)
    assert q.expired == 1
    # already-expired on admission: terminal immediately, never queued
    dead = _req(2, deadline=1.0)
    assert q.admit(dead, now=5.0)           # handled, not refused
    assert dead.status == "expired" and len(q) == 0


def test_admission_queue_edf_order_and_priority_tiers():
    q = AdmissionQueue(max_pending=8, policy="edf")
    q.admit(_req(0, deadline=9.0), 0.0)
    q.admit(_req(1, deadline=3.0), 0.0)
    q.admit(_req(2), 0.0)                   # no deadline: last in tier
    q.admit(_req(3, deadline=99.0, priority=1), 0.0)   # higher tier
    _, batch = q.pop_batch(8, 0.0)
    assert [r.request_id for r in batch] == [3, 1, 0, 2]


def test_admission_queue_single_plan_batches_hold_others_back():
    q = AdmissionQueue(max_pending=8, policy="fifo")
    q.admit(_req(0, plan_id="a"), 0.0)
    q.admit(_req(1, plan_id="b"), 0.0)
    q.admit(_req(2, plan_id="a"), 0.0)
    pid, batch = q.pop_batch(8, 0.0)
    assert pid == "a" and [r.request_id for r in batch] == [0, 2]
    # plan b's request kept its place and forms the next batch
    pid, batch = q.pop_batch(8, 0.0)
    assert pid == "b" and [r.request_id for r in batch] == [1]
    assert len(q) == 0


def test_admission_queue_cancelled_entries_never_pop():
    q = AdmissionQueue(max_pending=4, policy="fifo")
    reqs = [_req(i) for i in range(3)]
    for r in reqs:
        q.admit(r, 0.0)
    assert reqs[1].cancel()
    q.note_terminal()                       # the gateway's cancel hook
    assert len(q) == 2
    _, batch = q.pop_batch(8, 0.0)
    assert [r.request_id for r in batch] == [0, 2]


if HAVE_HYPOTHESIS:
    _ops = st.lists(st.tuples(
        st.sampled_from(["submit", "pop", "tick", "cancel"]),
        st.integers(0, 7),                  # pop width / cancel index
        st.one_of(st.none(), st.floats(0.0, 4.0)),   # relative deadline
    ), min_size=1, max_size=60)
else:                                        # pragma: no cover
    _ops = None


@settings(max_examples=60, deadline=None)
@given(ops_list=_ops, bound=st.integers(1, 6))
def test_admission_bound_and_deadline_invariants(ops_list, bound):
    """Property: under any interleaving of submits, pops, clock ticks
    and cancels, (a) the live pending count never exceeds the bound,
    (b) a popped batch never contains an expired or cancelled request,
    and (c) every request ends served-able, expired, cancelled, or
    refused — never silently late."""
    q = AdmissionQueue(max_pending=bound, policy="edf")
    now = 0.0
    submitted, popped, refused = [], [], []
    for op, arg, dl in ops_list:
        if op == "submit":
            r = _req(len(submitted),
                     deadline=None if dl is None else now + dl, now=now)
            if q.admit(r, now):
                if r.status == "pending":
                    submitted.append(r)
            else:
                refused.append(r)
            assert len(q) <= bound
        elif op == "pop":
            _, batch = q.pop_batch(arg + 1, now)
            for r in batch:
                assert r.status == "pending"
                assert r.deadline is None or r.deadline >= now
                popped.append(r)
            assert len(q) <= bound
        elif op == "tick":
            now += 0.5 + (0.0 if dl is None else dl)
        elif op == "cancel":
            pending = [r for r in submitted
                       if r.status == "pending" and r not in popped]
            if pending:
                r = pending[arg % len(pending)]
                assert r.cancel()
                q.note_terminal()
        assert 0 <= len(q) <= bound
    # drain: nothing left behind in a non-terminal, non-poppable state
    _, batch = q.pop_batch(10 ** 6, now)
    popped.extend(batch)
    assert len(q) == 0
    for r in submitted:
        assert (r in popped and r.status == "pending") \
            or r.status in ("expired", "cancelled")
    for r in refused:
        assert r.status == "pending" and r not in popped


# ---------------------------------------------------------------------------
# the asyncio gateway end-to-end
# ---------------------------------------------------------------------------

def test_gateway_serves_bit_exact():
    plan = _plan()
    gw = AsyncCNNGateway.from_plan(
        plan, AsyncServeConfig(max_batch=4, max_pending=16))
    compiled = gw.plans["plan0"].compiled
    imgs = _images(compiled, 9)

    async def main():
        async with gw:
            futs = [await gw.submit(img) for img in imgs]
            return await asyncio.gather(*futs)

    outs = asyncio.run(main())
    pcfg = deploy.plan_config(plan)
    for img, out in zip(imgs, outs):
        ref = cnn_forward_ref(compiled.params, jnp.asarray(img), pcfg)
        np.testing.assert_array_equal(out, np.asarray(ref))
    stats = gw.stats()
    assert stats["served"] == 9 and stats["pending"] == 0
    assert sum(k * v for k, v in stats["occupancy_hist"].items()) == 9


def test_gateway_backpressure_and_load_shedding():
    """submit_nowait sheds load at the bound; submit awaits space and
    completes once the drain frees it."""
    plan = _plan()
    gw = AsyncCNNGateway.from_plan(
        plan, AsyncServeConfig(max_batch=2, max_pending=3))
    compiled = gw.plans["plan0"].compiled
    imgs = _images(compiled, 12, seed=3)

    async def main():
        async with gw:
            # stall the drain so the queue actually fills: submit from
            # inside one loop iteration without yielding
            futs, shed = [], 0
            for img in imgs:
                try:
                    futs.append(gw.submit_nowait(img))
                except GatewayBacklog:
                    shed += 1
            assert shed > 0                  # the bound engaged
            assert gw.stats()["pending"] <= 3
            # backpressure path: waits for space instead of raising
            futs.append(await gw.submit(imgs[0]))
            outs = await asyncio.gather(*futs)
            return outs, shed

    outs, shed = asyncio.run(main())
    stats = gw.stats()
    assert stats["rejected"] == shed
    assert stats["served"] == len(outs)
    assert len(outs) == 12 - shed + 1


def test_gateway_expired_requests_fail_not_served_late():
    plan = _plan()
    gw = AsyncCNNGateway.from_plan(
        plan, AsyncServeConfig(max_batch=2, max_pending=32))
    compiled = gw.plans["plan0"].compiled
    imgs = _images(compiled, 3, seed=4)

    async def main():
        async with gw:
            # deadline already in the past on admission
            dead = await gw.submit(imgs[0], deadline=-1.0)
            ok = await gw.submit(imgs[1], deadline=60.0)
            with pytest.raises(DeadlineExpired):
                await dead
            return await ok

    out = asyncio.run(main())
    ref = cnn_forward_ref(compiled.params, jnp.asarray(imgs[1]),
                          deploy.plan_config(plan))
    np.testing.assert_array_equal(out, np.asarray(ref))
    assert gw.stats()["expired"] == 1


def test_gateway_cancellation_releases_bound_and_skips_serve():
    plan = _plan()
    gw = AsyncCNNGateway.from_plan(
        plan, AsyncServeConfig(max_batch=2, max_pending=4))
    compiled = gw.plans["plan0"].compiled
    imgs = _images(compiled, 4, seed=5)

    async def main():
        async with gw:
            futs = [gw.submit_nowait(img) for img in imgs]
            futs[2].cancel()
            done = await asyncio.gather(*futs, return_exceptions=True)
            return done

    done = asyncio.run(main())
    assert isinstance(done[2], asyncio.CancelledError)
    assert [isinstance(d, np.ndarray) for d in done] \
        == [True, True, False, True]
    stats = gw.stats()
    assert stats["cancelled"] == 1 and stats["served"] == 3


def test_gateway_multi_plan_routing_and_shared_cache():
    """Two plans with identical layer specs share every compiled
    executable (the regression the shared ExecutableCache exists for);
    requests route to their plan and both serve bit-exactly."""
    plan = _plan()
    gw = AsyncCNNGateway(AsyncServeConfig(max_batch=4, max_pending=16))
    gw.register_plan(plan, plan_id="a")
    compiles_after_a = gw.exec_cache.compiles
    assert compiles_after_a > 0
    gw.register_plan(plan, plan_id="b", key=jax.random.PRNGKey(7))
    # identical layer specs → zero new executables for plan b
    assert gw.exec_cache.compiles == compiles_after_a
    assert gw.plans["b"].compiled.compiles == 0
    assert gw.plans["b"].compiled.warmed_up

    ca, cb = gw.plans["a"].compiled, gw.plans["b"].compiled
    imgs = _images(ca, 6, seed=6)

    async def main():
        async with gw:
            fa = [await gw.submit(img, plan_id="a") for img in imgs[:3]]
            fb = [await gw.submit(img, plan_id="b") for img in imgs[3:]]
            return (await asyncio.gather(*fa), await asyncio.gather(*fb))

    outs_a, outs_b = asyncio.run(main())
    pcfg = deploy.plan_config(plan)
    for img, out in zip(imgs[:3], outs_a):
        np.testing.assert_array_equal(out, np.asarray(
            cnn_forward_ref(ca.params, jnp.asarray(img), pcfg)))
    for img, out in zip(imgs[3:], outs_b):
        np.testing.assert_array_equal(out, np.asarray(
            cnn_forward_ref(cb.params, jnp.asarray(img), pcfg)))
    stats = gw.stats()
    assert stats["plans"] == {"a": 3, "b": 3}


def test_gateway_failed_dispatch_fails_futures_instead_of_hanging():
    """Regression: a dispatch error other than DispatchAborted must
    propagate into every affected future — stranding them pending would
    hang clients forever."""
    plan = _plan()
    gw = AsyncCNNGateway.from_plan(
        plan, AsyncServeConfig(max_batch=2, max_pending=4))
    compiled = gw.plans["plan0"].compiled
    imgs = _images(compiled, 2)

    class _Exploding:
        def __init__(self, inner):
            self._inner = inner

        def __getattr__(self, name):
            return getattr(self._inner, name)

        def __call__(self, *a, **k):
            raise RuntimeError("device exploded")

    gw.plans["plan0"].compiled = _Exploding(compiled)

    async def main():
        async with gw:
            futs = [await gw.submit(img) for img in imgs]
            return await asyncio.gather(*futs, return_exceptions=True)

    done = asyncio.run(main())
    assert all(isinstance(d, RuntimeError)
               and "device exploded" in str(d) for d in done)
    assert gw.stats()["served"] == 0 and gw.stats()["pending"] == 0


def test_gateway_has_no_sync_drain():
    """The gateway reuses SlotPool bookkeeping but not its sync serving
    interface — run()/step() fail loudly instead of mis-admitting."""
    plan = _plan()
    gw = AsyncCNNGateway.from_plan(
        plan, AsyncServeConfig(max_batch=2, max_pending=4))
    with pytest.raises(TypeError, match="no sync drain"):
        gw.run([])
    with pytest.raises(TypeError, match="continuously"):
        gw.step()


def test_gateway_validates_images_at_the_door():
    plan = _plan()
    gw = AsyncCNNGateway.from_plan(
        plan, AsyncServeConfig(max_batch=2, max_pending=4))

    async def main():
        async with gw:
            with pytest.raises(ValueError, match="image shape"):
                gw.submit_nowait(np.zeros((3, 3, 1), np.int8))
            with pytest.raises(ValueError, match="non-integral"):
                gw.submit_nowait(np.full(
                    gw.plans["plan0"].compiled.in_shape, 0.5, np.float32))
            with pytest.raises(ValueError, match="unknown plan id"):
                gw.submit_nowait(np.zeros((3, 3, 1), np.int8),
                                 plan_id="nope")

    asyncio.run(main())
    assert gw.stats()["served"] == 0


def test_gateway_policy_matches_sync_engine_ordering():
    """The gateway and the sync drain schedule identically: same policy
    object, same keys, same realized order."""
    pol = get_policy("edf")
    reqs = [_req(0, deadline=9.0), _req(1, deadline=3.0),
            _req(2), _req(3, priority=2)]
    q = AdmissionQueue(max_pending=8, policy=pol)
    for r in reqs:
        q.admit(r, 0.0)
    _, batch = q.pop_batch(8, 0.0)
    assert [r.request_id for r in batch] \
        == [r.request_id for r in pol.order(reqs, 0.0)]


# ---------------------------------------------------------------------------
# runtime: shared cache + cancellation-safe dispatch
# ---------------------------------------------------------------------------

def test_compiled_cnn_shares_executables_across_instances():
    cfg = _cfg()
    params = init_cnn(jax.random.PRNGKey(0), cfg)
    blocks = [s.block for s in cfg.layers]
    cache = ExecutableCache()
    a = CompiledCNN(cfg, params, blocks, max_batch=4, exec_cache=cache)
    n = cache.compiles
    assert n == len(cache) == len(a.buckets) * len(cfg.layers)
    b = CompiledCNN(cfg, params, blocks, max_batch=4, exec_cache=cache)
    assert cache.compiles == n and b.compiles == 0   # all cache hits
    assert b.warmed_up
    x = np.stack(_images(a, 3, seed=8))
    np.testing.assert_array_equal(np.asarray(a(x)), np.asarray(b(x)))


def test_compiled_cnn_dispatch_abort():
    cfg = _cfg()
    params = init_cnn(jax.random.PRNGKey(0), cfg)
    cnn = CompiledCNN(cfg, params, [s.block for s in cfg.layers],
                      max_batch=2)
    x = np.stack(_images(cnn, 1, seed=9))
    with pytest.raises(DispatchAborted):
        cnn(x, should_abort=lambda: True)
    # a non-firing hook changes nothing
    y = cnn(x, should_abort=lambda: False)
    ref = cnn_forward_ref(params, jnp.asarray(x), cfg)
    np.testing.assert_array_equal(np.asarray(y), np.asarray(ref))


# ---------------------------------------------------------------------------
# the GatewayStats snapshot seam (shared by SlotPool and the gateway)
# ---------------------------------------------------------------------------

def test_slot_pool_and_gateway_share_the_snapshot_seam():
    """`GatewayStats` is the one stats capture both serving layers (and
    the fleet's health heartbeats) read: the raw SlotPool emits it, the
    gateway's override layers its terminal counters on, and stats() is
    derived from one snapshot rather than assembled field-by-field."""
    from repro.serve import GatewayStats
    from repro.serve.slots import SlotPool

    pool = SlotPool(max_batch=3)
    snap = pool.snapshot(clock=lambda: 12.5)
    assert isinstance(snap, GatewayStats)
    assert snap.timestamp == 12.5
    assert snap.queue_depth == 0 and snap.inflight == 0
    assert snap.depth == 0 and snap.max_batch == 3
    assert pool.stats()["occupancy_hist"] == {}

    plan = _plan()
    gw = AsyncCNNGateway.from_plan(
        plan, AsyncServeConfig(max_batch=2, max_pending=8))
    gsnap = gw.snapshot()
    assert isinstance(gsnap, GatewayStats)
    assert gsnap.max_batch == 2 and gsnap.depth == 0
    d = gsnap.asdict()
    for key in ("timestamp", "queue_depth", "inflight", "max_batch",
                "steps", "occupancy_hist", "served", "rejected",
                "expired", "cancelled", "failed"):
        assert key in d, key
    # the flattened stats() carries the same terminal counters
    stats = gw.stats()
    assert stats["served"] == 0 and stats["failed"] == 0
    assert stats["inflight"] == 0


def test_gateway_snapshot_tracks_queue_and_terminals():
    plan = _plan()
    gw = AsyncCNNGateway.from_plan(
        plan, AsyncServeConfig(max_batch=2, max_pending=8))
    compiled = gw.plans["plan0"].compiled
    imgs = _images(compiled, 5, seed=11)

    async def main():
        async with gw:
            futs = [gw.submit_nowait(img) for img in imgs]
            # before yielding to dispatch, all five sit in the queue
            pre = gw.snapshot()
            assert pre.queue_depth == 5 and pre.depth == 5
            outs = await asyncio.gather(*futs)
            return pre, outs

    pre, outs = asyncio.run(main())
    post = gw.snapshot()
    assert post.queue_depth == 0 and post.inflight == 0
    assert post.served == len(outs) == 5
    assert post.steps >= 3            # max_batch=2 → ≥ ceil(5/2) steps
    assert sum(k * v for k, v in post.occupancy_hist.items()) == 5
