"""Fleet front door: routing policies (unit + property), the worker
health state machine, the ``device_profile`` catalog lookup, and the
live asyncio ``Fleet`` end-to-end — bit-exact multi-worker serving,
saturation/no-worker errors, failure retry with ejection + probe
re-admission, and graceful draining that loses nothing."""

import asyncio

import jax.numpy as jnp
import numpy as np
import pytest

from hypothesis_compat import HAVE_HYPOTHESIS, given, settings, st

from repro.core import deploy
from repro.core.cnn import (CNNConfig, ConvLayerSpec, cnn_forward_ref,
                            fitted_block_models)
from repro.core.deploy import DeploymentError, device_profile
from repro.fleet import (TIERS, Fleet, FleetError, FleetSaturated,
                         FleetWorker, HealthPolicy, NoWorkerAvailable,
                         WorkerHealth, WorkerView, get_router, list_routers)
from repro.runtime import CompiledCNN
from repro.serve import AsyncCNNGateway, AsyncServeConfig


def _cfg():
    return CNNConfig(layers=(
        ConvLayerSpec(1, 4, data_bits=8, coeff_bits=6, block="conv4"),
        ConvLayerSpec(4, 3, data_bits=6, coeff_bits=4, block="conv3"),
    ), img_h=16, img_w=64)


@pytest.fixture(scope="module")
def compiled_plan():
    """One plan + warmed CompiledCNN shared by every live-fleet test
    (registering a pre-compiled plan into a gateway is free)."""
    plan = deploy.plan_deployment(_cfg(), fitted_block_models(),
                                  target=0.8, on_infeasible="fallback")
    return plan, CompiledCNN.from_plan(plan, max_batch=4)


def _gateway(compiled_plan, *, max_pending=16):
    plan, compiled = compiled_plan
    gw = AsyncCNNGateway(AsyncServeConfig(max_batch=4,
                                          max_pending=max_pending))
    gw.register_plan(plan, plan_id="cnn", compiled=compiled)
    return gw


def _ref_outputs(compiled_plan, imgs):
    plan, compiled = compiled_plan
    pcfg = deploy.plan_config(plan)
    return [np.asarray(cnn_forward_ref(compiled.params, jnp.asarray(i),
                                       pcfg)) for i in imgs]


# ---------------------------------------------------------------------------
# routers on synthetic views (no event loop, no gateways)
# ---------------------------------------------------------------------------

def _view(wid, *, cost=1.0, plans=("cnn",), depth=0, inflight=0,
          rate=10.0, healthy=True, draining=False):
    return WorkerView(wid, cost=cost, plan_ids=plans, rate=rate,
                      queue_depth=depth, inflight=inflight,
                      healthy=healthy, draining=draining)


def test_round_robin_rotates_over_admissible_only():
    r = get_router("round_robin")
    views = [_view("a"), _view("b", draining=True), _view("c"),
             _view("d", plans=("other",))]
    picks = [r.select("cnn", "batch", views, 0.0).worker_id
             for _ in range(4)]
    assert picks == ["a", "c", "a", "c"]


def test_least_loaded_minimizes_wait_then_cost():
    r = get_router("least_loaded")
    views = [_view("slow", depth=8, rate=10.0),
             _view("fast", depth=8, rate=100.0),
             _view("idle-pricey", cost=3.0),
             _view("idle-cheap", cost=0.2)]
    assert r.select("cnn", "batch", views, 0.0).worker_id == "idle-cheap"


def test_plan_aware_tiering():
    r = get_router("plan_aware")
    edge = _view("edge", cost=0.2, depth=2, rate=10.0)    # wait 0.2s
    v5p = _view("v5p", cost=3.4, depth=2, rate=200.0)     # wait 0.01s
    views = [edge, v5p]
    # interactive → fastest door, cost be damned
    assert r.select("cnn", "interactive", views, 0.0).worker_id == "v5p"
    # tight deadline does the same regardless of tier
    assert r.select("cnn", "batch", views, 0.0,
                    deadline=0.1).worker_id == "v5p"
    # best-effort → cheapest inside the wait budget
    assert r.select("cnn", "best_effort", views, 0.0).worker_id == "edge"
    # cheap tier saturated → spills up to the next cost tier
    edge.queue_depth = 100                                # wait 10s
    assert r.select("cnn", "best_effort", views, 0.0).worker_id == "v5p"
    # everyone past budget → least-loaded degradation, not a refusal
    v5p.queue_depth = 10_000
    assert r.select("cnn", "best_effort", views, 0.0).worker_id == "edge"


def test_get_router_fresh_instances_and_unknown_name():
    a, b = get_router("round_robin"), get_router("round_robin")
    assert a is not b                   # rotation state is never shared
    assert get_router(a) is a           # instances pass through
    assert get_router(None).name == "plan_aware"
    with pytest.raises(ValueError, match="unknown router"):
        get_router("coin_flip")
    assert list_routers() == ("least_loaded", "plan_aware", "round_robin")


if HAVE_HYPOTHESIS:
    _worker_specs = st.lists(
        st.tuples(
            st.floats(0.1, 5.0),        # cost
            st.booleans(),              # serves the plan
            st.integers(0, 50),         # queue depth
            st.floats(1.0, 500.0),      # rate
            st.booleans(),              # healthy
            st.booleans(),              # draining
        ), min_size=0, max_size=8)
else:                                        # pragma: no cover
    _worker_specs = None


@settings(max_examples=150, deadline=None)
@given(specs=_worker_specs, tier=st.sampled_from(TIERS),
       router_name=st.sampled_from(list_routers()),
       headroom=st.one_of(st.none(), st.floats(0.01, 10.0)),
       now=st.floats(0.0, 100.0))
def test_routers_never_pick_inadmissible_workers(specs, tier,
                                                 router_name, headroom,
                                                 now):
    """Property: for every registered router, under any fleet state,
    ``select`` never returns a worker that is draining, unhealthy, or
    lacks the plan — and never returns None while an admissible worker
    exists (routers place, they don't refuse)."""
    views = [_view(f"w{i}", cost=c, plans=("cnn",) if has else ("x",),
                   depth=d, rate=rate, healthy=h, draining=dr)
             for i, (c, has, d, rate, h, dr) in enumerate(specs)]
    router = get_router(router_name)
    deadline = None if headroom is None else now + headroom
    chosen = router.select("cnn", tier, views, now, deadline=deadline)
    admissible = [v for v in views if v.accepting and "cnn" in v.plan_ids]
    if admissible:
        assert chosen in admissible
    else:
        assert chosen is None


# ---------------------------------------------------------------------------
# the health state machine (fake clock)
# ---------------------------------------------------------------------------

def test_health_ejects_probes_and_readmits():
    h = WorkerHealth(HealthPolicy(eject_after=3, probe_interval=1.0))
    h.note_failure(0.0)
    h.note_success()                    # streak resets before the bar
    h.note_failure(1.0), h.note_failure(2.0)
    assert h.healthy
    h.note_failure(3.0)                 # third consecutive: ejected
    assert not h.healthy and h.ejections == 1
    assert not h.routable(3.5)          # still in exile
    assert h.routable(4.0)              # probe due
    h.begin_probe()
    assert not h.routable(5.0)          # one canary at a time
    h.note_failure(5.0)                 # failed probe re-arms the clock
    assert not h.routable(5.5) and h.routable(6.0)
    h.begin_probe()
    h.note_success()                    # served canary re-admits
    assert h.healthy and h.routable(6.1) and h.probes == 2


def test_health_neutral_outcome_releases_probe_only():
    h = WorkerHealth(HealthPolicy(eject_after=1, probe_interval=1.0))
    h.note_failure(0.0)
    assert not h.healthy
    h.begin_probe()
    h.note_neutral()                    # deadline expiry: no verdict
    assert not h.healthy and not h.probing
    assert h.routable(1.0)              # next canary may go out


def test_health_policy_validation():
    with pytest.raises(ValueError):
        HealthPolicy(eject_after=0)
    with pytest.raises(ValueError):
        HealthPolicy(probe_interval=0.0)


# ---------------------------------------------------------------------------
# the device_profile catalog lookup (ISSUE satellite)
# ---------------------------------------------------------------------------

def test_device_profile_lookup():
    assert device_profile("v5e").name == "v5e"
    assert device_profile("edge").cost < device_profile("v5p").cost


def test_device_profile_unknown_name_names_the_catalog():
    with pytest.raises(DeploymentError) as ei:
        device_profile("v5x")
    msg = str(ei.value)
    assert "v5x" in msg
    for name in ("edge", "v5e", "v5p"):
        assert name in msg


def test_fleet_worker_resolves_profile_and_rejects_typos():
    gw = object()                       # profile resolution is eager
    w = FleetWorker("w0", gw, "edge")
    assert w.profile.name == "edge" and w.rate > 0
    with pytest.raises(DeploymentError):
        FleetWorker("w1", gw, "edgy")


# ---------------------------------------------------------------------------
# the live asyncio fleet end-to-end
# ---------------------------------------------------------------------------

def test_fleet_serves_bit_exact_across_workers(compiled_plan):
    """Requests spread over heterogeneous workers all come back
    bit-exact — routing must never change results."""
    _, compiled = compiled_plan
    workers = [FleetWorker("edge0", _gateway(compiled_plan), "edge"),
               FleetWorker("v5e0", _gateway(compiled_plan), "v5e"),
               FleetWorker("v5p0", _gateway(compiled_plan), "v5p")]
    imgs = compiled.sample_inputs(9)

    async def main():
        fleet = Fleet(workers, router="round_robin")
        async with fleet:
            futs = [await fleet.submit(img, tier=TIERS[i % 3])
                    for i, img in enumerate(imgs)]
            outs = await asyncio.gather(*futs)
            return outs, fleet.stats()

    outs, stats = asyncio.run(main())
    for out, ref in zip(outs, _ref_outputs(compiled_plan, imgs)):
        np.testing.assert_array_equal(out, ref)
    assert stats["served"] == 9
    # round robin spread the work over every worker
    per_worker = [w["snapshot"]["served"]
                  for w in stats["workers"].values()]
    assert sorted(per_worker) == [3, 3, 3]


def test_fleet_validation():
    gw = object()
    with pytest.raises(ValueError, match="at least one"):
        Fleet([])
    with pytest.raises(ValueError, match="duplicate"):
        Fleet([FleetWorker("a", gw), FleetWorker("a", gw)])
    with pytest.raises(ValueError, match="max_retries"):
        Fleet([FleetWorker("a", gw)], max_retries=-1)

    async def bad_tier():
        fleet = Fleet([FleetWorker("a", gw)])
        await fleet.__aenter__()           # bind, but don't close object()
        with pytest.raises(ValueError, match="unknown tier"):
            fleet.submit_nowait(np.zeros(1), tier="platinum")

    asyncio.run(bad_tier())


def test_fleet_no_worker_and_saturation_errors(compiled_plan):
    _, compiled = compiled_plan
    imgs = compiled.sample_inputs(4)

    async def main():
        workers = [FleetWorker("a", _gateway(compiled_plan,
                                             max_pending=1), "v5e"),
                   FleetWorker("b", _gateway(compiled_plan,
                                             max_pending=1), "v5e")]
        fleet = Fleet(workers, router="least_loaded")
        async with fleet:
            # fill both admission bounds without yielding to dispatch
            f0 = fleet.submit_nowait(imgs[0])
            f1 = fleet.submit_nowait(imgs[1])
            with pytest.raises(FleetSaturated):
                fleet.submit_nowait(imgs[2])
            await asyncio.gather(f0, f1)
            # drain both workers: nothing admissible remains
            await fleet.drain("a")
            await fleet.drain("b")
            with pytest.raises(NoWorkerAvailable):
                fleet.submit_nowait(imgs[3])
            with pytest.raises(FleetError, match="unknown worker"):
                await fleet.drain("zz")
            return fleet.stats()

    stats = asyncio.run(main())
    assert stats["served"] == 2 and stats["drains"] == 2


def test_fleet_drain_loses_nothing(compiled_plan):
    """The drain invariant, live: a worker drained with a full queue
    hands every queued request back, the fleet re-routes them, and all
    of them complete bit-exactly."""
    _, compiled = compiled_plan
    imgs = compiled.sample_inputs(12)

    async def main():
        workers = [FleetWorker("a", _gateway(compiled_plan), "v5e"),
                   FleetWorker("b", _gateway(compiled_plan), "v5e")]
        fleet = Fleet(workers, router="round_robin")
        async with fleet:
            # no yields: both queues hold work when the drain lands
            futs = [fleet.submit_nowait(img) for img in imgs]
            drained = await fleet.drain("a")
            assert drained.draining
            assert not drained.outstanding      # in-flight finished
            outs = await asyncio.gather(*futs)
            return outs, fleet.stats()

    outs, stats = asyncio.run(main())
    for out, ref in zip(outs, _ref_outputs(compiled_plan, imgs)):
        np.testing.assert_array_equal(out, ref)
    assert stats["served"] == len(imgs)          # zero lost
    assert stats["rerouted"] > 0                 # the queue moved over
    assert stats["workers"]["a"]["draining"]


def test_fleet_failure_retry_ejection_and_probe_readmission(
        compiled_plan):
    """A worker whose dispatches explode takes health strikes, gets
    ejected, and its requests are retried elsewhere — clients see
    results, not errors.  Once healed, the probe canary re-admits it."""
    _, compiled = compiled_plan

    class _Exploding:
        def __init__(self, inner):
            self._inner = inner
            self.broken = True

        def __getattr__(self, name):
            return getattr(self._inner, name)

        def __call__(self, *a, **k):
            if self.broken:
                raise RuntimeError("device exploded")
            return self._inner(*a, **k)

    gw_bad = _gateway(compiled_plan)
    bomb = _Exploding(compiled)
    gw_bad.plans["cnn"].compiled = bomb
    workers = [
        FleetWorker("bad", gw_bad, "edge",
                    health=HealthPolicy(eject_after=1,
                                        probe_interval=0.05)),
        FleetWorker("good", _gateway(compiled_plan), "v5e"),
    ]
    imgs = compiled.sample_inputs(6)

    async def main():
        # least-loaded prefers the cheaper "bad" worker when idle
        fleet = Fleet(workers, router="least_loaded")
        async with fleet:
            out0 = await fleet.infer(imgs[0])    # explodes, retried
            assert workers[0].health.ejections == 1
            # while ejected (probe not yet due) everything lands on good
            outs = await asyncio.gather(
                *[await fleet.submit(img) for img in imgs[1:4]])
            await asyncio.sleep(0.06)            # probe comes due
            bomb.broken = False                  # the worker heals
            out4 = await fleet.infer(imgs[4])    # the canary
            assert workers[0].health.healthy
            out5 = await fleet.infer(imgs[5])
            return [out0, *outs, out4, out5], fleet.stats()

    outs, stats = asyncio.run(main())
    for out, ref in zip(outs, _ref_outputs(compiled_plan, imgs)):
        np.testing.assert_array_equal(out, ref)
    assert stats["served"] == 6                  # every client served
    assert stats["worker_failures"] >= 1
    assert stats["retried"] >= 1
    assert stats["workers"]["bad"]["probes"] >= 1


def test_fleet_cancelled_canary_releases_probe(compiled_plan):
    """Regression: a client cancelling the very request that was an
    ejected worker's probe canary must clear the probing flag
    (``note_neutral``) — pre-fix the worker stayed "probing" forever,
    was never routable again, and the fleet silently shrank by one
    worker even after it healed."""
    _, compiled = compiled_plan

    class _Exploding:
        def __init__(self, inner):
            self._inner = inner
            self.broken = True

        def __getattr__(self, name):
            return getattr(self._inner, name)

        def __call__(self, *a, **k):
            if self.broken:
                raise RuntimeError("device exploded")
            return self._inner(*a, **k)

    gw_bad = _gateway(compiled_plan)
    bomb = _Exploding(compiled)
    gw_bad.plans["cnn"].compiled = bomb
    workers = [
        FleetWorker("bad", gw_bad, "edge",
                    health=HealthPolicy(eject_after=1,
                                        probe_interval=0.05)),
        FleetWorker("good", _gateway(compiled_plan), "v5e"),
    ]
    imgs = compiled.sample_inputs(3)

    async def main():
        # least-loaded prefers the cheaper "bad" worker when idle
        fleet = Fleet(workers, router="least_loaded")
        async with fleet:
            await fleet.infer(imgs[0])           # explodes → ejected
            assert not workers[0].health.healthy
            await asyncio.sleep(0.06)            # probe comes due
            canary = fleet.submit_nowait(imgs[1])
            assert workers[0].health.probing     # it took the canary
            canary.cancel()                      # client walks away
            await asyncio.gather(canary, return_exceptions=True)
            await asyncio.sleep(0)               # worker-side settles
            # the probe slot is released (note_neutral), the worker is
            # still ejected, and the next canary may go out
            assert not workers[0].health.probing
            assert not workers[0].health.healthy
            await asyncio.sleep(0.06)
            bomb.broken = False                  # the worker heals
            await fleet.infer(imgs[2])           # the second canary
            assert workers[0].health.healthy
            return fleet.stats()

    stats = asyncio.run(main())
    assert stats["cancelled"] == 1
    assert stats["workers"]["bad"]["probes"] == 2
    assert stats["workers"]["bad"]["routable"]


def test_fleet_submit_chunk_partial_admission(compiled_plan):
    """A chunk admits as far as fleet capacity allows (spanning
    workers), returns the refused remainder count, and an outage
    (no admissible worker at all) still raises."""
    _, compiled = compiled_plan
    imgs = compiled.sample_inputs(6)

    async def main():
        workers = [FleetWorker("a", _gateway(compiled_plan,
                                             max_pending=1), "v5e"),
                   FleetWorker("b", _gateway(compiled_plan,
                                             max_pending=1), "v5e")]
        fleet = Fleet(workers, router="least_loaded")
        async with fleet:
            futs, refused = fleet.submit_chunk(imgs[:4])
            assert len(futs) == 2 and refused == 2   # one per worker
            outs = await asyncio.gather(*futs)
            await fleet.drain("a")
            await fleet.drain("b")
            with pytest.raises(NoWorkerAvailable):
                fleet.submit_chunk(imgs[4:])
            return outs

    outs = asyncio.run(main())
    assert len(outs) == 2
    for out, ref in zip(outs, _ref_outputs(compiled_plan, imgs[:2])):
        np.testing.assert_array_equal(out, ref)


def test_fleet_stats_surface(compiled_plan):
    workers = [FleetWorker("w0", _gateway(compiled_plan), "v5e")]

    async def main():
        fleet = Fleet(workers)
        async with fleet:
            return fleet.stats()

    stats = asyncio.run(main())
    w = stats["workers"]["w0"]
    assert stats["router"] == "plan_aware"
    assert w["profile"] == "v5e" and w["plans"] == ["cnn"]
    assert w["healthy"] and w["routable"] and not w["draining"]
    snap = w["snapshot"]
    assert snap["queue_depth"] == 0 and snap["inflight"] == 0
    assert snap["max_batch"] == 4
