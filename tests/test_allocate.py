"""Allocator hardening: greedy top-up termination + budget invariants."""

import numpy as np
import pytest

from hypothesis_compat import HAVE_HYPOTHESIS, given, settings, st

from repro.core import allocate
from repro.core.allocate import BUDGET_RESOURCES, DeviceProfile


class _Const:
    """Stand-in for a fitted PolyModel: predicts one constant value."""

    def __init__(self, v):
        self.v = float(v)

    def predict(self, d, c):
        return np.array([self.v])


def _bm(demands, convs):
    """BlockModels from literal per-block demand dicts (no sweep/fit)."""
    models = {b: {r: _Const(res.get(r, 0.0)) for r in BUDGET_RESOURCES}
              for b, res in demands.items()}
    return allocate.BlockModels(models=models, convs=dict(convs))


def test_zero_demand_block_terminates():
    """Regression: a block predicting ~0 demand on every budgeted
    resource used to make the greedy top-up loop spin forever (it always
    'fit').  Zero-demand blocks are now skipped."""
    bm = _bm({"free": {},                              # ~0 on everything
              "real": {"mxu_cost": 1e6, "vpu_ops": 1e4,
                       "hbm_bytes": 1e4, "vmem_bytes": 1e6}},
             {"free": 2.0, "real": 1.0})
    alloc = allocate.allocate(bm, target=0.8)
    assert alloc.counts["free"] == 0          # not packed to infinity
    assert alloc.counts["real"] > 0
    for pct in alloc.usage_pct.values():
        assert pct <= 80.0 + 1e-6


def test_zero_demand_only_block_terminates():
    bm = _bm({"free": {}}, {"free": 1.0})
    alloc = allocate.allocate(bm, only_block="free", target=0.8)
    assert alloc.counts["free"] == 0
    assert alloc.total_convs == 0.0


def test_lp_survives_zero_demand_block():
    """The zero-demand column must be dropped from the LP too: a free
    column with positive objective makes linprog unbounded, which used
    to throw away the LP solution for every other block."""
    bm = _bm({"free": {}, "real": {"vpu_ops": 1.0}},
             {"free": 2.0, "real": 1.0})
    alloc = allocate.allocate(bm, target=0.8)
    assert alloc.counts["free"] == 0
    # far beyond what the round-capped greedy alone could reach
    assert alloc.counts["real"] >= 1_000_000
    assert alloc.usage_pct["vpu_ops"] <= 80.0 + 1e-6


def test_topup_round_cap():
    """Sub-resolution demands terminate via the round cap backstop."""
    bm = _bm({"tiny": {"vpu_ops": 1e-6}}, {"tiny": 1.0})
    alloc = allocate.allocate(bm, only_block="tiny", target=0.8,
                              max_topup_rounds=5)
    assert alloc.counts["tiny"] >= 0          # terminated, that's the point


# ---------------------------------------------------------------------------
# property: allocations never exceed target × budget (any resource)
# ---------------------------------------------------------------------------

_frac = st.floats(min_value=0.0, max_value=2.0) if HAVE_HYPOTHESIS else None


@settings(max_examples=30, deadline=None)
@given(
    fracs=st.lists(st.lists(_frac, min_size=4, max_size=4),
                   min_size=1, max_size=4),
    convs=st.lists(st.floats(min_value=0.5, max_value=4.0),
                   min_size=4, max_size=4),
    data_bits=st.integers(min_value=3, max_value=16),
    coeff_bits=st.integers(min_value=3, max_value=16),
    target=st.floats(min_value=0.05, max_value=0.95),
)
def test_allocate_never_exceeds_budget(fracs, convs, data_bits, coeff_bits,
                                       target):
    budgets = dict(allocate.V5E_BUDGETS)
    demands = {
        f"b{i}": {r: f * budgets[r]
                  for r, f in zip(sorted(BUDGET_RESOURCES), row)}
        for i, row in enumerate(fracs)
    }
    bm = _bm(demands, {f"b{i}": convs[i % len(convs)]
                       for i in range(len(fracs))})
    alloc = allocate.allocate(bm, data_bits=data_bits,
                              coeff_bits=coeff_bits, target=target)
    for r, pct in alloc.usage_pct.items():
        assert pct <= 100.0 * target + 1e-4, (r, pct, target)


def test_allocate_accepts_device_profile():
    bm = _bm({"real": {"mxu_cost": 1e6, "vpu_ops": 1e4,
                       "hbm_bytes": 1e4, "vmem_bytes": 1e6}},
             {"real": 1.0})
    a_dict = allocate.allocate(bm, budgets=allocate.V5E_BUDGETS)
    a_dev = allocate.allocate(bm, budgets=allocate.V5E)
    assert a_dict.counts == a_dev.counts


def test_device_catalog_well_formed():
    names = [d.name for d in allocate.DEVICE_CATALOG]
    assert len(names) >= 3 and len(set(names)) == len(names)
    assert [d.cost for d in allocate.DEVICE_CATALOG] == sorted(
        d.cost for d in allocate.DEVICE_CATALOG)
    for dev in allocate.DEVICE_CATALOG:
        assert set(dev.budgets) >= set(BUDGET_RESOURCES)
        assert allocate.get_device(dev.name) is dev
    with pytest.raises(KeyError, match="zcu104"):
        allocate.get_device("zcu104")
    with pytest.raises(ValueError, match="missing budgets"):
        DeviceProfile(name="bad", budgets={"mxu_cost": 1.0})
