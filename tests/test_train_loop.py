"""Train loop: loss goes down; preemption → resume is exact."""

import jax
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.data import DataConfig
from repro.models import build_model
from repro.optim import AdamWConfig
from repro.train.loop import TrainConfig, train


def _setup(tmp_path, **kw):
    cfg = smoke_config("llama3.2-3b")
    model = build_model(cfg)
    data_cfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=32,
                          global_batch=4)
    tcfg = TrainConfig(steps=kw.pop("steps", 20), lr=1e-3, log_every=5,
                       ckpt_every=kw.pop("ckpt_every", 10),
                       ckpt_dir=str(tmp_path), **kw)
    return model, data_cfg, tcfg


def test_loss_decreases(tmp_path):
    model, data_cfg, tcfg = _setup(tmp_path, steps=30)
    _, _, history = train(model, data_cfg, tcfg, log=lambda *a: None)
    assert history[-1]["loss"] < history[0]["loss"]


def test_preemption_resume_matches_uninterrupted(tmp_path):
    """Kill at step 12, resume, and the final params must match a run that
    was never interrupted (determinism of data + optimizer + restore)."""
    model, data_cfg, tcfg = _setup(tmp_path / "a", steps=20, ckpt_every=6)

    # uninterrupted reference
    p_ref, _, _ = train(model, data_cfg, tcfg, log=lambda *a: None)

    # interrupted run in a different ckpt dir
    model2, data_cfg2, tcfg2 = _setup(tmp_path / "b", steps=20,
                                      ckpt_every=6)
    tcfg2.fail_at_step = 12
    with pytest.raises(RuntimeError):
        train(model2, data_cfg2, tcfg2, log=lambda *a: None)
    tcfg2.fail_at_step = None
    p_resumed, _, _ = train(model2, data_cfg2, tcfg2, log=lambda *a: None)

    for a, b in zip(jax.tree.leaves(p_ref), jax.tree.leaves(p_resumed)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=2e-2, atol=2e-2)


def test_straggler_stats_published(tmp_path):
    model, data_cfg, tcfg = _setup(tmp_path, steps=10)
    _, _, history = train(model, data_cfg, tcfg, log=lambda *a: None)
    assert "p95_ms" in history[-1] and history[-1]["p95_ms"] > 0
