"""Property tests for the Algorithm-1 machinery (hypothesis)."""

import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.core import polyfit

GRID_D, GRID_C = np.meshgrid(np.arange(3, 17, dtype=float),
                             np.arange(3, 17, dtype=float))
D, C = GRID_D.ravel(), GRID_C.ravel()

coef = st.floats(min_value=-50, max_value=50, allow_nan=False)


@settings(max_examples=25, deadline=None)
@given(a=coef, b=coef, c=coef)
def test_fit_recovers_linear(a, b, c):
    y = a + b * D + c * C
    m = polyfit.algorithm1(D, C, y)
    assert m.r2 > 0.999
    np.testing.assert_allclose(m.predict(D, C), y, rtol=1e-4, atol=1e-3)


@settings(max_examples=15, deadline=None)
@given(a=coef, b=coef, q=st.floats(0.1, 5.0))
def test_fit_recovers_quadratic(a, b, q):
    y = a + b * D + q * D * C
    # the direct degree-2 fit is exact
    m2 = polyfit.fit_poly(D, C, y, 2)
    assert m2.r2 > 0.999
    # Algorithm 1 keeps the LOWEST R² above the 0.9 gate (paper pseudocode)
    # so it may legitimately return a coarser model — but never below gate
    m = polyfit.algorithm1(D, C, y)
    assert m.r2 >= 0.9


def test_prefers_lowest_r2_above_gate():
    """Paper Algorithm 1 keeps the SMALLEST R² that still clears 0.9."""
    rng = np.random.default_rng(0)
    y = 3 + 2 * D + 0.5 * C + rng.normal(0, 1.0, D.shape)
    m = polyfit.algorithm1(D, C, y)
    assert m.r2 >= 0.9
    # a degree-4 fit has strictly higher R²; Algorithm 1 must not pick it
    m4 = polyfit.fit_poly(D, C, y, 4)
    assert m.r2 <= m4.r2 + 1e-12


def test_pruning_drops_noise_terms():
    y = 5 + 3 * D            # c is irrelevant
    m = polyfit.fit_poly(D, C, y, 2)
    pruned = polyfit.prune_insignificant(m, D, C, y)
    # pruned model keeps accuracy
    assert polyfit.r_squared(y, pruned.predict(D, C)) > 0.999
    assert len(pruned.terms) <= len(m.terms)


def test_segmented_exact_on_regime_split():
    y = np.where(D + C <= 12, 10 + D, 1000 + 5 * C)
    m = polyfit.fit_segmented(D, C, y, scheme="pack")
    np.testing.assert_allclose(m.predict(D, C), y, rtol=1e-6, atol=1e-4)
    assert m.r2 > 0.9999


def test_error_metrics_properties():
    y = np.array([1.0, 2.0, 4.0])
    met = polyfit.error_metrics(y, y)
    assert met["mse"] == 0 and met["mae"] == 0
    assert met["r2"] == 1.0 and met["mape_pct"] == 0
    met2 = polyfit.error_metrics(y, y + 1)
    assert met2["mse"] == 1.0 and met2["mae"] == 1.0
    assert met2["r2"] < 1.0


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_r2_bounded_above(seed):
    rng = np.random.default_rng(seed)
    y = rng.normal(size=D.shape)
    for deg in (1, 2, 3, 4):
        m = polyfit.fit_poly(D, C, y, deg)
        assert m.r2 <= 1.0 + 1e-9
