"""Deployment planner: per-layer precision/block search over the device
catalog, Pareto frontier, device selection, predicted-vs-measured."""

import numpy as np
import pytest

from repro.configs.paper_conv import REDUCED_SWEEP
from repro.core import allocate, deploy, synth
from repro.core.allocate import (BUDGET_RESOURCES, DEVICE_CATALOG,
                                 DeviceProfile)
from repro.core.cnn import (CNNConfig, ConvLayerSpec, choose_blocks,
                            quickstart_cnn_config)


@pytest.fixture(scope="module")
def rows():
    return synth.run_sweep()   # cached JSON after the first run


@pytest.fixture(scope="module")
def bm(rows):
    return allocate.BlockModels.fit(rows)


def _small_cfg():
    """Small enough to fit the constrained edge profile."""
    return CNNConfig(layers=(
        ConvLayerSpec(1, 2, data_bits=8, coeff_bits=6),
        ConvLayerSpec(2, 2, data_bits=6, coeff_bits=4),
    ), img_h=16, img_w=128)


NANO = DeviceProfile(name="nano", cost=0.01,
                     budgets={r: 1.0 for r in BUDGET_RESOURCES})


# ---------------------------------------------------------------------------
# plans respect per-device budgets
# ---------------------------------------------------------------------------

def test_plans_respect_budgets(bm):
    cfg = quickstart_cnn_config()
    feasible = 0
    for dev in DEVICE_CATALOG:
        try:
            plan = deploy.plan_deployment(
                cfg, bm, dev, bit_candidates=deploy.DEFAULT_BIT_CANDIDATES)
        except deploy.DeploymentError:
            continue
        feasible += 1
        assert plan.feasible
        for r in BUDGET_RESOURCES:
            assert plan.demand[r] <= plan.target * dev.budgets[r] + 1e-6, \
                (dev.name, r)
            assert plan.usage_pct[r] <= 100 * plan.target + 1e-6
        # plan totals are consistent with the per-layer assignments
        for r in deploy.RATE_RESOURCES:
            assert plan.demand[r] == pytest.approx(
                sum(a.demand[r] for a in plan.layers))
    assert feasible >= 1


def test_layer_demand_scales_with_calls(bm):
    """Rate demand is per-call × calls × grid ratio: doubling out_ch
    doubles it, halving the image height halves it."""
    s1 = ConvLayerSpec(4, 4, data_bits=8, coeff_bits=8)
    s2 = ConvLayerSpec(4, 8, data_bits=8, coeff_bits=8)
    d1 = deploy.predict_layer_demand(bm, "conv2", 8, 8, s1, 64, 128)
    d2 = deploy.predict_layer_demand(bm, "conv2", 8, 8, s2, 64, 128)
    dh = deploy.predict_layer_demand(bm, "conv2", 8, 8, s1, 32, 128)
    for r in deploy.RATE_RESOURCES:
        assert d2[r] == pytest.approx(2 * d1[r])
        assert dh[r] == pytest.approx(d1[r] / 2)
    # vmem is a capacity — independent of the channel count
    assert d2["vmem_bytes"] == pytest.approx(d1["vmem_bytes"])


# ---------------------------------------------------------------------------
# explicit overrides win
# ---------------------------------------------------------------------------

def test_explicit_overrides_win(bm):
    cfg = CNNConfig(layers=(
        ConvLayerSpec(1, 4, data_bits=5, coeff_bits=5, block="conv1"),
        ConvLayerSpec(4, 4, data_bits=8, coeff_bits=6),
    ), img_h=16, img_w=128)
    plan = deploy.plan_deployment(
        cfg, bm, allocate.V5P, bit_candidates=deploy.DEFAULT_BIT_CANDIDATES)
    # pinned layer keeps block AND bits, even with the bit search open
    assert plan.layers[0].block == "conv1"
    assert (plan.layers[0].data_bits, plan.layers[0].coeff_bits) == (5, 5)
    # the free layer is searched: its bits come from the candidate set
    assert (plan.layers[1].data_bits,
            plan.layers[1].coeff_bits) in deploy.DEFAULT_BIT_CANDIDATES
    # and choose_blocks (the thin wrapper) honors the same pin
    blocks = choose_blocks(cfg)
    assert blocks[0].name == "conv1"


def test_pinned_unmodeled_block(bm):
    """A pin on a registered block the sweep never modeled: strict mode
    raises, but choose_blocks keeps the seed's never-fail contract."""
    from repro.blocks import Conv2Block, register_block, unregister_block
    register_block(Conv2Block(name="conv2_pin", convs_per_step=1,
                              dual_output=False))
    try:
        cfg = CNNConfig(layers=(
            ConvLayerSpec(1, 2, data_bits=8, coeff_bits=6,
                          block="conv2_pin"),), img_h=16, img_w=128)
        with pytest.raises(deploy.DeploymentError, match="pins block"):
            deploy.plan_deployment(cfg, bm, allocate.V5P)
        plan = deploy.plan_deployment(cfg, bm, allocate.V5P,
                                      on_infeasible="fallback")
        assert plan.layers[0].block == "conv2_pin"
        assert not plan.feasible
        assert choose_blocks(cfg)[0].name == "conv2_pin"
    finally:
        unregister_block("conv2_pin")


def test_spec_bits_pinned_without_candidates(bm):
    """bit_candidates=None → every layer keeps its spec bits."""
    cfg = quickstart_cnn_config()
    plan = deploy.plan_deployment(cfg, bm, allocate.V5P)
    assert plan.bits() == [(s.data_bits, s.coeff_bits) for s in cfg.layers]


def test_empty_config(bm):
    """Zero-layer networks plan to an empty, feasible, zero-demand plan
    (the seed's choose_blocks returned [])."""
    cfg = CNNConfig(layers=())
    plan = deploy.plan_deployment(cfg, bm, allocate.V5E)
    assert plan.layers == () and plan.feasible
    assert plan.max_usage_pct == 0.0
    assert choose_blocks(cfg) == []


# ---------------------------------------------------------------------------
# infeasible budgets
# ---------------------------------------------------------------------------

def test_infeasible_raises_clear_error(bm):
    cfg = _small_cfg()
    with pytest.raises(deploy.DeploymentError, match="does not fit"):
        deploy.plan_deployment(cfg, bm, NANO)
    with pytest.raises(deploy.DeploymentError, match="nano"):
        deploy.plan_deployment(cfg, bm, NANO)


def test_infeasible_fallback_marks_plan(bm):
    plan = deploy.plan_deployment(_small_cfg(), bm, NANO,
                                  on_infeasible="fallback")
    assert not plan.feasible
    assert len(plan.layers) == 2
    # choose_blocks preserves the seed contract: selection never raises
    blocks = choose_blocks(_small_cfg(), budgets=NANO.budgets)
    assert len(blocks) == 2


def test_select_device_none_fits(bm):
    with pytest.raises(deploy.DeploymentError, match="no device"):
        deploy.select_device(_small_cfg(), bm, catalog=[NANO])


# ---------------------------------------------------------------------------
# device selection
# ---------------------------------------------------------------------------

def test_select_device_cheapest_fit(bm):
    dev, plan = deploy.select_device(_small_cfg(), bm)
    assert plan.feasible
    # the selected device is the cheapest whose plan fits
    for other in DEVICE_CATALOG:
        if other.cost >= dev.cost or other.name == dev.name:
            continue
        with pytest.raises(deploy.DeploymentError):
            deploy.plan_deployment(_small_cfg(), bm, other)
    # a bigger net needs a bigger part than the small one
    big_dev, _ = deploy.select_device(quickstart_cnn_config(), bm)
    assert big_dev.cost >= dev.cost


# ---------------------------------------------------------------------------
# Pareto frontier
# ---------------------------------------------------------------------------

def test_pareto_frontier_non_dominated(bm):
    frontier = deploy.pareto_frontier(
        quickstart_cnn_config(), bm,
        bit_candidates=((6, 4), (8, 6), (8, 8), (12, 10)))
    assert frontier
    for p in frontier:
        assert p.feasible
        assert p.quant_error is not None
    for a in frontier:
        for b in frontier:
            if a is not b:
                assert not deploy._dominates(a, b), (
                    a.device.name, a.bits(), b.device.name, b.bits())


def test_pareto_filter_drops_dominated(bm):
    cfg = _small_cfg()
    good = deploy.plan_deployment(cfg, bm, allocate.V5P)
    good.quant_error = 0.1
    worse = deploy.plan_deployment(cfg, bm, allocate.V5P)
    worse.quant_error = 0.5
    worse.usage_pct = {r: v + 1.0 for r, v in worse.usage_pct.items()}
    worse.convs_per_step = good.convs_per_step - 0.1
    kept = deploy.pareto_filter([good, worse])
    assert kept == [good]


# ---------------------------------------------------------------------------
# end-to-end on a reduced sweep (CI: the dedicated -m sweep job)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def reduced_rows(tmp_path_factory):
    """One fresh reduced-sweep trace shared by the sweep-marked tests
    (the 72 traces dominate the CI sweep job's cost)."""
    cache = tmp_path_factory.mktemp("sweep") / "reduced.json"
    return synth.run_sweep(REDUCED_SWEEP, cache_path=cache, force=True)


@pytest.mark.sweep
def test_predicted_vs_measured_reduced_sweep(reduced_rows):
    """The §4.1 loop on a fresh reduced sweep: fit models, plan, execute
    bit-exactly, and the models must predict the re-traced resources to
    ≤ 20% MAPE on every budgeted resource class."""
    bm = allocate.BlockModels.fit(reduced_rows)
    cfg = quickstart_cnn_config()
    dev, plan = deploy.select_device(cfg, bm)
    val = deploy.validate_plan(plan, cfg)
    assert val.bit_exact
    for r in BUDGET_RESOURCES:
        assert val.metrics[r]["mape_pct"] <= 20.0, (r, val.metrics[r])
        assert np.all(val.measured[r] >= 0)
    assert 0.0 <= val.quant_error


@pytest.mark.sweep
def test_frontier_reduced_sweep(reduced_rows):
    bm = allocate.BlockModels.fit(reduced_rows)
    frontier = deploy.pareto_frontier(
        _small_cfg(), bm, bit_candidates=((6, 4), (8, 8)))
    assert frontier
    devices = {p.device.name for p in frontier}
    assert devices <= {d.name for d in DEVICE_CATALOG}
