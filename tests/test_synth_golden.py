"""Golden regression for the synthesis sweep: the resource vectors every
downstream model is fitted on must not drift silently when kernels or the
hloscan census change.  If a change is *intentional*, regenerate the
fixture (see tests/golden/synth_golden.json) and bump
``synth.SWEEP_SCHEMA_VERSION``."""

import json
from pathlib import Path

import pytest

from repro.configs.paper_conv import SWEEP, ConvSweepConfig
from repro.core import synth

GOLDEN = Path(__file__).parent / "golden" / "synth_golden.json"


def _golden():
    return json.loads(GOLDEN.read_text())


def test_golden_fixture_matches_schema_version():
    assert _golden()["version"] == synth.SWEEP_SCHEMA_VERSION, (
        "SWEEP_SCHEMA_VERSION changed — regenerate the golden fixture "
        "to match the new row semantics")


@pytest.mark.parametrize("i", range(6), ids=lambda i: f"row{i}")
def test_synth_traces_match_golden(i):
    row = _golden()["rows"][i]
    got = synth.synth_one(row["block"], row["data_bits"], row["coeff_bits"],
                          SWEEP)
    for key, want in row.items():
        if key in ("block", "data_bits", "coeff_bits"):
            continue
        assert got[key] == pytest.approx(want, rel=1e-6), (
            row["block"], row["data_bits"], row["coeff_bits"], key)


# ---------------------------------------------------------------------------
# SWEEP_SCHEMA_VERSION cache regeneration
# ---------------------------------------------------------------------------

TINY = ConvSweepConfig(name="tiny", blocks=("conv1",),
                       data_bits=(4,), coeff_bits=(4,))


def test_stale_cache_regenerates(tmp_path):
    cache = tmp_path / "synth.json"
    stale = [{"block": "conv1", "data_bits": 4, "coeff_bits": 4,
              "vpu_ops": -1.0}]
    # pre-versioning bare-list payload → regenerated
    cache.write_text(json.dumps(stale))
    rows = synth.run_sweep(TINY, cache_path=cache)
    assert rows[0]["vpu_ops"] > 0
    payload = json.loads(cache.read_text())
    assert payload["version"] == synth.SWEEP_SCHEMA_VERSION

    # wrong version number → regenerated too
    cache.write_text(json.dumps({"version": synth.SWEEP_SCHEMA_VERSION - 1,
                                 "rows": stale}))
    rows = synth.run_sweep(TINY, cache_path=cache)
    assert rows[0]["vpu_ops"] > 0

    # current version → served verbatim, no re-trace
    sentinel = [{"block": "conv1", "data_bits": 4, "coeff_bits": 4,
                 "vpu_ops": 123.0}]
    cache.write_text(json.dumps({"version": synth.SWEEP_SCHEMA_VERSION,
                                 "rows": sentinel}))
    assert synth.run_sweep(TINY, cache_path=cache) == sentinel

    # force=True ignores even a current cache
    rows = synth.run_sweep(TINY, cache_path=cache, force=True)
    assert rows[0]["vpu_ops"] > 0
