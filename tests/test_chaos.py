"""``repro.chaos``: seeded fault plans (validation, serialization,
seed-determinism), the ``FaultInjector``'s seam semantics (sticky
crashes, stall windows, tracker disk-full), ``StoreRoot`` worker
leases, restart-from-store recovery (``respawn_gateway`` with zero
recompiles), and the live fleet kill→re-route→respawn path.  The
full crash-mid-trace end-to-end over a shared store is marked
``chaos`` (CI's chaos job)."""

import asyncio
import json
import os

import jax.numpy as jnp
import numpy as np
import pytest

from repro.chaos import (FaultInjector, FaultPlan, FaultSpec,
                         HeartbeatStalled, TrackerDiskFull, WorkerCrashed,
                         corrupt_cache_entries, make_fault_plan,
                         respawn_gateway)
from repro.core import deploy
from repro.core.cnn import (CNNConfig, ConvLayerSpec, cnn_forward_ref,
                            fitted_block_models)
from repro.fleet import Fleet, FleetError, FleetWorker, HealthPolicy
from repro.ops import LeaseHeld, PlanNotFound, StoreRoot
from repro.runtime import CompiledCNN
from repro.serve import AsyncCNNGateway, AsyncServeConfig


def _cfg():
    return CNNConfig(layers=(
        ConvLayerSpec(1, 4, data_bits=8, coeff_bits=6, block="conv4"),
        ConvLayerSpec(4, 3, data_bits=6, coeff_bits=4, block="conv3"),
    ), img_h=16, img_w=64)


@pytest.fixture(scope="module")
def compiled_plan():
    """One plan + warmed CompiledCNN shared by every live test
    (registering a pre-compiled plan into a gateway is free)."""
    plan = deploy.plan_deployment(_cfg(), fitted_block_models(),
                                  target=0.8, on_infeasible="fallback")
    return plan, CompiledCNN.from_plan(plan, max_batch=4)


def _gateway(compiled_plan, *, max_pending=16, faults=None):
    plan, compiled = compiled_plan
    gw = AsyncCNNGateway(AsyncServeConfig(max_batch=4,
                                          max_pending=max_pending),
                         faults=faults)
    gw.register_plan(plan, plan_id="cnn", compiled=compiled)
    return gw


def _ref_outputs(compiled_plan, imgs):
    plan, compiled = compiled_plan
    pcfg = deploy.plan_config(plan)
    return [np.asarray(cnn_forward_ref(compiled.params, jnp.asarray(i),
                                       pcfg)) for i in imgs]


# ---------------------------------------------------------------------------
# FaultSpec / FaultPlan: validation, serialization, seed-determinism
# ---------------------------------------------------------------------------

def test_fault_spec_validation():
    with pytest.raises(ValueError, match="unknown fault kind"):
        FaultSpec("explode", "w", at=1.0)
    with pytest.raises(ValueError, match="non-empty"):
        FaultSpec("crash_dispatch", "", at=1.0)
    with pytest.raises(ValueError, match="exactly one"):
        FaultSpec("crash_dispatch", "w")
    with pytest.raises(ValueError, match="exactly one"):
        FaultSpec("crash_dispatch", "w", at=1.0, after_n=1)
    with pytest.raises(ValueError, match="must be ≥ 0"):
        FaultSpec("crash_dispatch", "w", at=-1.0)
    with pytest.raises(ValueError, match="must be ≥ 1"):
        FaultSpec("crash_dispatch", "w", after_n=0)
    # windows only apply where they mean something
    with pytest.raises(ValueError, match="duration_s does not apply"):
        FaultSpec("crash_dispatch", "w", at=1.0, duration_s=1.0)
    with pytest.raises(ValueError, match="count does not apply"):
        FaultSpec("crash_dispatch", "w", after_n=1, count=2)
    with pytest.raises(ValueError, match="must be > 0"):
        FaultSpec("stall_heartbeat", "w", at=1.0, duration_s=0.0)
    with pytest.raises(ValueError, match="must be ≥ 1"):
        FaultSpec("tracker_disk_full", "w", after_n=1, count=0)


def test_fault_plan_round_trip_and_queries():
    plan = FaultPlan((
        FaultSpec("crash_dispatch", "a", at=3.5),
        FaultSpec("stall_heartbeat", "b", at=1.0, duration_s=2.0),
        FaultSpec("tracker_disk_full", "a", after_n=4, count=2),
    ), seed=7)
    again = FaultPlan.from_json(plan.to_json())
    assert again == plan and again.seed == 7
    assert len(plan) == 3 and tuple(plan) == plan.specs
    assert [s.kind for s in plan.for_target("a")] \
        == ["crash_dispatch", "tracker_disk_full"]
    assert [s.target for s in plan.of_kind("stall_heartbeat")] == ["b"]
    with pytest.raises(ValueError, match="unknown fault kind"):
        plan.of_kind("meteor_strike")
    # the payload is plain JSON with no None noise
    payload = plan.to_payload()
    assert payload["schema_version"] == 1
    assert "duration_s" not in payload["specs"][0]


def test_fault_plan_rejects_foreign_payloads():
    plan = FaultPlan((FaultSpec("crash_dispatch", "w", at=1.0),))
    payload = plan.to_payload()
    payload["schema_version"] = 999
    with pytest.raises(ValueError, match="schema_version"):
        FaultPlan.from_payload(payload)
    with pytest.raises(ValueError, match="unknown FaultSpec fields"):
        FaultSpec.from_payload({"kind": "crash_dispatch", "target": "w",
                                "at": 1.0, "blast_radius": 3})


def test_make_fault_plan_is_seed_deterministic():
    kw = dict(workers=("a", "b", "c"), horizon_s=100.0,
              kinds=("crash_dispatch", "stall_heartbeat",
                     "tracker_disk_full"))
    p1, p2 = make_fault_plan(7, **kw), make_fault_plan(7, **kw)
    assert p1 == p2 and p1.to_json() == p2.to_json()
    assert p1.seed == 7
    assert make_fault_plan(8, **kw) != p1
    # time-triggered faults land away from the trace edges
    for spec in p1.of_kind("crash_dispatch", "stall_heartbeat"):
        assert 0.2 * 100.0 <= spec.at <= 0.7 * 100.0
        assert spec.target in kw["workers"]
    with pytest.raises(ValueError, match="at least one worker"):
        make_fault_plan(7, workers=(), horizon_s=1.0)
    with pytest.raises(ValueError, match="horizon_s"):
        make_fault_plan(7, workers=("a",), horizon_s=0.0)
    with pytest.raises(ValueError, match="unknown fault kind"):
        make_fault_plan(7, workers=("a",), horizon_s=1.0, kinds=("x",))


# ---------------------------------------------------------------------------
# FaultInjector: seam semantics
# ---------------------------------------------------------------------------

def test_crash_is_sticky_until_revive():
    inj = FaultInjector(FaultPlan((
        FaultSpec("crash_dispatch", "w", after_n=1),)))
    seam = inj.for_target("w")
    with pytest.raises(WorkerCrashed, match="crashed mid-dispatch"):
        seam.check("dispatch", now=0.0)
    assert inj.crashed == frozenset({"w"})
    # a dead process is dead at EVERY seam, not just the one that fired
    with pytest.raises(WorkerCrashed, match="is dead"):
        seam.check("heartbeat", now=1.0)
    inj.check("other", "dispatch", now=1.0)      # other targets unharmed
    inj.revive("w")
    assert inj.crashed == frozenset()
    seam.check("dispatch", now=2.0)    # the fired spec stays consumed
    assert [(k, t) for k, t, _ in inj.injected] \
        == [("crash_dispatch", "w")]


def test_stall_heartbeat_window():
    inj = FaultInjector(FaultPlan((
        FaultSpec("stall_heartbeat", "w", at=10.0, duration_s=5.0),)))
    seam = inj.for_target("w")
    seam.check("heartbeat", now=9.0)             # before the window
    with pytest.raises(HeartbeatStalled):
        seam.check("heartbeat", now=10.0)
    with pytest.raises(HeartbeatStalled):
        seam.check("heartbeat", now=14.9)
    seam.check("heartbeat", now=15.0)            # window closed: recovers
    seam.check("dispatch", now=12.0)             # wrong seam point: silent
    assert [k for k, _, _ in inj.injected] == ["stall_heartbeat"] * 2


def test_tracker_disk_full_window_and_passthrough():
    inj = FaultInjector(FaultPlan((
        FaultSpec("tracker_disk_full", "w", after_n=2, count=2),)))
    assert inj.tracker_io_fault("other") is None  # pass-through when unplanned
    io_fault = inj.tracker_io_fault("w")
    io_fault({"event": "w1"})                    # write 1: fine
    for _ in range(2):                           # writes 2-3: disk full
        with pytest.raises(TrackerDiskFull, match="disk full"):
            io_fault({"event": "doomed"})
    io_fault({"event": "w4"})                    # window passed: recovers
    assert [k for k, _, _ in inj.injected] == ["tracker_disk_full"] * 2


def test_corrupt_cache_entries_sorted_and_limited(tmp_path):
    for name in ("b.exe", "a.exe", "c.exe", "keep.other"):
        (tmp_path / name).write_bytes(b"payload")
    hit = corrupt_cache_entries(tmp_path, limit=2)
    assert [p.name for p in hit] == ["a.exe", "b.exe"]  # deterministic order
    assert (tmp_path / "a.exe").read_bytes() != b"payload"
    assert (tmp_path / "c.exe").read_bytes() == b"payload"
    assert (tmp_path / "keep.other").read_bytes() == b"payload"


def test_gateway_dispatch_crash_rides_failed_dispatch_path(compiled_plan):
    """The injected crash surfaces through the gateway's *production*
    failed-dispatch path: the request future fails with WorkerCrashed,
    the sticky corpse fails its heartbeat too, and a revive (the
    restart) serves bit-exactly again."""
    _, compiled = compiled_plan
    imgs = compiled.sample_inputs(2)
    inj = FaultInjector(FaultPlan((
        FaultSpec("crash_dispatch", "w", after_n=1),)))

    async def main():
        gw = _gateway(compiled_plan, faults=inj.for_target("w"))
        async with gw:
            fut = await gw.submit(imgs[0])
            with pytest.raises(WorkerCrashed):
                await fut
            assert gw.failed == 1
            with pytest.raises(WorkerCrashed):   # missed heartbeat
                gw.snapshot()
            inj.revive("w")
            return await gw.infer(imgs[1])

    out = asyncio.run(main())
    np.testing.assert_array_equal(out, _ref_outputs(compiled_plan, imgs)[1])
    assert [k for k, _, _ in inj.injected] == ["crash_dispatch"]


# ---------------------------------------------------------------------------
# StoreRoot: shared layout + worker leases
# ---------------------------------------------------------------------------

def test_store_root_layout_and_lease_lifecycle(tmp_path, compiled_plan):
    plan, _ = compiled_plan
    root = StoreRoot(tmp_path / "state")
    root.plans.save(plan, "cnn")
    assert root.plans.list_plans() == ["cnn"]
    assert root.exec_cache_dir.is_dir()
    lease = root.acquire_lease("w0")
    assert lease.held and root.list_leases() == ["w0"]
    data = json.loads((root.root / "leases" / "w0").read_text())
    assert data["pid"] == os.getpid() and data["worker_id"] == "w0"
    lease.release()
    lease.release()                              # idempotent
    assert not lease.held and root.list_leases() == []
    # lease ids obey the same portable-filename rules as plan ids
    with pytest.raises(ValueError, match="plan_id"):
        root.acquire_lease("../escape")


def test_lease_takeover_and_stale_release(tmp_path):
    root = StoreRoot(tmp_path / "state")
    old = root.acquire_lease("w")
    new = root.acquire_lease("w")        # own-pid takeover (respawn path)
    assert root.list_leases() == ["w"]
    # releasing the stale pre-takeover handle must NOT evict the
    # successor: the unlink is token-checked
    old.release()
    assert root.list_leases() == ["w"]
    new.release()
    assert root.list_leases() == []
    # a dead holder's lease is taken over atomically (crash recovery
    # never requires manual lock removal); pid 2**30 exceeds pid_max
    path = root.root / "leases" / "w"
    path.write_text(json.dumps({"worker_id": "w", "pid": 2 ** 30,
                                "acquired_at": 0.0}))
    with root.acquire_lease("w"):
        assert json.loads(path.read_text())["pid"] == os.getpid()
    assert root.list_leases() == []              # context manager released


def test_lease_held_by_live_foreign_process(tmp_path):
    root = StoreRoot(tmp_path / "state")
    path = root.root / "leases" / "w"
    # forge a lease held by a live process that is not us (our parent)
    path.write_text(json.dumps({"worker_id": "w", "pid": os.getppid(),
                                "acquired_at": 1.0}))
    with pytest.raises(LeaseHeld, match="live pid"):
        root.acquire_lease("w")
    assert root.list_leases() == ["w"]           # the holder keeps it


# ---------------------------------------------------------------------------
# respawn_gateway: restart-from-store (the zero-recompile headline)
# ---------------------------------------------------------------------------

def test_respawn_gateway_warm_from_store_zero_recompiles(tmp_path,
                                                         compiled_plan):
    plan, compiled = compiled_plan
    root = StoreRoot(tmp_path / "state")
    root.plans.save(plan, "cnn")
    # the dead predecessor already paid the compile storm into the
    # shared cache (same max_batch → same bucket keys)
    pre = root.exec_cache()
    CompiledCNN.from_plan(plan, max_batch=4, exec_cache=pre)
    assert pre.stats()["disk_stores"] > 0

    gw = respawn_gateway(root, "w1", ["cnn"],
                         AsyncServeConfig(max_batch=4))
    s = gw.exec_cache.stats()
    assert s["compiles"] == 0                    # the acceptance headline
    assert s["disk_hits"] > 0
    assert sorted(gw.plans) == ["cnn"]
    assert gw.lease.held and root.list_leases() == ["w1"]

    imgs = compiled.sample_inputs(1)

    async def main():
        async with gw:
            return await gw.infer(imgs[0])

    out = asyncio.run(main())
    np.testing.assert_array_equal(out, _ref_outputs(compiled_plan, imgs)[0])
    gw.lease.release()

    # a missing plan fails loudly AND releases the lease it took — a
    # half-respawned identity must not stay claimed
    with pytest.raises(PlanNotFound):
        respawn_gateway(root, "w1", ["ghost"])
    assert root.list_leases() == []


# ---------------------------------------------------------------------------
# Fleet.kill / Fleet.respawn, live (tier-1 scale)
# ---------------------------------------------------------------------------

def test_fleet_kill_reroutes_and_respawn_readmits(compiled_plan):
    """The live kill invariant: a killed worker's queued requests are
    re-routed on their original budget and all complete bit-exactly;
    respawn re-admits the identity through the health-probe path."""
    _, compiled = compiled_plan
    imgs = compiled.sample_inputs(10)

    async def main():
        workers = [
            FleetWorker("a", _gateway(compiled_plan), "v5e",
                        health=HealthPolicy(eject_after=1,
                                            probe_interval=0.05)),
            FleetWorker("b", _gateway(compiled_plan), "v5e"),
        ]
        fleet = Fleet(workers, router="round_robin")
        async with fleet:
            futs = [fleet.submit_nowait(img) for img in imgs]
            killed = fleet.kill("a")
            assert killed.dead
            assert fleet.kill("a") is killed     # idempotent
            with pytest.raises(FleetError, match="unknown worker"):
                fleet.kill("zz")
            with pytest.raises(FleetError, match="not dead"):
                await fleet.respawn("b")
            with pytest.raises(FleetError, match="no spawn factory"):
                await fleet.respawn("a")
            outs = await asyncio.gather(*futs)   # zero lost
            await fleet.respawn("a", gateway=_gateway(compiled_plan))
            # probe is immediately due: the next requests routed to the
            # respawned worker are canaries that re-admit it
            canary = [await fleet.infer(img) for img in imgs[:2]]
            assert workers[0].health.healthy
            return outs, canary, fleet.stats()

    outs, canary, stats = asyncio.run(main())
    refs = _ref_outputs(compiled_plan, imgs)
    for out, ref in zip(outs, refs):
        np.testing.assert_array_equal(out, ref)
    np.testing.assert_array_equal(canary[0], refs[0])
    assert stats["kills"] == 1 and stats["respawns"] == 1
    assert stats["rerouted"] > 0                 # the queue moved over
    assert stats["served"] == len(imgs) + 2
    assert not stats["workers"]["a"]["dead"]


# ---------------------------------------------------------------------------
# the full crash-mid-trace end-to-end over a shared store — CI chaos job
# ---------------------------------------------------------------------------

@pytest.mark.chaos
def test_chaos_end_to_end_crash_kill_respawn_shared_store(tmp_path,
                                                          compiled_plan):
    """Seeded crash mid-dispatch → the fleet kills the worker and
    re-routes every queued + mid-dispatch request → respawn rebuilds
    the gateway from the shared StoreRoot (lease takeover, plans from
    the store, zero recompiles) → the probe path re-admits it.
    ``completed + refused == trace`` and ``lost == 0`` throughout."""
    plan, compiled = compiled_plan
    root = StoreRoot(tmp_path / "state")
    root.plans.save(plan, "cnn")
    pre = root.exec_cache()                      # predecessor's compiles
    CompiledCNN.from_plan(plan, max_batch=4, exec_cache=pre)

    inj = FaultInjector(FaultPlan((
        FaultSpec("crash_dispatch", "a", after_n=1),), seed=42))

    def _cfg_async():
        return AsyncServeConfig(max_batch=4, max_pending=32)

    def spawn_a():
        inj.revive("a")                          # the restart
        return respawn_gateway(root, "a", ["cnn"], _cfg_async())

    gw_a = respawn_gateway(root, "a", ["cnn"], _cfg_async(),
                           faults=inj.for_target("a"))
    gw_b = respawn_gateway(root, "b", ["cnn"], _cfg_async())
    assert root.list_leases() == ["a", "b"]
    imgs = compiled.sample_inputs(24)

    async def main():
        workers = [
            FleetWorker("a", gw_a, "v5e", spawn=spawn_a,
                        health=HealthPolicy(eject_after=1,
                                            probe_interval=0.05)),
            FleetWorker("b", gw_b, "v5e"),
        ]
        fleet = Fleet(workers, router="round_robin")
        async with fleet:
            futs, refused = [], 0
            for i, img in enumerate(imgs):
                try:
                    futs.append(fleet.submit_nowait(img))
                except FleetError:
                    refused += 1
                if i % 4 == 3:                   # let dispatches (and
                    await asyncio.sleep(0.01)    # the crash) happen
            outs = await asyncio.gather(*futs)
            assert fleet.workers["a"].dead       # the crash became a kill
            respawned = await fleet.respawn("a")  # via the spawn factory
            canary = [await fleet.infer(img) for img in imgs[:2]]
            assert respawned.health.healthy      # probe re-admitted it
            return (outs, refused, canary, fleet.stats(),
                    respawned.gateway.exec_cache.stats())

    outs, refused, canary, stats, respawn_cache = asyncio.run(main())
    # nothing lost: every admitted request completed, bit-exactly
    assert len(outs) + refused == len(imgs)
    refs = _ref_outputs(compiled_plan, imgs)
    for out, ref in zip(outs, refs[:len(outs)]):
        np.testing.assert_array_equal(out, ref)
    np.testing.assert_array_equal(canary[0], refs[0])
    assert stats["kills"] == 1 and stats["respawns"] == 1
    assert stats["rerouted"] > 0                 # victims were re-routed
    assert stats["served"] == len(outs) + 2
    # the injected schedule actually happened, exactly once
    assert [(k, t) for k, t, _ in inj.injected] == [("crash_dispatch", "a")]
    assert inj.crashed == frozenset()
    # restart-from-store: the respawned gateway deserialized everything
    # its dead predecessor had compiled — zero recompiles
    assert not stats["workers"]["a"]["dead"]
    assert respawn_cache["compiles"] == 0
    assert respawn_cache["disk_hits"] > 0
    assert root.list_leases() == ["a", "b"]      # identity re-claimed


@pytest.mark.chaos
def test_chaos_respawned_gateway_is_warm(tmp_path, compiled_plan):
    """The respawn factory's gateway — built while the dead
    predecessor's lease is still on disk — compiles nothing."""
    plan, compiled = compiled_plan
    root = StoreRoot(tmp_path / "state")
    root.plans.save(plan, "cnn")
    dead = respawn_gateway(root, "a", ["cnn"],
                           AsyncServeConfig(max_batch=4))
    # first spawn on a cold store pays the compiles...
    assert dead.exec_cache.stats()["compiles"] > 0
    # ...the respawn (same process takeover, lease still on disk)
    # deserializes them all
    reborn = respawn_gateway(root, "a", ["cnn"],
                             AsyncServeConfig(max_batch=4))
    s = reborn.exec_cache.stats()
    assert s["compiles"] == 0 and s["disk_hits"] > 0
    assert reborn.lease.held
    dead.lease.release()                         # stale: token-checked
    assert root.list_leases() == ["a"]

    imgs = compiled.sample_inputs(1)

    async def main():
        async with reborn:
            return await reborn.infer(imgs[0])

    out = asyncio.run(main())
    np.testing.assert_array_equal(out, _ref_outputs(compiled_plan, imgs)[0])
