"""MoE dispatch: sort-based capacity routing vs dense oracle."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.configs import smoke_config
from repro.models import moe as moe_mod


def _cfg(top_k=2, experts=4, cf=8.0):
    cfg = smoke_config("qwen3-moe-30b-a3b").with_overrides(dtype="float32")
    return cfg.with_overrides(moe=dataclasses.replace(
        cfg.moe, num_experts=experts, top_k=top_k, capacity_factor=cf))


def test_dispatch_matches_dense_oracle():
    cfg = _cfg()
    p = moe_mod.init_moe(jax.random.PRNGKey(0), cfg)
    x = 0.1 * jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model))
    out, aux = moe_mod.moe_layer(p, x, cfg)
    ref = moe_mod.moe_layer_dense_ref(p, x, cfg)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)
    assert float(aux) >= 0


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1),
       top_k=st.integers(1, 3),
       experts=st.sampled_from([4, 8]))
def test_dispatch_property(seed, top_k, experts):
    """With generous capacity the sorted dispatch equals the dense path for
    random router/tokens."""
    cfg = _cfg(top_k=top_k, experts=experts, cf=float(experts))
    p = moe_mod.init_moe(jax.random.PRNGKey(seed), cfg)
    x = 0.1 * jax.random.normal(jax.random.PRNGKey(seed + 1),
                                (1, 12, cfg.d_model))
    out, _ = moe_mod.moe_layer(p, x, cfg)
    ref = moe_mod.moe_layer_dense_ref(p, x, cfg)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=3e-4, atol=3e-4)


def test_capacity_drops_tokens():
    """At capacity_factor→0 the layer must drop most tokens (and stay
    finite) — switch-routing semantics."""
    cfg = _cfg(cf=0.25)
    p = moe_mod.init_moe(jax.random.PRNGKey(0), cfg)
    x = 0.1 * jax.random.normal(jax.random.PRNGKey(1), (2, 32, cfg.d_model))
    out, aux = moe_mod.moe_layer(p, x, cfg)
    ref = moe_mod.moe_layer_dense_ref(p, x, cfg)
    assert bool(jnp.all(jnp.isfinite(out)))
    # dropped tokens → output differs from the no-drop oracle
    assert float(jnp.max(jnp.abs(out - ref))) > 1e-3


def test_shared_expert_path():
    cfg = smoke_config("llama4-maverick-400b-a17b") \
        .with_overrides(dtype="float32")
    cfg = cfg.with_overrides(moe=dataclasses.replace(
        cfg.moe, capacity_factor=8.0))
    p = moe_mod.init_moe(jax.random.PRNGKey(0), cfg)
    assert "shared_up" in p
    x = 0.1 * jax.random.normal(jax.random.PRNGKey(1), (1, 8, cfg.d_model))
    out, _ = moe_mod.moe_layer(p, x, cfg)
    ref = moe_mod.moe_layer_dense_ref(p, x, cfg)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


def test_aux_loss_prefers_balance():
    """Uniform routing must yield a lower aux loss than collapsed routing."""
    cfg = _cfg(top_k=1, experts=4)
    n, e = 64, 4
    balanced = jnp.tile(jnp.eye(e), (n // e, 1)) * 10.0
    collapsed = jnp.zeros((n, e)).at[:, 0].set(10.0)

    def aux_of(logits):
        probs = jax.nn.softmax(logits, axis=-1)
        _, ids = jax.lax.top_k(probs, 1)
        me = jnp.mean(probs, axis=0)
        ce = jnp.mean(jnp.sum(jax.nn.one_hot(ids, e), axis=1), axis=0)
        return float(e * jnp.sum(me * ce))

    assert aux_of(balanced) < aux_of(collapsed)


def test_grouped_routing_matches_dense_oracle():
    """§Perf B2 path: group-local routing == dense oracle at high cap."""
    cfg = _cfg(cf=8.0).with_overrides(moe_groups=4)
    p = moe_mod.init_moe(jax.random.PRNGKey(0), cfg)
    x = 0.1 * jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model))
    out, aux = moe_mod.moe_layer(p, x, cfg)
    ref = moe_mod.moe_layer_dense_ref(p, x, cfg)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


def test_shardmap_dispatch_combine_multidevice():
    """§Perf B4/B6 path on a real (4,2) mesh: shard_map dispatch/combine
    == dense oracle, and gradients flow (subprocess, 8 host devices)."""
    import subprocess
    import sys
    import textwrap
    prog = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import sys
        sys.path.insert(0, "src")
        import dataclasses, jax, jax.numpy as jnp
        from repro.configs import smoke_config
        from repro.models import moe as moe_mod
        cfg = smoke_config('qwen3-moe-30b-a3b').with_overrides(
            dtype='float32')
        cfg = cfg.with_overrides(
            moe=dataclasses.replace(cfg.moe, capacity_factor=8.0),
            moe_groups=4, moe_combine_shardmap=True, moe_shard_hints=True)
        p = moe_mod.init_moe(jax.random.PRNGKey(0), cfg)
        x = 0.1 * jax.random.normal(jax.random.PRNGKey(1),
                                    (4, 16, cfg.d_model))
        mesh = jax.make_mesh((4, 2), ('data', 'model'))
        with mesh:
            out, _ = jax.jit(lambda p, x: moe_mod.moe_layer(p, x, cfg))(p, x)
            g = jax.jit(jax.grad(
                lambda p, x: moe_mod.moe_layer(p, x, cfg)[0].sum()))(p, x)
        ref = moe_mod.moe_layer_dense_ref(p, x, cfg)
        err = float(jnp.max(jnp.abs(out - ref)))
        gn = sum(float(jnp.sum(jnp.abs(l))) for l in jax.tree.leaves(g))
        assert err < 5e-3, err
        assert gn > 0
        print("SHARDMAP_MOE_OK", err)
    """)
    out = subprocess.run([sys.executable, "-c", prog], cwd=".",
                         capture_output=True, text=True, timeout=600)
    assert "SHARDMAP_MOE_OK" in out.stdout, out.stdout + out.stderr
