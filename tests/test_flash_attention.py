"""Flash-attention Pallas kernel vs the naive oracle: shapes / GQA /
causal sweep (interpret mode)."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import flash_attention
from tests.test_attention import naive_attention


@pytest.mark.parametrize("h,kh", [(4, 4), (4, 2), (8, 1)])
@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("s,t", [(128, 128), (256, 256)])
def test_flash_matches_naive(h, kh, causal, s, t):
    rng = np.random.default_rng(h * 100 + s + causal)
    b, d = 2, 32
    q = jnp.asarray(rng.normal(size=(b, s, h, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, t, kh, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, t, kh, d)), jnp.float32)
    out = flash_attention(q, k, v, causal=causal, block_q=64, block_k=64)
    ref = naive_attention(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


def test_flash_block_shapes():
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(size=(1, 256, 2, 16)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, 256, 2, 16)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(1, 256, 2, 16)), jnp.float32)
    ref = naive_attention(q, k, v, causal=True)
    for bq, bk in [(32, 128), (128, 32), (256, 256)]:
        out = flash_attention(q, k, v, causal=True, block_q=bq, block_k=bk)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-4, atol=2e-4)


def test_flash_bf16_io():
    rng = np.random.default_rng(1)
    q = jnp.asarray(rng.normal(size=(1, 128, 2, 16)), jnp.bfloat16)
    k = jnp.asarray(rng.normal(size=(1, 128, 2, 16)), jnp.bfloat16)
    v = jnp.asarray(rng.normal(size=(1, 128, 2, 16)), jnp.bfloat16)
    out = flash_attention(q, k, v, causal=True)
    ref = naive_attention(q.astype(jnp.float32), k.astype(jnp.float32),
                          v.astype(jnp.float32), causal=True)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref), rtol=2e-2, atol=2e-2)
