"""Pipeline parallelism: shard_map GPipe schedule == sequential reference
(subprocess with 4 host devices)."""

import subprocess
import sys
import textwrap

from repro.parallel.pipeline import bubble_fraction


def test_bubble_fraction():
    assert bubble_fraction(1, 8) == 0.0
    assert abs(bubble_fraction(4, 8) - 3 / 11) < 1e-9


def test_pipeline_matches_sequential():
    prog = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import sys
        sys.path.insert(0, "src")
        import jax, jax.numpy as jnp
        import numpy as np
        from repro.parallel.pipeline import pipeline_forward

        S, M, mb, d = 4, 8, 2, 16
        rng = np.random.default_rng(0)
        # one linear layer per stage
        W = jnp.asarray(rng.normal(size=(S, d, d)) / np.sqrt(d),
                        jnp.float32)
        xs = jnp.asarray(rng.normal(size=(M, mb, d)), jnp.float32)

        def stage_fn(w, x):
            return jnp.tanh(x @ w)

        mesh = jax.make_mesh((4,), ("pipe",))
        out = pipeline_forward(stage_fn, W, xs, mesh=mesh, axis="pipe")

        # sequential reference
        ref = xs
        for s in range(S):
            ref = jnp.tanh(ref @ W[s])
        err = float(jnp.max(jnp.abs(out - ref)))
        assert err < 1e-5, err
        print("PIPELINE_OK", err)
    """)
    out = subprocess.run([sys.executable, "-c", prog], cwd=".",
                         capture_output=True, text=True, timeout=300)
    assert "PIPELINE_OK" in out.stdout, out.stdout + out.stderr
