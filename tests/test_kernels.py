"""Per-kernel allclose vs the pure-jnp oracle, swept over shapes/bits."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.blocks import get_block, list_blocks
from repro.kernels import conv2d, ops

BITS = st.integers(min_value=3, max_value=16)


def _rand_data(rng, bits, shape):
    lo, hi = -(1 << (bits - 1)), (1 << (bits - 1)) - 1
    return ops.quantize_fixed(
        jnp.asarray(rng.integers(lo, hi + 1, shape), jnp.float32), bits)


@pytest.mark.parametrize("block", ["conv1", "conv2", "conv3", "conv4"])
@pytest.mark.parametrize("db,cb", [(3, 3), (4, 8), (8, 4), (8, 8),
                                   (9, 9), (12, 5), (16, 16)])
def test_block_matches_oracle(block, db, cb):
    rng = np.random.default_rng(db * 100 + cb)
    blk = get_block(block)
    x = _rand_data(rng, db, (64, 128))
    w = _rand_data(rng, cb, blk.weight_shape(cb))
    y = blk.apply(x, w, data_bits=db, coeff_bits=cb)
    yr = blk.reference(x, w)
    np.testing.assert_array_equal(np.asarray(y), np.asarray(yr))


@pytest.mark.parametrize("tile_h", [8, 16, 32])
def test_tile_shapes(tile_h):
    rng = np.random.default_rng(tile_h)
    blk = get_block("conv2")
    x = _rand_data(rng, 8, (64, 128))
    w = _rand_data(rng, 8, (3, 3))
    y = blk.apply(x, w, data_bits=8, coeff_bits=8, tile_h=tile_h)
    yr = blk.reference(x, w)
    np.testing.assert_array_equal(np.asarray(y), np.asarray(yr))


@settings(max_examples=20, deadline=None)
@given(db=BITS, cb=BITS, seed=st.integers(0, 2**31 - 1))
def test_conv3_packing_property(db, cb, seed):
    """conv3 (packed or fallback) always equals the oracle — the packing
    split must be exact for every representable operand pair."""
    rng = np.random.default_rng(seed)
    blk = get_block("conv3")
    x = _rand_data(rng, db, (16, 128))
    w = _rand_data(rng, cb, (2, 3, 3))
    y = blk.apply(x, w, data_bits=db, coeff_bits=cb)
    yr = blk.reference(x, w)
    np.testing.assert_array_equal(np.asarray(y), np.asarray(yr))


def test_packed_regime_boundary():
    assert conv2d.conv3_packed_ok(6, 6)
    assert conv2d.conv3_packed_ok(8, 4)
    assert not conv2d.conv3_packed_ok(8, 8)
    assert not conv2d.conv3_packed_ok(16, 16)
    blk = get_block("conv3")
    assert blk.packed_ok(6, 6) and not blk.packed_ok(8, 8)
    assert all(not get_block(b).packed_ok(4, 4)
               for b in list_blocks() if b != "conv3")


def test_deprecated_conv_block_shim():
    """ops.conv_block survives only as a deprecated string-dispatch shim
    over the registry; it must warn and stay bit-exact."""
    rng = np.random.default_rng(7)
    x = _rand_data(rng, 8, (32, 128))
    w = _rand_data(rng, 8, (3, 3))
    with pytest.warns(DeprecationWarning):
        y = ops.conv_block("conv2", x, w, data_bits=8, coeff_bits=8)
    with pytest.warns(DeprecationWarning):
        yr = ops.conv_block_ref("conv2", x, w)
    np.testing.assert_array_equal(np.asarray(y), np.asarray(yr))
    with pytest.raises(ValueError, match="unknown block"):  # seed contract
        with pytest.warns(DeprecationWarning):
            ops.conv_block("conv9", x, w, data_bits=8, coeff_bits=8)


@pytest.mark.parametrize("s,c,k", [(16, 8, 4), (37, 64, 4), (128, 128, 2)])
def test_conv1d_matches_oracle(s, c, k):
    rng = np.random.default_rng(s + c)
    x = jnp.asarray(rng.normal(size=(2, s, c)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(k, c)).astype(np.float32))
    y = ops.causal_conv1d(x, w)
    yr = ops.causal_conv1d_ref(x, w)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                               rtol=1e-5, atol=1e-5)


def test_conv1d_matches_model_path():
    """kernels/conv1d == models/ssm.causal_conv1d (pre-activation)."""
    import jax

    from repro.models.ssm import causal_conv1d as model_conv
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(2, 33, 16)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(4, 16)).astype(np.float32))
    y_kernel = jax.nn.silu(ops.causal_conv1d(x, w))
    y_model, _ = model_conv(x, w)          # model applies silu
    np.testing.assert_allclose(np.asarray(y_kernel), np.asarray(y_model),
                               rtol=1e-4, atol=1e-5)
