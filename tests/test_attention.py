"""Attention correctness: chunked-vs-naive, GQA, sliding window, softcap,
decode valid-length masking."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.attention import multi_head_attention


def naive_attention(q, k, v, *, causal, window=None, cap=None):
    b, s, h, d = q.shape
    t, kh = k.shape[1], k.shape[2]
    g = h // kh
    kk = jnp.repeat(k, g, axis=2)
    vv = jnp.repeat(v, g, axis=2)
    logits = jnp.einsum("bshd,bthd->bhst", q.astype(jnp.float32),
                        kk.astype(jnp.float32)) / (d ** 0.5)
    if cap is not None:
        logits = cap * jnp.tanh(logits / cap)
    rows = jnp.arange(s)[:, None]
    cols = jnp.arange(t)[None, :]
    mask = jnp.ones((s, t), bool)
    if causal:
        mask &= cols <= rows
    if window is not None:
        mask &= cols > rows - window
    logits = jnp.where(mask[None, None], logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhst,bthd->bshd", p, vv.astype(jnp.float32))


@pytest.mark.parametrize("h,kh", [(4, 4), (4, 2), (8, 1)])
@pytest.mark.parametrize("causal,window,cap", [
    (True, None, None), (True, 8, None), (True, None, 50.0),
    (False, None, None)])
def test_chunked_matches_naive(h, kh, causal, window, cap):
    rng = np.random.default_rng(0)
    b, s, d = 2, 64, 16
    q = jnp.asarray(rng.normal(size=(b, s, h, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, s, kh, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, s, kh, d)), jnp.float32)
    out = multi_head_attention(q, k, v, causal=causal, window=window,
                               cap=cap, q_chunk=16)
    ref = naive_attention(q, k, v, causal=causal, window=window, cap=cap)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


def test_decode_valid_len_masks_stale_cache():
    """Garbage beyond kv_valid_len must not leak into decode attention."""
    rng = np.random.default_rng(1)
    b, t, kh, d = 2, 32, 2, 16
    q = jnp.asarray(rng.normal(size=(b, 1, 4, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, t, kh, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, t, kh, d)), jnp.float32)
    valid = 10
    poisoned_k = k.at[:, valid:].set(1e4)
    poisoned_v = v.at[:, valid:].set(1e4)
    out = multi_head_attention(q, poisoned_k, poisoned_v, causal=False,
                               q_offset=valid - 1, kv_valid_len=valid)
    ref = naive_attention(q, k[:, :valid], v[:, :valid], causal=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


def test_non_divisible_chunking():
    rng = np.random.default_rng(2)
    q = jnp.asarray(rng.normal(size=(1, 48, 4, 8)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, 48, 4, 8)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(1, 48, 4, 8)), jnp.float32)
    out = multi_head_attention(q, k, v, causal=True, q_chunk=32)
    ref = naive_attention(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)
