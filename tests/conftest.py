import os
import sys

# tests see the default single host device (the dry-run sets its own flags
# in a separate process); keep determinism + quiet logs
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.dirname(__file__))   # for hypothesis_compat
