"""Golden regression for the serialized DeploymentPlan: the JSON plan
artifact is a cross-machine deployment contract, so its schema must not
drift silently.  If a change is *intentional*, bump
``deploy.PLAN_SCHEMA_VERSION`` and regenerate the fixtures:

    PYTHONPATH=src python tests/test_plan_golden.py

(mirrors the ``SWEEP_SCHEMA_VERSION`` / synth_golden.json pattern).
The golden plans are hand-constructed with pinned demand numbers — they
do not depend on the sweep or the fitted models, so they only move when
the schema itself does.

Three fixtures:

* ``plan_golden.json``      — the v2 CNN plan (regenerated on bumps)
* ``plan_moe_golden.json``  — the v2 MoE plan (regenerated on bumps)
* ``plan_v1_golden.json``   — the **frozen** v1 payload; never
  regenerated.  The upgrade tests pin that a v1 plan loads into the
  exact same in-memory plan as the v2 fixture: same dataclass equality,
  same ``plan_config``, same per-layer executable-cache keys — the
  "v1 plans load unchanged" contract.
"""

import json
from pathlib import Path

import pytest

from repro.core import deploy
from repro.core.allocate import DeviceProfile
from repro.core.cnn import CNNConfig, ConvLayerSpec
from repro.core.deploy import DeploymentPlan, LayerAssignment
from repro.runtime.compiled import CompiledCNN
from repro.runtime.workloads import MoELayerSpec, MoEWorkloadSpec

GOLDEN = Path(__file__).parent / "golden" / "plan_golden.json"
GOLDEN_V1 = Path(__file__).parent / "golden" / "plan_v1_golden.json"
GOLDEN_MOE = Path(__file__).parent / "golden" / "plan_moe_golden.json"


def _golden_plan() -> DeploymentPlan:
    """A fully-populated CNN plan with pinned values covering every
    schema field: custom device, two layers (one block-pinned),
    fractional demand, quant_error set, embedded network config."""
    device = DeviceProfile(
        name="golden-dev", cost=0.75,
        budgets={"hbm_bytes": 1000.0, "mxu_cost": 2000.0,
                 "vmem_bytes": 4096.0, "vpu_ops": 500.0},
        description="pinned fixture device")
    layers = (
        LayerAssignment(index=0, block="conv4", data_bits=8, coeff_bits=6,
                        calls=2,
                        demand={"hbm_bytes": 12.5, "mxu_cost": 100.25,
                                "vmem_bytes": 2048.0, "vpu_ops": 3.0}),
        LayerAssignment(index=1, block="conv1", data_bits=6, coeff_bits=4,
                        calls=8,
                        demand={"hbm_bytes": 40.0, "mxu_cost": 0.0,
                                "vmem_bytes": 1024.0, "vpu_ops": 44.75}),
    )
    cnn = CNNConfig(layers=(
        ConvLayerSpec(1, 4, data_bits=8, coeff_bits=6, shift=7),
        ConvLayerSpec(4, 2, data_bits=6, coeff_bits=4, shift=5,
                      block="conv1"),
    ), img_h=16, img_w=64)
    return DeploymentPlan(
        device=device, target=0.8, layers=layers,
        demand={"hbm_bytes": 52.5, "mxu_cost": 100.25,
                "vmem_bytes": 2048.0, "vpu_ops": 47.75},
        usage_pct={"hbm_bytes": 5.25, "mxu_cost": 5.0125,
                   "vmem_bytes": 50.0, "vpu_ops": 9.55},
        convs_per_step=1.6, feasible=True, quant_error=0.0421, cnn=cnn)


def _golden_moe_plan() -> DeploymentPlan:
    """A pinned MoE plan covering the non-CNN workload envelope: two
    layers at different planned precisions, shared experts on one."""
    device = DeviceProfile(
        name="golden-dev", cost=0.75,
        budgets={"hbm_bytes": 1000.0, "mxu_cost": 2000.0,
                 "vmem_bytes": 4096.0, "vpu_ops": 500.0},
        description="pinned fixture device")
    layers = (
        LayerAssignment(index=0, block="moe_ffn", data_bits=8,
                        coeff_bits=8, calls=16,
                        demand={"hbm_bytes": 60.5, "mxu_cost": 800.0,
                                "vmem_bytes": 512.0, "vpu_ops": 96.0}),
        LayerAssignment(index=1, block="moe_ffn", data_bits=6,
                        coeff_bits=4, calls=16,
                        demand={"hbm_bytes": 30.25, "mxu_cost": 800.0,
                                "vmem_bytes": 512.0, "vpu_ops": 96.0}),
    )
    workload = MoEWorkloadSpec(
        layers=(
            MoELayerSpec(d_ff_expert=16, num_experts=4, top_k=2,
                         data_bits=8, coeff_bits=8,
                         n_shared_experts=1, capacity_factor=2.0),
            MoELayerSpec(d_ff_expert=16, num_experts=4, top_k=2,
                         data_bits=6, coeff_bits=4,
                         capacity_factor=1.5),
        ), d_model=8, seq_len=8, act="silu", mlp_gated=True)
    return DeploymentPlan(
        device=device, target=0.8, layers=layers,
        demand={"hbm_bytes": 90.75, "mxu_cost": 1600.0,
                "vmem_bytes": 512.0, "vpu_ops": 192.0},
        usage_pct={"hbm_bytes": 9.075, "mxu_cost": 80.0,
                   "vmem_bytes": 12.5, "vpu_ops": 38.4},
        convs_per_step=8.0, feasible=True, quant_error=0.0123,
        cnn=None, workload=workload)


def test_golden_fixture_matches_schema_version():
    assert json.loads(GOLDEN.read_text())["version"] \
        == deploy.PLAN_SCHEMA_VERSION, (
        "PLAN_SCHEMA_VERSION changed — regenerate the golden fixture "
        "(PYTHONPATH=src python tests/test_plan_golden.py)")
    assert json.loads(GOLDEN_MOE.read_text())["version"] \
        == deploy.PLAN_SCHEMA_VERSION


def test_plan_serialization_matches_golden():
    """to_json of the pinned plans must byte-match the fixtures: any
    field added, renamed, or re-typed is a schema change and needs a
    PLAN_SCHEMA_VERSION bump + fixture regeneration."""
    assert _golden_plan().to_json() + "\n" == GOLDEN.read_text(), (
        "serialized plan drifted from tests/golden/plan_golden.json — "
        "if intentional, bump PLAN_SCHEMA_VERSION and regenerate")
    assert _golden_moe_plan().to_json() + "\n" == GOLDEN_MOE.read_text(), (
        "serialized MoE plan drifted from plan_moe_golden.json — "
        "if intentional, bump PLAN_SCHEMA_VERSION and regenerate")


def test_golden_fixture_round_trips():
    plan = DeploymentPlan.from_json(GOLDEN.read_text())
    assert plan == _golden_plan()
    assert DeploymentPlan.from_json(plan.to_json()) == plan


def test_moe_golden_round_trips():
    plan = DeploymentPlan.from_json(GOLDEN_MOE.read_text())
    assert plan == _golden_moe_plan()
    assert plan.cnn is None
    assert plan.workload.kind == "moe"
    assert DeploymentPlan.from_json(plan.to_json()) == plan


def test_wrong_schema_version_rejected():
    payload = json.loads(GOLDEN.read_text())
    payload["version"] = deploy.PLAN_SCHEMA_VERSION + 1
    with pytest.raises(ValueError, match="schema version"):
        DeploymentPlan.from_json(json.dumps(payload))
    with pytest.raises(ValueError, match="schema version"):
        DeploymentPlan.from_json("{}")      # pre-versioning payload


# ---------------------------------------------------------------------------
# v1 → v2 upgrade: the frozen v1 payload must load bit-identically
# ---------------------------------------------------------------------------

def test_v1_fixture_is_frozen_at_version_1():
    assert json.loads(GOLDEN_V1.read_text())["version"] == 1, (
        "plan_v1_golden.json is the frozen v1 upgrade input — it must "
        "NEVER be regenerated")


def test_v1_plan_upgrades_to_identical_plan():
    """The whole back-compat contract in one assert: loading the frozen
    v1 payload yields the same in-memory plan as the pinned v2 plan —
    every field, including the embedded CNNConfig (``workload`` stays
    None; CNN plans keep the legacy ``cnn`` field either way)."""
    v1 = DeploymentPlan.from_json(GOLDEN_V1.read_text())
    assert v1 == _golden_plan()
    assert v1.workload is None and v1.cnn is not None
    # re-serializing writes the *current* schema
    assert json.loads(v1.to_json())["version"] == deploy.PLAN_SCHEMA_VERSION
    assert DeploymentPlan.from_json(v1.to_json()) == v1


def test_v1_plan_same_plan_config_and_cache_keys():
    """An upgraded v1 plan must compile to byte-identical executables:
    same ``plan_config`` output and same per-layer ``ExecutableCache``
    keys as the v2 plan (so a fleet mid-upgrade shares its cache)."""
    v1 = DeploymentPlan.from_json(GOLDEN_V1.read_text())
    v2 = DeploymentPlan.from_json(GOLDEN.read_text())
    assert deploy.plan_config(v1) == deploy.plan_config(v2)
    c1 = CompiledCNN.from_plan(v1, max_batch=2, warmup=False)
    c2 = CompiledCNN.from_plan(v2, max_batch=2, warmup=False)
    keys1 = [c1._layer_key(i, b)
             for i in range(c1.num_layers) for b in c1.buckets]
    keys2 = [c2._layer_key(i, b)
             for i in range(c2.num_layers) for b in c2.buckets]
    assert keys1 == keys2


if __name__ == "__main__":                  # regenerate the v2 fixtures
    GOLDEN.write_text(_golden_plan().to_json() + "\n")
    GOLDEN_MOE.write_text(_golden_moe_plan().to_json() + "\n")
    print(f"wrote {GOLDEN} and {GOLDEN_MOE} at schema "
          f"v{deploy.PLAN_SCHEMA_VERSION} "
          f"({GOLDEN_V1} stays frozen at v1)")
