"""Golden regression for the serialized DeploymentPlan: the JSON plan
artifact is a cross-machine deployment contract, so its schema must not
drift silently.  If a change is *intentional*, bump
``deploy.PLAN_SCHEMA_VERSION`` and regenerate the fixture:

    PYTHONPATH=src python tests/test_plan_golden.py

(mirrors the ``SWEEP_SCHEMA_VERSION`` / synth_golden.json pattern).
The golden plan is hand-constructed with pinned demand numbers — it
does not depend on the sweep or the fitted models, so it only moves
when the schema itself does."""

import json
from pathlib import Path

import pytest

from repro.core import deploy
from repro.core.allocate import DeviceProfile
from repro.core.cnn import CNNConfig, ConvLayerSpec
from repro.core.deploy import DeploymentPlan, LayerAssignment

GOLDEN = Path(__file__).parent / "golden" / "plan_golden.json"


def _golden_plan() -> DeploymentPlan:
    """A fully-populated plan with pinned values covering every schema
    field: custom device, two layers (one block-pinned), fractional
    demand, quant_error set, embedded network config."""
    device = DeviceProfile(
        name="golden-dev", cost=0.75,
        budgets={"hbm_bytes": 1000.0, "mxu_cost": 2000.0,
                 "vmem_bytes": 4096.0, "vpu_ops": 500.0},
        description="pinned fixture device")
    layers = (
        LayerAssignment(index=0, block="conv4", data_bits=8, coeff_bits=6,
                        calls=2,
                        demand={"hbm_bytes": 12.5, "mxu_cost": 100.25,
                                "vmem_bytes": 2048.0, "vpu_ops": 3.0}),
        LayerAssignment(index=1, block="conv1", data_bits=6, coeff_bits=4,
                        calls=8,
                        demand={"hbm_bytes": 40.0, "mxu_cost": 0.0,
                                "vmem_bytes": 1024.0, "vpu_ops": 44.75}),
    )
    cnn = CNNConfig(layers=(
        ConvLayerSpec(1, 4, data_bits=8, coeff_bits=6, shift=7),
        ConvLayerSpec(4, 2, data_bits=6, coeff_bits=4, shift=5,
                      block="conv1"),
    ), img_h=16, img_w=64)
    return DeploymentPlan(
        device=device, target=0.8, layers=layers,
        demand={"hbm_bytes": 52.5, "mxu_cost": 100.25,
                "vmem_bytes": 2048.0, "vpu_ops": 47.75},
        usage_pct={"hbm_bytes": 5.25, "mxu_cost": 5.0125,
                   "vmem_bytes": 50.0, "vpu_ops": 9.55},
        convs_per_step=1.6, feasible=True, quant_error=0.0421, cnn=cnn)


def test_golden_fixture_matches_schema_version():
    assert json.loads(GOLDEN.read_text())["version"] \
        == deploy.PLAN_SCHEMA_VERSION, (
        "PLAN_SCHEMA_VERSION changed — regenerate the golden fixture "
        "(PYTHONPATH=src python tests/test_plan_golden.py)")


def test_plan_serialization_matches_golden():
    """to_json of the pinned plan must byte-match the fixture: any field
    added, renamed, or re-typed is a schema change and needs a
    PLAN_SCHEMA_VERSION bump + fixture regeneration."""
    assert _golden_plan().to_json() + "\n" == GOLDEN.read_text(), (
        "serialized plan drifted from tests/golden/plan_golden.json — "
        "if intentional, bump PLAN_SCHEMA_VERSION and regenerate")


def test_golden_fixture_round_trips():
    plan = DeploymentPlan.from_json(GOLDEN.read_text())
    assert plan == _golden_plan()
    assert DeploymentPlan.from_json(plan.to_json()) == plan


def test_wrong_schema_version_rejected():
    payload = json.loads(GOLDEN.read_text())
    payload["version"] = deploy.PLAN_SCHEMA_VERSION + 1
    with pytest.raises(ValueError, match="schema version"):
        DeploymentPlan.from_json(json.dumps(payload))
    with pytest.raises(ValueError, match="schema version"):
        DeploymentPlan.from_json("{}")      # pre-versioning payload


if __name__ == "__main__":                  # regenerate the fixture
    GOLDEN.write_text(_golden_plan().to_json() + "\n")
    print(f"wrote {GOLDEN} at schema v{deploy.PLAN_SCHEMA_VERSION}")
