"""End-to-end system test: train a tiny model → checkpoint → restore →
serve from the trained weights (the full paper-framework lifecycle)."""

import jax
import jax.numpy as jnp

from repro.configs import smoke_config
from repro.data import DataConfig
from repro.models import build_model
from repro.serve import Engine, Request, ServeConfig
from repro.train.checkpoint import Checkpointer
from repro.train.loop import TrainConfig, train


def test_train_checkpoint_serve_lifecycle(tmp_path):
    cfg = smoke_config("gemma2-2b")
    model = build_model(cfg)
    data_cfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=32,
                          global_batch=4)
    tcfg = TrainConfig(steps=15, lr=1e-3, log_every=5, ckpt_every=10,
                       ckpt_dir=str(tmp_path))
    params, _, history = train(model, data_cfg, tcfg, log=lambda *a: None)
    assert history[-1]["loss"] < history[0]["loss"]

    # restore from the committed checkpoint into fresh abstract state
    ck = Checkpointer(tmp_path)
    from repro.optim import AdamWConfig, adamw_init
    template = {"params": jax.eval_shape(
        lambda: model.init(jax.random.PRNGKey(0))),
        "opt": jax.eval_shape(
            lambda: adamw_init(model.init(jax.random.PRNGKey(0)),
                               AdamWConfig()))}
    step, state = ck.restore(template)
    assert step == 15

    # serve from restored params
    eng = Engine(model, state["params"], ServeConfig(
        max_batch=2, max_len=48, max_new_tokens=4))
    req = Request(prompt=[3, 1, 4, 1, 5, 9, 2, 6])
    eng.run([req])
    assert len(req.out_tokens) == 4
    assert all(0 <= t < cfg.vocab_size for t in req.out_tokens)
