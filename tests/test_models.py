"""Per-architecture smoke tests: reduced config, one forward/train step on
CPU, asserting output shapes and finiteness (assignment requirement)."""

import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config, list_archs, smoke_config
from repro.models import build_model

ARCHS = [a for a in list_archs()]


def _batch(cfg, b=2, s=32, seed=0):
    key = jax.random.PRNGKey(seed)
    toks = jax.random.randint(key, (b, s), 0, cfg.vocab_size)
    batch = {"tokens": toks, "labels": jnp.roll(toks, -1, axis=1)}
    if cfg.frontend == "vision":
        batch["patches"] = 0.05 * jax.random.normal(
            jax.random.PRNGKey(1), (b, cfg.frontend_len, cfg.d_model),
            cfg.jnp_dtype)
    if cfg.enc_dec:
        batch["frames"] = 0.05 * jax.random.normal(
            jax.random.PRNGKey(2), (b, cfg.frontend_len, cfg.d_model),
            cfg.jnp_dtype)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_train_step(arch):
    cfg = smoke_config(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch(cfg)
    loss, metrics = jax.jit(model.forward_train)(params, batch)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), f"{arch}: non-finite loss {loss}"
    assert float(metrics["nll"]) > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_grad_finite(arch):
    cfg = smoke_config(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch(cfg)
    grads = jax.grad(lambda p: model.forward_train(p, batch)[0])(params)
    leaves = jax.tree.leaves(grads)
    assert leaves
    for g in leaves:
        assert bool(jnp.all(jnp.isfinite(g))), f"{arch}: non-finite grad"


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_prefill_shapes(arch):
    cfg = smoke_config(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch(cfg)
    del batch["labels"]
    logits, cache = jax.jit(model.prefill)(params, batch)
    assert logits.shape == (2, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))
    assert jax.tree.leaves(cache), f"{arch}: empty cache"


def test_full_configs_match_assignment():
    """Exact assigned hyperparameters (spot checks)."""
    q = get_config("qwen3-moe-30b-a3b")
    assert (q.n_layers, q.d_model, q.n_heads, q.n_kv_heads) == \
        (48, 2048, 32, 4)
    assert q.moe.num_experts == 128 and q.moe.top_k == 8
    assert q.vocab_size == 151936

    g = get_config("granite-20b")
    assert (g.n_layers, g.d_model, g.n_heads, g.n_kv_heads) == \
        (52, 6144, 48, 1)

    j = get_config("jamba-1.5-large-398b")
    assert j.n_layers == 72 and j.moe.num_experts == 16
    # 1:7 attention:mamba ratio in the cycle
    kinds = [s.mixer for s in j.layer_cycle]
    assert kinds.count("attn") == 1 and kinds.count("mamba") == 7

    m = get_config("mamba2-1.3b")
    assert m.ssm.state_dim == 128 and m.n_heads == 0

    for name in ("gemma2-9b", "gemma2-2b"):
        g2 = get_config(name)
        assert g2.attn_softcap == 50.0 and g2.final_softcap == 30.0
        assert [s.mixer for s in g2.layer_cycle] == ["local", "attn"]


def test_param_counts_near_names():
    expect = {"qwen3-moe-30b-a3b": 30e9, "llama4-maverick-400b-a17b": 400e9,
              "pixtral-12b": 12e9, "granite-20b": 20e9, "gemma2-9b": 9e9,
              "llama3.2-3b": 3.2e9, "gemma2-2b": 2.6e9,
              "jamba-1.5-large-398b": 398e9, "mamba2-1.3b": 1.3e9}
    for arch, n in expect.items():
        got = get_config(arch).param_count()
        assert 0.7 * n < got < 1.45 * n, (arch, got, n)
