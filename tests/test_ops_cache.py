"""``repro.ops.PersistentExecutableCache`` + the single-flight
``ExecutableCache``: warm restarts deserialize instead of compiling,
stale/corrupt entries fall back silently, and concurrent builders of
one key coalesce into a single compile."""

import pickle
import threading

import numpy as np
import pytest

from repro.core import deploy
from repro.core.cnn import CNNConfig, ConvLayerSpec, fitted_block_models
from repro.ops import (CACHE_FORMAT_VERSION, PersistentExecutableCache,
                       cache_fingerprint)
from repro.runtime import CompiledCNN, ExecutableCache


def _cfg():
    return CNNConfig(layers=(
        ConvLayerSpec(1, 4, data_bits=8, coeff_bits=6, block="conv4"),
        ConvLayerSpec(4, 3, data_bits=6, coeff_bits=4, block="conv3"),
    ), img_h=16, img_w=64)


@pytest.fixture(scope="module")
def plan():
    return deploy.plan_deployment(_cfg(), fitted_block_models(),
                                  target=0.8, on_infeasible="fallback")


# ---------------------------------------------------------------------------
# single-flight compilation (in-memory tier)
# ---------------------------------------------------------------------------

def test_single_flight_counting_build():
    """N threads racing one missing key must call the build fn once;
    the losers wait and reuse (``coalesced`` counts them).  The build
    is held open until every loser is provably parked in the wait, so
    the coalescing path is exercised deterministically."""
    import time

    cache = ExecutableCache()
    calls = []
    building = threading.Event()
    release = threading.Event()

    def build():
        calls.append(1)                # only the winner runs this
        building.set()
        release.wait(timeout=10)
        return "the-executable"

    results = []

    def racer():
        results.append(cache.get_or_build(("k",), build))

    winner = threading.Thread(target=racer)
    winner.start()
    assert building.wait(timeout=10)   # the key is now claimed
    losers = [threading.Thread(target=racer) for _ in range(4)]
    for t in losers:
        t.start()
    deadline = time.monotonic() + 10   # all four must reach the wait
    while cache.stats()["coalesced"] < 4:
        assert time.monotonic() < deadline, "losers never coalesced"
        time.sleep(0.005)
    release.set()                      # let the winning build finish
    for t in [winner] + losers:
        t.join(timeout=10)
    assert results == ["the-executable"] * 5
    assert len(calls) == 1
    s = cache.stats()
    assert s["compiles"] == 1 and s["coalesced"] >= 4


def test_single_flight_failed_build_releases_waiters():
    """A failing producer must not wedge the key: waiters retry and one
    of them becomes the next builder."""
    cache = ExecutableCache()
    attempts = []

    def flaky():
        attempts.append(1)
        if len(attempts) == 1:
            raise RuntimeError("first build dies")
        return "ok"

    with pytest.raises(RuntimeError, match="first build dies"):
        cache.get_or_build(("k",), flaky)
    assert cache.get_or_build(("k",), flaky) == "ok"
    assert len(attempts) == 2 and ("k",) in cache


def test_cache_on_event_observer():
    cache = ExecutableCache()
    seen = []
    cache.on_event = lambda ev, fields: seen.append((ev, fields))
    cache.get_or_build(("k",), lambda: "x")
    assert [e for e, _ in seen] == ["cache_compile"]
    assert seen[0][1]["seconds"] >= 0
    # observer exceptions never reach the caller
    cache.on_event = lambda ev, fields: 1 / 0
    assert cache.get_or_build(("k2",), lambda: "y") == "y"


# ---------------------------------------------------------------------------
# persistent tier: warm restart skips the compiler
# ---------------------------------------------------------------------------

def test_warm_restart_zero_recompiles(tmp_path, plan):
    cold_cache = PersistentExecutableCache(tmp_path)
    cold = CompiledCNN.from_plan(plan, _cfg(), max_batch=2,
                                 exec_cache=cold_cache)
    assert cold.compiles > 0
    assert cold_cache.stats()["disk_stores"] == cold.compiles
    assert cold_cache.stats()["disk_hits"] == 0

    warm_cache = PersistentExecutableCache(tmp_path)  # "new process"
    warm = CompiledCNN.from_plan(plan, _cfg(), max_batch=2,
                                 exec_cache=warm_cache)
    assert warm.compiles == 0          # the acceptance headline
    s = warm_cache.stats()
    assert s["compiles"] == 0
    assert s["disk_hits"] == cold_cache.stats()["disk_stores"]
    assert warm.warmed_up

    x = np.stack([np.asarray(i, cold.in_dtype)
                  for i in cold.sample_inputs(2, seed=3)])
    np.testing.assert_array_equal(np.asarray(cold(x)), np.asarray(warm(x)))


def test_fingerprint_mismatch_falls_back_to_compile(tmp_path, plan):
    cold = PersistentExecutableCache(tmp_path)
    CompiledCNN.from_plan(plan, _cfg(), max_batch=1, exec_cache=cold)
    stored = cold.stats()["disk_stores"]
    assert stored > 0

    alien = PersistentExecutableCache(tmp_path)
    alien.fingerprint = ("other-jax", "other-backend")  # env changed
    CompiledCNN.from_plan(plan, _cfg(), max_batch=1, exec_cache=alien)
    s = alien.stats()
    assert s["disk_hits"] == 0         # mismatched entries ignored
    assert s["compiles"] > 0           # silent fallback to live compile


def test_fingerprint_drift_at_same_path_is_quarantined_not_loaded(
        tmp_path, plan):
    """Env-fingerprint drift under an *unchanged* entry path (a cache
    dir carried across builds whose key scheme coincided): the embedded
    fingerprint is the authority — the entry is quarantined as
    ``*.stale`` and recompiled; its payload is never deserialized (it
    is poisoned here, so any attempt would raise)."""
    cold = PersistentExecutableCache(tmp_path)
    CompiledCNN.from_plan(plan, _cfg(), max_batch=1, exec_cache=cold)
    entries = sorted(tmp_path.glob("*.exe"))
    assert entries
    for p in entries:
        entry = pickle.loads(p.read_bytes())
        entry["fingerprint"] = ("drifted-jax", "drifted-backend")
        entry["payload"] = b"not a serialized executable"
        p.write_bytes(pickle.dumps(entry))

    events = []
    warm = PersistentExecutableCache(tmp_path)
    warm.on_event = lambda ev, fields: events.append(ev)
    model = CompiledCNN.from_plan(plan, _cfg(), max_batch=1,
                                  exec_cache=warm)
    s = warm.stats()
    assert model.compiles > 0 and s["disk_hits"] == 0
    assert s["disk_stale"] == len(entries)
    assert "cache_disk_stale" in events
    stale = sorted(tmp_path.glob("*.stale"))
    assert len(stale) == len(entries)      # moved aside, not deleted
    assert pickle.loads(stale[0].read_bytes())["fingerprint"] \
        == ("drifted-jax", "drifted-backend")
    # the fallback compiles re-stored fresh entries at the live paths
    assert s["disk_stores"] == model.compiles


def test_corrupt_entry_quarantined_and_recompiled(tmp_path, plan):
    cold = PersistentExecutableCache(tmp_path)
    CompiledCNN.from_plan(plan, _cfg(), max_batch=1, exec_cache=cold)
    entries = sorted(tmp_path.glob("*.exe"))
    assert entries
    for p in entries:
        p.write_bytes(b"garbage that is not a pickle")

    events = []
    warm = PersistentExecutableCache(tmp_path)
    warm.on_event = lambda ev, fields: events.append(ev)
    warm_model = CompiledCNN.from_plan(plan, _cfg(), max_batch=1,
                                       exec_cache=warm)
    assert warm_model.compiles > 0     # fell back to live compiles
    assert warm.stats()["disk_errors"] > 0
    assert "cache_disk_fallback" in events
    assert list(tmp_path.glob("*.corrupt"))   # moved aside, not trusted
    # the fallback compiles re-stored fresh entries
    assert warm.stats()["disk_stores"] == warm_model.compiles


def test_disk_entry_format(tmp_path, plan):
    cache = PersistentExecutableCache(tmp_path)
    CompiledCNN.from_plan(plan, _cfg(), max_batch=1, exec_cache=cache)
    entry = pickle.loads(sorted(tmp_path.glob("*.exe"))[0].read_bytes())
    assert entry["format"] == CACHE_FORMAT_VERSION
    assert entry["fingerprint"] == cache_fingerprint()
    assert {"payload", "in_tree", "out_tree"} <= set(entry)


def test_non_jax_values_not_persisted(tmp_path):
    """Only real compiled executables go to disk — plain values built
    through the cache stay in the memory tier."""
    cache = PersistentExecutableCache(tmp_path)
    assert cache.get_or_build(("plain",), lambda: 42) == 42
    assert cache.stats()["disk_stores"] == 0
    assert not list(tmp_path.glob("*.exe"))


def test_shared_dir_across_plans_shares_layers(tmp_path, plan):
    """Content addressing: two *plans* whose layer identities coincide
    share disk entries — the second cache instance over the same dir
    deserializes them regardless of which plan stored them."""
    a = PersistentExecutableCache(tmp_path)
    CompiledCNN.from_plan(plan, _cfg(), max_batch=2, exec_cache=a)
    b = PersistentExecutableCache(tmp_path)
    model_b = CompiledCNN.from_plan(plan, _cfg(), max_batch=2,
                                    exec_cache=b)
    assert model_b.compiles == 0
    assert b.stats()["disk_hits"] > 0
