"""Roofline + model-DSE over the dry-run corpus (skips if absent)."""

import glob
import json

import pytest

from repro.core.model_dse import analytic_features, fit_dse, load_corpus
from repro.core.roofline import model_flops, roofline_terms


def _corpus():
    return load_corpus("results", "baseline")


def test_model_flops_formulas():
    r = {"arch": "x", "shape": "train_4k", "active_params": 1e9}
    assert model_flops(r) == 6e9 * 4096 * 256
    r2 = {"arch": "x", "shape": "decode_32k", "active_params": 1e9}
    assert model_flops(r2) == 2e9 * 128


def test_analytic_features_positive():
    f = analytic_features("qwen3-moe-30b-a3b", "train_4k", 256, "single")
    assert f["x_flops"] > 0 and f["x_mem"] > 0 and f["x_coll"] > 0


@pytest.mark.skipif(not glob.glob("results/baseline__*.json"),
                    reason="dry-run corpus not generated yet")
def test_roofline_terms_valid_on_corpus():
    rows = _corpus()
    assert rows, "corpus empty"
    for r in rows:
        t = roofline_terms(r)
        assert t["compute_s"] > 0
        assert t["memory_s"] > 0
        assert 0 < t["roofline_fraction"] <= 1.0001, \
            (r["arch"], r["shape"], t)
        assert t["dominant"] in ("compute_s", "memory_s", "collective_s")


@pytest.mark.skipif(len(glob.glob("results/baseline__*.json")) < 20,
                    reason="corpus too small")
def test_dse_predicts_order_of_magnitude():
    rows = _corpus()
    dse = fit_dse(rows)
    # LOO log10 MAE below 0.5 → predictions within ~3× across 6 orders of
    # magnitude of cell sizes; flops should be much tighter
    assert dse.loo["flops"]["log_mae"] < 0.5, dse.loo
