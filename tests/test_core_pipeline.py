"""End-to-end paper pipeline: sweep → correlate → fit → allocate."""

import numpy as np
import pytest

from repro.core import allocate, correlate, polyfit, synth


@pytest.fixture(scope="module")
def rows():
    return synth.run_sweep()   # cached JSON after the first benchmark run


def test_sweep_coverage(rows):
    assert len(rows) == 4 * 14 * 14
    blocks = {r["block"] for r in rows}
    assert blocks == {"conv1", "conv2", "conv3", "conv4"}


def test_conv1_has_no_mxu(rows):
    """Table 2: Conv1 uses no DSP (MXU) at all."""
    assert all(r["mxu_cost"] == 0 for r in rows if r["block"] == "conv1")
    assert all(r["mxu_cost"] > 0 for r in rows if r["block"] == "conv2")


def test_conv1_vpu_monotone_in_coeff_bits(rows):
    """Shift-add unroll: op count strictly increases with coeff bits."""
    for d in (3, 8, 16):
        ys = [r["vpu_ops"] for r in sorted(
            (r for r in rows if r["block"] == "conv1"
             and r["data_bits"] == d), key=lambda r: r["coeff_bits"])]
        assert all(a < b for a, b in zip(ys, ys[1:]))


def test_conv3_packed_regime(rows):
    """Packing happens exactly when data+coeff ≤ 12 (paper's ≤8-bit DSP
    constraint, TPU accumulator budget)."""
    for r in rows:
        if r["block"] != "conv3":
            continue
        assert bool(r["packed"]) == (r["data_bits"] + r["coeff_bits"] <= 12)


def test_conv3_packed_halves_dots(rows):
    """In the packed regime one dot produces two convolutions."""
    packed = next(r for r in rows if r["block"] == "conv3"
                  and r["data_bits"] == 4 and r["coeff_bits"] == 4)
    conv4 = next(r for r in rows if r["block"] == "conv4"
                 and r["data_bits"] == 4 and r["coeff_bits"] == 4)
    assert packed["mxu_flops"] == pytest.approx(conv4["mxu_flops"] / 2,
                                                rel=0.01)


def test_all_models_clear_gate(rows):
    for block in ("conv1", "conv2", "conv3", "conv4"):
        d, c, ys = synth.sweep_arrays(rows, block)
        for res in synth.RESOURCES:
            if np.std(ys[res]) < 1e-12:
                continue
            m = polyfit.fit_auto(d, c, ys[res], block=block)
            met = polyfit.error_metrics(ys[res], m.predict(d, c))
            assert met["r2"] >= 0.9, (block, res, met)


def test_correlations_bounded(rows):
    for block in ("conv1", "conv2", "conv3", "conv4"):
        table = correlate.correlation_table(rows, block)
        for res, entry in table.items():
            for k, v in entry.items():
                assert -1.0001 <= v <= 1.0001


def test_allocation_respects_budgets(rows):
    bm = allocate.BlockModels.fit(rows)
    alloc = allocate.allocate(bm, data_bits=8, coeff_bits=8, target=0.8)
    assert alloc.total_convs > 0
    for r, pct in alloc.usage_pct.items():
        assert pct <= 80.0 + 1e-6, (r, pct)
    # at least one resource should be nearly saturated
    assert max(alloc.usage_pct.values()) > 60.0


def test_single_block_rows(rows):
    bm = allocate.BlockModels.fit(rows)
    for block in ("conv1", "conv2", "conv3", "conv4"):
        a = allocate.allocate(bm, data_bits=8, coeff_bits=8, target=0.8,
                              only_block=block)
        assert a.counts[block] > 0
        assert all(p <= 80.0 + 1e-6 for p in a.usage_pct.values())


def test_mixed_beats_best_single(rows):
    """The paper's headline: a model-driven mixed allocation achieves more
    total convolutions than any single-block allocation."""
    bm = allocate.BlockModels.fit(rows)
    mixed = allocate.allocate(bm, data_bits=8, coeff_bits=8, target=0.8)
    singles = [allocate.allocate(bm, data_bits=8, coeff_bits=8, target=0.8,
                                 only_block=b).total_convs
               for b in ("conv1", "conv2", "conv3", "conv4")]
    assert mixed.total_convs >= max(singles)
